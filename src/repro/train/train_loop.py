"""Training step factory: microbatch gradient accumulation, remat, AdamW.

make_train_step(model, tcfg) returns a pure (state, batch) -> (state, metrics)
suitable for jax.jit with donated state. Microbatching reshapes the global
batch (B, ...) to (A, B/A, ...) and lax.scans the accumulation — this is what
bounds activation memory for the big dry-run configs (B_shard / A tokens live
at once); remat is configured on the model (scan-over-layers body).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import adamw, schedule


@dataclasses.dataclass(frozen=True)
class TrainCfg:
    microbatches: int = 1
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def init_state(model, key, tcfg: TrainCfg):
    params = model.init(key)
    mdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[tcfg.moment_dtype]
    return {"params": params, "opt": adamw.init(params, mdt)}


def make_train_step(model, tcfg: TrainCfg):
    A = tcfg.microbatches

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]

        if A == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(A, b // A, *x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mbs
            )
            grads = jax.tree.map(lambda g: g / A, grads)
            loss = loss_sum / A
            metrics = {}

        lr = schedule.warmup_cosine(
            state["opt"].step,
            peak_lr=tcfg.peak_lr,
            warmup_steps=tcfg.warmup_steps,
            total_steps=tcfg.total_steps,
        )
        new_params, new_opt, opt_metrics = adamw.update(
            grads,
            state["opt"],
            params,
            lr=lr,
            weight_decay=tcfg.weight_decay,
            grad_clip=tcfg.grad_clip,
        )
        out_metrics = {"loss": loss, "lr": lr, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step
