from .train_loop import TrainCfg, init_state, make_train_step

__all__ = ["TrainCfg", "init_state", "make_train_step"]
