"""Rosenblatt perceptron, single pass, unbiased (matches paper setup)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def fit_perceptron(X: jax.Array, y: jax.Array):
    """Returns (w, n_updates). X: (N, D), y: (N,) ±1."""

    def body(carry, xy):
        w, m = carry
        x, yn = xy
        mistake = yn * (w @ x) <= 0.0
        w = jnp.where(mistake, w + yn * x, w)
        return (w, m + mistake.astype(jnp.int32)), None

    w0 = jnp.zeros(X.shape[1], X.dtype)
    (w, m), _ = jax.lax.scan(body, (w0, jnp.asarray(0, jnp.int32)), (X, y))
    return w, m
