"""Full-batch l2-SVM — the paper's "libSVM (batch)" reference column.

Solves exactly the primal the paper states (eq. 1-2, unbiased):

    min_w  ||w||^2 + C sum_i max(0, 1 - y_i w.x_i)^2

The objective is smooth (squared hinge) and strongly convex, so full-batch
Nesterov gradient descent with a Lipschitz-based step converges to high
precision; no QP library is required. All data in memory, many passes —
deliberately NOT a streaming algorithm (it is the accuracy ceiling).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("iters",))
def fit_batch_l2svm(X: jax.Array, y: jax.Array, c: float, iters: int = 2000):
    """Returns (w, objective). Nesterov accelerated GD, fixed L-based step."""
    N, D = X.shape
    c = jnp.asarray(c, X.dtype)

    def obj_grad(w):
        margin = 1.0 - y * (X @ w)
        act = jnp.maximum(margin, 0.0)
        obj = w @ w + c * jnp.sum(act**2)
        grad = 2.0 * w - 2.0 * c * ((act * y) @ X)
        return obj, grad

    # Lipschitz constant of the gradient: 2 + 2 C lambda_max(X^T X)
    # power iteration for lambda_max
    def power(v, _):
        v = X.T @ (X @ v)
        return v / jnp.maximum(jnp.linalg.norm(v), 1e-12), None

    v0 = jnp.ones(D, X.dtype) / jnp.sqrt(D)
    v, _ = jax.lax.scan(power, v0, None, length=50)
    lam_max = jnp.linalg.norm(X.T @ (X @ v))
    L = 2.0 + 2.0 * c * lam_max
    step = 1.0 / L

    def body(carry, _):
        w, z, t = carry
        _, gz = obj_grad(z)
        w_next = z - step * gz
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_next = w_next + ((t - 1.0) / t_next) * (w_next - w)
        return (w_next, z_next, t_next), None

    w0 = jnp.zeros(D, X.dtype)
    (w, _, _), _ = jax.lax.scan(
        body, (w0, w0, jnp.asarray(1.0, X.dtype)), None, length=iters
    )
    obj, _ = obj_grad(w)
    return w, obj
