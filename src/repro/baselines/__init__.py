"""Baselines the paper compares against (Table 1 / Fig 2), re-implemented.

perceptron   — Rosenblatt, single pass.
pegasos      — Shalev-Shwartz et al. 2007 stochastic subgradient, single sweep,
               block size k (paper used k=1 and k=20).
lasvm        — Bordes et al. 2005 online SMO with PROCESS/REPROCESS, linear
               kernel, single pass.
cvm          — Tsang et al. 2005 core-vector machine: batch Badoiu-Clarkson
               core-set MEB in the same augmented space; one data pass per
               core vector (Fig 2's x-axis).
batch_l2svm  — full-batch solver of the identical l2-SVM primal (the "libSVM
               batch mode" reference column; libSVM itself is unavailable
               offline — same objective, solved to tolerance).
"""
from .perceptron import fit_perceptron
from .pegasos import fit_pegasos
from .lasvm import fit_lasvm
from .cvm import fit_cvm
from .batch_l2svm import fit_batch_l2svm

__all__ = [
    "fit_perceptron",
    "fit_pegasos",
    "fit_lasvm",
    "fit_cvm",
    "fit_batch_l2svm",
]
