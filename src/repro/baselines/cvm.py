"""CVM (Tsang et al. 2005) — batch core-set MEB SVM in the augmented space.

Badoiu-Clarkson core-set outer loop: each iteration scans the WHOLE dataset
for the farthest augmented point from the current center (= one data pass,
Fig 2's x-axis), adds it to the core set, and re-solves the core-set MEB.
Stops at (1+eps) enclosure or max_passes.

The core-set MEB is solved in explicit (D + |core|)-dim coordinates (each
core point owns one slack dimension) with Frank-Wolfe/BC iterations — the
same solver family CVM uses. Records the weight vector after every pass so
benchmarks/fig2 can plot accuracy-vs-passes against one StreamSVM pass.
"""
from __future__ import annotations

import numpy as np


def _solve_core_meb(P: np.ndarray, c_inv: float, iters: int = 2000):
    """MEB of core rows P (m, D) with per-point slack sqrt(c_inv)e_i.

    Returns (u (D,), sigma (m,), r). Explicit BC in D+m dims.
    """
    m, D = P.shape
    root = np.sqrt(c_inv)
    u = P.mean(axis=0)
    sigma = np.full(m, root / m)
    for t in range(1, iters + 1):
        d2 = (
            np.einsum("md,md->m", P - u, P - u)
            + np.sum(sigma**2)
            - 2.0 * root * sigma
            + c_inv
        )
        f = int(np.argmax(d2))
        eta = 1.0 / (t + 1.0)
        u += eta * (P[f] - u)
        sigma *= 1.0 - eta
        sigma[f] += eta * root
    d2 = (
        np.einsum("md,md->m", P - u, P - u)
        + np.sum(sigma**2)
        - 2.0 * root * sigma
        + c_inv
    )
    return u, sigma, float(np.sqrt(max(d2.max(), 0.0)))


def fit_cvm(
    X: np.ndarray,
    y: np.ndarray,
    C: float,
    eps: float = 1e-3,
    max_passes: int = 64,
    solver_iters: int = 2000,
):
    """Returns dict(w, r, core_idx, passes, w_per_pass)."""
    X = np.asarray(X, np.float64)
    y = np.asarray(y, np.float64)
    YX = y[:, None] * X
    N, D = X.shape
    c_inv = 1.0 / C

    core = [0]
    u, sigma, r = YX[0].copy(), np.array([np.sqrt(c_inv)]), 0.0
    w_per_pass = []
    passes = 0
    sig_map = np.zeros(N)
    sig_map[0] = sigma[0]

    for _ in range(max_passes):
        # one full data pass: farthest augmented point from current center
        d2_all = (
            np.einsum("nd,nd->n", YX - u, YX - u)
            + np.sum(sigma**2)
            - 2.0 * np.sqrt(c_inv) * sig_map
            + c_inv
        )
        passes += 1
        w_per_pass.append(u.copy())
        f = int(np.argmax(d2_all))
        d_far = np.sqrt(max(d2_all[f], 0.0))
        if d_far <= (1.0 + eps) * r:
            break
        if f not in core:
            core.append(f)
        u, sigma, r = _solve_core_meb(YX[np.array(core)], c_inv, iters=solver_iters)
        sig_map = np.zeros(N)
        sig_map[np.array(core)] = sigma

    return dict(w=u, r=r, core_idx=np.array(core), passes=passes, w_per_pass=w_per_pass)
