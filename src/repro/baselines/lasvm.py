"""LASVM (Bordes et al. 2005) — online SMO, linear kernel, single pass.

Faithful-in-spirit re-implementation for the unbiased linear C-SVM:
each new example triggers PROCESS (try to add it with one SMO direction
step) followed by one REPROCESS (one SMO step on the max tau-violating pair
among current support vectors), exactly the single-pass regime the paper
benchmarks. Uses y-signed alphas with box A_i = min(0, C y_i),
B_i = max(0, C y_i) and dual gradients g_i = y_i - w.x_i (linear kernel keeps
w = sum_i alpha_i x_i explicit, so every step is O(|S| D)).

numpy, sequential — this is a baseline for accuracy comparison, not a
production path.
"""
from __future__ import annotations

import numpy as np

_TAU = 1e-8


def fit_lasvm(X: np.ndarray, y: np.ndarray, C: float, return_bias: bool = False):
    """Single pass. Returns (w, n_support) or (w, b, n_support).

    The bias is recovered KKT-style after the pass: b = median over on-margin
    support vectors (0 < |alpha| < C) of (y_i - w.x_i). Real LASVM solves the
    biased SVM; without b, heavily imbalanced non-centered data (w3a) tilts
    toward the minority class.
    """
    X = np.asarray(X, np.float64)
    y = np.asarray(y, np.float64)
    N, D = X.shape

    w = np.zeros(D)
    S: list[int] = []  # indices of support candidates
    alpha = np.zeros(N)
    knorm = np.einsum("nd,nd->n", X, X)

    def g(i):
        return y[i] - X[i] @ w

    def smo_step(i, j):
        nonlocal w
        Kii, Kjj, Kij = knorm[i], knorm[j], X[i] @ X[j]
        denom = max(Kii + Kjj - 2.0 * Kij, 1e-12)
        lam = (g(i) - g(j)) / denom
        Bi = max(0.0, C * y[i])
        Aj = min(0.0, C * y[j])
        lam = min(lam, Bi - alpha[i], alpha[j] - Aj)
        if lam <= 0.0:
            return False
        alpha[i] += lam
        alpha[j] -= lam
        w += lam * (X[i] - X[j])
        return True

    def violating_extremes():
        if not S:
            return None, None
        Sv = np.array(S)
        gs = y[Sv] - X[Sv] @ w
        Bs = np.maximum(0.0, C * y[Sv])
        As = np.minimum(0.0, C * y[Sv])
        up = Sv[alpha[Sv] < Bs - 1e-12]
        dn = Sv[alpha[Sv] > As + 1e-12]
        if len(up) == 0 or len(dn) == 0:
            return None, None
        gu = y[up] - X[up] @ w
        gd = y[dn] - X[dn] @ w
        return int(up[np.argmax(gu)]), int(dn[np.argmin(gd)])

    for k in range(N):
        # PROCESS(k)
        if k not in S:
            S.append(k)
            if y[k] > 0:
                i, j = k, None
                _, j = violating_extremes()
            else:
                j, i = k, None
                i, _ = violating_extremes()
            if i is not None and j is not None and i != j:
                if g(i) - g(j) > _TAU:
                    smo_step(i, j)
        # REPROCESS: one step on the max violating pair
        i, j = violating_extremes()
        if i is not None and j is not None and i != j and (g(i) - g(j)) > _TAU:
            smo_step(i, j)
        # prune non-support (alpha == 0) to keep |S| small, LASVM-style
        if len(S) > 64 and k % 32 == 0:
            S = [s for s in S if abs(alpha[s]) > 1e-12 or s == k]

    n_sv = int(np.sum(np.abs(alpha) > 1e-12))
    if not return_bias:
        return w, n_sv
    on_margin = (np.abs(alpha) > 1e-9) & (np.abs(alpha) < C - 1e-9)
    if on_margin.any():
        b = float(np.median(y[on_margin] - X[on_margin] @ w))
    else:
        sv = np.abs(alpha) > 1e-12
        b = float(np.median(y[sv] - X[sv] @ w)) if sv.any() else 0.0
    return w, b, n_sv
