"""Pegasos (primal estimated sub-gradient SVM), single sweep, block size k.

Paper setup: "We make the Pegasos implementation do a single sweep over data
and have a user chosen block size k" (k=1, k=20). lambda maps from the SVM C
as lambda = 1/(C N) (standard correspondence).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("k",))
def fit_pegasos(X: jax.Array, y: jax.Array, lam: float, k: int = 1):
    """Single sweep in stream order with blocks of size k. Returns w.

    Truncates the trailing partial block (paper semantics unspecified; at
    N >= 4000 and k <= 20 this is < 0.5% of the data).
    """
    N, D = X.shape
    T = N // k
    Xb = X[: T * k].reshape(T, k, D)
    yb = y[: T * k].reshape(T, k)
    lam = jnp.asarray(lam, X.dtype)

    def body(w, tb):
        t, xblk, yblk = tb
        eta = 1.0 / (lam * (t + 1.0))
        margin = yblk * (xblk @ w)
        viol = (margin < 1.0).astype(X.dtype)
        grad_loss = -(viol * yblk)[:, None] * xblk  # (k, D)
        w = (1.0 - eta * lam) * w + (-eta / k) * jnp.sum(grad_loss, axis=0)
        # optional projection step of Pegasos onto ball radius 1/sqrt(lam)
        norm = jnp.linalg.norm(w)
        w = w * jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / jnp.maximum(norm, 1e-12))
        return w, None

    w0 = jnp.zeros(D, X.dtype)
    ts = jnp.arange(T, dtype=X.dtype)
    w, _ = jax.lax.scan(body, w0, (ts, Xb, yb))
    return w
