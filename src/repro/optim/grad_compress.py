"""Gradient compression for the cross-pod axis: top-k + error feedback, and
int8 quantization with per-tensor scales.

Used when the inter-pod link is the bottleneck (the `pod` axis of the
production mesh crosses DCN, not ICI). The compressor runs inside a
shard_map over the pod axis: each pod compresses its local gradient shard,
exchanges the compressed representation, and accumulates the residual into
an error-feedback buffer so the compression is unbiased over time
(Stich et al.; 1-bit Adam lineage).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: dict  # same structure/dtype as grads


def ef_init(grads_like):
    return EFState(residual=jax.tree.map(lambda x: jnp.zeros_like(x), grads_like))


def topk_compress(x: jax.Array, frac: float) -> Tuple[jax.Array, jax.Array]:
    """Keep the largest-|.| `frac` of entries. Returns (values, flat_idx)."""
    flat = x.reshape(-1)
    k = max(1, int(frac * flat.shape[0]))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_decompress(vals, idx, shape, dtype):
    flat = jnp.zeros((int(jnp.prod(jnp.asarray(shape))),), dtype)
    return flat.at[idx].set(vals.astype(dtype)).reshape(shape)


def int8_quant(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequant(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads_topk(grads, ef: EFState, frac: float):
    """Error-feedback top-k: returns (sparse_grads_dense, new_ef).

    The returned tree is dense (decompressed) so it can flow into any
    optimizer; the information bottleneck (what would cross the wire) is
    exactly the (vals, idx) pairs — bytes accounting in benchmarks/.
    """

    def one(g, r):
        acc = g.astype(jnp.float32) + r.astype(jnp.float32)
        vals, idx = topk_compress(acc, frac)
        dense = topk_decompress(vals, idx, g.shape, jnp.float32)
        return dense.astype(g.dtype), (acc - dense).astype(r.dtype)

    out = jax.tree.map(one, grads, ef.residual)
    dense = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    resid = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return dense, EFState(residual=resid)


def compressed_psum_pods(grads, mesh, frac: float, ef: EFState):
    """all-reduce gradients across the pod axis with top-k compression.

    Dense psum over ICI axes happens implicitly in the train step (GSPMD);
    this wraps ONLY the pod axis: g_pod = psum_pod(topk(g)) / n_pods.
    """
    from jax.sharding import PartitionSpec as P

    def local(g_tree, r_tree):
        def one(g, r):
            acc = g.astype(jnp.float32) + r.astype(jnp.float32)
            vals, idx = topk_compress(acc, frac)
            dense = topk_decompress(vals, idx, g.shape, jnp.float32)
            reduced = jax.lax.psum(dense, "pod") / mesh.shape["pod"]
            return reduced.astype(g.dtype), (acc - dense).astype(r.dtype)

        out = jax.tree.map(one, g_tree, r_tree)
        dense = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        resid = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return dense, EFState(residual=resid)

    fn = jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return fn(grads, ef)
