"""Hand-rolled AdamW (no optax in this environment).

Moments dtype is configurable per ArchConfig (`moment_dtype`): fp32 default;
bf16 for the 340B config so params+moments fit 16 GB/chip at 256 chips
(2+2+2 bytes/param, DESIGN.md §7). Moments inherit the param sharding, so the
optimizer is ZeRO-3-style fully sharded under the production mesh.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: dict
    v: dict
    step: jax.Array


def init(params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda x: jnp.zeros(x.shape, moment_dtype)
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))

    bc1 = 1.0 - b1**step.astype(jnp.float32)
    bc2 = 1.0 - b2**step.astype(jnp.float32)

    def upd_block(g, m, v, p):
        g32 = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1.0 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1.0 - b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(m.dtype), v32.astype(v.dtype)

    # NOTE (§Perf H5, refuted): chunking stacked-leaf updates with lax.map
    # to shrink f32 temporaries INCREASED peak memory 34 -> 47 GB at 340B —
    # the loop boundary breaks donation aliasing and forces whole-leaf
    # copies. Whole-leaf fused elementwise updates win; keep upd_block.
    out = jax.tree.map(upd_block, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return (
        new_params,
        AdamWState(m=new_m, v=new_v, step=step),
        {"grad_norm": gnorm},
    )
