from . import adamw, grad_compress, schedule

__all__ = ["adamw", "grad_compress", "schedule"]
