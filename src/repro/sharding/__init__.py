from .rules import (
    batch_spec,
    cache_spec,
    mesh_mapping,
    param_spec,
    params_shardings,
    tree_shardings,
)

__all__ = [
    "batch_spec", "cache_spec", "mesh_mapping", "param_spec",
    "params_shardings", "tree_shardings",
]
