"""Logical-axis sharding rules: param/batch/cache pytrees -> PartitionSpecs.

Two-level scheme (MaxText-style): leaf paths map to tuples of *logical* axes
by name-based rules; a mesh mapping resolves logical axes to mesh axes.
Default mapping:

  tensor-parallel axes (heads / ff / experts / vocab / d_inner) -> "model"
  fully-sharded-data-parallel axis (the remaining large dim)     -> dp axes
                                       ("pod","data") or ("data",)
  batch dims of activations/caches                               -> dp axes
  KV-cache sequence dim                                          -> "model"
    (decode attention then reduces over the sharded seq with tiny
     all-reduces — flash-decoding; see layers.direct_attention)

Any axis whose size does not divide the mesh-axis product is silently
replicated (kv=1 MQA, kv=4 GQA with model=16, 4-head xlstm states, ...).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey


# leaf-name -> logical axes (without the optional leading layer-stack dim)
PARAM_RULES: Dict[str, Tuple] = {
    # embed: vocab UNsharded so the token gather stays local (a vocab-sharded
    # table forces SPMD full-rematerialization of the gather); d_model -> tp.
    "embed": (None, "tp"),
    "unembed": ("fsdp", "vocab"),
    "wq": ("fsdp", "tp", None),
    "wk": ("fsdp", "tp", None),
    "wv": ("fsdp", "tp", None),
    "wo": ("tp", None, "fsdp"),
    "w1": ("fsdp", "tp"),
    "w3": ("fsdp", "tp"),
    "w2": ("tp", "fsdp"),
    "router": ("fsdp", None),
    # moe expert weights carry a leading experts dim (handled via ndim)
    "in_proj": ("fsdp", "tp"),
    "out_proj": ("tp", "fsdp"),
    "conv_w": (None, "tp"),
    "conv_b": ("tp",),
    "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    "w_out": ("tp", "fsdp"),
    "W": ("fsdp", "tp"),
    "R": (None, None, None),
    "w_if": ("fsdp", None),
}

MOE_RULES: Dict[str, Tuple] = {
    "w1": ("expert", "fsdp", None),
    "w3": ("expert", "fsdp", None),
    "w2": ("expert", None, "fsdp"),
}

DEFAULT_MAPPING: Dict[str, Any] = {
    "vocab": "model",
    "tp": "model",
    "expert": "model",
    "fsdp": ("data",),  # extended with "pod" on multi-pod meshes
    "dp": ("data",),
    "kvseq": "model",
}


def mesh_mapping(mesh: Mesh) -> Dict[str, Any]:
    m = dict(DEFAULT_MAPPING)
    if "pod" in mesh.axis_names:
        m["fsdp"] = ("pod", "data")
        m["dp"] = ("pod", "data")
    return m


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _resolve(logical: Tuple, shape, mesh: Mesh, mapping) -> P:
    spec = []
    for ax_name, dim in zip(logical, shape):
        axes = mapping.get(ax_name) if ax_name else None
        if axes is not None and dim % _axis_size(mesh, axes) == 0:
            spec.append(axes)
        else:
            spec.append(None)
    return P(*spec)


def _path_names(path) -> list:
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        elif isinstance(k, SequenceKey):
            out.append(int(k.idx))
    return out


def param_spec(path, leaf, mesh: Mesh, mapping=None) -> P:
    mapping = mapping or mesh_mapping(mesh)
    names = _path_names(path)
    key = next((n for n in reversed(names) if isinstance(n, str)), "")
    in_moe = "moe" in names
    rules = MOE_RULES if (in_moe and key in MOE_RULES) else PARAM_RULES
    rule = rules.get(key)
    shape = leaf.shape
    if rule is None:
        return P()  # norms, biases, scalars -> replicate
    if len(shape) == len(rule) + 1:  # stacked layer dim
        rule = (None,) + rule
    if len(shape) != len(rule):
        return P()
    return _resolve(rule, shape, mesh, mapping)


def params_shardings(params, mesh: Mesh):
    mapping = mesh_mapping(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(mesh, param_spec(p, x, mesh, mapping)), params
    )


# ---------------------------------------------------------------------------
# batch / cache / state specs
# ---------------------------------------------------------------------------


def batch_spec(path, leaf, mesh: Mesh, mapping=None) -> P:
    """Input batches: shard dim 0 (global batch) over dp axes."""
    mapping = mapping or mesh_mapping(mesh)
    dp = mapping["dp"]
    if leaf.shape and leaf.shape[0] % _axis_size(mesh, dp) == 0:
        return P(dp, *([None] * (len(leaf.shape) - 1)))
    return P()


def cache_spec(path, leaf, mesh: Mesh, mapping=None) -> P:
    """KV caches and recurrent states.

    5-D (L, B, S, KV, hd): batch->dp, seq->model (flash-decoding layout).
    4-D (B, S, KV, hd) or (B, H, p, n) ssm state: batch->dp, dim1 (seq or
    heads)->model when divisible.
    Other ranks: batch->dp only.
    """
    mapping = mapping or mesh_mapping(mesh)
    dp, tp = mapping["dp"], mapping["tp"]
    names = _path_names(path)
    shape = leaf.shape
    dp_ok = lambda d: d % _axis_size(mesh, dp) == 0
    tp_ok = lambda d: d % _axis_size(mesh, tp) == 0

    if len(shape) == 5 and ("k" in names or "v" in names):
        return P(
            None,
            dp if dp_ok(shape[1]) else None,
            tp if tp_ok(shape[2]) else None,
            None,
            None,
        )
    if len(shape) == 4:
        return P(
            dp if dp_ok(shape[0]) else None,
            tp if tp_ok(shape[1]) else None,
            None,
            None,
        )
    if len(shape) >= 1 and shape and dp_ok(shape[0]):
        return P(dp, *([None] * (len(shape) - 1)))
    return P()


def tree_shardings(tree, mesh: Mesh, spec_fn):
    mapping = mesh_mapping(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(mesh, spec_fn(p, x, mesh, mapping)), tree
    )


# ---------------------------------------------------------------------------
# serve-v2: weight-stationary decode layout (EXPERIMENTS.md §Perf H3)
#
# Baseline decode shards the global batch over dp and leaves weights
# FSDP(data)-sharded — every step re-gathers ~P bytes of weights. v2 keeps
# the identical 2-D weight sharding but maps the *data flow* so weights never
# move: batch -> model axis, KV-cache sequence -> data axis. Matmuls contract
# over the data-sharded d_model/ff dims (partial products + small activation
# all-reduces); decode attention reduces over the data-sharded sequence with
# flash-decoding partial-softmax combines. Collective bytes drop from
# O(P) to O(L * B * d) per token.
# ---------------------------------------------------------------------------


def serve_batch_spec(path, leaf, mesh: Mesh, mapping=None) -> P:
    mapping = mapping or mesh_mapping(mesh)
    tp = mapping["tp"]
    if leaf.shape and leaf.shape[0] % _axis_size(mesh, tp) == 0:
        return P(tp, *([None] * (len(leaf.shape) - 1)))
    return P()


def serve_cache_spec(path, leaf, mesh: Mesh, mapping=None) -> P:
    mapping = mapping or mesh_mapping(mesh)
    dp, tp = mapping["dp"], mapping["tp"]
    names = _path_names(path)
    shape = leaf.shape
    tp_ok = lambda d: d % _axis_size(mesh, tp) == 0
    dp_ok = lambda d: d % _axis_size(mesh, dp) == 0
    if len(shape) == 5 and ("k" in names or "v" in names):
        return P(
            None,
            tp if tp_ok(shape[1]) else None,   # batch -> model
            dp if dp_ok(shape[2]) else None,   # seq   -> data
            None,
            None,
        )
    if len(shape) == 4:  # recurrent states: batch -> model
        return P(tp if tp_ok(shape[0]) else None, None, None, None)
    if len(shape) >= 1 and shape and tp_ok(shape[0]):
        return P(tp, *([None] * (len(shape) - 1)))
    return P()
