"""Mesh-aware sharding hints usable from model code.

`shard_hint(x, dims)` applies lax.with_sharding_constraint when an abstract
mesh with the referenced axes is ambient, and is a no-op otherwise (smoke
tests / single-device runs). Logical dims:

  "dp"  -> the data-parallel axes ("pod","data") or ("data",)
  "tp"  -> the tensor-parallel axis ("model",)
  None  -> unsharded

Divisibility-guarded like rules.py: a dim that does not divide is left
unsharded rather than failing.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _ambient_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:  # pragma: no cover - API drift guard
        pass
    try:  # `with mesh:` context (legacy resource env)
        from jax._src.mesh import thread_resources

        pm = thread_resources.env.physical_mesh
        if pm is not None and pm.axis_names:
            return pm
    except Exception:  # pragma: no cover
        pass
    return None


def shard_hint(x, dims):
    """dims: tuple of "dp" | "tp" | None, one per array dim."""
    m = _ambient_mesh()
    if m is None:
        return x
    names = set(m.axis_names)
    if "model" not in names or "data" not in names:
        return x
    dp = tuple(a for a in ("pod", "data") if a in names)
    sizes = {a: m.shape[a] for a in m.axis_names}

    def size_of(tag):
        if tag == "tp":
            return sizes.get("model", 1)
        n = 1
        for a in dp:
            n *= sizes[a]
        return n

    spec = []
    for tag, dim in zip(dims, x.shape):
        if tag is None or dim % size_of(tag) != 0:
            spec.append(None)
        elif tag == "tp":
            spec.append("model")
        else:
            spec.append(dp if len(dp) > 1 else dp[0])
    return jax.lax.with_sharding_constraint(x, P(*spec))
