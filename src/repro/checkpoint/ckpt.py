"""Sharding-aware checkpointing with elastic restore and atomic commits.

save(): host-gathers every leaf (single-process container; in a multi-host
deployment each process would write its addressable shards — the manifest
format already records per-leaf sharding specs to support that) and writes
one .npz plus a JSON manifest (tree structure, dtypes, step metadata).

Commit protocol — a crash at ANY point leaves a checkpoint that restores
bit-exactly to either the previous or the new state, never a torn mix:

  1. the arrays payload is written to a fresh uniquely-named file through a
     ``.tmp`` + ``os.replace`` pair (a crash mid-write leaves only garbage
     under a name no manifest references);
  2. the manifest — which names its arrays file via ``arrays_file`` — is
     itself written ``.tmp`` + ``os.replace``: THE single commit point.
     Until it lands, the old manifest still points at the old, intact
     arrays file (this is why the arrays file is never overwritten in
     place: replacing ``arrays.npz`` under a not-yet-replaced manifest
     would marry old metadata to new arrays — a torn checkpoint that
     restores newer state than ``meta`` claims);
  3. stale arrays files from earlier commits are garbage-collected last
     (crash before cleanup leaves harmless orphans, removed next save).

restore(): rebuilds the pytree and device_puts each leaf with the sharding
derived from the *target* mesh — which may differ in size/shape from the mesh
that wrote the checkpoint. That is the elastic-rescale path: a 512-chip
checkpoint restores onto 256 or 1024 chips by re-slicing (weights are stored
logically; sharding is a property of the restore target, not the file).
An unreadable/truncated arrays payload raises a ValueError naming the file
instead of returning garbage.

StreamSVM head state (w, R, xi2, M, stream position) is O(D) and rides in the
same manifest — a preempted one-pass run resumes mid-stream without touching
already-consumed examples (the one-pass property survives restarts).
"""
from __future__ import annotations

import json
import os
import uuid
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, tree, *, meta: Optional[Dict[str, Any]] = None):
    os.makedirs(path, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays, dtypes = {}, []
    for i, x in enumerate(leaves):
        a = np.asarray(jax.device_get(x))
        dtypes.append(str(a.dtype))
        if str(a.dtype) == "bfloat16":  # numpy .npz cannot round-trip bf16
            a = a.view(np.uint16)
        arrays[f"leaf_{i}"] = a
    # Fresh name per commit; never overwrite the file the live manifest
    # references (see module docstring, step 2).
    arrays_file = f"arrays-{uuid.uuid4().hex[:12]}.npz"
    arrays_tmp = os.path.join(path, arrays_file + ".tmp")
    with open(arrays_tmp, "wb") as f:  # file object: savez must not append .npz
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(arrays_tmp, os.path.join(path, arrays_file))
    manifest = {
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "dtypes": dtypes,
        "shapes": [list(a.shape) for a in arrays.values()],
        "arrays_file": arrays_file,
        "meta": meta or {},
    }
    tmp = os.path.join(path, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(path, "manifest.json"))  # atomic commit
    for name in os.listdir(path):  # GC arrays of superseded commits
        if (
            name != arrays_file
            and name.startswith("arrays")
            and (name.endswith(".npz") or name.endswith(".tmp"))
        ):
            try:
                os.remove(os.path.join(path, name))
            except OSError:
                pass  # concurrent cleanup / permissions: orphans are harmless


def load_manifest(path: str) -> Dict[str, Any]:
    """The full manifest: treedef repr, n_leaves, dtypes, shapes, meta.

    The supported way to inspect a checkpoint's layout without a restore
    target (serve.BankServer.from_checkpoint rebuilds its Ball target from
    the shapes/dtypes here) — the on-disk format stays this module's."""
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def load_meta(path: str) -> Dict[str, Any]:
    return load_manifest(path)["meta"]


def zeros_like_manifest(manifest: Dict[str, Any], lo: int = 0, hi: Optional[int] = None):
    """Zero arrays matching the manifest's leaf slots ``[lo:hi)``.

    The building block for constructing a ``restore`` target straight from
    a manifest's recorded shapes/dtypes when no in-memory tree exists yet —
    serve.BankServer.from_checkpoint and repro.live's resume both rebuild
    their Ball/KernelBank targets this way instead of hand-rolling shapes.
    Returns a list, one leaf per slot, in manifest (flattened-tree) order.
    """
    shapes = manifest["shapes"][lo:hi]
    dtypes = manifest["dtypes"][lo:hi]
    return [jax.numpy.zeros(tuple(s), dt) for s, dt in zip(shapes, dtypes)]


def exists(path: str) -> bool:
    return os.path.exists(os.path.join(path, "manifest.json"))


def _load_arrays(path: str, manifest: Dict[str, Any]) -> Dict[str, np.ndarray]:
    """Eagerly read every leaf array, refusing torn payloads loudly.

    ``arrays_file`` defaults to the pre-atomic-commit layout's fixed name so
    old checkpoints keep restoring. npz reads are lazy (zip members decode on
    access), so a truncated payload is forced to surface HERE as a clear
    ValueError instead of as garbage mid-restore."""
    arrays_path = os.path.join(path, manifest.get("arrays_file", "arrays.npz"))
    try:
        with np.load(arrays_path) as data:
            return {name: data[name] for name in data.files}
    except Exception as e:  # BadZipFile / EOFError / zlib / OSError ...
        raise ValueError(
            f"checkpoint at {path!r}: arrays payload {arrays_path!r} is "
            f"unreadable ({type(e).__name__}: {e}) — the file is torn or "
            "corrupt; refusing to restore garbage. Restore from an older "
            "checkpoint or re-save."
        ) from e


def restore(path: str, target_tree, *, shardings=None):
    """Restore into the structure of `target_tree` (values replaced).

    `shardings`: optional matching pytree of NamedSharding for elastic
    placement on the current mesh; None leaves go wherever jnp defaults.
    """
    manifest = load_manifest(path)
    dtypes = manifest["dtypes"]
    data = _load_arrays(path, manifest)
    leaves, treedef = _flatten(target_tree)
    if len(leaves) != len(data):
        raise ValueError(
            f"checkpoint at {path!r} holds {len(data)} leaves but the "
            f"restore target has {len(leaves)} — the target tree's structure "
            "does not match what was saved (wrong checkpoint, or a "
            "differently-shaped restore target)"
        )
    new_leaves = []
    sh_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(leaves)
    )
    for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = data[f"leaf_{i}"]
        if dtypes[i] == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16.dtype)
        x = jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr)
        x = x.astype(ref.dtype) if hasattr(ref, "dtype") and x.dtype != ref.dtype else x
        new_leaves.append(x)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def replicated_shardings(target_tree, mesh):
    """A ``shardings`` pytree fully REPLICATING every leaf of ``target_tree``
    on ``mesh`` — the elastic-remesh restore target for state that must live
    whole on every device (e.g. the live loop's sub-banks, which any shard
    may merge against). A checkpoint written under an 8-device mesh restores
    replicated onto 4 devices, 1 device, or a fresh mesh of any shape —
    placement is a property of the restore call, never of the file.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    sharding = NamedSharding(mesh, PartitionSpec())
    return jax.tree_util.tree_map(lambda _: sharding, target_tree)
