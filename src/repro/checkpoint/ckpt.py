"""Sharding-aware checkpointing with elastic restore.

save(): host-gathers every leaf (single-process container; in a multi-host
deployment each process would write its addressable shards — the manifest
format already records per-leaf sharding specs to support that) and writes
one .npz plus a JSON manifest (tree structure, dtypes, step metadata).

restore(): rebuilds the pytree and device_puts each leaf with the sharding
derived from the *target* mesh — which may differ in size/shape from the mesh
that wrote the checkpoint. That is the elastic-rescale path: a 512-chip
checkpoint restores onto 256 or 1024 chips by re-slicing (weights are stored
logically; sharding is a property of the restore target, not the file).

StreamSVM head state (w, R, xi2, M, stream position) is O(D) and rides in the
same manifest — a preempted one-pass run resumes mid-stream without touching
already-consumed examples (the one-pass property survives restarts).
"""
from __future__ import annotations

import json
import os
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, tree, *, meta: Optional[Dict[str, Any]] = None):
    os.makedirs(path, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays, dtypes = {}, []
    for i, x in enumerate(leaves):
        a = np.asarray(jax.device_get(x))
        dtypes.append(str(a.dtype))
        if str(a.dtype) == "bfloat16":  # numpy .npz cannot round-trip bf16
            a = a.view(np.uint16)
        arrays[f"leaf_{i}"] = a
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "dtypes": dtypes,
        "shapes": [list(a.shape) for a in arrays.values()],
        "meta": meta or {},
    }
    tmp = os.path.join(path, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(path, "manifest.json"))  # atomic commit


def load_manifest(path: str) -> Dict[str, Any]:
    """The full manifest: treedef repr, n_leaves, dtypes, shapes, meta.

    The supported way to inspect a checkpoint's layout without a restore
    target (serve.BankServer.from_checkpoint rebuilds its Ball target from
    the shapes/dtypes here) — the on-disk format stays this module's."""
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def load_meta(path: str) -> Dict[str, Any]:
    return load_manifest(path)["meta"]


def exists(path: str) -> bool:
    return os.path.exists(os.path.join(path, "manifest.json"))


def restore(path: str, target_tree, *, shardings=None):
    """Restore into the structure of `target_tree` (values replaced).

    `shardings`: optional matching pytree of NamedSharding for elastic
    placement on the current mesh; None leaves go wherever jnp defaults.
    """
    import json as _json

    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        dtypes = _json.load(f)["dtypes"]
    leaves, treedef = _flatten(target_tree)
    assert len(leaves) == len(data.files), (len(leaves), len(data.files))
    new_leaves = []
    sh_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(leaves)
    )
    for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = data[f"leaf_{i}"]
        if dtypes[i] == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16.dtype)
        x = jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr)
        x = x.astype(ref.dtype) if hasattr(ref, "dtype") and x.dtype != ref.dtype else x
        new_leaves.append(x)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
