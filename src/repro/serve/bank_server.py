"""Bank-serving engine: microbatched query scoring against a StreamSVM bank.

The inference-side twin of the token scheduler (token_scheduler.py), built
for the deploy shape the paper's one-pass training produces: a *tiny,
constant-storage* (B, D) bank — classes x C-grid x variants — and a firehose
of queries. Same slot/utilization discipline as continuous batching, applied
to query ROWS instead of decode tokens:

  - a fixed microbatch of ``q_block`` row slots (the Pallas predict kernel's
    query-tile height, so every step is one fused kernel launch);
  - ragged requests (any number of rows each) are packed FIFO into the free
    slots of each step — a large request spans several steps, several small
    requests share one — so slot waste is only the final partial batch;
  - ``SchedulerStats``-style accounting: busy-row / idle-row utilization.

Scoring runs through ``kernels.ops.predict_bank`` (data-major tiled grid,
fused scores / per-C-grid-group ovr-argmax / topk epilogues, optional bf16
query tiles). f32 served scores are bit-exact with the direct jnp readout
``X @ bank.w.T`` (tests/test_bank_server.py pins this against
core.predict_ovr).

Train -> serve handoff: ``BankServer.from_checkpoint`` loads the stacked-Ball
bank a ``fit_chunked_many`` checkpoint callback persisted via
``repro.checkpoint.ckpt.save`` (manifest + npz), picking up ``n_classes``
from the checkpoint meta when serving OVR.

Hot swap: ``swap_bank`` replaces the bank between steps WITHOUT dropping
queued requests — rows already scored keep their results, every row scored
after the swap sees the new bank, and a same-shape swap never recompiles
(only shapes and epilogue parameters are static to the kernel's jit).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernel_bank import KernelBank
from repro.core.meb import Ball, fold_banks, fold_kernel_banks
from repro.kernels.ops import predict_bank, predict_kernel_bank


@dataclasses.dataclass
class ScoreRequest:
    """One scoring request: a ragged block of query rows and its results.

    ``result`` is filled in place as the server's microbatches cover the
    request's rows: an (n, B) f32 array for the "scores" epilogue, an
    ``((n, G) int32 class ids, (n, G) f32 margins)`` pair for "ovr", and an
    ``((n, k) f32, (n, k) int32)`` pair for "topk".
    """

    rid: int
    queries: np.ndarray  # (n, D) float32
    result: Union[np.ndarray, Tuple[np.ndarray, ...], None] = None
    rows_scored: int = 0
    done: bool = False


@dataclasses.dataclass
class ServerStats:
    """Row-slot accounting, mirroring token_scheduler.SchedulerStats."""

    steps: int = 0
    admitted: int = 0
    finished: int = 0
    slot_busy_rows: int = 0
    slot_idle_rows: int = 0
    bank_swaps: int = 0

    @property
    def utilization(self) -> float:
        tot = self.slot_busy_rows + self.slot_idle_rows
        return self.slot_busy_rows / tot if tot else 0.0


class BankServer:
    """Serve a trained (B, D) bank: microbatch, score, hot-swap.

    bank: a stacked ``Ball`` (``fit_bank``/``fit_ovr``/``fit_c_grid`` result
    or a restored checkpoint), a plain (B, D) weight array, or a
    ``KernelBank`` (``fit_kernel_bank`` result) — the kernelized bank is
    detected by its (B, S, D) core-set ``points``/(B, S) ``coef`` arrays and
    served through ``kernels.ops.predict_kernel_bank`` instead, with
    ``kernel=`` ("linear"/"rbf", REQUIRED for kernel banks) and ``gamma=``
    naming the kernel the bank was trained with (they must match the fit —
    the checkpoint meta records them, and ``from_checkpoint`` restores them
    automatically).
    epilogue/n_classes/k/q_block/b_tile/stream_dtype/bank_resident: the
    fused-kernel serving configuration — see ``kernels.ops.predict_bank``
    (``bank_resident="hbm"`` serves the bank straight out of ANY/HBM space
    through the kernel's 2-slot ring — the deploy shape for banks whose
    (B, D) footprint exceeds the VMEM budget; "auto" picks that exactly
    when it does). These are static (fixed per server); the bank itself is
    traced, so ``swap_bank`` with a same-shape bank reuses the compiled
    kernel — in any residency. Kernel banks ignore ``b_tile`` and
    ``bank_resident`` (their state is bounded by construction — the Gram
    operand streams through the tiled kernel's own block pipeline).
    """

    def __init__(
        self,
        bank,
        *,
        epilogue: str = "scores",
        n_classes: Optional[int] = None,
        k: Optional[int] = None,
        q_block: int = 256,
        b_tile: Optional[int] = None,
        stream_dtype=None,
        bank_resident: str = "auto",
        kernel: Optional[str] = None,
        gamma: float = 1.0,
        interpret: Optional[bool] = None,
    ):
        if self._is_kernel_bank(bank):
            if kernel is None:
                raise ValueError(
                    "serving a KernelBank needs kernel='linear' or 'rbf' "
                    "(the kernel the bank was trained with); pass it "
                    "explicitly or use from_checkpoint, which restores it "
                    "from the checkpoint meta"
                )
            self._w = None
            self._points, self._coef = self._kernel_bank_arrays(bank)
            b, _, d = self._points.shape
        else:
            if kernel is not None:
                raise ValueError(
                    f"kernel={kernel!r} only applies to a KernelBank; this "
                    "bank is a linear (B, D) weight bank"
                )
            self._w = self._bank_weights(bank)
            self._points = self._coef = None
            b, d = self._w.shape
        self.kernel = kernel
        self.gamma = float(gamma)
        self._b, self._d = b, d
        if epilogue not in ("scores", "ovr", "topk"):
            raise ValueError(
                f"unknown epilogue {epilogue!r}; expected 'scores', 'ovr' "
                "or 'topk'"
            )
        if epilogue == "ovr":
            if n_classes is None or n_classes < 1 or b % n_classes:
                raise ValueError(
                    f"epilogue='ovr' needs n_classes >= 1 dividing B: got "
                    f"n_classes={n_classes}, B={b}"
                )
        elif epilogue == "topk" and (k is None or not (1 <= k <= b)):
            raise ValueError(
                f"epilogue='topk' needs 1 <= k <= B: got k={k}, B={b}"
            )
        self.epilogue = epilogue
        self.n_classes = n_classes
        self.k = k
        self.q_block = int(q_block)
        self.b_tile = b_tile
        self.stream_dtype = stream_dtype
        self.bank_resident = bank_resident
        self.interpret = interpret
        self.stats = ServerStats()
        self._queue: List[ScoreRequest] = []  # FIFO; head may be partial
        self._next_rid = 0

    # -- bank management ----------------------------------------------------

    @staticmethod
    def _is_kernel_bank(bank) -> bool:
        return hasattr(bank, "points") and hasattr(bank, "coef")

    @staticmethod
    def _kernel_bank_arrays(bank) -> Tuple[jnp.ndarray, jnp.ndarray]:
        points = jnp.asarray(bank.points, jnp.float32)
        coef = jnp.asarray(bank.coef, jnp.float32)
        if points.ndim != 3 or coef.shape != points.shape[:2]:
            raise ValueError(
                f"KernelBank needs (B, S, D) points with (B, S) coef: got "
                f"points.shape={tuple(points.shape)}, coef.shape="
                f"{tuple(coef.shape)}"
            )
        return points, coef

    @staticmethod
    def _bank_weights(bank) -> jnp.ndarray:
        w = bank.w if hasattr(bank, "w") else bank
        w = jnp.asarray(w, jnp.float32)
        if w.ndim != 2:
            raise ValueError(
                f"bank must be a stacked Ball or a (B, D) weight array: got "
                f"weights of shape {w.shape}"
            )
        return w

    @property
    def bank_shape(self) -> Tuple[int, ...]:
        if self._w is None:
            return tuple(self._points.shape)
        return tuple(self._w.shape)

    def swap_bank(self, bank, *, kernel: Optional[str] = None,
                  gamma=None) -> None:
        """Replace the served bank between steps; queued requests survive.

        Rows already scored keep their (old-bank) results; every row scored
        from the next ``step()`` on sees the new bank. The new bank must
        match the current shape — (B, D) weights for a linear server,
        (B, S, D) core sets for a kernel server (a linear bank cannot swap
        into a kernel server or vice versa) — same shape means the kernel's
        jit cache is reused, so a swap never stalls serving on a recompile.

        ``kernel``/``gamma``: optionally declare the kernel config the
        incoming bank was TRAINED with; a mismatch with this server's
        config raises a ValueError naming both instead of serving silent
        garbage scores (a core-set bank scored under the wrong kernel or
        gamma is numerically valid but semantically wrong).
        """
        if kernel is not None and kernel != self.kernel:
            raise ValueError(
                f"hot-swap bank was trained with kernel={kernel!r}; this "
                f"server is configured kernel={self.kernel!r} "
                f"(gamma={self.gamma}) — scoring under a different kernel "
                "serves silent garbage; start a BankServer matching the "
                "bank's kernel config"
            )
        if (
            gamma is not None
            and self.kernel is not None
            and float(gamma) != self.gamma
        ):
            raise ValueError(
                f"hot-swap bank was trained with gamma={float(gamma)}; this "
                f"server is configured kernel={self.kernel!r} with "
                f"gamma={self.gamma} — scoring under a different gamma "
                "serves silent garbage; start a BankServer matching the "
                "bank's kernel config"
            )
        if self._w is None:
            if not self._is_kernel_bank(bank):
                raise ValueError(
                    "this server serves a KernelBank; hot-swap needs another "
                    "KernelBank of the same (B, S, D) shape"
                )
            points, coef = self._kernel_bank_arrays(bank)
            if points.shape != self._points.shape:
                raise ValueError(
                    f"hot-swap core-set shape {tuple(points.shape)} != "
                    f"served shape {tuple(self._points.shape)}; start a new "
                    "BankServer to change shape"
                )
            self._points, self._coef = points, coef
            self.stats.bank_swaps += 1
            return
        if self._is_kernel_bank(bank):
            raise ValueError(
                "this server serves a linear (B, D) bank; a KernelBank "
                "needs its own BankServer(kernel=...)"
            )
        w = self._bank_weights(bank)
        if w.shape != self._w.shape:
            raise ValueError(
                f"hot-swap bank shape {tuple(w.shape)} != served bank shape "
                f"{tuple(self._w.shape)}; start a new BankServer to change "
                "shape"
            )
        self._w = w
        self.stats.bank_swaps += 1

    @classmethod
    def from_checkpoint(cls, path: str, **kwargs) -> "BankServer":
        """Serve the bank a trainer checkpoint persisted to disk.

        ``path`` is a ``repro.checkpoint.ckpt.save`` directory whose tree is
        the stacked Ball (the ``StreamCheckpoint.ball`` handed to the
        checkpoint callback) — or, when the manifest meta carries
        ``bank_kind == "kernel"`` (a ``core.save_kernel_bank`` checkpoint),
        the 7-leaf ``KernelBank``, in which case ``kernel``/``gamma`` are
        restored from the meta unless overridden. A ``repro.live``
        StreamCheckpoint (meta carries ``live_k``) also serves directly:
        the K-slot state is restored and the live sub-banks are folded
        oldest-first — linear or kernelized per the meta's ``bank_kind`` —
        into exactly the bank the live loop itself would push next (serve
        straight from the trainer's last durable commit after a trainer
        death). The manifest's shapes/dtypes rebuild the restore target;
        ``meta["n_classes"]`` (if the trainer recorded it) fills in OVR
        serving unless overridden.
        """
        from repro.checkpoint import ckpt

        manifest = ckpt.load_manifest(path)
        shapes = manifest["shapes"]
        meta = manifest.get("meta", {})
        if "live_k" in meta:
            bank = cls._fold_live_checkpoint(path, manifest, meta, kwargs)
        elif meta.get("bank_kind") == "kernel":
            if len(shapes) != len(KernelBank._fields):
                raise ValueError(
                    f"kernel-bank checkpoint at {path!r} has {len(shapes)} "
                    f"leaves; expected the {len(KernelBank._fields)}-leaf "
                    "KernelBank a save_kernel_bank checkpoint carries"
                )
            target = KernelBank(*ckpt.zeros_like_manifest(manifest))
            kwargs.setdefault("kernel", meta.get("kernel"))
            kwargs.setdefault("gamma", float(meta.get("gamma", 1.0)))
            bank = ckpt.restore(path, target)
        elif len(shapes) != 4:
            raise ValueError(
                f"checkpoint at {path!r} has {len(shapes)} leaves; expected "
                "the 4-leaf stacked Ball (w, r, xi2, m) a fit_chunked_many "
                "checkpoint carries"
            )
        else:
            target = Ball(*ckpt.zeros_like_manifest(manifest))
            bank = ckpt.restore(path, target)
        if (
            kwargs.get("epilogue") == "ovr"
            and "n_classes" not in kwargs
            and "n_classes" in meta
        ):
            kwargs["n_classes"] = int(meta["n_classes"])
        return cls(bank, **kwargs)

    @staticmethod
    def _fold_live_checkpoint(path, manifest, meta, kwargs):
        """Fold a repro.live StreamCheckpoint into its serving bank.

        The state tree is ``{"birth": (K,), "live": (K,), "sub": stacked
        Ball|KernelBank}``; the serving bank is the Sec-4.3 fold of the
        LIVE slots, oldest (birth, slot) first — the same order and fold
        the loop's own serving fold uses, so the result is bit-identical
        (f32) to what the loop was serving at its last durable commit.
        Kernel folds read kernel/gamma/eviction from the meta (the
        save_kernel_bank meta contract) and seed the server's ``kernel=``/
        ``gamma=`` unless overridden.
        """
        from repro.checkpoint import ckpt

        kind = meta.get("bank_kind", "linear")
        sub_cls = KernelBank if kind == "kernel" else Ball
        head = ckpt.zeros_like_manifest(manifest, 0, 2)
        target = {
            "birth": head[0],
            "live": head[1].astype(bool),
            "sub": sub_cls(*ckpt.zeros_like_manifest(manifest, 2)),
        }
        state = ckpt.restore(path, target)
        live = np.asarray(state["live"])
        birth = np.asarray(state["birth"])
        order = sorted(
            (int(s) for s in np.flatnonzero(live)),
            key=lambda s: (int(birth[s]), s),
        )
        if not order:
            raise ValueError(
                f"live checkpoint at {path!r} has no live sub-bank slots — "
                "nothing to fold into a serving bank"
            )
        banks = [
            jax.tree.map(lambda x, s=s: x[s], state["sub"]) for s in order
        ]
        if kind == "kernel":
            kwargs.setdefault("kernel", meta.get("kernel"))
            kwargs.setdefault("gamma", float(meta.get("gamma", 1.0)))
            return fold_kernel_banks(
                banks,
                kernel=meta.get("kernel"),
                gamma=float(meta.get("gamma", 1.0)),
                eviction=meta.get("eviction", "smallest-coef"),
            )
        return fold_banks(banks)

    # -- request lifecycle --------------------------------------------------

    def submit(self, queries) -> ScoreRequest:
        """Queue a ragged block of query rows; returns its ScoreRequest."""
        q = np.asarray(queries, np.float32)
        if q.ndim != 2 or q.shape[1] != self._d:
            raise ValueError(
                f"queries must be (n, D={self._d}) rows: got shape "
                f"{q.shape}"
            )
        n = q.shape[0]
        b = self._b
        if self.epilogue == "scores":
            result = np.empty((n, b), np.float32)
        elif self.epilogue == "ovr":
            g = b // self.n_classes
            result = (np.empty((n, g), np.int32), np.empty((n, g), np.float32))
        else:
            result = (
                np.empty((n, self.k), np.float32),
                np.empty((n, self.k), np.int32),
            )
        req = ScoreRequest(rid=self._next_rid, queries=q, result=result)
        self._next_rid += 1
        self.stats.admitted += 1
        if n == 0:  # nothing to score — finished on arrival
            req.done = True
            self.stats.finished += 1
        else:
            self._queue.append(req)
        return req

    def pending_rows(self) -> int:
        return sum(r.queries.shape[0] - r.rows_scored for r in self._queue)

    def step(self) -> int:
        """Pack up to q_block queued rows, run ONE fused kernel launch,
        scatter results back. Returns the number of rows scored."""
        if not self._queue:
            return 0
        buf = np.zeros((self.q_block, self._d), np.float32)
        segments: List[Tuple[ScoreRequest, int, int, int]] = []
        filled = 0
        qi = 0
        while qi < len(self._queue) and filled < self.q_block:
            req = self._queue[qi]
            off = req.rows_scored
            take = min(req.queries.shape[0] - off, self.q_block - filled)
            buf[filled : filled + take] = req.queries[off : off + take]
            segments.append((req, off, take, filled))
            filled += take
            qi += 1
        if self._w is None:
            out = predict_kernel_bank(
                jnp.asarray(buf),
                self._points,
                self._coef,
                kernel=self.kernel,
                gamma=self.gamma,
                epilogue=self.epilogue,
                n_classes=self.n_classes,
                k=self.k,
                q_block=self.q_block,
                stream_dtype=self.stream_dtype,
                interpret=self.interpret,
            )
        else:
            out = predict_bank(
                jnp.asarray(buf),
                self._w,
                epilogue=self.epilogue,
                n_classes=self.n_classes,
                k=self.k,
                q_block=self.q_block,
                b_tile=self.b_tile,
                stream_dtype=self.stream_dtype,
                bank_resident=self.bank_resident,
                interpret=self.interpret,
            )
        parts = (out,) if self.epilogue == "scores" else out
        parts = tuple(np.asarray(p) for p in parts)
        finished = 0
        for req, off, take, at in segments:
            dests = (
                (req.result,) if self.epilogue == "scores" else req.result
            )
            for dst, src in zip(dests, parts):
                dst[off : off + take] = src[at : at + take]
            req.rows_scored = off + take
            if req.rows_scored == req.queries.shape[0]:
                req.done = True
                finished += 1
        self._queue = [r for r in self._queue if not r.done]
        self.stats.steps += 1
        self.stats.slot_busy_rows += filled
        self.stats.slot_idle_rows += self.q_block - filled
        self.stats.finished += finished
        return filled

    def run(self, max_steps: int = 100_000) -> ServerStats:
        """Drain the queue; raises if ``max_steps`` can't cover it.

        Every step scores at least one row, so the queue always drains given
        enough steps — ``max_steps`` is a runaway valve, and exhausting it
        with rows still pending is an error (returning would leave requests
        with uninitialized result rows)."""
        for _ in range(max_steps):
            if not self._queue:
                return self.stats
            self.step()
        if self._queue:
            raise RuntimeError(
                f"run(max_steps={max_steps}) left {self.pending_rows()} rows "
                f"pending in {len(self._queue)} request(s); raise max_steps"
            )
        return self.stats

    def score(self, queries):
        """Submit one request and drain: returns its epilogue result."""
        req = self.submit(queries)
        self.run()
        return req.result
