"""serve/ — two schedulers over one slot/utilization discipline.

token_scheduler.py: continuous batching of LLM decode slots (Orca/vLLM
style). bank_server.py: microbatched query scoring against a trained
StreamSVM (B, D) bank via the fused Pallas predict kernel, with checkpoint
loading and mid-stream bank hot-swap. scheduler.py is a compatibility shim
for the token scheduler's old location.
"""
from .bank_server import BankServer, ScoreRequest, ServerStats
from .token_scheduler import ContinuousBatcher, Request, SchedulerStats

__all__ = [
    "BankServer",
    "ContinuousBatcher",
    "Request",
    "SchedulerStats",
    "ScoreRequest",
    "ServerStats",
]
