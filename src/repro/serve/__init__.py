from .scheduler import ContinuousBatcher, Request, SchedulerStats

__all__ = ["ContinuousBatcher", "Request", "SchedulerStats"]
