"""Continuous-batching TOKEN scheduler (slot-based LLM decode management).

One of serve/'s two schedulers: this module batches LLM decode steps;
``bank_server.py`` microbatches query scoring against a trained StreamSVM
bank (same slot/stats discipline, applied to rows instead of tokens).

The Orca/vLLM idea mapped to JAX with static shapes: a fixed pool of B
slots; requests join as slots free (admission = single-request prefill whose
state is scattered into the slot), every decode step advances all busy slots
together, finished requests release their slot immediately — no
head-of-line blocking on the longest request in the batch.

Scope: exact for the *recurrent* families (xlstm, and zamba2's SSM/conv
states), whose per-slot state is position-free — a fresh request's state
drops into any slot at any time. Attention-family continuous batching
additionally needs per-slot cache positions inside attention (per-slot RoPE
offsets + scatter writes); that is an engine-level extension flagged in
DESIGN.md §future. Recurrent models are precisely where the paper's
constant-state philosophy makes continuous batching trivial.

Throughput accounting: `SchedulerStats.utilization` = busy-slot-tokens /
total-slot-tokens; static batching of mixed-length requests wastes the
difference (measured in tests/test_serving.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new: int = 32
    eos_id: Optional[int] = None
    generated: Optional[List[int]] = None
    done: bool = False


@dataclasses.dataclass
class SchedulerStats:
    steps: int = 0
    admitted: int = 0
    finished: int = 0
    slot_busy_tokens: int = 0
    slot_idle_tokens: int = 0

    @property
    def utilization(self) -> float:
        tot = self.slot_busy_tokens + self.slot_idle_tokens
        return self.slot_busy_tokens / tot if tot else 0.0


def _scatter_slot(slot_state, one_state, slot: int):
    """Copy a batch-1 request state into `slot` of the slot-batched state.

    Leaf convention: any leaf whose dim-0 equals the slot batch in the big
    tree and 1 in the small tree is a per-slot state; scalars pass through.
    """

    def one_leaf(big, small):
        big = jnp.asarray(big)
        small = jnp.asarray(small)
        if big.ndim == 0 or big.shape == small.shape:
            return big
        if small.ndim == big.ndim and small.shape[0] == 1:
            return jax.lax.dynamic_update_slice_in_dim(
                big, small.astype(big.dtype), slot, axis=0
            )
        return big

    return jax.tree.map(one_leaf, slot_state, one_state)


class ContinuousBatcher:
    def __init__(self, model, params, n_slots: int, max_len: int = 4096):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        st = model.decode_state(n_slots, 1)
        self.state = {**st, "pos": jnp.asarray(0, jnp.int32)}
        self.active: Dict[int, Request] = {}
        self.last_tok = np.zeros((n_slots, 1), np.int32)
        self.stats = SchedulerStats()
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, {**b, "max_len": max_len})
        )

    def free_slots(self) -> List[int]:
        return [s for s in range(self.n_slots) if s not in self.active]

    def admit(self, req: Request) -> bool:
        slots = self.free_slots()
        if not slots:
            return False
        slot = slots[0]
        logits, st = self._prefill(
            self.params, {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
        )
        self.state = {
            **_scatter_slot({k: v for k, v in self.state.items() if k != "pos"},
                            {k: v for k, v in st.items() if k != "pos"}, slot),
            "pos": self.state["pos"],
        }
        tok = int(jnp.argmax(logits[0]))
        req.generated = [tok]
        self.last_tok[slot, 0] = tok
        self.active[slot] = req
        self.stats.admitted += 1
        return True

    def _release(self, slot: int):
        req = self.active.pop(slot)
        req.done = True
        self.stats.finished += 1

    def step(self):
        if not self.active:
            return
        logits, self.state = self._decode(
            self.params, self.state, jnp.asarray(self.last_tok)
        )
        toks = np.array(jnp.argmax(logits, -1), np.int32)  # writable copy
        self.stats.steps += 1
        self.stats.slot_busy_tokens += len(self.active)
        self.stats.slot_idle_tokens += self.n_slots - len(self.active)
        for slot in list(self.active):
            req = self.active[slot]
            tok = int(toks[slot])
            req.generated.append(tok)
            if (req.eos_id is not None and tok == req.eos_id) or len(
                req.generated
            ) >= req.max_new:
                self._release(slot)
        self.last_tok = toks[:, None]

    def run(self, requests: List[Request], max_steps: int = 10_000) -> SchedulerStats:
        pending = list(requests)
        for _ in range(max_steps):
            while pending and self.admit(pending[0]):
                pending.pop(0)
            if not self.active and not pending:
                break
            self.step()
        return self.stats
