"""Compatibility shim — serve/ now holds TWO schedulers; import from them.

The LLM continuous batcher that used to live here moved (unchanged) to
``serve/token_scheduler.py``: a fixed pool of decode slots, requests admitted
as slots free, every decode step advancing all busy slots together.

Its inference-side sibling is ``serve/bank_server.py``: the same
slot/utilization discipline applied to StreamSVM bank serving — ragged
request batches microbatched into fixed (q_block,) row slots and scored
against a trained (B, D) bank by the fused Pallas predict kernel
(kernels.ops.predict_bank), with checkpoint loading and mid-stream bank
hot-swap.

This module re-exports the token scheduler's public names so existing
imports keep working; new code should import from the specific module (or
from ``repro.serve``, which exports both).
"""
from .token_scheduler import ContinuousBatcher, Request, SchedulerStats

__all__ = ["ContinuousBatcher", "Request", "SchedulerStats"]
