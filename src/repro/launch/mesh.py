"""Production mesh builders (functions, not module constants — importing this
module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod
    axis crosses DCN; data/model stay on ICI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_devices: int | None = None, model_axis: int = 1):
    """Small mesh over actually-available devices (tests/examples)."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
