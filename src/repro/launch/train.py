"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --smoke --steps 50 [--batch 8] [--seq 128] [--ckpt-dir DIR] [--resume]

Builds the selected architecture (full or --smoke reduced config), runs the
jit'd train step over the synthetic token pipeline with checkpointing every
--ckpt-every steps, and resumes from the newest checkpoint when --resume is
set. On a real TPU deployment the same entry point runs under
`jax.distributed.initialize()` with the production mesh from launch/mesh.py;
in this CPU container it drives the single-device path (the multi-device
config is exercised by launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import get_config, list_archs
from repro.data.tokens import token_batches
from repro.models import build_model
from repro.train import TrainCfg, init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if not args.smoke:
        print("WARNING: full config on this host is for dry-run only; "
              "use --smoke for an actual CPU run.")
    model = build_model(cfg)
    tcfg = TrainCfg(peak_lr=args.lr, warmup_steps=max(2, args.steps // 10),
                    total_steps=args.steps, microbatches=args.microbatches,
                    moment_dtype=cfg.moment_dtype)
    state = init_state(model, jax.random.PRNGKey(0), tcfg)
    start = 0
    if args.resume and ckpt.exists(args.ckpt_dir):
        meta = ckpt.load_meta(args.ckpt_dir)
        state = ckpt.restore(args.ckpt_dir, state)
        start = int(meta["step"])
        print(f"resumed from step {start}")

    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params; steps {start}->{args.steps}")
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))

    extras = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = jnp.zeros(
            (args.batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        extras["frames"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)

    t0 = time.time()
    batches = token_batches(cfg.vocab, args.batch, args.seq, args.steps, seed=1)
    for i, b in enumerate(batches):
        if i < start:
            continue
        b = {k: jnp.asarray(v) for k, v in b.items()} | extras
        state, m = step_fn(state, b)
        if (i + 1) % args.ckpt_every == 0 or (i + 1) == args.steps:
            ckpt.save(args.ckpt_dir, state, meta={"step": i + 1})
            tokens = args.batch * args.seq * (i + 1 - start)
            print(f"step {i+1:5d} loss={float(m['loss']):.4f} "
                  f"tok/s={tokens/(time.time()-t0):.0f} [ckpt]", flush=True)
    print(f"done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
