import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds abstract params / optimizer state / batch (ShapeDtypeStructs —
     no allocation),
  2. jits the train_step / prefill / decode_step with explicit in/out
     shardings from repro.sharding.rules,
  3. .lower().compile() against the 16x16 (single-pod, 256 chips) and
     2x16x16 (multi-pod, 512 chips) meshes,
  4. records memory_analysis(), cost_analysis() and the per-collective
     byte totals parsed from the optimized HLO,
  5. appends one JSON record per cell to --out (results cache: cells already
     present are skipped unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
      --shape train_4k --mesh single,multi --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, applicable, get_config, list_archs
from repro.configs.base import ArchConfig
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim import adamw
from repro.sharding import rules as R
from repro.train import TrainCfg, make_train_step

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
# operand/result types like bf16[2,16,4096]{...} inside an HLO instruction
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)\[([0-9,]*)\]")

BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
         "pred": 1, "s64": 8, "f64": 8}


def collective_bytes(hlo_text: str):
    """Sum *operand* bytes per collective kind from optimized HLO text."""
    out = {k: 0 for k in
           ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute")}
    counts = dict(out)
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        if f" {kind}(" not in line and f"{kind}-start(" not in line:
            continue
        # operands appear after the op name's '('
        try:
            args = line.split("(", 1)[1]
        except IndexError:
            continue
        total = 0
        for dm in SHAPE_RE.finditer(args):
            dt, dims = dm.groups()
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            total += n * BYTES[dt]
        out[kind] += total
        counts[kind] += 1
    return out, counts


def microbatches_for(cfg: ArchConfig, shape) -> int:
    """Accumulation factor keeping live activations ~O(1 GB)/device.

    Global batch 256 over dp=16 -> 16/shard; A=16 leaves 1 sequence per
    shard per microbatch for the largest models."""
    if shape.kind != "train":
        return 1
    # §Perf H4 (refuted): halving A for MoE-235B to halve FSDP gather
    # traffic costs +20 GB peak (dispatch buffers scale with per-mb tokens)
    # and breaks the 16 GB fit; A=16 stands.
    if cfg.unrolled:
        # §Perf H7: unrolled families are per-mb-activation bound; A=16
        # halves their live activations vs A=8.
        return 16
    big = cfg.n_params() > 20e9
    return 16 if big else 8


def _train_artifacts(cfg, model, mesh):
    tcfg = TrainCfg(
        microbatches=microbatches_for(cfg, SHAPES["train_4k"]),
        moment_dtype=cfg.moment_dtype,
    )
    step = make_train_step(model, tcfg)
    params_sds = S.params_specs(model)
    mdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moment_dtype]
    opt_sds = jax.eval_shape(lambda p: adamw.init(p, mdt), params_sds)
    state_sds = {"params": params_sds, "opt": opt_sds}

    p_sh = R.tree_shardings(params_sds, mesh, R.param_spec)
    state_sh = {
        "params": p_sh,
        "opt": adamw.AdamWState(
            m=p_sh, v=p_sh,
            step=jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        ),
    }
    return step, state_sds, state_sh, tcfg


def lower_cell(cfg: ArchConfig, shape, mesh, mesh_name: str):
    model = build_model(cfg, remat="full" if shape.kind == "train" else "none")
    rec = {}
    t0 = time.time()
    if shape.kind == "train":
        step, state_sds, state_sh, tcfg = _train_artifacts(cfg, model, mesh)
        batch_sds = S.train_batch_specs(cfg, shape)
        batch_sh = R.tree_shardings(batch_sds, mesh, R.batch_spec)
        jitted = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(state_sds, batch_sds)
        rec["microbatches"] = tcfg.microbatches
    elif shape.kind == "prefill":
        params_sds = S.params_specs(model)
        p_sh = R.tree_shardings(params_sds, mesh, R.param_spec)
        batch_sds = S.prefill_batch_specs(cfg, shape)
        batch_sh = R.tree_shardings(batch_sds, mesh, R.batch_spec)
        cache_sds = jax.eval_shape(
            lambda p, b: model.prefill(p, b), params_sds, batch_sds
        )
        out_sh = (
            None,
            R.tree_shardings(cache_sds[1], mesh, R.cache_spec),
        )
        jitted = jax.jit(
            lambda p, b: model.prefill(p, b),
            in_shardings=(p_sh, batch_sh),
            out_shardings=out_sh,
        )
        lowered = jitted.lower(params_sds, batch_sds)
    else:  # decode
        serve_v2 = os.environ.get("REPRO_SERVE_SHARDING", "v1") == "v2"
        params_sds = S.params_specs(model)
        p_sh = R.tree_shardings(params_sds, mesh, R.param_spec)
        cache_sds, tokens_sds = S.decode_specs(model, cfg, shape)
        cspec = R.serve_cache_spec if serve_v2 else R.cache_spec
        bspec = R.serve_batch_spec if serve_v2 else R.batch_spec
        cache_sh = R.tree_shardings(cache_sds, mesh, cspec)
        tok_sh = R.tree_shardings(tokens_sds, mesh, bspec)
        rec["serve_sharding"] = "v2-weight-stationary" if serve_v2 else "v1"
        jitted = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t),
            in_shardings=(p_sh, cache_sh, tok_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_sds, cache_sds, tokens_sds)

    rec["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    if mem is not None:
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            v = getattr(mem, k, None)
            if v is not None:
                rec[k] = int(v)
    cost = compiled.cost_analysis() or {}
    rec["flops"] = float(cost.get("flops", -1))
    rec["bytes_accessed"] = float(cost.get("bytes accessed", -1))
    cbytes, ccounts = collective_bytes(compiled.as_text())
    rec["collective_bytes"] = cbytes
    rec["collective_counts"] = ccounts
    rec["mesh"] = mesh_name
    rec["devices"] = int(mesh.size)
    return rec


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: Path, force=False):
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    out_path = out_dir / f"{cell_id}.json"
    if out_path.exists() and not force:
        print(f"[skip cached] {cell_id}")
        return json.loads(out_path.read_text())
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "kind": shape.kind}
    if not ok:
        rec.update({"status": "SKIP", "reason": why})
    else:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        try:
            with mesh:
                rec.update(lower_cell(cfg, shape, mesh, mesh_name))
            rec["status"] = "OK"
            rec["model_flops_6nd"] = 6.0 * cfg.active_params() * (
                shape.global_batch * shape.seq_len if shape.kind == "train"
                else shape.global_batch * (shape.seq_len if shape.kind == "prefill" else 1)
            )
            rec["n_params"] = cfg.n_params()
            rec["active_params"] = cfg.active_params()
        except Exception as e:  # a failure here is a bug in the system
            rec["status"] = "FAIL"
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-4000:]
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    status = rec["status"]
    extra = "" if status != "OK" else (
        f" compile={rec.get('compile_s')}s flops={rec.get('flops'):.3g}"
    )
    print(f"[{status}] {cell_id}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = list_archs() if (args.all or args.arch is None) else args.arch.split(",")
    shapes = list(SHAPES) if (args.all or args.shape is None) else args.shape.split(",")
    meshes = args.mesh.split(",")

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                rec = run_cell(arch, shape, mesh_name, out_dir, force=args.force)
                n_fail += rec["status"] == "FAIL"
    print(f"done; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
