"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

input_specs(cfg, shape) returns the abstract batch for a cell; together with
jax.eval_shape over model.init / decode_state this lets the dry-run lower and
compile every (arch x shape x mesh) cell without materializing a single
weight. The VLM/audio modality frontends are stubs per the assignment: their
`image_embeds` / `frames` are precomputed-embedding inputs.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": SDS((B, S), jnp.int32),
        "targets": SDS((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = SDS((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = SDS((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    batch = train_batch_specs(cfg, shape)
    batch.pop("targets")
    return batch


def decode_specs(model, cfg: ArchConfig, shape: ShapeSpec):
    """(cache_sds, tokens_sds) — one new token against a seq_len cache."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: model.decode_state(B, S))
    tokens = SDS((B, 1), jnp.int32)
    return cache, tokens


def params_specs(model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))
