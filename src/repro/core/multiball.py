"""Multi-ball StreamSVM — the paper's Sec 4.3 general case, implemented.

The paper *describes* maintaining L balls ("the L balls plus the new data
point should be merged, resulting again into a set of L balls") but only
implements the degenerate lookahead special case. Here is the general
algorithm, jit-compatible:

state: L ball slots (stacked Ball pytree) + active mask.
per point (not enclosed by any active ball):
  - if a slot is free: open a new zero-radius ball at the point;
  - else: evaluate all merge options — point into ball j (L options), or
    balls (i, j) merged with the point opening the freed slot (L(L-1)/2
    options) — and apply the one minimizing the largest resulting radius.
final classifier: fold-merge the active balls into one (same readout as
Algorithm 1), or keep the L balls as a piecewise classifier (max-decision).

Cost: O(L^2 + L D) per update — polylog-compatible for L = O(log N).
Because merging is deferred and spatially informed, multiball preserves
cluster structure that a single greedy ball destroys; EXPERIMENTS.md §Beyond
measures the effect on stream-order robustness.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .meb import Ball, fold_merge, merge_balls


class MultiBall(NamedTuple):
    w: jax.Array  # (L, D)
    r: jax.Array  # (L,)
    xi2: jax.Array  # (L,)
    m: jax.Array  # (L,) int32
    active: jax.Array  # (L,) bool


def _ball_at(mb: MultiBall, i) -> Ball:
    return Ball(w=mb.w[i], r=mb.r[i], xi2=mb.xi2[i], m=mb.m[i])


def _set_ball(mb: MultiBall, i, b: Ball, active=True) -> MultiBall:
    return MultiBall(
        w=mb.w.at[i].set(b.w),
        r=mb.r.at[i].set(b.r),
        xi2=mb.xi2.at[i].set(b.xi2),
        m=mb.m.at[i].set(b.m),
        active=mb.active.at[i].set(active),
    )


@partial(jax.jit, static_argnames=("n_balls", "c", "variant"))
def fit_multiball(
    X: jax.Array, y: jax.Array, c: float, n_balls: int = 4, variant: str = "exact"
) -> MultiBall:
    """Single pass with L ball slots. X: (N, D), y: (N,) ±1."""
    L = n_balls
    N, D = X.shape
    c_inv = jnp.asarray(1.0 / c, X.dtype)
    slack0 = c_inv if variant == "exact" else jnp.asarray(1.0, X.dtype)

    mb0 = MultiBall(
        w=jnp.zeros((L, D), X.dtype).at[0].set(y[0] * X[0]),
        r=jnp.zeros((L,), X.dtype),
        xi2=jnp.zeros((L,), X.dtype).at[0].set(slack0),
        m=jnp.zeros((L,), jnp.int32).at[0].set(1),
        active=jnp.zeros((L,), bool).at[0].set(True),
    )

    ii, jj = jnp.triu_indices(L, k=1)

    def point_ball(row) -> Ball:
        return Ball(
            w=row, r=jnp.asarray(0.0, X.dtype), xi2=slack0, m=jnp.asarray(1, jnp.int32)
        )

    def step(mb: MultiBall, row):
        # distances to every ball (inactive -> +inf)
        d2 = jnp.sum((mb.w - row[None, :]) ** 2, -1) + mb.xi2 + c_inv
        d = jnp.sqrt(jnp.maximum(d2, 1e-12))
        d = jnp.where(mb.active, d, jnp.inf)
        enclosed = jnp.any(d <= mb.r)

        def absorb(mb):
            pb = point_ball(row)
            free = jnp.argmin(mb.active)  # first False slot, or 0 if none
            has_free = ~jnp.all(mb.active)

            # option A: new point into free slot (radius increase: 0)
            # option B_j: merge point into ball j -> radius of merged ball
            into_j = jax.vmap(lambda i: merge_balls(_ball_at(mb, i), pb))(
                jnp.arange(L)
            )
            cost_b = jnp.where(mb.active, into_j.r, jnp.inf)
            best_b = jnp.argmin(cost_b)

            def do_free(mb):
                return _set_ball(mb, free, pb)

            def do_b(mb):
                merged = jax.tree.map(lambda x: x[best_b], into_j)
                return _set_ball(mb, best_b, merged)

            if L == 1:  # no pair-merge option exists
                return jax.lax.cond(has_free, do_free, do_b, mb)

            # option C_(i,j): merge balls i,j; point opens the freed slot
            pair = jax.vmap(lambda a, b: merge_balls(_ball_at(mb, a), _ball_at(mb, b)))(
                ii, jj
            )
            cost_c = jnp.where(mb.active[ii] & mb.active[jj], pair.r, jnp.inf)
            best_c = jnp.argmin(cost_c)
            use_c = cost_c[best_c] < cost_b[best_b]

            def do_c(mb):
                merged = jax.tree.map(lambda x: x[best_c], pair)
                mb = _set_ball(mb, ii[best_c], merged)
                return _set_ball(mb, jj[best_c], pb)

            return jax.lax.cond(
                has_free, do_free, lambda m_: jax.lax.cond(use_c, do_c, do_b, m_), mb
            )

        mb = jax.lax.cond(enclosed, lambda m_: m_, absorb, mb)
        return mb, None

    yx = y[:, None] * X
    mb, _ = jax.lax.scan(step, mb0, yx[1:])
    return mb


# ---------------------------------------------------------------------------
# Ball banks — B *independent* models sharing one pass over the stream
# ---------------------------------------------------------------------------
#
# Distinct from the L-slot algorithm above (one model, L interacting balls):
# a *bank* is a stacked Ball pytree with leading axis B where every model
# (classes x C-grid x variants) runs its own Algorithm 1, and the Pallas
# engine (kernels.ops.streamsvm_fit_many) amortizes ONE HBM read of each
# (block_n, D) tile across all B conditional updates. B passes of math,
# one pass of data movement.


def fit_bank(
    X: jax.Array,
    Y: jax.Array,
    cs,
    balls: Ball | None = None,
    *,
    variant: str = "exact",
    lookahead=None,
    block_n: int = 256,
    b_tile: int | None = None,
    stream_dtype=None,
    bank_resident: str = "auto",
    mesh=None,
    shard_axis="data",
    interpret: bool | None = None,
) -> Ball:
    """One-pass fit of a bank of B models via the tiled multi-ball engine.

    X: (N, D) shared stream; Y: (B, N) per-model label signs; cs: scalar or
    (B,) per-model C. Continues from ``balls`` (stacked Ball) when given.
    ``b_tile`` tiles the bank across the engine's second grid axis (any B in
    one stream pass), ``stream_dtype="bf16"`` halves stream HBM traffic, and
    ``variant="lookahead"`` runs fused Algorithm 2 with per-model windows
    (``lookahead``: int or length-B tuple, static) — see kernels.ops.

    ``bank_resident``: "vmem" / "hbm" / "auto" — where the bank lives while
    the grid runs. "hbm" double-buffers (b_tile, D) slices through a VMEM
    ring so B*D is no longer capped by VMEM scratch (bit-exact f32 with
    "vmem"); "auto" picks from the per-step byte model in kernels.ops.

    ``mesh=`` additionally shards the STREAM over the ``shard_axis`` axes of
    a device mesh: each shard runs the engine over its contiguous range and
    the per-shard banks are folded with the Sec-4.3 merge (see
    distributed.fit_bank_sharded — N need not divide the shard count).
    Residency is resolved PER SHARD (each device runs its own engine pass
    over an identical-size range, so every shard picks the same mode).
    """
    if mesh is not None:
        from .distributed import fit_bank_sharded  # lazy: module cycle

        return fit_bank_sharded(
            X, Y, cs, mesh, balls,
            axis=shard_axis, variant=variant, lookahead=lookahead,
            block_n=block_n, b_tile=b_tile, stream_dtype=stream_dtype,
            bank_resident=bank_resident, interpret=interpret,
        )
    from repro.kernels.ops import streamsvm_fit_many  # lazy: avoids core<->kernels cycle

    return streamsvm_fit_many(
        X, Y, cs, balls,
        variant=variant, lookahead=lookahead, block_n=block_n,
        b_tile=b_tile, stream_dtype=stream_dtype,
        bank_resident=bank_resident, interpret=interpret,
    )


def bank_take(bank: Ball, i) -> Ball:
    """Model i of a stacked bank as a plain single Ball."""
    return jax.tree.map(lambda x: x[i], bank)


def bank_stack(balls) -> Ball:
    """Stack an iterable of single Balls into a bank (leading axis B)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *list(balls))


def to_single_ball(mb: MultiBall) -> Ball:
    """Merge all active balls (inactive slots folded as zero-size dupes of 0)."""
    # replace inactive slots with copies of the first active ball
    first = jnp.argmax(mb.active)
    rep = lambda arr: jnp.where(
        mb.active.reshape((-1,) + (1,) * (arr.ndim - 1)), arr, arr[first]
    )
    balls = Ball(w=rep(mb.w), r=rep(mb.r), xi2=rep(mb.xi2),
                 m=jnp.where(mb.active, mb.m, 0))
    return fold_merge(balls)


def decision_function(mb: MultiBall, X: jax.Array, mode: str = "merged") -> jax.Array:
    if mode == "merged":
        return X @ to_single_ball(mb).w
    # piecewise: each ball votes with its own center, weighted by closeness
    scores = X @ mb.w.T  # (N, L)
    return jnp.sum(jnp.where(mb.active[None, :], scores, 0.0), -1)
