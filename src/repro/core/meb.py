"""Ball algebra for the augmented-space MEB that underlies the l2-SVM.

A ``Ball`` is the streaming state of StreamSVM: the center of the minimum
enclosing ball in the augmented feature space ``phi~(z_n) = [y_n x_n ;
C^{-1/2} e_n]`` is ``[w ; sigma]`` where ``sigma`` is the slack block. Because
every example contributes a fresh orthogonal slack direction and is seen only
once, ``sigma`` never needs to be stored: its squared norm ``xi2`` suffices
for every distance computation the algorithm performs (paper, Sec. 4.1).

All functions are branch-free (jnp.where) so they jit/vmap/scan cleanly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

_EPS = 1e-12


class Ball(NamedTuple):
    """Streaming MEB state == StreamSVM classifier state.

    w:   (D,) feature block of the ball center == SVM weight vector.
    r:   () radius.
    xi2: () squared norm of the slack block of the center.
    m:   () int32 — number of core vectors absorbed (paper's M).
    """

    w: jax.Array
    r: jax.Array
    xi2: jax.Array
    m: jax.Array

    @property
    def dim(self) -> int:
        return self.w.shape[-1]


def make_ball(w, r=0.0, xi2=0.0, m=1) -> Ball:
    w = jnp.asarray(w)
    dt = w.dtype
    return Ball(
        w=w,
        r=jnp.asarray(r, dt),
        xi2=jnp.asarray(xi2, dt),
        m=jnp.asarray(m, jnp.int32),
    )


def center_distance(b1: Ball, b2: Ball) -> jax.Array:
    """Distance between two ball centers in the augmented space.

    Valid when the two balls were built from disjoint example sets (always
    true for stream shards): their slack blocks are orthogonal, so
    ``|c1-c2|^2 = |w1-w2|^2 + xi1^2 + xi2^2``.
    """
    d2 = jnp.sum((b1.w - b2.w) ** 2) + b1.xi2 + b2.xi2
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def point_distance(ball: Ball, yx: jax.Array, c_inv) -> jax.Array:
    """Distance from the ball center to augmented point [y x ; C^{-1/2} e_new].

    ``yx`` is the label-signed feature row y*x; ``c_inv`` is 1/C. The point's
    slack direction is fresh, hence the ``+ xi2 + 1/C`` closed form
    (Algorithm 1, line 5).
    """
    d2 = jnp.sum((ball.w - yx) ** 2) + ball.xi2 + c_inv
    return jnp.sqrt(jnp.maximum(d2, _EPS))


def enclose_point(ball: Ball, yx: jax.Array, c_inv, *, variant: str = "exact") -> Ball:
    """Algorithm 1 inner update, unconditionally applied (branchless).

    Returns the smallest ball enclosing ``ball`` and the augmented point.
    Caller selects with the ``d >= r`` predicate. ``variant``:
      - "exact": slack recursion xi2 <- xi2 (1-s)^2 + s^2 / C (exact
        bookkeeping of the augmented center; see DESIGN.md erratum note).
      - "paper-listing": verbatim line 9, xi2 <- xi2 (1-s)^2 + s^2.
    """
    d = point_distance(ball, yx, c_inv)
    s = 0.5 * (1.0 - ball.r / d)  # step toward the new point
    w = ball.w + s * (yx - ball.w)
    r = ball.r + 0.5 * (d - ball.r)
    slack_gain = c_inv if variant == "exact" else jnp.asarray(1.0, ball.xi2.dtype)
    xi2 = ball.xi2 * (1.0 - s) ** 2 + (s**2) * slack_gain
    return Ball(w=w, r=r, xi2=xi2, m=ball.m + 1)


def merge_balls(b1: Ball, b2: Ball) -> Ball:
    """Smallest ball enclosing two balls built from disjoint example sets.

    Exact in the augmented space (slack blocks orthogonal). This is the
    paper's Sec 4.3 multi-ball merge; we use it as the cross-shard collective
    combiner. Branch-free: handles mutual containment and coincident centers.
    """
    dist = center_distance(b1, b2)
    safe = jnp.maximum(dist, _EPS)

    one_in_two = dist + b1.r <= b2.r
    two_in_one = dist + b2.r <= b1.r

    r_join = 0.5 * (b1.r + b2.r + dist)
    t = jnp.clip((r_join - b1.r) / safe, 0.0, 1.0)
    w_join = b1.w + t * (b2.w - b1.w)
    xi2_join = (1.0 - t) ** 2 * b1.xi2 + t**2 * b2.xi2

    w = jnp.where(one_in_two, b2.w, jnp.where(two_in_one, b1.w, w_join))
    r = jnp.where(one_in_two, b2.r, jnp.where(two_in_one, b1.r, r_join))
    xi2 = jnp.where(one_in_two, b2.xi2, jnp.where(two_in_one, b1.xi2, xi2_join))
    return Ball(w=w, r=r, xi2=xi2, m=b1.m + b2.m)


def merge_banks(b1: Ball, b2: Ball) -> Ball:
    """Sec-4.3 merge vmapped over a leading bank axis: B models at once.

    Both arguments are Balls stacked on a leading B axis (w: (B, D), scalars
    (B,)); model b of the result merges model b of each bank — the lanes
    never interact.
    """
    return jax.vmap(merge_balls)(b1, b2)


def stack_banks(banks) -> Ball:
    """Stack an iterable of same-shape Ball banks on a NEW leading axis.

    K banks of shape (B, D) become one stacked Ball with w: (K, B, D) —
    the layout ``fold_merge`` folds bank-wise and the live loop checkpoints
    (repro.live keeps its K rotating sub-banks exactly like this).
    """
    banks = list(banks)
    if not banks:
        raise ValueError("stack_banks needs at least one bank; got an empty sequence")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *banks)


def fold_banks(banks) -> Ball:
    """Sec-4.3 fold of a python sequence of same-shape banks, in order.

    The sub-bank fold helper behind the live loop's drift repair: K rotating
    sub-banks — each a (B, D) stacked Ball trained over its own span of the
    stream, hence disjoint example sets — fold left-to-right (callers pass
    oldest first) into ONE serving bank via the bank-vectorized merge.
    Equivalent to ``fold_merge(stack_banks(banks))``; a single bank passes
    through untouched.
    """
    banks = list(banks)
    if not banks:
        raise ValueError("fold_banks needs at least one bank; got an empty sequence")
    if len(banks) == 1:
        return banks[0]
    return fold_merge(stack_banks(banks))


def fold_merge(balls: Ball, live: jax.Array | None = None) -> Ball:
    """Deterministic left fold of a stacked Ball pytree (leading axis).

    Accepts stacked single balls (w: (S, D)) or stacked BANKS (w: (S, B, D))
    — the bank case folds every model lane independently via the vmapped
    Sec-4.3 merge, which is how fit_bank_sharded combines per-shard banks.

    ``live``: optional (S,) bool mask; entries with ``live[i] == False`` are
    skipped exactly (the accumulator passes through), which is how fully
    padded shards — shards whose whole contiguous range is remainder padding
    — are excluded from the fold. The fold starts at the FIRST live entry
    (so a dead entry 0 cannot contaminate the result); at least one entry
    must be live.
    """
    n = balls.w.shape[0]
    merge = merge_balls if balls.w.ndim == 2 else merge_banks

    def take(i):
        return jax.tree.map(lambda x: x[i], balls)

    if live is None:
        def body(i, acc):
            return merge(acc, take(i))

        return jax.lax.fori_loop(1, n, body, take(0))

    i0 = jnp.argmax(live)  # index of the first live entry

    def body(i, acc):
        new = merge(acc, take(i))
        use = jnp.logical_and(live[i], i != i0)  # skip dead; don't self-merge
        return jax.tree.map(lambda a, b: jnp.where(use, a, b), new, acc)

    return jax.lax.fori_loop(0, n, body, take(i0))
