"""Ball algebra for the augmented-space MEB that underlies the l2-SVM.

A ``Ball`` is the streaming state of StreamSVM: the center of the minimum
enclosing ball in the augmented feature space ``phi~(z_n) = [y_n x_n ;
C^{-1/2} e_n]`` is ``[w ; sigma]`` where ``sigma`` is the slack block. Because
every example contributes a fresh orthogonal slack direction and is seen only
once, ``sigma`` never needs to be stored: its squared norm ``xi2`` suffices
for every distance computation the algorithm performs (paper, Sec. 4.1).

All functions are branch-free (jnp.where) so they jit/vmap/scan cleanly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

_EPS = 1e-12


class Ball(NamedTuple):
    """Streaming MEB state == StreamSVM classifier state.

    w:   (D,) feature block of the ball center == SVM weight vector.
    r:   () radius.
    xi2: () squared norm of the slack block of the center.
    m:   () int32 — number of core vectors absorbed (paper's M).
    """

    w: jax.Array
    r: jax.Array
    xi2: jax.Array
    m: jax.Array

    @property
    def dim(self) -> int:
        return self.w.shape[-1]


def make_ball(w, r=0.0, xi2=0.0, m=1) -> Ball:
    w = jnp.asarray(w)
    dt = w.dtype
    return Ball(
        w=w,
        r=jnp.asarray(r, dt),
        xi2=jnp.asarray(xi2, dt),
        m=jnp.asarray(m, jnp.int32),
    )


def center_distance(b1: Ball, b2: Ball) -> jax.Array:
    """Distance between two ball centers in the augmented space.

    Valid when the two balls were built from disjoint example sets (always
    true for stream shards): their slack blocks are orthogonal, so
    ``|c1-c2|^2 = |w1-w2|^2 + xi1^2 + xi2^2``.
    """
    d2 = jnp.sum((b1.w - b2.w) ** 2) + b1.xi2 + b2.xi2
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def point_distance(ball: Ball, yx: jax.Array, c_inv) -> jax.Array:
    """Distance from the ball center to augmented point [y x ; C^{-1/2} e_new].

    ``yx`` is the label-signed feature row y*x; ``c_inv`` is 1/C. The point's
    slack direction is fresh, hence the ``+ xi2 + 1/C`` closed form
    (Algorithm 1, line 5).
    """
    d2 = jnp.sum((ball.w - yx) ** 2) + ball.xi2 + c_inv
    return jnp.sqrt(jnp.maximum(d2, _EPS))


def enclose_point(ball: Ball, yx: jax.Array, c_inv, *, variant: str = "exact") -> Ball:
    """Algorithm 1 inner update, unconditionally applied (branchless).

    Returns the smallest ball enclosing ``ball`` and the augmented point.
    Caller selects with the ``d >= r`` predicate. ``variant``:
      - "exact": slack recursion xi2 <- xi2 (1-s)^2 + s^2 / C (exact
        bookkeeping of the augmented center; see DESIGN.md erratum note).
      - "paper-listing": verbatim line 9, xi2 <- xi2 (1-s)^2 + s^2.
    """
    d = point_distance(ball, yx, c_inv)
    s = 0.5 * (1.0 - ball.r / d)  # step toward the new point
    w = ball.w + s * (yx - ball.w)
    r = ball.r + 0.5 * (d - ball.r)
    slack_gain = c_inv if variant == "exact" else jnp.asarray(1.0, ball.xi2.dtype)
    xi2 = ball.xi2 * (1.0 - s) ** 2 + (s**2) * slack_gain
    return Ball(w=w, r=r, xi2=xi2, m=ball.m + 1)


def merge_balls(b1: Ball, b2: Ball) -> Ball:
    """Smallest ball enclosing two balls built from disjoint example sets.

    Exact in the augmented space (slack blocks orthogonal). This is the
    paper's Sec 4.3 multi-ball merge; we use it as the cross-shard collective
    combiner. Branch-free: handles mutual containment and coincident centers.
    """
    dist = center_distance(b1, b2)
    safe = jnp.maximum(dist, _EPS)

    one_in_two = dist + b1.r <= b2.r
    two_in_one = dist + b2.r <= b1.r

    r_join = 0.5 * (b1.r + b2.r + dist)
    t = jnp.clip((r_join - b1.r) / safe, 0.0, 1.0)
    w_join = b1.w + t * (b2.w - b1.w)
    xi2_join = (1.0 - t) ** 2 * b1.xi2 + t**2 * b2.xi2

    w = jnp.where(one_in_two, b2.w, jnp.where(two_in_one, b1.w, w_join))
    r = jnp.where(one_in_two, b2.r, jnp.where(two_in_one, b1.r, r_join))
    xi2 = jnp.where(one_in_two, b2.xi2, jnp.where(two_in_one, b1.xi2, xi2_join))
    return Ball(w=w, r=r, xi2=xi2, m=b1.m + b2.m)


def _is_kernel_bank(bank) -> bool:
    """True for KernelBank-shaped pytrees (core-set buffers present)."""
    return hasattr(bank, "coef") and hasattr(bank, "points")


def _require_kind(fn_name: str, banks, *, want_kernel: bool) -> None:
    """Refuse linear/kernel bank mixing with a ValueError naming both sides.

    A Ball center lives in the explicit feature space; a KernelBank center
    is a coefficient expansion over stored core-set points. Their merge
    algebras are NOT interchangeable — silently treating one as the other
    produces garbage scores, so every fold/merge entry point checks first.
    """
    names = [type(b).__name__ for b in banks]
    bad = [n for b, n in zip(banks, names) if _is_kernel_bank(b) != want_kernel]
    if bad:
        expected = "KernelBank" if want_kernel else "linear Ball"
        other = (
            "linear banks merge via merge_banks/fold_banks/stack_banks"
            if want_kernel
            else "kernelized banks merge via merge_kernel_banks/"
            "fold_kernel_banks/stack_kernel_banks (kernel=..., gamma=...)"
        )
        raise ValueError(
            f"{fn_name} operates on {expected} banks; got {names} — "
            f"mixing linear and kernelized banks has no exact merge; {other}"
        )


def _pair_gram(P1, P2, kernel: str, gamma):
    """(B, S1, S2) kernel matrix between two (B, S, D) core-set buffers."""
    P1 = P1.astype(jnp.float32)
    P2 = P2.astype(jnp.float32)
    acc = jnp.einsum("bsd,btd->bst", P1, P2, preferred_element_type=jnp.float32)
    if kernel == "rbf":
        n1 = jnp.sum(P1 * P1, axis=-1)
        n2 = jnp.sum(P2 * P2, axis=-1)
        return jnp.exp(
            -jnp.asarray(gamma, jnp.float32)
            * jnp.maximum(n1[:, :, None] + n2[:, None, :] - 2.0 * acc, 0.0)
        )
    return acc


def merge_kernel_banks(b1, b2, *, kernel: str, gamma=1.0,
                       eviction: str = "smallest-coef",
                       return_dropped: bool = False):
    """Sec-4.3 merge of two kernelized banks built from disjoint example sets.

    The kernel-space twin of ``merge_banks``: both arguments are
    ``KernelBank``s of identical (B, S) shape whose centers live in the same
    RKHS, c_i = sum_s coef_i[s] phi(p_i[s]) plus an orthogonal slack block of
    squared norm xi2_i. The center distance needs one cross-Gram
    contraction,

        |c1 - c2|^2 = q1 + q2 - 2 sum_{s,t} coef1[s] coef2[t] k(p1s, p2t)
                      + xi1 + xi2,

    and then the EXACT ``merge_balls`` algebra applies unchanged: r_join =
    (r1 + r2 + dist) / 2, t = clip((r_join - r1)/dist, 0, 1), with the
    merged center c = (1-t) c1 + t c2 represented on the CONCATENATED
    (B, 2S) buffer as [(1-t) coef1 ; t coef2] and q_join following the same
    interpolation ((1-t)^2 q1 + 2 t (1-t) cross + t^2 q2). Containment and
    empty-bank cases (m == 0 — a fully padded stream shard — is an exact
    identity) collapse onto t in {0, 1}, keeping everything branch-free.

    The 2S-slot buffer is then compressed back to S slots — the
    coreset-of-coresets step ("On Coresets for SVMs", PAPERS.md) — keeping
    the top-S slots under the SAME ``eviction`` policy the fit used:
    "smallest-coef" keeps the largest |coef|, "farthest-point" keeps the
    slots farthest from the merged center. Free slots (coef 0 / score -inf)
    are always dropped first, so the merge is EXACT (no mass lost) whenever
    the live slots of both inputs fit in S; beyond that it is lossy in the
    same sense as the fit's eviction — q keeps the dense-recursion value
    while the buffer approximates the center. Numpy oracle:
    ``kernels.ref.merge_kernel_banks_ref``; property/parity suites:
    tests/test_kernel_merge.py.

    ``return_dropped=True`` additionally returns the (B,) |coef| mass the
    2S->S cut discarded per model — the re-compression loss audit. It is
    computed from the NOT-kept slots directly (a scatter of the kept index
    set), so it is exactly 0.0 whenever every dropped slot was free
    (coef == 0), with no f32 mass-difference round-off.
    """
    from .kernel_bank import KernelBank  # lazy: module cycle

    _require_kind("merge_kernel_banks", (b1, b2), want_kernel=True)
    if b1.coef.shape != b2.coef.shape:
        raise ValueError(
            f"merge_kernel_banks needs identically-shaped banks: got "
            f"coef {b1.coef.shape} vs {b2.coef.shape}"
        )
    if eviction not in ("smallest-coef", "farthest-point"):
        raise ValueError(
            f"unknown eviction {eviction!r}; expected 'smallest-coef' or "
            "'farthest-point'"
        )
    s_size = b1.coef.shape[1]
    c1 = b1.coef.astype(jnp.float32)
    c2 = b2.coef.astype(jnp.float32)
    k12 = _pair_gram(b1.points, b2.points, kernel, gamma)
    cross = jnp.einsum("bs,bst,bt->b", c1, k12, c2)

    d2 = b1.q + b2.q - 2.0 * cross + b1.xi2 + b2.xi2
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    safe = jnp.maximum(dist, _EPS)
    one_in_two = dist + b1.r <= b2.r
    two_in_one = dist + b2.r <= b1.r
    empty1 = b1.m == 0
    empty2 = b2.m == 0

    r_join = 0.5 * (b1.r + b2.r + dist)
    t = jnp.clip((r_join - b1.r) / safe, 0.0, 1.0)
    # Containment / empty-identity collapse onto the interpolation weight
    # (t = 1 keeps bank 2's center exactly, t = 0 bank 1's) and the radius.
    t = jnp.where(one_in_two, 1.0, jnp.where(two_in_one, 0.0, t))
    t = jnp.where(empty1, 1.0, jnp.where(empty2, 0.0, t))
    r = jnp.where(one_in_two, b2.r, jnp.where(two_in_one, b1.r, r_join))
    r = jnp.where(empty1, b2.r, jnp.where(empty2, b1.r, r))

    q = (1.0 - t) ** 2 * b1.q + 2.0 * t * (1.0 - t) * cross + t**2 * b2.q
    xi2 = (1.0 - t) ** 2 * b1.xi2 + t**2 * b2.xi2
    m = b1.m + b2.m

    idx_c = jnp.concatenate([b1.idx, b2.idx], axis=1)  # (B, 2S)
    coef_c = jnp.concatenate(
        [(1.0 - t)[:, None] * c1, t[:, None] * c2], axis=1
    )
    pts_c = jnp.concatenate(
        [b1.points.astype(jnp.float32), b2.points.astype(jnp.float32)], axis=1
    )

    if eviction == "farthest-point":
        kcc = _pair_gram(pts_c, pts_c, kernel, gamma)
        gs = jnp.einsum(
            "bst,bt->bs", kcc, coef_c, preferred_element_type=jnp.float32
        )
        kdiag = jnp.diagonal(kcc, axis1=1, axis2=2)
        score = jnp.where(
            idx_c >= 0,
            q[:, None] - 2.0 * jnp.sign(coef_c) * gs + kdiag,
            -jnp.inf,
        )  # keep the slots FARTHEST from the merged center
    else:
        score = jnp.where(idx_c >= 0, jnp.abs(coef_c), -jnp.inf)
    _, keep = jax.lax.top_k(score, s_size)  # (B, S), ties -> lowest index
    merged = KernelBank(
        idx=jnp.take_along_axis(idx_c, keep, axis=1),
        coef=jnp.take_along_axis(coef_c, keep, axis=1),
        points=jnp.take_along_axis(pts_c, keep[..., None], axis=1),
        q=q, r=r, xi2=xi2, m=m,
    )
    if not return_dropped:
        return merged
    bsz = coef_c.shape[0]
    kept = jnp.zeros(coef_c.shape, bool).at[
        jnp.arange(bsz)[:, None], keep
    ].set(True)
    dropped = jnp.sum(jnp.where(kept, 0.0, jnp.abs(coef_c)), axis=1)
    return merged, dropped


def stack_kernel_banks(banks):
    """Stack an iterable of same-shape KernelBanks on a NEW leading axis.

    The kernelized ``stack_banks``: K banks of coef shape (B, S) become one
    stacked KernelBank with coef (K, B, S) — the layout the live loop
    checkpoints its K rotating kernel sub-banks in, and the form
    ``fold_kernel_banks`` unstacks to fold.
    """
    banks = list(banks)
    if not banks:
        raise ValueError(
            "stack_kernel_banks needs at least one bank; got an empty sequence"
        )
    _require_kind("stack_kernel_banks", banks, want_kernel=True)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *banks)


def fold_kernel_banks(banks, *, kernel: str, gamma=1.0,
                      eviction: str = "smallest-coef",
                      live=None, return_dropped: bool = False):
    """Left fold of same-shape KernelBanks, in order.

    The kernelized ``fold_banks``: shard count is static and small, so the
    fold is a plain python loop of ``merge_kernel_banks`` (callers pass
    shards oldest/leftmost first — the order ``fit_kernel_bank_sharded``
    gathers them in, and the birth order the live loop folds its sub-bank
    slots in). ``banks`` is either a python sequence of (B, S) banks or a
    stacked KernelBank from ``stack_kernel_banks`` (coef (K, B, S)).

    ``live``: optional (K,) bool mask; dead entries are skipped EXACTLY —
    the fold of the live entries is bit-identical to folding only those
    entries, because dead slots never enter a merge at all (the dead-slot
    exactness contract of the linear ``fold_merge``). At least one entry
    must be live. ``return_dropped=True`` additionally returns the summed
    (B,) dropped-|coef| mass over every 2S->S cut the fold performed
    (see ``merge_kernel_banks``); a single live bank passes through with
    exactly zero dropped mass.
    """
    if _is_kernel_bank(banks) and getattr(banks.coef, "ndim", 0) == 3:
        k = banks.coef.shape[0]
        banks = [jax.tree.map(lambda x, i=i: x[i], banks) for i in range(k)]
    else:
        banks = list(banks)
    if not banks:
        raise ValueError(
            "fold_kernel_banks needs at least one bank; got an empty sequence"
        )
    _require_kind("fold_kernel_banks", banks, want_kernel=True)
    if live is not None:
        import numpy as np

        mask = np.asarray(live)
        if mask.shape != (len(banks),):
            raise ValueError(
                f"live mask shape {mask.shape} does not match the "
                f"{len(banks)} banks being folded"
            )
        banks = [b for b, alive in zip(banks, mask) if alive]
        if not banks:
            raise ValueError(
                "fold_kernel_banks needs at least one LIVE bank; the live "
                "mask marked every entry dead"
            )
    acc = banks[0]
    dropped = jnp.zeros(acc.coef.shape[0], jnp.float32)
    for nxt in banks[1:]:
        acc, d = merge_kernel_banks(
            acc, nxt, kernel=kernel, gamma=gamma, eviction=eviction,
            return_dropped=True,
        )
        dropped = dropped + d
    if return_dropped:
        return acc, dropped
    return acc


def nonfinite_rows(bank) -> jax.Array:
    """(B,) bool: model rows whose FLOAT state contains NaN/Inf.

    Works on any (B, ...)-leading bank pytree — a linear ``Ball`` (w, r,
    xi2 checked; integer m skipped) or a ``KernelBank`` (coef, points, q,
    r, xi2 checked; integer idx/m skipped). This is the live loop's
    publish guard: a fold with any poisoned row must never be hot-swapped
    into a server, because a single NaN coordinate turns every score of
    that model row into NaN.
    """
    leaves = [
        jnp.asarray(leaf)
        for leaf in jax.tree.leaves(bank)
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating)
    ]
    if not leaves:
        raise ValueError(
            f"nonfinite_rows needs at least one float leaf: got {bank!r}"
        )
    b = leaves[0].shape[0]
    bad = jnp.zeros((b,), bool)
    for leaf in leaves:
        if leaf.shape[:1] != (b,):
            raise ValueError(
                "nonfinite_rows needs every float leaf stacked on the same "
                f"leading B axis: got shapes {[l.shape for l in leaves]}"
            )
        bad = bad | jnp.any(~jnp.isfinite(leaf.reshape(b, -1)), axis=1)
    return bad


def merge_banks(b1: Ball, b2: Ball) -> Ball:
    """Sec-4.3 merge vmapped over a leading bank axis: B models at once.

    Both arguments are Balls stacked on a leading B axis (w: (B, D), scalars
    (B,)); model b of the result merges model b of each bank — the lanes
    never interact.
    """
    _require_kind("merge_banks", (b1, b2), want_kernel=False)
    return jax.vmap(merge_balls)(b1, b2)


def stack_banks(banks) -> Ball:
    """Stack an iterable of same-shape Ball banks on a NEW leading axis.

    K banks of shape (B, D) become one stacked Ball with w: (K, B, D) —
    the layout ``fold_merge`` folds bank-wise and the live loop checkpoints
    (repro.live keeps its K rotating sub-banks exactly like this).
    """
    banks = list(banks)
    if not banks:
        raise ValueError("stack_banks needs at least one bank; got an empty sequence")
    _require_kind("stack_banks", banks, want_kernel=False)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *banks)


def fold_banks(banks, live=None) -> Ball:
    """Sec-4.3 fold of a python sequence of same-shape banks, in order.

    The sub-bank fold helper behind the live loop's drift repair: K rotating
    sub-banks — each a (B, D) stacked Ball trained over its own span of the
    stream, hence disjoint example sets — fold left-to-right (callers pass
    oldest first) into ONE serving bank via the bank-vectorized merge.
    Equivalent to ``fold_merge(stack_banks(banks))``; a single bank passes
    through untouched. ``live``: optional (K,) bool mask forwarded to
    ``fold_merge`` — dead entries are skipped exactly, matching
    ``fold_kernel_banks(..., live=)``.
    """
    banks = list(banks)
    if not banks:
        raise ValueError("fold_banks needs at least one bank; got an empty sequence")
    _require_kind("fold_banks", banks, want_kernel=False)
    if live is not None:
        return fold_merge(stack_banks(banks), live=jnp.asarray(live))
    if len(banks) == 1:
        return banks[0]
    return fold_merge(stack_banks(banks))


def fold_merge(balls: Ball, live: jax.Array | None = None) -> Ball:
    """Deterministic left fold of a stacked Ball pytree (leading axis).

    Accepts stacked single balls (w: (S, D)) or stacked BANKS (w: (S, B, D))
    — the bank case folds every model lane independently via the vmapped
    Sec-4.3 merge, which is how fit_bank_sharded combines per-shard banks.

    ``live``: optional (S,) bool mask; entries with ``live[i] == False`` are
    skipped exactly (the accumulator passes through), which is how fully
    padded shards — shards whose whole contiguous range is remainder padding
    — are excluded from the fold. The fold starts at the FIRST live entry
    (so a dead entry 0 cannot contaminate the result); at least one entry
    must be live.
    """
    n = balls.w.shape[0]
    merge = merge_balls if balls.w.ndim == 2 else merge_banks

    def take(i):
        return jax.tree.map(lambda x: x[i], balls)

    if live is None:
        def body(i, acc):
            return merge(acc, take(i))

        return jax.lax.fori_loop(1, n, body, take(0))

    i0 = jnp.argmax(live)  # index of the first live entry

    def body(i, acc):
        new = merge(acc, take(i))
        use = jnp.logical_and(live[i], i != i0)  # skip dead; don't self-merge
        return jax.tree.map(lambda a, b: jnp.where(use, a, b), new, acc)

    return jax.lax.fori_loop(0, n, body, take(i0))
