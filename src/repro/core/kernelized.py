"""Kernelized StreamSVM (paper Sec 4.2).

Maintains the N-vector of Lagrange coefficients alpha (the center is
c = sum_m alpha_m phi(x_m)); per-example work is O(n) kernel evaluations.
This gives up the constant-memory property (as the paper notes) but keeps the
single pass. For the linear kernel it is algebraically identical to
Algorithm 1 — property-tested via w = X^T alpha.

Kernels must satisfy K(x,x) = kappa (constant); linear assumes normalized
inputs only for the theory — the algorithm itself runs regardless.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class KernelBall(NamedTuple):
    alpha: jax.Array  # (N,) signed coefficients (include label sign)
    q: jax.Array  # () running |c|^2 = alpha^T K alpha
    r: jax.Array  # () radius
    xi2: jax.Array  # () slack-block squared norm
    m: jax.Array  # () int32 core-vector count


def linear_kernel(A, B):
    return A @ B.T


def rbf_kernel(gamma):
    def k(A, B):
        a2 = jnp.sum(A * A, -1)[:, None]
        b2 = jnp.sum(B * B, -1)[None, :]
        # Clamp the squared distance at 0: near-duplicate rows make the
        # expansion a2 + b2 - 2<a, b> go (slightly) negative in f32, which
        # would yield K(x, x') > kappa and break the constant-diagonal
        # assumption the MEB update relies on. Matches the Pallas Gram
        # epilogue (kernels/gram.py) exactly.
        d2 = jnp.maximum(a2 + b2 - 2.0 * A @ B.T, 0.0)
        return jnp.exp(-gamma * d2)

    return k


@partial(jax.jit, static_argnames=("kernel_fn", "variant"))
def fit_kernelized(
    X: jax.Array,
    y: jax.Array,
    c: float,
    kernel_fn: Callable = linear_kernel,
    variant: str = "exact",
) -> KernelBall:
    """Single pass; scan over examples; O(N) per step via full kernel rows.

    alpha is zero for unseen examples, so g_n = sum_m alpha_m k(x_m, x_n)
    computed against the whole row is exact at step n.
    """
    N, _ = X.shape
    c_inv = jnp.asarray(1.0 / c, X.dtype)
    slack_gain = c_inv if variant == "exact" else jnp.asarray(1.0, X.dtype)

    kdiag = jax.vmap(lambda v: kernel_fn(v[None, :], v[None, :])[0, 0])(X)

    alpha0 = jnp.zeros((N,), X.dtype).at[0].set(y[0])
    state0 = KernelBall(
        alpha=alpha0,
        q=kdiag[0],
        r=jnp.asarray(0.0, X.dtype),
        xi2=(c_inv if variant == "exact" else jnp.asarray(1.0, X.dtype)),
        m=jnp.asarray(1, jnp.int32),
    )

    def body(st: KernelBall, n):
        xn = X[n]
        yn = y[n]
        kn = kernel_fn(X, xn[None, :])[:, 0]  # (N,)
        g = jnp.dot(st.alpha, kn)  # <c, phi(x_n)>
        d2 = st.q - 2.0 * yn * g + kdiag[n] + st.xi2 + c_inv
        d = jnp.sqrt(jnp.maximum(d2, 1e-12))
        upd = d >= st.r
        s = 0.5 * (1.0 - st.r / d)
        alpha = st.alpha * (1.0 - s)
        alpha = alpha.at[n].add(s * yn)
        q = (1.0 - s) ** 2 * st.q + 2.0 * s * (1.0 - s) * yn * g + s**2 * kdiag[n]
        r = st.r + 0.5 * (d - st.r)
        xi2 = st.xi2 * (1.0 - s) ** 2 + s**2 * slack_gain
        new = KernelBall(alpha=alpha, q=q, r=r, xi2=xi2, m=st.m + 1)
        st = jax.tree.map(lambda a, b: jnp.where(upd, a, b), new, st)
        return st, upd

    state, _ = jax.lax.scan(body, state0, jnp.arange(1, N))
    return state


def decision_function(kb: KernelBall, X_train, X_test, kernel_fn: Callable = linear_kernel):
    return kernel_fn(X_test, X_train) @ kb.alpha


def linear_weights(kb: KernelBall, X_train) -> jax.Array:
    """For the linear kernel, c = X^T alpha — must equal Algorithm 1's w."""
    return X_train.T @ kb.alpha
