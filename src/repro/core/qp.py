"""MEB of (ball ∪ L augmented points) — the lookahead "QP" of Algorithm 2.

The paper solves a size-L quadratic program whenever the lookahead buffer
fills. We solve the equivalent geometric problem — smallest enclosing ball of
the current ball plus L augmented points — with a fixed-iteration
Badoiu–Clarkson / Frank–Wolfe scheme, which is branch-free and jit-able
(no QP library exists in this environment, and BC is exactly what CVM uses).

Coordinates. The augmented space is R^{D + old-slack-dims + L}. Relative to
the current center only three blocks matter, so a candidate center is carried
as ``(u, a, b)``:
  u: (D,)  feature block,
  a: ()    magnitude along the *old* slack block direction sigma/|sigma|,
  b: (L,)  coordinates along the L fresh slack directions of buffered points.
The current ball center is (w, sqrt(xi2), 0); buffered point i is
(P_i, 0, sqrt(1/C) e_i). Distances and BC updates stay closed-form in these
blocks; the solved center folds back to Ball(u, r_new, a^2 + |b|^2).

Guarantee: after the BC iterations we *set* the radius to the max distance
over all entities, so the returned ball always encloses ball ∪ points
(enclosure is exact; only optimality is approximate — consistent with the
paper's approximation-algorithm framing).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .meb import Ball

_EPS = 1e-12


def _distances(u, a, b, w, sxi, r, pts, valid, c_inv):
    """Distances from candidate center (u,a,b) to each point and to the ball.

    Returns (point_dists (L,), ball_dist ()) where ball_dist is the distance
    to the *far side* of the old ball (center dist + r).
    """
    # |c - p_i|^2 = |u - P_i|^2 + a^2 + |b|^2 - 2 sqrt(cinv) b_i + cinv
    b2 = jnp.sum(b * b)
    pd2 = (
        jnp.sum((u[None, :] - pts) ** 2, axis=-1)
        + a * a
        + b2
        - 2.0 * jnp.sqrt(c_inv) * b
        + c_inv
    )
    pd = jnp.sqrt(jnp.maximum(pd2, 0.0))
    pd = jnp.where(valid, pd, -jnp.inf)
    # |c - c_ball|^2 = |u - w|^2 + (a - sqrt(xi2))^2 + |b|^2
    cd2 = jnp.sum((u - w) ** 2) + (a - sxi) ** 2 + b2
    cd = jnp.sqrt(jnp.maximum(cd2, 0.0))
    return pd, cd + r, cd


def solve_meb_ball_points(
    ball: Ball,
    pts: jax.Array,
    valid: jax.Array,
    c_inv,
    *,
    iters: int = 128,
    return_aux: bool = False,
):
    """Smallest ball enclosing ``ball`` and the valid rows of ``pts``.

    pts:   (L, D) label-signed feature rows (y_i * x_i).
    valid: (L,) bool — rows beyond the current buffer fill are masked out.
    """
    L, _ = pts.shape
    w, r, xi2 = ball.w, ball.r, ball.xi2
    sxi = jnp.sqrt(jnp.maximum(xi2, 0.0))
    c_inv = jnp.asarray(c_inv, w.dtype)
    nvalid = jnp.sum(valid.astype(jnp.int32))

    # Init: midpoint between ball center and the valid-point centroid (in the
    # (u, a, b) blocks). Any interior-ish start works for BC.
    denom = jnp.maximum(nvalid.astype(w.dtype), 1.0)
    cen_u = jnp.sum(jnp.where(valid[:, None], pts, 0.0), axis=0) / denom
    cen_b = jnp.where(valid, jnp.sqrt(c_inv), 0.0) / denom
    u0 = 0.5 * (w + cen_u)
    a0 = 0.5 * sxi
    b0 = 0.5 * cen_b

    def body(t, carry):
        u, a, b = carry
        pd, bd, cd = _distances(u, a, b, w, sxi, r, pts, valid, c_inv)
        far_pt = jnp.argmax(pd)
        ball_wins = bd >= pd[far_pt]
        # Support (farthest) point of the chosen entity.
        #  - point i: (P_i, 0, sqrt(cinv) e_i)
        #  - ball: the far side, c_ball + r * (c_ball - c)/|c_ball - c|
        inv_cd = 1.0 / jnp.maximum(cd, _EPS)
        fu_ball = w - r * (u - w) * inv_cd
        fa_ball = sxi - r * (a - sxi) * inv_cd
        fb_ball = -r * b * inv_cd
        fu_pt = pts[far_pt]
        fa_pt = jnp.zeros_like(a)
        fb_pt = jnp.sqrt(c_inv) * jax.nn.one_hot(far_pt, L, dtype=b.dtype)
        fu = jnp.where(ball_wins, fu_ball, fu_pt)
        fa = jnp.where(ball_wins, fa_ball, fa_pt)
        fb = jnp.where(ball_wins, fb_ball, fb_pt)
        eta = 1.0 / (t + 2.0)
        return (u + eta * (fu - u), a + eta * (fa - a), b + eta * (fb - b))

    u, a, b = jax.lax.fori_loop(
        0, iters, body, (u0, a0, b0), unroll=False
    )
    pd, bd, _ = _distances(u, a, b, w, sxi, r, pts, valid, c_inv)
    r_new = jnp.maximum(jnp.max(pd), bd)
    # Degenerate case: no valid points -> keep the old ball untouched.
    any_valid = nvalid > 0
    xi2_new = a * a + jnp.sum(b * b)
    out = Ball(
        w=jnp.where(any_valid, u, w),
        r=jnp.where(any_valid, r_new, r),
        xi2=jnp.where(any_valid, xi2_new, xi2),
        m=ball.m + nvalid,
    )
    if return_aux:
        return out, {"u": u, "a": a, "b": b, "point_dists": pd, "ball_dist": bd}
    return out
