"""One-vs-rest multiclass StreamSVM and hyper-parameter-grid fitting.

Classes and C-grid points are embarrassingly parallel *in math* but share the
same stream, so the default path flattens them onto the model axis of the
tiled multi-ball Pallas engine (kernels.ops.streamsvm_fit_many): every
(block_n, D) tile is read from HBM once and updates all B models — bank
tiling (``b_tile``) keeps that true for hundreds of classes x a C-grid, and
``lookahead > 1`` runs the fused in-kernel Algorithm 2. The pre-engine vmap'd
lax.scan path is kept as ``engine="scan"``. On a mesh, the class/grid axis maps to the
`model` axis (see launch/train.py --svm-head) while the stream itself shards
over (pod, data) via distributed.fit_sharded.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .meb import Ball
from .multiball import fit_bank
from .streamsvm import fit, fit_lookahead


def _cast_ball(ball: Ball, dtype) -> Ball:
    """Match the scan path's output dtype (the kernel accumulates in f32)."""
    return Ball(
        w=ball.w.astype(dtype), r=ball.r.astype(dtype),
        xi2=ball.xi2.astype(dtype), m=ball.m,
    )


def ovr_signs(labels: jax.Array, n_classes: int, dtype=jnp.float32) -> jax.Array:
    """(N,) int labels -> (n_classes, N) one-vs-rest sign rows in {-1, +1}."""
    return jnp.where(
        labels[None, :] == jnp.arange(n_classes)[:, None], 1.0, -1.0
    ).astype(dtype)


@partial(
    jax.jit,
    static_argnames=(
        "n_classes", "lookahead", "variant", "engine", "b_tile", "stream_dtype",
        "bank_resident", "mesh", "shard_axis",
    ),
)
def fit_ovr(
    X: jax.Array,
    labels: jax.Array,
    n_classes: int,
    c,
    *,
    lookahead: int = 1,
    variant: str = "exact",
    engine: str = "pallas",
    b_tile: int | None = None,
    stream_dtype=None,
    bank_resident: str = "auto",
    mesh=None,
    shard_axis="data",
) -> Ball:
    """labels: (N,) int in [0, n_classes). Returns Ball stacked over classes.

    ``c`` is traced (sweeping C reuses one compilation). The default engine
    flattens all classes onto the bank axis of the tiled Pallas engine —
    including ``lookahead > 1``, which runs the fused in-kernel Algorithm 2 —
    so hundreds of classes train in ONE stream pass; ``b_tile`` bounds the
    per-step VMEM working set, ``stream_dtype="bf16"`` halves stream HBM
    traffic, and ``bank_resident="hbm"`` lifts the VMEM cap on the bank
    (classes x C-grid banks beyond VMEM scratch double-buffer through HBM —
    see kernels.ops). ``engine="scan"`` keeps the pre-engine vmap'd lax.scan path
    (Badoiu-Clarkson window solves for lookahead > 1).

    ``mesh=`` (pallas engine only) shards the stream over ``shard_axis`` of
    a device mesh and folds the per-shard banks with the Sec-4.3 merge:
    classes x shards in one pass of each shard's range (fit_bank_sharded).
    """
    if engine not in ("pallas", "scan"):
        raise ValueError(f"unknown engine {engine!r}; expected 'pallas' or 'scan'")
    if variant not in ("exact", "paper-listing"):
        raise ValueError(
            f"unknown variant {variant!r}; expected 'exact' or 'paper-listing'"
        )
    if mesh is not None and engine != "pallas":
        raise ValueError(
            f"mesh= requires engine='pallas': got engine={engine!r}"
        )
    ys = ovr_signs(labels, n_classes, X.dtype)
    if engine == "pallas":
        if lookahead <= 1:
            bank = fit_bank(
                X, ys, c, variant=variant, b_tile=b_tile,
                stream_dtype=stream_dtype, bank_resident=bank_resident,
                mesh=mesh, shard_axis=shard_axis,
            )
        else:
            bank = fit_bank(
                X, ys, c,
                variant="lookahead" if variant == "exact" else "lookahead-paper",
                lookahead=int(lookahead),
                b_tile=b_tile, stream_dtype=stream_dtype,
                bank_resident=bank_resident,
                mesh=mesh, shard_axis=shard_axis,
            )
        return _cast_ball(bank, X.dtype)
    if lookahead <= 1:
        f = lambda yv: fit(X, yv, c, variant=variant)
    else:
        f = lambda yv: fit_lookahead(X, yv, c, lookahead, variant=variant, engine="qp")
    return jax.vmap(f)(ys)


def predict_ovr(balls: Ball, X: jax.Array) -> jax.Array:
    """Direct jnp OVR readout: argmax margin over the bank's model axis.

    The serving fast path for this readout is kernels.ops.predict_bank /
    serve.BankServer (fused tiled kernel, bit-exact with this matmul in
    f32); this stays the one-liner oracle.
    """
    scores = X @ balls.w.T  # (N, K)
    return jnp.argmax(scores, axis=-1)


def predict_c_grid(balls: Ball, X: jax.Array, n_classes: int):
    """Per-C-grid-group OVR readout of a (G * n_classes)-model bank.

    ``balls`` is a stacked bank laid out class-major within each
    hyper-parameter group (model = g * n_classes + class — exactly what
    ``fit_ovr``/``fit_c_grid``/the quickstart's ``jnp.tile(signs, (G, 1))``
    produce). Returns ``((N, G) int32 predicted class, (N, G) f32 margin)``:
    each C-grid point's classifier answers independently, so one readout
    scores the whole grid. Direct jnp path — the fused serving twin is
    ``kernels.ops.predict_bank(..., epilogue="ovr")``, bit-exact in f32.
    """
    scores = X @ balls.w.T  # (N, B)
    b = scores.shape[1]
    if n_classes < 1 or b % n_classes:
        raise ValueError(
            f"n_classes must be >= 1 and divide the bank size: got "
            f"n_classes={n_classes}, B={b}"
        )
    grouped = scores.reshape(X.shape[0], b // n_classes, n_classes)
    return (
        jnp.argmax(grouped, axis=-1).astype(jnp.int32),
        jnp.max(grouped, axis=-1),
    )


@partial(
    jax.jit,
    static_argnames=(
        "variant", "engine", "b_tile", "stream_dtype", "bank_resident",
        "mesh", "shard_axis",
    ),
)
def fit_c_grid(
    X: jax.Array,
    y: jax.Array,
    c_grid: jax.Array,
    *,
    variant: str = "exact",
    engine: str = "pallas",
    b_tile: int | None = None,
    stream_dtype=None,
    bank_resident: str = "auto",
    mesh=None,
    shard_axis="data",
) -> Ball:
    """Model-selection sweep over a grid of C values in ONE stream pass.

    Every grid point is a model in the engine's bank (c enters only through
    1/C, so the grid can be traced). Returns Ball stacked over the grid.
    ``mesh=`` (pallas engine only) shards the stream over ``shard_axis`` and
    folds the per-shard grid banks with the Sec-4.3 merge.
    """
    if engine not in ("pallas", "scan"):
        raise ValueError(f"unknown engine {engine!r}; expected 'pallas' or 'scan'")
    if mesh is not None and engine != "pallas":
        raise ValueError(
            f"mesh= requires engine='pallas': got engine={engine!r}"
        )
    c_grid = jnp.asarray(c_grid)
    b = c_grid.shape[0]
    if engine == "pallas":
        Y = jnp.broadcast_to(y[None, :], (b, y.shape[0])).astype(X.dtype)
        return _cast_ball(
            fit_bank(
                X, Y, c_grid, variant=variant, b_tile=b_tile,
                stream_dtype=stream_dtype, bank_resident=bank_resident,
                mesh=mesh, shard_axis=shard_axis,
            ),
            X.dtype,
        )

    def f(cv):
        from .meb import enclose_point, point_distance

        c_inv = 1.0 / cv
        xi2 = c_inv if variant == "exact" else jnp.asarray(1.0, X.dtype)
        ball = Ball(
            w=y[0] * X[0],
            r=jnp.asarray(0.0, X.dtype),
            xi2=jnp.asarray(xi2, X.dtype),
            m=jnp.asarray(1, jnp.int32),
        )
        yx = y[1:, None] * X[1:]

        def body(b_, row):
            d = point_distance(b_, row, c_inv)
            upd = d >= b_.r
            new = enclose_point(b_, row, c_inv, variant=variant)
            return jax.tree.map(lambda a_, o_: jnp.where(upd, a_, o_), new, b_), None

        ball, _ = jax.lax.scan(body, ball, yx)
        return ball

    return jax.vmap(f)(c_grid)
