"""One-vs-rest multiclass StreamSVM and hyper-parameter-grid fitting.

Classes (and C-grid points) are embarrassingly parallel: we vmap the
single-pass fit over the class axis. On a mesh, the class/grid axis maps to
the `model` axis (see launch/train.py --svm-head) while the stream itself
shards over (pod, data) via distributed.fit_sharded.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .meb import Ball
from .streamsvm import fit, fit_lookahead


@partial(jax.jit, static_argnames=("n_classes", "c", "lookahead", "variant"))
def fit_ovr(
    X: jax.Array,
    labels: jax.Array,
    n_classes: int,
    c: float,
    *,
    lookahead: int = 1,
    variant: str = "exact",
) -> Ball:
    """labels: (N,) int in [0, n_classes). Returns Ball stacked over classes."""
    ys = jnp.where(labels[None, :] == jnp.arange(n_classes)[:, None], 1.0, -1.0)
    ys = ys.astype(X.dtype)
    if lookahead <= 1:
        f = lambda yv: fit(X, yv, c, variant=variant)
    else:
        f = lambda yv: fit_lookahead(X, yv, c, lookahead, variant=variant)
    return jax.vmap(f)(ys)


def predict_ovr(balls: Ball, X: jax.Array) -> jax.Array:
    scores = X @ balls.w.T  # (N, K)
    return jnp.argmax(scores, axis=-1)


@partial(jax.jit, static_argnames=("variant",))
def fit_c_grid(X: jax.Array, y: jax.Array, c_grid: jax.Array, *, variant: str = "exact") -> Ball:
    """vmap the one-pass fit over a grid of C values (model-selection sweep).

    Note c enters only through 1/C inside the scan, so it can be traced.
    """

    def f(cv):
        from .meb import make_ball, point_distance, enclose_point

        c_inv = 1.0 / cv
        xi2 = c_inv if variant == "exact" else jnp.asarray(1.0, X.dtype)
        ball = Ball(
            w=y[0] * X[0],
            r=jnp.asarray(0.0, X.dtype),
            xi2=jnp.asarray(xi2, X.dtype),
            m=jnp.asarray(1, jnp.int32),
        )
        yx = y[1:, None] * X[1:]

        def body(b, row):
            d = point_distance(b, row, c_inv)
            upd = d >= b.r
            new = enclose_point(b, row, c_inv, variant=variant)
            return jax.tree.map(lambda a_, b_: jnp.where(upd, a_, b_), new, b), None

        ball, _ = jax.lax.scan(body, ball, yx)
        return ball

    return jax.vmap(f)(c_grid)
