"""One-pass kernelized bank: B core-set CVMs per stream read (paper Sec 4.2).

The dense kernelized StreamSVM (``kernelized.fit_kernelized``) keeps the full
N-vector of Lagrange coefficients — O(N) memory and O(N) kernel rows per
step, which forfeits the paper's constant-storage claim. This module is the
bank engine's kernel-space twin with BOUNDED memory: every model of a B-model
bank keeps a fixed-size **core-set buffer** of at most ``coreset_size`` (S)
stream rows,

  idx:  (B, S) int32  — stream indices of the buffered core vectors (-1 free)
  coef: (B, S) f32    — their signed Lagrange coefficients,

so state is O(B * S * D) no matter how long the stream, and the stream is
read ONCE for all B models (classes x C-grid flatten onto the bank axis,
exactly like ``fit_bank``).

Per stream tile the engine computes two kernel blocks through the tiled
Pallas Gram kernel (``kernels.ops.gram``, fused linear/RBF epilogues):

  K_cs = k(tile, core sets)   (block_n, B, S)  — one gram call for ALL models
  K_tt = k(tile, tile)        (block_n, block_n)

and then runs the O(block_n * B * S) coefficient recursion (a lax.scan of
cheap elementwise work — the MXU-shaped O(block_n * B * S * D) kernel
evaluations all live in the gram calls). A row inserted mid-tile reads its
kernel values against later rows from K_tt, so the recursion is exactly
row-at-a-time despite the tiled evaluation. ``s_tile=`` chunks the K_cs
launch over the S axis (bit-exact f32 with the unchunked launch), so banks
whose (B * S) core-set operand outgrows the VMEM budget still train — the
kernel-bank twin of the linear engine's ``bank_resident`` knob, preflighted
against the same byte model (``kernels.ops.kernel_engine_vmem_bytes``).

Each model SEEDS on the first row whose sign is nonzero for it (the paper's
line-3 init, deferred past inert sign-0 rows): the recursion runs with a
forced step s = 1, which reproduces the closed-form init exactly. The public
``fit_kernel_bank`` still REQUIRES ``Y[:, 0]`` in {-1, +1} — a sign-0 seed
row is almost always a label-encoding bug — but the deferred seed is what
lets ``mesh=`` shard the stream into ranges whose first rows may be inert
(ragged-N padding, per-class sign structure).

When a model's buffer is full, the incoming core vector evicts a slot
chosen by the ``eviction`` policy ("On Coresets for SVMs" / "Accurate
Streaming SVMs", PAPERS.md):

  "smallest-coef"   (default) evict argmin |coef| — the recursion scales
                    every coefficient by (1 - s) at each absorb, so the
                    smallest |coef| contributes least to the center.
  "farthest-point"  evict the buffered point CLOSEST to the current center
                    (keep the farthest — the blurred-ball/Badoiu-Clarkson
                    choice: extreme points carry the ball geometry). Needs a
                    (B, S, S) buffer-buffer Gram carried per tile.

Free slots carry coef == 0 (smallest-coef) / score -inf (farthest-point), so
both policies fill free slots before evicting anything. The running center
norm q keeps the DENSE recursion (it needs only g and k(x, x)), so with
``coreset_size >= N`` nothing is ever evicted and the engine reproduces
``fit_kernelized`` exactly — property-tested, per model, in
tests/test_kernel_bank.py.

``mesh=`` shards the stream over a device mesh: each shard runs this engine
over its contiguous range and the per-shard banks are folded with the
kernelized Sec-4.3 merge (``meb.merge_kernel_banks`` — coreset-of-coresets
compression + the ball-state merge; see ``distributed.fit_kernel_bank_
sharded``).

Kernels must satisfy K(x, x) ~ kappa (constant diagonal); the RBF epilogue
clamps d^2 at 0 so duplicates cannot push K above kappa. ``gamma`` is
TRACED through the Gram launches (a gamma sweep reuses one compilation,
like the C sweep); ``kernel`` / ``coreset_size`` / ``eviction`` stay static.

Serving rides ``kernels.ops.predict_kernel_bank`` (same fused Gram
epilogues against the stored core-set points) and ``serve.BankServer``
(kernel-bank checkpoints carry ``meta={"bank_kind": "kernel", ...}``).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

_KERNELS = ("linear", "rbf")
_EVICTIONS = ("smallest-coef", "farthest-point")


class KernelBank(NamedTuple):
    """Streaming state / result of the kernelized bank engine.

    idx:    (B, S) int32 — stream index of each buffered core vector, -1 for
            a free slot. Sharded fits report GLOBAL stream indices.
    coef:   (B, S) f32 — signed Lagrange coefficients (exactly 0 in free
            slots, so free slots never contribute to any readout).
    points: (B, S, D) f32 — the buffered core vectors themselves (zeros in
            free slots), gathered once at the end of the fit so checkpoints
            are self-contained (serving never needs the stream back).
    q:      (B,) running |center|^2 (dense recursion — see module docstring).
    r:      (B,) radius.
    xi2:    (B,) slack-block squared norm.
    m:      (B,) int32 core-vector absorb count (the paper's M; 0 == the
            model never saw a live row — an identity for the merge).
    """

    idx: jax.Array
    coef: jax.Array
    points: jax.Array
    q: jax.Array
    r: jax.Array
    xi2: jax.Array
    m: jax.Array


def _kdiag(X, kernel: str):
    """k(x, x) per row, matching the Gram epilogue's arithmetic."""
    x2 = jnp.sum(X.astype(jnp.float32) ** 2, axis=-1)
    if kernel == "rbf":
        # K(x, x) = exp(-gamma * 0) = 1 identically, for every x and every
        # gamma: the epilogue's d^2 = |x|^2 + |x|^2 - 2<x, x> is exactly 0
        # (and clamped at 0 against rounding), so the RBF Gram diagonal is a
        # constant ones vector — parity-tested against the Pallas epilogue
        # diagonal in tests/test_kernel_bank.py.
        return jnp.ones_like(x2)
    return x2


@partial(
    jax.jit,
    static_argnames=(
        "kernel", "coreset_size", "eviction", "variant", "block_n", "s_tile",
        "stream_dtype", "interpret",
    ),
)
def _fit_kernel_bank(
    X: jax.Array,
    Y: jax.Array,
    cs,
    gamma,
    *,
    kernel: str,
    coreset_size: int,
    eviction: str,
    variant: str,
    block_n: int,
    s_tile: int | None,
    stream_dtype,
    interpret: bool | None,
) -> KernelBank:
    """jit'd engine core of ``fit_kernel_bank`` (deferred per-model seeding).

    Module-level so the public wrapper (which adds the eager seed-sign
    validation, the VMEM preflight and the ``mesh=`` routing) stays a plain
    python function, and so ``fit_kernel_bank_sharded``'s shard-local calls
    — whose ranges legitimately start with inert sign-0 rows — share the
    same jit cache.
    """
    n, d = X.shape
    b, n_y = Y.shape
    if n_y != n:
        raise ValueError(
            f"Y must be (B, N) sign rows matching X: got Y.shape={Y.shape}, "
            f"X.shape={X.shape}"
        )
    s_size = int(coreset_size)
    from repro.kernels.ops import _resolve_stream_dtype, gram

    sdt = _resolve_stream_dtype(stream_dtype)
    Xf = X.astype(jnp.float32)
    cs = jnp.broadcast_to(jnp.asarray(cs, jnp.float32), (b,))
    gamma = jnp.asarray(gamma, jnp.float32)
    c_inv = 1.0 / cs
    gain = c_inv if variant == "exact" else jnp.ones_like(c_inv)
    st = s_size if s_tile is None else min(int(s_tile), s_size)
    farthest = eviction == "farthest-point"

    # Empty init: every model seeds inside the recursion on its first live
    # row (m == 0 forces step s = 1, which IS the paper's line-3 init —
    # coef = y, q = k(x, x), r = 0, xi2 = gain — bit-exact f32 with the old
    # closed-form row-0 seed when Y[:, 0] is +-1).
    state0 = (
        jnp.full((b, s_size), -1, jnp.int32),   # idx
        jnp.zeros((b, s_size), jnp.float32),    # coef
        jnp.zeros((b,), jnp.float32),           # q
        jnp.zeros((b,), jnp.float32),           # r
        jnp.zeros((b,), jnp.float32),           # xi2
        jnp.zeros((b,), jnp.int32),             # m
    )

    n_tiles = -(-n // block_n)
    pad = n_tiles * block_n - n
    Xt = jnp.pad(Xf, ((0, pad), (0, 0))).reshape(n_tiles, block_n, d)
    Yt = (
        jnp.pad(Y.astype(jnp.float32), ((0, 0), (0, pad)))
        .reshape(b, n_tiles, block_n)
        .transpose(1, 0, 2)
    )
    valid = (jnp.arange(n_tiles * block_n) < n).reshape(n_tiles, block_n)
    base = jnp.arange(n_tiles * block_n, dtype=jnp.int32).reshape(
        n_tiles, block_n
    )

    def tile_body(carry, xs):
        idx, coef, q, r, xi2, m = carry
        x_tile, y_tile, base_t, valid_t = xs
        x_stream = x_tile if sdt is None else x_tile.astype(sdt)
        # Core-set rows at tile entry, gathered once; free slots read row 0
        # but are zeroed (their coef is 0 anyway — this keeps the gather
        # deterministic).
        xc = jnp.where(
            (idx >= 0)[..., None], Xf[jnp.clip(idx, 0)], 0.0
        )  # (B, S, D)
        # The fused Gram launch covers every model's core set; ``s_tile``
        # chunks its (B * S) column axis so the operand/output tiles fit the
        # VMEM budget. Each chunk is an independent launch over the same
        # stream tile — the concatenation is bit-exact f32 with one launch.
        parts = [
            gram(
                x_stream,
                xc[:, lo : min(lo + st, s_size), :].reshape(
                    b * (min(lo + st, s_size) - lo), d
                ),
                epilogue=kernel, gamma=gamma, interpret=interpret,
            ).reshape(block_n, b, min(lo + st, s_size) - lo)
            for lo in range(0, s_size, st)
        ]
        k_cs = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=2)
        # ...and one more launch covers rows inserted mid-tile.
        k_tt = gram(
            x_stream, x_stream, epilogue=kernel, gamma=gamma,
            interpret=interpret,
        )
        kdiag_t = jnp.diagonal(k_tt)
        if farthest:
            # Buffer-buffer Gram per model, recomputed at tile entry and
            # maintained incrementally across insertions — the eviction
            # score needs each slot's kernel row against the whole buffer.
            acc = jnp.einsum(
                "bsd,btd->bst", xc, xc, preferred_element_type=jnp.float32
            )
            if kernel == "rbf":
                n2 = jnp.sum(xc * xc, axis=-1)  # (B, S)
                kbb = jnp.exp(
                    -gamma
                    * jnp.maximum(n2[:, :, None] + n2[:, None, :] - 2.0 * acc, 0.0)
                )
            else:
                kbb = acc
        else:
            kbb = None

        def row_body(rcarry, i):
            idx, coef, q, r, xi2, m, intile, kbb = rcarry
            # Kernel row of each buffered core vector against stream row i:
            # from K_tt if the slot was filled earlier in this tile, else
            # from the tile-entry K_cs block.
            kv = jnp.where(
                intile >= 0, k_tt[jnp.clip(intile, 0), i], k_cs[i]
            )  # (B, S)
            g = jnp.sum(coef * kv, axis=1)
            yn = y_tile[:, i]
            ok = jnp.logical_and(valid_t[i], yn != 0)
            seed = jnp.logical_and(m == 0, ok)  # deferred line-3 init
            d2 = q - 2.0 * yn * g + kdiag_t[i] + xi2 + c_inv
            dist = jnp.sqrt(jnp.maximum(d2, 1e-12))
            upd = jnp.logical_and(jnp.logical_and(~seed, ok), dist >= r)
            act = jnp.logical_or(seed, upd)
            s = jnp.where(
                seed, 1.0, jnp.where(upd, 0.5 * (1.0 - r / dist), 0.0)
            )
            # Slot choice: free slots are always preferred (coef == 0 /
            # score -inf); with a full buffer this IS the coreset-
            # compression eviction.
            if farthest:
                gs = jnp.einsum(
                    "bst,bt->bs", kbb, coef,
                    preferred_element_type=jnp.float32,
                )
                kbb_diag = jnp.diagonal(kbb, axis1=1, axis2=2)
                score = jnp.where(
                    idx >= 0,
                    q[:, None] - 2.0 * jnp.sign(coef) * gs + kbb_diag,
                    -jnp.inf,
                )  # squared center->point distance; evict the closest
                slot = jnp.argmin(score, axis=1)
            else:
                # the uniform (1-s) scaling preserves the |coef| ordering
                slot = jnp.argmin(jnp.abs(coef), axis=1)
            hit = jnp.logical_and(
                jnp.arange(s_size)[None, :] == slot[:, None], act[:, None]
            )
            if farthest:
                # Replaced slot's kernel row/col against the (pre-insert)
                # buffer is exactly kv; its diagonal entry is k(x_i, x_i).
                kbb = jnp.where(hit[:, :, None], kv[:, None, :], kbb)
                kbb = jnp.where(hit[:, None, :], kv[:, :, None], kbb)
                kbb = jnp.where(
                    jnp.logical_and(hit[:, :, None], hit[:, None, :]),
                    kdiag_t[i], kbb,
                )
            coef = coef * (1.0 - s)[:, None]
            coef = jnp.where(hit, (s * yn)[:, None], coef)
            idx = jnp.where(hit, base_t[i], idx)
            intile = jnp.where(hit, i, intile)
            # s == 0 when not updating, so the recursions are no-ops there;
            # the seed's s == 1 zeroes the stale q/xi2 terms exactly.
            q_new = (
                (1.0 - s) ** 2 * q
                + 2.0 * s * (1.0 - s) * yn * g
                + s**2 * kdiag_t[i]
            )
            r_new = r + jnp.where(upd, 0.5 * (dist - r), 0.0)
            xi2_new = xi2 * (1.0 - s) ** 2 + s**2 * gain
            m_new = m + act.astype(jnp.int32)
            return (idx, coef, q_new, r_new, xi2_new, m_new, intile, kbb), None

        intile0 = jnp.full((b, s_size), -1, jnp.int32)
        (idx, coef, q, r, xi2, m, _, _), _ = jax.lax.scan(
            row_body, (idx, coef, q, r, xi2, m, intile0, kbb),
            jnp.arange(block_n),
        )
        return (idx, coef, q, r, xi2, m), None

    state, _ = jax.lax.scan(tile_body, state0, (Xt, Yt, base, valid))
    return _finish(Xf, state)


def fit_kernel_bank(
    X: jax.Array,
    Y: jax.Array,
    cs,
    *,
    kernel: str = "rbf",
    gamma=1.0,
    coreset_size: int = 64,
    eviction: str = "smallest-coef",
    variant: str = "exact",
    block_n: int = 256,
    s_tile: int | None = None,
    stream_dtype=None,
    mesh=None,
    shard_axis="data",
    vmem_budget_bytes: int | None = None,
    interpret: bool | None = None,
    seed_check: bool = True,
) -> KernelBank:
    """One-pass kernelized Algorithm 1 for a bank of B models.

    X: (N, D) shared stream; Y: (B, N) per-model label signs in {-1, 0, +1}
    (0 marks a row inert for that model — the same padding contract as the
    linear engine). ``Y[:, 0]`` must be +-1: row 0 seeds every model, and a
    sign-0 seed is almost always a label-encoding bug, so it raises a
    ValueError naming the offending model rows (checked eagerly; inside a
    jit trace the check is skipped and the engine's deferred seeding takes
    the first live row instead). cs: scalar or (B,) per-model C and
    ``gamma`` are both TRACED — C and gamma sweeps reuse one compilation;
    ``kernel``/``coreset_size``/``eviction`` are static.

    kernel: "rbf" (K = exp(-gamma d^2), d^2 clamped at 0) or "linear".
    coreset_size: S — the per-model buffer bound. With S >= N the buffer
    never evicts and the fit equals the dense ``fit_kernelized`` per model;
    smaller S trades accuracy for O(B*S*D) state.
    eviction: "smallest-coef" (drop the smallest |coef| slot) or
    "farthest-point" (drop the slot closest to the center — keep the
    extreme points that carry the ball geometry). Both oracle-tested.
    variant: "exact" / "paper-listing" — Algorithm 1's slack gain.
    s_tile: chunk the K_cs Gram launch over the S axis (bit-exact f32) so a
    (B * S, D) core-set operand beyond the VMEM budget still trains; the
    preflight below raises an actionable error naming this knob.
    block_n / stream_dtype / interpret: the tiling and dtype knobs of the
    linear engine. ``stream_dtype="bf16"`` rounds the streamed tiles (the
    Gram operand) to bf16; buffered core-set points and all state stay f32.
    mesh / shard_axis: shard the STREAM over the mesh axes — per-shard
    engine passes folded with the kernelized Sec-4.3 merge
    (``distributed.fit_kernel_bank_sharded``; ragged N pads inert).
    vmem_budget_bytes: preflight budget override (else
    ``REPRO_VMEM_BUDGET_BYTES`` / the 16 MiB default).
    seed_check: pass False to skip the eager Y[:, 0] seed-sign validation.
    For a mid-stream CONTINUATION chunk (repro.live trains each arriving
    chunk as its own fit and Sec-4.3-merges it into the slot's prior state)
    there is no "row 0 seeds the model" contract — any model may be inert
    on the chunk's first row — and the engine's deferred seeding handles
    that exactly. First-fit callers should keep the default.
    """
    if kernel not in _KERNELS:
        raise ValueError(
            f"unknown kernel {kernel!r}; expected one of {_KERNELS}"
        )
    if eviction not in _EVICTIONS:
        raise ValueError(
            f"unknown eviction {eviction!r}; expected one of {_EVICTIONS}"
        )
    if variant not in ("exact", "paper-listing"):
        raise ValueError(
            f"unknown variant {variant!r}; expected 'exact' or "
            "'paper-listing'"
        )
    if int(coreset_size) < 1:
        raise ValueError(f"coreset_size must be >= 1, got {coreset_size}")
    if s_tile is not None and int(s_tile) < 1:
        raise ValueError(f"s_tile must be >= 1 (or None), got {s_tile}")
    if Y.ndim != 2:
        raise ValueError(f"Y must be (B, N) sign rows: got Y.shape={Y.shape}")
    if seed_check and not isinstance(Y, jax.core.Tracer):
        # Eager seed-sign validation (satellite of the deferred-seed change):
        # the old engine silently seeded coef = 0 with a live q here.
        bad = np.flatnonzero(np.asarray(Y[:, 0]) == 0)
        if bad.size:
            raise ValueError(
                "fit_kernel_bank needs Y[:, 0] in {-1, +1}: row 0 seeds "
                "every model, and a sign-0 seed almost always means the "
                "label encoding dropped a model. Offending model rows "
                f"(Y[b, 0] == 0): b = {bad.tolist()}"
            )
    from repro.kernels.ops import (
        kernel_engine_vmem_bytes,
        vmem_budget_bytes as _vmem_budget,
    )

    b = Y.shape[0]
    d = X.shape[1]
    by = kernel_engine_vmem_bytes(
        b, d, coreset_size=coreset_size, block_n=block_n, s_tile=s_tile,
        stream_dtype=stream_dtype,
    )
    budget = _vmem_budget(vmem_budget_bytes)
    if sum(by.values()) > budget:
        raise ValueError(
            f"fit_kernel_bank with B={b}, D={d}, S={coreset_size}, "
            f"block_n={block_n}, s_tile={s_tile} needs a per-step VMEM "
            f"working set of {sum(by.values())} bytes (breakdown: {by}), "
            f"exceeding the budget of {budget} bytes — pass a smaller "
            "s_tile= (chunks the core-set Gram operand, bit-exact f32) or "
            "shrink block_n. The budget follows vmem_budget_bytes(): pass "
            "vmem_budget_bytes= or set REPRO_VMEM_BUDGET_BYTES."
        )
    if mesh is not None:
        from .distributed import fit_kernel_bank_sharded  # lazy: module cycle

        return fit_kernel_bank_sharded(
            X, Y, cs, mesh,
            axis=shard_axis, kernel=kernel, gamma=gamma,
            coreset_size=coreset_size, eviction=eviction, variant=variant,
            block_n=block_n, s_tile=s_tile, stream_dtype=stream_dtype,
            interpret=interpret,
        )
    return _fit_kernel_bank(
        X, Y, cs, gamma,
        kernel=kernel, coreset_size=coreset_size, eviction=eviction,
        variant=variant, block_n=block_n, s_tile=s_tile,
        stream_dtype=stream_dtype, interpret=interpret,
    )


# The jit-cache regression tests (C sweep, gamma sweep) read the engine's
# cache through the public name.
fit_kernel_bank._cache_size = _fit_kernel_bank._cache_size


def _finish(Xf, state) -> KernelBank:
    idx, coef, q, r, xi2, m = state
    points = jnp.where((idx >= 0)[..., None], Xf[jnp.clip(idx, 0)], 0.0)
    return KernelBank(
        idx=idx, coef=coef, points=points, q=q, r=r, xi2=xi2, m=m
    )


def kernel_bank_decision(
    bank: KernelBank,
    X: jax.Array,
    *,
    kernel: str = "rbf",
    gamma=1.0,
    interpret: bool | None = None,
) -> jax.Array:
    """(Q, B) decision margins of every model against the stored core sets.

    Routes through the fused serving kernel (``ops.predict_kernel_bank``,
    "scores" epilogue) — the same path ``BankServer`` serves, so served
    scores are bit-exact with this readout.
    """
    from repro.kernels.ops import predict_kernel_bank

    return predict_kernel_bank(
        X, bank.points, bank.coef, kernel=kernel, gamma=gamma,
        interpret=interpret,
    )


def save_kernel_bank(
    path: str,
    bank: KernelBank,
    *,
    kernel: str,
    gamma: float = 1.0,
    meta: dict | None = None,
) -> None:
    """Checkpoint a KernelBank so ``BankServer.from_checkpoint`` can serve it.

    Persists the 7-leaf bank pytree via ``repro.checkpoint.ckpt.save`` with
    ``meta["bank_kind"] = "kernel"`` plus the kernel config the fit used —
    the serve side needs them to rebuild the decision function. Sharded-
    trained banks checkpoint identically: the fold replicates the same
    7-leaf pytree on every device.
    """
    from repro.checkpoint import ckpt

    if kernel not in _KERNELS:
        raise ValueError(
            f"unknown kernel {kernel!r}; expected one of {_KERNELS}"
        )
    full_meta = dict(meta or {})
    full_meta.update(
        {"bank_kind": "kernel", "kernel": kernel, "gamma": float(gamma)}
    )
    ckpt.save(path, bank, meta=full_meta)
