"""One-pass kernelized bank: B core-set CVMs per stream read (paper Sec 4.2).

The dense kernelized StreamSVM (``kernelized.fit_kernelized``) keeps the full
N-vector of Lagrange coefficients — O(N) memory and O(N) kernel rows per
step, which forfeits the paper's constant-storage claim. This module is the
bank engine's kernel-space twin with BOUNDED memory: every model of a B-model
bank keeps a fixed-size **core-set buffer** of at most ``coreset_size`` (S)
stream rows,

  idx:  (B, S) int32  — stream indices of the buffered core vectors (-1 free)
  coef: (B, S) f32    — their signed Lagrange coefficients,

so state is O(B * S * D) no matter how long the stream, and the stream is
read ONCE for all B models (classes x C-grid flatten onto the bank axis,
exactly like ``fit_bank``).

Per stream tile the engine computes two kernel blocks through the tiled
Pallas Gram kernel (``kernels.ops.gram``, fused linear/RBF epilogues):

  K_cs = k(tile, core sets)   (block_n, B, S)  — one gram call for ALL models
  K_tt = k(tile, tile)        (block_n, block_n)

and then runs the O(block_n * B * S) coefficient recursion (a lax.scan of
cheap elementwise work — the MXU-shaped O(block_n * B * S * D) kernel
evaluations all live in the gram calls). A row inserted mid-tile reads its
kernel values against later rows from K_tt, so the recursion is exactly
row-at-a-time despite the tiled evaluation.

When a model's buffer is full, the incoming core vector **evicts the
smallest-|coef| slot** — the bounded-buffer compression step ("On Coresets
for SVMs", PAPERS.md): the recursion scales every coefficient by (1 - s) at
each absorb, so the smallest |coef| is the slot contributing least to the
center. The running center norm q keeps the dense recursion (it needs only
g and k(x, x)), so with ``coreset_size >= N`` nothing is ever evicted and
the engine reproduces ``fit_kernelized`` exactly — property-tested, per
model, in tests/test_kernel_bank.py.

Kernels must satisfy K(x, x) ~ kappa (constant diagonal); the RBF epilogue
clamps d^2 at 0 so duplicates cannot push K above kappa (the bug fixed in
``kernelized.rbf_kernel`` this PR).

Serving rides ``kernels.ops.predict_kernel_bank`` (same fused Gram
epilogues against the stored core-set points) and ``serve.BankServer``
(kernel-bank checkpoints carry ``meta={"bank_kind": "kernel", ...}``).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

_KERNELS = ("linear", "rbf")


class KernelBank(NamedTuple):
    """Streaming state / result of the kernelized bank engine.

    idx:    (B, S) int32 — stream index of each buffered core vector, -1 for
            a free slot.
    coef:   (B, S) f32 — signed Lagrange coefficients (exactly 0 in free
            slots, so free slots never contribute to any readout).
    points: (B, S, D) f32 — the buffered core vectors themselves (zeros in
            free slots), gathered once at the end of the fit so checkpoints
            are self-contained (serving never needs the stream back).
    q:      (B,) running |center|^2 (dense recursion — see module docstring).
    r:      (B,) radius.
    xi2:    (B,) slack-block squared norm.
    m:      (B,) int32 core-vector absorb count (the paper's M).
    """

    idx: jax.Array
    coef: jax.Array
    points: jax.Array
    q: jax.Array
    r: jax.Array
    xi2: jax.Array
    m: jax.Array


def _kdiag(X, kernel: str, gamma: float):
    """k(x, x) per row, matching the Gram epilogue's arithmetic."""
    x2 = jnp.sum(X.astype(jnp.float32) ** 2, axis=-1)
    if kernel == "rbf":
        return jnp.exp(-gamma * jnp.maximum(x2 + x2 - 2.0 * x2, 0.0))
    return x2


@partial(
    jax.jit,
    static_argnames=(
        "kernel", "gamma", "coreset_size", "variant", "block_n",
        "stream_dtype", "interpret",
    ),
)
def fit_kernel_bank(
    X: jax.Array,
    Y: jax.Array,
    cs,
    *,
    kernel: str = "rbf",
    gamma: float = 1.0,
    coreset_size: int = 64,
    variant: str = "exact",
    block_n: int = 256,
    stream_dtype=None,
    interpret: bool | None = None,
) -> KernelBank:
    """One-pass kernelized Algorithm 1 for a bank of B models.

    X: (N, D) shared stream; Y: (B, N) per-model label signs in {-1, 0, +1}
    (0 marks a row inert for that model — the same padding contract as the
    linear engine; row 0 seeds every model, so ``Y[:, 0]`` must be +-1).
    cs: scalar or (B,) per-model C (traced — a C sweep reuses one
    compilation; ``kernel``/``gamma``/``coreset_size`` are static, so those
    sweeps recompile).

    kernel: "rbf" (K = exp(-gamma d^2), d^2 clamped at 0) or "linear".
    coreset_size: S — the per-model buffer bound. With S >= N the buffer
    never evicts and the fit equals the dense ``fit_kernelized`` per model;
    smaller S trades accuracy for O(B*S*D) state via smallest-|coef|
    eviction.
    variant: "exact" / "paper-listing" — Algorithm 1's slack gain.
    block_n / stream_dtype / interpret: the tiling and dtype knobs of the
    linear engine. ``stream_dtype="bf16"`` rounds the streamed tiles (the
    Gram operand) to bf16; buffered core-set points and all state stay f32.
    """
    n, d = X.shape
    b, n_y = Y.shape
    if n_y != n:
        raise ValueError(
            f"Y must be (B, N) sign rows matching X: got Y.shape={Y.shape}, "
            f"X.shape={X.shape}"
        )
    if kernel not in _KERNELS:
        raise ValueError(
            f"unknown kernel {kernel!r}; expected one of {_KERNELS}"
        )
    if variant not in ("exact", "paper-listing"):
        raise ValueError(
            f"unknown variant {variant!r}; expected 'exact' or "
            "'paper-listing'"
        )
    s_size = int(coreset_size)
    if s_size < 1:
        raise ValueError(f"coreset_size must be >= 1, got {coreset_size}")
    from repro.kernels.ops import _resolve_stream_dtype, gram

    sdt = _resolve_stream_dtype(stream_dtype)
    Xf = X.astype(jnp.float32)
    cs = jnp.broadcast_to(jnp.asarray(cs, jnp.float32), (b,))
    c_inv = 1.0 / cs
    gain = c_inv if variant == "exact" else jnp.ones_like(c_inv)

    # Init (paper line 3) from row 0, per model: one core vector, coef y0.
    idx0 = jnp.full((b, s_size), -1, jnp.int32).at[:, 0].set(0)
    coef0 = jnp.zeros((b, s_size), jnp.float32).at[:, 0].set(
        Y[:, 0].astype(jnp.float32)
    )
    q0 = jnp.broadcast_to(_kdiag(Xf[0], kernel, gamma), (b,))
    state0 = (
        idx0, coef0, q0,
        jnp.zeros((b,), jnp.float32),  # r
        gain,                          # xi2 = 1/C (exact) or 1
        jnp.ones((b,), jnp.int32),     # m
    )
    ns = n - 1
    if ns == 0:
        return _finish(Xf, state0)

    # Tile rows 1..N-1; padded rows are masked invalid.
    n_tiles = -(-ns // block_n)
    pad = n_tiles * block_n - ns
    Xt = jnp.pad(Xf[1:], ((0, pad), (0, 0))).reshape(n_tiles, block_n, d)
    # Y was (B, N); drop the consumed row 0 before padding.
    Yt = (
        jnp.pad(Y[:, 1:].astype(jnp.float32), ((0, 0), (0, pad)))
        .reshape(b, n_tiles, block_n)
        .transpose(1, 0, 2)
    )
    valid = (jnp.arange(n_tiles * block_n) < ns).reshape(n_tiles, block_n)
    base = (1 + jnp.arange(n_tiles * block_n, dtype=jnp.int32)).reshape(
        n_tiles, block_n
    )

    def tile_body(carry, xs):
        idx, coef, q, r, xi2, m = carry
        x_tile, y_tile, base_t, valid_t = xs
        x_stream = x_tile if sdt is None else x_tile.astype(sdt)
        # Core-set rows at tile entry, gathered once; free slots read row 0
        # but are zeroed (their coef is 0 anyway — this keeps the gather
        # deterministic).
        xc = jnp.where(
            (idx >= 0)[..., None], Xf[jnp.clip(idx, 0)], 0.0
        )  # (B, S, D)
        # ONE fused Gram launch covers every model's core set...
        k_cs = gram(
            x_stream, xc.reshape(b * s_size, d),
            epilogue=kernel, gamma=gamma, interpret=interpret,
        ).reshape(block_n, b, s_size)
        # ...and one more covers rows inserted mid-tile.
        k_tt = gram(
            x_stream, x_stream, epilogue=kernel, gamma=gamma,
            interpret=interpret,
        )
        kdiag_t = jnp.diagonal(k_tt)

        def row_body(rcarry, i):
            idx, coef, q, r, xi2, m, intile = rcarry
            # Kernel row of each buffered core vector against stream row i:
            # from K_tt if the slot was filled earlier in this tile, else
            # from the tile-entry K_cs block.
            kv = jnp.where(
                intile >= 0, k_tt[jnp.clip(intile, 0), i], k_cs[i]
            )  # (B, S)
            g = jnp.sum(coef * kv, axis=1)
            yn = y_tile[:, i]
            d2 = q - 2.0 * yn * g + kdiag_t[i] + xi2 + c_inv
            dist = jnp.sqrt(jnp.maximum(d2, 1e-12))
            upd = jnp.logical_and(
                dist >= r, jnp.logical_and(valid_t[i], yn != 0)
            )
            s = jnp.where(upd, 0.5 * (1.0 - r / dist), 0.0)
            # Slot choice: free slots carry coef == 0 so argmin|coef| finds
            # them first; with a full buffer this IS the coreset-compression
            # eviction (the uniform (1-s) scaling preserves the ordering).
            slot = jnp.argmin(jnp.abs(coef), axis=1)
            hit = jnp.logical_and(
                jnp.arange(s_size)[None, :] == slot[:, None], upd[:, None]
            )
            coef = coef * (1.0 - s)[:, None]
            coef = jnp.where(hit, (s * yn)[:, None], coef)
            idx = jnp.where(hit, base_t[i], idx)
            intile = jnp.where(hit, i, intile)
            # s == 0 when not updating, so the recursions are no-ops there.
            q_new = (
                (1.0 - s) ** 2 * q
                + 2.0 * s * (1.0 - s) * yn * g
                + s**2 * kdiag_t[i]
            )
            r_new = r + jnp.where(upd, 0.5 * (dist - r), 0.0)
            xi2_new = xi2 * (1.0 - s) ** 2 + s**2 * gain
            m_new = m + upd.astype(jnp.int32)
            return (idx, coef, q_new, r_new, xi2_new, m_new, intile), None

        intile0 = jnp.full((b, s_size), -1, jnp.int32)
        (idx, coef, q, r, xi2, m, _), _ = jax.lax.scan(
            row_body, (idx, coef, q, r, xi2, m, intile0),
            jnp.arange(block_n),
        )
        return (idx, coef, q, r, xi2, m), None

    state, _ = jax.lax.scan(tile_body, state0, (Xt, Yt, base, valid))
    return _finish(Xf, state)


def _finish(Xf, state) -> KernelBank:
    idx, coef, q, r, xi2, m = state
    points = jnp.where((idx >= 0)[..., None], Xf[jnp.clip(idx, 0)], 0.0)
    return KernelBank(
        idx=idx, coef=coef, points=points, q=q, r=r, xi2=xi2, m=m
    )


def kernel_bank_decision(
    bank: KernelBank,
    X: jax.Array,
    *,
    kernel: str = "rbf",
    gamma: float = 1.0,
    interpret: bool | None = None,
) -> jax.Array:
    """(Q, B) decision margins of every model against the stored core sets.

    Routes through the fused serving kernel (``ops.predict_kernel_bank``,
    "scores" epilogue) — the same path ``BankServer`` serves, so served
    scores are bit-exact with this readout.
    """
    from repro.kernels.ops import predict_kernel_bank

    return predict_kernel_bank(
        X, bank.points, bank.coef, kernel=kernel, gamma=gamma,
        interpret=interpret,
    )


def save_kernel_bank(
    path: str,
    bank: KernelBank,
    *,
    kernel: str,
    gamma: float = 1.0,
    meta: dict | None = None,
) -> None:
    """Checkpoint a KernelBank so ``BankServer.from_checkpoint`` can serve it.

    Persists the 7-leaf bank pytree via ``repro.checkpoint.ckpt.save`` with
    ``meta["bank_kind"] = "kernel"`` plus the (static) kernel config the fit
    used — the serve side needs them to rebuild the decision function.
    """
    from repro.checkpoint import ckpt

    if kernel not in _KERNELS:
        raise ValueError(
            f"unknown kernel {kernel!r}; expected one of {_KERNELS}"
        )
    full_meta = dict(meta or {})
    full_meta.update(
        {"bank_kind": "kernel", "kernel": kernel, "gamma": float(gamma)}
    )
    ckpt.save(path, bank, meta=full_meta)
