"""Distributed one-pass StreamSVM — beyond-paper mesh parallelism.

The stream is sharded into contiguous ranges across mesh axes; each shard
runs Algorithm 1/2 locally (one pass, O(D) state), then shards exchange their
balls with an all_gather and every shard deterministically folds them with the
paper's Sec-4.3 merge operator (exact in the augmented space because shards
touch disjoint slack coordinates — DESIGN.md §5).

Communication: one all_gather of (D+3) floats per shard, once per stream —
negligible against ICI bandwidth at any D that fits in HBM.

The fold is commutative-associative up to float error (property-tested), so
straggler re-assignment / elastic reshard does not change the model class.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 public API (replication check kwarg renamed to check_vma)
    from jax import shard_map as _shard_map
    _CHECK_REP_KW = "check_vma"
except ImportError:  # older jax: experimental location, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_REP_KW = "check_rep"

from .meb import Ball, fold_merge
from .streamsvm import fit, fit_lookahead


def fit_sharded(
    X: jax.Array,
    y: jax.Array,
    c: float,
    mesh: Mesh,
    *,
    axis: str | Tuple[str, ...] = "data",
    lookahead: int = 1,
    variant: str = "exact",
) -> Ball:
    """One-pass fit with the stream sharded over ``axis`` of ``mesh``.

    X: (N, D), y: (N,). N must divide by the product of the axis sizes.
    Returns the merged Ball, replicated on every device.
    """
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    assert X.shape[0] % n_shards == 0, (X.shape, n_shards)

    def local_fit(Xs, ys):
        # Xs: (N/n_shards, D) local contiguous range of the stream.
        if lookahead <= 1:
            ball = fit(Xs, ys, c, variant=variant)
        else:
            ball = fit_lookahead(Xs, ys, c, lookahead, variant=variant)
        # Exchange balls and fold identically on every shard.
        stacked = Ball(
            w=jax.lax.all_gather(ball.w, axes, tiled=False),
            r=jax.lax.all_gather(ball.r, axes),
            xi2=jax.lax.all_gather(ball.xi2, axes),
            m=jax.lax.all_gather(ball.m, axes),
        )
        return fold_merge(stacked)

    spec = P(axes)
    fn = _shard_map(
        local_fit,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=jax.tree.map(lambda _: P(), Ball(0, 0, 0, 0)),
        # scalar ball carries are constant-initialized per shard
        **{_CHECK_REP_KW: False},
    )
    X = jax.device_put(X, NamedSharding(mesh, P(axes)))
    y = jax.device_put(y, NamedSharding(mesh, P(axes)))
    return fn(X, y)
