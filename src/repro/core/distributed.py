"""Distributed one-pass StreamSVM — beyond-paper mesh parallelism.

The stream is sharded into contiguous ranges across mesh axes; each shard
runs Algorithm 1/2 locally (one pass, O(D) state), then shards exchange their
balls with an all_gather and every shard deterministically folds them with the
paper's Sec-4.3 merge operator (exact in the augmented space because shards
touch disjoint slack coordinates — DESIGN.md §5).

Two entry points:

``fit_sharded``       one model, scan-path Algorithm 1/2 per shard.
``fit_bank_sharded``  a BANK of B models per shard via the tiled multi-ball
                      Pallas engine — M stream shards x B models in ONE data
                      pass each, folded with the bank-vectorized merge
                      (meb.fold_merge over the gathered (S, B, ...) stack).
                      Ragged streams are padded with inert sign-0 rows, so
                      any N works on any shard count.
``fit_kernel_bank_sharded``
                      the KERNELIZED bank per shard (bounded core-set
                      buffers), folded with the kernelized Sec-4.3 merge
                      (meb.merge_kernel_banks: cross-Gram center distance +
                      coreset-of-coresets compression back to S slots).

Communication: one all_gather of B * (D+3) floats per shard, once per stream —
negligible against ICI bandwidth at any B * D that fits in HBM.

The fold is commutative and, up to bounded geometric slack, order-invariant
(any fold order yields an enclosing ball with radius within 2x of the optimum
and center inside the hull of the shard centers — property-tested in
tests/test_sharded_bank.py), so straggler re-assignment / elastic reshard
does not change the model class.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 public API (replication check kwarg renamed to check_vma)
    from jax import shard_map as _shard_map
    _CHECK_REP_KW = "check_vma"
except ImportError:  # older jax: experimental location, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_REP_KW = "check_rep"

from .kernel_bank import KernelBank, _fit_kernel_bank
from .meb import Ball, fold_merge, merge_banks, merge_kernel_banks
from .streamsvm import fit, fit_lookahead


def _mesh_axes(axis: str | Tuple[str, ...]) -> Tuple[str, ...]:
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _n_shards(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def shard_ranges(n: int, n_shards: int) -> list[Tuple[int, int]]:
    """The canonical ceil-split of ``n`` stream rows into ``n_shards``
    contiguous ``[lo, hi)`` ranges — exactly the ranges ``fit_bank_sharded``
    and ``fit_kernel_bank_sharded`` assign to mesh shards (rows-per-shard
    ``ceil(n / n_shards)``, remainder padded with inert rows on the last
    live shard, trailing shards empty).

    Always returns ``n_shards`` entries; shards past the data get empty
    ``(n, n)`` ranges. The elastic live loop keys its LOGICAL fold structure
    on these ranges, so per-range single-device fits fold bit-identically to
    the mesh fast path regardless of the physical device count.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1: got {n_shards}")
    if n < 0:
        raise ValueError(f"n must be >= 0: got {n}")
    shard_n = -(-n // n_shards) if n else 0
    return [
        (min(j * shard_n, n), min((j + 1) * shard_n, n))
        for j in range(n_shards)
    ]


def fit_sharded(
    X: jax.Array,
    y: jax.Array,
    c: float,
    mesh: Mesh,
    *,
    axis: str | Tuple[str, ...] = "data",
    lookahead: int = 1,
    variant: str = "exact",
) -> Ball:
    """One-pass fit with the stream sharded over ``axis`` of ``mesh``.

    X: (N, D), y: (N,). N must divide by the product of the axis sizes
    (``fit_bank_sharded`` lifts this by padding with inert rows).
    Returns the merged Ball, replicated on every device.
    """
    axes = _mesh_axes(axis)
    n_shards = _n_shards(mesh, axes)
    if X.shape[0] % n_shards != 0:
        raise ValueError(
            f"X rows must divide evenly over the {n_shards} stream shards of "
            f"mesh axes {axes}: got X.shape={X.shape}. Pad the stream, or "
            "use fit_bank_sharded, which pads ragged remainders with inert "
            "sign-0 rows."
        )

    def local_fit(Xs, ys):
        # Xs: (N/n_shards, D) local contiguous range of the stream.
        if lookahead <= 1:
            ball = fit(Xs, ys, c, variant=variant)
        else:
            ball = fit_lookahead(Xs, ys, c, lookahead, variant=variant)
        # Exchange balls and fold identically on every shard.
        stacked = Ball(
            w=jax.lax.all_gather(ball.w, axes, tiled=False),
            r=jax.lax.all_gather(ball.r, axes),
            xi2=jax.lax.all_gather(ball.xi2, axes),
            m=jax.lax.all_gather(ball.m, axes),
        )
        return fold_merge(stacked)

    spec = P(axes)
    fn = _shard_map(
        local_fit,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=jax.tree.map(lambda _: P(), Ball(0, 0, 0, 0)),
        # scalar ball carries are constant-initialized per shard
        **{_CHECK_REP_KW: False},
    )
    X = jax.device_put(X, NamedSharding(mesh, P(axes)))
    y = jax.device_put(y, NamedSharding(mesh, P(axes)))
    return fn(X, y)


@partial(
    jax.jit,
    static_argnames=(
        "mesh", "axes", "n_shards", "shard_n", "n_rows", "variant",
        "lookahead", "block_n", "b_tile", "stream_dtype", "bank_resident",
        "interpret",
    ),
)
def _sharded_fold(
    X, Y, cs, *,
    mesh, axes, n_shards, shard_n, n_rows, variant, lookahead, block_n,
    b_tile, stream_dtype, bank_resident, interpret,
):
    """jit'd shard_map core of fit_bank_sharded.

    Module-level so repeated calls with the same (shapes, mesh, config) hit
    the jit cache instead of rebuilding and re-tracing the shard_map closure
    — fit_chunked_many(mesh=...) calls this once per CHUNK.
    """

    def local_fit(Xs, Ys, cs_):
        from repro.kernels.ops import streamsvm_fit_many  # lazy: module cycle

        # Shards whose whole contiguous range is padding produce a
        # placeholder ball; mask them out of the fold so padding never
        # changes results. A trace-time constant: every quantity is static.
        live = jnp.arange(n_shards) * shard_n < n_rows
        bank = streamsvm_fit_many(
            Xs, Ys, cs_, None,
            variant=variant, lookahead=lookahead, block_n=block_n,
            b_tile=b_tile, stream_dtype=stream_dtype,
            bank_resident=bank_resident, interpret=interpret,
        )
        gather = lambda v: jax.lax.all_gather(v, axes, tiled=False)
        stacked = Ball(
            w=gather(bank.w), r=gather(bank.r),
            xi2=gather(bank.xi2), m=gather(bank.m),
        )  # (S, B, ...) on every shard
        return fold_merge(stacked, live=live)

    fn = _shard_map(
        local_fit,
        mesh=mesh,
        in_specs=(P(axes), P(None, axes), P()),
        out_specs=jax.tree.map(lambda _: P(), Ball(0, 0, 0, 0)),
        **{_CHECK_REP_KW: False},
    )
    return fn(X, Y, cs)


@partial(
    jax.jit,
    static_argnames=(
        "mesh", "axes", "n_shards", "shard_n", "n_rows", "kernel",
        "coreset_size", "eviction", "variant", "block_n", "s_tile",
        "stream_dtype", "interpret",
    ),
)
def _sharded_kernel_fold(
    X, Y, cs, gamma, *,
    mesh, axes, n_shards, shard_n, n_rows, kernel, coreset_size, eviction,
    variant, block_n, s_tile, stream_dtype, interpret,
):
    """jit'd shard_map core of fit_kernel_bank_sharded.

    Module-level for the same jit-cache reason as ``_sharded_fold``. Each
    shard runs the kernelized engine over its contiguous range (the engine's
    DEFERRED seeding makes ranges starting with inert sign-0 rows — or
    entirely padding — correct without special-casing), rewrites its
    buffer's stream indices to GLOBAL coordinates, gathers every shard's
    7-leaf bank, and folds them with the kernelized Sec-4.3 merge. Fully
    padded shards produce m == 0 banks — exact merge identities — and are
    additionally skipped statically (shard liveness is a trace-time
    constant).
    """

    def local_fit(Xs, Ys, cs_, gamma_):
        bank = _fit_kernel_bank(
            Xs, Ys, cs_, gamma_,
            kernel=kernel, coreset_size=coreset_size, eviction=eviction,
            variant=variant, block_n=block_n, s_tile=s_tile,
            stream_dtype=stream_dtype, interpret=interpret,
        )
        # Shard-local buffer indices -> global stream indices (the shards
        # hold contiguous ranges in mesh-axes row-major order, matching the
        # all_gather stacking below). Points were already gathered from the
        # LOCAL rows by the engine, so only idx needs the offset.
        sid = jnp.zeros((), jnp.int32)
        for a in axes:
            sid = sid * mesh.shape[a] + jax.lax.axis_index(a)
        bank = bank._replace(
            idx=jnp.where(bank.idx >= 0, bank.idx + sid * shard_n, bank.idx)
        )
        gather = lambda v: jax.lax.all_gather(v, axes, tiled=False)
        stacked = KernelBank(*(gather(leaf) for leaf in bank))
        take = lambda i: jax.tree.map(lambda x: x[i], stacked)
        live = [i * shard_n < n_rows for i in range(n_shards)]
        acc = None
        for i in range(n_shards):
            if not live[i]:
                continue
            acc = take(i) if acc is None else merge_kernel_banks(
                acc, take(i), kernel=kernel, gamma=gamma_, eviction=eviction
            )
        return acc

    fn = _shard_map(
        local_fit,
        mesh=mesh,
        in_specs=(P(axes), P(None, axes), P(), P()),
        out_specs=jax.tree.map(lambda _: P(), KernelBank(*range(7))),
        **{_CHECK_REP_KW: False},
    )
    return fn(X, Y, cs, gamma)


def fit_kernel_bank_sharded(
    X: jax.Array,
    Y: jax.Array,
    cs,
    mesh: Mesh,
    *,
    axis: str | Tuple[str, ...] = "data",
    kernel: str = "rbf",
    gamma=1.0,
    coreset_size: int = 64,
    eviction: str = "smallest-coef",
    variant: str = "exact",
    block_n: int = 256,
    s_tile: int | None = None,
    stream_dtype=None,
    interpret: bool | None = None,
) -> KernelBank:
    """M stream shards x B kernelized models in one pass each.

    The kernel-space twin of ``fit_bank_sharded``: the stream is split into
    ``n_shards`` contiguous ranges over the ``axis`` axes of ``mesh``; every
    shard runs the tiled core-set engine (``core.fit_kernel_bank``'s jit'd
    core — ``coreset_size``, ``eviction``, ``s_tile``, ``stream_dtype`` all
    apply per shard) over its local range, the per-shard (B, S) banks are
    exchanged with one all_gather (B * S * (D + 2) floats + the ball
    scalars, still independent of N), and every model lane is folded with
    the kernelized Sec-4.3 merge: concatenate core-set buffers, re-compress
    to S slots (coreset-of-coresets), merge (q, r, xi2) with the
    ``merge_balls`` algebra (``meb.merge_kernel_banks``).

    Ragged N is fine: the remainder is padded with inert rows (feature 0,
    sign 0), shard ranges that START with padding seed on their first live
    row (the engine's deferred seeding), and fully-padded shards fold as
    exact m == 0 identities AND are skipped statically. The folded bank's
    ``idx`` leaf carries GLOBAL stream indices, so the result is directly
    comparable with a single-device fit's buffer.

    Numpy oracle for the fold: per-range single-device fits merged with
    ``kernels.ref.merge_kernel_banks_ref`` (tests/test_kernel_merge.py).
    Returns the folded KernelBank, replicated on every device — checkpoint
    it with ``save_kernel_bank`` and ``BankServer.from_checkpoint`` serves
    it bit-exact with ``kernel_bank_decision`` (f32).
    """
    axes = _mesh_axes(axis)
    n_shards = _n_shards(mesh, axes)
    n, d = X.shape
    b = Y.shape[0]
    if Y.shape != (b, n):
        raise ValueError(
            f"Y must be (B, N) sign rows matching X: got Y.shape={Y.shape}, "
            f"X.shape={X.shape}"
        )
    if n < 1:
        raise ValueError(f"need at least one stream row: got X.shape={X.shape}")
    cs = jnp.broadcast_to(jnp.asarray(cs, jnp.float32), (b,))
    gamma = jnp.asarray(gamma, jnp.float32)

    shard_n = -(-n // n_shards)  # rows per shard, ceil
    pad = shard_n * n_shards - n
    if pad:
        # Inert remainder rows: feature 0 AND sign 0 — never seed, violate
        # or absorb, so the padded run folds identically to the ragged
        # ranges.
        X = jnp.pad(X, ((0, pad), (0, 0)))
        Y = jnp.pad(Y, ((0, 0), (0, pad)))
    if not isinstance(X, jax.core.Tracer):  # eager call: place shards up front
        X = jax.device_put(X, NamedSharding(mesh, P(axes)))
        Y = jax.device_put(Y, NamedSharding(mesh, P(None, axes)))
    return _sharded_kernel_fold(
        X, Y, cs, gamma,
        mesh=mesh, axes=axes, n_shards=n_shards, shard_n=shard_n, n_rows=n,
        kernel=kernel, coreset_size=coreset_size, eviction=eviction,
        variant=variant, block_n=block_n, s_tile=s_tile,
        stream_dtype=stream_dtype, interpret=interpret,
    )


@partial(
    jax.jit,
    static_argnames=(
        "mesh", "axes", "n_shards", "shard_n", "kernel", "coreset_size",
        "eviction", "variant", "block_n", "s_tile", "stream_dtype",
        "interpret",
    ),
)
def _sharded_kernel_shards(
    X, Y, cs, gamma, *,
    mesh, axes, n_shards, shard_n, kernel, coreset_size, eviction,
    variant, block_n, s_tile, stream_dtype, interpret,
):
    """jit'd shard_map core of fit_kernel_bank_shards: per-shard fits +
    all_gather, NO in-jit fold. Module-level for the jit-cache reason of
    ``_sharded_kernel_fold``."""

    def local_fit(Xs, Ys, cs_, gamma_):
        bank = _fit_kernel_bank(
            Xs, Ys, cs_, gamma_,
            kernel=kernel, coreset_size=coreset_size, eviction=eviction,
            variant=variant, block_n=block_n, s_tile=s_tile,
            stream_dtype=stream_dtype, interpret=interpret,
        )
        sid = jnp.zeros((), jnp.int32)
        for a in axes:
            sid = sid * mesh.shape[a] + jax.lax.axis_index(a)
        bank = bank._replace(
            idx=jnp.where(bank.idx >= 0, bank.idx + sid * shard_n, bank.idx)
        )
        gather = lambda v: jax.lax.all_gather(v, axes, tiled=False)
        return KernelBank(*(gather(leaf) for leaf in bank))

    fn = _shard_map(
        local_fit,
        mesh=mesh,
        in_specs=(P(axes), P(None, axes), P(), P()),
        out_specs=jax.tree.map(lambda _: P(), KernelBank(*range(7))),
        **{_CHECK_REP_KW: False},
    )
    return fn(X, Y, cs, gamma)


def fit_kernel_bank_shards(
    X: jax.Array,
    Y: jax.Array,
    cs,
    mesh: Mesh,
    *,
    axis: str | Tuple[str, ...] = "data",
    kernel: str = "rbf",
    gamma=1.0,
    coreset_size: int = 64,
    eviction: str = "smallest-coef",
    variant: str = "exact",
    block_n: int = 256,
    s_tile: int | None = None,
    stream_dtype=None,
    interpret: bool | None = None,
) -> KernelBank:
    """Per-shard kernelized fits on the mesh WITHOUT the in-jit fold.

    Returns the STACKED per-shard banks — every KernelBank leaf grows a
    leading ``(n_shards,)`` axis, replicated on every device — with ``idx``
    already rewritten to global stream coordinates. The caller folds them
    however it likes (``meb.merge_kernel_banks`` / ``fold_kernel_banks``),
    typically skipping shards whose range is empty (see ``shard_ranges``).

    Why this exists next to ``fit_kernel_bank_sharded``: the in-jit fold
    fuses the merge interpolation arithmetic differently from the eager
    ``merge_kernel_banks`` chain (last-ulp q/xi2 differences), while the
    per-shard FITS are bit-identical to single-device fits of the same
    ranges. The elastic live loop needs its mesh fast path and its
    per-range degraded path to agree bit-exactly (f32), so it takes the
    stacked banks from here and folds them with the SAME eager merge code
    both paths share. Ragged N pads with inert sign-0 rows exactly like
    ``fit_kernel_bank_sharded``; fully-padded shards come back as exact
    m == 0 identity banks.
    """
    axes = _mesh_axes(axis)
    n_shards = _n_shards(mesh, axes)
    n, d = X.shape
    b = Y.shape[0]
    if Y.shape != (b, n):
        raise ValueError(
            f"Y must be (B, N) sign rows matching X: got Y.shape={Y.shape}, "
            f"X.shape={X.shape}"
        )
    if n < 1:
        raise ValueError(f"need at least one stream row: got X.shape={X.shape}")
    cs = jnp.broadcast_to(jnp.asarray(cs, jnp.float32), (b,))
    gamma = jnp.asarray(gamma, jnp.float32)

    shard_n = -(-n // n_shards)  # rows per shard, ceil (== shard_ranges)
    pad = shard_n * n_shards - n
    if pad:
        X = jnp.pad(X, ((0, pad), (0, 0)))
        Y = jnp.pad(Y, ((0, 0), (0, pad)))
    if not isinstance(X, jax.core.Tracer):  # eager call: place shards up front
        X = jax.device_put(X, NamedSharding(mesh, P(axes)))
        Y = jax.device_put(Y, NamedSharding(mesh, P(None, axes)))
    return _sharded_kernel_shards(
        X, Y, cs, gamma,
        mesh=mesh, axes=axes, n_shards=n_shards, shard_n=shard_n,
        kernel=kernel, coreset_size=coreset_size, eviction=eviction,
        variant=variant, block_n=block_n, s_tile=s_tile,
        stream_dtype=stream_dtype, interpret=interpret,
    )


def fit_bank_sharded(
    X: jax.Array,
    Y: jax.Array,
    cs,
    mesh: Mesh,
    balls: Ball | None = None,
    *,
    axis: str | Tuple[str, ...] = "data",
    variant: str = "exact",
    lookahead=None,
    block_n: int = 256,
    b_tile: int | None = None,
    stream_dtype=None,
    bank_resident: str = "auto",
    interpret: bool | None = None,
) -> Ball:
    """M stream shards x B models in one pass: the sharded bank engine.

    The stream is split into ``n_shards`` contiguous ranges over the ``axis``
    axes of ``mesh``; every shard runs the tiled multi-ball Pallas engine
    (``kernels.streamsvm_fit_many`` — ``b_tile``, fused ``lookahead``,
    ``stream_dtype="bf16"`` and ``bank_resident`` all apply per shard: each
    device holds its own bank copy, so residency is a per-shard decision
    and "auto" resolves identically on every shard) over its local range, the
    per-shard (B, D) banks are exchanged with one all_gather, and every
    model lane is folded with the Sec-4.3 merge (``meb.fold_merge`` over the
    (S, B, ...) stack). Total data movement: each stream row is read from
    HBM exactly once, on exactly one shard.

    X: (N, D) stream, Y: (B, N) per-model sign rows, cs: scalar or (B,)
    per-model C (traced). ``N % n_shards != 0`` is fine: the remainder is
    padded with inert rows (feature 0, sign 0 — the engine's sign-0 contract
    guarantees they update nothing), and shards whose whole range is padding
    are masked out of the fold, so the result is identical to folding the
    unpadded ragged ranges. (Padding is always a suffix, so every LIVE
    shard's first row — its engine init example — is a real stream row;
    the init caveat on ``streamsvm_fit_many`` never triggers here.)

    ``balls`` (a stacked bank) continues a previous fit: shards fit their
    ranges FRESH (keeping shard example-sets disjoint, which the merge's
    slack orthogonality needs) and the prior bank is folded in at the end —
    this is what makes checkpoint/resume under a mesh shard-count agnostic.

    Returns the folded bank (Ball stacked on B), replicated on every device.
    """
    axes = _mesh_axes(axis)
    n_shards = _n_shards(mesh, axes)
    n, d = X.shape
    b = Y.shape[0]
    if Y.shape != (b, n):
        raise ValueError(
            f"Y must be (B, N) sign rows matching X: got Y.shape={Y.shape}, "
            f"X.shape={X.shape}"
        )
    if n < 1:
        raise ValueError(f"need at least one stream row: got X.shape={X.shape}")
    cs = jnp.broadcast_to(jnp.asarray(cs, jnp.float32), (b,))
    if isinstance(lookahead, list):  # static arg below: must be hashable
        lookahead = tuple(lookahead)

    shard_n = -(-n // n_shards)  # rows per shard, ceil
    pad = shard_n * n_shards - n
    if pad:
        # Inert remainder rows: feature 0 AND sign 0 — the engine never lets
        # them violate, absorb, or enter a lookahead window, so the padded
        # run is bit-identical to fitting the ragged ranges directly.
        X = jnp.pad(X, ((0, pad), (0, 0)))
        Y = jnp.pad(Y, ((0, 0), (0, pad)))
    if not isinstance(X, jax.core.Tracer):  # eager call: place shards up front
        X = jax.device_put(X, NamedSharding(mesh, P(axes)))
        Y = jax.device_put(Y, NamedSharding(mesh, P(None, axes)))
    folded = _sharded_fold(
        X, Y, cs,
        mesh=mesh, axes=axes, n_shards=n_shards, shard_n=shard_n, n_rows=n,
        variant=variant, lookahead=lookahead, block_n=block_n, b_tile=b_tile,
        stream_dtype=stream_dtype, bank_resident=bank_resident,
        interpret=interpret,
    )
    if balls is not None:
        # The prior bank saw a disjoint (earlier) slice of the stream, so it
        # merges exactly like one more shard.
        prior = Ball(
            w=jnp.asarray(balls.w, jnp.float32),
            r=jnp.broadcast_to(jnp.asarray(balls.r, jnp.float32), (b,)),
            xi2=jnp.broadcast_to(jnp.asarray(balls.xi2, jnp.float32), (b,)),
            m=jnp.broadcast_to(jnp.asarray(balls.m, jnp.int32), (b,)),
        )
        if not isinstance(prior.w, jax.core.Tracer):
            # A checkpoint may come from a run on a DIFFERENT mesh (elastic
            # reshard); re-place it on this mesh so the merge has one device
            # set.
            prior = jax.tree.map(
                lambda v: jax.device_put(v, NamedSharding(mesh, P())), prior
            )
        folded = merge_banks(prior, folded)
    return folded
