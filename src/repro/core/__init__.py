"""Core StreamSVM library — the paper's contribution as composable JAX modules."""
from .meb import (
    Ball,
    center_distance,
    fold_merge,
    make_ball,
    merge_balls,
    merge_banks,
    point_distance,
)
from .streamsvm import (
    StreamCheckpoint,
    accuracy,
    decision_function,
    fit,
    fit_ball,
    fit_chunked,
    fit_chunked_many,
    fit_lookahead,
    fit_lookahead_ball,
    init_ball,
    predict,
)
from .qp import solve_meb_ball_points
from .kernelized import KernelBall, fit_kernelized, linear_kernel, rbf_kernel, linear_weights
from .kernel_bank import (
    KernelBank,
    fit_kernel_bank,
    kernel_bank_decision,
    save_kernel_bank,
)
from .distributed import fit_bank_sharded, fit_sharded
from .multiball import (
    MultiBall,
    bank_stack,
    bank_take,
    fit_bank,
    fit_multiball,
    to_single_ball,
)
from .multiclass import fit_ovr, ovr_signs, predict_c_grid, predict_ovr, fit_c_grid

__all__ = [
    "Ball",
    "KernelBall",
    "KernelBank",
    "StreamCheckpoint",
    "accuracy",
    "bank_stack",
    "bank_take",
    "center_distance",
    "decision_function",
    "fit",
    "fit_ball",
    "fit_bank",
    "fit_bank_sharded",
    "fit_c_grid",
    "fit_chunked",
    "fit_chunked_many",
    "fit_kernel_bank",
    "fit_kernelized",
    "fit_lookahead",
    "fit_lookahead_ball",
    "fit_ovr",
    "fit_sharded",
    "fold_merge",
    "init_ball",
    "kernel_bank_decision",
    "linear_kernel",
    "linear_weights",
    "make_ball",
    "merge_balls",
    "merge_banks",
    "ovr_signs",
    "point_distance",
    "predict",
    "predict_c_grid",
    "predict_ovr",
    "rbf_kernel",
    "save_kernel_bank",
    "solve_meb_ball_points",
]
