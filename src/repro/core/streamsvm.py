"""StreamSVM — one-pass l2-SVM via streaming MEB (paper Algorithms 1 & 2).

Entry points
------------
fit(X, y, c)                    Algorithm 1 (closed-form updates), lax.scan.
fit_lookahead(X, y, c, L)       Algorithm 2 (buffer L violators, BC solve).
fit_chunked(...)                python-level streaming driver over an
                                iterator of chunks, with checkpoint hooks —
                                the "real" one-pass entry point.
fit_chunked_many(...)           same driver for a BANK of B models (classes x
                                C-grid x variants) via the multi-ball Pallas
                                engine: one data pass total, O(B*D) state.
decision_function / predict     linear classifier readout.

All core math lives in meb.py / qp.py; this module provides the streaming
control flow. Everything jits; fit/fit_lookahead vmap over classes and over
hyper-parameter grids (see multiclass.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from .meb import Ball, enclose_point, make_ball, point_distance
from .qp import solve_meb_ball_points


def init_ball(x1: jax.Array, y1: jax.Array, c: float, *, variant: str = "exact") -> Ball:
    """Paper line 3: w = y1 x1, R = 0, xi2 = 1/C (exact) or 1 (paper-listing)."""
    w = y1 * x1
    xi2 = (1.0 / c) if variant == "exact" else 1.0
    return make_ball(w, r=0.0, xi2=xi2, m=1)


def _step(ball: Ball, yx: jax.Array, c_inv, variant: str) -> Tuple[Ball, jax.Array]:
    d = point_distance(ball, yx, c_inv)
    update = d >= ball.r
    new = enclose_point(ball, yx, c_inv, variant=variant)
    out = jax.tree.map(lambda a, b: jnp.where(update, a, b), new, ball)
    return out, update


def fit_ball(ball: Ball, X: jax.Array, y: jax.Array, c: float, *, variant: str = "exact") -> Ball:
    """Continue Algorithm 1 from an existing ball over (X, y)."""
    c_inv = jnp.asarray(1.0 / c, X.dtype)
    yx = y[:, None] * X

    def body(b, row):
        return _step(b, row, c_inv, variant)

    ball, _ = jax.lax.scan(body, ball, yx)
    return ball


def fit(X: jax.Array, y: jax.Array, c: float, *, variant: str = "exact") -> Ball:
    """Algorithm 1 over a full (in-memory) stream. X: (N, D), y: (N,) in ±1."""
    ball = init_ball(X[0], y[0], c, variant=variant)
    return fit_ball(ball, X[1:], y[1:], c, variant=variant)


# ---------------------------------------------------------------------------
# Algorithm 2 — lookahead
# ---------------------------------------------------------------------------


def fit_lookahead_ball(
    ball: Ball,
    X: jax.Array,
    y: jax.Array,
    c: float,
    lookahead: int,
    *,
    qp_iters: int = 128,
) -> Ball:
    """Continue Algorithm 2 from an existing ball."""
    L = int(lookahead)
    c_inv = jnp.asarray(1.0 / c, X.dtype)
    yx = y[:, None] * X
    D = X.shape[-1]

    buf0 = jnp.zeros((L, D), X.dtype)
    cnt0 = jnp.asarray(0, jnp.int32)

    def body(carry, row):
        b, buf, cnt = carry
        d = point_distance(b, row, c_inv)
        take = d >= b.r
        buf = jnp.where(take, buf.at[cnt].set(row), buf)
        cnt = cnt + take.astype(jnp.int32)

        def flush(args):
            b_, buf_, cnt_ = args
            valid = jnp.arange(L) < cnt_
            b_ = solve_meb_ball_points(b_, buf_, valid, c_inv, iters=qp_iters)
            return b_, jnp.zeros_like(buf_), jnp.zeros_like(cnt_)

        b, buf, cnt = jax.lax.cond(
            cnt >= L, flush, lambda a: a, (b, buf, cnt)
        )
        return (b, buf, cnt), take

    (ball, buf, cnt), _ = jax.lax.scan(body, (ball, buf0, cnt0), yx)
    # Final partial flush (paper lines 12-14).
    valid = jnp.arange(L) < cnt
    return solve_meb_ball_points(ball, buf, valid, c_inv, iters=qp_iters)


def fit_lookahead(
    X: jax.Array,
    y: jax.Array,
    c: float,
    lookahead: int,
    *,
    qp_iters: int = 128,
    variant: str = "exact",
    engine: str = "pallas",
    block_n: int = 256,
    stream_dtype=None,
    bank_resident: str = "auto",
) -> Ball:
    """Algorithm 2. lookahead=1 ~ Algorithm 1 (exactly, for engine="pallas").

    engine="pallas" (default) routes through the fused lookahead path of the
    multi-ball engine: the L-row window lives in VMEM next to the ball and is
    flushed farthest-point-first inside the kernel (greedy Badoiu-Clarkson
    insertion over the window), so Algorithm 2 costs the same single stream
    read as Algorithm 1. engine="qp" keeps the pre-engine behavior — a
    lax.scan that solves the buffered window with the iterative BC solver in
    qp.py (also what ``fit_chunked`` uses, chunk by chunk). The two accept
    slightly different core-vector sets (greedy insertion vs window solve);
    both satisfy the paper's enclosure guarantee.
    """
    if engine not in ("pallas", "qp"):
        raise ValueError(f"unknown engine {engine!r}; expected 'pallas' or 'qp'")
    if variant not in ("exact", "paper-listing"):
        raise ValueError(
            f"unknown variant {variant!r}; expected 'exact' or 'paper-listing'"
        )
    if engine == "pallas":
        from .multiball import fit_bank

        bank = fit_bank(
            X, y[None, :].astype(X.dtype), c,
            variant="lookahead" if variant == "exact" else "lookahead-paper",
            lookahead=int(lookahead),
            block_n=block_n, stream_dtype=stream_dtype,
            bank_resident=bank_resident,
        )
        return jax.tree.map(lambda v: v[0], bank)
    ball = init_ball(X[0], y[0], c, variant=variant)
    return fit_lookahead_ball(ball, X[1:], y[1:], c, lookahead, qp_iters=qp_iters)


# ---------------------------------------------------------------------------
# Streaming driver (true one-pass over an iterator, constant memory)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StreamCheckpoint:
    ball: Ball
    position: int  # number of examples consumed


def fit_chunked(
    chunks: Iterable[Tuple[jax.Array, jax.Array]],
    c: float,
    *,
    lookahead: int = 1,
    variant: str = "exact",
    qp_iters: int = 128,
    resume: Optional[StreamCheckpoint] = None,
    checkpoint_every: int = 0,
    checkpoint_cb: Optional[Callable[[StreamCheckpoint], None]] = None,
) -> StreamCheckpoint:
    """One pass over an iterator of (X_chunk, y_chunk) with constant memory.

    The jit'd per-chunk update keeps state O(D); ``checkpoint_cb`` receives a
    StreamCheckpoint every ``checkpoint_every`` consumed examples, enabling
    preemption-safe resume *without a second pass* (resume at .position).
    NOTE: lookahead buffers are flushed at chunk boundaries when
    lookahead > 1; with the default chunk sizes (>= 4096) this matches the
    paper's final-flush semantics per chunk and keeps resume state O(D).
    """
    ball = resume.ball if resume is not None else None
    pos = resume.position if resume is not None else 0
    since_ckpt = 0

    if lookahead <= 1:
        step = jax.jit(fit_ball, static_argnames=("c", "variant"))
    else:
        step = jax.jit(
            fit_lookahead_ball, static_argnames=("c", "lookahead", "qp_iters")
        )

    it: Iterator = iter(chunks)
    for Xc, yc in it:
        Xc = jnp.asarray(Xc)
        yc = jnp.asarray(yc)
        n_chunk = int(Xc.shape[0])
        if ball is None:
            ball = init_ball(Xc[0], yc[0], c, variant=variant)
            Xc, yc = Xc[1:], yc[1:]
        if Xc.shape[0]:
            if lookahead <= 1:
                ball = step(ball, Xc, yc, c=c, variant=variant)
            else:
                ball = step(ball, Xc, yc, c=c, lookahead=lookahead, qp_iters=qp_iters)
        pos += n_chunk
        since_ckpt += n_chunk
        if checkpoint_every and checkpoint_cb and since_ckpt >= checkpoint_every:
            checkpoint_cb(StreamCheckpoint(ball=jax.tree.map(jnp.asarray, ball), position=pos))
            since_ckpt = 0
    if ball is None:
        raise ValueError(
            "fit_chunked got an empty stream: the chunk iterator yielded no "
            f"examples (resume={resume!r}) — at least one (X, y) chunk with "
            "one row is required to initialize the ball"
        )
    return StreamCheckpoint(ball=ball, position=pos)


def fit_chunked_many(
    chunks: Iterable[Tuple[jax.Array, jax.Array]],
    cs,
    *,
    variant: str = "exact",
    block_n: int = 256,
    b_tile: Optional[int] = None,
    stream_dtype=None,
    bank_resident: str = "auto",
    mesh=None,
    shard_axis="data",
    resume: Optional[StreamCheckpoint] = None,
    checkpoint_every: int = 0,
    checkpoint_cb: Optional[Callable[[StreamCheckpoint], None]] = None,
) -> StreamCheckpoint:
    """One pass of the multi-ball engine over an iterator of chunks.

    Bank analogue of ``fit_chunked``: ``cs`` is a (B,) array of per-model C
    values and each chunk is ``(X_chunk, y_chunk)`` with ``y_chunk`` either
    (n,) shared +-1 labels (broadcast to every model — the C-grid case) or
    (B, n) per-model sign rows (the one-vs-rest case). The checkpoint carries
    the whole bank — state stays O(B * D) — so preemption/resume keeps the
    stream single-pass for all B models at once. ``bank_resident`` passes
    through to the engine per chunk ("hbm" double-buffers banks beyond VMEM
    scratch through HBM; checkpoints are residency-agnostic — a run may
    resume under a different residency, bit-exact in f32).

    ``mesh=`` shards every chunk over the ``shard_axis`` axes of a device
    mesh (distributed.fit_bank_sharded): each shard fits its contiguous
    slice of the chunk fresh and the per-shard banks are folded with the
    Sec-4.3 merge, the prior bank folding in as one more disjoint summand.
    Because the checkpoint still carries ONE folded bank, a run may resume
    on a DIFFERENT shard count (elastic reshard) — chunk sizes need not
    divide the shard count (inert-row padding).
    """
    from repro.core.multiball import fit_bank

    cs = jnp.atleast_1d(jnp.asarray(cs, jnp.float32))
    n_models = int(cs.shape[0])
    bank = resume.ball if resume is not None else None
    pos = resume.position if resume is not None else 0
    since_ckpt = 0

    for Xc, yc in iter(chunks):
        Xc = jnp.asarray(Xc)
        yc = jnp.asarray(yc)
        if yc.ndim == 1:
            yc = jnp.broadcast_to(yc[None, :], (n_models, yc.shape[0]))
        n_chunk = int(Xc.shape[0])
        bank = fit_bank(
            Xc, yc, cs, bank, variant=variant, block_n=block_n,
            b_tile=b_tile, stream_dtype=stream_dtype,
            bank_resident=bank_resident,
            mesh=mesh, shard_axis=shard_axis,
        )
        pos += n_chunk
        since_ckpt += n_chunk
        if checkpoint_every and checkpoint_cb and since_ckpt >= checkpoint_every:
            checkpoint_cb(
                StreamCheckpoint(ball=jax.tree.map(jnp.asarray, bank), position=pos)
            )
            since_ckpt = 0
    if bank is None:
        raise ValueError(
            "fit_chunked_many got an empty stream: the chunk iterator "
            f"yielded no examples for the {n_models}-model bank "
            f"(resume={resume!r}) — at least one (X, Y) chunk with one row "
            "is required to initialize the bank"
        )
    return StreamCheckpoint(ball=bank, position=pos)


# ---------------------------------------------------------------------------
# Readout
# ---------------------------------------------------------------------------


def decision_function(ball: Ball, X: jax.Array) -> jax.Array:
    return X @ ball.w


def predict(ball: Ball, X: jax.Array) -> jax.Array:
    return jnp.sign(decision_function(ball, X))


def accuracy(ball: Ball, X: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.mean((decision_function(ball, X) * y) > 0)
