"""Explicit augmented-space simulator — the ground-truth oracle for tests.

Stores the *full* center [w ; sigma] with one slack coordinate per example
(O(N) memory — exactly what StreamSVM avoids) and runs Algorithm 1 literally
in that space. Property tests assert that streamsvm.fit's O(D) recursion
reproduces this simulator's (w, R, ||sigma||^2, M) to float tolerance.

Pure numpy, float64 — deliberately independent of the JAX implementation.
"""
from __future__ import annotations

import numpy as np


def fit_explicit(X, y, c, variant: str = "exact"):
    """Returns dict(w, r, xi2, m, sigma). X: (N,D) y: (N,) in ±1."""
    X = np.asarray(X, np.float64)
    y = np.asarray(y, np.float64)
    N, D = X.shape
    c_inv = 1.0 / c
    root = np.sqrt(c_inv) if variant == "exact" else 1.0

    w = y[0] * X[0].copy()
    sigma = np.zeros(N)
    sigma[0] = root  # first point's slack coordinate
    r = 0.0
    m = 1
    for n in range(1, N):
        p_feat = y[n] * X[n]
        # augmented distance: point n has slack coord root at index n
        diff2 = np.sum((w - p_feat) ** 2)
        slack2 = np.sum(sigma**2) - 2.0 * sigma[n] * root + root**2
        d = np.sqrt(diff2 + slack2)
        if d >= r:
            s = 0.5 * (1.0 - r / d)
            w = w + s * (p_feat - w)
            sigma = (1.0 - s) * sigma
            sigma[n] += s * root
            r = r + 0.5 * (d - r)
            m += 1
    return dict(w=w, r=r, xi2=float(np.sum(sigma**2)), m=m, sigma=sigma)


def meb_brute(points, iters: int = 20000):
    """High-iteration Badoiu–Clarkson MEB of a point set (reference optimum)."""
    P = np.asarray(points, np.float64)
    c = P.mean(axis=0)
    for t in range(1, iters + 1):
        d = np.linalg.norm(P - c, axis=1)
        f = int(np.argmax(d))
        c = c + (P[f] - c) / (t + 1.0)
    return c, float(np.max(np.linalg.norm(P - c, axis=1)))
