"""LiveBank — the always-on ingest -> train -> fold -> hot-swap loop.

Closes the loop between the one-pass trainer (``core.fit_bank`` /
``fit_chunked_many``) and the serving engine (``serve.BankServer``): consume
an unbounded stream of ``(X_chunk, y_chunk)`` chunks, train each into the
active sub-bank through the tiled Pallas engine, fold the sub-banks with the
bank-vectorized Sec-4.3 merge, and hot-swap the merged bank into a running
server on a cadence — checkpointed, restartable, and drift-repairing.

``bank_kind="kernel"`` runs the same loop in RKHS: chunks train through
``core.fit_kernel_bank`` into bounded (B, S) core-set sub-banks, each
arriving chunk Sec-4.3-merges into the active slot's prior state
(``merge_kernel_banks`` — exact while the live slots fit S, then lossy
top-k re-compression whose dropped |coef| mass is audited in
``LiveStats.merge_dropped_mass``), retirement re-merges kernel epochs, and
the serving fold goes through ``fold_kernel_banks`` over the live slots,
oldest first. Everything else — cadences, checkpoints, crash equivalence —
is bank-kind agnostic.

K-sub-bank drift-repair contract
--------------------------------
The paper's one-pass recursion is stream-order sensitive: a single greedy
ball absorbs every point into an ever-growing radius, so early data shapes
the center forever and late drift is diluted. The repair (blurred-ball
cover, "Accurate Streaming SVMs", PAPERS.md) keeps a small COVER of balls
instead of one:

  - the stream is cut into epochs of ``rotate_every`` chunks; each epoch
    trains its OWN fresh sub-bank (Algorithm 1 from scratch — per model,
    a ball enclosing just that epoch's augmented points);
  - the serving bank is the Sec-4.3 fold of the <= K live sub-banks,
    oldest first (``core.fold_banks``) — exact in the augmented space
    because epochs touch disjoint examples;
  - when all K slots are full, the OLDEST sub-bank is retired:
    ``retire="merge"`` re-merges the two oldest into one (no example's
    influence is dropped — the cover coarsens at the old end, blurred-ball
    style), ``retire="drop"`` forgets the oldest epoch outright (bounded
    memory of the last ~K * rotate_every chunks — concept-drift adaptation).

Bound: each sub-ball encloses its epoch's points by the Algorithm-1
invariant, and every fold/merge yields a ball enclosing both inputs with
radius within 2x of the optimal enclosing ball (property-tested bounds in
tests/test_sharded_bank.py). Order sensitivity is therefore confined WITHIN
an epoch (``rotate_every`` chunks of lookback); across epochs the cover
re-merges from small balls instead of absorbing points one by one — drift
in a new epoch lands in a fresh ball at full weight rather than nudging a
giant stale center.

Fault tolerance
---------------
Every fold commits an atomic ``StreamCheckpoint`` (checkpoint/ckpt.py:
manifest-commit protocol — a crash at any instant leaves the previous or
the new checkpoint, never a torn mix). ``run()`` always resumes from the
last durable checkpoint, and the source is addressed by absolute chunk
index (see sources.py), so a crash at ANY phase boundary replays to a
bit-identical (f32) bank: train/fold/swap are pure functions of
(checkpoint state, chunk index). Flaky fetches retry under a
``runtime.RetryPolicy`` (capped exponential backoff); chunks that exhaust
the budget are quarantined — recorded, skipped, and the loop moves on.
The server is decoupled: while the trainer crashes and recovers, an
attached ``BankServer`` keeps answering with the last good bank, and
``LiveStats.bank_age_chunks`` reports how stale it is.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core.kernel_bank import KernelBank, fit_kernel_bank
from repro.core.meb import (
    Ball,
    fold_banks,
    fold_kernel_banks,
    merge_banks,
    merge_kernel_banks,
)
from repro.core.multiball import fit_bank
from repro.runtime.fault_tolerance import InjectedFailure, RetryPolicy

from .sources import TransientSourceError

# fetch() sentinels: stream exhausted / chunk abandoned after retries
_END = object()
_QUARANTINED = object()
# "server has no kernel attribute" sentinel for duck-typed swap targets
_NO_KERNEL_ATTR = object()

PHASES = (
    "fetch", "post_train", "post_rotate", "post_fold", "post_swap",
    "mid_checkpoint", "post_checkpoint",
)


@dataclasses.dataclass
class LiveStats:
    """Trainer-side staleness/health surface, mirroring serve.ServerStats.

    Durable counters (restored from the checkpoint on restart, so a crashy
    run's final accounting matches the uninterrupted run's): chunks/rows
    ingested, folds, swaps, rotations, retirements, checkpoints, the
    quarantined chunk ids, ``last_swap_chunk``, and — for kernelized loops
    — ``merge_dropped_mass``: the total |coef| mass every 2S->S kernel-
    merge re-compression has discarded (chunk continuation merges, retire
    merges, and counted serving folds; exactly 0.0 while the live slots
    always fit S — the re-compression loss audit). Volatile counters
    (facts about THIS process's life, never restored): ``restarts`` and
    ``retries``. ``bank_age_chunks`` is the staleness signal: chunks
    ingested since the served bank was last swapped.
    """

    chunks_ingested: int = 0
    rows_ingested: int = 0
    folds: int = 0
    swaps: int = 0
    rotations: int = 0
    retirements: int = 0
    checkpoints: int = 0
    quarantined: List[int] = dataclasses.field(default_factory=list)
    last_swap_chunk: int = -1
    merge_dropped_mass: float = 0.0
    bank_age_chunks: int = 0
    restarts: int = 0
    retries: int = 0

    _DURABLE = (
        "chunks_ingested", "rows_ingested", "folds", "swaps", "rotations",
        "retirements", "checkpoints", "quarantined", "last_swap_chunk",
        "merge_dropped_mass",
    )

    def durable(self) -> dict:
        return {k: getattr(self, k) for k in self._DURABLE}

    def load_durable(self, d: dict) -> None:
        for k in self._DURABLE:
            if k in d:
                setattr(self, k, d[k])


class LiveBank:
    """Continuous train->serve driver over a replayable chunk source.

    source:        ``source(i) -> (X, y) | None`` — absolute-chunk-index
                   addressing; must replay (sources.py documents the
                   contract). ``y`` is (n,) shared labels or (B, n) signs.
    cs:            (B,) per-model C values (scalar broadcasts).
    n_sub_banks:   K rotating sub-bank slots (drift-repair cover size).
    rotate_every:  chunks per sub-bank epoch before rotation.
    swap_every:    chunks between fold + hot-swap pushes.
    retire:        "merge" (re-merge two oldest, keep everything) or
                   "drop" (forget the oldest epoch) when slots exhaust.
    ckpt_dir:      StreamCheckpoint directory; ``run()`` resumes from it.
    checkpoint_every_folds: folds per checkpoint commit (0 disables — then
                   a restart replays the stream from chunk 0).
    server / server_factory: hot-swap target. ``server_factory(bank)`` is
                   called at the first fold to build one (e.g.
                   ``lambda b: BankServer(b)``); an existing server can be
                   passed or attached any time with ``attach_server``.
    retry:         RetryPolicy classifying fetch failures (default:
                   TransientSourceError/OSError/TimeoutError retry with
                   capped exponential backoff; others propagate). Chunks
                   exhausting the budget are quarantined and skipped.
    failpoints:    crash-injection hooks for tests: a set of
                   ``(phase, chunk_idx)`` pairs (phase in PHASES); each
                   fires ONCE, raising InjectedFailure at that boundary.
                   ``mid_checkpoint`` additionally drops a garbage
                   ``.tmp`` into ckpt_dir first — the exact debris an
                   OS-level crash mid-commit leaves behind.
    bank_kind:     "linear" (Ball sub-banks via ``core.fit_bank``) or
                   "kernel" (KernelBank sub-banks via
                   ``core.fit_kernel_bank``; each chunk fits fresh with
                   ``seed_check=False`` and Sec-4.3-merges into the active
                   slot — core-set ids are lifted to absolute stream
                   coordinates so resume replays bit-exactly).
    kernel/gamma/coreset_size/eviction/s_tile: the kernel-engine knobs
                   (``core.fit_kernel_bank``); used only when
                   ``bank_kind="kernel"``. The same kernel/gamma/eviction
                   drive every retire merge and serving fold, and are
                   persisted in the checkpoint meta (the
                   ``save_kernel_bank`` meta contract, so
                   ``BankServer.from_checkpoint`` reads them back).
    Engine kwargs (variant/block_n/b_tile/stream_dtype/bank_resident/mesh/
    shard_axis/interpret) pass straight through to ``core.fit_bank`` (the
    kernel engine takes all but b_tile/bank_resident, which are linear-
    engine knobs).
    """

    def __init__(
        self,
        source: Callable,
        cs,
        *,
        ckpt_dir: str,
        n_sub_banks: int = 4,
        rotate_every: int = 8,
        swap_every: int = 1,
        retire: str = "merge",
        checkpoint_every_folds: int = 1,
        server=None,
        server_factory: Optional[Callable] = None,
        retry: Optional[RetryPolicy] = None,
        failpoints: Optional[Sequence[Tuple[str, int]]] = None,
        sleep: Callable[[float], None] = time.sleep,
        bank_kind: str = "linear",
        kernel: str = "rbf",
        gamma=1.0,
        coreset_size: int = 64,
        eviction: str = "smallest-coef",
        s_tile: Optional[int] = None,
        # engine passthrough
        variant: str = "exact",
        block_n: int = 256,
        b_tile: Optional[int] = None,
        stream_dtype=None,
        bank_resident: str = "auto",
        mesh=None,
        shard_axis="data",
        interpret: Optional[bool] = None,
    ):
        if bank_kind not in ("linear", "kernel"):
            raise ValueError(
                f"bank_kind must be 'linear' or 'kernel': got {bank_kind!r}"
            )
        if n_sub_banks < 1:
            raise ValueError(f"n_sub_banks must be >= 1: got {n_sub_banks}")
        if rotate_every < 1:
            raise ValueError(f"rotate_every must be >= 1: got {rotate_every}")
        if swap_every < 1:
            raise ValueError(f"swap_every must be >= 1: got {swap_every}")
        if retire not in ("merge", "drop"):
            raise ValueError(
                f"retire must be 'merge' or 'drop': got {retire!r}"
            )
        for fp in failpoints or ():
            if fp[0] not in PHASES:
                raise ValueError(
                    f"unknown failpoint phase {fp[0]!r}; expected one of "
                    f"{PHASES}"
                )
        self.source = source
        self.cs = jnp.atleast_1d(jnp.asarray(cs, jnp.float32))
        self.n_models = int(self.cs.shape[0])
        self.ckpt_dir = ckpt_dir
        self.k = int(n_sub_banks)
        self.rotate_every = int(rotate_every)
        self.swap_every = int(swap_every)
        self.retire = retire
        self.checkpoint_every_folds = int(checkpoint_every_folds)
        self.server = server
        self.server_factory = server_factory
        self.retry = retry or RetryPolicy(
            retryable=(TransientSourceError, OSError, TimeoutError),
            max_retries=4,
        )
        self._failpoints: Set[Tuple[str, int]] = set(failpoints or ())
        self._sleep = sleep
        self.bank_kind = bank_kind
        self.kernel = kernel if bank_kind == "kernel" else None
        self.gamma = float(gamma)
        self.coreset_size = int(coreset_size)
        self.eviction = eviction
        if bank_kind == "kernel":
            # fail fast on a bad kernel config instead of at the first chunk
            if kernel not in ("rbf", "linear"):
                raise ValueError(
                    f"unknown kernel {kernel!r}; expected 'rbf' or 'linear'"
                )
            if eviction not in ("smallest-coef", "farthest-point"):
                raise ValueError(
                    f"unknown eviction {eviction!r}; expected 'smallest-coef'"
                    " or 'farthest-point'"
                )
            if self.coreset_size < 1:
                raise ValueError(
                    f"coreset_size must be >= 1, got {coreset_size}"
                )
            # seed_check=False: a mid-stream continuation chunk has no
            # "row 0 seeds every model" contract (deferred seeding is exact)
            self._engine_kw = dict(
                kernel=kernel, gamma=self.gamma,
                coreset_size=self.coreset_size, eviction=eviction,
                variant=variant, block_n=block_n, s_tile=s_tile,
                stream_dtype=stream_dtype, mesh=mesh, shard_axis=shard_axis,
                interpret=interpret, seed_check=False,
            )
            self._merge_kw = dict(
                kernel=kernel, gamma=self.gamma, eviction=eviction
            )
        else:
            self._engine_kw = dict(
                variant=variant, block_n=block_n, b_tile=b_tile,
                stream_dtype=stream_dtype, bank_resident=bank_resident,
                mesh=mesh, shard_axis=shard_axis, interpret=interpret,
            )
            self._merge_kw = {}
        self.stats = LiveStats()
        self._reset_state()

    # -- state ---------------------------------------------------------------

    def _reset_state(self) -> None:
        self._slots: List[Optional[object]] = [None] * self.k  # Ball|KernelBank
        self._birth: List[int] = [0] * self.k
        self._active: int = 0
        self.chunk_idx: int = 0
        self._folds_since_ckpt: int = 0
        self._last_merged = None
        self._fold_dropped: float = 0.0  # |coef| mass the LAST fold cut
        # reset durable counters without touching volatile ones (restarts,
        # retries, bank_age are facts about this process, not the stream)
        self.stats.load_durable(LiveStats().durable())

    def _state_tree(self) -> dict:
        ref = next(s for s in self._slots if s is not None)
        zero = jax.tree.map(jnp.zeros_like, ref)
        slots = [s if s is not None else zero for s in self._slots]
        return {
            "birth": jnp.asarray(self._birth, jnp.int32),
            "live": jnp.asarray(
                [s is not None for s in self._slots], bool
            ),
            # stack every sub-bank leaf on a NEW leading K axis — works for
            # Ball (w (K,B,D), r, xi2, m) and KernelBank (idx (K,B,S), ...)
            "sub": jax.tree.map(lambda *xs: jnp.stack(xs), *slots),
        }

    def _resume_from_disk(self) -> None:
        """Disk is the source of truth at run() entry: reset in-memory state
        and reload the last durable StreamCheckpoint (if any) — the restart
        path after a crash, and a no-op-equivalent on a fresh start."""
        self._reset_state()
        if not ckpt.exists(self.ckpt_dir):
            return
        manifest = ckpt.load_manifest(self.ckpt_dir)
        meta = manifest["meta"]
        if meta.get("live_k") != self.k or meta.get("n_models") != self.n_models:
            raise ValueError(
                f"checkpoint at {self.ckpt_dir!r} was written by a live loop "
                f"with K={meta.get('live_k')}, B={meta.get('n_models')}; this "
                f"loop is configured K={self.k}, B={self.n_models} — resume "
                "needs a matching configuration"
            )
        ck_kind = meta.get("bank_kind", "linear")
        if ck_kind != self.bank_kind:
            raise ValueError(
                f"checkpoint at {self.ckpt_dir!r} holds bank_kind={ck_kind!r} "
                f"state; this loop is configured bank_kind={self.bank_kind!r}"
                " — linear Ball and kernelized core-set states are not "
                "interchangeable"
            )
        if self.bank_kind == "kernel":
            ck_cfg = {
                key: meta.get(key)
                for key in ("kernel", "gamma", "coreset_size", "eviction")
            }
            cfg = {
                "kernel": self.kernel, "gamma": self.gamma,
                "coreset_size": self.coreset_size, "eviction": self.eviction,
            }
            if ck_cfg != cfg:
                raise ValueError(
                    f"checkpoint at {self.ckpt_dir!r} was written with "
                    f"kernel config {ck_cfg}; this loop is configured {cfg} "
                    "— a resumed kernel stream needs the exact same kernel, "
                    "gamma, coreset size and eviction policy"
                )
        # leaf order of the state dict (sorted keys, then NamedTuple field
        # order): birth (K,), live (K,), then the stacked sub-bank leaves —
        # Ball (w (K,B,D), r, xi2, m) or KernelBank (idx (K,B,S), coef,
        # points, q, r, xi2, m)
        head = ckpt.zeros_like_manifest(manifest, 0, 2)
        sub_cls = KernelBank if self.bank_kind == "kernel" else Ball
        target = {
            "birth": head[0],
            "live": head[1].astype(bool),
            "sub": sub_cls(*ckpt.zeros_like_manifest(manifest, 2)),
        }
        state = ckpt.restore(self.ckpt_dir, target)
        live = np.asarray(state["live"])
        self._birth = [int(b) for b in np.asarray(state["birth"])]
        self._slots = [
            jax.tree.map(lambda x, i=i: x[i], state["sub"]) if live[i] else None
            for i in range(self.k)
        ]
        self._active = int(meta["active_slot"])
        self.chunk_idx = int(meta["chunk_idx"])
        self.stats.load_durable(meta["stats"])
        if any(s is not None for s in self._slots):
            self._last_merged = self._merged()

    def _checkpoint(self, i: int) -> None:
        if all(s is None for s in self._slots):
            return  # nothing durable yet (e.g. every chunk so far quarantined)
        self._failpoint("mid_checkpoint", i, torn_tmp=True)
        # Count the commit in the meta it rides in: restoring checkpoint N
        # must report N checkpoints, or every restart would lose one.
        self.stats.checkpoints += 1
        meta = {
            "chunk_idx": self.chunk_idx,
            "active_slot": self._active,
            "live_k": self.k,
            "n_models": self.n_models,
            "bank_kind": self.bank_kind,
            "stats": self.stats.durable(),
        }
        if self.bank_kind == "kernel":
            # the save_kernel_bank meta contract — what
            # BankServer.from_checkpoint reads kernel config back from
            meta.update(
                kernel=self.kernel, gamma=self.gamma,
                coreset_size=self.coreset_size, eviction=self.eviction,
            )
        ckpt.save(self.ckpt_dir, self._state_tree(), meta=meta)
        self._folds_since_ckpt = 0
        self._failpoint("post_checkpoint", i)

    # -- failure injection ---------------------------------------------------

    def _failpoint(self, phase: str, i: int, torn_tmp: bool = False) -> None:
        key = (phase, i)
        if key not in self._failpoints:
            return
        self._failpoints.discard(key)  # fire once: the restart sails past
        if torn_tmp:
            # The debris an OS crash mid-commit leaves under the atomic
            # protocol: a half-written arrays tmp nothing references. The
            # resume path must shrug it off and restore the previous commit.
            with open(
                os.path.join(self.ckpt_dir, "arrays-torn.npz.tmp"), "wb"
            ) as f:
                f.write(b"\x00garbage, not a zip")
        raise InjectedFailure(f"injected at {phase} of chunk {i}")

    # -- ingest --------------------------------------------------------------

    def _fetch(self, i: int):
        attempt = 0
        while True:
            try:
                chunk = self.source(i)
            except Exception as e:
                if not self.retry.is_retryable(e):
                    raise  # programming error: surface it
                if attempt >= self.retry.max_retries:
                    self.stats.quarantined.append(i)
                    return _QUARANTINED
                self._sleep(self.retry.delay(attempt))
                attempt += 1
                self.stats.retries += 1
                continue
            return _END if chunk is None else chunk

    # -- train / fold / swap -------------------------------------------------

    def _train(self, X, y) -> int:
        Xc = jnp.asarray(X)
        yc = jnp.asarray(y)
        if yc.ndim == 1:
            yc = jnp.broadcast_to(yc[None, :], (self.n_models, yc.shape[0]))
        prior = self._slots[self._active]
        if self.bank_kind == "kernel":
            bank = fit_kernel_bank(Xc, yc, self.cs, **self._engine_kw)
            # Lift the chunk-local core-set ids to ABSOLUTE stream
            # coordinates. rows_ingested is durable and not yet advanced for
            # this chunk, so a crash-replayed chunk re-derives the identical
            # offset — the id lift is replay-stable, hence bit-exact resume.
            offset = self.stats.rows_ingested
            bank = bank._replace(
                idx=jnp.where(bank.idx >= 0, bank.idx + offset, bank.idx)
            )
            if prior is not None:
                bank, dropped = merge_kernel_banks(
                    prior, bank, return_dropped=True, **self._merge_kw
                )
                self.stats.merge_dropped_mass += float(jnp.sum(dropped))
        else:
            bank = fit_bank(Xc, yc, self.cs, prior, **self._engine_kw)
        self._slots[self._active] = jax.tree.map(jnp.asarray, bank)
        return int(Xc.shape[0])

    def _age_order(self) -> List[int]:
        """Live slot indices, oldest epoch first (deterministic)."""
        return sorted(
            (s for s in range(self.k) if self._slots[s] is not None),
            key=lambda s: (self._birth[s], s),
        )

    def _rotate(self) -> None:
        if self._slots[self._active] is None:
            return  # empty epoch (all chunks quarantined): nothing to freeze
        free = [s for s in range(self.k) if self._slots[s] is None]
        if free:
            nxt = free[0]
        else:
            order = self._age_order()
            oldest = order[0]
            if self.retire == "drop" or self.k == 1:
                self._slots[oldest] = None
            else:
                second = order[1]
                if self.bank_kind == "kernel":
                    merged, dropped = merge_kernel_banks(
                        self._slots[oldest], self._slots[second],
                        return_dropped=True, **self._merge_kw,
                    )
                    self.stats.merge_dropped_mass += float(jnp.sum(dropped))
                else:
                    merged = merge_banks(
                        self._slots[oldest], self._slots[second]
                    )
                self._slots[second] = jax.tree.map(jnp.asarray, merged)
                self._birth[second] = self._birth[oldest]
                self._slots[oldest] = None
            self.stats.retirements += 1
            nxt = oldest
        self._active = nxt
        self._birth[nxt] = self.chunk_idx
        self.stats.rotations += 1

    def _merged(self):
        """Serving fold of the live slots, oldest first (Ball or KernelBank).

        Also records the fold's dropped |coef| mass in ``_fold_dropped`` —
        the caller that COUNTS the fold (cadence/finalize, not resume)
        accumulates it into the durable ``stats.merge_dropped_mass``.
        """
        order = self._age_order()
        if not order:
            return None
        banks = [self._slots[s] for s in order]
        if self.bank_kind == "kernel":
            folded, dropped = fold_kernel_banks(
                banks, return_dropped=True, **self._merge_kw
            )
            self._fold_dropped = float(jnp.sum(dropped))
        else:
            folded = fold_banks(banks)
            self._fold_dropped = 0.0
        return jax.tree.map(jnp.asarray, folded)

    def _check_server_config(self, server) -> None:
        """Refuse hot-swapping into a server with a mismatched kernel config.

        Duck-typed swap targets without a ``kernel`` attribute (e.g. test
        recorders) opt out; a real ``serve.BankServer`` always has one.
        """
        skernel = getattr(server, "kernel", _NO_KERNEL_ATTR)
        if skernel is _NO_KERNEL_ATTR:
            return
        sgamma = getattr(server, "gamma", None)
        mine = (
            f"bank_kind={self.bank_kind!r}, kernel={self.kernel!r}, "
            f"gamma={self.gamma if self.kernel else None!r}"
        )
        theirs = f"kernel={skernel!r}, gamma={sgamma!r}"
        if skernel != self.kernel or (
            self.kernel is not None
            and sgamma is not None
            and float(sgamma) != self.gamma
        ):
            raise ValueError(
                f"live loop ({mine}) cannot hot-swap into a server "
                f"configured {theirs} — a bank scored under the wrong "
                "kernel config serves silent garbage; rebuild the server "
                "with the loop's kernel configuration"
            )

    def _push(self, merged) -> None:
        if merged is None:
            return
        self._last_merged = merged
        if self.server is None and self.server_factory is not None:
            self.server = self.server_factory(merged)
            self._check_server_config(self.server)
        elif self.server is not None:
            self._check_server_config(self.server)
            self.server.swap_bank(merged)
        self.stats.swaps += 1
        self.stats.last_swap_chunk = self.chunk_idx
        self.stats.bank_age_chunks = 0

    # -- public surface ------------------------------------------------------

    def attach_server(self, server, push_current: bool = True) -> None:
        """Point hot-swaps at ``server``; optionally push the current bank."""
        self._check_server_config(server)
        self.server = server
        if push_current and self._last_merged is not None:
            server.swap_bank(self._last_merged)

    def serving_bank(self):
        """The last folded bank — Ball or KernelBank by ``bank_kind`` —
        i.e. what an attached server is serving."""
        return self._last_merged

    def run(self, max_chunks: Optional[int] = None) -> LiveStats:
        """Resume from the last durable checkpoint and consume the stream.

        Stops when the source returns None (bounded/drained stream) or
        after ``max_chunks`` chunk positions this call. On exit a final
        fold + swap + checkpoint makes the tail durable and served. Crash
        recovery = call run() again (see run_live_with_restarts).
        """
        self._resume_from_disk()
        processed = 0
        while max_chunks is None or processed < max_chunks:
            i = self.chunk_idx
            self._failpoint("fetch", i)
            chunk = self._fetch(i)
            if chunk is _END:
                break
            if chunk is _QUARANTINED:
                self.chunk_idx = i + 1
                processed += 1
                self._cadences(i)
                continue
            X, y = chunk
            if np.asarray(X).shape[0] == 0:
                self.chunk_idx = i + 1
                processed += 1
                continue
            rows = self._train(X, y)
            self._failpoint("post_train", i)
            self.chunk_idx = i + 1
            self.stats.chunks_ingested += 1
            self.stats.rows_ingested += rows
            processed += 1
            self._cadences(i)
        self._finalize()
        return self.stats

    def _cadences(self, i: int) -> None:
        """Rotation / fold+swap / checkpoint, keyed on the ABSOLUTE chunk
        position so a replayed window re-fires them identically."""
        if self.chunk_idx % self.rotate_every == 0:
            self._rotate()
            self._failpoint("post_rotate", i)
        if self.chunk_idx % self.swap_every == 0:
            merged = self._merged()
            if merged is not None:
                self.stats.folds += 1
                self.stats.merge_dropped_mass += self._fold_dropped
                self._folds_since_ckpt += 1
                self._failpoint("post_fold", i)
                self._push(merged)
                self._failpoint("post_swap", i)
        if (
            self.checkpoint_every_folds
            and self._folds_since_ckpt >= self.checkpoint_every_folds
        ):
            self._checkpoint(i)
        if self.stats.last_swap_chunk >= 0:
            self.stats.bank_age_chunks = (
                self.chunk_idx - self.stats.last_swap_chunk
            )

    def _finalize(self) -> None:
        """Drained-stream tail: fold+swap anything trained since the last
        cadence hit, then commit a final checkpoint."""
        if self.chunk_idx % self.swap_every != 0:
            merged = self._merged()
            if merged is not None and (
                self.stats.last_swap_chunk != self.chunk_idx
            ):
                self.stats.folds += 1
                self.stats.merge_dropped_mass += self._fold_dropped
                self._folds_since_ckpt += 1
                self._push(merged)
        if self.checkpoint_every_folds and self._folds_since_ckpt:
            self._checkpoint(self.chunk_idx - 1)


def run_live_with_restarts(
    live: LiveBank,
    *,
    max_restarts: int = 8,
    policy: Optional[RetryPolicy] = None,
    sleep: Callable[[float], None] = time.sleep,
    max_chunks: Optional[int] = None,
) -> LiveStats:
    """Crash-recovery driver: re-enter ``live.run()`` after retryable
    failures (the live-loop analogue of runtime.run_with_restarts).

    Each restart resumes from the last durable StreamCheckpoint — the
    crash-equivalence suite proves the recovered bank and served scores are
    bit-identical (f32) to an uninterrupted run. Non-retryable exceptions
    (programming errors) propagate immediately.
    """
    policy = policy or RetryPolicy(max_retries=max_restarts)
    restarts = 0
    while True:
        try:
            return live.run(max_chunks=max_chunks)
        except Exception as e:
            if not policy.is_retryable(e):
                raise
            restarts += 1
            if restarts > max_restarts:
                raise
            live.stats.restarts += 1
            sleep(policy.delay(restarts - 1))
