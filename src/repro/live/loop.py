"""LiveBank — the always-on ingest -> train -> fold -> hot-swap loop.

Closes the loop between the one-pass trainer (``core.fit_bank`` /
``fit_chunked_many``) and the serving engine (``serve.BankServer``): consume
an unbounded stream of ``(X_chunk, y_chunk)`` chunks, train each into the
active sub-bank through the tiled Pallas engine, fold the sub-banks with the
bank-vectorized Sec-4.3 merge, and hot-swap the merged bank into a running
server on a cadence — checkpointed, restartable, and drift-repairing.

``bank_kind="kernel"`` runs the same loop in RKHS: chunks train through
``core.fit_kernel_bank`` into bounded (B, S) core-set sub-banks, each
arriving chunk Sec-4.3-merges into the active slot's prior state
(``merge_kernel_banks`` — exact while the live slots fit S, then lossy
top-k re-compression whose dropped |coef| mass is audited in
``LiveStats.merge_dropped_mass``), retirement re-merges kernel epochs, and
the serving fold goes through ``fold_kernel_banks`` over the live slots,
oldest first. Everything else — cadences, checkpoints, crash equivalence —
is bank-kind agnostic.

K-sub-bank drift-repair contract
--------------------------------
The paper's one-pass recursion is stream-order sensitive: a single greedy
ball absorbs every point into an ever-growing radius, so early data shapes
the center forever and late drift is diluted. The repair (blurred-ball
cover, "Accurate Streaming SVMs", PAPERS.md) keeps a small COVER of balls
instead of one:

  - the stream is cut into epochs of ``rotate_every`` chunks; each epoch
    trains its OWN fresh sub-bank (Algorithm 1 from scratch — per model,
    a ball enclosing just that epoch's augmented points);
  - the serving bank is the Sec-4.3 fold of the <= K live sub-banks,
    oldest first (``core.fold_banks``) — exact in the augmented space
    because epochs touch disjoint examples;
  - when all K slots are full, the OLDEST sub-bank is retired:
    ``retire="merge"`` re-merges the two oldest into one (no example's
    influence is dropped — the cover coarsens at the old end, blurred-ball
    style), ``retire="drop"`` forgets the oldest epoch outright (bounded
    memory of the last ~K * rotate_every chunks — concept-drift adaptation).

Bound: each sub-ball encloses its epoch's points by the Algorithm-1
invariant, and every fold/merge yields a ball enclosing both inputs with
radius within 2x of the optimal enclosing ball (property-tested bounds in
tests/test_sharded_bank.py). Order sensitivity is therefore confined WITHIN
an epoch (``rotate_every`` chunks of lookback); across epochs the cover
re-merges from small balls instead of absorbing points one by one — drift
in a new epoch lands in a fresh ball at full weight rather than nudging a
giant stale center.

Fault tolerance
---------------
Every fold commits an atomic ``StreamCheckpoint`` (checkpoint/ckpt.py:
manifest-commit protocol — a crash at any instant leaves the previous or
the new checkpoint, never a torn mix). ``run()`` always resumes from the
last durable checkpoint, and the source is addressed by absolute chunk
index (see sources.py), so a crash at ANY phase boundary replays to a
bit-identical (f32) bank: train/fold/swap are pure functions of
(checkpoint state, chunk index). Flaky fetches retry under a
``runtime.RetryPolicy`` (capped exponential backoff); chunks that exhaust
the budget are quarantined — recorded, skipped, and the loop moves on.
The server is decoupled: while the trainer crashes and recovers, an
attached ``BankServer`` keeps answering with the last good bank, and
``LiveStats.bank_age_chunks`` reports how stale it is.

Elastic sharded training
------------------------
``mesh=`` / ``n_stream_shards=`` turn per-chunk training into mesh
training that tolerates losing or gaining devices mid-stream. The key
split is LOGICAL vs PHYSICAL:

  - ``n_stream_shards`` (durable in every checkpoint) fixes the chunk's
    fold STRUCTURE: each chunk is ceil-split into that many contiguous
    ranges (``core.shard_ranges``), fit fresh per range, and folded in
    ascending-range order with the eager Sec-4.3 merges; the active
    slot's prior state merges in last. This structure never depends on
    hardware.
  - the physical mesh only decides WHERE the range fits execute. When
    the device count equals the logical shard count and the chunk is
    fault-free, one mesh dispatch runs all ranges at once
    (``core.fit_bank_sharded`` for linear; ``core.fit_kernel_bank_shards``
    — per-shard fits gathered WITHOUT the in-jit fold — for kernel); any
    other device count, including none, falls back to per-range
    single-device fits. Both paths are bit-identical (f32), so a
    checkpoint written on 8 devices resumes bit-exactly on 4, 1, or 16
    (the ``remeshes`` counter records the transition).

Mid-chunk shard faults degrade gracefully instead of killing the loop: a
lost device or declared straggler (``StragglerPolicy`` over per-shard
heartbeats) has its range re-issued to the surviving shards
(``runtime.rebalance_ranges``; counted in ``ranges_reissued``), and a
shard whose fetch faults exhaust the per-shard retry budget is masked out
through the inert-range contract — its rows are recorded in
``LiveStats.rows_lost`` / ``shard_ranges_lost`` and the fold simply skips
the range. The chaos harness (live/chaos.py) proves process kills and
remesh events are INVISIBLE: final bank, served scores, and durable stats
bit-identical (f32) to the crash-free reference under the same shard-
fault plan.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core.distributed import (
    _mesh_axes,
    _n_shards,
    fit_bank_sharded,
    fit_kernel_bank_shards,
    shard_ranges,
)
from repro.core.kernel_bank import KernelBank, fit_kernel_bank
from repro.core.meb import (
    Ball,
    fold_banks,
    fold_kernel_banks,
    merge_banks,
    merge_kernel_banks,
    nonfinite_rows,
    stack_banks,
    fold_merge,
)
from repro.core.multiball import fit_bank
from repro.runtime.fault_tolerance import (
    DeviceLostError,
    InjectedFailure,
    RetryPolicy,
    StragglerPolicy,
    default_live_retryable,
    rebalance_ranges,
    runtime_device_errors,
)

from .sources import TransientSourceError

# fetch() sentinels: stream exhausted / chunk abandoned after retries
_END = object()
_QUARANTINED = object()
# "server has no kernel attribute" sentinel for duck-typed swap targets
_NO_KERNEL_ATTR = object()

PHASES = (
    "fetch", "post_train", "post_rotate", "post_fold", "post_swap",
    "mid_checkpoint", "post_checkpoint",
)


@dataclasses.dataclass
class LiveStats:
    """Trainer-side staleness/health surface, mirroring serve.ServerStats.

    Durable counters (restored from the checkpoint on restart, so a crashy
    run's final accounting matches the uninterrupted run's): chunks/rows
    ingested, folds, swaps, rotations, retirements, checkpoints, the
    quarantined chunk ids, ``last_swap_chunk``, and — for kernelized loops
    — ``merge_dropped_mass``: the total |coef| mass every 2S->S kernel-
    merge re-compression has discarded (chunk continuation merges, retire
    merges, and counted serving folds; exactly 0.0 while the live slots
    always fit S — the re-compression loss audit).

    The elastic sharded loop adds durable loss/repair accounting —
    derived from the deterministic shard-fault plan, so a crash replay
    re-derives them identically:

    ``rows_lost``          stream rows masked out because their shard's
                           fetch faults exhausted the shard retry budget.
    ``shard_ranges_lost``  how many assigned ranges those rows spanned.
    ``ranges_reissued``    lost/straggler shard ranges re-issued to
                           survivors via ``runtime.rebalance_ranges``.
    ``folds_quarantined``  serving folds refused by the non-finite
                           publish guard (NaN/Inf model rows) — the
                           server kept the last good bank.

    Volatile counters (facts about THIS process's life, never restored):
    ``restarts``, ``retries``, ``shard_retries`` (per-shard fetch retries
    burned), and ``remeshes`` (resumes whose physical mesh differed from
    the mesh that wrote the checkpoint). ``bank_age_chunks`` is the
    staleness signal: chunks ingested since the served bank was last
    swapped.
    """

    chunks_ingested: int = 0
    rows_ingested: int = 0
    folds: int = 0
    swaps: int = 0
    rotations: int = 0
    retirements: int = 0
    checkpoints: int = 0
    quarantined: List[int] = dataclasses.field(default_factory=list)
    last_swap_chunk: int = -1
    merge_dropped_mass: float = 0.0
    rows_lost: int = 0
    shard_ranges_lost: int = 0
    ranges_reissued: int = 0
    folds_quarantined: int = 0
    bank_age_chunks: int = 0
    restarts: int = 0
    retries: int = 0
    shard_retries: int = 0
    remeshes: int = 0

    _DURABLE = (
        "chunks_ingested", "rows_ingested", "folds", "swaps", "rotations",
        "retirements", "checkpoints", "quarantined", "last_swap_chunk",
        "merge_dropped_mass", "rows_lost", "shard_ranges_lost",
        "ranges_reissued", "folds_quarantined",
    )

    def durable(self) -> dict:
        return {k: getattr(self, k) for k in self._DURABLE}

    def load_durable(self, d: dict) -> None:
        for k in self._DURABLE:
            if k in d:
                setattr(self, k, d[k])


class LiveBank:
    """Continuous train->serve driver over a replayable chunk source.

    source:        ``source(i) -> (X, y) | None`` — absolute-chunk-index
                   addressing; must replay (sources.py documents the
                   contract). ``y`` is (n,) shared labels or (B, n) signs.
    cs:            (B,) per-model C values (scalar broadcasts).
    n_sub_banks:   K rotating sub-bank slots (drift-repair cover size).
    rotate_every:  chunks per sub-bank epoch before rotation.
    swap_every:    chunks between fold + hot-swap pushes.
    retire:        "merge" (re-merge two oldest, keep everything) or
                   "drop" (forget the oldest epoch) when slots exhaust.
    ckpt_dir:      StreamCheckpoint directory; ``run()`` resumes from it.
    checkpoint_every_folds: folds per checkpoint commit (0 disables — then
                   a restart replays the stream from chunk 0).
    server / server_factory: hot-swap target. ``server_factory(bank)`` is
                   called at the first fold to build one (e.g.
                   ``lambda b: BankServer(b)``); an existing server can be
                   passed or attached any time with ``attach_server``.
    retry:         RetryPolicy classifying fetch failures (default:
                   TransientSourceError/OSError/TimeoutError retry with
                   capped exponential backoff; others propagate). Chunks
                   exhausting the budget are quarantined and skipped.
    failpoints:    crash-injection hooks for tests: a set of
                   ``(phase, chunk_idx)`` pairs (phase in PHASES); each
                   fires ONCE, raising InjectedFailure at that boundary.
                   ``mid_checkpoint`` additionally drops a garbage
                   ``.tmp`` into ckpt_dir first — the exact debris an
                   OS-level crash mid-commit leaves behind.
    bank_kind:     "linear" (Ball sub-banks via ``core.fit_bank``) or
                   "kernel" (KernelBank sub-banks via
                   ``core.fit_kernel_bank``; each chunk fits fresh with
                   ``seed_check=False`` and Sec-4.3-merges into the active
                   slot — core-set ids are lifted to absolute stream
                   coordinates so resume replays bit-exactly).
    kernel/gamma/coreset_size/eviction/s_tile: the kernel-engine knobs
                   (``core.fit_kernel_bank``); used only when
                   ``bank_kind="kernel"``. The same kernel/gamma/eviction
                   drive every retire merge and serving fold, and are
                   persisted in the checkpoint meta (the
                   ``save_kernel_bank`` meta contract, so
                   ``BankServer.from_checkpoint`` reads them back).
    mesh / shard_axis: train each chunk across this device mesh (the
                   elastic sharded path — see the module docstring).
                   When the mesh's device count equals the logical shard
                   count and a chunk is fault-free, training is one mesh
                   dispatch (``fit_bank_sharded`` / the stacked-shards
                   kernel path); otherwise ranges fit per-device,
                   bit-identically. With a mesh (or n_stream_shards > 1)
                   the linear loop switches from in-engine continuation
                   to fresh-fit + Sec-4.3 prior merge — the shard-count-
                   agnostic semantics an elastic resume needs.
    n_stream_shards: the LOGICAL shard count — fixes each chunk's fold
                   structure, durable in every checkpoint. Defaults to
                   the mesh's device count (or 1 without a mesh). A
                   resumed loop that did not set it explicitly ADOPTS
                   the checkpoint's value, which is what makes an
                   8 -> 4 -> 1 remesh bit-exact; setting it explicitly
                   to a different value than the checkpoint raises.
    shard_faults:  a ``sources.ShardFaults`` plan (or duck-typed
                   equivalent) injecting per-(chunk, shard) device-loss /
                   straggler / fetch faults — the chaos-testing surface.
    shard_retry:   RetryPolicy for per-shard fetch faults (default:
                   transient source / OS / timeout / device-lost errors,
                   2 retries). Past the budget the shard's assigned
                   ranges are masked out and recorded in ``rows_lost``.
    straggler_policy: ``runtime.StragglerPolicy`` applied to the fault
                   plan's per-shard elapsed times; declared stragglers
                   are re-issued like lost shards.
    rotate_on:     optional ``rotate_on(stats) -> bool`` extra rotation
                   trigger, composing (OR) with ``rotate_every`` — e.g.
                   fire on a ``merge_dropped_mass`` spike. Evaluated
                   after every ingested chunk; keep it a pure function
                   of DURABLE stats so a crash replay re-fires rotations
                   identically (replay stability).
    strict_finite: non-finite publish guard mode. A serving fold with
                   NaN/Inf in any model row is never hot-swapped; by
                   default it is quarantined (``folds_quarantined``
                   counts it, the server keeps the last good bank) —
                   ``strict_finite=True`` raises a ValueError naming the
                   offending model rows instead.
    Engine kwargs (variant/block_n/b_tile/stream_dtype/bank_resident/
    interpret) pass straight through to ``core.fit_bank`` (the kernel
    engine takes all but b_tile/bank_resident, which are linear-engine
    knobs).
    """

    def __init__(
        self,
        source: Callable,
        cs,
        *,
        ckpt_dir: str,
        n_sub_banks: int = 4,
        rotate_every: int = 8,
        swap_every: int = 1,
        retire: str = "merge",
        checkpoint_every_folds: int = 1,
        server=None,
        server_factory: Optional[Callable] = None,
        retry: Optional[RetryPolicy] = None,
        failpoints: Optional[Sequence[Tuple[str, int]]] = None,
        sleep: Callable[[float], None] = time.sleep,
        bank_kind: str = "linear",
        kernel: str = "rbf",
        gamma=1.0,
        coreset_size: int = 64,
        eviction: str = "smallest-coef",
        s_tile: Optional[int] = None,
        # elastic sharded training
        n_stream_shards: Optional[int] = None,
        shard_faults=None,
        shard_retry: Optional[RetryPolicy] = None,
        straggler_policy: Optional[StragglerPolicy] = None,
        # cadence / publish hooks
        rotate_on: Optional[Callable[[LiveStats], bool]] = None,
        strict_finite: bool = False,
        # engine passthrough
        variant: str = "exact",
        block_n: int = 256,
        b_tile: Optional[int] = None,
        stream_dtype=None,
        bank_resident: str = "auto",
        mesh=None,
        shard_axis="data",
        interpret: Optional[bool] = None,
    ):
        if bank_kind not in ("linear", "kernel"):
            raise ValueError(
                f"bank_kind must be 'linear' or 'kernel': got {bank_kind!r}"
            )
        if n_sub_banks < 1:
            raise ValueError(f"n_sub_banks must be >= 1: got {n_sub_banks}")
        if rotate_every < 1:
            raise ValueError(f"rotate_every must be >= 1: got {rotate_every}")
        if swap_every < 1:
            raise ValueError(f"swap_every must be >= 1: got {swap_every}")
        if retire not in ("merge", "drop"):
            raise ValueError(
                f"retire must be 'merge' or 'drop': got {retire!r}"
            )
        for fp in failpoints or ():
            if fp[0] not in PHASES:
                raise ValueError(
                    f"unknown failpoint phase {fp[0]!r}; expected one of "
                    f"{PHASES}"
                )
        self.source = source
        self.cs = jnp.atleast_1d(jnp.asarray(cs, jnp.float32))
        self.n_models = int(self.cs.shape[0])
        self.ckpt_dir = ckpt_dir
        self.k = int(n_sub_banks)
        self.rotate_every = int(rotate_every)
        self.swap_every = int(swap_every)
        self.retire = retire
        self.checkpoint_every_folds = int(checkpoint_every_folds)
        self.server = server
        self.server_factory = server_factory
        self.retry = retry or RetryPolicy(
            retryable=(TransientSourceError, OSError, TimeoutError),
            max_retries=4,
        )
        # a SET passed in is kept by reference (not copied): the chaos
        # driver shares one failpoint set across relaunches so every kill
        # fires exactly once per run, not once per process
        self._failpoints: Set[Tuple[str, int]] = (
            failpoints if isinstance(failpoints, set)
            else set(failpoints or ())
        )
        self._sleep = sleep
        self.mesh = mesh
        self.shard_axis = shard_axis
        self._shards_explicit = n_stream_shards is not None
        if n_stream_shards is None:
            n_stream_shards = self._mesh_devices() or 1
        if n_stream_shards < 1:
            raise ValueError(
                f"n_stream_shards must be >= 1: got {n_stream_shards}"
            )
        self.n_stream_shards = int(n_stream_shards)
        self.shard_faults = shard_faults
        self.shard_retry = shard_retry or RetryPolicy(
            retryable=(
                TransientSourceError, OSError, TimeoutError, DeviceLostError,
            ) + runtime_device_errors(),
            max_retries=2,
        )
        self.straggler_policy = straggler_policy
        self.rotate_on = rotate_on
        self.strict_finite = bool(strict_finite)
        self.bank_kind = bank_kind
        self.kernel = kernel if bank_kind == "kernel" else None
        self.gamma = float(gamma)
        self.coreset_size = int(coreset_size)
        self.eviction = eviction
        if bank_kind == "kernel":
            # fail fast on a bad kernel config instead of at the first chunk
            if kernel not in ("rbf", "linear"):
                raise ValueError(
                    f"unknown kernel {kernel!r}; expected 'rbf' or 'linear'"
                )
            if eviction not in ("smallest-coef", "farthest-point"):
                raise ValueError(
                    f"unknown eviction {eviction!r}; expected 'smallest-coef'"
                    " or 'farthest-point'"
                )
            if self.coreset_size < 1:
                raise ValueError(
                    f"coreset_size must be >= 1, got {coreset_size}"
                )
            # seed_check=False: a mid-stream continuation chunk has no
            # "row 0 seeds every model" contract (deferred seeding is exact).
            # mesh/shard_axis are NOT in the engine kwargs: the elastic
            # trainer owns placement (per-range fits must run single-device
            # so the degraded path stays bit-identical to the mesh path).
            self._engine_kw = dict(
                kernel=kernel, gamma=self.gamma,
                coreset_size=self.coreset_size, eviction=eviction,
                variant=variant, block_n=block_n, s_tile=s_tile,
                stream_dtype=stream_dtype, interpret=interpret,
                seed_check=False,
            )
            self._merge_kw = dict(
                kernel=kernel, gamma=self.gamma, eviction=eviction
            )
        else:
            self._engine_kw = dict(
                variant=variant, block_n=block_n, b_tile=b_tile,
                stream_dtype=stream_dtype, bank_resident=bank_resident,
                interpret=interpret,
            )
            self._merge_kw = {}
        self.stats = LiveStats()
        self._reset_state()

    # -- state ---------------------------------------------------------------

    def _mesh_devices(self) -> Optional[int]:
        """Physical device count across the training axes (None: no mesh)."""
        if self.mesh is None:
            return None
        return _n_shards(self.mesh, _mesh_axes(self.shard_axis))

    def _mesh_shape(self) -> Optional[List[int]]:
        """Per-axis device counts of the training mesh, for checkpoint meta
        (json-stable list; None without a mesh)."""
        if self.mesh is None:
            return None
        return [int(self.mesh.shape[a]) for a in _mesh_axes(self.shard_axis)]

    def _reset_state(self) -> None:
        self._slots: List[Optional[object]] = [None] * self.k  # Ball|KernelBank
        self._birth: List[int] = [0] * self.k
        self._active: int = 0
        self.chunk_idx: int = 0
        self._folds_since_ckpt: int = 0
        self._last_merged = None
        self._fold_dropped: float = 0.0  # |coef| mass the LAST fold cut
        # reset durable counters without touching volatile ones (restarts,
        # retries, bank_age are facts about this process, not the stream)
        self.stats.load_durable(LiveStats().durable())

    def _state_tree(self) -> dict:
        ref = next(s for s in self._slots if s is not None)
        zero = jax.tree.map(jnp.zeros_like, ref)
        slots = [s if s is not None else zero for s in self._slots]
        return {
            "birth": jnp.asarray(self._birth, jnp.int32),
            "live": jnp.asarray(
                [s is not None for s in self._slots], bool
            ),
            # stack every sub-bank leaf on a NEW leading K axis — works for
            # Ball (w (K,B,D), r, xi2, m) and KernelBank (idx (K,B,S), ...)
            "sub": jax.tree.map(lambda *xs: jnp.stack(xs), *slots),
        }

    def _resume_from_disk(self) -> None:
        """Disk is the source of truth at run() entry: reset in-memory state
        and reload the last durable StreamCheckpoint (if any) — the restart
        path after a crash, and a no-op-equivalent on a fresh start."""
        self._reset_state()
        if not ckpt.exists(self.ckpt_dir):
            return
        manifest = ckpt.load_manifest(self.ckpt_dir)
        meta = manifest["meta"]
        if meta.get("live_k") != self.k or meta.get("n_models") != self.n_models:
            raise ValueError(
                f"checkpoint at {self.ckpt_dir!r} was written by a live loop "
                f"with K={meta.get('live_k')}, B={meta.get('n_models')}; this "
                f"loop is configured K={self.k}, B={self.n_models} — resume "
                "needs a matching configuration"
            )
        ck_kind = meta.get("bank_kind", "linear")
        if ck_kind != self.bank_kind:
            raise ValueError(
                f"checkpoint at {self.ckpt_dir!r} holds bank_kind={ck_kind!r} "
                f"state; this loop is configured bank_kind={self.bank_kind!r}"
                " — linear Ball and kernelized core-set states are not "
                "interchangeable"
            )
        if self.bank_kind == "kernel":
            ck_cfg = {
                key: meta.get(key)
                for key in ("kernel", "gamma", "coreset_size", "eviction")
            }
            cfg = {
                "kernel": self.kernel, "gamma": self.gamma,
                "coreset_size": self.coreset_size, "eviction": self.eviction,
            }
            if ck_cfg != cfg:
                raise ValueError(
                    f"checkpoint at {self.ckpt_dir!r} was written with "
                    f"kernel config {ck_cfg}; this loop is configured {cfg} "
                    "— a resumed kernel stream needs the exact same kernel, "
                    "gamma, coreset size and eviction policy"
                )
        # The LOGICAL shard count is durable: it pins every chunk's fold
        # structure, so it must survive any physical remesh. An explicit
        # mismatch is a configuration error; an implicit (mesh-derived or
        # defaulted) count ADOPTS the checkpoint's — the elastic resume.
        ck_shards = int(meta.get("n_stream_shards", 1))
        if self._shards_explicit and ck_shards != self.n_stream_shards:
            raise ValueError(
                f"checkpoint at {self.ckpt_dir!r} was written with "
                f"n_stream_shards={ck_shards}; this loop explicitly set "
                f"n_stream_shards={self.n_stream_shards} — the logical "
                "shard count pins the per-chunk fold structure and cannot "
                "change mid-stream (the PHYSICAL mesh can: pass a different "
                "mesh=, or omit n_stream_shards to adopt the checkpoint's)"
            )
        self.n_stream_shards = ck_shards
        if meta.get("mesh_shape") != self._mesh_shape():
            # volatile: an elastic remesh happened between processes
            self.stats.remeshes += 1
        # leaf order of the state dict (sorted keys, then NamedTuple field
        # order): birth (K,), live (K,), then the stacked sub-bank leaves —
        # Ball (w (K,B,D), r, xi2, m) or KernelBank (idx (K,B,S), coef,
        # points, q, r, xi2, m)
        head = ckpt.zeros_like_manifest(manifest, 0, 2)
        sub_cls = KernelBank if self.bank_kind == "kernel" else Ball
        target = {
            "birth": head[0],
            "live": head[1].astype(bool),
            "sub": sub_cls(*ckpt.zeros_like_manifest(manifest, 2)),
        }
        # Re-place the restored sub-banks on the CURRENT mesh, replicated —
        # a checkpoint written under any device count restores onto this
        # one (placement is a property of the restore call, not the file).
        shardings = (
            ckpt.replicated_shardings(target, self.mesh)
            if self.mesh is not None else None
        )
        state = ckpt.restore(self.ckpt_dir, target, shardings=shardings)
        live = np.asarray(state["live"])
        self._birth = [int(b) for b in np.asarray(state["birth"])]
        self._slots = [
            jax.tree.map(lambda x, i=i: x[i], state["sub"]) if live[i] else None
            for i in range(self.k)
        ]
        self._active = int(meta["active_slot"])
        self.chunk_idx = int(meta["chunk_idx"])
        self.stats.load_durable(meta["stats"])
        if any(s is not None for s in self._slots):
            merged = self._merged()
            # the resume fold is uncounted; a poisoned restored state keeps
            # _last_merged at None so nothing non-finite ever gets served
            if merged is not None and not bool(jnp.any(nonfinite_rows(merged))):
                self._last_merged = merged

    def _checkpoint(self, i: int) -> None:
        if all(s is None for s in self._slots):
            return  # nothing durable yet (e.g. every chunk so far quarantined)
        self._failpoint("mid_checkpoint", i, torn_tmp=True)
        # Count the commit in the meta it rides in: restoring checkpoint N
        # must report N checkpoints, or every restart would lose one.
        self.stats.checkpoints += 1
        meta = {
            "chunk_idx": self.chunk_idx,
            "active_slot": self._active,
            "live_k": self.k,
            "n_models": self.n_models,
            "bank_kind": self.bank_kind,
            # elastic contract: the LOGICAL fold structure is durable, the
            # physical mesh shape is informational (remesh detection)
            "n_stream_shards": self.n_stream_shards,
            "mesh_shape": self._mesh_shape(),
            "stats": self.stats.durable(),
        }
        if self.bank_kind == "kernel":
            # the save_kernel_bank meta contract — what
            # BankServer.from_checkpoint reads kernel config back from
            meta.update(
                kernel=self.kernel, gamma=self.gamma,
                coreset_size=self.coreset_size, eviction=self.eviction,
            )
        ckpt.save(self.ckpt_dir, self._state_tree(), meta=meta)
        self._folds_since_ckpt = 0
        self._failpoint("post_checkpoint", i)

    # -- failure injection ---------------------------------------------------

    def _failpoint(self, phase: str, i: int, torn_tmp: bool = False) -> None:
        key = (phase, i)
        if key not in self._failpoints:
            return
        self._failpoints.discard(key)  # fire once: the restart sails past
        if torn_tmp:
            # The debris an OS crash mid-commit leaves under the atomic
            # protocol: a half-written arrays tmp nothing references. The
            # resume path must shrug it off and restore the previous commit.
            with open(
                os.path.join(self.ckpt_dir, "arrays-torn.npz.tmp"), "wb"
            ) as f:
                f.write(b"\x00garbage, not a zip")
        raise InjectedFailure(f"injected at {phase} of chunk {i}")

    # -- ingest --------------------------------------------------------------

    def _fetch(self, i: int):
        attempt = 0
        while True:
            try:
                chunk = self.source(i)
            except Exception as e:
                if not self.retry.is_retryable(e):
                    raise  # programming error: surface it
                if attempt >= self.retry.max_retries:
                    self.stats.quarantined.append(i)
                    return _QUARANTINED
                self._sleep(self.retry.delay(attempt))
                attempt += 1
                self.stats.retries += 1
                continue
            return _END if chunk is None else chunk

    # -- train / fold / swap -------------------------------------------------

    def _train(self, X, y) -> int:
        Xc = jnp.asarray(X)
        yc = jnp.asarray(y)
        if yc.ndim == 1:
            yc = jnp.broadcast_to(yc[None, :], (self.n_models, yc.shape[0]))
        n = int(Xc.shape[0])
        if self.n_stream_shards == 1 and self.mesh is None:
            self._train_single(Xc, yc)
        else:
            self._train_elastic(Xc, yc, n)
        return n

    def _train_single(self, Xc, yc) -> None:
        """The legacy single-device chunk path (no mesh, one logical shard):
        linear chunks CONTINUE the active slot inside the engine; kernel
        chunks fit fresh and Sec-4.3-merge into the prior."""
        prior = self._slots[self._active]
        if self.bank_kind == "kernel":
            bank = fit_kernel_bank(Xc, yc, self.cs, **self._engine_kw)
            # Lift the chunk-local core-set ids to ABSOLUTE stream
            # coordinates. rows_ingested is durable and not yet advanced for
            # this chunk, so a crash-replayed chunk re-derives the identical
            # offset — the id lift is replay-stable, hence bit-exact resume.
            offset = self.stats.rows_ingested
            bank = bank._replace(
                idx=jnp.where(bank.idx >= 0, bank.idx + offset, bank.idx)
            )
            if prior is not None:
                bank, dropped = merge_kernel_banks(
                    prior, bank, return_dropped=True, **self._merge_kw
                )
                self.stats.merge_dropped_mass += float(jnp.sum(dropped))
        else:
            bank = fit_bank(Xc, yc, self.cs, prior, **self._engine_kw)
        self._slots[self._active] = jax.tree.map(jnp.asarray, bank)

    # -- elastic sharded chunk path ------------------------------------------

    def _train_elastic(self, Xc, yc, n: int) -> None:
        """One chunk across the LOGICAL stream shards (module docstring:
        "Elastic sharded training").

        Fold structure is fixed by ``n_stream_shards`` alone: ranges fit
        FRESH, fold in ascending-range order through the eager Sec-4.3
        merges, and the active slot's prior merges in last. The physical
        mesh only decides where the fits execute, so the mesh fast path,
        the per-range degraded path, and any later remesh all produce
        bit-identical (f32) sub-bank state.
        """
        i = self.chunk_idx
        ranges = shard_ranges(n, self.n_stream_shards)
        dead = self._dead_shards(i, ranges)
        if len(dead) == len(ranges):
            # every shard lost at once: the whole chunk degrades to
            # recorded loss (there is no survivor to re-issue ranges to)
            self.stats.rows_lost += n
            self.stats.shard_ranges_lost += sum(
                1 for lo, hi in ranges if lo < hi
            )
            return
        clean = not dead and (
            self.shard_faults is None or self.shard_faults.clean(i)
        )
        if clean and self.mesh is not None and (
            self._mesh_devices() == self.n_stream_shards
        ):
            parts = self._fit_chunk_mesh(Xc, yc, ranges)
        else:
            parts = self._fit_chunk_ranges(Xc, yc, i, ranges, dead)
        if not parts:
            return  # every range masked out: the chunk contributes nothing
        bank = self._fold_chunk(parts)
        prior = self._slots[self._active]
        if self.bank_kind == "kernel":
            # chunk-local -> absolute stream ids; rows_ingested advances by
            # the FULL chunk (masked rows included) so ids stay unique and
            # replay-stable whatever was lost
            offset = self.stats.rows_ingested
            bank = bank._replace(
                idx=jnp.where(bank.idx >= 0, bank.idx + offset, bank.idx)
            )
            if prior is not None:
                bank, dropped = merge_kernel_banks(
                    prior, bank, return_dropped=True, **self._merge_kw
                )
                self.stats.merge_dropped_mass += float(jnp.sum(dropped))
        elif prior is not None:
            bank = merge_banks(prior, bank)
        self._slots[self._active] = jax.tree.map(jnp.asarray, bank)

    def _dead_shards(self, i: int, ranges) -> set:
        """Structurally dead logical shards for chunk ``i``: planned device
        losses plus declared stragglers. Plan-keyed and stateless, so every
        run (crash replay, chaos reference) re-derives the same set."""
        faults = self.shard_faults
        if faults is None:
            return set()
        dead = {int(j) for j in faults.lost(i) if 0 <= int(j) < len(ranges)}
        elapsed = faults.elapsed(i)
        if elapsed is not None and self.straggler_policy is not None:
            dead |= {
                j for j in self.straggler_policy.stragglers(list(elapsed))
                if 0 <= j < len(ranges)
            }
        return dead

    def _fit_chunk_mesh(self, Xc, yc, ranges):
        """Fast path: every logical shard fits on its own device in ONE mesh
        dispatch. Returns the same (lo, bank) parts list as the degraded
        path — for kernel banks literally the per-shard fits (gathered,
        unfolded); for linear banks the mesh's folded bank as a single part
        (``fit_bank_sharded``'s in-jit fold is bit-identical to the eager
        fold, so both paths agree)."""
        if self.bank_kind == "kernel":
            kw = {k: v for k, v in self._engine_kw.items() if k != "seed_check"}
            stacked = fit_kernel_bank_shards(
                Xc, yc, self.cs, self.mesh, axis=self.shard_axis, **kw
            )
            return [
                (lo, jax.tree.map(lambda x, j=j: x[j], stacked))
                for j, (lo, hi) in enumerate(ranges) if lo < hi
            ]
        folded = fit_bank_sharded(
            Xc, yc, self.cs, self.mesh, None, axis=self.shard_axis,
            **self._engine_kw,
        )
        return [(0, folded)]

    def _fit_chunk_ranges(self, Xc, yc, i: int, ranges, dead):
        """Degraded path: per-range single-device fits. Lost/straggler
        ranges are re-issued to survivors (``rebalance_ranges``); a shard
        whose fetch faults exhaust the retry budget has its whole assigned
        queue masked out with the loss recorded durably."""
        if dead:
            queues = rebalance_ranges(list(ranges), sorted(dead), grouped=True)
            self.stats.ranges_reissued += sum(
                1 for j in dead if ranges[j][0] < ranges[j][1]
            )
        else:
            queues = {j: [r] for j, r in enumerate(ranges)}
        parts = []
        for j in sorted(queues):
            work = [(lo, hi) for lo, hi in queues[j] if lo < hi]
            if not work:
                continue
            if not self._shard_fetch_ok(i, j):
                self.stats.rows_lost += sum(hi - lo for lo, hi in work)
                self.stats.shard_ranges_lost += len(work)
                continue
            for lo, hi in work:
                parts.append((lo, self._fit_range(Xc, yc, lo, hi)))
        parts.sort(key=lambda part: part[0])
        return parts

    def _shard_fetch_ok(self, i: int, j: int) -> bool:
        """Clear shard ``j``'s fetch channel for chunk ``i`` under the
        per-shard retry budget. False = budget exhausted: mask the shard's
        ranges out (the caller records the loss)."""
        if self.shard_faults is None:
            return True
        attempt = 0
        while True:
            try:
                self.shard_faults.check(i, j)
                return True
            except Exception as e:
                if not self.shard_retry.is_retryable(e):
                    raise  # programming error: surface it
                if attempt >= self.shard_retry.max_retries:
                    return False
                self._sleep(self.shard_retry.delay(attempt))
                attempt += 1
                self.stats.shard_retries += 1

    def _fit_range(self, Xc, yc, lo: int, hi: int):
        """Fresh single-device fit of rows [lo, hi); kernel ids lifted to
        chunk coordinates (the +lo the mesh path applies in-shard_map)."""
        Xr, Yr = Xc[lo:hi], yc[:, lo:hi]
        if self.bank_kind == "kernel":
            bank = fit_kernel_bank(Xr, Yr, self.cs, **self._engine_kw)
            return bank._replace(
                idx=jnp.where(bank.idx >= 0, bank.idx + lo, bank.idx)
            )
        return fit_bank(Xr, Yr, self.cs, None, **self._engine_kw)

    def _fold_chunk(self, parts):
        """Eager ascending-range Sec-4.3 fold of the chunk's per-range banks
        — the ONE fold implementation both execution paths share, which is
        what makes them bit-identical. Kernel re-compression drops are
        audited into ``merge_dropped_mass`` (deterministic: the fold
        structure is logical, so every run derives the same drops)."""
        banks = [b for _, b in parts]
        if self.bank_kind == "kernel":
            folded, dropped = fold_kernel_banks(
                banks, return_dropped=True, **self._merge_kw
            )
            self.stats.merge_dropped_mass += float(jnp.sum(dropped))
            return folded
        if len(banks) == 1:
            return banks[0]
        return fold_merge(stack_banks(banks))

    def _age_order(self) -> List[int]:
        """Live slot indices, oldest epoch first (deterministic)."""
        return sorted(
            (s for s in range(self.k) if self._slots[s] is not None),
            key=lambda s: (self._birth[s], s),
        )

    def _rotate(self) -> None:
        if self._slots[self._active] is None:
            return  # empty epoch (all chunks quarantined): nothing to freeze
        free = [s for s in range(self.k) if self._slots[s] is None]
        if free:
            nxt = free[0]
        else:
            order = self._age_order()
            oldest = order[0]
            if self.retire == "drop" or self.k == 1:
                self._slots[oldest] = None
            else:
                second = order[1]
                if self.bank_kind == "kernel":
                    merged, dropped = merge_kernel_banks(
                        self._slots[oldest], self._slots[second],
                        return_dropped=True, **self._merge_kw,
                    )
                    self.stats.merge_dropped_mass += float(jnp.sum(dropped))
                else:
                    merged = merge_banks(
                        self._slots[oldest], self._slots[second]
                    )
                self._slots[second] = jax.tree.map(jnp.asarray, merged)
                self._birth[second] = self._birth[oldest]
                self._slots[oldest] = None
            self.stats.retirements += 1
            nxt = oldest
        self._active = nxt
        self._birth[nxt] = self.chunk_idx
        self.stats.rotations += 1

    def _merged(self):
        """Serving fold of the live slots, oldest first (Ball or KernelBank).

        Also records the fold's dropped |coef| mass in ``_fold_dropped`` —
        the caller that COUNTS the fold (cadence/finalize, not resume)
        accumulates it into the durable ``stats.merge_dropped_mass``.
        """
        order = self._age_order()
        if not order:
            return None
        banks = [self._slots[s] for s in order]
        if self.bank_kind == "kernel":
            folded, dropped = fold_kernel_banks(
                banks, return_dropped=True, **self._merge_kw
            )
            self._fold_dropped = float(jnp.sum(dropped))
        else:
            folded = fold_banks(banks)
            self._fold_dropped = 0.0
        return jax.tree.map(jnp.asarray, folded)

    def _check_server_config(self, server) -> None:
        """Refuse hot-swapping into a server with a mismatched kernel config.

        Duck-typed swap targets without a ``kernel`` attribute (e.g. test
        recorders) opt out; a real ``serve.BankServer`` always has one.
        """
        skernel = getattr(server, "kernel", _NO_KERNEL_ATTR)
        if skernel is _NO_KERNEL_ATTR:
            return
        sgamma = getattr(server, "gamma", None)
        mine = (
            f"bank_kind={self.bank_kind!r}, kernel={self.kernel!r}, "
            f"gamma={self.gamma if self.kernel else None!r}"
        )
        theirs = f"kernel={skernel!r}, gamma={sgamma!r}"
        if skernel != self.kernel or (
            self.kernel is not None
            and sgamma is not None
            and float(sgamma) != self.gamma
        ):
            raise ValueError(
                f"live loop ({mine}) cannot hot-swap into a server "
                f"configured {theirs} — a bank scored under the wrong "
                "kernel config serves silent garbage; rebuild the server "
                "with the loop's kernel configuration"
            )

    def _push(self, merged) -> None:
        if merged is None:
            return
        self._last_merged = merged
        if self.server is None and self.server_factory is not None:
            self.server = self.server_factory(merged)
            self._check_server_config(self.server)
        elif self.server is not None:
            self._check_server_config(self.server)
            self.server.swap_bank(merged)
        self.stats.swaps += 1
        self.stats.last_swap_chunk = self.chunk_idx
        self.stats.bank_age_chunks = 0

    # -- public surface ------------------------------------------------------

    def attach_server(self, server, push_current: bool = True) -> None:
        """Point hot-swaps at ``server``; optionally push the current bank."""
        self._check_server_config(server)
        self.server = server
        if push_current and self._last_merged is not None:
            server.swap_bank(self._last_merged)

    def serving_bank(self):
        """The last folded bank — Ball or KernelBank by ``bank_kind`` —
        i.e. what an attached server is serving."""
        return self._last_merged

    def run(self, max_chunks: Optional[int] = None) -> LiveStats:
        """Resume from the last durable checkpoint and consume the stream.

        Stops when the source returns None (bounded/drained stream) or
        after ``max_chunks`` chunk positions this call. On exit a final
        fold + swap + checkpoint makes the tail durable and served. Crash
        recovery = call run() again (see run_live_with_restarts).
        """
        self._resume_from_disk()
        processed = 0
        while max_chunks is None or processed < max_chunks:
            i = self.chunk_idx
            self._failpoint("fetch", i)
            chunk = self._fetch(i)
            if chunk is _END:
                break
            if chunk is _QUARANTINED:
                self.chunk_idx = i + 1
                processed += 1
                self._cadences(i)
                continue
            X, y = chunk
            if np.asarray(X).shape[0] == 0:
                self.chunk_idx = i + 1
                processed += 1
                continue
            rows = self._train(X, y)
            self._failpoint("post_train", i)
            self.chunk_idx = i + 1
            self.stats.chunks_ingested += 1
            self.stats.rows_ingested += rows
            processed += 1
            self._cadences(i)
        self._finalize()
        return self.stats

    def _publishable(self, merged) -> bool:
        """The non-finite publish guard: a fold with NaN/Inf in ANY model
        row must never be hot-swapped (one poisoned coordinate turns every
        score of that row into NaN). Default: quarantine the fold —
        ``folds_quarantined`` counts it, the server keeps the last good
        bank. ``strict_finite=True``: raise, naming the offending rows."""
        bad = nonfinite_rows(merged)
        if not bool(jnp.any(bad)):
            return True
        rows = np.flatnonzero(np.asarray(bad)).tolist()
        if self.strict_finite:
            raise ValueError(
                f"non-finite serving fold at chunk {self.chunk_idx}: model "
                f"row(s) {rows} contain NaN/Inf — refusing to publish "
                "(strict_finite=True). The last good bank keeps serving; "
                "inspect the stream window since the last swap."
            )
        self.stats.folds_quarantined += 1
        return False

    def _cadences(self, i: int) -> None:
        """Rotation / fold+swap / checkpoint, keyed on the ABSOLUTE chunk
        position so a replayed window re-fires them identically (and
        ``rotate_on`` sees only replay-stable durable stats)."""
        rotate = self.chunk_idx % self.rotate_every == 0
        if not rotate and self.rotate_on is not None:
            rotate = bool(self.rotate_on(self.stats))
        if rotate:
            self._rotate()
            self._failpoint("post_rotate", i)
        if self.chunk_idx % self.swap_every == 0:
            merged = self._merged()
            if merged is not None:
                if self._publishable(merged):
                    self.stats.folds += 1
                    self.stats.merge_dropped_mass += self._fold_dropped
                    self._folds_since_ckpt += 1
                    self._failpoint("post_fold", i)
                    self._push(merged)
                    self._failpoint("post_swap", i)
                else:
                    # quarantined folds still count toward the checkpoint
                    # cadence: durability must not stall on poisoned data
                    self._folds_since_ckpt += 1
        if (
            self.checkpoint_every_folds
            and self._folds_since_ckpt >= self.checkpoint_every_folds
        ):
            self._checkpoint(i)
        if self.stats.last_swap_chunk >= 0:
            self.stats.bank_age_chunks = (
                self.chunk_idx - self.stats.last_swap_chunk
            )

    def _finalize(self) -> None:
        """Drained-stream tail: fold+swap anything trained since the last
        cadence hit, then commit a final checkpoint."""
        if self.chunk_idx % self.swap_every != 0:
            merged = self._merged()
            if merged is not None and (
                self.stats.last_swap_chunk != self.chunk_idx
            ):
                if self._publishable(merged):
                    self.stats.folds += 1
                    self.stats.merge_dropped_mass += self._fold_dropped
                    self._folds_since_ckpt += 1
                    self._push(merged)
                else:
                    self._folds_since_ckpt += 1
        if self.checkpoint_every_folds and self._folds_since_ckpt:
            self._checkpoint(self.chunk_idx - 1)


def run_live_with_restarts(
    live: LiveBank,
    *,
    max_restarts: int = 8,
    policy: Optional[RetryPolicy] = None,
    sleep: Callable[[float], None] = time.sleep,
    max_chunks: Optional[int] = None,
) -> LiveStats:
    """Crash-recovery driver: re-enter ``live.run()`` after retryable
    failures (the live-loop analogue of runtime.run_with_restarts).

    Each restart resumes from the last durable StreamCheckpoint — the
    crash-equivalence suite proves the recovered bank and served scores are
    bit-identical (f32) to an uninterrupted run. Non-retryable exceptions
    (programming errors) propagate immediately.

    The default policy classifies injected test failures, ``DeviceLostError``
    and the JAX/XLA runtime's device-fault exceptions (e.g.
    ``jaxlib.xla_extension.XlaRuntimeError``) as retryable
    (``runtime.default_live_retryable``): a transient device fault burns a
    restart instead of propagating as if it were a programming error.
    """
    policy = policy or RetryPolicy(
        retryable=default_live_retryable(), max_retries=max_restarts
    )
    restarts = 0
    while True:
        try:
            return live.run(max_chunks=max_chunks)
        except Exception as e:
            if not policy.is_retryable(e):
                raise
            restarts += 1
            if restarts > max_restarts:
                raise
            live.stats.restarts += 1
            sleep(policy.delay(restarts - 1))
