"""repro.live — fault-tolerant continuous train->serve loop with drift repair.

``LiveBank`` closes the trainer/server loop into an always-on system — for
linear Ball banks AND kernelized core-set banks (``bank_kind="kernel"``):
see loop.py for the K-sub-bank drift-repair contract, the kernel-space
train->merge->fold path, and the crash-recovery protocol; sources.py for
the replayable-chunk-source contract.
"""
from .loop import PHASES, LiveBank, LiveStats, run_live_with_restarts
from .sources import ArraySource, FlakySource, TransientSourceError

__all__ = [
    "ArraySource",
    "FlakySource",
    "LiveBank",
    "LiveStats",
    "PHASES",
    "TransientSourceError",
    "run_live_with_restarts",
]
