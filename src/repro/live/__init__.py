"""repro.live — fault-tolerant continuous train->serve loop with drift repair.

``LiveBank`` closes the trainer/server loop into an always-on system — for
linear Ball banks AND kernelized core-set banks (``bank_kind="kernel"``):
see loop.py for the K-sub-bank drift-repair contract, the kernel-space
train->merge->fold path, the crash-recovery protocol, and the elastic
sharded-training contract (``mesh=`` / ``n_stream_shards=``); sources.py
for the replayable-chunk-source and per-shard fault-plan contracts;
chaos.py for the seeded kill/fault/remesh harness that proves crashes and
remeshes are invisible.
"""
from .chaos import ChaosSchedule, chaos_reference, chaos_schedule, run_chaos
from .loop import PHASES, LiveBank, LiveStats, run_live_with_restarts
from .sources import ArraySource, FlakySource, ShardFaults, TransientSourceError

__all__ = [
    "ArraySource",
    "ChaosSchedule",
    "FlakySource",
    "LiveBank",
    "LiveStats",
    "PHASES",
    "ShardFaults",
    "TransientSourceError",
    "chaos_reference",
    "chaos_schedule",
    "run_chaos",
]
