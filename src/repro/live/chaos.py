"""Chaos harness for the elastic live loop — seeded fault schedules + driver.

``chaos_schedule(seed, ...)`` draws one deterministic schedule of everything
that can go wrong around a LiveBank:

  - process KILLS: ``(phase, chunk)`` failpoints raising ``InjectedFailure``
    at the loop's phase boundaries (including the torn-tmp
    ``mid_checkpoint`` crash);
  - per-shard fetch faults: device-loss (``lost``), transient (``flaky``),
    poison, and straggler (``slow``) plans packaged as a
    ``sources.ShardFaults``;

and ``run_chaos`` drives a loop through it, relaunching after every kill and
switching to the next mesh in ``meshes`` on relaunch (remesh-on-restart:
the 8 -> 4 -> 1 elastic story).

The chaos CONTRACT — what tests/test_live_bank.py asserts for both bank
kinds: kills and remeshes are INVISIBLE. The final bank, served scores and
durable LiveStats of the chaos run are bit-identical (f32) to
``chaos_reference`` — the same stream and the same ShardFaults plan, but no
kills and a single (or no) mesh. Shard faults themselves are structural
(they decide which ranges train and how work is re-issued), so they appear
identically in both runs; what chaos adds on top must change nothing.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Set, Tuple

import numpy as np

from repro.runtime.fault_tolerance import InjectedFailure

from .loop import PHASES, LiveBank
from .sources import ShardFaults

# a LiveBank factory: make_live(mesh, failpoints, shard_faults) -> LiveBank.
# Every call must address the same stream and the same ckpt_dir; the driver
# passes the SHARED failpoint set (kills fire once per run, not per process)
# and the shared ShardFaults instance (attempt counters span relaunches).
MakeLive = Callable[[object, Set[Tuple[str, int]], ShardFaults], LiveBank]


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """One seeded draw of kills + shard faults (see ``chaos_schedule``)."""

    seed: int
    kills: Tuple[Tuple[str, int], ...]
    lost: Dict[int, Tuple[int, ...]]
    flaky: Dict[Tuple[int, int], int]
    slow: Dict[int, Tuple[float, ...]]

    def shard_faults(self) -> ShardFaults:
        """A FRESH ShardFaults over this schedule's plans (attempt counters
        zeroed) — build one per RUN: the chaos run and its reference each
        get their own, while relaunches within a run share the driver's."""
        return ShardFaults(lost=self.lost, flaky=self.flaky, slow=self.slow)


def chaos_schedule(
    seed: int,
    *,
    n_chunks: int,
    n_shards: int,
    kills: int = 4,
    kill_phases: Sequence[str] = PHASES,
    lost_chunks: int = 2,
    flaky_chunks: int = 2,
    poison_chunks: int = 1,
    slow_chunks: int = 1,
    flaky_budget: int = 2,
) -> ChaosSchedule:
    """Draw a deterministic chaos schedule from ``seed``.

    ``kills`` distinct (phase, chunk) process kills; ``lost_chunks`` chunks
    each lose 1..n_shards-1 devices (never all — rebalance needs a
    survivor); ``flaky_chunks`` chunks get one shard failing 1..flaky_budget
    times before delivering (keep ``flaky_budget`` <= the loop's shard
    retry budget or the fault decays into a poison — the replay-stability
    caveat of ``ShardFaults``); ``poison_chunks`` chunks get one shard
    failing forever (masked out past the budget); ``slow_chunks`` chunks get
    a 10x straggler in their per-shard heartbeat times. Fault categories
    land on DISTINCT chunks so each outcome is independently attributable.
    """
    if n_shards < 2:
        raise ValueError(
            f"chaos_schedule needs n_shards >= 2 (lost/straggler shards "
            f"must leave a survivor): got {n_shards}"
        )
    n_fault_chunks = lost_chunks + flaky_chunks + poison_chunks + slow_chunks
    if n_fault_chunks > n_chunks:
        raise ValueError(
            f"{n_fault_chunks} fault chunks requested but the stream has "
            f"only {n_chunks}"
        )
    rng = np.random.default_rng(seed)

    kill_set: Set[Tuple[str, int]] = set()
    while len(kill_set) < kills:
        kill_set.add((
            str(rng.choice(list(kill_phases))),
            int(rng.integers(0, n_chunks)),
        ))

    fault_chunks = rng.choice(n_chunks, size=n_fault_chunks, replace=False)
    cursor = 0

    lost: Dict[int, Tuple[int, ...]] = {}
    for c in fault_chunks[cursor:cursor + lost_chunks]:
        k = int(rng.integers(1, n_shards))  # 1 .. n_shards-1 lost
        shards = rng.choice(n_shards, size=k, replace=False)
        lost[int(c)] = tuple(int(j) for j in sorted(shards))
    cursor += lost_chunks

    flaky: Dict[Tuple[int, int], int] = {}
    for c in fault_chunks[cursor:cursor + flaky_chunks]:
        shard = int(rng.integers(0, n_shards))
        flaky[(int(c), shard)] = int(rng.integers(1, flaky_budget + 1))
    cursor += flaky_chunks

    for c in fault_chunks[cursor:cursor + poison_chunks]:
        shard = int(rng.integers(0, n_shards))
        flaky[(int(c), shard)] = ShardFaults.POISON
    cursor += poison_chunks

    slow: Dict[int, Tuple[float, ...]] = {}
    for c in fault_chunks[cursor:cursor + slow_chunks]:
        times = rng.uniform(0.8, 1.2, size=n_shards)
        times[int(rng.integers(0, n_shards))] *= 10.0  # one clear straggler
        slow[int(c)] = tuple(float(t) for t in times)

    return ChaosSchedule(
        seed=int(seed), kills=tuple(sorted(kill_set)),
        lost=lost, flaky=flaky, slow=slow,
    )


def run_chaos(
    make_live: MakeLive,
    schedule: ChaosSchedule,
    *,
    meshes: Sequence[object] = (None,),
    max_chunks: Optional[int] = None,
) -> LiveBank:
    """Drive ``make_live`` through ``schedule`` to completion.

    Every kill crashes ``run()`` with an InjectedFailure; the driver then
    relaunches — resuming from the last durable StreamCheckpoint — on the
    NEXT mesh in ``meshes`` (the last mesh repeats once the list is
    exhausted: a run under ``meshes=(mesh8, mesh4, None)`` executes the
    8 -> 4 -> single-device elastic schedule). The failpoint set and
    ShardFaults instance are shared across relaunches, so each kill fires
    exactly once and per-shard attempt counters span processes, exactly
    like a real fleet. Returns the final LiveBank after a clean run.
    """
    faults = schedule.shard_faults()
    failpoints: Set[Tuple[str, int]] = set(schedule.kills)
    meshes = list(meshes) or [None]
    mesh_i = 0
    live = make_live(meshes[mesh_i], failpoints, faults)
    fired = 0
    while True:
        try:
            live.run(max_chunks=max_chunks)
            return live
        except InjectedFailure:
            fired += 1
            if fired > len(schedule.kills):
                raise  # a failpoint re-fired: the shared-set contract broke
            restarts = live.stats.restarts + 1
            if mesh_i + 1 < len(meshes):
                mesh_i += 1  # remesh-on-restart
                live = make_live(meshes[mesh_i], failpoints, faults)
            live.stats.restarts = restarts


def chaos_reference(
    make_live: MakeLive,
    schedule: ChaosSchedule,
    *,
    mesh: object = None,
    max_chunks: Optional[int] = None,
) -> LiveBank:
    """The crash-free referent: the SAME shard-fault plan, NO kills, one
    mesh (default none — pure per-range execution). Point ``make_live`` at
    a separate ckpt_dir from the chaos run's."""
    live = make_live(mesh, set(), schedule.shard_faults())
    live.run(max_chunks=max_chunks)
    return live
