"""Chunk sources for the live loop — replayable-by-index stream adapters.

The live loop's crash-safety contract is REPLAY: a source is a callable
``source(i) -> (X_chunk, y_chunk) | None`` addressed by absolute chunk index.
After a crash, the trainer restarts from its last durable StreamCheckpoint
and re-requests exactly the chunks consumed since — so a source must return
the same rows for the same index every time it is asked (Kafka offsets, a
sharded log, or a file of fixed-size records all satisfy this; a one-shot
python iterator does NOT). ``None`` means the stream is (currently)
exhausted — the loop stops; an unbounded deployment source would block
instead of returning None.

Bit-exact crash equivalence additionally needs the per-chunk *outcome* to be
stable across re-fetches: a chunk either delivers the same rows (possibly
after transient failures) or always fails into quarantine. A chunk whose
retry budget only sometimes covers its flakiness trains in one run and is
quarantined in another — that is a property of the source, not of the loop.

``ArraySource``    in-memory (X, y) arrays chunked by index (tests/examples).
``FlakySource``    wraps a source with a deterministic failure plan —
                   transient faults (fail n times, then deliver) and poison
                   chunks (fail forever) for retry/quarantine testing.
``ShardFaults``    the per-(chunk, shard) fault plan of the ELASTIC live
                   loop: device-loss and straggler events (structural —
                   the shard's range is re-issued to survivors) plus
                   per-shard fetch faults (transient or poison — masked
                   out past the shard retry budget).
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

import numpy as np


class TransientSourceError(RuntimeError):
    """A retryable chunk-fetch fault (network blip, storage timeout)."""


Chunk = Tuple[np.ndarray, np.ndarray]


class ArraySource:
    """Replayable chunks out of in-memory arrays.

    ``y`` is (N,) shared labels or (B, N) per-model sign rows — chunk i is
    rows [i*chunk_size, (i+1)*chunk_size) of X and the matching columns/rows
    of y, exactly like ``data.stream.chunk_stream`` but addressed by index.
    """

    def __init__(self, X, y, chunk_size: int):
        self.X = np.asarray(X)
        self.y = np.asarray(y)
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1: got {chunk_size}")
        self.chunk_size = int(chunk_size)
        n = self.X.shape[0]
        self.n_chunks = -(-n // self.chunk_size) if n else 0

    def __call__(self, i: int) -> Optional[Chunk]:
        lo = i * self.chunk_size
        if lo >= self.X.shape[0]:
            return None
        hi = min(lo + self.chunk_size, self.X.shape[0])
        yc = self.y[lo:hi] if self.y.ndim == 1 else self.y[:, lo:hi]
        return self.X[lo:hi], yc


class FlakySource:
    """Deterministic fault injection around any replayable source.

    ``fail_plan`` maps chunk index -> number of consecutive failures before
    the chunk delivers; ``POISON`` (or any negative count) marks a chunk
    that fails on every attempt, forever — the quarantine case. Attempts
    are counted per chunk across the source's lifetime, so a transient
    chunk's outcome is stable per fetch only while its budget lasts (see
    module docstring).
    """

    POISON = -1

    def __init__(
        self,
        inner: Callable[[int], Optional[Chunk]],
        fail_plan: Dict[int, int],
        exc: Callable[[str], BaseException] = TransientSourceError,
    ):
        self.inner = inner
        self.fail_plan = dict(fail_plan)
        self.exc = exc
        self.attempts: Dict[int, int] = {}

    def __call__(self, i: int) -> Optional[Chunk]:
        plan = self.fail_plan.get(i, 0)
        seen = self.attempts.get(i, 0)
        self.attempts[i] = seen + 1
        if plan < 0:
            raise self.exc(f"poison chunk {i} (attempt {seen + 1})")
        if seen < plan:
            raise self.exc(
                f"transient fault on chunk {i} (attempt {seen + 1}/{plan})"
            )
        return self.inner(i)


class ShardFaults:
    """Deterministic per-(chunk, shard) fault plan for the elastic live loop.

    The elastic loop splits every chunk into ``n_stream_shards`` LOGICAL
    ranges (``core.shard_ranges``); this object scripts what goes wrong per
    (chunk index, logical shard) — the shard-level analogue of
    ``FlakySource``:

    ``lost``   chunk -> shard ids whose DEVICE is lost for that chunk.
               Structural: queried (never raised), fires in every run and
               on every crash replay, so the re-issued range layout —
               ``runtime.rebalance_ranges`` splits the lost range among
               survivors — is identical in a chaos run and its crash-free
               reference.
    ``flaky``  (chunk, shard) -> consecutive per-shard fetch failures
               before the range delivers; ``POISON`` (any negative) fails
               forever — past the loop's shard retry budget the shard's
               assigned ranges are MASKED OUT (rows recorded in
               ``LiveStats.rows_lost``). Attempts are counted across this
               instance's lifetime, so share ONE instance across the
               relaunches of a crashy run (the replay-stability caveat of
               the module docstring applies per shard: keep transient
               counts within the retry budget, or use POISON).
    ``slow``   chunk -> simulated per-shard elapsed seconds, handed to the
               loop's ``StragglerPolicy``; declared stragglers are
               re-issued exactly like ``lost`` shards. Structural and
               stateless, hence replay-stable.
    """

    POISON = -1

    def __init__(
        self,
        *,
        lost: Optional[Dict[int, Iterable[int]]] = None,
        flaky: Optional[Dict[Tuple[int, int], int]] = None,
        slow: Optional[Dict[int, Sequence[float]]] = None,
        exc: Callable[[str], BaseException] = TransientSourceError,
    ):
        self._lost = {
            int(c): frozenset(int(j) for j in js)
            for c, js in (lost or {}).items()
        }
        self._flaky = {
            (int(c), int(j)): int(n) for (c, j), n in (flaky or {}).items()
        }
        self._slow = {
            int(c): tuple(float(t) for t in ts)
            for c, ts in (slow or {}).items()
        }
        self.exc = exc
        self.attempts: Dict[Tuple[int, int], int] = {}

    def lost(self, i: int) -> frozenset:
        """Shard ids whose device is lost for chunk ``i``."""
        return self._lost.get(i, frozenset())

    def elapsed(self, i: int) -> Optional[Tuple[float, ...]]:
        """Simulated per-shard elapsed seconds for chunk ``i`` (or None)."""
        return self._slow.get(i)

    def clean(self, i: int) -> bool:
        """True when chunk ``i`` has NO planned fault of any kind — the
        loop's license to take the single-dispatch mesh fast path. Plan-
        keyed (not attempt-keyed), so every run answers identically."""
        return (
            i not in self._lost
            and i not in self._slow
            and all(c != i for (c, _j) in self._flaky)
        )

    def check(self, i: int, j: int) -> None:
        """Raise shard ``j``'s planned fetch fault for chunk ``i``, if any."""
        plan = self._flaky.get((i, j), 0)
        if plan == 0:
            return
        seen = self.attempts.get((i, j), 0)
        self.attempts[(i, j)] = seen + 1
        if plan < 0:
            raise self.exc(f"poisoned shard {j} of chunk {i} (attempt {seen + 1})")
        if seen < plan:
            raise self.exc(
                f"transient fault on shard {j} of chunk {i} "
                f"(attempt {seen + 1}/{plan})"
            )
