"""Synthetic LM token pipeline: Zipf-Markov streams + two-"style" corpora.

No internet in this container, so LM training/serving examples run on
synthetic token streams with enough structure for the loss to fall fast
(first-order Markov chains with Zipfian marginals). `styled_corpus` yields
two latent styles (different transition matrices) for the feature->StreamSVM
classification example.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def _markov(rng, vocab: int, branch: int = 20, temp: float = 1.0, lo=0, hi=None):
    """Sparse per-token transition table: (vocab, branch) targets + probs.

    Targets are confined to [lo, hi) so corpora can occupy distinct vocab
    regions (distinguishable styles)."""
    hi = vocab if hi is None else hi
    targets = rng.integers(lo, hi, size=(vocab, branch))
    raw = rng.exponential(scale=temp, size=(vocab, branch))
    probs = raw / raw.sum(axis=1, keepdims=True)
    return targets, probs


def _sample(rng, targets, probs, n: int, start: int = 0) -> np.ndarray:
    out = np.empty(n, np.int32)
    t = start
    for i in range(n):
        j = rng.choice(probs.shape[1], p=probs[t])
        t = int(targets[t, j])
        out[i] = t
    return out


def token_batches(
    vocab: int, batch: int, seq: int, steps: int, seed: int = 0
) -> Iterator[dict]:
    """Yields {tokens, targets} int32 (batch, seq) — targets are shifted."""
    rng = np.random.default_rng(seed)
    targets_tab, probs = _markov(rng, vocab)
    for _ in range(steps):
        toks = np.stack(
            [_sample(rng, targets_tab, probs, seq + 1, start=int(rng.integers(vocab)))
             for _ in range(batch)]
        )
        yield {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def styled_corpus(
    vocab: int, n_docs: int, seq: int, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """(tokens (n_docs, seq), labels ±1) — two Markov 'styles'."""
    rng = np.random.default_rng(seed)
    # two styles: mostly-disjoint vocab regions + branching factors
    tabs = [
        _markov(rng, vocab, branch=4, temp=0.7, lo=0, hi=int(0.55 * vocab)),
        _markov(rng, vocab, branch=50, temp=2.5, lo=int(0.45 * vocab), hi=vocab),
    ]
    starts = [rng.integers(0, vocab // 2, 64), rng.integers(vocab // 2, vocab, 64)]
    toks = np.empty((n_docs, seq), np.int32)
    labels = np.empty(n_docs, np.float32)
    for i in range(n_docs):
        s = i % 2
        t, p = tabs[s]
        toks[i] = _sample(rng, t, p, seq, start=int(rng.choice(starts[s])))
        labels[i] = 1.0 if s == 0 else -1.0
    return toks, labels
