"""Datasets for the paper's Table 1, generated offline.

Synthetic A/B/C and Waveform are genuinely synthetic in the paper too and are
generated to the paper's specs (dims, sizes, ~85% separability for A/B/C;
Waveform is the classic CART generator). MNIST / IJCNN / w3a are real datasets
that cannot be downloaded in this container — we substitute *spec-matched
surrogates* (same dimensionality, train/test sizes, class balance, and a
difficulty profile tuned so the batch-SVM ceiling lands near the paper's
libSVM column). Every deviation is recorded in EXPERIMENTS.md §Datasets.

All generators are deterministic given `seed`.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np

Arrays = Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]


def _split(X, y, n_train, n_test, rng):
    idx = rng.permutation(len(y))
    X, y = X[idx], y[idx]
    return (
        X[:n_train].astype(np.float32),
        y[:n_train].astype(np.float32),
        X[n_train : n_train + n_test].astype(np.float32),
        y[n_train : n_train + n_test].astype(np.float32),
    )


def _gauss_clusters(
    rng, n, dim, centers_pos, centers_neg, scale
) -> Tuple[np.ndarray, np.ndarray]:
    half = n // 2
    Xp = np.concatenate(
        [
            rng.normal(loc=c, scale=scale, size=(half // len(centers_pos), dim))
            for c in centers_pos
        ]
    )
    Xn = np.concatenate(
        [
            rng.normal(loc=c, scale=scale, size=(half // len(centers_neg), dim))
            for c in centers_neg
        ]
    )
    X = np.concatenate([Xp, Xn])
    y = np.concatenate([np.ones(len(Xp)), -np.ones(len(Xn))])
    return X, y


def synthetic_a(seed=0) -> Arrays:
    """2-D, two normally distributed clusters, ~96% linearly separable."""
    rng = np.random.default_rng(seed)
    X, y = _gauss_clusters(
        rng, 20200, 2, centers_pos=[[1.2, 1.2]], centers_neg=[[-1.2, -1.2]], scale=1.0
    )
    return _split(X, y, 20000, 200, rng)


def synthetic_b(seed=0) -> Arrays:
    """3-D asymmetric flipped mixture — linear ceiling ~66% (paper: 66.0)."""
    rng = np.random.default_rng(seed)
    n = 20200
    npos = n // 2
    frac = 0.65
    mu = np.array([1.0, 1.0, 0.5]) * 1.2
    nmain = int(frac * npos)
    Xp = np.vstack(
        [rng.normal(size=(nmain, 3)) + mu, rng.normal(size=(npos - nmain, 3)) - mu]
    )
    Xn = np.vstack(
        [rng.normal(size=(nmain, 3)) - mu, rng.normal(size=(npos - nmain, 3)) + mu]
    )
    X = np.vstack([Xp, Xn])
    y = np.concatenate([np.ones(npos), -np.ones(npos)])
    return _split(X, y, 20000, 200, rng)


def synthetic_c(seed=0) -> Arrays:
    """5-D normally distributed clusters, moderate overlap (~93%)."""
    rng = np.random.default_rng(seed)
    mu = np.array([0.9, 0.7, 0.5, 0.4, 0.3])
    X, y = _gauss_clusters(rng, 20200, 5, centers_pos=[mu], centers_neg=[-mu], scale=1.0)
    return _split(X, y, 20000, 200, rng)


def waveform(seed=0) -> Arrays:
    """Waveform-21 (Breiman et al.): classes 1 vs 2, 21 dims, 4000/1000."""
    rng = np.random.default_rng(seed)
    t = np.arange(1, 22, dtype=np.float64)

    def tri(center):
        return np.maximum(6.0 - np.abs(t - center), 0.0)

    h1, h2, h3 = tri(11), tri(7), tri(15)

    def gen(n, a, b):
        u = rng.uniform(size=(n, 1))
        return u * a + (1.0 - u) * b + rng.normal(size=(n, 21))

    n_tot = 5200
    X1 = gen(n_tot // 2, h1, h2)  # class 1
    X2 = gen(n_tot // 2, h1, h3)  # class 2
    X = np.concatenate([X1, X2])
    y = np.concatenate([np.ones(len(X1)), -np.ones(len(X2))])
    return _split(X, y, 4000, 1000, rng)


def _digit_prototypes(rng, easy: bool):
    """Two 28x28 stroke prototypes; easy=(0,1)-like, hard=(8,9)-like."""
    yy, xx = np.mgrid[0:28, 0:28]

    def ring(cy, cx, r, width):
        d = np.sqrt((yy - cy) ** 2 + (xx - cx) ** 2)
        return np.exp(-((d - r) ** 2) / (2.0 * width**2))

    def stroke(y0, y1, x0, x1, width=1.6):
        # line segment brush
        n = 64
        ys = np.linspace(y0, y1, n)[:, None, None]
        xs = np.linspace(x0, x1, n)[:, None, None]
        d2 = (yy[None] - ys) ** 2 + (xx[None] - xs) ** 2
        return np.exp(-d2 / (2.0 * width**2)).max(axis=0)

    if easy:
        p_a = ring(14, 14, 8, 1.8)  # "0"
        p_b = stroke(4, 24, 14, 14)  # "1"
    else:
        p_a = ring(9, 14, 5, 1.6) + ring(19, 14, 5, 1.6)  # "8"
        p_b = ring(9, 14, 5, 1.6) + stroke(13, 24, 18, 16)  # "9"
    return p_a, p_b


def _mnist_like(seed, easy, n_train, n_test) -> Arrays:
    rng = np.random.default_rng(seed)
    p_a, p_b = _digit_prototypes(rng, easy)
    n = n_train + n_test
    X = np.empty((n, 784), np.float32)
    y = np.empty(n, np.float32)
    for i in range(n):
        proto = p_a if i % 2 == 0 else p_b
        img = np.roll(proto, rng.integers(-2, 3), axis=0)
        img = np.roll(img, rng.integers(-2, 3), axis=1)
        img = img * rng.uniform(0.7, 1.3) + rng.normal(scale=0.25, size=(28, 28))
        X[i] = np.clip(img, 0, None).reshape(-1)
        y[i] = 1.0 if i % 2 == 0 else -1.0
    # normalize like MNIST pixels /255-ish scale
    X /= max(X.max(), 1e-6)
    return _split(X, y, n_train, n_test, rng)


def mnist01_like(seed=0) -> Arrays:
    return _mnist_like(seed, easy=True, n_train=12665, n_test=2115)


def mnist89_like(seed=0) -> Arrays:
    return _mnist_like(seed, easy=False, n_train=11800, n_test=1983)


def ijcnn_like(seed=0) -> Arrays:
    """22-dim, 35k/91701, ~10% positive, mostly non-linear boundary.

    Tuned so the linear-SVM ceiling sits just above the majority rate — the
    profile of the real IJCNN-2001 data (paper: libSVM 91.64 vs ~90.3
    majority; all single-pass methods below majority).
    """
    rng = np.random.default_rng(seed)
    n = 35000 + 91701
    X = rng.normal(size=(n, 22))
    score = 0.8 * (X[:, 0] + 0.5 * X[:, 4]) + (
        0.8 * X[:, 1] * X[:, 2] + 0.6 * np.sin(2.0 * X[:, 3]) + 0.5 * X[:, 5] * X[:, 6]
    )
    thresh = np.quantile(score, 0.90)  # ~10% positives
    y = np.where(score + 0.2 * rng.normal(size=n) > thresh, 1.0, -1.0)
    return _split(X.astype(np.float32), y, 35000, 91701, rng)


def w3a_like(seed=0) -> Arrays:
    """300-dim sparse binary, 44837/4912, ~3% positive (w3a profile)."""
    rng = np.random.default_rng(seed)
    n = 44837 + 4912
    density = 0.04
    X = (rng.uniform(size=(n, 300)) < density).astype(np.float32)
    w_true = rng.normal(size=300) * (rng.uniform(size=300) < 0.15)
    score = X @ w_true + 0.3 * rng.normal(size=n)
    thresh = np.quantile(score, 0.97)  # ~3% positives
    y = np.where(score > thresh, 1.0, -1.0)
    return _split(X, y, 44837, 4912, rng)


DATASETS: Dict[str, Callable[..., Arrays]] = {
    "synthetic_a": synthetic_a,
    "synthetic_b": synthetic_b,
    "synthetic_c": synthetic_c,
    "waveform": waveform,
    "mnist01": mnist01_like,
    "mnist89": mnist89_like,
    "ijcnn": ijcnn_like,
    "w3a": w3a_like,
}

# Paper Table 1 reference numbers (for EXPERIMENTS.md comparison columns).
PAPER_TABLE1 = {
    # dataset: (libSVM batch, Perceptron, Pegasos k=1, Pegasos k=20, LASVM,
    #           StreamSVM Algo1, StreamSVM Algo2)
    "synthetic_a": (96.5, 95.5, 83.8, 89.9, 96.5, 95.5, 97.0),
    "synthetic_b": (66.0, 68.0, 57.05, 65.85, 64.5, 64.4, 68.5),
    "synthetic_c": (93.2, 77.0, 55.0, 73.2, 68.0, 73.1, 87.5),
    "waveform": (89.4, 72.5, 77.34, 78.12, 77.6, 74.3, 78.4),
    "mnist01": (99.52, 99.47, 95.06, 99.48, 98.82, 99.34, 99.71),
    "mnist89": (96.57, 95.9, 69.41, 90.62, 90.32, 84.75, 94.7),
    "ijcnn": (91.64, 64.82, 67.35, 88.9, 74.27, 85.32, 87.81),
    "w3a": (98.29, 89.27, 57.36, 87.28, 96.95, 88.56, 89.06),
}


def load_dataset(name: str, seed: int = 0) -> Arrays:
    return DATASETS[name](seed=seed)
