"""Feature preprocessing — makes the MEB<->SVM theory's assumption hold.

The reduction requires K(x,x) = kappa constant; for the linear kernel that
means L2-normalized inputs ("dot product (normalized inputs)", paper Sec 3).
We additionally (a) center dense features on the train mean — the unbiased
classifier otherwise degenerates on all-positive feature spaces (every pair
of unit rows has a non-negative dot product, so any single-example-dominated
center classifies everything as one class), and (b) optionally append a
constant bias coordinate *before* normalization, the standard augmentation
for the "biased" extension the paper mentions. Both preserve K(x,x)=1.

Sparse datasets (w3a) are not centered, matching standard SVM practice.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

# per-dataset policy: (center, bias_feature)
POLICY: Dict[str, Tuple[bool, bool]] = {
    "synthetic_a": (True, False),
    "synthetic_b": (True, False),
    "synthetic_c": (True, False),
    "waveform": (True, False),
    "mnist01": (True, False),
    "mnist89": (True, False),
    "ijcnn": (True, True),
    "w3a": (False, False),
}


def l2_normalize(X: np.ndarray) -> np.ndarray:
    n = np.linalg.norm(X, axis=1, keepdims=True)
    return X / np.maximum(n, 1e-8)


def preprocess(Xtr, Xte, *, center: bool = True, bias: bool = False):
    Xtr = np.asarray(Xtr, np.float32)
    Xte = np.asarray(Xte, np.float32)
    if center:
        mu = Xtr.mean(axis=0, keepdims=True)
        Xtr, Xte = Xtr - mu, Xte - mu
    if bias:
        Xtr = np.hstack([Xtr, np.ones((len(Xtr), 1), np.float32)])
        Xte = np.hstack([Xte, np.ones((len(Xte), 1), np.float32)])
    return l2_normalize(Xtr), l2_normalize(Xte)


def preprocess_for(name: str, Xtr, Xte):
    center, bias = POLICY.get(name, (True, False))
    return preprocess(Xtr, Xte, center=center, bias=bias)
