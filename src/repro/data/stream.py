"""Streaming utilities: permutations, chunk iterators, contiguous shard ranges.

The shard-range contract matters for fault tolerance: work is assigned as
contiguous [start, end) ranges so a failed/straggling shard's range can be
re-issued to survivors, and the ball merge is order-insensitive (see
core/distributed.py and runtime/fault_tolerance.py).
"""
from __future__ import annotations

from typing import Iterator, List, Tuple

import numpy as np


def permuted(X, y, seed: int):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(y))
    return X[idx], y[idx]


def chunk_stream(X, y, chunk_size: int = 4096, start: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yield (X_chunk, y_chunk) from `start` — supports checkpoint resume."""
    n = len(y)
    for lo in range(start, n, chunk_size):
        hi = min(lo + chunk_size, n)
        yield X[lo:hi], y[lo:hi]


def shard_ranges(n: int, n_shards: int) -> List[Tuple[int, int]]:
    """Contiguous near-equal [start, end) ranges covering [0, n)."""
    base, rem = divmod(n, n_shards)
    out, lo = [], 0
    for s in range(n_shards):
        hi = lo + base + (1 if s < rem else 0)
        out.append((lo, hi))
        lo = hi
    return out
