from .synthetic import DATASETS, PAPER_TABLE1, load_dataset
from .stream import chunk_stream, permuted, shard_ranges
from .preprocess import POLICY, preprocess, preprocess_for

__all__ = [
    "DATASETS",
    "PAPER_TABLE1",
    "POLICY",
    "chunk_stream",
    "load_dataset",
    "permuted",
    "preprocess",
    "preprocess_for",
    "shard_ranges",
]
