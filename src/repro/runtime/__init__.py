from .fault_tolerance import (
    DeviceLostError,
    InjectedFailure,
    RetryPolicy,
    RunReport,
    StragglerPolicy,
    default_live_retryable,
    rebalance_ranges,
    remesh_state,
    run_with_restarts,
    runtime_device_errors,
)

__all__ = [
    "DeviceLostError",
    "InjectedFailure",
    "RetryPolicy",
    "RunReport",
    "StragglerPolicy",
    "default_live_retryable",
    "rebalance_ranges",
    "remesh_state",
    "run_with_restarts",
    "runtime_device_errors",
]
