from .fault_tolerance import (
    InjectedFailure,
    RunReport,
    StragglerPolicy,
    rebalance_ranges,
    remesh_state,
    run_with_restarts,
)

__all__ = [
    "InjectedFailure",
    "RunReport",
    "StragglerPolicy",
    "rebalance_ranges",
    "remesh_state",
    "run_with_restarts",
]
