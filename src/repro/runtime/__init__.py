from .fault_tolerance import (
    InjectedFailure,
    RetryPolicy,
    RunReport,
    StragglerPolicy,
    rebalance_ranges,
    remesh_state,
    run_with_restarts,
)

__all__ = [
    "InjectedFailure",
    "RetryPolicy",
    "RunReport",
    "StragglerPolicy",
    "rebalance_ranges",
    "remesh_state",
    "run_with_restarts",
]
