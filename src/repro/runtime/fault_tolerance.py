"""Fault-tolerance runtime: checkpointed training driver with restart, range
re-assignment for stragglers/failures, and elastic re-meshing.

Design for 1000+ nodes (DESIGN.md §8):
- the training driver checkpoints every `ckpt_every` steps (atomic manifest
  commit) and restarts from the last durable state after any failure;
- stream work is assigned as contiguous [start, end) ranges; a failed or
  straggling shard's range is re-issued to survivors (`rebalance_ranges`).
  The StreamSVM ball merge is order-insensitive (commutative fold, property-
  tested), so re-assignment does not change the model class;
- `remesh_state` restores a checkpoint onto a different mesh (elastic scale
  up/down) by re-slicing — sharding lives in the restore target, not the
  checkpoint (see checkpoint/ckpt.py).

The injected-failure test (tests/test_fault_tolerance.py) proves
bit-equivalent recovery: train K steps with a crash at step j == train K
steps without a crash.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, List, Optional, Sequence, Tuple, Type

import jax

from repro.checkpoint import ckpt


class InjectedFailure(RuntimeError):
    pass


class DeviceLostError(RuntimeError):
    """A shard's device disappeared mid-chunk (host preemption, ICI link
    loss, accelerator reset). Always classified as retryable infrastructure
    failure — the work range is re-issued or the process restarts — never as
    a programming error."""


def runtime_device_errors() -> Tuple[Type[BaseException], ...]:
    """The exception classes the JAX/XLA runtime raises for device-level
    faults (e.g. ``jaxlib.xla_extension.XlaRuntimeError`` for a lost or
    wedged device). Import-guarded: on a build without jaxlib (stubbed CI,
    docs env) this returns an empty tuple and callers degrade gracefully.
    """
    errs: List[Type[BaseException]] = []
    try:
        from jaxlib.xla_extension import XlaRuntimeError

        errs.append(XlaRuntimeError)
    except Exception:
        pass
    try:
        from jax.errors import JaxRuntimeError

        errs.append(JaxRuntimeError)
    except Exception:
        pass
    # newer jax aliases one onto the other; keep each class once
    out: List[Type[BaseException]] = []
    for e in errs:
        if e not in out:
            out.append(e)
    return tuple(out)


def default_live_retryable() -> Tuple[Type[BaseException], ...]:
    """Default retryable classes for the live restart driver
    (``repro.live.run_live_with_restarts``): injected test failures, our
    own ``DeviceLostError``, and the JAX/XLA runtime's device-fault
    exceptions — so a transient device fault burns a restart (resume from
    the last durable checkpoint) instead of propagating as if it were a
    programming error."""
    return (InjectedFailure, DeviceLostError) + runtime_device_errors()


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Failure classification + capped exponential backoff, as one object.

    ``retryable`` names the exception classes worth restarting for —
    transient infrastructure faults (preemption, flaky I/O, injected test
    failures). Everything else is treated as a programming error and
    propagates immediately: retrying a ValueError re-raises the same
    ValueError ``max_retries`` times slower.

    ``delay(attempt)`` is ``backoff_base * 2**attempt`` capped at
    ``backoff_cap`` seconds (attempt counts from 0). Both the restart driver
    (``run_with_restarts``) and the live loop's chunk-fetch retry
    (repro.live) share this policy object.
    """

    retryable: Tuple[Type[BaseException], ...] = (InjectedFailure,)
    max_retries: int = 8
    backoff_base: float = 0.05
    backoff_cap: float = 2.0

    def is_retryable(self, exc: BaseException) -> bool:
        return isinstance(exc, self.retryable)

    def delay(self, attempt: int) -> float:
        return min(self.backoff_cap, self.backoff_base * (2.0 ** attempt))


@dataclasses.dataclass
class RunReport:
    steps_done: int
    restarts: int
    metrics: list


def run_with_restarts(
    step_fn: Callable,
    state,
    batches: Sequence,
    *,
    ckpt_dir: str,
    ckpt_every: int = 10,
    fail_at: Optional[Sequence[int]] = None,
    max_restarts: int = 8,
    shardings=None,
    retryable: Sequence[Type[BaseException]] = (InjectedFailure,),
    backoff_base: float = 0.05,
    backoff_cap: float = 2.0,
    sleep: Callable[[float], None] = time.sleep,
) -> Tuple[object, RunReport]:
    """Run `step_fn` over `batches` with checkpoint/restart semantics.

    `fail_at`: steps at which an InjectedFailure fires *after* the step
    executes but *before* its checkpoint would commit — the worst case
    (work lost back to the last checkpoint).

    `retryable` classifies failures: exceptions of these classes restart
    from the last durable checkpoint after a capped exponential backoff
    (``backoff_base * 2**restart``, capped at ``backoff_cap``; ``sleep`` is
    injectable for tests); anything else — a programming error — propagates
    immediately with no restart burned.
    """
    policy = RetryPolicy(
        retryable=tuple(retryable), max_retries=max_restarts,
        backoff_base=backoff_base, backoff_cap=backoff_cap,
    )
    fail_at = set(fail_at or ())
    restarts = 0
    metrics_log: list = []

    while True:
        # resume point
        if ckpt.exists(ckpt_dir):
            meta = ckpt.load_meta(ckpt_dir)
            start = int(meta["step"])
            state = ckpt.restore(ckpt_dir, state, shardings=shardings)
        else:
            start = 0
            ckpt.save(ckpt_dir, state, meta={"step": 0})
        # Steps between the last checkpoint and a crash re-run from `start`:
        # drop their already-logged metrics so RunReport.metrics matches the
        # uninterrupted run exactly (one entry per step, no duplicates).
        del metrics_log[start:]
        try:
            for i in range(start, len(batches)):
                state, m = step_fn(state, batches[i])
                if (i + 1) in fail_at:
                    fail_at.discard(i + 1)
                    raise InjectedFailure(f"injected at step {i + 1}")
                if (i + 1) % ckpt_every == 0 or (i + 1) == len(batches):
                    ckpt.save(ckpt_dir, state, meta={"step": i + 1})
                metrics_log.append(m)
            return state, RunReport(len(batches), restarts, metrics_log)
        except Exception as e:
            if not policy.is_retryable(e):
                raise  # programming error: no restart to burn
            restarts += 1
            if restarts > max_restarts:
                raise
            sleep(policy.delay(restarts - 1))


def rebalance_ranges(
    ranges: List[Tuple[int, int]], dead: Iterable[int], *, grouped: bool = False
):
    """Re-issue dead shards' [start, end) ranges to survivors (round-robin
    splits). Survivor count = len(ranges) - len(dead); each dead range is
    split evenly among survivors, appended to their work queues.

    ``grouped=True`` returns the per-survivor work queues as a dict
    ``{survivor_index: [(lo, hi), ...]}`` (each queue starts with the
    survivor's own range) instead of the flattened list — the form the
    elastic live loop needs to charge re-issued ranges to the surviving
    shard whose fetch channel delivers them."""
    dead = set(dead)
    survivors = [i for i in range(len(ranges)) if i not in dead]
    if not survivors:
        raise ValueError(
            f"rebalance_ranges: all {len(ranges)} shard(s) are dead "
            f"(dead={sorted(dead)}) — no survivors to re-issue ranges to"
        )
    out = {i: [ranges[i]] for i in survivors}
    # sorted(): set iteration order is hash-dependent; the re-issued work
    # queues must be deterministic across processes.
    for d in sorted(dead):
        lo, hi = ranges[d]
        n = len(survivors)
        width = (hi - lo + n - 1) // n
        for j, s in enumerate(survivors):
            a = lo + j * width
            b = min(lo + (j + 1) * width, hi)
            if a < b:
                out[s].append((a, b))
    if grouped:
        return out
    return [r for s in survivors for r in out[s]]


def remesh_state(ckpt_dir: str, target_state, new_mesh, sharding_fn):
    """Elastic rescale: restore onto `new_mesh` with shardings from
    `sharding_fn(target_state, new_mesh)`."""
    shardings = sharding_fn(target_state, new_mesh)
    return ckpt.restore(ckpt_dir, target_state, shardings=shardings)


@dataclasses.dataclass
class StragglerPolicy:
    """Deadline-based straggler mitigation for the streaming fit.

    In a real deployment the controller observes per-shard heartbeats; here
    the policy object carries the decision logic (pure, testable): after
    `deadline_factor` x median shard time, a shard is declared straggling and
    its remaining range re-issued via rebalance_ranges. Because ball merging
    is commutative and idempotent-per-example-set, duplicated suffixes are
    avoided by splitting at the straggler's last-acked position.
    """

    deadline_factor: float = 3.0

    def stragglers(self, elapsed: Sequence[float]) -> List[int]:
        if not elapsed:
            return []
        med = sorted(elapsed)[len(elapsed) // 2]
        return [i for i, t in enumerate(elapsed) if t > self.deadline_factor * max(med, 1e-9)]
