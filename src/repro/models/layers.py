"""Foundational layers: norms, RoPE, blocked (flash) attention, MLPs.

All functions are pure; params are nested dicts of jnp arrays. Compute dtype
follows the input (bf16 in production configs); softmax/norm statistics are
always fp32. Attention is computed with an online-softmax scan over KV blocks
(never materializing (S, S) scores) — required for the 32k prefill and 4k
train shapes to fit HBM, and the TPU-idiomatic replacement for GPU
flash-attention kernels (XLA fuses the scan body; see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def init_dense(key, shape, dtype, scale: float = 1.0):
    import math

    fan_in = shape[0] if len(shape) <= 2 else math.prod(shape[:-1])
    std = scale / max(fan_in, 1) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def rmsnorm(x, w, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, w, b, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, base):
    """x: (..., S, H, hd); positions: (..., S). Rotates pairs (even, odd)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.power(
        jnp.asarray(base, jnp.float32), -jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked attention (online softmax over KV blocks)
# ---------------------------------------------------------------------------


def _block_mask(q_pos, k_pos, causal: bool, window):
    """(Sq, Sk) additive mask block from absolute positions."""
    m = jnp.zeros((q_pos.shape[0], k_pos.shape[0]), jnp.float32)
    rel = q_pos[:, None] - k_pos[None, :]
    if causal:
        m = jnp.where(rel < 0, _NEG_INF, m)
    if window is not None:
        m = jnp.where(rel >= window, _NEG_INF, m)
    return m


def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, KV, hd)
    v: jax.Array,  # (B, Sk, KV, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,  # absolute position of q[0] (decode: cache length)
    kv_valid_len=None,  # mask kv positions >= this (cache decode)
    block_kv: int = 1024,
) -> jax.Array:
    """Grouped-query blocked attention; returns (B, Sq, H, hd).

    Scans KV blocks with an online-softmax carry (m, l, acc): peak memory is
    O(Sq * block_kv) per head instead of O(Sq * Sk).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / (hd**0.5)

    pad = (-Sk) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Skp = Sk + pad
    n_blocks = Skp // block_kv

    qg = q.reshape(B, Sq, KV, G, hd)
    q_pos = q_offset + jnp.arange(Sq)
    kv_limit = jnp.asarray(Sk if kv_valid_len is None else kv_valid_len)

    kb = k.reshape(B, n_blocks, block_kv, KV, hd)
    vb = v.reshape(B, n_blocks, block_kv, KV, hd)

    def body2(carry, inp):
        m, l, acc = carry
        blk_idx, kblk, vblk = inp
        k_pos = blk_idx * block_kv + jnp.arange(block_kv)
        s = jnp.einsum(
            "bqkgd,bskd->bkgqs", qg, kblk, preferred_element_type=jnp.float32
        ) * scale
        mask = _block_mask(q_pos, k_pos, causal, window)
        mask = jnp.where(k_pos[None, :] >= kv_limit, _NEG_INF, mask)
        s = s + mask
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, G, Sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0)  # (n_blocks, B, block, KV, hd)
    vb_t = jnp.moveaxis(vb, 1, 0)
    # checkpoint the block body: backward recomputes the (Sq, block_kv)
    # probability tile instead of saving one per block (the dominant
    # activation cost at 4k/32k sequence lengths).
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body2, prevent_cse=False),
        (m0, l0, a0), (jnp.arange(n_blocks), kb_t, vb_t)
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(B, KV, G, Sq, hd)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def direct_attention(
    q, k, v, *, causal=True, window=None, q_offset=0, kv_valid_len=None
):
    """Unblocked attention for tiny Sq (decode): scores materialize as
    (B, KV, G, Sq, Sk). With the KV cache sequence-sharded over `model`,
    GSPMD turns the softmax/PV reductions into tiny cross-shard
    all-reduces — the flash-decoding pattern, for free."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32)
    s = s / (hd**0.5)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = _block_mask(q_pos, k_pos, causal, window)
    if kv_valid_len is not None:
        mask = jnp.where(k_pos[None, :] >= kv_valid_len, _NEG_INF, mask)
    s = s + mask
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + cache handling)
# ---------------------------------------------------------------------------


def attn_init(key, d_model, n_heads, n_kv, hd, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_dense(k1, (d_model, n_heads, hd), dtype),
        "wk": init_dense(k2, (d_model, n_kv, hd), dtype),
        "wv": init_dense(k3, (d_model, n_kv, hd), dtype),
        "wo": init_dense(k4, (n_heads, hd, d_model), dtype),
    }


def attn_apply(
    p,
    x,
    *,
    rope_base=None,
    causal=True,
    window=None,
    kv_x=None,  # cross attention source
    cache=None,  # dict(k, v) fixed-size buffers
    cache_pos=None,  # scalar: current length (decode write position)
    block_kv: int = 1024,
):
    """Returns (out, new_cache). x: (B, S, D)."""
    B, S, D = x.shape
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])

    if cache is None:
        q_offset = 0
        if rope_base is not None:
            pos = jnp.arange(S)
            q = rope(q, pos, rope_base)
            k = rope(k, pos, rope_base)
        out = flash_attention(
            q, k, v, causal=causal, window=window, block_kv=min(block_kv, k.shape[1]),
        )
        new_cache = None
    else:
        # decode: write new k/v at cache_pos, attend over the whole buffer
        if rope_base is not None:
            pos = cache_pos + jnp.arange(S)
            q = rope(q, pos, rope_base)
            k = rope(k, pos, rope_base)
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
        if S == 1:  # decode: direct attention (flash-decoding via GSPMD)
            out = direct_attention(
                q, ck, cv, causal=causal, window=window,
                q_offset=cache_pos, kv_valid_len=cache_pos + S,
            )
        else:
            out = flash_attention(
                q, ck, cv,
                causal=causal, window=window, q_offset=cache_pos,
                kv_valid_len=cache_pos + S,
                block_kv=min(block_kv, ck.shape[1]),
            )
        new_cache = {"k": ck, "v": cv}

    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, d_model, d_ff, kind, dtype):
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w1": init_dense(ks[0], (d_model, d_ff), dtype),
            "w3": init_dense(ks[1], (d_model, d_ff), dtype),
            "w2": init_dense(ks[2], (d_ff, d_model), dtype),
        }
    return {
        "w1": init_dense(ks[0], (d_model, d_ff), dtype),
        "w2": init_dense(ks[2], (d_ff, d_model), dtype),
    }


def mlp_apply(p, x, kind: str):
    h = x @ p["w1"]
    if kind == "swiglu":
        h = jax.nn.silu(h) * (x @ p["w3"])
    elif kind == "geglu":
        h = jax.nn.gelu(h) * (x @ p["w3"])
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    elif kind == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(kind)
    return h @ p["w2"]


def cross_entropy(logits, targets, ignore_index: int = -1):
    """Mean CE over valid targets. logits: (..., V) any float dtype.

    The picked-logit term uses a one-hot contraction rather than
    take_along_axis: with the vocab dim sharded over `model`, the gather
    would force an all-gather of fp32 logits (GBs/device at 4k x 256); the
    contraction reduces locally and all-reduces a scalar per token.
    """
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    tgt = jnp.maximum(targets, 0)
    V = logits.shape[-1]
    eq = jnp.arange(V)[None, None, :] == tgt[..., None]  # pred, fuses
    picked = jnp.sum(jnp.where(eq, logits32, 0.0), axis=-1)
    nll = lse - picked
    mask = (targets != ignore_index).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
