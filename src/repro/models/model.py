"""Model registry/dispatch: build_model(cfg) -> model object with the shared
API (init / loss / prefill / decode_step / init_cache)."""
from __future__ import annotations

from repro.configs.base import ArchConfig
from .families import EncDecModel, XLSTMModel, Zamba2Model
from .transformer import DecoderLM


def build_model(cfg: ArchConfig, remat: str = "none"):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg, remat=remat)
    if cfg.family == "hybrid":
        return Zamba2Model(cfg, remat=remat)
    if cfg.family == "ssm":
        return XLSTMModel(cfg, remat=remat)
    if cfg.family == "encdec":
        return EncDecModel(cfg, remat=remat)
    raise ValueError(cfg.family)
