"""Mamba2 mixer (SSD — state-space duality form), for zamba2.

Training uses the chunked SSD algorithm: intra-chunk quadratic term +
inter-chunk state recurrence (lax.scan over chunks); decode is the O(1)
recurrent update. Single B/C group (n_groups=1), per-head scalar A, D skip,
causal depthwise conv on the xBC path — the standard minimal-Mamba2 layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import init_dense, rmsnorm


def mamba_init(key, d_model: int, ssm, dtype):
    d_in = ssm.expand * d_model
    n_heads = d_in // ssm.head_dim
    n = ssm.d_state
    ks = jax.random.split(key, 5)
    d_proj = 2 * d_in + 2 * n + n_heads  # z, xBC, dt
    return {
        "in_proj": init_dense(ks[0], (d_model, d_proj), dtype),
        "conv_w": init_dense(ks[1], (ssm.d_conv, d_in + 2 * n), dtype, scale=3.0),
        "conv_b": jnp.zeros((d_in + 2 * n,), dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": init_dense(ks[2], (d_in, d_model), dtype),
    }


def _causal_conv(x, w, b):
    """x: (B, S, C); w: (K, C) depthwise; left-padded causal."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _segsum(a):
    """a: (..., L) -> (..., L, L) lower-tri sums: out[t, s] = sum_{s<j<=t} a[j]."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(tri, diff, -jnp.inf)


def ssd_chunked(x, dt, A_log, B, C, chunk: int, init_state=None):
    """x: (b,s,h,p) pre-discretization; dt: (b,s,h) post-softplus;
    B, C: (b,s,n). Returns (y (b,s,h,p), final_state (b,h,p,n))."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    c = s // chunk
    A = -jnp.exp(A_log)  # (h,)
    dA = dt * A  # (b,s,h)
    xdt = x * dt[..., None]  # discretized input

    # chunked views
    dA_c = dA.reshape(b, c, chunk, h).transpose(0, 1, 3, 2)  # (b,c,h,l)
    x_c = xdt.reshape(b, c, chunk, h, p)
    B_c = B.reshape(b, c, chunk, n)
    C_c = C.reshape(b, c, chunk, n)

    A_cs = jnp.cumsum(dA_c, axis=-1)  # (b,c,h,l)

    # intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dA_c))  # (b,c,h,l,l)
    Y_diag = jnp.einsum("bcln,bcsn,bchls,bcshp->bclhp", C_c, B_c, L, x_c)

    # per-chunk states
    decay_states = jnp.exp(A_cs[..., -1:] - A_cs)  # (b,c,h,l)
    states = jnp.einsum("bcln,bchl,bclhp->bchpn", B_c, decay_states, x_c)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(A_cs[..., -1])  # (b,c,h)
    s0 = (
        jnp.zeros((b, h, p, n), x.dtype)
        if init_state is None
        else init_state.astype(x.dtype)
    )

    def scan_body(carry, inp):
        st = carry
        dec, snew = inp  # (b,h), (b,h,p,n)
        st_next = st * dec[..., None, None] + snew
        return st_next, st  # emit the state *entering* this chunk

    cd_t = jnp.moveaxis(chunk_decay, 1, 0)  # (c,b,h)
    st_t = jnp.moveaxis(states, 1, 0)  # (c,b,h,p,n)
    final_state, prev_states = jax.lax.scan(scan_body, s0, (cd_t, st_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,c,h,p,n)

    state_decay_out = jnp.exp(A_cs)  # (b,c,h,l)
    Y_off = jnp.einsum("bcln,bchpn,bchl->bclhp", C_c, prev_states, state_decay_out)

    y = (Y_diag + Y_off).reshape(b, s, h, p)
    return y, final_state


def mamba_apply(p, x, ssm, *, state=None, conv_state=None):
    """Full-sequence mixer. x: (B, S, D). Returns (out, (state, conv_state)).

    When `state`/`conv_state` are given, continues from them (decode uses
    S=1 via the same path; chunk handling degrades to a single chunk).
    """
    Bsz, S, D = x.shape
    d_in = ssm.expand * D
    h = d_in // ssm.head_dim
    n = ssm.d_state

    proj = x @ p["in_proj"]  # (B,S,2*d_in+2n+h)
    z, xBC, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * n], axis=-1)

    K1 = p["conv_w"].shape[0] - 1  # conv history length
    hist = xBC if conv_state is None else jnp.concatenate([conv_state, xBC], axis=1)
    if conv_state is None:
        conv_out = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    else:
        conv_out = _causal_conv(hist, p["conv_w"], p["conv_b"])[:, -S:, :]
    # new conv state = last K1 raw inputs (zero-padded when the seq is short)
    pad = max(0, K1 - hist.shape[1])
    hist_p = jnp.pad(hist, ((0, 0), (pad, 0), (0, 0)))
    new_conv_state = hist_p[:, hist_p.shape[1] - K1 :, :]
    xBC_a = jax.nn.silu(conv_out)
    x_in, B_, C_ = jnp.split(xBC_a, [d_in, d_in + n], axis=-1)
    x_h = x_in.reshape(Bsz, S, h, ssm.head_dim)
    dt_s = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,h)

    if S % ssm.chunk == 0 and S > 1:
        y, new_state = ssd_chunked(
            x_h.astype(jnp.float32), dt_s, p["A_log"],
            B_.astype(jnp.float32), C_.astype(jnp.float32),
            ssm.chunk, init_state=state,
        )
    else:
        # sequential fallback (decode / odd lengths): scan over time
        A = -jnp.exp(p["A_log"])  # (h,)

        def step(st, inp):
            xt, dtt, Bt, Ct = inp  # (B,h,p), (B,h), (B,n), (B,n)
            dA = jnp.exp(dtt * A)  # (B,h)
            st = st * dA[..., None, None] + jnp.einsum(
                "bhp,bn->bhpn", xt * dtt[..., None], Bt
            )
            yt = jnp.einsum("bhpn,bn->bhp", st, Ct)
            return st, yt

        s0 = (
            jnp.zeros((Bsz, h, ssm.head_dim, n), jnp.float32)
            if state is None
            else state.astype(jnp.float32)
        )
        xs = (
            jnp.moveaxis(x_h.astype(jnp.float32), 1, 0),
            jnp.moveaxis(dt_s, 1, 0),
            jnp.moveaxis(B_.astype(jnp.float32), 1, 0),
            jnp.moveaxis(C_.astype(jnp.float32), 1, 0),
        )
        new_state, y_t = jax.lax.scan(step, s0, xs)
        y = jnp.moveaxis(y_t, 0, 1)  # (B,S,h,p)

    y = y + x_h.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(Bsz, S, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"])
    out = y @ p["out_proj"]
    return out, (new_state, new_conv_state)
