"""Model classes for the hybrid (zamba2), ssm (xlstm) and encdec (whisper)
families — same API as DecoderLM (loss / prefill / decode_step / init_cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.sharding.hints import shard_hint
from .layers import (
    attn_apply,
    attn_init,
    cross_entropy,
    init_dense,
    mlp_apply,
    mlp_init,
    rmsnorm,
)
from .mamba2 import mamba_apply, mamba_init
from .xlstm import mlstm_block, mlstm_init, slstm_block, slstm_init


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------------------
# Zamba2 — mamba2 backbone + one shared attention block every k layers
# ---------------------------------------------------------------------------


class Zamba2Model:
    """Shared transformer block (attn+mlp, single set of weights) applied
    before every `shared_attn_every`-th mamba2 layer. Each *application* has
    its own KV cache. Simplification vs the published model: the shared block
    consumes the hidden state directly (no concat-with-embedding projector);
    recorded in DESIGN.md."""

    def __init__(self, cfg: ArchConfig, remat: str = "none"):
        self.cfg = cfg
        self.remat = remat
        self.dtype = _dtype(cfg.param_dtype)
        self.n_shared = len(self._shared_sites())

    def _shared_sites(self):
        every = self.cfg.shared_attn_every
        return [i for i in range(self.cfg.n_layers) if every and i % every == 0]

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, cfg.n_layers + 4)
        params = {
            "embed": init_dense(ks[0], (cfg.vocab, cfg.d_model), self.dtype),
            "mamba": [
                {"ln": jnp.zeros((cfg.d_model,), self.dtype),
                 "mix": mamba_init(ks[1 + i], cfg.d_model, cfg.ssm, self.dtype)}
                for i in range(cfg.n_layers)
            ],
            "shared": {
                "ln1": jnp.zeros((cfg.d_model,), self.dtype),
                "attn": attn_init(ks[-3], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, self.dtype),
                "ln2": jnp.zeros((cfg.d_model,), self.dtype),
                "mlp": mlp_init(ks[-2], cfg.d_model, cfg.d_ff, cfg.mlp, self.dtype),
            },
            "final_norm": jnp.zeros((cfg.d_model,), self.dtype),
            "unembed": init_dense(ks[-1], (cfg.d_model, cfg.vocab), self.dtype),
        }
        return params

    def _shared_block(self, p, h, cache=None, cache_pos=None):
        a, nc = attn_apply(
            p["attn"], rmsnorm(h, p["ln1"], self.cfg.norm_eps),
            rope_base=self.cfg.rope_base, causal=True,
            cache=cache, cache_pos=cache_pos,
        )
        h = h + a
        h = h + mlp_apply(p["mlp"], rmsnorm(h, p["ln2"], self.cfg.norm_eps), self.cfg.mlp)
        return h, nc

    def _forward(self, params, h, caches=None, cache_pos=None):
        """caches: dict(kv=[per-site], ssm=[per-layer], conv=[per-layer])."""
        cfg = self.cfg
        sites = set(self._shared_sites())
        new_kv, new_ssm, new_conv = [], [], []
        si = 0
        for i in range(cfg.n_layers):
            if i in sites:
                c = None if caches is None else jax.tree.map(lambda a: a[si], caches["kv"])
                h, nc = self._shared_block(params["shared"], h, cache=c, cache_pos=cache_pos)
                if nc is not None:
                    new_kv.append(nc)
                si += 1
            st = None if caches is None else caches["ssm"][i]
            cv = None if caches is None else caches["conv"][i]

            def mamba_layer(lp, hh, st=st, cv=cv):
                return mamba_apply(
                    lp["mix"], rmsnorm(hh, lp["ln"], cfg.norm_eps),
                    cfg.ssm, state=st, conv_state=cv,
                )

            if self.remat != "none" and caches is None:
                mamba_layer = jax.checkpoint(mamba_layer, prevent_cse=False)
            out, (nst, ncv) = mamba_layer(params["mamba"][i], h)
            h = h + out
            new_ssm.append(nst)
            new_conv.append(ncv)
        new_caches = None
        if caches is not None or new_kv:
            new_caches = {
                "kv": jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv) if new_kv else None,
                "ssm": new_ssm,
                "conv": new_conv,
            }
        return h, new_caches

    def _logits(self, params, h):
        logits = jnp.einsum(
            "bsd,dv->bsv", rmsnorm(h, params["final_norm"], self.cfg.norm_eps),
            params["unembed"],
        )
        # vocab-sharded logits (same fix as DecoderLM; EXPERIMENTS §Perf H2b)
        return shard_hint(logits, ("dp", None, "tp"))

    def loss(self, params, batch):
        h = params["embed"][batch["tokens"]]
        h, _ = self._forward(params, h)
        ce = cross_entropy(self._logits(params, h), batch["targets"])
        return ce, {"ce": ce, "aux": 0.0}

    def init_cache(self, batch_size: int, max_len: int):
        cfg = self.cfg
        d_in = cfg.ssm.expand * cfg.d_model
        nh = d_in // cfg.ssm.head_dim
        return {
            "kv": {
                "k": jnp.zeros((self.n_shared, batch_size, max_len, cfg.n_kv_heads, cfg.hd), self.dtype),
                "v": jnp.zeros((self.n_shared, batch_size, max_len, cfg.n_kv_heads, cfg.hd), self.dtype),
            },
            "ssm": [
                jnp.zeros((batch_size, nh, cfg.ssm.head_dim, cfg.ssm.d_state), jnp.float32)
                for _ in range(cfg.n_layers)
            ],
            "conv": [
                jnp.zeros((batch_size, cfg.ssm.d_conv - 1, d_in + 2 * cfg.ssm.d_state), self.dtype)
                for _ in range(cfg.n_layers)
            ],
        }

    def prefill(self, params, batch):
        tokens = batch["tokens"]
        B, S = tokens.shape
        h = params["embed"][tokens]
        caches = self.init_cache(B, batch.get("max_len", S))
        h, caches = self._forward(params, h, caches=caches, cache_pos=0)
        logits = self._logits(params, h[:, -1:])
        return logits[:, 0], {"c": caches, "pos": jnp.asarray(S, jnp.int32)}

    def decode_step(self, params, cache, tokens):
        h = params["embed"][tokens]
        h, caches = self._forward(params, h, caches=cache["c"], cache_pos=cache["pos"])
        logits = self._logits(params, h)
        return logits[:, 0], {"c": caches, "pos": cache["pos"] + tokens.shape[1]}

    def decode_state(self, batch_size: int, max_len: int):
        return {
            "c": self.init_cache(batch_size, max_len),
            "pos": jnp.asarray(max_len - 1, jnp.int32),
        }


# ---------------------------------------------------------------------------
# xLSTM
# ---------------------------------------------------------------------------


class XLSTMModel:
    def __init__(self, cfg: ArchConfig, remat: str = "none"):
        self.cfg = cfg
        self.remat = remat
        self.dtype = _dtype(cfg.param_dtype)

    def _is_slstm(self, i: int) -> bool:
        e = self.cfg.slstm_every
        return bool(e) and (i % e == e - 1)

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, cfg.n_layers + 3)
        blocks = []
        for i in range(cfg.n_layers):
            if self._is_slstm(i):
                blocks.append(slstm_init(ks[i], cfg.d_model, cfg.n_heads, self.dtype))
            else:
                blocks.append(mlstm_init(ks[i], cfg.d_model, cfg.n_heads, self.dtype))
        return {
            "embed": init_dense(ks[-2], (cfg.vocab, cfg.d_model), self.dtype),
            "blocks": blocks,
            "final_norm": jnp.zeros((cfg.d_model,), self.dtype),
            "unembed": init_dense(ks[-1], (cfg.d_model, cfg.vocab), self.dtype),
        }

    def _forward(self, params, h, states=None):
        cfg = self.cfg
        new_states = []
        use_remat = self.remat != "none" and states is None
        for i in range(cfg.n_layers):
            st = None if states is None else states[i]
            if self._is_slstm(i):
                blk = slstm_block
                if use_remat:
                    blk = jax.checkpoint(blk, static_argnums=(2,), prevent_cse=False)
                h, ns = blk(params["blocks"][i], h, cfg.n_heads, state=st)
            else:
                mst = None if st is None else st[0]
                cst = None if st is None else st[1]
                blk = mlstm_block
                if use_remat:
                    blk = jax.checkpoint(blk, static_argnums=(2,), prevent_cse=False)
                h, (ns_m, ns_c) = blk(
                    params["blocks"][i], h, cfg.n_heads, state=mst, conv_state=cst
                )
                ns = (ns_m, ns_c)
            new_states.append(ns)
        return h, new_states

    def _logits(self, params, h):
        logits = jnp.einsum(
            "bsd,dv->bsv", rmsnorm(h, params["final_norm"], self.cfg.norm_eps),
            params["unembed"],
        )
        return shard_hint(logits, ("dp", None, "tp"))

    def loss(self, params, batch):
        h = params["embed"][batch["tokens"]]
        h, _ = self._forward(params, h)
        ce = cross_entropy(self._logits(params, h), batch["targets"])
        return ce, {"ce": ce, "aux": 0.0}

    def init_cache(self, batch_size: int, max_len: int):
        cfg = self.cfg
        B = batch_size
        d_in = 2 * cfg.d_model
        hd = d_in // cfg.n_heads
        states = []
        for i in range(cfg.n_layers):
            if self._is_slstm(i):
                states.append(
                    (
                        jnp.zeros((B, cfg.d_model), jnp.float32),
                        jnp.ones((B, cfg.d_model), jnp.float32),
                        jnp.zeros((B, cfg.n_heads), jnp.float32),
                        jnp.zeros((B, cfg.d_model), jnp.float32),
                    )
                )
            else:
                states.append(
                    (
                        (
                            jnp.zeros((B, cfg.n_heads, hd, hd), jnp.float32),
                            jnp.zeros((B, cfg.n_heads, hd), jnp.float32),
                            jnp.zeros((B, cfg.n_heads), jnp.float32),
                        ),
                        jnp.zeros((B, 3, d_in), self.dtype),
                    )
                )
        return states

    def prefill(self, params, batch):
        tokens = batch["tokens"]
        B, S = tokens.shape
        h = params["embed"][tokens]
        states = self.init_cache(B, 0)
        h, states = self._forward(params, h, states=states)
        logits = self._logits(params, h[:, -1:])
        return logits[:, 0], {"c": states, "pos": jnp.asarray(S, jnp.int32)}

    def decode_step(self, params, cache, tokens):
        h = params["embed"][tokens]
        h, states = self._forward(params, h, states=cache["c"])
        logits = self._logits(params, h)
        return logits[:, 0], {"c": states, "pos": cache["pos"] + tokens.shape[1]}

    def decode_state(self, batch_size: int, max_len: int):
        # constant-size recurrent state: max_len only sets the position
        return {
            "c": self.init_cache(batch_size, 0),
            "pos": jnp.asarray(max_len - 1, jnp.int32),
        }


# ---------------------------------------------------------------------------
# Whisper (enc-dec); conv audio frontend is a stub — `frames` arrive as
# precomputed (B, encoder_seq, d_model) embeddings per the assignment.
# ---------------------------------------------------------------------------


def _sinusoid(S, D):
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    i = jnp.arange(D // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / D)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class EncDecModel:
    def __init__(self, cfg: ArchConfig, remat: str = "none"):
        self.cfg = cfg
        self.remat = remat
        self.dtype = _dtype(cfg.param_dtype)

    def _enc_layer_init(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": jnp.zeros((cfg.d_model,), self.dtype),
            "attn": attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, self.dtype),
            "ln2": jnp.zeros((cfg.d_model,), self.dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp, self.dtype),
        }

    def _dec_layer_init(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        p = self._enc_layer_init(jax.random.fold_in(key, 7))
        p["ln_x"] = jnp.zeros((cfg.d_model,), self.dtype)
        p["xattn"] = attn_init(k3, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, self.dtype)
        return p

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, cfg.n_encoder_layers + cfg.n_layers + 3)
        return {
            "enc_layers": [self._enc_layer_init(ks[i]) for i in range(cfg.n_encoder_layers)],
            "enc_norm": jnp.zeros((cfg.d_model,), self.dtype),
            "embed": init_dense(ks[-2], (cfg.vocab, cfg.d_model), self.dtype),
            "dec_layers": [
                self._dec_layer_init(ks[cfg.n_encoder_layers + i]) for i in range(cfg.n_layers)
            ],
            "final_norm": jnp.zeros((cfg.d_model,), self.dtype),
            "unembed": init_dense(ks[-1], (cfg.d_model, cfg.vocab), self.dtype),
        }

    def encode(self, params, frames):
        cfg = self.cfg
        h = frames.astype(self.dtype) + _sinusoid(frames.shape[1], cfg.d_model).astype(self.dtype)

        def enc_layer(p, hh):
            a, _ = attn_apply(p["attn"], rmsnorm(hh, p["ln1"], cfg.norm_eps), causal=False)
            hh = hh + a
            return hh + mlp_apply(p["mlp"], rmsnorm(hh, p["ln2"], cfg.norm_eps), cfg.mlp)

        if self.remat != "none":
            enc_layer = jax.checkpoint(enc_layer, prevent_cse=False)
        for p in params["enc_layers"]:
            h = enc_layer(p, h)
        return rmsnorm(h, params["enc_norm"], cfg.norm_eps)

    def _decoder(self, params, h, enc_out, caches=None, cache_pos=None, pos0=0):
        cfg = self.cfg
        S = h.shape[1]
        pos = _sinusoid(65536, cfg.d_model)
        start = pos0 if cache_pos is None else cache_pos
        h = h + jax.lax.dynamic_slice_in_dim(pos, start, S, 0).astype(h.dtype)
        new_kv = []

        def dec_layer(p, hh, c):
            a, nc = attn_apply(
                p["attn"], rmsnorm(hh, p["ln1"], cfg.norm_eps),
                causal=True, cache=c, cache_pos=cache_pos,
            )
            hh = hh + a
            x, _ = attn_apply(
                p["xattn"], rmsnorm(hh, p["ln_x"], cfg.norm_eps),
                causal=False, kv_x=enc_out,
            )
            hh = hh + x
            return hh + mlp_apply(p["mlp"], rmsnorm(hh, p["ln2"], cfg.norm_eps), cfg.mlp), nc

        layer_fn = dec_layer
        if self.remat != "none" and caches is None:
            layer_fn = jax.checkpoint(dec_layer, prevent_cse=False)
        for i, p in enumerate(params["dec_layers"]):
            c = None if caches is None else jax.tree.map(lambda a: a[i], caches)
            h, nc = layer_fn(p, h, c)
            if nc is not None:
                new_kv.append(nc)
        nc_st = jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv) if new_kv else None
        return h, nc_st

    def _logits(self, params, h):
        # NOTE: the vocab-shard hint (H2) measurably HURT here (61->73 GB):
        # the enc-dec step's temp is dominated by cross-attention residuals,
        # and the hint only adds reshard traffic. Left unhinted (H2b).
        return jnp.einsum(
            "bsd,dv->bsv", rmsnorm(h, params["final_norm"], self.cfg.norm_eps),
            params["unembed"],
        )

    def loss(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        h = params["embed"][batch["tokens"]]
        h, _ = self._decoder(params, h, enc_out)
        ce = cross_entropy(self._logits(params, h), batch["targets"])
        return ce, {"ce": ce, "aux": 0.0}

    def init_cache(self, batch_size: int, max_len: int):
        cfg = self.cfg
        shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shape, self.dtype), "v": jnp.zeros(shape, self.dtype)}

    def prefill(self, params, batch):
        tokens = batch["tokens"]
        B, S = tokens.shape
        enc_out = self.encode(params, batch["frames"])
        h = params["embed"][tokens]
        kv = self.init_cache(B, batch.get("max_len", S))
        h, kv = self._decoder(params, h, enc_out, caches=kv, cache_pos=0)
        logits = self._logits(params, h[:, -1:])
        return logits[:, 0], {"kv": kv, "enc": enc_out, "pos": jnp.asarray(S, jnp.int32)}

    def decode_step(self, params, cache, tokens):
        h = params["embed"][tokens]
        h, kv = self._decoder(
            params, h, cache["enc"], caches=cache["kv"], cache_pos=cache["pos"]
        )
        logits = self._logits(params, h)
        return logits[:, 0], {"kv": kv, "enc": cache["enc"], "pos": cache["pos"] + tokens.shape[1]}

    def decode_state(self, batch_size: int, max_len: int):
        cfg = self.cfg
        return {
            "kv": self.init_cache(batch_size, max_len),
            "enc": jnp.zeros((batch_size, cfg.encoder_seq, cfg.d_model), self.dtype),
            "pos": jnp.asarray(max_len - 1, jnp.int32),
        }
