"""Decoder-only transformer LM covering the dense / moe / vlm families.

Uniform layer stacks use lax.scan over stacked per-layer params (compact HLO
for 96-layer models) with optional jax.checkpoint around the body; per-layer
heterogeneity (gemma3's 5:1 local:global pattern, dual RoPE bases) rides
along as scanned (L,)-shaped metadata so the body stays uniform. Small /
heterogeneous archs use a python loop (cfg.unrolled).

API (shared by every model class in this package):
  init(key) -> params
  loss(params, batch) -> (scalar, metrics)
  prefill(params, batch) -> (last_logits, cache)
  decode_step(params, cache, tokens) -> (logits, cache)
  init_cache(batch_size, max_len) -> cache (abstract-friendly)
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.sharding.hints import shard_hint
from .layers import (
    attn_apply,
    attn_init,
    cross_entropy,
    init_dense,
    mlp_apply,
    mlp_init,
    rmsnorm,
)
from .moe import moe_apply, moe_init

_NO_WINDOW = 1 << 30


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


class DecoderLM:
    def __init__(self, cfg: ArchConfig, remat: str = "none"):
        self.cfg = cfg
        self.remat = remat
        self.dtype = _dtype(cfg.param_dtype)

    # -- params ------------------------------------------------------------
    def _layer_init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        p = {
            "ln1": jnp.zeros((cfg.d_model,), self.dtype),
            "attn": attn_init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, self.dtype),
            "ln2": jnp.zeros((cfg.d_model,), self.dtype),
        }
        if cfg.moe is not None:
            p["moe"] = moe_init(ks[1], cfg.d_model, cfg.moe, self.dtype)
        else:
            p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp, self.dtype)
        return p

    def init(self, key):
        cfg = self.cfg
        kemb, klayers, kout = jax.random.split(key, 3)
        if cfg.unrolled:
            layer_keys = jax.random.split(klayers, cfg.n_layers)
            layers = [self._layer_init(k) for k in layer_keys]
        else:
            layer_keys = jax.random.split(klayers, cfg.n_layers)
            layers = jax.vmap(self._layer_init)(layer_keys)
        params = {
            "embed": init_dense(kemb, (cfg.vocab, cfg.d_model), self.dtype),
            "layers": layers,
            "final_norm": jnp.zeros((cfg.d_model,), self.dtype),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = init_dense(kout, (cfg.d_model, cfg.vocab), self.dtype)
        return params

    # -- per-layer meta (gemma3 local/global pattern) ------------------------
    def _layer_meta(self):
        cfg = self.cfg
        L = cfg.n_layers
        idx = jnp.arange(L)
        if cfg.global_every:
            is_global = (idx + 1) % cfg.global_every == 0
        else:
            is_global = jnp.ones((L,), bool) if cfg.window is None else jnp.zeros((L,), bool)
        window = jnp.where(
            is_global, _NO_WINDOW, cfg.window if cfg.window is not None else _NO_WINDOW
        )
        base_g = cfg.rope_base_global if cfg.rope_base_global else cfg.rope_base
        ropeb = jnp.where(is_global, base_g, cfg.rope_base)
        return window.astype(jnp.int32), ropeb.astype(jnp.float32)

    # -- blocks --------------------------------------------------------------
    def _block(self, p, x, window, rope_base, cache=None, cache_pos=None):
        cfg = self.cfg
        h, new_cache = attn_apply(
            p["attn"],
            rmsnorm(x, p["ln1"], cfg.norm_eps),
            rope_base=rope_base,
            causal=True,
            window=window,
            cache=cache,
            cache_pos=cache_pos,
        )
        x = x + h
        hin = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            h2, aux = moe_apply(p["moe"], hin, cfg.moe)
        else:
            h2, aux = mlp_apply(p["mlp"], hin, cfg.mlp), 0.0
        return x + h2, new_cache, aux

    # -- forward -------------------------------------------------------------
    def _embed(self, params, batch):
        cfg = self.cfg
        h = params["embed"][batch["tokens"]]  # (B, S, D)
        if cfg.tie_embeddings:
            h = h * jnp.asarray(cfg.d_model**0.5, h.dtype)
        if cfg.family == "vlm" and "image_embeds" in batch:
            P = batch["image_embeds"].shape[1]
            h = jax.lax.dynamic_update_slice(
                h, batch["image_embeds"].astype(h.dtype), (0, 0, 0)
            )
        return h

    def _unembed(self, params, h):
        cfg = self.cfg
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        logits = jnp.einsum("bsd,dv->bsv", h, w)
        # Keep logits vocab-sharded: for tied embeddings the contraction runs
        # over the model-sharded d_model, and without this hint GSPMD emits
        # REPLICATED (B, S, V) logits — 68 GB/device f32 at gemma3's 262k
        # vocab (EXPERIMENTS.md §Perf H2: 224 GB -> fits).
        return shard_hint(logits, ("dp", None, "tp"))

    def _stack(self, params, h, cache=None, cache_pos=None):
        """Run all layers. Returns (h, new_cache, aux_sum)."""
        cfg = self.cfg
        window, ropeb = self._layer_meta()
        if cfg.unrolled:
            new_caches = []
            aux = 0.0
            for i in range(cfg.n_layers):
                c = None if cache is None else jax.tree.map(lambda a: a[i], cache)
                h, nc, a = self._block(
                    params["layers"][i], h, int(window[i]), float(ropeb[i]),
                    cache=c, cache_pos=cache_pos,
                )
                aux = aux + a
                if nc is not None:
                    new_caches.append(nc)
            nc_st = (
                jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
                if new_caches
                else None
            )
            return h, nc_st, aux

        def body(carry, xs):
            h = carry
            if cache is None:
                lp, win, rb = xs
                c = None
            else:
                lp, win, rb, c = xs
            # Sequence parallelism: layer-boundary activations shard over
            # (dp, tp) — at 340B/4k this moves the saved-for-backward
            # boundaries from 151 MB to 9.4 MB per layer per device
            # (EXPERIMENTS.md §Perf H1). GSPMD inserts the all-gather /
            # reduce-scatter pair around attention/MLP automatically.
            # NOT for MoE: the dispatch sort wants tokens dp-sharded only;
            # a seq-sharded boundary forces ~10x collective volume
            # (refuted sub-hypothesis H1b, EXPERIMENTS.md §Perf).
            seq_par = cache is None and self.cfg.moe is None
            if seq_par:
                h = shard_hint(h, ("dp", "tp", None))
            h, nc, a = self._block(lp, h, win, rb, cache=c, cache_pos=cache_pos)
            if seq_par:
                h = shard_hint(h, ("dp", "tp", None))
            return h, (nc, a)

        if self.remat != "none":
            policy = (
                None
                if self.remat == "full"
                else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )
            body = jax.checkpoint(body, policy=policy, prevent_cse=False)

        xs = (params["layers"], window, ropeb)
        if cache is not None:
            xs = xs + (cache,)
        h, (new_cache, aux) = jax.lax.scan(body, h, xs)
        return h, new_cache, jnp.sum(aux) if cfg.moe is not None else 0.0

    # -- public API ------------------------------------------------------------
    def loss(self, params, batch):
        h = self._embed(params, batch)
        h, _, aux = self._stack(params, h)
        logits = self._unembed(params, h)
        targets = batch["targets"]
        if self.cfg.family == "vlm" and "image_embeds" in batch:
            P = batch["image_embeds"].shape[1]
            pos = jnp.arange(targets.shape[1])[None, :]
            targets = jnp.where(pos < P, -1, targets)
        ce = cross_entropy(logits, targets)
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    def init_cache(self, batch_size: int, max_len: int):
        cfg = self.cfg
        shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.hd)
        return {
            "k": jnp.zeros(shape, self.dtype),
            "v": jnp.zeros(shape, self.dtype),
        }

    def prefill(self, params, batch):
        """Full forward building the cache; returns (last_logits, cache)."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        h = self._embed(params, batch)
        cache0 = self.init_cache(B, batch.get("max_len", S))
        h, cache, _ = self._stack(params, h, cache=cache0, cache_pos=0)
        logits = self._unembed(params, h[:, -1:, :])
        return logits[:, 0, :], {"kv": cache, "pos": jnp.asarray(S, jnp.int32)}

    def decode_step(self, params, cache, tokens):
        """tokens: (B, 1). Returns (logits (B, V), cache)."""
        h = self._embed(params, {"tokens": tokens})
        h, kv, _ = self._stack(params, h, cache=cache["kv"], cache_pos=cache["pos"])
        logits = self._unembed(params, h)
        return logits[:, 0, :], {"kv": kv, "pos": cache["pos"] + tokens.shape[1]}

    def decode_state(self, batch_size: int, max_len: int):
        """Full decode-time state (cache + position) for input_specs."""
        return {
            "kv": self.init_cache(batch_size, max_len),
            "pos": jnp.asarray(max_len - 1, jnp.int32),
        }
