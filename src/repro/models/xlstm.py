"""xLSTM blocks: mLSTM (matrix memory, parallel/stabilized form) and sLSTM
(scalar memory, strictly sequential), wired per the xLSTM-125M layout
(1 sLSTM per `slstm_every` blocks, the rest mLSTM; no separate FFN).

mLSTM trains with the quadratic stabilized parallel form and decodes with the
O(1) recurrent form (equivalence is property-tested); sLSTM always scans over
time. Both are constant-state in decode, which is what qualifies xlstm-125m
for the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import init_dense, rmsnorm

_NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, d_model: int, n_heads: int, dtype):
    d_in = 2 * d_model
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.zeros((d_model,), dtype),
        "w_up": init_dense(ks[0], (d_model, 2 * d_in), dtype),  # u, g
        "conv_w": init_dense(ks[1], (4, d_in), dtype, scale=2.0),
        "conv_b": jnp.zeros((d_in,), dtype),
        "wq": init_dense(ks[2], (d_in, d_in), dtype),
        "wk": init_dense(ks[3], (d_in, d_in), dtype),
        "wv": init_dense(ks[4], (d_in, d_in), dtype),
        "w_if": init_dense(ks[5], (d_in, 2 * n_heads), dtype),
        "if_bias": jnp.concatenate(
            [jnp.zeros((n_heads,), jnp.float32), 3.0 * jnp.ones((n_heads,), jnp.float32)]
        ),
        "w_down": init_dense(ks[6], (d_in, d_model), dtype),
    }


def _conv4(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K)) + b


def mlstm_parallel(q, k, v, i_pre, f_pre):
    """q,k,v: (B,S,H,hd); i_pre,f_pre: (B,S,H). Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))  # (B,S,H)
    F = jnp.cumsum(logf, axis=1)
    # D[t,s] = F[t] - F[s] + i[s]  (s <= t)
    D = F[:, :, None, :] - F[:, None, :, :] + i_pre.astype(jnp.float32)[:, None, :, :]
    tri = jnp.tril(jnp.ones((S, S), bool))[None, :, :, None]
    D = jnp.where(tri, D, _NEG)  # (B,T,S,H)
    m = jnp.max(D, axis=2)  # (B,T,H)
    Smat = jnp.exp(D - m[:, :, None, :])
    qk = jnp.einsum("bthd,bshd->btsh", q.astype(jnp.float32), k.astype(jnp.float32))
    w = qk / (hd**0.5) * Smat
    denom = jnp.maximum(jnp.abs(jnp.sum(w, axis=2)), jnp.exp(-m))  # (B,T,H)
    y = jnp.einsum("btsh,bshd->bthd", w, v.astype(jnp.float32))
    return (y / denom[..., None]).astype(q.dtype)


def mlstm_step(state, q, k, v, i_pre, f_pre):
    """O(1) recurrence. state: (C (B,H,hd,hd), n (B,H,hd), m (B,H)).
    q,k,v: (B,H,hd); gates: (B,H). Returns (y (B,H,hd), new_state)."""
    C, n, m = state
    hd = q.shape[-1]
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    i = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(logf + m, i)
    fprime = jnp.exp(logf + m - m_new)
    iprime = jnp.exp(i - m_new)
    k32, v32, q32 = (a.astype(jnp.float32) for a in (k, v, q))
    C = fprime[..., None, None] * C + iprime[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", v32, k32
    )
    n = fprime[..., None] * n + iprime[..., None] * k32
    num = jnp.einsum("bhde,bhe->bhd", C, q32) / (hd**0.5)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", n, q32)) / (hd**0.5), jnp.exp(-m_new)
    )
    y = num / den[..., None]
    return y.astype(q.dtype), (C, n, m_new)


def mlstm_block(p, x, n_heads: int, *, state=None, conv_state=None):
    """x: (B,S,D). state=(C,n,m) for decode. Returns (out, new_states)."""
    B, S, D = x.shape
    d_in = 2 * D
    hd = d_in // n_heads
    hin = rmsnorm(x, p["ln"])
    ug = hin @ p["w_up"]
    u, g = jnp.split(ug, 2, axis=-1)
    hist = u if conv_state is None else jnp.concatenate([conv_state, u], axis=1)
    cv = _conv4(hist, p["conv_w"], p["conv_b"])
    if conv_state is not None:
        cv = cv[:, -S:, :]
    pad = max(0, 3 - hist.shape[1])
    new_conv = jnp.pad(hist, ((0, 0), (pad, 0), (0, 0)))[:, -3:, :]
    c_act = jax.nn.silu(cv)
    q = (c_act @ p["wq"]).reshape(B, S, n_heads, hd)
    k = (c_act @ p["wk"]).reshape(B, S, n_heads, hd)
    v = (u @ p["wv"]).reshape(B, S, n_heads, hd)
    if_pre = c_act @ p["w_if"] + p["if_bias"]
    i_pre, f_pre = jnp.split(if_pre, 2, axis=-1)  # (B,S,H)

    if state is None and S > 1:
        y = mlstm_parallel(q, k, v, i_pre, f_pre)
        new_state = None  # training path does not thread state
    else:
        st = state
        if st is None:
            st = (
                jnp.zeros((B, n_heads, hd, hd), jnp.float32),
                jnp.zeros((B, n_heads, hd), jnp.float32),
                jnp.full((B, n_heads), 0.0, jnp.float32),
            )

        def step(carry, inp):
            qt, kt, vt, it, ft = inp
            yt, carry = mlstm_step(carry, qt, kt, vt, it, ft)
            return carry, yt

        xs = tuple(
            jnp.moveaxis(a, 1, 0) for a in (q, k, v, i_pre, f_pre)
        )
        new_state, ys = jax.lax.scan(step, st, xs)
        y = jnp.moveaxis(ys, 0, 1)
    y = y.reshape(B, S, d_in) * jax.nn.silu(g)
    return x + y @ p["w_down"], (new_state, new_conv)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, d_model: int, n_heads: int, dtype):
    dh = d_model // n_heads
    ks = jax.random.split(key, 3)
    return {
        "ln": jnp.zeros((d_model,), dtype),
        "W": init_dense(ks[0], (d_model, 4 * d_model), dtype),  # z i f o
        "R": init_dense(ks[1], (n_heads, dh, 4 * dh), dtype),  # block-diag recurrent
        "bias": jnp.zeros((4 * d_model,), jnp.float32),
        "w_out": init_dense(ks[2], (d_model, d_model), dtype),
    }


def slstm_block(p, x, n_heads: int, *, state=None):
    """x: (B,S,D). state=(c,n,m,h) each (B,D)-shaped (m,(B,H))."""
    B, S, D = x.shape
    dh = D // n_heads
    hin = rmsnorm(x, p["ln"])
    wx = (hin @ p["W"] + p["bias"].astype(hin.dtype)).astype(jnp.float32)  # (B,S,4D)

    if state is None:
        c0 = jnp.zeros((B, D), jnp.float32)
        n0 = jnp.ones((B, D), jnp.float32)
        m0 = jnp.zeros((B, n_heads), jnp.float32)
        h0 = jnp.zeros((B, D), jnp.float32)
    else:
        c0, n0, m0, h0 = state

    R = p["R"].astype(jnp.float32)

    def step(carry, wx_t):
        c, n, m, h = carry
        hh = h.reshape(B, n_heads, dh)
        rec = jnp.einsum("bhd,hde->bhe", hh, R).reshape(B, 4 * D)
        z_, i_, f_, o_ = jnp.split(wx_t + rec, 4, axis=-1)  # (B,D) each
        ih = i_.reshape(B, n_heads, dh)
        fh = f_.reshape(B, n_heads, dh)
        # stabilizer per head (max over units for a shared head-level m)
        logf = jax.nn.log_sigmoid(fh)
        m_new = jnp.maximum(jnp.max(logf, -1) + m, jnp.max(ih, -1))  # (B,H)
        iprime = jnp.exp(ih - m_new[..., None]).reshape(B, D)
        fprime = jnp.exp(logf + (m - m_new)[..., None]).reshape(B, D)
        z = jnp.tanh(z_)
        o = jax.nn.sigmoid(o_)
        c = fprime * c + iprime * z
        n = fprime * n + iprime
        h = o * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, h), h

    (c, n, m, h), hs = jax.lax.scan(step, (c0, n0, m0, h0), jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # (B,S,D)
    return x + y @ p["w_out"], (c, n, m, h)
