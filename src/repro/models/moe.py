"""Top-k MoE FFN with sort-based capacity dispatch (dropless up to capacity).

Dispatch path (DESIGN.md §7): tokens are routed top-k, sorted by expert id,
placed into an (E, C, D) buffer at their within-expert position (computed
from a stable sort + exclusive cumsum of expert counts), processed with two
batched einsums over the expert dim, and combined back with a scatter-add
weighted by the renormalized gates. Under the production mesh the expert dim
shards over `model` and tokens over `(pod, data)`; XLA inserts the
all-to-all pair at the dispatch/combine boundaries.

Capacity C = ceil(capacity_factor * T * k / E); overflow tokens drop (their
residual path passes through unchanged) — standard capacity semantics.
Returns the load-balancing aux loss (Switch-style) alongside the output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import init_dense


def moe_init(key, d_model: int, moe_cfg, dtype):
    ks = jax.random.split(key, 4)
    E, F = moe_cfg.n_experts, moe_cfg.d_ff
    return {
        "router": init_dense(ks[0], (d_model, E), jnp.float32),
        "w1": init_dense(ks[1], (E, d_model, F), dtype),
        "w3": init_dense(ks[2], (E, d_model, F), dtype),
        "w2": init_dense(ks[3], (E, F, d_model), dtype),
    }


def moe_apply(p, x, moe_cfg):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    T = B * S
    E, K = moe_cfg.n_experts, moe_cfg.top_k
    C = max(1, int(moe_cfg.capacity_factor * T * K / E))
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32)) @ p["router"]  # (T, E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)  # (T, K)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)  # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gate.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.bincount(flat_e, length=E)
    seg_start = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * K) - seg_start[se]  # within-expert slot

    # dispatch: out-of-capacity slots fall off via mode="drop"
    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[se, pos].set(xt[st], mode="drop")

    h1 = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
    h3 = jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    h = jax.nn.silu(h1) * h3
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w2"])  # (E, C, D)

    keep = (pos < C)[:, None]
    vals = jnp.where(keep, out_e.at[se, pos].get(mode="fill", fill_value=0.0), 0.0)
    out = jnp.zeros((T, D), x.dtype).at[st].add((vals * sg[:, None]).astype(x.dtype))

    # Switch-style load-balance loss: E * sum_e f_e * p_e
    frac = counts.astype(jnp.float32) / (T * K)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * mean_prob)
    return out.reshape(B, S, D), aux
