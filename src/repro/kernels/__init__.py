"""Pallas TPU kernels for the paper's compute hot-spots.

streamsvm_scan — blocked one-pass Algorithm 1 (ball state resident in VMEM),
                 single-ball and multi-ball (B-model bank, one data pass)
gram           — tiled kernel-matrix blocks (linear / RBF epilogues)

ops.py carries the jit'd public wrappers; ref.py the pure-jnp oracles.
Kernels validate in interpret=True mode on CPU and target TPU BlockSpec
tiling (128-aligned lanes, f32 VMEM accumulators).
"""
from .ops import gram, streamsvm_fit, streamsvm_fit_many

__all__ = ["gram", "streamsvm_fit", "streamsvm_fit_many"]
