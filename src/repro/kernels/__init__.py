"""Pallas TPU kernels for the paper's compute hot-spots.

streamsvm_scan — blocked one-pass Algorithm 1: single-ball, and the tiled
                 multi-ball bank engine — a 2-D data-major grid training B
                 models per stream pass for arbitrary B, with fused
                 Algorithm-2 lookahead windows, a bf16 stream-tile policy,
                 and two bank residencies sharing one compute core: VMEM
                 scratch, or HBM/ANY double-buffered through a 2-slot
                 async-copy ring (lifts the VMEM cap on B*D, bit-exact f32)
predict        — the serving twin: (Q, D) query tiles x (B, D) bank tiles on
                 the same data-major grid, with fused scores / per-C-grid-
                 group ovr-argmax / topk epilogues and the same HBM-resident
                 ring option for the bank
gram           — tiled kernel-matrix blocks (linear / RBF epilogues)

ops.py carries the jit'd public wrappers (padding, bank tiling, dtype
policy); ref.py the pure-jnp/numpy oracles. Kernels validate in
interpret=True mode on CPU and target TPU BlockSpec tiling (128-aligned
lanes, f32 VMEM accumulators).
"""
from .ops import (
    gram,
    predict_bank,
    predict_kernel_bank,
    streamsvm_fit,
    streamsvm_fit_many,
)

__all__ = [
    "gram",
    "predict_bank",
    "predict_kernel_bank",
    "streamsvm_fit",
    "streamsvm_fit_many",
]
