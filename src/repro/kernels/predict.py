"""Pallas TPU kernel: fused bank inference — (Q, D) queries x (B, D) bank.

The serving-side twin of the training engine (streamsvm_scan.py): the same
data-major 2-D grid ``(q_block, bank_tile)`` with the QUERY axis outer, so
each (q_block, D) query tile is DMA'd from HBM exactly once (its BlockSpec
index ignores the bank axis and Pallas elides the re-copy) and is revisited
by every (b_tile, D) slice of the bank. The trained bank is tiny — O(B * D),
the paper's constant-storage claim — so re-reading a bank tile per resident
query tile is the cheap term; the query firehose is the expensive one and it
is read ONCE per batch.

One MXU matmul per (i, j) step — (q_block, D) x (D, b_tile) margins — feeds a
fused epilogue selected statically:

  scores  raw margin matrix S[q, b] = <x_q, w_b>, written tile by tile
          (bit-exact with the jnp ``X @ W.T`` readout: same full-D
          contraction per element, no accumulation across grid steps).
  ovr     per-C-grid-group argmax: the bank is laid out class-major within
          each hyper-parameter group (model = g * n_classes + class, the
          fit_ovr/fit_c_grid flattening), groups are padded to whole bank
          tiles by ops.py, and each grid step emits the winning class id and
          its margin for the g_tile groups resident in the tile — the
          argmax never crosses a tile boundary.
  topk    running top-k (score, model-id) per query across bank tiles, kept
          in VMEM scratch like the training engine's ball state: each step
          merges the resident tile's b_tile candidates into the running k
          (static k selection steps of max + first-argmax + mask), and the
          last bank tile writes the sorted result.

Padded bank lanes (B -> b_tile multiple, classes -> nc_pad) are masked with a
large negative additive bias so no epilogue can select them; padded query
rows are sliced off by ops.py. Query tiles may be bf16 (ops.py's
``stream_dtype`` policy — halves the dominant HBM term); the bank, bias and
every epilogue accumulator stay f32.

Bank residency (``bank_resident``) mirrors the training engine's knob:

  "vmem"  bank tiles are BlockSpec-delivered — Pallas's automatic pipeline
          stages each (b_tile, D) slice into VMEM (the PR 4 layout).
  "hbm"   the bank stays in an ANY/HBM-space ref and the kernel streams
          (b_tile, D) slices through a 2-slot VMEM ring with
          ``pltpu.make_async_copy`` — the prefetch of grid step t+1's tile
          issued before compute on step t's slot, DMA semaphores in scratch.
          Read-only, so there is no write-back leg; the epilogue compute is
          shared op-for-op with "vmem" (bit-exact f32). This is the serving
          twin of the training engine's HBM-resident mode: a bank whose
          (B, D) footprint exceeds the VMEM budget serves without ever
          claiming VMEM residency for it, and ops.py's ``auto`` policy keeps
          train/serve residency decisions consistent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Large-but-finite lane mask: padded bank lanes carry this additive bias so
# every real margin beats them (finite so bias + margin never becomes NaN).
NEG_MASK = -3.0e38


def _first_argmax(vals: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(rows, lanes) -> per-row (max, first index achieving it).

    max/min/where/iota only — the Mosaic-friendly spelling of jnp.argmax
    (ties resolve to the lowest lane, matching jnp.argmax / lax.top_k).
    """
    best = jnp.max(vals, axis=1)
    lanes = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
    arg = jnp.min(
        jnp.where(vals == best[:, None], lanes, vals.shape[1]), axis=1
    )
    return best, arg


def _kernel(
    q_ref,  # (q_block, D) query tile (f32 or bf16)
    w_ref,  # (b_tile, D) bank tile (f32) — or the full ANY-space bank (hbm)
    bias_ref,  # (b_tile, 1) additive lane bias: 0 live, NEG_MASK padded
    *refs,  # epilogue outputs, then scratch (topk adds 2; hbm adds ring+sem)
    epilogue: str,
    b_tile: int,
    nc_pad: int | None,
    k: int | None,
    hbm: bool = False,
    n_q_blocks: int | None = None,
):
    j = pl.program_id(1)  # bank tile (inner — revisits the resident queries)
    n_btiles = pl.num_programs(1)

    if hbm:
        # HBM-resident bank: stream (b_tile, D) slices through a 2-slot VMEM
        # ring — prefetch of step t+1's tile issued before compute on step
        # t's slot. Read-only, so no write-back leg; with <= 2 bank tiles
        # each tile owns a slot and loads once, on the first query tile.
        ring, sem = refs[-2], refs[-1]
        refs = refs[:-2]
        i = pl.program_id(0)
        J = n_btiles
        t = i * J + j
        T = n_q_blocks * J

        def din(tt):
            tile = jax.lax.rem(tt, J)
            slot = jax.lax.rem(tt, 2) if J > 2 else tile
            return pltpu.make_async_copy(
                w_ref.at[pl.ds(tile * b_tile, b_tile), :],
                ring.at[slot],
                sem.at[slot],
            )

        if J <= 2:
            @pl.when(i == 0)
            def _load():
                d = din(t)
                d.start()
                d.wait()

            slot = j
        else:
            @pl.when(t == 0)
            def _warmup():
                din(0).start()

            @pl.when(t + 1 < T)
            def _prefetch():  # overlaps the matmul + epilogue below
                din(t + 1).start()

            din(t).wait()
            slot = jax.lax.rem(t, 2)
        w_tile = ring[slot]
    else:
        w_tile = w_ref[...]

    q = q_ref[...].astype(jnp.float32)  # bf16 query tiles upcast here
    s = jax.lax.dot_general(
        q, w_tile, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (q_block, b_tile) margins

    if epilogue == "scores":
        # No bias: live lanes must stay bit-identical to X @ W.T (padded
        # lanes are sliced off by ops.py, so masking them is pointless).
        (out_ref,) = refs
        out_ref[...] = s
        return

    s = s + bias_ref[...][:, 0][None, :]

    if epilogue == "ovr":
        cls_ref, margin_ref = refs
        g_tile = b_tile // nc_pad
        cls_cols, margin_cols = [], []
        for g in range(g_tile):  # static: groups resident in this tile
            seg = s[:, g * nc_pad : (g + 1) * nc_pad]
            best, arg = _first_argmax(seg)
            cls_cols.append(arg)  # class lane == class id (padded lanes lose)
            margin_cols.append(best)
        cls_ref[...] = jnp.stack(cls_cols, axis=1)
        margin_ref[...] = jnp.stack(margin_cols, axis=1)
        return

    # ----- topk: running (score, model-id) top-k across bank tiles --------
    vals_out, ids_out, vals_ref, ids_ref = refs

    @pl.when(j == 0)
    def _reset():  # fresh query tile: forget the previous tile's ranking
        vals_ref[...] = jnp.full(vals_ref.shape, NEG_MASK, jnp.float32)
        ids_ref[...] = jnp.zeros(ids_ref.shape, jnp.int32)

    lane_ids = j * b_tile + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    cand_v = jnp.concatenate([vals_ref[...], s], axis=1)  # (q_block, k+b_tile)
    cand_i = jnp.concatenate([ids_ref[...], lane_ids], axis=1)
    vals, ids = [], []
    for _ in range(k):  # static selection: max + first-argmax + mask
        best, pos = _first_argmax(cand_v)
        sel = (
            jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, 1)
            == pos[:, None]
        )
        vals.append(best)
        ids.append(jnp.sum(jnp.where(sel, cand_i, 0), axis=1))  # one-hot pick
        cand_v = jnp.where(sel, NEG_MASK, cand_v)
    vals_ref[...] = jnp.stack(vals, axis=1)  # descending by construction
    ids_ref[...] = jnp.stack(ids, axis=1)

    @pl.when(j == n_btiles - 1)
    def _write():
        vals_out[...] = vals_ref[...]
        ids_out[...] = ids_ref[...]


def predict_bank_pallas(
    Q: jax.Array,
    W: jax.Array,
    bias: jax.Array,
    *,
    epilogue: str = "scores",
    q_block: int = 256,
    b_tile: int | None = None,
    nc_pad: int | None = None,
    k: int | None = None,
    bank_resident: str = "vmem",
    interpret: bool | None = None,
):
    """Score padded queries against a padded bank with a fused epilogue.

    Q: (Qn, D) query rows (f32 or bf16) — D padded to a multiple of 128 and
    Qn to a multiple of ``q_block`` by ops.py. W: (Bp, D) f32 bank, Bp a
    multiple of ``b_tile``. bias: (Bp, 1) f32 additive lane mask (0 for live
    model lanes, NEG_MASK for padding). Epilogues:

      "scores" -> (Qn, Bp) f32 margins
      "ovr"    -> ((Qn, Gp) int32 class ids, (Qn, Gp) f32 margins) where the
                  bank is packed as Gp groups of ``nc_pad`` class lanes and
                  ``b_tile`` is a whole number of groups (ops.py arranges
                  both), so every group's argmax completes inside one step
      "topk"   -> ((Qn, k) f32, (Qn, k) int32) per-query top-k model scores
                  and ids, descending (running VMEM scratch across tiles)

    ``bank_resident="hbm"`` keeps W in ANY/HBM memory and double-buffers
    (b_tile, D) slices through a 2-slot VMEM ring (see module docstring);
    bit-exact with the default BlockSpec-delivered layout.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if bank_resident not in ("vmem", "hbm"):
        raise ValueError(
            f"unknown bank_resident {bank_resident!r}; expected 'vmem' or "
            "'hbm' (ops.predict_bank resolves 'auto' before calling the "
            "kernel)"
        )
    qn, d = Q.shape
    bp, dw = W.shape
    if dw != d:
        raise ValueError(
            f"queries and bank must share the feature axis: got Q.shape="
            f"{Q.shape}, W.shape={W.shape}"
        )
    if bias.shape != (bp, 1):
        raise ValueError(
            f"bias must be (B, 1) matching the bank: got bias.shape="
            f"{bias.shape}, W.shape={W.shape}"
        )
    if qn % q_block != 0:
        raise ValueError(
            f"Q={qn} must be a multiple of q_block={q_block} (pad the "
            "queries; ops.predict_bank does this)"
        )
    if b_tile is None:
        b_tile = bp
    if bp % b_tile != 0:
        raise ValueError(
            f"B={bp} must be a multiple of b_tile={b_tile} (pad the bank; "
            "ops.predict_bank does this)"
        )
    if epilogue == "ovr":
        if nc_pad is None or b_tile % nc_pad != 0:
            raise ValueError(
                f"epilogue='ovr' needs nc_pad dividing b_tile: got "
                f"nc_pad={nc_pad}, b_tile={b_tile}"
            )
    elif epilogue == "topk":
        if k is None or k < 1:
            raise ValueError(f"epilogue='topk' needs k >= 1, got {k}")
    elif epilogue != "scores":
        raise ValueError(
            f"unknown epilogue {epilogue!r}; expected 'scores', 'ovr' or "
            "'topk'"
        )

    grid = (qn // q_block, bp // b_tile)
    hbm = bank_resident == "hbm"
    in_specs = [
        # query tile index ignores j -> DMA'd once, resident across the bank
        pl.BlockSpec((q_block, d), lambda i, j: (i, 0)),
        # hbm: the bank never enters the BlockSpec pipeline — the kernel
        # rings (b_tile, D) slices out of ANY space itself
        pl.BlockSpec(memory_space=pltpu.ANY)
        if hbm
        else pl.BlockSpec((b_tile, d), lambda i, j: (j, 0)),
        pl.BlockSpec((b_tile, 1), lambda i, j: (j, 0)),
    ]
    scratch = []
    if epilogue == "scores":
        out_specs = [pl.BlockSpec((q_block, b_tile), lambda i, j: (i, j))]
        out_shape = [jax.ShapeDtypeStruct((qn, bp), jnp.float32)]
    elif epilogue == "ovr":
        g_tile = b_tile // nc_pad
        gp = bp // nc_pad
        out_specs = [
            pl.BlockSpec((q_block, g_tile), lambda i, j: (i, j)),
            pl.BlockSpec((q_block, g_tile), lambda i, j: (i, j)),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((qn, gp), jnp.int32),
            jax.ShapeDtypeStruct((qn, gp), jnp.float32),
        ]
    else:  # topk: outputs parked at tile 0, written on the last bank tile
        out_specs = [
            pl.BlockSpec((q_block, k), lambda i, j: (i, 0)),
            pl.BlockSpec((q_block, k), lambda i, j: (i, 0)),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((qn, k), jnp.float32),
            jax.ShapeDtypeStruct((qn, k), jnp.int32),
        ]
        scratch = [
            pltpu.VMEM((q_block, k), jnp.float32),
            pltpu.VMEM((q_block, k), jnp.int32),
        ]

    if hbm:
        scratch = scratch + [
            pltpu.VMEM((2, b_tile, d), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
        ]
    outs = pl.pallas_call(
        functools.partial(
            _kernel, epilogue=epilogue, b_tile=b_tile, nc_pad=nc_pad, k=k,
            hbm=hbm, n_q_blocks=grid[0],
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(Q, W.astype(jnp.float32), bias.astype(jnp.float32))
    return outs[0] if epilogue == "scores" else tuple(outs)
