"""Pure-jnp oracles for every Pallas kernel (the allclose references).

Deliberately written as straight-line jnp (row-at-a-time scan for the
streaming kernel, one einsum for the Gram kernel) and independent of the
kernel implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def streamsvm_scan_ref(X, y, w0, r0, xi20, c_inv, m0, *, gain=None, n_valid=None):
    """Row-at-a-time Algorithm 1 from an arbitrary starting state.

    ``gain`` is the slack-recursion gain (defaults to ``c_inv`` — the "exact"
    variant; pass 1.0 for the paper-listing variant).
    """
    n = X.shape[0]
    n_valid = n if n_valid is None else n_valid
    gain = c_inv if gain is None else gain
    yx = (y[:, None] * X).astype(jnp.float32)
    valid = jnp.arange(n) < n_valid

    def body(carry, inp):
        w, r, xi2, m = carry
        row, ok = inp
        d2 = jnp.sum((w - row) ** 2) + xi2 + c_inv
        d = jnp.sqrt(jnp.maximum(d2, 1e-12))
        upd = jnp.logical_and(d >= r, ok)
        s = jnp.where(upd, 0.5 * (1.0 - r / d), 0.0)
        w = (1.0 - s) * w + s * row
        r = jnp.where(upd, r + 0.5 * (d - r), r)
        xi2 = xi2 * (1.0 - s) ** 2 + s**2 * gain
        m = m + upd.astype(jnp.int32)
        return (w, r, xi2, m), None

    w0 = jnp.asarray(w0, jnp.float32)
    init = (
        w0,
        jnp.asarray(r0, jnp.float32),
        jnp.asarray(xi20, jnp.float32),
        jnp.asarray(m0, jnp.int32),
    )
    (w, r, xi2, m), _ = jax.lax.scan(body, init, (yx, valid))
    return w, r, xi2, m


def streamsvm_scan_many_ref(X, Y, W0, r0, xi20, c_inv, m0, *, gain=None, n_valid=None):
    """Bank-of-balls oracle: per-model Algorithm 1 over the shared stream.

    X: (N, D); Y: (B, N) per-model signs; W0: (B, D); the remaining state
    arrays are (B,). A plain vmap of the single-ball reference — B logical
    passes — used as the allclose target for the one-pass engine.
    """
    b = Y.shape[0]
    bcast = lambda v: jnp.broadcast_to(jnp.asarray(v, jnp.float32), (b,))
    gain = bcast(c_inv if gain is None else gain)

    def one(y, w0, r0_, xi20_, ci, m0_, g_):
        return streamsvm_scan_ref(
            X, y, w0, r0_, xi20_, ci, m0_, gain=g_, n_valid=n_valid
        )

    return jax.vmap(one)(
        Y, jnp.asarray(W0, jnp.float32), bcast(r0), bcast(xi20), bcast(c_inv),
        bcast(m0).astype(jnp.int32), gain,
    )


def gram_ref(A, B, *, epilogue="linear", gamma=1.0, out_dtype=jnp.float32):
    acc = jnp.einsum("md,nd->mn", A.astype(jnp.float32), B.astype(jnp.float32))
    if epilogue == "rbf":
        an = jnp.sum(A.astype(jnp.float32) ** 2, 1)[:, None]
        bn = jnp.sum(B.astype(jnp.float32) ** 2, 1)[None, :]
        return jnp.exp(-gamma * jnp.maximum(an + bn - 2 * acc, 0.0)).astype(out_dtype)
    return acc.astype(out_dtype)
