"""Pure-jnp/numpy oracles for every Pallas kernel (the allclose references).

Deliberately written as straight-line jnp (row-at-a-time scan for the
streaming kernel, one einsum for the Gram kernel) or plain-python numpy
(the lookahead oracle, buffer as a python list) and independent of the
kernel implementations.

These oracles are RESIDENCY-AGNOSTIC: they model the algorithms' math, with
no notion of where the bank lives (``bank_resident="vmem"`` vs ``"hbm"`` is
a pure data-movement choice in the kernels). One oracle therefore anchors
both layouts — and because the two kernel layouts share their compute core,
the parity suites additionally pin them bit-exact (f32) against EACH OTHER
(tests/test_hbm_bank.py, tests/test_predict_engine.py), a stronger
statement than each being allclose to the reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def streamsvm_scan_ref(X, y, w0, r0, xi20, c_inv, m0, *, gain=None, n_valid=None):
    """Row-at-a-time Algorithm 1 from an arbitrary starting state.

    ``gain`` is the slack-recursion gain (defaults to ``c_inv`` — the "exact"
    variant; pass 1.0 for the paper-listing variant). Rows with label sign 0
    are inert (the stream-padding contract), as are rows >= ``n_valid``.
    """
    n = X.shape[0]
    n_valid = n if n_valid is None else n_valid
    gain = c_inv if gain is None else gain
    yx = (y[:, None] * X).astype(jnp.float32)
    valid = jnp.logical_and(jnp.arange(n) < n_valid, jnp.asarray(y) != 0)

    def body(carry, inp):
        w, r, xi2, m = carry
        row, ok = inp
        d2 = jnp.sum((w - row) ** 2) + xi2 + c_inv
        d = jnp.sqrt(jnp.maximum(d2, 1e-12))
        upd = jnp.logical_and(d >= r, ok)
        s = jnp.where(upd, 0.5 * (1.0 - r / d), 0.0)
        w = (1.0 - s) * w + s * row
        r = jnp.where(upd, r + 0.5 * (d - r), r)
        xi2 = xi2 * (1.0 - s) ** 2 + s**2 * gain
        m = m + upd.astype(jnp.int32)
        return (w, r, xi2, m), None

    w0 = jnp.asarray(w0, jnp.float32)
    init = (
        w0,
        jnp.asarray(r0, jnp.float32),
        jnp.asarray(xi20, jnp.float32),
        jnp.asarray(m0, jnp.int32),
    )
    (w, r, xi2, m), _ = jax.lax.scan(body, init, (yx, valid))
    return w, r, xi2, m


def streamsvm_scan_many_ref(X, Y, W0, r0, xi20, c_inv, m0, *, gain=None, n_valid=None):
    """Bank-of-balls oracle: per-model Algorithm 1 over the shared stream.

    X: (N, D); Y: (B, N) per-model signs; W0: (B, D); the remaining state
    arrays are (B,). A plain vmap of the single-ball reference — B logical
    passes — used as the allclose target for the one-pass engine.
    """
    b = Y.shape[0]
    bcast = lambda v: jnp.broadcast_to(jnp.asarray(v, jnp.float32), (b,))
    gain = bcast(c_inv if gain is None else gain)

    def one(y, w0, r0_, xi20_, ci, m0_, g_):
        return streamsvm_scan_ref(
            X, y, w0, r0_, xi20_, ci, m0_, gain=g_, n_valid=n_valid
        )

    return jax.vmap(one)(
        Y, jnp.asarray(W0, jnp.float32), bcast(r0), bcast(xi20), bcast(c_inv),
        bcast(m0).astype(jnp.int32), gain,
    )


def streamsvm_scan_lookahead_ref(
    X, y, w0, r0, xi20, c_inv, m0, lookahead, *, gain=None, n_valid=None
):
    """Row-at-a-time Algorithm 2: deferred acceptance through an L-row window.

    A violating row is buffered instead of absorbed; when the buffer holds
    ``lookahead`` rows it is flushed farthest-point-first — repeatedly apply
    the Algorithm-1 update to the farthest buffered point and drop the whole
    window as soon as its farthest point is already enclosed (greedy
    Badoiu-Clarkson insertion over the window; the engine's in-kernel
    semantics). ``m`` counts buffered violators at push time (matching the
    QP path's per-flush accounting). The trailing partial window is flushed
    at end of stream. ``lookahead == 1`` is exactly Algorithm 1.

    Plain-python numpy on purpose: the slow, obviously-correct target the
    fused kernel is swept against.
    """
    L = int(lookahead)
    if L < 1:
        raise ValueError(f"lookahead must be >= 1, got {L}")
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.float32)
    w = np.array(w0, np.float32, copy=True)
    r = np.float32(r0)
    xi2 = np.float32(xi20)
    cinv = np.float32(c_inv)
    g = np.float32(cinv if gain is None else gain)
    m = int(m0)
    n = X.shape[0]
    nv = n if n_valid is None else int(n_valid)
    buf: list = []

    def dist(p):
        d2 = np.sum((w - p) ** 2, dtype=np.float32) + xi2 + cinv
        return np.sqrt(np.maximum(d2, np.float32(1e-12)))

    def flush():
        nonlocal w, r, xi2, buf
        while buf:
            ds = [dist(p) for p in buf]
            k = int(np.argmax(ds))
            dk = ds[k]
            if not dk >= r:  # farthest enclosed -> whole window enclosed
                buf = []
                break
            s = np.float32(0.5) * (np.float32(1.0) - r / dk)
            w = (np.float32(1.0) - s) * w + s * buf[k]
            r = r + np.float32(0.5) * (dk - r)
            xi2 = xi2 * (np.float32(1.0) - s) ** 2 + s**2 * g
            buf.pop(k)

    for i in range(min(n, nv)):
        if y[i] == 0:  # sign-0 rows are inert (stream-padding contract)
            continue
        p = y[i] * X[i]
        if dist(p) >= r:
            buf.append(p)
            m += 1
            if len(buf) >= L:
                flush()
    flush()  # trailing partial window
    return w, r, xi2, m


def streamsvm_scan_lookahead_many_ref(
    X, Y, W0, r0, xi20, c_inv, m0, lookahead, *, gain=None, n_valid=None
):
    """Bank-of-balls lookahead oracle: per-model Algorithm 2, per-model L.

    Shapes as in ``streamsvm_scan_many_ref`` plus ``lookahead``: an int or
    (B,) of ints (python loop over models — L is per-model static).
    """
    b = Y.shape[0]
    bc = lambda v: np.broadcast_to(np.asarray(v, np.float32), (b,))
    r0, xi20, c_inv = bc(r0), bc(xi20), bc(c_inv)
    m0 = np.broadcast_to(np.asarray(m0), (b,)).astype(np.int32)
    gain = c_inv if gain is None else bc(gain)
    ls = np.broadcast_to(np.asarray(lookahead), (b,)).astype(np.int32)
    W0 = np.asarray(W0, np.float32)
    outs = [
        streamsvm_scan_lookahead_ref(
            X, np.asarray(Y)[i], W0[i], r0[i], xi20[i], c_inv[i], m0[i],
            int(ls[i]), gain=gain[i], n_valid=n_valid,
        )
        for i in range(b)
    ]
    w = np.stack([o[0] for o in outs])
    return (
        w,
        np.asarray([o[1] for o in outs], np.float32),
        np.asarray([o[2] for o in outs], np.float32),
        np.asarray([o[3] for o in outs], np.int32),
    )


def predict_bank_ref(X, W, *, epilogue="scores", n_classes=None, k=None):
    """Bank-inference oracle: one einsum + a jnp epilogue.

    X: (Q, D) queries; W: (B, D) bank of model weight rows. Mirrors
    ops.predict_bank's epilogue contract:

      "scores" -> (Q, B) f32 margins
      "ovr"    -> ((Q, G) int32, (Q, G) f32): per-C-grid-group argmax class
                  and its margin, with the bank laid out class-major within
                  each group (model = g * n_classes + class — the
                  fit_ovr/fit_c_grid flattening) and G = B // n_classes
      "topk"   -> ((Q, k) f32, (Q, k) int32) descending top-k model scores
                  and ids per query (lax.top_k)
    """
    scores = jnp.einsum(
        "qd,bd->qb", X.astype(jnp.float32), W.astype(jnp.float32)
    )
    if epilogue == "scores":
        return scores
    if epilogue == "ovr":
        q, b = scores.shape
        if n_classes is None or n_classes < 1 or b % n_classes:
            raise ValueError(
                f"epilogue='ovr' needs n_classes >= 1 dividing B: got "
                f"n_classes={n_classes}, B={b}"
            )
        grouped = scores.reshape(q, b // n_classes, n_classes)
        return (
            jnp.argmax(grouped, axis=-1).astype(jnp.int32),
            jnp.max(grouped, axis=-1),
        )
    if epilogue == "topk":
        if k is None or not (1 <= k <= scores.shape[1]):
            raise ValueError(
                f"epilogue='topk' needs 1 <= k <= B: got k={k}, "
                f"B={scores.shape[1]}"
            )
        vals, ids = jax.lax.top_k(scores, k)
        return vals, ids.astype(jnp.int32)
    raise ValueError(
        f"unknown epilogue {epilogue!r}; expected 'scores', 'ovr' or 'topk'"
    )


def gram_ref(A, B, *, epilogue="linear", gamma=1.0, out_dtype=jnp.float32):
    acc = jnp.einsum("md,nd->mn", A.astype(jnp.float32), B.astype(jnp.float32))
    if epilogue == "rbf":
        an = jnp.sum(A.astype(jnp.float32) ** 2, 1)[:, None]
        bn = jnp.sum(B.astype(jnp.float32) ** 2, 1)[None, :]
        return jnp.exp(-gamma * jnp.maximum(an + bn - 2 * acc, 0.0)).astype(out_dtype)
    return acc.astype(out_dtype)


def _kernel_ref(A, B, *, kernel, gamma):
    A = np.asarray(A, np.float32)
    B = np.asarray(B, np.float32)
    acc = A @ B.T
    if kernel == "rbf":
        an = np.sum(A * A, 1)[:, None]
        bn = np.sum(B * B, 1)[None, :]
        return np.exp(-gamma * np.maximum(an + bn - 2.0 * acc, 0.0))
    return acc


def fit_kernel_bank_ref(
    X, Y, cs, *, kernel="rbf", gamma=1.0, coreset_size=64, variant="exact",
    eviction="smallest-coef",
):
    """Core-set kernel-bank oracle: per-model, row-at-a-time, plain numpy.

    Mirrors core.fit_kernel_bank's contract exactly — per-model bounded
    buffer of ``coreset_size`` (index, coefficient) pairs, DEFERRED seeding
    (each model seeds with a forced step s = 1 on its first nonzero-sign
    row, so shard-local ranges beginning with inert rows are correct),
    uniform (1 - s) coefficient decay on each absorb, and the ``eviction``
    slot policy (first minimum on ties; free slots always preferred —
    coef 0 under "smallest-coef", score -inf under "farthest-point") — but
    with an explicit python buffer per model and no tiling, so it is the
    slow, obviously-correct target the fused engine is swept against.
    Returns (idx, coef, points, q, r, xi2, m) matching KernelBank's arrays.
    """
    X = np.asarray(X, np.float32)
    Y = np.asarray(Y)
    n, d = X.shape
    b, _ = Y.shape
    S = int(coreset_size)
    if eviction not in ("smallest-coef", "farthest-point"):
        raise ValueError(f"unknown eviction {eviction!r}")
    cs = np.broadcast_to(np.asarray(cs, np.float32), (b,))
    kd = np.ones(n, np.float32) if kernel == "rbf" else np.sum(X * X, 1)

    idx = np.full((b, S), -1, np.int32)
    coef = np.zeros((b, S), np.float32)
    q = np.zeros(b, np.float32)
    r = np.zeros(b, np.float32)
    xi2 = np.zeros(b, np.float32)
    m = np.zeros(b, np.int32)
    for bi in range(b):
        c_inv = np.float32(1.0 / cs[bi])
        gain = c_inv if variant == "exact" else np.float32(1.0)
        for i in range(n):
            yn = np.float32(Y[bi, i])
            if yn == 0:
                continue
            live = idx[bi] >= 0
            kv = np.zeros(S, np.float32)
            kv[live] = _kernel_ref(
                X[i][None], X[idx[bi, live]], kernel=kernel, gamma=gamma
            )[0]
            g = np.float32(np.sum(coef[bi] * kv))
            seed = m[bi] == 0  # deferred line-3 init: forced s = 1
            d2 = q[bi] - 2.0 * yn * g + kd[i] + xi2[bi] + c_inv
            dist = np.sqrt(np.maximum(d2, np.float32(1e-12)))
            if not seed and not dist >= r[bi]:
                continue
            s = (
                np.float32(1.0)
                if seed
                else np.float32(0.5) * (np.float32(1.0) - r[bi] / dist)
            )
            if eviction == "farthest-point":
                pts = np.where(
                    live[:, None], X[np.clip(idx[bi], 0, None)], 0.0
                ).astype(np.float32)
                kbb = _kernel_ref(pts, pts, kernel=kernel, gamma=gamma)
                gs = kbb @ coef[bi]
                score = np.where(
                    live,
                    q[bi] - 2.0 * np.sign(coef[bi]) * gs + np.diag(kbb),
                    -np.inf,
                )  # squared center->point distance; evict the closest
                slot = int(np.argmin(score))
            else:
                slot = int(np.argmin(np.abs(coef[bi])))
            coef[bi] *= np.float32(1.0) - s
            coef[bi, slot] = s * yn
            idx[bi, slot] = i
            q[bi] = (
                (np.float32(1.0) - s) ** 2 * q[bi]
                + np.float32(2.0) * s * (np.float32(1.0) - s) * yn * g
                + s**2 * kd[i]
            )
            if not seed:
                r[bi] = r[bi] + np.float32(0.5) * (dist - r[bi])
            xi2[bi] = xi2[bi] * (np.float32(1.0) - s) ** 2 + s**2 * gain
            m[bi] += 1
    points = np.where(
        (idx >= 0)[..., None], X[np.clip(idx, 0, max(n - 1, 0))], 0.0
    )
    return idx, coef, points.astype(np.float32), q, r, xi2, m


def merge_kernel_banks_ref(b1, b2, *, kernel="rbf", gamma=1.0,
                           eviction="smallest-coef"):
    """Numpy oracle for ``core.merge_kernel_banks`` (kernelized Sec-4.3).

    Accepts two KernelBank pytrees (or 7-tuples of arrays in KernelBank leaf
    order), mirrors the branch-free merge algebra in straight-line f32
    numpy — cross-Gram center distance, containment / empty-identity
    collapse onto the interpolation weight t, coefficient scaling
    [(1-t) coef1 ; t coef2] on the concatenated buffer, q/xi2 recursions —
    and compresses 2S -> S with a stable argsort (descending score, ties ->
    lowest index, matching lax.top_k). Returns (idx, coef, points, q, r,
    xi2, m).
    """
    idx1, coef1, pts1, q1, r1, xi21, m1 = [np.asarray(v) for v in tuple(b1)]
    idx2, coef2, pts2, q2, r2, xi22, m2 = [np.asarray(v) for v in tuple(b2)]
    if coef1.shape != coef2.shape:
        raise ValueError(
            f"merge_kernel_banks_ref needs identically-shaped banks: got "
            f"coef {coef1.shape} vs {coef2.shape}"
        )
    B, S = coef1.shape
    f32 = lambda a: np.asarray(a, np.float32)
    coef1, coef2 = f32(coef1), f32(coef2)
    pts1, pts2 = f32(pts1), f32(pts2)
    q1, q2, r1, r2 = f32(q1), f32(q2), f32(r1), f32(r2)
    xi21, xi22 = f32(xi21), f32(xi22)

    k12 = np.stack(
        [
            _kernel_ref(pts1[i], pts2[i], kernel=kernel, gamma=gamma)
            for i in range(B)
        ]
    ).astype(np.float32)
    cross = np.einsum("bs,bst,bt->b", coef1, k12, coef2).astype(np.float32)
    d2 = q1 + q2 - np.float32(2.0) * cross + xi21 + xi22
    dist = np.sqrt(np.maximum(d2, np.float32(0.0)))
    safe = np.maximum(dist, np.float32(1e-12))
    one_in_two = dist + r1 <= r2
    two_in_one = dist + r2 <= r1
    empty1 = m1 == 0
    empty2 = m2 == 0

    r_join = np.float32(0.5) * (r1 + r2 + dist)
    t = np.clip((r_join - r1) / safe, np.float32(0.0), np.float32(1.0))
    t = np.where(one_in_two, np.float32(1.0),
                 np.where(two_in_one, np.float32(0.0), t))
    t = np.where(empty1, np.float32(1.0),
                 np.where(empty2, np.float32(0.0), t)).astype(np.float32)
    r = np.where(one_in_two, r2, np.where(two_in_one, r1, r_join))
    r = np.where(empty1, r2, np.where(empty2, r1, r)).astype(np.float32)

    q = (
        (np.float32(1.0) - t) ** 2 * q1
        + np.float32(2.0) * t * (np.float32(1.0) - t) * cross
        + t**2 * q2
    ).astype(np.float32)
    xi2 = ((np.float32(1.0) - t) ** 2 * xi21 + t**2 * xi22).astype(np.float32)
    m = (m1 + m2).astype(np.int32)

    idx_c = np.concatenate([idx1, idx2], axis=1)
    coef_c = np.concatenate(
        [(np.float32(1.0) - t)[:, None] * coef1, t[:, None] * coef2], axis=1
    ).astype(np.float32)
    pts_c = np.concatenate([pts1, pts2], axis=1)

    if eviction == "farthest-point":
        kcc = np.stack(
            [
                _kernel_ref(pts_c[i], pts_c[i], kernel=kernel, gamma=gamma)
                for i in range(B)
            ]
        ).astype(np.float32)
        gs = np.einsum("bst,bt->bs", kcc, coef_c).astype(np.float32)
        kdiag = np.stack([np.diag(kcc[i]) for i in range(B)])
        score = np.where(
            idx_c >= 0,
            q[:, None] - np.float32(2.0) * np.sign(coef_c) * gs + kdiag,
            -np.inf,
        )
    elif eviction == "smallest-coef":
        score = np.where(idx_c >= 0, np.abs(coef_c), -np.inf)
    else:
        raise ValueError(f"unknown eviction {eviction!r}")
    keep = np.argsort(-score, axis=1, kind="stable")[:, :S]  # == lax.top_k
    take = np.take_along_axis
    return (
        take(idx_c, keep, axis=1),
        take(coef_c, keep, axis=1),
        take(pts_c, keep[..., None], axis=1),
        q, r, xi2, m,
    )


def predict_kernel_bank_ref(
    X, points, coef, *, kernel="rbf", gamma=1.0, epilogue="scores",
    n_classes=None, k=None,
):
    """Kernel-bank inference oracle: gram_ref + the coefficient contraction.

    X: (Q, D) queries; points: (B, S, D) core sets; coef: (B, S). Epilogue
    contract identical to predict_bank_ref (scores / ovr / topk).
    """
    q, d = jnp.asarray(X).shape
    b, s, _ = jnp.asarray(points).shape
    K = gram_ref(
        jnp.asarray(X), jnp.asarray(points).reshape(b * s, d),
        epilogue=kernel, gamma=gamma,
    )
    scores = jnp.einsum(
        "qbs,bs->qb", K.reshape(q, b, s), jnp.asarray(coef, jnp.float32)
    )
    if epilogue == "scores":
        return scores
    if epilogue == "ovr":
        if n_classes is None or n_classes < 1 or b % n_classes:
            raise ValueError(
                f"epilogue='ovr' needs n_classes >= 1 dividing B: got "
                f"n_classes={n_classes}, B={b}"
            )
        grouped = scores.reshape(q, b // n_classes, n_classes)
        return (
            jnp.argmax(grouped, axis=-1).astype(jnp.int32),
            jnp.max(grouped, axis=-1),
        )
    if epilogue == "topk":
        if k is None or not (1 <= k <= b):
            raise ValueError(
                f"epilogue='topk' needs 1 <= k <= B: got k={k}, B={b}"
            )
        vals, ids = jax.lax.top_k(scores, k)
        return vals, ids.astype(jnp.int32)
    raise ValueError(
        f"unknown epilogue {epilogue!r}; expected 'scores', 'ovr' or 'topk'"
    )
