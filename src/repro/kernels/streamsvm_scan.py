"""Pallas TPU kernel: one-pass StreamSVM over a VMEM-blocked stream.

TPU adaptation of Algorithm 1 (DESIGN.md §3). The ball state (w, R, xi2, M)
lives in VMEM/SMEM scratch across a *sequential* grid over row-blocks of the
stream; each grid step:

  1. loads a (block_n, D) tile of label-signed rows from HBM into VMEM,
  2. computes the block Gram matrix G = YX YX^T and the state inner products
     g_j = <w, yx_j> on the MXU (one matmul + one matvec per block instead of
     the paper's per-row scalar loop),
  3. runs the inherently-sequential conditional updates with an in-register
     fori_loop over the block's rows, maintaining <w, yx_k> for k > j with
     rank-1 corrections from G (O(block_n) per row) and updating w itself
     with a single AXPY per *accepted* row.

Per-block cost: one (block_n x D x block_n) matmul + block_n * O(block_n + D)
vector work — MXU-friendly, and exactly equal in result to the reference
scan (tests sweep shapes/dtypes against ref.py).

Scalar state is carried in an SMEM (4,)-vector: [r, xi2, m, n_valid].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    x_ref,  # (block_n, D) VMEM tile of X
    y_ref,  # (block_n, 1) VMEM tile of labels
    w0_ref,  # (1, D) initial weight vector
    s0_ref,  # (1, 4) initial scalars [r, xi2, c_inv, m]
    nv_ref,  # (1, 1) number of valid rows (N before padding)
    w_out_ref,  # (1, D) output weights
    s_out_ref,  # (1, 4) output scalars
    w_ref,  # VMEM scratch (1, D) — persistent ball center
    st_ref,  # SMEM scratch (4,) — persistent [r, xi2, wsq, m]
    *,
    block_n: int,
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        w_ref[...] = w0_ref[...]
        st_ref[0] = s0_ref[0, 0]  # r
        st_ref[1] = s0_ref[0, 1]  # xi2
        st_ref[2] = jnp.sum(w0_ref[...] * w0_ref[...])  # |w|^2
        st_ref[3] = s0_ref[0, 3]  # m (as float)

    c_inv = s0_ref[0, 2]
    n_valid = nv_ref[0, 0]

    yx = x_ref[...] * y_ref[...]  # (block_n, D) label-signed rows
    # Block Gram and state inner products — MXU work.
    gram = jax.lax.dot_general(
        yx, yx, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (block_n, block_n)
    g0 = jax.lax.dot_general(
        yx, w_ref[...], (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )[:, 0]  # (block_n,)

    row_base = step * block_n
    row_ids = row_base + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = (row_ids < n_valid).astype(jnp.float32)

    def body(j, carry):
        g, w, r, xi2, wsq, m = carry
        # d^2 = |w|^2 - 2 g_j + G_jj + xi2 + 1/C  (current w)
        gj = g[j]
        d2 = wsq - 2.0 * gj + gram[j, j] + xi2 + c_inv
        d = jnp.sqrt(jnp.maximum(d2, 1e-12))
        upd = jnp.logical_and(d >= r, valid[j] > 0.0)
        s = jnp.where(upd, 0.5 * (1.0 - r / d), 0.0)
        # rank-1 maintenance of g_k = <w, yx_k> after w <- (1-s) w + s yx_j
        g = (1.0 - s) * g + s * gram[j]
        w = (1.0 - s) * w + s * yx[j][None, :]
        wsq = (1.0 - s) ** 2 * wsq + 2.0 * s * (1.0 - s) * gj + s**2 * gram[j, j]
        r = jnp.where(upd, r + 0.5 * (d - r), r)
        xi2 = xi2 * (1.0 - s) ** 2 + s**2 * c_inv
        m = m + jnp.where(upd, 1.0, 0.0)
        return g, w, r, xi2, wsq, m

    g, w, r, xi2, wsq, m = jax.lax.fori_loop(
        0,
        block_n,
        body,
        (g0, w_ref[...], st_ref[0], st_ref[1], st_ref[2], st_ref[3]),
    )
    w_ref[...] = w
    st_ref[0], st_ref[1], st_ref[2], st_ref[3] = r, xi2, wsq, m

    @pl.when(step == pl.num_programs(0) - 1)
    def _finish():
        w_out_ref[...] = w_ref[...]
        s_out_ref[0, 0] = st_ref[0]
        s_out_ref[0, 1] = st_ref[1]
        s_out_ref[0, 2] = c_inv
        s_out_ref[0, 3] = st_ref[3]


def streamsvm_scan_pallas(
    X: jax.Array,
    y: jax.Array,
    w0: jax.Array,
    r0,
    xi20,
    c_inv,
    m0,
    *,
    n_valid: int | None = None,
    block_n: int = 256,
    interpret: bool | None = None,
):
    """Run Algorithm 1 from (w0, r0, xi20, m0) over the padded stream (X, y).

    X: (N, D) float32 — D should be padded to a multiple of 128 by ops.py,
    N to a multiple of block_n; rows >= n_valid are ignored.
    Returns (w, r, xi2, m).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = X.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)

    w0 = w0.reshape(1, d).astype(jnp.float32)
    s0 = jnp.array([[r0, xi20, c_inv, m0]], jnp.float32)
    nv = jnp.array([[n if n_valid is None else n_valid]], jnp.int32)

    w_out, s_out = pl.pallas_call(
        functools.partial(_kernel, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 4), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.SMEM((4,), jnp.float32),
        ],
        interpret=interpret,
    )(X.astype(jnp.float32), y.reshape(n, 1).astype(jnp.float32), w0, s0, nv)
    return w_out[0], s_out[0, 0], s_out[0, 1], s_out[0, 3].astype(jnp.int32)
