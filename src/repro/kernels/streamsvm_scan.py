"""Pallas TPU kernel: one-pass StreamSVM over a VMEM-blocked stream.

TPU adaptation of Algorithm 1 (DESIGN.md §3). The ball state (w, R, xi2, M)
lives in VMEM/SMEM scratch across a *sequential* grid over row-blocks of the
stream; each grid step:

  1. loads a (block_n, D) tile of label-signed rows from HBM into VMEM,
  2. computes the block Gram matrix G = YX YX^T and the state inner products
     g_j = <w, yx_j> on the MXU (one matmul + one matvec per block instead of
     the paper's per-row scalar loop),
  3. runs the inherently-sequential conditional updates with an in-register
     fori_loop over the block's rows, maintaining <w, yx_k> for k > j with
     rank-1 corrections from G (O(block_n) per row) and updating w itself
     with a single AXPY per *accepted* row.

Per-block cost: one (block_n x D x block_n) matmul + block_n * O(block_n + D)
vector work — MXU-friendly, and exactly equal in result to the reference
scan (tests sweep shapes/dtypes against ref.py).

Scalar state is carried in an SMEM (4,)-vector: [r, xi2, m, n_valid].

The multi-ball variant (`_kernel_many` / `streamsvm_scan_many_pallas`) is the
same pass generalized to a BANK of B independent models: a (B, D) bank of
ball centers plus a (4, B) scalar block live in VMEM scratch, each (block_n,
D) tile is read from HBM once, and one shared unsigned block Gram + one
bank/tile matmul feed a fori_loop whose conditional update is vectorized
across the model axis (per-model label signs re-applied as rank-1 factors).
The bank itself is updated once per block via accumulated (decay, alpha)
coefficients — a single (B, block_n) x (block_n, D) matmul — so B models cost
one pass of data movement.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    x_ref,  # (block_n, D) VMEM tile of X
    y_ref,  # (block_n, 1) VMEM tile of labels
    w0_ref,  # (1, D) initial weight vector
    s0_ref,  # (1, 4) initial scalars [r, xi2, c_inv, m]
    nv_ref,  # (1, 1) number of valid rows (N before padding)
    w_out_ref,  # (1, D) output weights
    s_out_ref,  # (1, 4) output scalars
    w_ref,  # VMEM scratch (1, D) — persistent ball center
    st_ref,  # SMEM scratch (4,) — persistent [r, xi2, wsq, m]
    *,
    block_n: int,
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        w_ref[...] = w0_ref[...]
        st_ref[0] = s0_ref[0, 0]  # r
        st_ref[1] = s0_ref[0, 1]  # xi2
        st_ref[2] = jnp.sum(w0_ref[...] * w0_ref[...])  # |w|^2
        st_ref[3] = s0_ref[0, 3]  # m (as float)

    c_inv = s0_ref[0, 2]
    n_valid = nv_ref[0, 0]

    yx = x_ref[...] * y_ref[...]  # (block_n, D) label-signed rows
    # Block Gram and state inner products — MXU work.
    gram = jax.lax.dot_general(
        yx, yx, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (block_n, block_n)
    g0 = jax.lax.dot_general(
        yx, w_ref[...], (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )[:, 0]  # (block_n,)

    row_base = step * block_n
    row_ids = row_base + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = (row_ids < n_valid).astype(jnp.float32)

    def body(j, carry):
        g, w, r, xi2, wsq, m = carry
        # d^2 = |w|^2 - 2 g_j + G_jj + xi2 + 1/C  (current w)
        gj = g[j]
        d2 = wsq - 2.0 * gj + gram[j, j] + xi2 + c_inv
        d = jnp.sqrt(jnp.maximum(d2, 1e-12))
        upd = jnp.logical_and(d >= r, valid[j] > 0.0)
        s = jnp.where(upd, 0.5 * (1.0 - r / d), 0.0)
        # rank-1 maintenance of g_k = <w, yx_k> after w <- (1-s) w + s yx_j
        g = (1.0 - s) * g + s * gram[j]
        w = (1.0 - s) * w + s * yx[j][None, :]
        wsq = (1.0 - s) ** 2 * wsq + 2.0 * s * (1.0 - s) * gj + s**2 * gram[j, j]
        r = jnp.where(upd, r + 0.5 * (d - r), r)
        xi2 = xi2 * (1.0 - s) ** 2 + s**2 * c_inv
        m = m + jnp.where(upd, 1.0, 0.0)
        return g, w, r, xi2, wsq, m

    g, w, r, xi2, wsq, m = jax.lax.fori_loop(
        0,
        block_n,
        body,
        (g0, w_ref[...], st_ref[0], st_ref[1], st_ref[2], st_ref[3]),
    )
    w_ref[...] = w
    st_ref[0], st_ref[1], st_ref[2], st_ref[3] = r, xi2, wsq, m

    @pl.when(step == pl.num_programs(0) - 1)
    def _finish():
        w_out_ref[...] = w_ref[...]
        s_out_ref[0, 0] = st_ref[0]
        s_out_ref[0, 1] = st_ref[1]
        s_out_ref[0, 2] = c_inv
        s_out_ref[0, 3] = st_ref[3]


def _kernel_many(
    x_ref,  # (block_n, D) VMEM tile of X (raw, unsigned rows)
    ys_ref,  # (B, block_n) VMEM tile of per-model label signs
    w0_ref,  # (B, D) initial ball-center bank
    s0_ref,  # (B, 4) initial scalars [r, xi2, c_inv, _] per model
    m0_ref,  # (B, 1) initial core-vector counts (int32)
    gain_ref,  # (B, 1) per-model slack gain (1/C exact, 1.0 paper-listing)
    nv_ref,  # (1, 1) number of valid rows (N before padding)
    w_out_ref,  # (B, D) output bank
    s_out_ref,  # (B, 4) output scalars
    m_out_ref,  # (B, 1) output core-vector counts (int32)
    w_ref,  # VMEM scratch (B, D) — persistent bank of ball centers
    st_ref,  # VMEM scratch (4, B) — persistent rows [r, xi2, wsq, _]
    m_ref,  # VMEM scratch (1, B) int32 — persistent m (exact past 2^24)
    *,
    block_n: int,
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        w_ref[...] = w0_ref[...]
        st_ref[0, :] = s0_ref[:, 0]  # r
        st_ref[1, :] = s0_ref[:, 1]  # xi2
        st_ref[2, :] = jnp.sum(w0_ref[...] * w0_ref[...], axis=1)  # |w_b|^2
        st_ref[3, :] = jnp.zeros_like(s0_ref[:, 3])
        m_ref[0, :] = m0_ref[:, 0]

    c_inv = s0_ref[:, 2]  # (B,)
    gain = gain_ref[:, 0]  # (B,)
    n_valid = nv_ref[0, 0]

    x = x_ref[...]  # (block_n, D)
    ys = ys_ref[...]  # (B, block_n)
    # One block Gram of the *unsigned* rows, shared by every model (signs are
    # re-applied per model as rank-1 outer factors), plus the bank/tile inner
    # products — the only O(D) work in the block, all MXU.
    gram = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (block_n, block_n)
    h0 = jax.lax.dot_general(
        w_ref[...], x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (B, block_n): <w_b, x_k>
    g0 = ys * h0  # g[b, k] = <w_b, y_bk x_k>

    row_base = step * block_n
    row_ids = row_base + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = (row_ids < n_valid).astype(jnp.float32)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, ys.shape, 1)  # (B, block_n)

    def body(j, carry):
        g, alpha, decay, r, xi2, wsq, m = carry
        gj = g[:, j]  # (B,) current <w_b, y_bj x_j>
        gjj = gram[j, j]
        d2 = wsq - 2.0 * gj + gjj + xi2 + c_inv
        d = jnp.sqrt(jnp.maximum(d2, 1e-12))
        upd = jnp.logical_and(d >= r, valid[j] > 0.0)
        s = jnp.where(upd, 0.5 * (1.0 - r / d), 0.0)  # (B,)
        one_s = 1.0 - s
        yj = ys[:, j]  # (B,)
        # rank-1 maintenance of g under w_b <- (1-s_b) w_b + s_b y_bj x_j:
        # <x_j, y_bk x_k> = y_bk G[j, k]
        g = one_s[:, None] * g + (s * yj)[:, None] * (ys * gram[j][None, :])
        # Deferred bank update: w_end = decay * w_start + sum_j alpha_j y_bj x_j,
        # with alpha_j = s_j * prod_{k>j} (1 - s_k) — applied post-loop as one
        # (B, block_n) x (block_n, D) matmul instead of a per-row AXPY.
        alpha = one_s[:, None] * alpha + jnp.where(col_ids == j, s[:, None], 0.0)
        decay = decay * one_s
        wsq = one_s**2 * wsq + 2.0 * s * one_s * gj + s**2 * gjj
        r = jnp.where(upd, r + 0.5 * (d - r), r)
        xi2 = xi2 * one_s**2 + s**2 * gain
        m = m + upd.astype(jnp.int32)
        return g, alpha, decay, r, xi2, wsq, m

    B = ys.shape[0]
    init = (
        g0,
        jnp.zeros_like(g0),
        jnp.ones((B,), jnp.float32),
        st_ref[0, :],
        st_ref[1, :],
        st_ref[2, :],
        m_ref[0, :],
    )
    g, alpha, decay, r, xi2, wsq, m = jax.lax.fori_loop(0, block_n, body, init)
    w_ref[...] = decay[:, None] * w_ref[...] + jax.lax.dot_general(
        alpha * ys, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    st_ref[0, :], st_ref[1, :], st_ref[2, :] = r, xi2, wsq
    m_ref[0, :] = m

    @pl.when(step == pl.num_programs(0) - 1)
    def _finish():
        w_out_ref[...] = w_ref[...]
        s_out_ref[...] = jnp.stack(
            (st_ref[0, :], st_ref[1, :], c_inv, st_ref[3, :]), axis=-1
        )
        m_out_ref[...] = m_ref[0, :][:, None]


def streamsvm_scan_pallas(
    X: jax.Array,
    y: jax.Array,
    w0: jax.Array,
    r0,
    xi20,
    c_inv,
    m0,
    *,
    n_valid: int | None = None,
    block_n: int = 256,
    interpret: bool | None = None,
):
    """Run Algorithm 1 from (w0, r0, xi20, m0) over the padded stream (X, y).

    X: (N, D) float32 — D should be padded to a multiple of 128 by ops.py,
    N to a multiple of block_n; rows >= n_valid are ignored.
    Returns (w, r, xi2, m).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = X.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)

    w0 = w0.reshape(1, d).astype(jnp.float32)
    s0 = jnp.array([[r0, xi20, c_inv, m0]], jnp.float32)
    nv = jnp.array([[n if n_valid is None else n_valid]], jnp.int32)

    w_out, s_out = pl.pallas_call(
        functools.partial(_kernel, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 4), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.SMEM((4,), jnp.float32),
        ],
        interpret=interpret,
    )(X.astype(jnp.float32), y.reshape(n, 1).astype(jnp.float32), w0, s0, nv)
    return w_out[0], s_out[0, 0], s_out[0, 1], s_out[0, 3].astype(jnp.int32)


def streamsvm_scan_many_pallas(
    X: jax.Array,
    Y: jax.Array,
    W0: jax.Array,
    r0: jax.Array,
    xi20: jax.Array,
    c_inv: jax.Array,
    m0: jax.Array,
    gain: jax.Array | None = None,
    *,
    n_valid: int | None = None,
    block_n: int = 256,
    interpret: bool | None = None,
):
    """One data pass updating a bank of B balls (the multi-ball engine).

    X: (N, D) float32 stream (raw rows, no label signs) — D padded to a
    multiple of 128, N to a multiple of block_n; rows >= n_valid are ignored.
    Y: (B, N) per-model label signs in {-1, +1} (0 on padded model rows).
    W0/(r0, xi20, c_inv, m0): per-model starting state, shapes (B, D)/(B,).
    gain: per-model slack gain (defaults to c_inv — the "exact" variant).

    Every (block_n, D) tile is loaded from HBM once and updates all B models:
    one block Gram matmul + one bank/tile matmul feed a fori_loop that runs
    the sequential conditional updates vectorized across the model axis.
    Returns (W, r, xi2, m) with leading axis B.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = X.shape
    b = Y.shape[0]
    assert Y.shape == (b, n), (Y.shape, (b, n))
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)

    W0 = W0.reshape(b, d).astype(jnp.float32)
    c_inv = jnp.broadcast_to(jnp.asarray(c_inv, jnp.float32), (b,))
    gain = c_inv if gain is None else jnp.broadcast_to(
        jnp.asarray(gain, jnp.float32), (b,)
    )
    s0 = jnp.stack(
        [
            jnp.broadcast_to(jnp.asarray(r0, jnp.float32), (b,)),
            jnp.broadcast_to(jnp.asarray(xi20, jnp.float32), (b,)),
            c_inv,
            jnp.zeros((b,), jnp.float32),
        ],
        axis=-1,
    )  # (B, 4)
    m0 = jnp.broadcast_to(jnp.asarray(m0, jnp.int32), (b,)).reshape(b, 1)
    nv = jnp.array([[n if n_valid is None else n_valid]], jnp.int32)

    w_out, s_out, m_out = pl.pallas_call(
        functools.partial(_kernel_many, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((b, block_n), lambda i: (0, i)),
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((b, 4), lambda i: (0, 0)),
            pl.BlockSpec((b, 1), lambda i: (0, 0)),
            pl.BlockSpec((b, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((b, 4), lambda i: (0, 0)),
            pl.BlockSpec((b, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, 4), jnp.float32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((b, d), jnp.float32),
            pltpu.VMEM((4, b), jnp.float32),
            pltpu.VMEM((1, b), jnp.int32),
        ],
        interpret=interpret,
    )(
        X.astype(jnp.float32),
        Y.astype(jnp.float32),
        W0,
        s0,
        m0,
        gain.reshape(b, 1),
        nv,
    )
    return w_out, s_out[:, 0], s_out[:, 1], m_out[:, 0]
