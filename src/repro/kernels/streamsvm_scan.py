"""Pallas TPU kernel: one-pass StreamSVM over a VMEM-blocked stream.

TPU adaptation of Algorithm 1 (DESIGN.md §3). The ball state (w, R, xi2, M)
lives in VMEM/SMEM scratch across a *sequential* grid over row-blocks of the
stream; each grid step:

  1. loads a (block_n, D) tile of label-signed rows from HBM into VMEM,
  2. computes the block Gram matrix G = YX YX^T and the state inner products
     g_j = <w, yx_j> on the MXU (one matmul + one matvec per block instead of
     the paper's per-row scalar loop),
  3. runs the inherently-sequential conditional updates with an in-register
     fori_loop over the block's rows, maintaining <w, yx_k> for k > j with
     rank-1 corrections from G (O(block_n) per row) and updating w itself
     with a single AXPY per *accepted* row.

Per-block cost: one (block_n x D x block_n) matmul + block_n * O(block_n + D)
vector work — MXU-friendly, and exactly equal in result to the reference
scan (tests sweep shapes/dtypes against ref.py).

Scalar state is carried in an SMEM (4,)-vector: [r, xi2, m, n_valid].

The multi-ball variant (`_kernel_many_tiled` / `streamsvm_scan_many_pallas`)
is the same pass generalized to a BANK of B independent models on a 2-D grid
``(n_block, bank_tile)`` with DATA-MAJOR iteration order: the data-block axis
is outer and the bank-tile axis inner, so each (block_n, D) stream tile is
fetched from HBM exactly once (its BlockSpec index ignores the bank axis, so
Pallas elides the re-copy across the inner iterations) and is revisited by
every (b_tile, D) slice of the bank. The full (B, D) bank plus the (4, B)
scalar block live tiled across VMEM-resident scratch, dynamically sliced per
bank tile — the per-step BlockSpec working set is O(b_tile * D + block_n * D)
no matter how large B grows, which lifts PR 1's "whole bank per grid step"
VMEM cap. Per (i, j) step: one shared unsigned block Gram + one tile/block
matmul feed a fori_loop whose conditional update is vectorized across the
b_tile model lanes (per-model label signs re-applied as rank-1 factors), and
the bank tile is updated once per block via accumulated (decay, alpha)
coefficients — a single (b_tile, block_n) x (block_n, D) matmul. B models
still cost ONE pass of data movement, now for arbitrary B.

The fused Algorithm-2 variant (``lookahead`` is not None) defers acceptance:
violating rows are pushed into a per-model L-row VMEM buffer (persistent
scratch, like the bank) and only when a model's buffer fills is it flushed —
repeatedly absorbing the FARTHEST buffered point (the paper's farthest-point
lookahead; greedy Badoiu-Clarkson insertion over the window) and dropping
buffered points the grown ball now encloses. Per-model L rides a (B,) input;
buffers persist across block AND tile boundaries, with a final partial flush
on the last grid step (same boundary-flush semantics as fit_chunked).

Stream tiles may be bf16 (``X``/``Y`` dtype is whatever the caller DMAs in —
see ops.py's ``stream_dtype`` policy); the bank, scalar state, and every
accumulator stay f32 in scratch.

Bank residency (``bank_resident``): the tiled kernel exists in two layouts
sharing ONE compute core (``_block_update`` — identical arithmetic, so the
two are bit-exact in f32):

  "vmem"  the full (B, D) bank + (4, B) state + (B*L, D) lookahead windows
          persist in VMEM scratch across the grid (the PR 2 layout). Fast,
          but B*D is capped by VMEM.
  "hbm"   the bank, state, and windows live in HBM/ANY-space buffers
          (aliased pallas_call inputs→outputs, so the update is in place)
          and the kernel streams (b_tile, D) slices through a 2-slot VMEM
          ring buffer with ``pltpu.make_async_copy``: the prefetch of grid
          step t+1's tile into ring slot (t+1) % 2 is issued BEFORE compute
          on step t's slot t % 2, and the updated tile is written back
          async — its wait deferred to step t+1 — so both DMA directions
          overlap the MXU work of the (stream tile x bank tile) step. DMA
          semaphores live in scratch (one in/out pair per slot per array).
          Correctness of the ring: every step t >= 1 first waits the
          writeback issued at t-1, so by the time step t prefetches tile
          (t+1) % J, the last writeback of that tile (issued at step
          t+1-J <= t-1) has already been waited — no RAW through HBM, and
          the slot being prefetched into is never still draining (WAR).
          With J = B/b_tile <= 2 tiles there is nothing to cycle: the bank
          loads once on the first visit and writes back once on the last.

ops.py's ``auto`` policy picks the residency from a per-step VMEM byte
model; the per-step VMEM working set in "hbm" mode is O(ring slots + stream
tile) no matter how large B*D grows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    x_ref,  # (block_n, D) VMEM tile of X
    y_ref,  # (block_n, 1) VMEM tile of labels
    w0_ref,  # (1, D) initial weight vector
    s0_ref,  # (1, 4) initial scalars [r, xi2, c_inv, m]
    nv_ref,  # (1, 1) number of valid rows (N before padding)
    w_out_ref,  # (1, D) output weights
    s_out_ref,  # (1, 4) output scalars
    w_ref,  # VMEM scratch (1, D) — persistent ball center
    st_ref,  # SMEM scratch (4,) — persistent [r, xi2, wsq, m]
    *,
    block_n: int,
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        w_ref[...] = w0_ref[...]
        st_ref[0] = s0_ref[0, 0]  # r
        st_ref[1] = s0_ref[0, 1]  # xi2
        st_ref[2] = jnp.sum(w0_ref[...] * w0_ref[...])  # |w|^2
        st_ref[3] = s0_ref[0, 3]  # m (as float)

    c_inv = s0_ref[0, 2]
    n_valid = nv_ref[0, 0]

    yx = x_ref[...] * y_ref[...]  # (block_n, D) label-signed rows
    # Block Gram and state inner products — MXU work.
    gram = jax.lax.dot_general(
        yx, yx, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (block_n, block_n)
    g0 = jax.lax.dot_general(
        yx, w_ref[...], (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )[:, 0]  # (block_n,)

    row_base = step * block_n
    row_ids = row_base + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    # Rows past n_valid AND rows with label sign 0 are inert: sign-0 rows are
    # the stream-padding contract (fit_bank_sharded pads ragged shard
    # remainders with them), distinct from a genuine zero FEATURE row, which
    # is a legitimate slack-only point.
    valid = jnp.logical_and(
        row_ids < n_valid, y_ref[...][:, 0] != 0.0
    ).astype(jnp.float32)

    def body(j, carry):
        g, w, r, xi2, wsq, m = carry
        # d^2 = |w|^2 - 2 g_j + G_jj + xi2 + 1/C  (current w)
        gj = g[j]
        d2 = wsq - 2.0 * gj + gram[j, j] + xi2 + c_inv
        d = jnp.sqrt(jnp.maximum(d2, 1e-12))
        upd = jnp.logical_and(d >= r, valid[j] > 0.0)
        s = jnp.where(upd, 0.5 * (1.0 - r / d), 0.0)
        # rank-1 maintenance of g_k = <w, yx_k> after w <- (1-s) w + s yx_j
        g = (1.0 - s) * g + s * gram[j]
        w = (1.0 - s) * w + s * yx[j][None, :]
        wsq = (1.0 - s) ** 2 * wsq + 2.0 * s * (1.0 - s) * gj + s**2 * gram[j, j]
        r = jnp.where(upd, r + 0.5 * (d - r), r)
        xi2 = xi2 * (1.0 - s) ** 2 + s**2 * c_inv
        m = m + jnp.where(upd, 1.0, 0.0)
        return g, w, r, xi2, wsq, m

    g, w, r, xi2, wsq, m = jax.lax.fori_loop(
        0,
        block_n,
        body,
        (g0, w_ref[...], st_ref[0], st_ref[1], st_ref[2], st_ref[3]),
    )
    w_ref[...] = w
    st_ref[0], st_ref[1], st_ref[2], st_ref[3] = r, xi2, wsq, m

    @pl.when(step == pl.num_programs(0) - 1)
    def _finish():
        w_out_ref[...] = w_ref[...]
        s_out_ref[0, 0] = st_ref[0]
        s_out_ref[0, 1] = st_ref[1]
        s_out_ref[0, 2] = c_inv
        s_out_ref[0, 3] = st_ref[3]


def _bank_flush(w, r, xi2, g, cnt, buf, fmask, x, ys, c_inv, gain):
    """Farthest-first flush of the lookahead buffers of the masked models.

    Vectorized over the b_tile model lanes: up to L_max greedy steps, each
    absorbing the farthest still-buffered point of every flushing model (the
    Algorithm-1 update), dropping the whole remaining window as soon as its
    farthest point is already enclosed. ``g`` (the maintained <w, y x_k> for
    the rest of the current block) picks up a rank-1 correction per absorb via
    one (b_tile, D) x (D, block_n) matmul. Returns the updated carry pieces
    (m is counted at buffer-push time, not here).
    """
    bt, l_max, _ = buf.shape
    slot = jax.lax.broadcasted_iota(jnp.int32, (bt, l_max), 1)
    remain = jnp.logical_and(slot < cnt[:, None], fmask[:, None])

    def fstep(_, carry):
        w, r, xi2, g, remain = carry
        bd2 = (
            jnp.sum((w[:, None, :] - buf) ** 2, axis=-1)
            + xi2[:, None]
            + c_inv[:, None]
        )  # (bt, L)
        bd = jnp.sqrt(jnp.maximum(bd2, 1e-12))
        bdm = jnp.where(remain, bd, -jnp.inf)
        far = jnp.argmax(bdm, axis=1)  # (bt,)
        dfar = jnp.max(bdm, axis=1)
        has = jnp.any(remain, axis=1)
        act = jnp.logical_and(has, dfar >= r)  # absorb only live violators
        s = jnp.where(act, 0.5 * (1.0 - r / jnp.where(act, dfar, 1.0)), 0.0)
        one_s = 1.0 - s
        sel = slot == far[:, None]
        pfar = jnp.sum(jnp.where((sel & remain)[:, :, None], buf, 0.0), axis=1)
        w = one_s[:, None] * w + s[:, None] * pfar
        r = jnp.where(act, r + 0.5 * (dfar - r), r)
        xi2 = xi2 * one_s**2 + s**2 * gain
        # <w', y_bk x_k> = (1-s) g + s y_bk <pfar, x_k>
        pg = jax.lax.dot_general(
            pfar, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bt, block_n)
        g = one_s[:, None] * g + s[:, None] * (ys * pg)
        # remove the absorbed slot; if the farthest point was enclosed, every
        # remaining buffered point is too — drop the whole window.
        drop_all = jnp.logical_and(has, jnp.logical_not(act))
        remain = jnp.logical_and(remain, jnp.logical_not(sel & act[:, None]))
        remain = jnp.where(drop_all[:, None], False, remain)
        return w, r, xi2, g, remain

    w, r, xi2, g, _ = jax.lax.fori_loop(
        0, l_max, fstep, (w, r, xi2, g, remain)
    )
    cnt = jnp.where(fmask, 0, cnt)
    return w, r, xi2, g, cnt


def _block_update(
    x,  # (block_n, D) f32 stream block (bf16 tiles already upcast)
    ys,  # (b_tile, block_n) f32 per-model label signs
    w_tile,  # (b_tile, D) f32 ball centers of the resident bank tile
    r, xi2, wsq,  # (b_tile,) f32 per-model scalars
    m,  # (b_tile,) int32 core-vector counts
    cnt,  # (b_tile,) int32 lookahead fill counts (None for Algorithm 1)
    buf,  # (b_tile, L_max, D) f32 lookahead windows (None for Algorithm 1)
    c_inv,  # (b_tile,) f32
    gain,  # (b_tile,) f32 slack gain
    l_arr,  # (b_tile,) int32 per-model L (None for Algorithm 1)
    valid,  # (block_n,) f32 row-validity mask (n_valid cutoff)
    is_last_block,  # traced bool: final data block (lookahead boundary flush)
    *,
    block_n: int,
    b_tile: int,
    lookahead_max: int | None,
):
    """One (stream block x bank tile) update — the residency-agnostic core.

    Shared op-for-op by the VMEM-resident and HBM-resident kernels, which is
    what makes the two layouts bit-exact in f32: only WHERE the bank tile
    came from differs, never the arithmetic applied to it. Returns
    ``(w, r, xi2, wsq, m, cnt, buf)`` (cnt/buf None for Algorithm 1).
    """
    # One block Gram of the *unsigned* rows, shared by every model (signs are
    # re-applied per model as rank-1 outer factors), plus the tile/block inner
    # products — the only O(D) work in the block, all MXU.
    gram = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (block_n, block_n)
    h0 = jax.lax.dot_general(
        w_tile, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (b_tile, block_n): <w_b, x_k>
    g0 = ys * h0  # g[b, k] = <w_b, y_bk x_k>
    col_ids = jax.lax.broadcasted_iota(jnp.int32, ys.shape, 1)  # (b_tile, block_n)
    # Sign-0 inertness is PER MODEL LANE here: a row whose sign is 0 for
    # model b never violates model b (the stream-padding contract used by
    # fit_bank_sharded's ragged-remainder rows, and what keeps padded *bank*
    # lanes from absorbing anything).

    if lookahead_max is None:
        # ----- Algorithm 1: immediate greedy acceptance (bit-exact with the
        # single-tile PR 1 path — identical per-lane arithmetic). -----
        def body(jr, carry):
            g, alpha, decay, r, xi2, wsq, m = carry
            gj = g[:, jr]  # (b_tile,) current <w_b, y_bj x_j>
            gjj = gram[jr, jr]
            d2 = wsq - 2.0 * gj + gjj + xi2 + c_inv
            d = jnp.sqrt(jnp.maximum(d2, 1e-12))
            yj = ys[:, jr]  # (b_tile,)
            upd = jnp.logical_and(
                jnp.logical_and(d >= r, valid[jr] > 0.0), yj != 0.0
            )
            s = jnp.where(upd, 0.5 * (1.0 - r / d), 0.0)  # (b_tile,)
            one_s = 1.0 - s
            # rank-1 maintenance of g under w_b <- (1-s_b) w_b + s_b y_bj x_j:
            # <x_j, y_bk x_k> = y_bk G[j, k]
            g = one_s[:, None] * g + (s * yj)[:, None] * (ys * gram[jr][None, :])
            # Deferred bank update: w_end = decay * w_start + sum_j alpha_j
            # y_bj x_j with alpha_j = s_j * prod_{k>j} (1 - s_k) — applied
            # post-loop as ONE (b_tile, block_n) x (block_n, D) matmul.
            alpha = one_s[:, None] * alpha + jnp.where(
                col_ids == jr, s[:, None], 0.0
            )
            decay = decay * one_s
            wsq = one_s**2 * wsq + 2.0 * s * one_s * gj + s**2 * gjj
            r = jnp.where(upd, r + 0.5 * (d - r), r)
            xi2 = xi2 * one_s**2 + s**2 * gain
            m = m + upd.astype(jnp.int32)
            return g, alpha, decay, r, xi2, wsq, m

        init = (
            g0,
            jnp.zeros_like(g0),
            jnp.ones((b_tile,), jnp.float32),
            r, xi2, wsq, m,
        )
        g, alpha, decay, r, xi2, wsq, m = jax.lax.fori_loop(
            0, block_n, body, init
        )
        w = decay[:, None] * w_tile + jax.lax.dot_general(
            alpha * ys, x, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return w, r, xi2, wsq, m, None, None

    # ----- Algorithm 2: deferred acceptance through per-model L-row
    # lookahead windows, flushed farthest-point-first. -----
    def body(jr, carry):
        g, w, r, xi2, wsq, m, cnt, buf = carry
        gj = g[:, jr]
        d2 = wsq - 2.0 * gj + gram[jr, jr] + xi2 + c_inv
        d = jnp.sqrt(jnp.maximum(d2, 1e-12))
        violate = jnp.logical_and(
            jnp.logical_and(d >= r, valid[jr] > 0.0), ys[:, jr] != 0.0
        )
        # push the signed row into each violated model's window
        p = ys[:, jr][:, None] * x[jr][None, :]  # (b_tile, D)
        slot = jax.lax.broadcasted_iota(
            jnp.int32, (b_tile, lookahead_max), 1
        )
        put = jnp.logical_and(violate[:, None], slot == cnt[:, None])
        buf = jnp.where(put[:, :, None], p[:, None, :], buf)
        cnt = cnt + violate.astype(jnp.int32)
        m = m + violate.astype(jnp.int32)  # counted at push (QP parity)
        full = cnt >= l_arr

        def flush(args):
            g, w, r, xi2, wsq, cnt, buf = args
            w, r, xi2, g, cnt = _bank_flush(
                w, r, xi2, g, cnt, buf, full, x, ys, c_inv, gain
            )
            # w only changes here, so |w|^2 only needs refreshing here
            return g, w, r, xi2, jnp.sum(w * w, axis=1), cnt, buf

        g, w, r, xi2, wsq, cnt, buf = jax.lax.cond(
            jnp.any(full), flush, lambda a: a,
            (g, w, r, xi2, wsq, cnt, buf),
        )
        return g, w, r, xi2, wsq, m, cnt, buf

    init = (g0, w_tile, r, xi2, wsq, m, cnt, buf)
    g, w, r, xi2, wsq, m, cnt, buf = jax.lax.fori_loop(
        0, block_n, body, init
    )

    # Final partial flush on the last data block (paper lines 12-14 /
    # fit_chunked's boundary-flush semantics).
    def final_flush(args):
        w, r, xi2, g, wsq, cnt = args
        w, r, xi2, g, cnt = _bank_flush(
            w, r, xi2, g, cnt, buf, cnt > 0, x, ys, c_inv, gain
        )
        return w, r, xi2, g, jnp.sum(w * w, axis=1), cnt

    w, r, xi2, g, wsq, cnt = jax.lax.cond(
        jnp.logical_and(is_last_block, jnp.any(cnt > 0)),
        final_flush,
        lambda a: a,
        (w, r, xi2, g, wsq, cnt),
    )
    return w, r, xi2, wsq, m, cnt, buf


def _kernel_many_tiled(
    x_ref,  # (block_n, D) stream tile (raw rows; f32 or bf16)
    ys_ref,  # (b_tile, block_n) per-model label-sign tile
    w0_ref,  # (b_tile, D) initial ball-center tile of the bank
    s0_ref,  # (b_tile, 4) initial scalars [r, xi2, c_inv, _] per model
    m0_ref,  # (b_tile, 1) initial core-vector counts (int32)
    gain_ref,  # (b_tile, 1) per-model slack gain (1/C exact, 1.0 paper-listing)
    l_ref,  # (b_tile, 1) per-model lookahead window (int32; 1 == greedy)
    nv_ref,  # (1, 1) number of valid rows (N before padding)
    w_out_ref,  # (b_tile, D) output bank tile
    s_out_ref,  # (b_tile, 4) output scalars
    m_out_ref,  # (b_tile, 1) output core-vector counts (int32)
    bank_ref,  # VMEM scratch (B, D) — persistent full bank, sliced per tile
    st_ref,  # VMEM scratch (4, B) — persistent rows [r, xi2, wsq, _]
    m_ref,  # VMEM scratch (1, B) int32 — persistent m (exact past 2^24)
    cnt_ref=None,  # VMEM scratch (1, B) int32 — lookahead buffer fill counts
    buf_ref=None,  # VMEM scratch (B * L_max, D) — lookahead windows (flat)
    *,
    block_n: int,
    b_tile: int,
    lookahead_max: int | None,
):
    i = pl.program_id(0)  # data block (outer — the stream is read ONCE)
    j = pl.program_id(1)  # bank tile (inner — revisits the resident tile)
    n_blocks = pl.num_programs(0)
    j0 = j * b_tile
    tile = pl.ds(j0, b_tile)

    @pl.when(i == 0)
    def _init():  # first visit of bank tile j
        bank_ref[tile, :] = w0_ref[...].astype(jnp.float32)
        st_ref[0, tile] = s0_ref[:, 0]  # r
        st_ref[1, tile] = s0_ref[:, 1]  # xi2
        st_ref[2, tile] = jnp.sum(
            w0_ref[...].astype(jnp.float32) ** 2, axis=1
        )  # |w_b|^2
        st_ref[3, tile] = jnp.zeros_like(s0_ref[:, 3])
        m_ref[0, tile] = m0_ref[:, 0]
        if lookahead_max is not None:
            cnt_ref[0, tile] = jnp.zeros((b_tile,), jnp.int32)
            buf_ref[pl.ds(j0 * lookahead_max, b_tile * lookahead_max), :] = (
                jnp.zeros((b_tile * lookahead_max, buf_ref.shape[1]), jnp.float32)
            )

    c_inv = s0_ref[:, 2]  # (b_tile,)
    gain = gain_ref[:, 0]  # (b_tile,)
    n_valid = nv_ref[0, 0]

    x = x_ref[...].astype(jnp.float32)  # (block_n, D) — bf16 tiles upcast here
    ys = ys_ref[...].astype(jnp.float32)  # (b_tile, block_n)
    w_tile = bank_ref[tile, :]  # (b_tile, D)

    row_base = i * block_n
    row_ids = row_base + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = (row_ids < n_valid).astype(jnp.float32)

    if lookahead_max is None:
        l_arr, cnt0, buf0 = None, None, None
    else:
        l_arr = l_ref[:, 0]  # (b_tile,) per-model L
        btile_rows = pl.ds(j0 * lookahead_max, b_tile * lookahead_max)
        cnt0 = cnt_ref[0, tile]
        buf0 = buf_ref[btile_rows, :].reshape(
            b_tile, lookahead_max, x.shape[1]
        )

    w, r, xi2, wsq, m, cnt, buf = _block_update(
        x, ys, w_tile,
        st_ref[0, tile], st_ref[1, tile], st_ref[2, tile], m_ref[0, tile],
        cnt0, buf0, c_inv, gain, l_arr, valid, i == n_blocks - 1,
        block_n=block_n, b_tile=b_tile, lookahead_max=lookahead_max,
    )
    bank_ref[tile, :] = w
    if lookahead_max is not None:
        cnt_ref[0, tile] = cnt
        buf_ref[btile_rows, :] = buf.reshape(
            b_tile * lookahead_max, x.shape[1]
        )

    st_ref[0, tile], st_ref[1, tile], st_ref[2, tile] = r, xi2, wsq
    m_ref[0, tile] = m

    @pl.when(i == n_blocks - 1)
    def _finish():
        w_out_ref[...] = bank_ref[tile, :]
        s_out_ref[...] = jnp.stack(
            (st_ref[0, tile], st_ref[1, tile], c_inv, st_ref[3, tile]), axis=-1
        )
        m_out_ref[...] = m_ref[0, tile][:, None]


def _kernel_many_hbm(
    x_ref,  # (block_n, D) stream tile (raw rows; f32 or bf16)
    ys_ref,  # (b_tile, block_n) per-model label-sign tile
    s0_ref,  # (b_tile, 4) per-model scalars — only column 2 (c_inv) is read
    gain_ref,  # (b_tile, 1) per-model slack gain
    l_ref,  # (b_tile, 1) per-model lookahead window (int32; 1 == greedy)
    nv_ref,  # (1, 1) number of valid rows (N before padding)
    *refs,  # aliased ANY inputs, ANY outputs, VMEM ring slots, DMA sems
    block_n: int,
    b_tile: int,
    lookahead_max: int | None,
    n_blocks: int,
    n_btiles: int,
):
    """HBM-resident layout: bank/state/windows in ANY memory, 2-slot ring.

    ``refs`` unpacks as ``n_arrays`` aliased input refs (unused — the
    aliased OUTPUT refs address the same buffers and carry the initial
    state), then ``n_arrays`` ANY-space output refs [bank (B, D) f32,
    st (4, B) f32 rows (r, xi2, wsq, unused), m (1, B) i32, and with
    lookahead cnt (1, B) i32 + buf (B * L_max, D) f32], then ``n_arrays``
    2-slot VMEM ring buffers, then one DMA-semaphore array of shape
    (n_arrays, 2, 2) = (array, in/out, slot).

    Grid step t = i * n_btiles + j works on ring slot t % 2; the schedule
    (prefetch t+1 before compute on t, async write-back of t waited at t+1)
    and its hazard argument are in the module docstring. With <= 2 bank
    tiles nothing ever cycles, so tiles load on first visit and write back
    on the last — degenerating to the VMEM-resident data movement.
    """
    n_arrays = 3 if lookahead_max is None else 5
    hbm = refs[n_arrays : 2 * n_arrays]  # aliased outputs == the live state
    rings = refs[2 * n_arrays : 3 * n_arrays]
    sems = refs[3 * n_arrays]

    i = pl.program_id(0)
    j = pl.program_id(1)
    J = n_btiles
    T = n_blocks * J
    t = i * J + j

    def _dmas(tt, direction):
        """The ring transfers of grid step tt (0 = HBM->ring, 1 = ring->HBM).

        Reconstructing the same (src, dst, semaphore) triple is how a copy
        started at one grid step is waited at a later one.
        """
        tile = jax.lax.rem(tt, J)
        # Cycling tiles alternate slots by STEP parity; with <= 2 tiles each
        # tile owns the slot with its own index for the whole pass.
        slot = jax.lax.rem(tt, 2) if J > 2 else tile
        row = lambda ref, n: ref.at[pl.ds(tile * n, n), :]  # row-major slab
        col = lambda ref, n: ref.at[:, pl.ds(tile * n, n)]  # lane slice
        slices = [row(hbm[0], b_tile), col(hbm[1], b_tile), col(hbm[2], b_tile)]
        if lookahead_max is not None:
            slices += [
                col(hbm[3], b_tile),
                row(hbm[4], b_tile * lookahead_max),
            ]
        out = []
        for a, (hslice, ring) in enumerate(zip(slices, rings)):
            pair = (hslice, ring.at[slot])
            src, dst = pair if direction == 0 else pair[::-1]
            out.append(
                pltpu.make_async_copy(src, dst, sems.at[a, direction, slot])
            )
        return out

    start_in = lambda tt: [d.start() for d in _dmas(tt, 0)]
    wait_in = lambda tt: [d.wait() for d in _dmas(tt, 0)]
    start_out = lambda tt: [d.start() for d in _dmas(tt, 1)]
    wait_out = lambda tt: [d.wait() for d in _dmas(tt, 1)]

    if J <= 2:
        # Nothing cycles: each tile owns a ring slot for the whole pass.
        @pl.when(i == 0)
        def _load():
            start_in(t)
            wait_in(t)
    else:
        @pl.when(t == 0)
        def _warmup():
            start_in(0)

        @pl.when(t >= 1)
        def _drain_writeback():  # the async write-back issued at step t-1
            wait_out(t - 1)

        @pl.when(t + 1 < T)
        def _prefetch():  # overlap tile t+1's fetch with compute on tile t
            start_in(t + 1)

        wait_in(t)

    slot = jax.lax.rem(t, 2) if J > 2 else j  # J <= 2: tile j owns slot j
    bank_ring, st_ring, m_ring = rings[0], rings[1], rings[2]

    w_tile = bank_ring[slot]  # (b_tile, D)

    @pl.when(i == 0)
    def _init_wsq():  # first visit: |w_b|^2 from the seeded centers,
        st_ring[slot, 2] = jnp.sum(w_tile**2, axis=1)  # as the VMEM init does

    c_inv = s0_ref[:, 2]  # (b_tile,)
    gain = gain_ref[:, 0]
    n_valid = nv_ref[0, 0]
    x = x_ref[...].astype(jnp.float32)
    ys = ys_ref[...].astype(jnp.float32)

    row_base = i * block_n
    row_ids = row_base + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = (row_ids < n_valid).astype(jnp.float32)

    if lookahead_max is None:
        l_arr, cnt0, buf0 = None, None, None
    else:
        l_arr = l_ref[:, 0]
        cnt0 = rings[3][slot, 0]
        buf0 = rings[4][slot].reshape(b_tile, lookahead_max, x.shape[1])

    w, r, xi2, wsq, m, cnt, buf = _block_update(
        x, ys, w_tile,
        st_ring[slot, 0], st_ring[slot, 1], st_ring[slot, 2], m_ring[slot, 0],
        cnt0, buf0, c_inv, gain, l_arr, valid, i == n_blocks - 1,
        block_n=block_n, b_tile=b_tile, lookahead_max=lookahead_max,
    )
    bank_ring[slot] = w
    st_ring[slot, 0], st_ring[slot, 1], st_ring[slot, 2] = r, xi2, wsq
    m_ring[slot, 0] = m
    if lookahead_max is not None:
        rings[3][slot, 0] = cnt
        rings[4][slot] = buf.reshape(b_tile * lookahead_max, x.shape[1])

    if J <= 2:
        @pl.when(i == n_blocks - 1)
        def _store():
            start_out(t)
            wait_out(t)
    else:
        start_out(t)  # waited at step t+1 (or just below on the last step)

        @pl.when(t == T - 1)
        def _drain_last():
            wait_out(t)


def streamsvm_scan_pallas(
    X: jax.Array,
    y: jax.Array,
    w0: jax.Array,
    r0,
    xi20,
    c_inv,
    m0,
    *,
    n_valid: int | None = None,
    block_n: int = 256,
    interpret: bool | None = None,
):
    """Run Algorithm 1 from (w0, r0, xi20, m0) over the padded stream (X, y).

    X: (N, D) float32 — D should be padded to a multiple of 128 by ops.py,
    N to a multiple of block_n; rows >= n_valid and rows with y == 0 are
    ignored. Returns (w, r, xi2, m).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = X.shape
    if n % block_n != 0:
        raise ValueError(
            f"N={n} must be a multiple of block_n={block_n} (pad the stream; "
            "ops.streamsvm_fit does this)"
        )
    grid = (n // block_n,)

    w0 = w0.reshape(1, d).astype(jnp.float32)
    s0 = jnp.array([[r0, xi20, c_inv, m0]], jnp.float32)
    nv = jnp.array([[n if n_valid is None else n_valid]], jnp.int32)

    w_out, s_out = pl.pallas_call(
        functools.partial(_kernel, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 4), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.SMEM((4,), jnp.float32),
        ],
        interpret=interpret,
    )(X.astype(jnp.float32), y.reshape(n, 1).astype(jnp.float32), w0, s0, nv)
    return w_out[0], s_out[0, 0], s_out[0, 1], s_out[0, 3].astype(jnp.int32)


def streamsvm_scan_many_pallas(
    X: jax.Array,
    Y: jax.Array,
    W0: jax.Array,
    r0: jax.Array,
    xi20: jax.Array,
    c_inv: jax.Array,
    m0: jax.Array,
    gain: jax.Array | None = None,
    *,
    lookahead: jax.Array | None = None,
    lookahead_max: int | None = None,
    n_valid: int | None = None,
    block_n: int = 256,
    b_tile: int | None = None,
    stream_dtype=None,
    bank_resident: str = "vmem",
    interpret: bool | None = None,
):
    """One data pass updating a bank of B balls (the tiled multi-ball engine).

    X: (N, D) stream (raw rows, no label signs) — D padded to a multiple of
    128, N to a multiple of block_n; rows >= n_valid are ignored.
    Y: (B, N) per-model label signs in {-1, +1}. Sign 0 marks an inert row
    for that model — padded model lanes, and padded stream rows (the ragged
    shard remainders fit_bank_sharded appends) never violate, absorb or
    buffer anything.
    W0/(r0, xi20, c_inv, m0): per-model starting state, shapes (B, D)/(B,).
    gain: per-model slack gain (defaults to c_inv — the "exact" variant).
    lookahead/lookahead_max: per-model (B,) int32 Algorithm-2 window sizes
    plus their static max — None runs Algorithm 1. Partial windows are
    flushed on the last grid step.
    b_tile: models per bank tile (must divide B; defaults to B — the PR 1
    single-tile layout). The grid is (N/block_n, B/b_tile) with the DATA axis
    outer, so every stream tile is DMA'd from HBM once and revisited by all
    bank tiles; the full bank persists in VMEM scratch across the grid.
    stream_dtype: dtype the (block_n, D) stream and (b_tile, block_n) sign
    tiles are DMA'd as (e.g. jnp.bfloat16 halves stream HBM traffic); bank,
    scalar state, and accumulators stay f32.
    bank_resident: "vmem" keeps bank/state/windows in persistent VMEM
    scratch; "hbm" keeps them in HBM/ANY and double-buffers (b_tile, D)
    slices through a 2-slot VMEM ring (see the module docstring) — bit-exact
    (f32) with "vmem", per-step VMEM working set O(ring + stream tile).
    ops.py resolves the "auto" policy before calling here.

    Returns (W, r, xi2, m) with leading axis B.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = X.shape
    b = Y.shape[0]
    if Y.shape != (b, n):
        raise ValueError(
            f"Y must be (B, N) sign rows matching X: got Y.shape={Y.shape}, "
            f"X.shape={X.shape}"
        )
    if n % block_n != 0:
        raise ValueError(
            f"N={n} must be a multiple of block_n={block_n} (pad the stream; "
            "ops.streamsvm_fit_many does this)"
        )
    if b_tile is None:
        b_tile = b
    if b % b_tile != 0:
        raise ValueError(
            f"B={b} must be a multiple of b_tile={b_tile} (pad the bank; "
            "ops.streamsvm_fit_many does this)"
        )
    if (lookahead is None) != (lookahead_max is None):
        raise ValueError(
            "lookahead (per-model array) and lookahead_max (static int) must "
            f"be passed together: got {lookahead=}, {lookahead_max=}"
        )
    if bank_resident not in ("vmem", "hbm"):
        raise ValueError(
            f"unknown bank_resident {bank_resident!r}; expected 'vmem' or "
            "'hbm' (ops.streamsvm_fit_many resolves 'auto' before calling "
            "the kernel)"
        )
    n_blocks = n // block_n
    n_btiles = b // b_tile
    grid = (n_blocks, n_btiles)
    stream_dtype = jnp.float32 if stream_dtype is None else stream_dtype

    W0 = W0.reshape(b, d).astype(jnp.float32)
    c_inv = jnp.broadcast_to(jnp.asarray(c_inv, jnp.float32), (b,))
    gain = c_inv if gain is None else jnp.broadcast_to(
        jnp.asarray(gain, jnp.float32), (b,)
    )
    s0 = jnp.stack(
        [
            jnp.broadcast_to(jnp.asarray(r0, jnp.float32), (b,)),
            jnp.broadcast_to(jnp.asarray(xi20, jnp.float32), (b,)),
            c_inv,
            jnp.zeros((b,), jnp.float32),
        ],
        axis=-1,
    )  # (B, 4)
    m0 = jnp.broadcast_to(jnp.asarray(m0, jnp.int32), (b,)).reshape(b, 1)
    l_arr = (
        jnp.ones((b,), jnp.int32)
        if lookahead is None
        else jnp.broadcast_to(jnp.asarray(lookahead, jnp.int32), (b,))
    ).reshape(b, 1)
    nv = jnp.array([[n if n_valid is None else n_valid]], jnp.int32)

    if bank_resident == "hbm":
        return _call_many_hbm(
            X.astype(stream_dtype),
            Y.astype(stream_dtype),
            W0, s0, m0, gain, l_arr, nv,
            block_n=block_n, b_tile=b_tile, lookahead_max=lookahead_max,
            n_blocks=n_blocks, n_btiles=n_btiles, interpret=interpret,
        )

    # Index maps. The stream tile ignores the (inner) bank axis, so Pallas
    # keeps it resident across all bank tiles of a data block — that is the
    # data-major reuse the 2-D grid exists for. W0 is only consumed on the
    # i == 0 row of the grid and the outputs are only stored on the last row;
    # parking their index at tile 0 elsewhere stops Pallas re-streaming
    # B x D bytes every step (outputs flush once per tile, not once per step).
    first_i = lambda i, j: (jnp.where(i == 0, j, 0), 0)
    last_i = lambda i, j: (jnp.where(i == n_blocks - 1, j, 0), 0)
    scratch = [
        pltpu.VMEM((b, d), jnp.float32),
        pltpu.VMEM((4, b), jnp.float32),
        pltpu.VMEM((1, b), jnp.int32),
    ]
    if lookahead_max is not None:
        scratch += [
            pltpu.VMEM((1, b), jnp.int32),
            pltpu.VMEM((b * lookahead_max, d), jnp.float32),
        ]

    w_out, s_out, m_out = pl.pallas_call(
        functools.partial(
            _kernel_many_tiled,
            block_n=block_n,
            b_tile=b_tile,
            lookahead_max=lookahead_max,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((b_tile, block_n), lambda i, j: (j, i)),
            pl.BlockSpec((b_tile, d), first_i),
            pl.BlockSpec((b_tile, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((b_tile, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((b_tile, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((b_tile, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b_tile, d), last_i),
            pl.BlockSpec((b_tile, 4), last_i),
            pl.BlockSpec((b_tile, 1), last_i),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, 4), jnp.float32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(
        X.astype(stream_dtype),
        Y.astype(stream_dtype),
        W0,
        s0,
        m0,
        gain.reshape(b, 1),
        l_arr,
        nv,
    )
    return w_out, s_out[:, 0], s_out[:, 1], m_out[:, 0]


def _call_many_hbm(
    X, Y, W0, s0, m0, gain, l_arr, nv,
    *,
    block_n: int,
    b_tile: int,
    lookahead_max: int | None,
    n_blocks: int,
    n_btiles: int,
    interpret: bool,
):
    """Build the HBM-resident pallas_call: aliased ANY-space state + rings.

    The bank / scalar state / lookahead windows enter as ANY-memory-space
    inputs ALIASED to the outputs, so they are pre-initialized outside the
    kernel (wsq is re-derived in-kernel on the first visit so the arithmetic
    stays identical to the VMEM init) and updated in place by the ring's
    write-backs. Per-step VMEM cost: the stream/sign tiles plus TWO
    (b_tile, D) bank slots, two (4, b_tile) state slots and, with lookahead,
    two (b_tile * L_max, D) window slots — independent of B.
    """
    b, d = W0.shape
    # st rows: [r, xi2, wsq (computed in-kernel at i == 0), unused]
    st0 = jnp.stack(
        [s0[:, 0], s0[:, 1], jnp.zeros((b,), jnp.float32),
         jnp.zeros((b,), jnp.float32)],
        axis=0,
    )  # (4, B)
    m0_row = m0.reshape(1, b)
    hbm_inputs = [W0, st0, m0_row]
    rings = [
        pltpu.VMEM((2, b_tile, d), jnp.float32),
        pltpu.VMEM((2, 4, b_tile), jnp.float32),
        pltpu.VMEM((2, 1, b_tile), jnp.int32),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, d), jnp.float32),
        jax.ShapeDtypeStruct((4, b), jnp.float32),
        jax.ShapeDtypeStruct((1, b), jnp.int32),
    ]
    if lookahead_max is not None:
        hbm_inputs += [
            jnp.zeros((1, b), jnp.int32),
            jnp.zeros((b * lookahead_max, d), jnp.float32),
        ]
        rings += [
            pltpu.VMEM((2, 1, b_tile), jnp.int32),
            pltpu.VMEM((2, b_tile * lookahead_max, d), jnp.float32),
        ]
        out_shape += [
            jax.ShapeDtypeStruct((1, b), jnp.int32),
            jax.ShapeDtypeStruct((b * lookahead_max, d), jnp.float32),
        ]
    n_arrays = len(hbm_inputs)
    n_small = 6  # x, ys, s0, gain, l, nv precede the ANY-space state arrays
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)

    outs = pl.pallas_call(
        functools.partial(
            _kernel_many_hbm,
            block_n=block_n,
            b_tile=b_tile,
            lookahead_max=lookahead_max,
            n_blocks=n_blocks,
            n_btiles=n_btiles,
        ),
        grid=(n_blocks, n_btiles),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((b_tile, block_n), lambda i, j: (j, i)),
            pl.BlockSpec((b_tile, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((b_tile, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((b_tile, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ] + [any_spec] * n_arrays,
        out_specs=[any_spec] * n_arrays,
        out_shape=out_shape,
        scratch_shapes=rings + [pltpu.SemaphoreType.DMA((n_arrays, 2, 2))],
        input_output_aliases={n_small + a: a for a in range(n_arrays)},
        interpret=interpret,
    )(
        X, Y, s0, gain.reshape(b, 1), l_arr, nv, *hbm_inputs
    )
    w_out, st_out, m_out = outs[0], outs[1], outs[2]
    return w_out, st_out[0], st_out[1], m_out[0]
