"""Pallas TPU kernel: tiled Gram / kernel-matrix blocks with epilogues.

K = A B^T tiled (bm, bn, bk) with an f32 VMEM accumulator; on the last
k-step an epilogue maps the accumulator to the kernel value:

  linear: K_ij = <a_i, b_j>
  rbf:    K_ij = exp(-gamma (|a_i|^2 + |b_j|^2 - 2 <a_i, b_j>))

Row norms are passed in (computed once by ops.py) so the RBF epilogue is a
fused elementwise transform. ``gamma`` is a TRACED (1, 1) operand staged
with a constant-index BlockSpec — a gamma sweep reuses one compilation
(the scalar-operand idiom of streamsvm_scan.py). Serves the kernelized
StreamSVM (Sec 4.2) and the lookahead QP; it is the MXU-shaped replacement
for the paper's per-element kernel evaluations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, an_ref, bn_ref, g_ref, o_ref, acc_ref, *, epilogue: str):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_step == pl.num_programs(2) - 1)
    def _epilogue():
        acc = acc_ref[...]
        if epilogue == "rbf":
            gamma = g_ref[0, 0]
            d2 = an_ref[...] + bn_ref[...].T - 2.0 * acc
            o_ref[...] = jnp.exp(-gamma * jnp.maximum(d2, 0.0)).astype(o_ref.dtype)
        else:
            o_ref[...] = acc.astype(o_ref.dtype)


def gram_pallas(
    A: jax.Array,
    B: jax.Array,
    *,
    epilogue: str = "linear",
    gamma=1.0,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
):
    """K = epilogue(A B^T). A: (M, D), B: (N, D) — pre-padded by ops.py.

    ``gamma`` may be a python float or a traced scalar: it enters the grid
    as a (1, 1) f32 operand, so it never forces a recompile.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, d = A.shape
    n, d2 = B.shape
    if d != d2 or m % bm or n % bn or d % bk:
        raise ValueError(
            f"gram_pallas needs pre-padded operands sharing the feature "
            f"axis with M % bm == 0, N % bn == 0, D % bk == 0: got "
            f"A.shape={A.shape}, B.shape={B.shape}, bm={bm}, bn={bn}, "
            f"bk={bk} (use kernels.ops.gram for arbitrary shapes)"
        )

    an = jnp.sum(A.astype(jnp.float32) ** 2, axis=1, keepdims=True)  # (M,1)
    bn_ = jnp.sum(B.astype(jnp.float32) ** 2, axis=1, keepdims=True)  # (N,1)
    g = jnp.reshape(jnp.asarray(gamma, jnp.float32), (1, 1))

    grid = (m // bm, n // bn, d // bk)
    return pl.pallas_call(
        functools.partial(_kernel, epilogue=epilogue),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j, k: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(A, B, an, bn_, g)
