"""jit'd public wrappers around the Pallas kernels (padding, dtype policy).

These are the entry points the rest of the framework uses; they handle
128-alignment padding, interpret-mode selection (CPU container vs real TPU),
bank tiling (`b_tile`), the stream dtype policy, and state packing.
Semantics match ref.py exactly (tests sweep shapes and dtypes).

Dtype policy
------------
``stream_dtype`` controls the precision the *streamed* tiles — the
(block_n, D) data tiles and (b_tile, block_n) sign tiles — are DMA'd from
HBM as. ``"bf16"`` halves stream HBM traffic, which is the dominant byte
term at scale (the bank is O(B*D) once, the stream is O(N*D) every fit).
The bank, ball scalars, and every in-kernel accumulator stay f32
regardless. Labels in {-1, 0, +1} are exact in bf16; feature rounding is
bounded by the bf16 eps sweep in tests/test_tiled_engine.py.

Compile caching
---------------
``c`` / ``cs`` enter the kernels only through the traced ``1/C`` array, so
sweeping C values NEVER recompiles — only shape, ``block_n``, ``b_tile``,
``variant``, ``lookahead``, ``bank_resident`` and dtype changes do
(regression-tested via the jit cache in tests/test_tiled_engine.py and
tests/test_hbm_bank.py).

Bank residency policy
---------------------
``bank_resident`` picks where the engine keeps the (B, D) bank (plus state
and lookahead windows) while the grid runs:

  "vmem"  persistent VMEM scratch — the per-step working set contains the
          WHOLE bank, so B*D is capped by the VMEM budget;
  "hbm"   HBM/ANY-space buffers streamed through a 2-slot VMEM ring with
          async DMA (prefetch overlapped with compute) — the per-step
          working set is O(b_tile * D), independent of B;
  "auto"  picks from the per-step VMEM byte model (``engine_vmem_bytes`` /
          ``predict_vmem_bytes``) against a budget: the default
          ``DEFAULT_VMEM_BUDGET_BYTES`` (16 MiB — the guide number for a
          TPU core), overridable per call (``vmem_budget_bytes=``) or per
          process (``REPRO_VMEM_BUDGET_BYTES``).

Configs that fit NO residency (e.g. a single (b_tile, D) ring slot already
beyond the budget) are rejected up front with a ValueError carrying the
byte breakdown — including when ``bank_resident="vmem"`` is forced on an
oversized bank, which previously died deep inside Pallas lowering with an
opaque scratch-allocation error.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.meb import Ball
from .gram import gram_pallas
from .predict import NEG_MASK, predict_bank_pallas
from .streamsvm_scan import streamsvm_scan_many_pallas, streamsvm_scan_pallas

_STREAM_DTYPES = {
    None: None,
    "f32": jnp.float32,
    "float32": jnp.float32,
    "bf16": jnp.bfloat16,
    "bfloat16": jnp.bfloat16,
}


def _resolve_stream_dtype(stream_dtype):
    if stream_dtype in _STREAM_DTYPES:
        return _STREAM_DTYPES[stream_dtype]
    try:
        return jnp.dtype(stream_dtype).type
    except TypeError:
        raise ValueError(
            f"unknown stream_dtype {stream_dtype!r}; expected None, 'f32', "
            "'bf16', or a jnp dtype"
        ) from None


def bank_tiling(b: int, b_tile: int | None):
    """Resolve the engine's bank tiling for B models.

    Returns ``(effective_b_tile, n_bank_tiles)``: the requested tile rounded
    up to the f32 sublane multiple of 8 (default: one tile holding the whole
    bank) and the number of tiles covering the (padded) bank. The single
    source of truth for this policy — the throughput harness derives its
    modeled tile counts from here too.
    """
    bt = -(-b // 8) * 8 if b_tile is None else -(-b_tile // 8) * 8
    return bt, -(-b // bt)


def gram_tiling(m: int, n: int, bm: int, bn: int):
    """Resolve the Gram kernel's derived (bm_, bn_) block shapes.

    Shrinks the requested tiles to the data but keeps the f32 sublane/lane
    alignment Mosaic requires — bm_ a multiple of 8, bn_ a multiple of 128.
    (The old ``min(bm, max(8, m))`` produced misaligned blocks for odd M/N,
    e.g. m=100 -> bm_=100, which only survived in interpret mode.) The
    single source of truth for this policy; regression-tested on odd shapes
    in tests/test_kernel_bank.py.
    """
    bm_ = -(-min(bm, max(8, m)) // 8) * 8
    bn_ = -(-min(bn, max(128, n)) // 128) * 128
    return bm_, bn_


def ovr_group_tiling(b: int, n_classes: int, b_tile: int | None):
    """Resolve the predict engine's ovr-epilogue bank tiling for B models.

    Each group's ``n_classes`` class lanes are padded to the f32 sublane
    multiple of 8 (``nc_pad``) and the bank is tiled in WHOLE groups so a
    group's argmax never crosses a bank tile. Returns ``(nc_pad, g_tile,
    padded_groups)``: lanes per padded group, groups per bank tile (derived
    from the requested lane ``b_tile``; default one tile holding every
    group), and the group count padded to a whole number of tiles. The
    single source of truth for this policy — the serving throughput harness
    derives its modeled tile counts from here too.
    """
    g = b // n_classes
    nc_pad = -(-n_classes // 8) * 8
    g_tile = g if b_tile is None else max(1, b_tile // nc_pad)
    return nc_pad, g_tile, -(-g // g_tile) * g_tile


# ---------------------------------------------------------------------------
# Bank residency: per-step VMEM byte model + the "auto" policy
# ---------------------------------------------------------------------------

#: Default per-step VMEM budget for the "auto" residency policy (and the
#: preflight check). ~16 MiB is the classic per-core figure; real parts vary,
#: so it is overridable per call (``vmem_budget_bytes=``) and per process
#: (``REPRO_VMEM_BUDGET_BYTES``).
DEFAULT_VMEM_BUDGET_BYTES = 16 * 2**20

_BANK_RESIDENCIES = ("vmem", "hbm", "auto")


def vmem_budget_bytes(override: int | None = None) -> int:
    """The VMEM budget the residency policy checks against, in bytes."""
    if override is not None:
        return int(override)
    env = os.environ.get("REPRO_VMEM_BUDGET_BYTES")
    return int(env) if env else DEFAULT_VMEM_BUDGET_BYTES


def _stream_bytes(stream_dtype) -> int:
    dt = _resolve_stream_dtype(stream_dtype)
    return 2 if dt == jnp.bfloat16 else 4


def engine_vmem_bytes(
    b: int,
    d: int,
    *,
    block_n: int = 256,
    b_tile: int | None = None,
    stream_dtype=None,
    lookahead_max: int | None = None,
    bank_resident: str = "vmem",
) -> dict:
    """Per-step VMEM working set of the training engine, bytes by term.

    Models the padded shapes the kernel actually allocates (D to the lane
    multiple of 128, B to whole bank tiles, tiles to the sublane multiple of
    8). BlockSpec-delivered tiles count twice — Pallas double-buffers its
    own pipeline — and so do the explicit 2-slot rings of the HBM-resident
    layout. The "auto" policy and the preflight ValueError both read this;
    the BENCH harnesses record its total per row as
    ``vmem_working_set_bytes``.
    """
    sz = _stream_bytes(stream_dtype)
    bt, n_tiles = bank_tiling(b, b_tile)
    bp = bt * n_tiles
    dp = -(-d // 128) * 128
    L = lookahead_max
    state_rows = 4 + 1 + (1 if L else 0)  # st rows + m + cnt (lanes x 4B)
    out = {
        "stream_tile": 2 * block_n * dp * sz,
        "sign_tile": 2 * bt * block_n * sz,
        # per-tile params in + outputs out (w0/w, scalars, m, gain, L), all
        # staged through the BlockSpec pipeline (x2)
        "params_io": 2 * (2 * bt * dp + 2 * bt * 4 + 3 * bt) * 4,
    }
    if bank_resident == "vmem":
        out["bank"] = bp * dp * 4
        out["state"] = state_rows * bp * 4
        out["lookahead"] = bp * L * dp * 4 if L else 0
    else:
        out["bank"] = 2 * bt * dp * 4  # 2-slot ring
        out["state"] = 2 * state_rows * bt * 4
        out["lookahead"] = 2 * bt * L * dp * 4 if L else 0
    return out


def kernel_engine_vmem_bytes(
    b: int,
    d: int,
    *,
    coreset_size: int,
    block_n: int = 256,
    s_tile: int | None = None,
    stream_dtype=None,
) -> dict:
    """Per-step VMEM working set of the kernelized bank engine, bytes by term.

    The kernelized engine's resident blocks are the two fused Gram launches'
    tiles: the K_cs launch scores a (block_n, D) stream tile against the
    (B * s_chunk, D) core-set operand (``s_tile`` chunks the S axis per
    model, so the Gram N axis — and with it the operand and output tiles —
    shrinks from B*S to B*s_tile columns per launch: the kernel-bank twin of
    PR 5's ``bank_resident`` knob, same budget, same preflight), and the
    K_tt launch is (block_n, block_n). Gram operands are staged f32
    (``gram`` upcasts before padding), BlockSpec-delivered tiles count twice
    (Pallas double-buffers its own pipeline), and the f32 accumulator
    scratch counts once. The preflight in ``core.fit_kernel_bank`` and the
    BENCH engine harness's kernelized ``vmem_working_set_bytes`` both read
    this.
    """
    S = int(coreset_size)
    st = S if s_tile is None else min(int(s_tile), S)
    cols = b * st  # columns per K_cs launch
    bm_, bn_ = gram_tiling(block_n, cols, 256, 256)
    bk = min(512, -(-d // 512) * 512)  # gram pads the feature axis to 512s
    dp = -(-d // 128) * 128
    return {
        # one K_cs launch's per-step tiles: A/B operands + out + the f32
        # accumulator; BlockSpec-staged tiles count twice (Pallas double-
        # buffers its own pipeline). The grid bounds these at (256, 256)
        # regardless of B*S.
        "gram_tiles": (
            2 * (bm_ + bn_) * bk * 4 + 2 * bm_ * bn_ * 4 + bm_ * bn_ * 4
        ),
        # The terms ``s_tile`` actually caps — the whole-buffer analogues of
        # the linear engine's VMEM-resident bank term: each tile step
        # materializes the launch's full (block_n, B * s_chunk) K_cs block
        # for the recursion to read, plus the (B * s_chunk, D) gathered
        # core-set operand it was scored against.
        "k_cs_block": block_n * cols * 4,
        "coreset_operand": cols * dp * 4,
        # the K_tt block and the stream tile itself
        "k_tt": block_n * block_n * 4,
        "stream_tile": 2 * block_n * dp * 4,
    }


def predict_vmem_bytes(
    b: int,
    d: int,
    *,
    q_block: int = 256,
    b_tile: int | None = None,
    stream_dtype=None,
    epilogue: str = "scores",
    n_classes: int | None = None,
    k: int | None = None,
    bank_resident: str = "vmem",
) -> dict:
    """Per-step VMEM working set of the predict engine, bytes by term.

    The serving kernel holds no full-bank scratch in either residency — a
    (b_tile, D) slice is staged per step by the BlockSpec pipeline ("vmem")
    or the explicit 2-slot ring ("hbm"), so the two working sets coincide.
    What "hbm" changes is WHERE the bank lives between steps (ANY/HBM, never
    claiming VMEM residency) — the policy knob exists so a bank too big to
    train VMEM-resident also serves HBM-resident (see
    ``resolve_bank_resident``).
    """
    sz = _stream_bytes(stream_dtype)
    dp = -(-d // 128) * 128
    if epilogue == "ovr":
        nc_pad, g_tile, gp = ovr_group_tiling(b, n_classes, b_tile)
        bt = g_tile * nc_pad
        out_cols = 2 * g_tile  # class ids + margins
    else:
        bt, _ = bank_tiling(b, b_tile)
        out_cols = 2 * k if epilogue == "topk" else bt
    out = {
        "query_tile": 2 * q_block * dp * sz,
        "bank": 2 * bt * dp * 4,  # BlockSpec pipeline or 2-slot ring: same
        "bias": 2 * bt * 4,
        "epilogue_state": (2 * q_block * k * 4 if epilogue == "topk" else 0),
        "out_tiles": 2 * q_block * out_cols * 4,
    }
    return out


def derive_hbm_b_tile(b: int, byte_model_at, *, vmem_budget: int):
    """Pick a ring tile for an HBM-resident bank when the caller gave none.

    The default ``b_tile=None`` means "one tile holding the whole bank" —
    the right default VMEM-resident, but self-defeating HBM-resident (the
    2-slot ring would be twice the bank). ``byte_model_at(b_tile)`` returns
    the hbm working-set breakdown for a candidate tile; this returns the
    largest power-of-two tile (512 down to 8) under the budget, or the
    whole bank if even that fits, so ``bank_resident="auto"``/``"hbm"``
    work on beyond-VMEM banks without the caller hand-picking a tile. A
    caller-supplied ``b_tile`` is never overridden.
    """
    if sum(byte_model_at(None).values()) <= vmem_budget:
        return None  # the whole bank rings within budget — keep one tile
    for cand in (512, 256, 128, 64, 32, 16, 8):
        if cand < b and sum(byte_model_at(cand).values()) <= vmem_budget:
            return cand
    return 8  # nothing fits: smallest tile, and let the preflight raise


def resolve_bank_resident(
    bank_resident: str,
    byte_model,
    *,
    vmem_budget: int,
    what: str,
    shapes: str,
) -> tuple[str, dict]:
    """Resolve the residency policy against the per-step VMEM byte model.

    ``byte_model(residency)`` returns the working-set breakdown for one
    residency. "auto" picks "vmem" when its working set fits ``vmem_budget``
    and "hbm" otherwise; a FORCED residency whose working set exceeds the
    budget, and configs no residency can satisfy, raise a ValueError
    carrying the shapes, the breakdown and the budget (this preflight is
    what turns the old opaque Pallas scratch-allocation failure into an
    actionable error). Returns ``(residency, breakdown)``.
    """
    if bank_resident not in _BANK_RESIDENCIES:
        raise ValueError(
            f"unknown bank_resident {bank_resident!r}; expected one of "
            f"{_BANK_RESIDENCIES}"
        )
    if bank_resident == "auto":
        by = byte_model("vmem")
        if sum(by.values()) <= vmem_budget:
            return "vmem", by
        bank_resident = "hbm"
    by = byte_model(bank_resident)
    total = sum(by.values())
    if total > vmem_budget:
        hint = (
            "shrink b_tile/block_n/lookahead or raise the budget"
            if bank_resident == "hbm"
            else 'use bank_resident="hbm" (or "auto"), or shrink the bank'
        )
        raise ValueError(
            f"{what} with {shapes} needs a per-step VMEM working set of "
            f"{total} bytes under bank_resident={bank_resident!r} "
            f"(breakdown: {by}), exceeding the budget of {vmem_budget} "
            f"bytes — {hint}. The budget follows vmem_budget_bytes(): "
            "pass vmem_budget_bytes= or set REPRO_VMEM_BUDGET_BYTES."
        )
    return bank_resident, by


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def streamsvm_fit(
    X: jax.Array,
    y: jax.Array,
    c,
    ball: Ball | None = None,
    *,
    block_n: int = 256,
    interpret: bool | None = None,
) -> Ball:
    """One-pass Algorithm 1 via the Pallas kernel. Returns a core Ball.

    Starts from `ball` if given, else initializes from the first example
    (exact variant: xi2 = 1/C). ``c`` is traced (a C sweep reuses one
    compilation); only ``block_n``/``interpret`` are static.
    """
    n, d = X.shape
    if y.shape != (n,):
        raise ValueError(
            f"y must be (N,) labels matching X: got y.shape={y.shape}, "
            f"X.shape={X.shape}"
        )
    c_inv = 1.0 / jnp.asarray(c, jnp.float32)
    if ball is None:
        w0 = y[0] * X[0]
        r0, xi20, m0 = jnp.float32(0.0), c_inv, 1
        X, y = X[1:], y[1:]
        n -= 1
    else:
        w0, r0, xi20, m0 = ball.w, ball.r, ball.xi2, ball.m
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), 128, 1), block_n, 0)
    yp = _pad_to(y.astype(jnp.float32), block_n, 0)
    w0p = _pad_to(w0.astype(jnp.float32), 128, 0)
    w, r, xi2, m = streamsvm_scan_pallas(
        Xp, yp, w0p, r0, xi20, c_inv, m0,
        n_valid=n, block_n=block_n, interpret=interpret,
    )
    return Ball(w=w[:d], r=r, xi2=xi2, m=m)


# The residency helpers below shadow their module-level names inside the
# jit'd wrappers (whose keyword arguments reuse the public names).
_vmem_budget = vmem_budget_bytes


@partial(
    jax.jit,
    static_argnames=(
        "variant", "lookahead", "block_n", "b_tile", "stream_dtype",
        "bank_resident", "vmem_budget_bytes", "interpret",
    ),
)
def streamsvm_fit_many(
    X: jax.Array,
    Y: jax.Array,
    cs: jax.Array,
    balls: Ball | None = None,
    *,
    variant: str = "exact",
    lookahead=None,
    block_n: int = 256,
    b_tile: int | None = None,
    stream_dtype=None,
    bank_resident: str = "auto",
    vmem_budget_bytes: int | None = None,
    interpret: bool | None = None,
) -> Ball:
    """One-pass Algorithm 1/2 for a bank of B models — ONE read of the stream.

    X: (N, D) shared stream; Y: (B, N) per-model label signs in {-1, +1}
    (classes x C-grid x variants all flatten onto the B axis). A sign of 0
    marks a STREAMED row inert *for that model* — no violation, no absorb,
    no lookahead buffering — which is how core.fit_bank_sharded pads ragged
    shard remainders without changing any model. Caveat: when ``balls`` is
    None, row 0 is consumed as every model's init example BEFORE the
    contract applies, so it must carry a real +-1 sign for every model
    (``Y[b, 0] == 0`` would seed model b from the zero point w=0, m=1 —
    pass an explicit ``balls`` or keep sign-0 rows off position 0).
    cs: scalar or
    (B,) per-model C (traced — a C sweep reuses one compilation). Starts from
    ``balls`` (a Ball stacked on a leading B axis) if given, else initializes
    every model from the first example. Returns a stacked Ball; state stays
    O(B * D) while each (block_n, D) tile is loaded from HBM exactly once and
    updates all B models.

    variant: "exact" / "paper-listing" select Algorithm 1's slack gain;
    "lookahead" / "lookahead-paper" run fused Algorithm 2 (exact vs
    paper-listing slack gain) with per-model windows given by ``lookahead``
    (an int, or a length-B tuple of ints; static). Windows are flushed
    farthest-point-first when full and at end of stream.
    b_tile: models per VMEM bank tile (rounded up to the f32 sublane multiple
    of 8; default: one tile holding the whole bank). The engine's grid is
    data-major, so any B runs in ONE stream pass — B/b_tile bank tiles
    revisit each resident stream tile instead of re-reading it.
    stream_dtype: None/"f32" or "bf16" — see the module dtype policy.
    bank_resident: "vmem" / "hbm" / "auto" (default) — see the module
    residency policy. "hbm" lifts the VMEM cap on B*D by keeping the bank,
    state and lookahead windows in HBM/ANY space, double-buffered through a
    2-slot VMEM ring (bit-exact f32 with "vmem"); impossible configs raise
    a ValueError carrying the per-step byte breakdown and the budget
    (``vmem_budget_bytes`` / REPRO_VMEM_BUDGET_BYTES).
    """
    b, n_y = Y.shape
    n, d = X.shape
    if n_y != n:
        raise ValueError(
            f"Y must be (B, N) sign rows matching X: got Y.shape={Y.shape}, "
            f"X.shape={X.shape}"
        )
    if variant not in ("exact", "paper-listing", "lookahead", "lookahead-paper"):
        raise ValueError(
            f"unknown variant {variant!r}; expected 'exact', 'paper-listing', "
            "'lookahead' or 'lookahead-paper'"
        )
    is_lookahead = variant in ("lookahead", "lookahead-paper")
    if not is_lookahead and lookahead is not None:
        raise ValueError(
            f"lookahead={lookahead!r} requires variant='lookahead' or "
            f"'lookahead-paper' (got variant={variant!r})"
        )
    stream_dtype = _resolve_stream_dtype(stream_dtype)
    cs = jnp.broadcast_to(jnp.asarray(cs, jnp.float32), (b,))
    c_inv = 1.0 / cs
    gain = (
        jnp.ones_like(c_inv)
        if variant in ("paper-listing", "lookahead-paper")
        else c_inv
    )
    if is_lookahead:
        lookahead = 1 if lookahead is None else lookahead
        if isinstance(lookahead, int):
            lookahead = (lookahead,) * b
        lookahead = tuple(int(l) for l in lookahead)
        if len(lookahead) != b or min(lookahead) < 1:
            raise ValueError(
                f"lookahead must be an int >= 1 or a length-B tuple of them: "
                f"got {lookahead} for B={b}"
            )
    l_max = max(lookahead) if is_lookahead else None
    budget = _vmem_budget(vmem_budget_bytes)
    engine_bytes_at = lambda bt_, res: engine_vmem_bytes(
        b, d, block_n=block_n, b_tile=bt_, stream_dtype=stream_dtype,
        lookahead_max=l_max, bank_resident=res,
    )
    # b_tile=None means "whole bank in one tile" — right VMEM-resident,
    # self-defeating as a ring slot. When residency is (or may resolve to)
    # hbm and the caller named no tile, derive one that fits the budget so
    # "auto" genuinely rescues beyond-VMEM banks.
    if b_tile is None and bank_resident in ("auto", "hbm"):
        vmem_fits = sum(engine_bytes_at(None, "vmem").values()) <= budget
        if bank_resident == "hbm" or not vmem_fits:
            b_tile = derive_hbm_b_tile(
                b, lambda bt_: engine_bytes_at(bt_, "hbm"),
                vmem_budget=budget,
            )
    # Residency preflight: resolve "auto" and reject configs whose per-step
    # VMEM working set cannot fit under ANY residency — BEFORE Pallas gets a
    # chance to fail opaquely inside lowering (also guards forced "vmem").
    residency, _ = resolve_bank_resident(
        bank_resident,
        lambda res: engine_bytes_at(b_tile, res),
        vmem_budget=budget,
        what="streamsvm_fit_many",
        shapes=(
            f"B={b}, D={d}, block_n={block_n}, b_tile={b_tile}, "
            f"lookahead_max={l_max}, stream_dtype={stream_dtype!r}"
        ),
    )
    if balls is None:
        w0 = Y[:, 0:1] * X[0][None, :]
        r0 = jnp.zeros((b,), jnp.float32)
        xi20, m0 = gain, jnp.ones((b,), jnp.float32)
        X, Y = X[1:], Y[:, 1:]
        n -= 1
    else:
        w0, r0, xi20, m0 = balls.w, balls.r, balls.xi2, balls.m
    if n == 0:  # nothing (left) to stream — the initial state IS the answer
        return Ball(
            w=w0.astype(jnp.float32),
            r=jnp.broadcast_to(jnp.asarray(r0, jnp.float32), (b,)),
            xi2=jnp.broadcast_to(jnp.asarray(xi20, jnp.float32), (b,)),
            m=jnp.broadcast_to(jnp.asarray(m0, jnp.int32), (b,)),
        )
    # Pad models to a whole number of bank tiles (tiles themselves to the f32
    # sublane multiple of 8); padded rows carry zero signs, C=1, L=1 and an
    # infinite starting radius — they never "violate", so they absorb nothing
    # and (in lookahead mode) never buffer or flush — and are sliced off
    # below.
    bt, _ = bank_tiling(b, b_tile)
    bp = -(-b // bt) * bt
    live = jnp.arange(bp) < b
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), 128, 1), block_n, 0)
    Yp = _pad_to(_pad_to(Y.astype(jnp.float32), block_n, 1), bp, 0)
    W0p = _pad_to(_pad_to(w0.astype(jnp.float32), 128, 1), bp, 0)
    pad1 = lambda v: _pad_to(
        jnp.broadcast_to(jnp.asarray(v, jnp.float32), (b,)), bp, 0
    )
    if is_lookahead:
        l_pad = lookahead + (1,) * (bp - b)
        l_arr = jnp.asarray(l_pad, jnp.int32)
        l_max = max(lookahead)
    else:
        l_arr = None
        l_max = None
    W, r, xi2, m = streamsvm_scan_many_pallas(
        Xp,
        Yp,
        W0p,
        jnp.where(live, pad1(r0), jnp.inf),
        pad1(xi20),
        jnp.where(live, pad1(c_inv), 1.0),
        _pad_to(jnp.broadcast_to(jnp.asarray(m0, jnp.int32), (b,)), bp, 0),
        jnp.where(live, pad1(gain), 1.0),
        lookahead=l_arr,
        lookahead_max=l_max,
        n_valid=n,
        block_n=block_n,
        b_tile=bt,
        stream_dtype=stream_dtype,
        bank_resident=residency,
        interpret=interpret,
    )
    return Ball(w=W[:b, :d], r=r[:b], xi2=xi2[:b], m=m[:b])


@partial(
    jax.jit,
    static_argnames=("epilogue", "bm", "bn", "bk", "interpret"),
)
def gram(
    A: jax.Array,
    B: jax.Array,
    *,
    epilogue: str = "linear",
    gamma=1.0,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Kernel matrix K[i, j] = k(a_i, b_j) with MXU tiling.

    ``gamma`` is TRACED (a (1, 1) scalar operand of the Pallas launch), so a
    gamma sweep reuses one compilation — regression-tested alongside the C
    sweep in tests/test_kernel_bank.py.
    """
    m, d = A.shape
    n, _ = B.shape
    if B.shape[1] != d:
        raise ValueError(
            f"A and B must share the feature axis: got A.shape={A.shape}, "
            f"B.shape={B.shape}"
        )
    bm_, bn_ = gram_tiling(m, n, bm, bn)
    Ap = _pad_to(_pad_to(A.astype(jnp.float32), bk, 1), bm_, 0)
    Bp = _pad_to(_pad_to(B.astype(jnp.float32), bk, 1), bn_, 0)
    out = gram_pallas(
        Ap, Bp, epilogue=epilogue, gamma=gamma, bm=bm_, bn=bn_, bk=bk,
        interpret=interpret,
    )
    return out[:m, :n]


@partial(
    jax.jit,
    static_argnames=(
        "epilogue", "n_classes", "k", "q_block", "b_tile", "stream_dtype",
        "bank_resident", "vmem_budget_bytes", "interpret",
    ),
)
def predict_bank(
    X: jax.Array,
    W: jax.Array,
    *,
    epilogue: str = "scores",
    n_classes: int | None = None,
    k: int | None = None,
    q_block: int = 256,
    b_tile: int | None = None,
    stream_dtype=None,
    bank_resident: str = "auto",
    vmem_budget_bytes: int | None = None,
    interpret: bool | None = None,
):
    """Score (Q, D) queries against a (B, D) bank with a fused epilogue.

    The serving twin of ``streamsvm_fit_many``: the kernel's 2-D grid is
    data-major (query tiles outer), so each (q_block, D) query tile is DMA'd
    from HBM once and revisited by every (b_tile, D) bank tile. ``W`` is the
    trained bank's weight rows (``bank.w`` of a fit_bank/fit_ovr/fit_c_grid
    result). Only shapes and the static epilogue parameters compile — serving
    a NEW bank of the same shape never recompiles (regression-tested via the
    jit cache in tests/test_predict_engine.py).

    epilogue:
      "scores"          -> (Q, B) f32 margins, bit-exact (f32 queries) with
                           the jnp readout ``X @ W.T``
      "ovr", n_classes= -> ((Q, G) int32, (Q, G) f32): winning class id and
                           its margin per C-grid group, G = B // n_classes,
                           bank laid out class-major within each group
                           (model = g * n_classes + class — exactly the
                           fit_ovr/fit_c_grid flattening). Groups are padded
                           to whole bank tiles so the argmax fuses into the
                           matmul step.
      "topk", k=        -> ((Q, k) f32, (Q, k) int32) descending top-k model
                           scores and ids per query.

    q_block: query rows per tile (the microbatch slot count BankServer packs
    into). b_tile: bank lanes per tile (rounded up to the f32 sublane
    multiple of 8; for "ovr" rounded to whole padded groups; default: one
    tile holding the whole bank). stream_dtype: None/"f32" or "bf16" — query
    tiles DMA'd as bf16 (half the dominant HBM term; the bank, bias and
    accumulators stay f32; see the module dtype policy).
    bank_resident: "vmem" / "hbm" / "auto" (default). "hbm" keeps the bank
    in ANY/HBM space and rings (b_tile, D) slices through a 2-slot VMEM
    buffer with async-copy prefetch (bit-exact f32 with "vmem"); "auto"
    serves HBM-resident exactly when the bank's full (B, D) f32 footprint
    exceeds the VMEM budget — the dominant term of the training policy's
    boundary, so train/serve residency decisions agree except in the
    narrow window where training's extra per-step stream-tile terms tip
    it over first (a bank clearly beyond VMEM trains AND serves
    HBM-resident). Per-step working sets are preflighted against the
    budget either way (ValueError with the byte breakdown on impossible
    configs).
    """
    q, d = X.shape
    b, dw = W.shape
    if dw != d:
        raise ValueError(
            f"queries and bank must share the feature axis: got X.shape="
            f"{X.shape}, W.shape={W.shape}"
        )
    if epilogue not in ("scores", "ovr", "topk"):
        raise ValueError(
            f"unknown epilogue {epilogue!r}; expected 'scores', 'ovr' or "
            "'topk'"
        )
    if epilogue != "ovr" and n_classes is not None:
        raise ValueError(
            f"n_classes={n_classes} requires epilogue='ovr' (got "
            f"epilogue={epilogue!r})"
        )
    if epilogue != "topk" and k is not None:
        raise ValueError(
            f"k={k} requires epilogue='topk' (got epilogue={epilogue!r})"
        )
    if epilogue == "ovr" and (
        n_classes is None or n_classes < 1 or b % n_classes
    ):
        raise ValueError(
            f"epilogue='ovr' needs n_classes >= 1 dividing B: got "
            f"n_classes={n_classes}, B={b}"
        )
    if epilogue == "topk" and (k is None or not (1 <= k <= b)):
        raise ValueError(
            f"epilogue='topk' needs 1 <= k <= B: got k={k}, B={b}"
        )
    stream_dtype = _resolve_stream_dtype(stream_dtype)
    # Residency: "auto" serves HBM-resident exactly when the full bank's f32
    # footprint exceeds the VMEM budget — the dominant term of the training
    # policy's boundary (which also counts per-step stream-tile terms), so
    # train/serve decisions agree away from the boundary; the chosen
    # residency's per-step working set is then preflighted either way.
    budget = _vmem_budget(vmem_budget_bytes)
    if bank_resident == "auto":  # unknown strings fall through to the
        dp = -(-d // 128) * 128  # resolver's own membership ValueError
        bank_resident = "hbm" if b * dp * 4 > budget else "vmem"
    predict_bytes_at = lambda bt_, res: predict_vmem_bytes(
        b, d, q_block=q_block, b_tile=bt_, stream_dtype=stream_dtype,
        epilogue=epilogue, n_classes=n_classes, k=k, bank_resident=res,
    )
    if bank_resident == "hbm" and b_tile is None:
        # default "whole bank per tile" is self-defeating as a ring slot —
        # derive a budget-fitting tile (a caller's b_tile is never touched)
        b_tile = derive_hbm_b_tile(
            b, lambda bt_: predict_bytes_at(bt_, "hbm"), vmem_budget=budget
        )
    residency, _ = resolve_bank_resident(
        bank_resident,
        lambda res: predict_bytes_at(b_tile, res),
        vmem_budget=budget,
        what="predict_bank",
        shapes=(
            f"Q={q}, B={b}, D={d}, q_block={q_block}, b_tile={b_tile}, "
            f"epilogue={epilogue!r}, stream_dtype={stream_dtype!r}"
        ),
    )
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), 128, 1), q_block, 0)
    if stream_dtype is not None:
        Xp = Xp.astype(stream_dtype)
    Wf = W.astype(jnp.float32)

    if epilogue == "ovr":
        g = b // n_classes
        # Pad each group's class lanes to the sublane multiple of 8, then
        # tile the bank in whole GROUPS so a group's argmax never crosses a
        # tile boundary (the cross-tile running state "scores" and "topk"
        # need is unnecessary here).
        nc_pad, g_tile, gp = ovr_group_tiling(b, n_classes, b_tile)
        Wg = _pad_to(_pad_to(Wf.reshape(g, n_classes, d), nc_pad, 1), gp, 0)
        Wp = _pad_to(Wg.reshape(gp * nc_pad, d), 128, 1)
        lane = jnp.arange(gp * nc_pad)
        live = jnp.logical_and(
            lane % nc_pad < n_classes, lane // nc_pad < g
        )
        bias = jnp.where(live, 0.0, NEG_MASK)[:, None].astype(jnp.float32)
        cls, margin = predict_bank_pallas(
            Xp, Wp, bias, epilogue="ovr", q_block=q_block,
            b_tile=g_tile * nc_pad, nc_pad=nc_pad, bank_resident=residency,
            interpret=interpret,
        )
        return cls[:q, :g], margin[:q, :g]

    bt, _ = bank_tiling(b, b_tile)
    bp = -(-b // bt) * bt
    Wp = _pad_to(_pad_to(Wf, 128, 1), bp, 0)
    bias = jnp.where(jnp.arange(bp) < b, 0.0, NEG_MASK)[:, None].astype(
        jnp.float32
    )
    if epilogue == "topk":
        vals, ids = predict_bank_pallas(
            Xp, Wp, bias, epilogue="topk", q_block=q_block, b_tile=bt, k=k,
            bank_resident=residency, interpret=interpret,
        )
        return vals[:q], ids[:q]
    scores = predict_bank_pallas(
        Xp, Wp, bias, epilogue="scores", q_block=q_block, b_tile=bt,
        bank_resident=residency, interpret=interpret,
    )
    return scores[:q, :b]


@partial(
    jax.jit,
    static_argnames=(
        "kernel", "epilogue", "n_classes", "k", "q_block",
        "stream_dtype", "interpret",
    ),
)
def predict_kernel_bank(
    X: jax.Array,
    points: jax.Array,
    coef: jax.Array,
    *,
    kernel: str = "rbf",
    gamma=1.0,
    epilogue: str = "scores",
    n_classes: int | None = None,
    k: int | None = None,
    q_block: int = 256,
    stream_dtype=None,
    interpret: bool | None = None,
):
    """Score (Q, D) queries against a kernelized bank's stored core sets.

    The serving twin of ``core.fit_kernel_bank``: ``points`` is the bank's
    (B, S, D) core-set buffer and ``coef`` its (B, S) signed coefficients
    (free slots hold coef == 0, so they contribute exactly nothing). One
    fused Gram launch (``gram``, the same linear/RBF epilogue the trainer
    used) evaluates k(query tile, EVERY model's core set) as a
    (Q, B*S) block; the per-model readout is then the contraction

        scores[qi, bi] = sum_s coef[bi, s] * k(x_qi, points[bi, s])

    which is bit-exact (f32) with ``ref.predict_kernel_bank_ref`` /
    ``kernelized.decision_function`` against the stored core set — the
    train->serve parity contract of the linear ``predict_bank``, carried to
    kernel space. Epilogues mirror ``predict_bank``:

      "scores"          -> (Q, B) f32 margins
      "ovr", n_classes= -> ((Q, G) int32, (Q, G) f32) per C-grid group,
                           G = B // n_classes, class-major flattening
      "topk", k=        -> ((Q, k) f32, (Q, k) int32) descending

    ``gamma`` is traced through the Gram launch — a gamma sweep at serve
    time reuses one compilation, exactly like the C sweep at train time.

    q_block: query rows per Gram tile (BankServer's microbatch slot count).
    stream_dtype: "bf16" rounds the query tiles before the Gram launch; the
    core-set points and coefficients stay f32. The (B, S) state is small by
    construction (that is the point of the core-set bound), so there is no
    bank_resident knob here — the Gram operand is (B*S, D) and already
    streams through the tiled kernel's own block pipeline.
    """
    q, d = X.shape
    b, s, dp = points.shape
    if dp != d:
        raise ValueError(
            f"queries and core-set points must share the feature axis: got "
            f"X.shape={X.shape}, points.shape={points.shape}"
        )
    if coef.shape != (b, s):
        raise ValueError(
            f"coef must be (B, S) matching points: got coef.shape="
            f"{coef.shape}, points.shape={points.shape}"
        )
    if kernel not in ("linear", "rbf"):
        raise ValueError(
            f"unknown kernel {kernel!r}; expected 'linear' or 'rbf'"
        )
    if epilogue not in ("scores", "ovr", "topk"):
        raise ValueError(
            f"unknown epilogue {epilogue!r}; expected 'scores', 'ovr' or "
            "'topk'"
        )
    if epilogue != "ovr" and n_classes is not None:
        raise ValueError(
            f"n_classes={n_classes} requires epilogue='ovr' (got "
            f"epilogue={epilogue!r})"
        )
    if epilogue != "topk" and k is not None:
        raise ValueError(
            f"k={k} requires epilogue='topk' (got epilogue={epilogue!r})"
        )
    if epilogue == "ovr" and (
        n_classes is None or n_classes < 1 or b % n_classes
    ):
        raise ValueError(
            f"epilogue='ovr' needs n_classes >= 1 dividing B: got "
            f"n_classes={n_classes}, B={b}"
        )
    if epilogue == "topk" and (k is None or not (1 <= k <= b)):
        raise ValueError(
            f"epilogue='topk' needs 1 <= k <= B: got k={k}, B={b}"
        )
    sdt = _resolve_stream_dtype(stream_dtype)
    Xq = X.astype(jnp.float32)
    if sdt is not None:
        Xq = Xq.astype(sdt)
    K = gram(
        Xq, points.reshape(b * s, d).astype(jnp.float32),
        epilogue=kernel, gamma=gamma, bm=q_block, interpret=interpret,
    )
    scores = jnp.einsum(
        "qbs,bs->qb", K.reshape(q, b, s), coef.astype(jnp.float32)
    )
    if epilogue == "scores":
        return scores
    if epilogue == "ovr":
        g = b // n_classes
        grouped = scores.reshape(q, g, n_classes)
        cls = jnp.argmax(grouped, axis=-1).astype(jnp.int32)
        margin = jnp.max(grouped, axis=-1)
        return cls, margin
    vals, ids = jax.lax.top_k(scores, k)
    return vals, ids.astype(jnp.int32)
