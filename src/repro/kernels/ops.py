"""jit'd public wrappers around the Pallas kernels (padding, dtype policy).

These are the entry points the rest of the framework uses; they handle
128-alignment padding, interpret-mode selection (CPU container vs real TPU),
bank tiling (`b_tile`), the stream dtype policy, and state packing.
Semantics match ref.py exactly (tests sweep shapes and dtypes).

Dtype policy
------------
``stream_dtype`` controls the precision the *streamed* tiles — the
(block_n, D) data tiles and (b_tile, block_n) sign tiles — are DMA'd from
HBM as. ``"bf16"`` halves stream HBM traffic, which is the dominant byte
term at scale (the bank is O(B*D) once, the stream is O(N*D) every fit).
The bank, ball scalars, and every in-kernel accumulator stay f32
regardless. Labels in {-1, 0, +1} are exact in bf16; feature rounding is
bounded by the bf16 eps sweep in tests/test_tiled_engine.py.

Compile caching
---------------
``c`` / ``cs`` enter the kernels only through the traced ``1/C`` array, so
sweeping C values NEVER recompiles — only shape, ``block_n``, ``b_tile``,
``variant``, ``lookahead`` and dtype changes do (regression-tested via the
jit cache in tests/test_tiled_engine.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.meb import Ball
from .gram import gram_pallas
from .predict import NEG_MASK, predict_bank_pallas
from .streamsvm_scan import streamsvm_scan_many_pallas, streamsvm_scan_pallas

_STREAM_DTYPES = {
    None: None,
    "f32": jnp.float32,
    "float32": jnp.float32,
    "bf16": jnp.bfloat16,
    "bfloat16": jnp.bfloat16,
}


def _resolve_stream_dtype(stream_dtype):
    if stream_dtype in _STREAM_DTYPES:
        return _STREAM_DTYPES[stream_dtype]
    try:
        return jnp.dtype(stream_dtype).type
    except TypeError:
        raise ValueError(
            f"unknown stream_dtype {stream_dtype!r}; expected None, 'f32', "
            "'bf16', or a jnp dtype"
        ) from None


def bank_tiling(b: int, b_tile: int | None):
    """Resolve the engine's bank tiling for B models.

    Returns ``(effective_b_tile, n_bank_tiles)``: the requested tile rounded
    up to the f32 sublane multiple of 8 (default: one tile holding the whole
    bank) and the number of tiles covering the (padded) bank. The single
    source of truth for this policy — the throughput harness derives its
    modeled tile counts from here too.
    """
    bt = -(-b // 8) * 8 if b_tile is None else -(-b_tile // 8) * 8
    return bt, -(-b // bt)


def ovr_group_tiling(b: int, n_classes: int, b_tile: int | None):
    """Resolve the predict engine's ovr-epilogue bank tiling for B models.

    Each group's ``n_classes`` class lanes are padded to the f32 sublane
    multiple of 8 (``nc_pad``) and the bank is tiled in WHOLE groups so a
    group's argmax never crosses a bank tile. Returns ``(nc_pad, g_tile,
    padded_groups)``: lanes per padded group, groups per bank tile (derived
    from the requested lane ``b_tile``; default one tile holding every
    group), and the group count padded to a whole number of tiles. The
    single source of truth for this policy — the serving throughput harness
    derives its modeled tile counts from here too.
    """
    g = b // n_classes
    nc_pad = -(-n_classes // 8) * 8
    g_tile = g if b_tile is None else max(1, b_tile // nc_pad)
    return nc_pad, g_tile, -(-g // g_tile) * g_tile


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def streamsvm_fit(
    X: jax.Array,
    y: jax.Array,
    c,
    ball: Ball | None = None,
    *,
    block_n: int = 256,
    interpret: bool | None = None,
) -> Ball:
    """One-pass Algorithm 1 via the Pallas kernel. Returns a core Ball.

    Starts from `ball` if given, else initializes from the first example
    (exact variant: xi2 = 1/C). ``c`` is traced (a C sweep reuses one
    compilation); only ``block_n``/``interpret`` are static.
    """
    n, d = X.shape
    if y.shape != (n,):
        raise ValueError(
            f"y must be (N,) labels matching X: got y.shape={y.shape}, "
            f"X.shape={X.shape}"
        )
    c_inv = 1.0 / jnp.asarray(c, jnp.float32)
    if ball is None:
        w0 = y[0] * X[0]
        r0, xi20, m0 = jnp.float32(0.0), c_inv, 1
        X, y = X[1:], y[1:]
        n -= 1
    else:
        w0, r0, xi20, m0 = ball.w, ball.r, ball.xi2, ball.m
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), 128, 1), block_n, 0)
    yp = _pad_to(y.astype(jnp.float32), block_n, 0)
    w0p = _pad_to(w0.astype(jnp.float32), 128, 0)
    w, r, xi2, m = streamsvm_scan_pallas(
        Xp, yp, w0p, r0, xi20, c_inv, m0,
        n_valid=n, block_n=block_n, interpret=interpret,
    )
    return Ball(w=w[:d], r=r, xi2=xi2, m=m)


@partial(
    jax.jit,
    static_argnames=(
        "variant", "lookahead", "block_n", "b_tile", "stream_dtype", "interpret",
    ),
)
def streamsvm_fit_many(
    X: jax.Array,
    Y: jax.Array,
    cs: jax.Array,
    balls: Ball | None = None,
    *,
    variant: str = "exact",
    lookahead=None,
    block_n: int = 256,
    b_tile: int | None = None,
    stream_dtype=None,
    interpret: bool | None = None,
) -> Ball:
    """One-pass Algorithm 1/2 for a bank of B models — ONE read of the stream.

    X: (N, D) shared stream; Y: (B, N) per-model label signs in {-1, +1}
    (classes x C-grid x variants all flatten onto the B axis). A sign of 0
    marks a STREAMED row inert *for that model* — no violation, no absorb,
    no lookahead buffering — which is how core.fit_bank_sharded pads ragged
    shard remainders without changing any model. Caveat: when ``balls`` is
    None, row 0 is consumed as every model's init example BEFORE the
    contract applies, so it must carry a real +-1 sign for every model
    (``Y[b, 0] == 0`` would seed model b from the zero point w=0, m=1 —
    pass an explicit ``balls`` or keep sign-0 rows off position 0).
    cs: scalar or
    (B,) per-model C (traced — a C sweep reuses one compilation). Starts from
    ``balls`` (a Ball stacked on a leading B axis) if given, else initializes
    every model from the first example. Returns a stacked Ball; state stays
    O(B * D) while each (block_n, D) tile is loaded from HBM exactly once and
    updates all B models.

    variant: "exact" / "paper-listing" select Algorithm 1's slack gain;
    "lookahead" / "lookahead-paper" run fused Algorithm 2 (exact vs
    paper-listing slack gain) with per-model windows given by ``lookahead``
    (an int, or a length-B tuple of ints; static). Windows are flushed
    farthest-point-first when full and at end of stream.
    b_tile: models per VMEM bank tile (rounded up to the f32 sublane multiple
    of 8; default: one tile holding the whole bank). The engine's grid is
    data-major, so any B runs in ONE stream pass — B/b_tile bank tiles
    revisit each resident stream tile instead of re-reading it.
    stream_dtype: None/"f32" or "bf16" — see the module dtype policy.
    """
    b, n_y = Y.shape
    n, d = X.shape
    if n_y != n:
        raise ValueError(
            f"Y must be (B, N) sign rows matching X: got Y.shape={Y.shape}, "
            f"X.shape={X.shape}"
        )
    if variant not in ("exact", "paper-listing", "lookahead", "lookahead-paper"):
        raise ValueError(
            f"unknown variant {variant!r}; expected 'exact', 'paper-listing', "
            "'lookahead' or 'lookahead-paper'"
        )
    is_lookahead = variant in ("lookahead", "lookahead-paper")
    if not is_lookahead and lookahead is not None:
        raise ValueError(
            f"lookahead={lookahead!r} requires variant='lookahead' or "
            f"'lookahead-paper' (got variant={variant!r})"
        )
    stream_dtype = _resolve_stream_dtype(stream_dtype)
    cs = jnp.broadcast_to(jnp.asarray(cs, jnp.float32), (b,))
    c_inv = 1.0 / cs
    gain = (
        jnp.ones_like(c_inv)
        if variant in ("paper-listing", "lookahead-paper")
        else c_inv
    )
    if is_lookahead:
        lookahead = 1 if lookahead is None else lookahead
        if isinstance(lookahead, int):
            lookahead = (lookahead,) * b
        lookahead = tuple(int(l) for l in lookahead)
        if len(lookahead) != b or min(lookahead) < 1:
            raise ValueError(
                f"lookahead must be an int >= 1 or a length-B tuple of them: "
                f"got {lookahead} for B={b}"
            )
    if balls is None:
        w0 = Y[:, 0:1] * X[0][None, :]
        r0 = jnp.zeros((b,), jnp.float32)
        xi20, m0 = gain, jnp.ones((b,), jnp.float32)
        X, Y = X[1:], Y[:, 1:]
        n -= 1
    else:
        w0, r0, xi20, m0 = balls.w, balls.r, balls.xi2, balls.m
    if n == 0:  # nothing (left) to stream — the initial state IS the answer
        return Ball(
            w=w0.astype(jnp.float32),
            r=jnp.broadcast_to(jnp.asarray(r0, jnp.float32), (b,)),
            xi2=jnp.broadcast_to(jnp.asarray(xi20, jnp.float32), (b,)),
            m=jnp.broadcast_to(jnp.asarray(m0, jnp.int32), (b,)),
        )
    # Pad models to a whole number of bank tiles (tiles themselves to the f32
    # sublane multiple of 8); padded rows carry zero signs, C=1, L=1 and an
    # infinite starting radius — they never "violate", so they absorb nothing
    # and (in lookahead mode) never buffer or flush — and are sliced off
    # below.
    bt, _ = bank_tiling(b, b_tile)
    bp = -(-b // bt) * bt
    live = jnp.arange(bp) < b
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), 128, 1), block_n, 0)
    Yp = _pad_to(_pad_to(Y.astype(jnp.float32), block_n, 1), bp, 0)
    W0p = _pad_to(_pad_to(w0.astype(jnp.float32), 128, 1), bp, 0)
    pad1 = lambda v: _pad_to(
        jnp.broadcast_to(jnp.asarray(v, jnp.float32), (b,)), bp, 0
    )
    if is_lookahead:
        l_pad = lookahead + (1,) * (bp - b)
        l_arr = jnp.asarray(l_pad, jnp.int32)
        l_max = max(lookahead)
    else:
        l_arr = None
        l_max = None
    W, r, xi2, m = streamsvm_scan_many_pallas(
        Xp,
        Yp,
        W0p,
        jnp.where(live, pad1(r0), jnp.inf),
        pad1(xi20),
        jnp.where(live, pad1(c_inv), 1.0),
        _pad_to(jnp.broadcast_to(jnp.asarray(m0, jnp.int32), (b,)), bp, 0),
        jnp.where(live, pad1(gain), 1.0),
        lookahead=l_arr,
        lookahead_max=l_max,
        n_valid=n,
        block_n=block_n,
        b_tile=bt,
        stream_dtype=stream_dtype,
        interpret=interpret,
    )
    return Ball(w=W[:b, :d], r=r[:b], xi2=xi2[:b], m=m[:b])


@partial(
    jax.jit,
    static_argnames=("epilogue", "gamma", "bm", "bn", "bk", "interpret"),
)
def gram(
    A: jax.Array,
    B: jax.Array,
    *,
    epilogue: str = "linear",
    gamma: float = 1.0,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Kernel matrix K[i, j] = k(a_i, b_j) with MXU tiling."""
    m, d = A.shape
    n, _ = B.shape
    if B.shape[1] != d:
        raise ValueError(
            f"A and B must share the feature axis: got A.shape={A.shape}, "
            f"B.shape={B.shape}"
        )
    bm_ = min(bm, max(8, m))
    bn_ = min(bn, max(128, n))
    Ap = _pad_to(_pad_to(A.astype(jnp.float32), bk, 1), bm_, 0)
    Bp = _pad_to(_pad_to(B.astype(jnp.float32), bk, 1), bn_, 0)
    out = gram_pallas(
        Ap, Bp, epilogue=epilogue, gamma=gamma, bm=bm_, bn=bn_, bk=bk,
        interpret=interpret,
    )
    return out[:m, :n]


@partial(
    jax.jit,
    static_argnames=(
        "epilogue", "n_classes", "k", "q_block", "b_tile", "stream_dtype",
        "interpret",
    ),
)
def predict_bank(
    X: jax.Array,
    W: jax.Array,
    *,
    epilogue: str = "scores",
    n_classes: int | None = None,
    k: int | None = None,
    q_block: int = 256,
    b_tile: int | None = None,
    stream_dtype=None,
    interpret: bool | None = None,
):
    """Score (Q, D) queries against a (B, D) bank with a fused epilogue.

    The serving twin of ``streamsvm_fit_many``: the kernel's 2-D grid is
    data-major (query tiles outer), so each (q_block, D) query tile is DMA'd
    from HBM once and revisited by every (b_tile, D) bank tile. ``W`` is the
    trained bank's weight rows (``bank.w`` of a fit_bank/fit_ovr/fit_c_grid
    result). Only shapes and the static epilogue parameters compile — serving
    a NEW bank of the same shape never recompiles (regression-tested via the
    jit cache in tests/test_predict_engine.py).

    epilogue:
      "scores"          -> (Q, B) f32 margins, bit-exact (f32 queries) with
                           the jnp readout ``X @ W.T``
      "ovr", n_classes= -> ((Q, G) int32, (Q, G) f32): winning class id and
                           its margin per C-grid group, G = B // n_classes,
                           bank laid out class-major within each group
                           (model = g * n_classes + class — exactly the
                           fit_ovr/fit_c_grid flattening). Groups are padded
                           to whole bank tiles so the argmax fuses into the
                           matmul step.
      "topk", k=        -> ((Q, k) f32, (Q, k) int32) descending top-k model
                           scores and ids per query.

    q_block: query rows per tile (the microbatch slot count BankServer packs
    into). b_tile: bank lanes per tile (rounded up to the f32 sublane
    multiple of 8; for "ovr" rounded to whole padded groups; default: one
    tile holding the whole bank). stream_dtype: None/"f32" or "bf16" — query
    tiles DMA'd as bf16 (half the dominant HBM term; the bank, bias and
    accumulators stay f32; see the module dtype policy).
    """
    q, d = X.shape
    b, dw = W.shape
    if dw != d:
        raise ValueError(
            f"queries and bank must share the feature axis: got X.shape="
            f"{X.shape}, W.shape={W.shape}"
        )
    if epilogue not in ("scores", "ovr", "topk"):
        raise ValueError(
            f"unknown epilogue {epilogue!r}; expected 'scores', 'ovr' or "
            "'topk'"
        )
    if epilogue != "ovr" and n_classes is not None:
        raise ValueError(
            f"n_classes={n_classes} requires epilogue='ovr' (got "
            f"epilogue={epilogue!r})"
        )
    if epilogue != "topk" and k is not None:
        raise ValueError(
            f"k={k} requires epilogue='topk' (got epilogue={epilogue!r})"
        )
    stream_dtype = _resolve_stream_dtype(stream_dtype)
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), 128, 1), q_block, 0)
    if stream_dtype is not None:
        Xp = Xp.astype(stream_dtype)
    Wf = W.astype(jnp.float32)

    if epilogue == "ovr":
        if n_classes is None or n_classes < 1 or b % n_classes:
            raise ValueError(
                f"epilogue='ovr' needs n_classes >= 1 dividing B: got "
                f"n_classes={n_classes}, B={b}"
            )
        g = b // n_classes
        # Pad each group's class lanes to the sublane multiple of 8, then
        # tile the bank in whole GROUPS so a group's argmax never crosses a
        # tile boundary (the cross-tile running state "scores" and "topk"
        # need is unnecessary here).
        nc_pad, g_tile, gp = ovr_group_tiling(b, n_classes, b_tile)
        Wg = _pad_to(_pad_to(Wf.reshape(g, n_classes, d), nc_pad, 1), gp, 0)
        Wp = _pad_to(Wg.reshape(gp * nc_pad, d), 128, 1)
        lane = jnp.arange(gp * nc_pad)
        live = jnp.logical_and(
            lane % nc_pad < n_classes, lane // nc_pad < g
        )
        bias = jnp.where(live, 0.0, NEG_MASK)[:, None].astype(jnp.float32)
        cls, margin = predict_bank_pallas(
            Xp, Wp, bias, epilogue="ovr", q_block=q_block,
            b_tile=g_tile * nc_pad, nc_pad=nc_pad, interpret=interpret,
        )
        return cls[:q, :g], margin[:q, :g]

    bt, _ = bank_tiling(b, b_tile)
    bp = -(-b // bt) * bt
    Wp = _pad_to(_pad_to(Wf, 128, 1), bp, 0)
    bias = jnp.where(jnp.arange(bp) < b, 0.0, NEG_MASK)[:, None].astype(
        jnp.float32
    )
    if epilogue == "topk":
        if k is None or not (1 <= k <= b):
            raise ValueError(
                f"epilogue='topk' needs 1 <= k <= B: got k={k}, B={b}"
            )
        vals, ids = predict_bank_pallas(
            Xp, Wp, bias, epilogue="topk", q_block=q_block, b_tile=bt, k=k,
            interpret=interpret,
        )
        return vals[:q], ids[:q]
    scores = predict_bank_pallas(
        Xp, Wp, bias, epilogue="scores", q_block=q_block, b_tile=bt,
        interpret=interpret,
    )
    return scores[:q, :b]
