"""jit'd public wrappers around the Pallas kernels (padding, dtype policy).

These are the entry points the rest of the framework uses; they handle
128-alignment padding, interpret-mode selection (CPU container vs real TPU),
and state packing. Semantics match ref.py exactly (tests sweep shapes and
dtypes).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.meb import Ball
from .gram import gram_pallas
from .streamsvm_scan import streamsvm_scan_many_pallas, streamsvm_scan_pallas


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("c", "block_n", "interpret"))
def streamsvm_fit(
    X: jax.Array,
    y: jax.Array,
    c: float,
    ball: Ball | None = None,
    *,
    block_n: int = 256,
    interpret: bool | None = None,
) -> Ball:
    """One-pass Algorithm 1 via the Pallas kernel. Returns a core Ball.

    Starts from `ball` if given, else initializes from the first example
    (exact variant: xi2 = 1/C).
    """
    n, d = X.shape
    c_inv = 1.0 / c
    if ball is None:
        w0 = y[0] * X[0]
        r0, xi20, m0 = 0.0, c_inv, 1
        X, y = X[1:], y[1:]
        n -= 1
    else:
        w0, r0, xi20, m0 = ball.w, ball.r, ball.xi2, ball.m
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), 128, 1), block_n, 0)
    yp = _pad_to(y.astype(jnp.float32), block_n, 0)
    w0p = _pad_to(w0.astype(jnp.float32), 128, 0)
    w, r, xi2, m = streamsvm_scan_pallas(
        Xp, yp, w0p, r0, xi20, c_inv, m0,
        n_valid=n, block_n=block_n, interpret=interpret,
    )
    return Ball(w=w[:d], r=r, xi2=xi2, m=m)


@partial(jax.jit, static_argnames=("variant", "block_n", "interpret"))
def streamsvm_fit_many(
    X: jax.Array,
    Y: jax.Array,
    cs: jax.Array,
    balls: Ball | None = None,
    *,
    variant: str = "exact",
    block_n: int = 256,
    interpret: bool | None = None,
) -> Ball:
    """One-pass Algorithm 1 for a bank of B models — ONE read of the stream.

    X: (N, D) shared stream; Y: (B, N) per-model label signs in {-1, +1}
    (classes x C-grid x variants all flatten onto the B axis); cs: scalar or
    (B,) per-model C. Starts from ``balls`` (a Ball stacked on a leading B
    axis) if given, else initializes every model from the first example.
    Returns a stacked Ball; state stays O(B * D) while each (block_n, D) tile
    is loaded from HBM exactly once and updates all B models.
    """
    b, n_y = Y.shape
    n, d = X.shape
    assert n_y == n, (Y.shape, X.shape)
    cs = jnp.broadcast_to(jnp.asarray(cs, jnp.float32), (b,))
    c_inv = 1.0 / cs
    gain = c_inv if variant == "exact" else jnp.ones_like(c_inv)
    if balls is None:
        w0 = Y[:, 0:1] * X[0][None, :]
        r0 = jnp.zeros((b,), jnp.float32)
        xi20, m0 = gain, jnp.ones((b,), jnp.float32)
        X, Y = X[1:], Y[:, 1:]
        n -= 1
    else:
        w0, r0, xi20, m0 = balls.w, balls.r, balls.xi2, balls.m
    if n == 0:  # nothing (left) to stream — the initial state IS the answer
        return Ball(
            w=w0.astype(jnp.float32),
            r=jnp.broadcast_to(jnp.asarray(r0, jnp.float32), (b,)),
            xi2=jnp.broadcast_to(jnp.asarray(xi20, jnp.float32), (b,)),
            m=jnp.broadcast_to(jnp.asarray(m0, jnp.int32), (b,)),
        )
    # Pad models to the f32 sublane multiple; padded rows carry zero signs and
    # C=1 so they stay finite, and are sliced off below.
    bp = -(-b // 8) * 8
    live = jnp.arange(bp) < b
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), 128, 1), block_n, 0)
    Yp = _pad_to(_pad_to(Y.astype(jnp.float32), block_n, 1), 8, 0)
    W0p = _pad_to(_pad_to(w0.astype(jnp.float32), 128, 1), 8, 0)
    pad1 = lambda v: _pad_to(
        jnp.broadcast_to(jnp.asarray(v, jnp.float32), (b,)), 8, 0
    )
    W, r, xi2, m = streamsvm_scan_many_pallas(
        Xp,
        Yp,
        W0p,
        pad1(r0),
        pad1(xi20),
        jnp.where(live, pad1(c_inv), 1.0),
        _pad_to(jnp.broadcast_to(jnp.asarray(m0, jnp.int32), (b,)), 8, 0),
        jnp.where(live, pad1(gain), 1.0),
        n_valid=n,
        block_n=block_n,
        interpret=interpret,
    )
    return Ball(w=W[:b, :d], r=r[:b], xi2=xi2[:b], m=m[:b])


@partial(
    jax.jit,
    static_argnames=("epilogue", "gamma", "bm", "bn", "bk", "interpret"),
)
def gram(
    A: jax.Array,
    B: jax.Array,
    *,
    epilogue: str = "linear",
    gamma: float = 1.0,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Kernel matrix K[i, j] = k(a_i, b_j) with MXU tiling."""
    m, d = A.shape
    n, _ = B.shape
    bm_ = min(bm, max(8, m))
    bn_ = min(bn, max(128, n))
    Ap = _pad_to(_pad_to(A.astype(jnp.float32), bk, 1), bm_, 0)
    Bp = _pad_to(_pad_to(B.astype(jnp.float32), bk, 1), bn_, 0)
    out = gram_pallas(
        Ap, Bp, epilogue=epilogue, gamma=gamma, bm=bm_, bn=bn_, bk=bk,
        interpret=interpret,
    )
    return out[:m, :n]
