"""jit'd public wrappers around the Pallas kernels (padding, dtype policy).

These are the entry points the rest of the framework uses; they handle
128-alignment padding, interpret-mode selection (CPU container vs real TPU),
and state packing. Semantics match ref.py exactly (tests sweep shapes and
dtypes).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.meb import Ball
from .gram import gram_pallas
from .streamsvm_scan import streamsvm_scan_pallas


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("c", "block_n", "interpret"))
def streamsvm_fit(
    X: jax.Array,
    y: jax.Array,
    c: float,
    ball: Ball | None = None,
    *,
    block_n: int = 256,
    interpret: bool | None = None,
) -> Ball:
    """One-pass Algorithm 1 via the Pallas kernel. Returns a core Ball.

    Starts from `ball` if given, else initializes from the first example
    (exact variant: xi2 = 1/C).
    """
    n, d = X.shape
    c_inv = 1.0 / c
    if ball is None:
        w0 = y[0] * X[0]
        r0, xi20, m0 = 0.0, c_inv, 1
        X, y = X[1:], y[1:]
        n -= 1
    else:
        w0, r0, xi20, m0 = ball.w, ball.r, ball.xi2, ball.m
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), 128, 1), block_n, 0)
    yp = _pad_to(y.astype(jnp.float32), block_n, 0)
    w0p = _pad_to(w0.astype(jnp.float32), 128, 0)
    w, r, xi2, m = streamsvm_scan_pallas(
        Xp, yp, w0p, r0, xi20, c_inv, m0,
        n_valid=n, block_n=block_n, interpret=interpret,
    )
    return Ball(w=w[:d], r=r, xi2=xi2, m=m)


@partial(
    jax.jit,
    static_argnames=("epilogue", "gamma", "bm", "bn", "bk", "interpret"),
)
def gram(
    A: jax.Array,
    B: jax.Array,
    *,
    epilogue: str = "linear",
    gamma: float = 1.0,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Kernel matrix K[i, j] = k(a_i, b_j) with MXU tiling."""
    m, d = A.shape
    n, _ = B.shape
    bm_ = min(bm, max(8, m))
    bn_ = min(bn, max(128, n))
    Ap = _pad_to(_pad_to(A.astype(jnp.float32), bk, 1), bm_, 0)
    Bp = _pad_to(_pad_to(B.astype(jnp.float32), bk, 1), bn_, 0)
    out = gram_pallas(
        Ap, Bp, epilogue=epilogue, gamma=gamma, bm=bm_, bn=bn_, bk=bk,
        interpret=interpret,
    )
    return out[:m, :n]
