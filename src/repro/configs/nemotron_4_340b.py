"""Nemotron-4 340B [arXiv:2402.16819]: dense GQA, squared-ReLU MLP."""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-340b", family="dense",
        n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, head_dim=192,
        d_ff=73728, vocab=256000, mlp="sq_relu", rope_base=1e4,
        moment_dtype="bfloat16",  # 340B: fp32 moments would not fit 16G/chip
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-340b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab=512, mlp="sq_relu", rope_base=1e4,
    )


register("nemotron-4-340b", full, smoke)
