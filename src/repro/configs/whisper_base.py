"""Whisper base [arXiv:2212.04356]: enc-dec; conv audio frontend is a STUB —
input_specs() provides precomputed frame embeddings (encoder_seq x d_model)."""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="whisper-base", family="encdec",
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
        d_ff=2048, vocab=51865, mlp="gelu",
        n_encoder_layers=6, encoder_seq=1500, unrolled=True,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="whisper-base-smoke", family="encdec",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=512, mlp="gelu",
        n_encoder_layers=2, encoder_seq=64, unrolled=True,
    )


register("whisper-base", full, smoke)
