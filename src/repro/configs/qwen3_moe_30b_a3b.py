"""Qwen3-MoE 30B-A3B [hf:Qwen/Qwen3-30B-A3B]: 128 experts, top-8."""
from .base import ArchConfig, MoECfg, register


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=768, vocab=151936, mlp="swiglu",
        moe=MoECfg(n_experts=128, top_k=8, d_ff=768),
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=64, vocab=512, mlp="swiglu",
        moe=MoECfg(n_experts=8, top_k=2, d_ff=64),
    )


register("qwen3-moe-30b-a3b", full, smoke)
