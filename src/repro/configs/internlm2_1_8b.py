"""InternLM2 1.8B [arXiv:2403.17297]: llama-family dense GQA."""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="internlm2-1.8b", family="dense",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab=92544, mlp="swiglu",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="internlm2-1.8b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, mlp="swiglu",
    )


register("internlm2-1.8b", full, smoke)
