"""xLSTM 125M [arXiv:2405.04517]: mLSTM blocks with one sLSTM per 4 blocks.

d_ff=0 per assignment: blocks carry their own up/down projections, no
separate FFN. mLSTM trains with the parallel (stabilized) form, decodes with
the O(1) recurrent form; sLSTM is sequential in both (lax.scan over time).
"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="xlstm-125m", family="ssm",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
        d_ff=0, vocab=50304, mlp="none",
        slstm_every=4, sub_quadratic=True, unrolled=True,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="xlstm-125m-smoke", family="ssm",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=0, vocab=512, mlp="none",
        slstm_every=2, sub_quadratic=True, unrolled=True,
    )


register("xlstm-125m", full, smoke)
