"""Zamba2 1.2B [arXiv:2411.15242]: Mamba2 backbone + one shared attention
block applied every 6 mixer layers (weights shared across applications)."""
from .base import ArchConfig, SSMCfg, register


def full() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab=32000, mlp="gelu",
        ssm=SSMCfg(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
        shared_attn_every=6, sub_quadratic=True, unrolled=True,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b-smoke", family="hybrid",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=512, mlp="gelu",
        ssm=SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=32),
        shared_attn_every=2, sub_quadratic=True, unrolled=True,
    )


register("zamba2-1.2b", full, smoke)
