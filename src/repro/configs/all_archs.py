"""Import side-effect module: populates the REGISTRY with all 10 archs."""
from . import (  # noqa: F401
    nemotron_4_340b,
    internlm2_1_8b,
    granite_34b,
    gemma3_27b,
    qwen3_moe_235b_a22b,
    qwen3_moe_30b_a3b,
    llava_next_mistral_7b,
    zamba2_1_2b,
    whisper_base,
    xlstm_125m,
)
