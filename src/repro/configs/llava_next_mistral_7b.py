"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

The assignment specifies the transformer BACKBONE; the anyres vision tower is
a STUB — input_specs() provides precomputed patch embeddings (n_patches x
d_model) which replace the first n_patches token positions.
"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="llava-next-mistral-7b", family="vlm",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=32000, mlp="swiglu", n_patches=576,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="llava-next-mistral-7b-smoke", family="vlm",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, mlp="swiglu", n_patches=16,
    )


register("llava-next-mistral-7b", full, smoke)
