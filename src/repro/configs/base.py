"""Architecture configuration schema + registry.

Every assigned architecture gets one module defining an ArchConfig with the
exact published hyper-parameters, plus a reduced `smoke()` variant of the
same family for CPU tests. `--arch <id>` resolves through REGISTRY.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden width
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMCfg:  # mamba2 (zamba2's mixer)
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    mlp: str = "swiglu"  # swiglu | geglu | gelu | sq_relu | none
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    # attention pattern
    window: Optional[int] = None  # sliding-window size for local layers
    global_every: int = 0  # gemma3: one global layer per `global_every` (6 -> 5:1)
    rope_base: float = 1e4
    rope_base_global: Optional[float] = None
    # hybrid (zamba2): one *shared* attn+mlp block applied every k mixer layers
    shared_attn_every: int = 0
    # enc-dec (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 0  # stub-frontend frames presented to the encoder
    # vlm (llava): patch embeddings prepended by the stub frontend
    n_patches: int = 0
    # xlstm
    slstm_every: int = 0  # one sLSTM block per k blocks (rest mLSTM)
    # numerics / misc
    act_dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    moment_dtype: str = "float32"  # optimizer moments (bf16 for the largest)
    sub_quadratic: bool = False  # True -> long_500k decode supported
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # layers implemented with a python loop instead of scan-over-layers
    unrolled: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        hd = self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.family == "ssm":  # xlstm: internal projections approx 8 d^2
            per_layer = 8 * d * d
        else:
            if self.moe is not None:
                ff = self.moe.n_experts * (3 * d * self.moe.d_ff) + d * self.moe.n_experts
            elif self.mlp in ("swiglu", "geglu"):
                ff = 3 * d * self.d_ff
            elif self.mlp == "none":
                ff = 0
            else:
                ff = 2 * d * self.d_ff
            per_layer = attn + ff if self.shared_attn_every == 0 else 0
            if self.ssm is not None:  # mamba2 mixer
                d_in = self.ssm.expand * d
                per_layer = 2 * d * d_in + d_in * d + d_in * 2 * self.ssm.d_state
        total = emb + self.n_layers * per_layer
        if self.shared_attn_every:
            total += attn + 3 * d * self.d_ff  # the single shared block
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (attn + 2 * d * self.d_ff)
            total += self.n_layers * attn  # decoder cross-attention
        return int(total)

    def active_params(self) -> int:
        """Active parameters per token (MoE uses top_k of n_experts)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        dense = self.n_params() - self.n_layers * self.moe.n_experts * 3 * d * self.moe.d_ff
        return int(dense + self.n_layers * self.moe.top_k * 3 * d * self.moe.d_ff)


# registry: name -> (full_config_fn, smoke_config_fn)
REGISTRY: Dict[str, Tuple[Callable[[], ArchConfig], Callable[[], ArchConfig]]] = {}


def register(name: str, full: Callable[[], ArchConfig], smoke: Callable[[], ArchConfig]):
    REGISTRY[name] = (full, smoke)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    import repro.configs.all_archs  # noqa: F401  (populates REGISTRY)

    full, sm = REGISTRY[name]
    return sm() if smoke else full()


def list_archs():
    import repro.configs.all_archs  # noqa: F401

    return sorted(REGISTRY.keys())
