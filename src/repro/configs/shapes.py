"""Assigned input-shape specs and (arch x shape) applicability rules."""
from __future__ import annotations

import dataclasses
from typing import List

from .base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

SMOKE_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 64, 2),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 128, 2),
    "decode_32k": ShapeSpec("decode_32k", "decode", 128, 2),
    "long_500k": ShapeSpec("long_500k", "decode", 256, 1),
}


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(is_applicable, reason-if-not). Skip rules from the assignment:

    - long_500k needs sub-quadratic attention: run only for SSM/hybrid
      archs (zamba2, xlstm); skip for pure full-attention archs (gemma3's
      global layers are full attention, so it is skipped too).
    - encoder-only archs would skip decode shapes — none assigned here
      (whisper has a decoder).
    """
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 512k dense KV decode skipped per assignment"
    return True, ""


def cells(cfgs: List[ArchConfig]):
    """All (arch, shape) cells with applicability annotations."""
    out = []
    for cfg in cfgs:
        for shape in SHAPES.values():
            ok, why = applicable(cfg, shape)
            out.append((cfg, shape, ok, why))
    return out
