"""Gemma-3 27B [hf:google/gemma-3]: 5:1 local(window 1024):global, GeGLU,
dual RoPE bases (10k local / 1M global), decoupled head_dim."""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
        d_ff=21504, vocab=262144, mlp="geglu",
        window=1024, global_every=6, rope_base=1e4, rope_base_global=1e6,
        tie_embeddings=True,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="gemma3-27b-smoke", family="dense",
        n_layers=6, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, mlp="geglu",
        window=16, global_every=3, rope_base=1e4, rope_base_global=1e6,
        tie_embeddings=True,
    )


register("gemma3-27b", full, smoke)
