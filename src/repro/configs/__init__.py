from .base import ArchConfig, MoECfg, SSMCfg, get_config, list_archs
from .shapes import SHAPES, SMOKE_SHAPES, ShapeSpec, applicable, cells

__all__ = [
    "ArchConfig", "MoECfg", "SSMCfg", "get_config", "list_archs",
    "SHAPES", "SMOKE_SHAPES", "ShapeSpec", "applicable", "cells",
]
