"""Granite 34B code [arXiv:2405.04324]: dense, MQA (kv=1), 4x gelu MLP."""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="granite-34b", family="dense",
        n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
        d_ff=24576, vocab=49152, mlp="gelu",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="granite-34b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=512, vocab=512, mlp="gelu",
    )


register("granite-34b", full, smoke)
