"""Paper Table 1: single-pass accuracies across 8 datasets x 7 algorithms.

Columns match the paper: libSVM(batch) | Perceptron | Pegasos k=1 | Pegasos
k=20 | LASVM | StreamSVM Algo-1 | StreamSVM Algo-2 (L~10). Results are
averaged over `--runs` random stream orders (paper: 20; default here 5 for
CI time). The paper's own numbers print alongside for comparison.

The C-grid model selection trains every grid point in ONE stream pass via the
multi-ball Pallas engine (fit_c_grid -> streamsvm_fit_many) and reports the
measured speedup over the per-model loop of single-ball kernel fits, which
re-reads the stream once per C.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import (
    fit_batch_l2svm,
    fit_lasvm,
    fit_pegasos,
    fit_perceptron,
)
from repro.core import fit, fit_c_grid, fit_lookahead
from repro.data import PAPER_TABLE1, load_dataset, preprocess_for
from repro.data.stream import permuted
from repro.kernels import streamsvm_fit

C_GRID = (1.0, 10.0, 100.0)


def _acc(w, Xte, yte):
    return float(np.mean(np.sign(Xte @ np.asarray(w)) == yte)) * 100.0


def _pick_c(Xj, yj, Xva, yva):
    """Validate C over the grid with ONE pass of the multi-ball engine.

    Returns (c_star, onepass_seconds, permodel_loop_seconds): both paths are
    warmed up first so the timings compare steady-state stream passes (bank
    engine: one data read for the whole grid; loop: one read per grid point).
    """
    grid = jnp.asarray(C_GRID, jnp.float32)

    bank = fit_c_grid(Xj, yj, grid)  # warmup/compile
    jax.block_until_ready(bank.w)
    t0 = time.perf_counter()
    bank = fit_c_grid(Xj, yj, grid)
    jax.block_until_ready(bank.w)
    t_bank = time.perf_counter() - t0

    for c in C_GRID:  # warmup/compile the per-model loop
        jax.block_until_ready(streamsvm_fit(Xj, yj, c).w)
    t0 = time.perf_counter()
    for c in C_GRID:
        jax.block_until_ready(streamsvm_fit(Xj, yj, c).w)
    t_loop = time.perf_counter() - t0

    accs = [_acc(bank.w[i], Xva, yva) for i in range(len(C_GRID))]
    return C_GRID[int(np.argmax(accs))], t_bank, t_loop


def run(runs: int = 5, datasets=None, lasvm_cap: int = 8000, seed: int = 0):
    """Returns list of row dicts; one per dataset."""
    rows = []
    names = datasets or list(PAPER_TABLE1)
    for name in names:
        Xtr0, ytr0, Xte, yte = load_dataset(name, seed=seed)
        Xtr0, Xte = preprocess_for(name, Xtr0, Xte)
        n_val = max(500, len(ytr0) // 10)
        Xva, yva = Xtr0[-n_val:], ytr0[-n_val:]

        Xj = jnp.asarray(Xtr0)
        yj = jnp.asarray(ytr0)
        c_star, t_grid_onepass, t_grid_loop = _pick_c(Xj, yj, Xva, yva)
        lam = 1.0 / (c_star * len(ytr0))

        accs = {k: [] for k in
                ("perceptron", "pegasos1", "pegasos20", "lasvm", "algo1", "algo2")}
        t0 = time.time()
        for r in range(runs):
            Xp, yp = permuted(Xtr0, ytr0, seed=seed * 1000 + r)
            Xpj, ypj = jnp.asarray(Xp), jnp.asarray(yp)
            wp, _ = fit_perceptron(Xpj, ypj)
            accs["perceptron"].append(_acc(wp, Xte, yte))
            accs["pegasos1"].append(_acc(fit_pegasos(Xpj, ypj, lam, k=1), Xte, yte))
            accs["pegasos20"].append(_acc(fit_pegasos(Xpj, ypj, lam, k=20), Xte, yte))
            if r == 0:  # LASVM is O(N |S| D) python: once per dataset
                # LASVM needs its own C: single-pass online SMO degenerates at
                # large C (one REPROCESS/example cannot unwind saturated
                # alphas), so validate over a small C grid on a prefix.
                best_l = -1.0
                for c_l in (1.0, 10.0):
                    w_try, b_try, _ = fit_lasvm(
                        Xp[: min(2000, lasvm_cap)], yp[: min(2000, lasvm_cap)],
                        C=c_l, return_bias=True,
                    )
                    a_try = float(np.mean(np.sign(Xva @ w_try + b_try) == yva)) * 100
                    if a_try > best_l:
                        best_l, c_lasvm = a_try, c_l
                wl, bl, _ = fit_lasvm(
                    Xp[:lasvm_cap], yp[:lasvm_cap], C=c_lasvm, return_bias=True
                )
                accs["lasvm"].append(
                    float(np.mean(np.sign(Xte @ wl + bl) == yte)) * 100
                )
            accs["algo1"].append(_acc(fit(Xpj, ypj, c_star).w, Xte, yte))
            accs["algo2"].append(
                _acc(fit_lookahead(Xpj, ypj, c_star, 10).w, Xte, yte)
            )
        wbatch, _ = fit_batch_l2svm(Xj, yj, c_star, iters=2000)
        row = {
            "dataset": name,
            "C": c_star,
            "batch": _acc(wbatch, Xte, yte),
            **{k: float(np.mean(v)) for k, v in accs.items()},
            "paper": PAPER_TABLE1[name],
            "seconds": round(time.time() - t0, 1),
            "grid_onepass_s": round(t_grid_onepass, 3),
            "grid_loop_s": round(t_grid_loop, 3),
            "grid_speedup": round(t_grid_loop / max(t_grid_onepass, 1e-9), 2),
        }
        rows.append(row)
    return rows


def main():
    rows = run()
    hdr = ("dataset", "batch", "perceptron", "pegasos1", "pegasos20",
           "lasvm", "algo1", "algo2")
    print(",".join(hdr) + ",paper_batch,paper_algo1,paper_algo2")
    for r in rows:
        p = r["paper"]
        print(
            f'{r["dataset"]},{r["batch"]:.2f},{r["perceptron"]:.2f},'
            f'{r["pegasos1"]:.2f},{r["pegasos20"]:.2f},{r["lasvm"]:.2f},'
            f'{r["algo1"]:.2f},{r["algo2"]:.2f},{p[0]},{p[5]},{p[6]}'
        )
    print()
    print("# C-grid model selection: multi-ball engine (one stream pass for "
          f"{len(C_GRID)} C values) vs per-model single-ball loop")
    for r in rows:
        print(
            f'# {r["dataset"]}: one-pass {r["grid_onepass_s"]:.3f}s, '
            f'loop {r["grid_loop_s"]:.3f}s, speedup {r["grid_speedup"]:.2f}x'
        )


if __name__ == "__main__":
    main()
