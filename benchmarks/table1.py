"""Paper Table 1: single-pass accuracies across 8 datasets x 7 algorithms.

Columns match the paper: libSVM(batch) | Perceptron | Pegasos k=1 | Pegasos
k=20 | LASVM | StreamSVM Algo-1 | StreamSVM Algo-2 (L~10). Results are
averaged over `--runs` random stream orders (paper: 20; default here 5 for
CI time). The paper's own numbers print alongside for comparison.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.baselines import (
    fit_batch_l2svm,
    fit_lasvm,
    fit_pegasos,
    fit_perceptron,
)
from repro.core import fit, fit_lookahead
from repro.data import PAPER_TABLE1, load_dataset, preprocess_for
from repro.data.stream import permuted

C_GRID = (1.0, 10.0, 100.0)


def _acc(w, Xte, yte):
    return float(np.mean(np.sign(Xte @ np.asarray(w)) == yte)) * 100.0


def _pick_c(fit_fn, Xtr, ytr, Xva, yva):
    best, best_c = -1.0, C_GRID[0]
    for c in C_GRID:
        w = fit_fn(c)
        a = _acc(w, Xva, yva)
        if a > best:
            best, best_c = a, c
    return best_c


def run(runs: int = 5, datasets=None, lasvm_cap: int = 8000, seed: int = 0):
    """Returns list of row dicts; one per dataset."""
    rows = []
    names = datasets or list(PAPER_TABLE1)
    for name in names:
        Xtr0, ytr0, Xte, yte = load_dataset(name, seed=seed)
        Xtr0, Xte = preprocess_for(name, Xtr0, Xte)
        n_val = max(500, len(ytr0) // 10)
        Xva, yva = Xtr0[-n_val:], ytr0[-n_val:]

        Xj = jnp.asarray(Xtr0)
        yj = jnp.asarray(ytr0)
        c_star = _pick_c(lambda c: fit(Xj, yj, c).w, Xtr0, ytr0, Xva, yva)
        lam = 1.0 / (c_star * len(ytr0))

        accs = {k: [] for k in
                ("perceptron", "pegasos1", "pegasos20", "lasvm", "algo1", "algo2")}
        t0 = time.time()
        for r in range(runs):
            Xp, yp = permuted(Xtr0, ytr0, seed=seed * 1000 + r)
            Xpj, ypj = jnp.asarray(Xp), jnp.asarray(yp)
            wp, _ = fit_perceptron(Xpj, ypj)
            accs["perceptron"].append(_acc(wp, Xte, yte))
            accs["pegasos1"].append(_acc(fit_pegasos(Xpj, ypj, lam, k=1), Xte, yte))
            accs["pegasos20"].append(_acc(fit_pegasos(Xpj, ypj, lam, k=20), Xte, yte))
            if r == 0:  # LASVM is O(N |S| D) python: once per dataset
                # LASVM needs its own C: single-pass online SMO degenerates at
                # large C (one REPROCESS/example cannot unwind saturated
                # alphas), so validate over a small C grid on a prefix.
                best_l = -1.0
                for c_l in (1.0, 10.0):
                    w_try, b_try, _ = fit_lasvm(
                        Xp[: min(2000, lasvm_cap)], yp[: min(2000, lasvm_cap)],
                        C=c_l, return_bias=True,
                    )
                    a_try = float(np.mean(np.sign(Xva @ w_try + b_try) == yva)) * 100
                    if a_try > best_l:
                        best_l, c_lasvm = a_try, c_l
                wl, bl, _ = fit_lasvm(
                    Xp[:lasvm_cap], yp[:lasvm_cap], C=c_lasvm, return_bias=True
                )
                accs["lasvm"].append(
                    float(np.mean(np.sign(Xte @ wl + bl) == yte)) * 100
                )
            accs["algo1"].append(_acc(fit(Xpj, ypj, c_star).w, Xte, yte))
            accs["algo2"].append(
                _acc(fit_lookahead(Xpj, ypj, c_star, 10).w, Xte, yte)
            )
        wbatch, _ = fit_batch_l2svm(Xj, yj, c_star, iters=2000)
        row = {
            "dataset": name,
            "C": c_star,
            "batch": _acc(wbatch, Xte, yte),
            **{k: float(np.mean(v)) for k, v in accs.items()},
            "paper": PAPER_TABLE1[name],
            "seconds": round(time.time() - t0, 1),
        }
        rows.append(row)
    return rows


def main():
    rows = run()
    hdr = ("dataset", "batch", "perceptron", "pegasos1", "pegasos20",
           "lasvm", "algo1", "algo2")
    print(",".join(hdr) + ",paper_batch,paper_algo1,paper_algo2")
    for r in rows:
        p = r["paper"]
        print(
            f'{r["dataset"]},{r["batch"]:.2f},{r["perceptron"]:.2f},'
            f'{r["pegasos1"]:.2f},{r["pegasos20"]:.2f},{r["lasvm"]:.2f},'
            f'{r["algo1"]:.2f},{r["algo2"]:.2f},{p[0]},{p[5]},{p[6]}'
        )


if __name__ == "__main__":
    main()
