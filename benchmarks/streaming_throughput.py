"""Engine throughput harness: sweeps the tiled bank engine, emits BENCH JSON.

Sweeps (B, D, N, block_n, b_tile, stream_dtype, variant, n_shards,
bank_resident) over the tiled multi-ball engine, measures seconds/pass,
rows/s and model-rows/s, derives achieved GB/s from the engine's modeled HBM
byte traffic, and compares against a bandwidth-roofline estimate (default
TPU v5e 819 GB/s per chip — override with ``--hbm-peak-gbps`` or the
``REPRO_HBM_PEAK_GBPS`` env var for TPU-measured runs; on the CPU interpret
backend the roofline fraction is reported for trend only).

The modeled bytes encode the engine's central claim: the stream is read ONCE
per fit regardless of how many bank tiles revisit it (``stream_passes`` stays
1.0 while ``naive_stream_bytes`` shows what B/b_tile passes would cost), and
bf16 stream tiles halve the stream term. Under ``bank_resident="vmem"`` the
bank round-trips HBM twice (in + out), independent of N; under "hbm" it
round-trips once per DATA BLOCK (the 2-slot ring re-fetches and writes back
every (b_tile, D) slice each time a stream block revisits it) — the traffic
the ring's async prefetch/write-back is there to hide. Rows carry the
per-config VMEM working-set estimate (``vmem_working_set_bytes``, from
kernels.ops's residency byte model) and hbm rows carry
``dma_overlap_efficiency`` — seconds(vmem baseline) / seconds(hbm) at equal
shape. The two rows do the SAME fit, so this is the achieved-GB/s ratio at
equal (the baseline's) modeled bytes: 1.0 = the added bank round-trips are
fully hidden behind compute, below 1.0 = they cost wall time. (Each row's
own ``achieved_gbps`` uses its own residency's byte model — the hbm row
genuinely moves more HBM bytes — so the efficiency is NOT the ratio of the
two ``achieved_gbps`` fields.)

``n_shards > 1`` rows run ``core.fit_bank_sharded`` over a ``(n_shards,)``
device mesh — each shard reads 1/n_shards of the stream, so the per-device
byte model divides the stream/sign terms by the shard count and the ideal
scaling efficiency is ``seconds(1 shard) / (n_shards * seconds(n))``.
Configs needing more devices than the process has are SKIPPED (printed, not
silent); CI's bench-smoke forces 8 host devices so the sharded smoke row is
always measured there.

Kernelized rows (schema v3) sweep ``coreset_size`` x ``eviction`` x
``n_shards``: ``eviction`` picks the core-set compression policy
("smallest-coef" or "farthest-point" — the latter maintains an extra (S, S)
core-set Gram carry per model), and ``n_shards > 1`` routes through
``fit_kernel_bank(..., mesh=)`` — per-shard one-pass fits folded with the
kernelized Sec-4.3 merge. Their ``vmem_working_set_bytes`` comes from
``kernels.ops.kernel_engine_vmem_bytes``, the same byte model the fit's
preflight budgets against (``s_tile=`` caps its core-set operand terms).

Writes ``BENCH_engine.json`` at the repo root (schema below) so the perf
trajectory is tracked from this PR onward, and prints one ``BENCH`` line per
config. ``--smoke`` runs a seconds-scale sweep in interpret mode for CI,
which validates the same schema.

    PYTHONPATH=src python benchmarks/streaming_throughput.py [--smoke]
        [--out BENCH_engine.json] [--reps 3]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import streamsvm_fit_many
from repro.kernels.ops import bank_tiling, engine_vmem_bytes

SCHEMA = "streamsvm-bench-engine/v4"
DEFAULT_HBM_PEAK_GBPS = 819.0  # TPU v5e, per chip
_DTYPE_BYTES = {"f32": 4, "bf16": 2}


def hbm_peak_gbps(override=None) -> float:
    """Roofline peak: --hbm-peak-gbps flag > REPRO_HBM_PEAK_GBPS env >
    the TPU v5e default — so TPU-measured runs never need a source edit."""
    if override is not None:
        return float(override)
    env = os.environ.get("REPRO_HBM_PEAK_GBPS")
    return float(env) if env else DEFAULT_HBM_PEAK_GBPS


# Keys every result row must carry — CI validates the emitted JSON against
# this (see .github/workflows/ci.yml bench-smoke).
RESULT_KEYS = (
    "name", "B", "D", "N", "block_n", "b_tile", "n_bank_tiles", "n_shards",
    "stream_dtype", "variant", "lookahead", "bank_resident", "kernel",
    "coreset_size", "eviction", "vmem_working_set_bytes", "seconds_per_pass",
    "rows_per_s", "rows_per_s_per_shard", "model_rows_per_s", "bytes",
    "stream_passes",
    "naive_stream_bytes", "achieved_gbps", "hbm_peak_gbps",
    "roofline_seconds", "roofline_frac", "dma_overlap_efficiency",
)


def modeled_bytes(B, D, N, stream_dtype, n_shards=1, *, block_n=256,
                  b_tile=None, bank_resident="vmem", lookahead=None,
                  kernel=None, coreset_size=None):
    """PER-DEVICE HBM bytes per pass under the tiled engine's movement model.

    stream: each (block_n, D) tile DMA'd once (data-major grid) — N*D at the
    stream dtype, NOT multiplied by the B/b_tile bank tiles that revisit it.
    Sharding splits the stream over devices: N/n_shards rows per device.
    signs:  each (b_tile, block_n) tile read once over the whole grid —
    B*N/n_shards per device.
    bank:   under bank_resident="vmem" the (B, D) f32 bank enters and leaves
    HBM once per device (it persists in VMEM across the grid); under "hbm"
    every (b_tile, D) slice round-trips once per DATA BLOCK — the ring
    re-fetches and writes back the whole bank (and the B*L*D lookahead
    windows) each of the ceil(N_shard/block_n) times the stream revisits it —
    EXCEPT when the bank spans <= 2 tiles, where the kernel degenerates to
    load-once/store-once (each tile owns a ring slot) and the traffic equals
    the vmem layout's. The fold's all_gather moves another
    (n_shards-1)*B*(D+3) floats over ICI (not HBM — excluded).
    """
    sz = _DTYPE_BYTES[stream_dtype]
    shard_n = -(-N // n_shards)
    if kernel is not None:
        # Kernelized bank: the stream is still read once (data-major tiles);
        # every tile additionally gathers each model's (S, D) core set back
        # from HBM (the buffer indices change as slots fill/evict, so the
        # gather cannot persist across tiles) and writes the two Gram blocks
        # the recursion reads. State out is the (B, S, D) core-set buffer.
        n_tiles = -(-shard_n // block_n)
        return {
            "stream": shard_n * D * sz,
            "signs": B * shard_n * sz,
            "coreset_gather": n_tiles * B * coreset_size * D * 4,
            "gram_blocks": n_tiles
            * (block_n * B * coreset_size + block_n * block_n) * 4,
            "bank": B * coreset_size * (D + 1) * 4,
        }
    _, n_btiles = bank_tiling(B, b_tile)
    trips = (
        -(-shard_n // block_n)
        if bank_resident == "hbm" and n_btiles > 2
        else 1
    )
    by = {
        "stream": shard_n * D * sz,
        "signs": B * shard_n * sz,
        "bank": 2 * B * D * 4 * trips,
    }
    if bank_resident == "hbm" and lookahead:
        l_max = max(lookahead) if isinstance(lookahead, (tuple, list)) else lookahead
        by["lookahead_windows"] = 2 * B * l_max * D * 4 * trips
    return by


def bench_one(cfg, reps, interpret, peak_gbps):
    B, D, N = cfg["B"], cfg["D"], cfg["N"]
    n_shards = cfg.get("n_shards", 1)
    bank_resident = cfg.get("bank_resident", "vmem")
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    Y = jnp.asarray(np.sign(rng.normal(size=(B, N))).astype(np.float32))
    cs = jnp.asarray(np.full(B, 10.0, np.float32))
    variant = cfg.get("variant", "exact")
    lookahead = cfg.get("lookahead")
    kernel = cfg.get("kernel")
    coreset_size = cfg.get("coreset_size")
    sdt = cfg["stream_dtype"] if cfg["stream_dtype"] != "f32" else None
    if kernel is not None:
        from repro.core import fit_kernel_bank
        from repro.kernels.ops import kernel_engine_vmem_bytes

        eviction = cfg.get("eviction", "smallest-coef")
        s_tile = cfg.get("s_tile")
        mesh = (
            jax.make_mesh((n_shards,), ("data",)) if n_shards > 1 else None
        )
        fit = lambda X_, Y_, cs_: fit_kernel_bank(
            X_, Y_, cs_, kernel=kernel, gamma=0.5,
            coreset_size=coreset_size, eviction=eviction, variant=variant,
            block_n=cfg["block_n"], s_tile=s_tile, stream_dtype=sdt,
            mesh=mesh, interpret=interpret,
        )
        run = lambda: jax.block_until_ready(fit(X, Y, cs))
        run()  # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            run()
        sec = (time.perf_counter() - t0) / reps
        by = modeled_bytes(
            B, D, N, cfg["stream_dtype"], n_shards, block_n=cfg["block_n"],
            kernel=kernel, coreset_size=coreset_size,
        )
        total = sum(by.values())
        roofline_sec = total / (peak_gbps * 1e9)
        # Per-step VMEM working set from the engine's own preflight byte
        # model (Gram tiles + the s_tile-capped K_cs block / core-set
        # operand + the stream tile) — the same numbers fit_kernel_bank
        # budgets against.
        working_set = sum(
            kernel_engine_vmem_bytes(
                B, D, coreset_size=coreset_size, block_n=cfg["block_n"],
                s_tile=s_tile, stream_dtype=sdt,
            ).values()
        )
        return {
            "name": cfg["name"],
            "B": B,
            "D": D,
            "N": N,
            "block_n": cfg["block_n"],
            "b_tile": None,
            "n_bank_tiles": 1,
            "n_shards": n_shards,
            "stream_dtype": cfg["stream_dtype"],
            "variant": variant,
            "lookahead": None,
            "bank_resident": "vmem",
            "kernel": kernel,
            "coreset_size": coreset_size,
            "eviction": eviction,
            "vmem_working_set_bytes": working_set,
            "seconds_per_pass": sec,
            "rows_per_s": N / sec,
            # v4: per-device ingest rate — the elastic live loop's scaling
            # denominator (kernelized fits here are single-device)
            "rows_per_s_per_shard": N / sec / n_shards,
            "model_rows_per_s": B * N / sec,
            "bytes": {**by, "total": total},
            "stream_passes": 1.0,
            # a per-model dense kernelized fit would re-read the stream B
            # times (and carry O(N) coefficients); the bank reads it once
            "naive_stream_bytes": B * by["stream"],
            "achieved_gbps": total / sec / 1e9,
            "hbm_peak_gbps": peak_gbps,
            "roofline_seconds": roofline_sec,
            "roofline_frac": roofline_sec / sec,
            "dma_overlap_efficiency": None,
        }
    kw = dict(
        variant=variant,
        lookahead=lookahead,
        block_n=cfg["block_n"],
        b_tile=cfg["b_tile"],
        stream_dtype=sdt,
        bank_resident=bank_resident,
        interpret=interpret,
    )
    if n_shards > 1:
        from repro.core import fit_bank_sharded

        mesh = jax.make_mesh((n_shards,), ("data",))
        fit = jax.jit(
            lambda X_, Y_, cs_: fit_bank_sharded(X_, Y_, cs_, mesh, **kw)
        )
    else:
        fit = lambda X_, Y_, cs_: streamsvm_fit_many(X_, Y_, cs_, **kw)
    run = lambda: jax.block_until_ready(fit(X, Y, cs))
    run()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        run()
    sec = (time.perf_counter() - t0) / reps

    b_tile_eff, n_btiles = bank_tiling(B, cfg["b_tile"])
    by = modeled_bytes(
        B, D, N, cfg["stream_dtype"], n_shards, block_n=cfg["block_n"],
        b_tile=cfg["b_tile"], bank_resident=bank_resident,
        lookahead=lookahead,
    )
    total = sum(by.values())
    roofline_sec = total / (peak_gbps * 1e9)
    l_max = (
        max(lookahead) if isinstance(lookahead, (tuple, list)) else lookahead
    )
    working_set = sum(
        engine_vmem_bytes(
            B, D, block_n=cfg["block_n"], b_tile=cfg["b_tile"],
            stream_dtype=(
                cfg["stream_dtype"] if cfg["stream_dtype"] != "f32" else None
            ),
            lookahead_max=l_max, bank_resident=bank_resident,
        ).values()
    )
    return {
        "name": cfg["name"],
        "B": B,
        "D": D,
        "N": N,
        "block_n": cfg["block_n"],
        "b_tile": b_tile_eff,
        "n_bank_tiles": n_btiles,
        "n_shards": n_shards,
        "stream_dtype": cfg["stream_dtype"],
        "variant": variant,
        "lookahead": lookahead,
        "bank_resident": bank_resident,
        "kernel": None,
        "coreset_size": None,
        "eviction": None,
        "vmem_working_set_bytes": working_set,
        "seconds_per_pass": sec,
        "rows_per_s": N / sec,
        # v4: ingest rate per mesh device — flat rows_per_s across shard
        # counts means linear weak scaling of the sharded engine
        "rows_per_s_per_shard": N / sec / n_shards,
        "model_rows_per_s": B * N / sec,  # conditional updates applied / s
        "bytes": {**by, "total": total},
        "stream_passes": 1.0,  # data-major grid: NOT B/b_tile
        "naive_stream_bytes": n_btiles * by["stream"],  # bank-major would pay this
        "achieved_gbps": total / sec / 1e9,
        "hbm_peak_gbps": peak_gbps,
        "roofline_seconds": roofline_sec,
        "roofline_frac": roofline_sec / sec,
        # filled in post-sweep for hbm rows with a named vmem baseline
        "dma_overlap_efficiency": None,
    }


def sweep(smoke: bool):
    if smoke:
        base = dict(B=16, D=64, N=512, block_n=128)
        return [
            dict(name="smoke_single_tile", **base, b_tile=None, stream_dtype="f32"),
            dict(name="smoke_tiled", **base, b_tile=8, stream_dtype="f32"),
            dict(name="smoke_bf16", **base, b_tile=8, stream_dtype="bf16"),
            dict(name="smoke_lookahead", **base, b_tile=8, stream_dtype="f32",
                 variant="lookahead", lookahead=4),
            # HBM-resident bank: same shape as smoke_tiled, bank double-
            # buffered through the ring — the ratio of achieved GB/s is the
            # DMA-overlap efficiency (CI asserts this row + its fields)
            dict(name="smoke_hbm", **base, b_tile=8, stream_dtype="f32",
                 bank_resident="hbm", overlap_baseline="smoke_tiled"),
            # sharded bank engine (needs >= 8 devices; CI's bench-smoke job
            # forces 8 host devices via XLA_FLAGS so this row is measured)
            dict(name="smoke_sharded_s8", **base, b_tile=8, stream_dtype="f32",
                 n_shards=8),
            # kernelized core-set bank: same one-pass read, RBF Gram blocks
            # through the fused epilogue (CI asserts this row + its fields)
            dict(name="smoke_kernel_rbf", **base, b_tile=None,
                 stream_dtype="f32", kernel="rbf", coreset_size=32),
            # eviction-policy variant of the same kernelized fit
            dict(name="smoke_kernel_rbf_fp", **base, b_tile=None,
                 stream_dtype="f32", kernel="rbf", coreset_size=32,
                 eviction="farthest-point"),
            # mesh-sharded kernelized bank (8 host devices in CI's second
            # bench-smoke pass; CI asserts this row carries n_shards == 8
            # and an eviction field)
            dict(name="smoke_sharded_kernel_rbf_s8", **base, b_tile=None,
                 stream_dtype="f32", kernel="rbf", coreset_size=32,
                 n_shards=8),
        ]
    base = dict(D=128, N=4096, block_n=256)
    cfgs = [
        # bank scaling at fixed tile: one stream pass for 1x..8x the tile
        dict(name="bank_b64_single_tile", B=64, **base, b_tile=None,
             stream_dtype="f32"),
        dict(name="bank_b64_t8", B=64, **base, b_tile=8, stream_dtype="f32"),
        dict(name="bank_b128_t8", B=128, **base, b_tile=8, stream_dtype="f32"),
        dict(name="bank_b256_t32", B=256, **base, b_tile=32, stream_dtype="f32"),
        # dtype policy: same shape, half the stream bytes
        dict(name="bank_b64_t8_bf16", B=64, **base, b_tile=8,
             stream_dtype="bf16"),
        dict(name="bank_b256_t32_bf16", B=256, **base, b_tile=32,
             stream_dtype="bf16"),
        # fused Algorithm-2 lookahead in the same single pass
        dict(name="lookahead_b64_t8_L8", B=64, **base, b_tile=8,
             stream_dtype="f32", variant="lookahead", lookahead=8),
        # HBM-resident bank: equal-shape pair measures the DMA-overlap
        # efficiency (how much of the per-block bank round-trip the ring's
        # async prefetch/write-back hides behind the MXU work)
        dict(name="bank_b256_t32_hbm", B=256, **base, b_tile=32,
             stream_dtype="f32", bank_resident="hbm",
             overlap_baseline="bank_b256_t32"),
        # a bank whose (B, D) f32 footprint (25.2 MB) exceeds the default
        # 16 MiB VMEM budget — impossible to hold VMEM-resident at all
        dict(name="bank_b1536_d4096_hbm_beyond_vmem", B=1536, D=4096, N=1024,
             block_n=256, b_tile=64, stream_dtype="f32",
             bank_resident="hbm"),
        # block_n sensitivity
        dict(name="bank_b64_t8_n512", B=64, D=128, N=4096, block_n=512,
             b_tile=8, stream_dtype="f32"),
        # stream sharding: same fit spread over a device mesh — scaling
        # efficiency is seconds(bank_b64_t8) / (n_shards * seconds(row))
        dict(name="sharded_b64_t8_s2", B=64, **base, b_tile=8,
             stream_dtype="f32", n_shards=2),
        dict(name="sharded_b64_t8_s4", B=64, **base, b_tile=8,
             stream_dtype="f32", n_shards=4),
        dict(name="sharded_b64_t8_s8", B=64, **base, b_tile=8,
             stream_dtype="f32", n_shards=8),
        dict(name="sharded_b256_t32_s8_bf16", B=256, **base, b_tile=32,
             stream_dtype="bf16", n_shards=8),
        # kernelized core-set bank: bounded O(B*S*D) state, per-tile RBF /
        # linear Gram blocks through the fused epilogue, one stream pass
        dict(name="kernel_rbf_b16_s64", B=16, **base, b_tile=None,
             stream_dtype="f32", kernel="rbf", coreset_size=64),
        dict(name="kernel_rbf_b64_s64", B=64, **base, b_tile=None,
             stream_dtype="f32", kernel="rbf", coreset_size=64),
        dict(name="kernel_linear_b16_s64", B=16, **base, b_tile=None,
             stream_dtype="f32", kernel="linear", coreset_size=64),
        dict(name="kernel_rbf_b16_s64_bf16", B=16, **base, b_tile=None,
             stream_dtype="bf16", kernel="rbf", coreset_size=64),
        # core-set size sweep: S is the state/accuracy knob — smaller S
        # means less Gram work and gather traffic per tile
        dict(name="kernel_rbf_b16_s16", B=16, **base, b_tile=None,
             stream_dtype="f32", kernel="rbf", coreset_size=16),
        dict(name="kernel_rbf_b16_s128", B=16, **base, b_tile=None,
             stream_dtype="f32", kernel="rbf", coreset_size=128),
        # eviction-policy sweep at fixed shape: farthest-point maintains a
        # per-model (S, S) core-set Gram carry on top of smallest-coef
        dict(name="kernel_rbf_b16_s64_fp", B=16, **base, b_tile=None,
             stream_dtype="f32", kernel="rbf", coreset_size=64,
             eviction="farthest-point"),
        # mesh-sharded kernelized bank: per-shard one-pass fits folded with
        # the kernelized Sec-4.3 merge (measured in the forced-8-device
        # second pass, like the linear sharded rows)
        dict(name="sharded_kernel_rbf_b16_s64_s8", B=16, **base, b_tile=None,
             stream_dtype="f32", kernel="rbf", coreset_size=64, n_shards=8),
        dict(name="sharded_kernel_rbf_b16_s64_fp_s8", B=16, **base,
             b_tile=None, stream_dtype="f32", kernel="rbf", coreset_size=64,
             eviction="farthest-point", n_shards=8),
    ]
    return cfgs


def run(smoke: bool, reps: int, interpret, name_filter: str | None = None,
        peak_gbps: float | None = None):
    peak = hbm_peak_gbps(peak_gbps)
    n_dev = len(jax.devices())
    results = []
    baselines = {}
    for cfg in sweep(smoke):
        if name_filter is not None and name_filter not in cfg["name"]:
            continue
        if cfg.get("n_shards", 1) > n_dev:
            # no silent caps: say what was dropped and how to get it
            print(
                f'SKIP {cfg["name"]}: n_shards={cfg["n_shards"]} > '
                f"{n_dev} visible device(s) (set XLA_FLAGS="
                f'--xla_force_host_platform_device_count={cfg["n_shards"]} '
                "and re-run with --filter sharded --append, or use a real "
                "mesh)"
            )
            continue
        row = bench_one(cfg, reps, interpret, peak)
        base = baselines.get(cfg.get("overlap_baseline"))
        if base is not None:
            # DMA-overlap efficiency: wall time vs the equal-shape
            # VMEM-resident baseline — same fit, so 1.0 = the hbm bank
            # round-trips fully hidden behind compute (see module docstring;
            # deliberately NOT the ratio of the rows' achieved_gbps, whose
            # byte models differ)
            row["dma_overlap_efficiency"] = (
                base["seconds_per_pass"] / row["seconds_per_pass"]
            )
        elif cfg.get("overlap_baseline") is not None:
            print(
                f'NOTE {cfg["name"]}: overlap baseline '
                f'{cfg["overlap_baseline"]!r} not measured in this run — '
                "dma_overlap_efficiency stays null"
            )
        baselines[cfg["name"]] = row
        results.append(row)
    return {
        "schema": SCHEMA,
        "generated": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "backend": jax.default_backend(),
        "interpret": (
            jax.default_backend() != "tpu" if interpret is None else interpret
        ),
        "jax_version": jax.__version__,
        "hbm_peak_gbps": peak,
        "smoke": smoke,
        "reps": reps,
        "results": results,
    }


def validate(report: dict):
    """Schema check (used by the CI bench-smoke job).

    This validates the report's SHAPE and that the measurements are sane
    numbers. The one-pass property itself (stream_passes == 1.0) is a design
    invariant of the data-major grid, enforced by the kernel parity suites
    (tests/test_tiled_engine.py bit-exactness across b_tile), not something
    this harness can measure from wall time in interpret mode — the field is
    reported so downstream readers model bytes correctly.
    """
    for key in ("schema", "generated", "backend", "hbm_peak_gbps", "results"):
        if key not in report:
            raise ValueError(f"BENCH report missing key {key!r}")
    if report["schema"] != SCHEMA:
        raise ValueError(f"unexpected schema {report['schema']!r}")
    if not report["results"]:
        raise ValueError("BENCH report has no results")
    for row in report["results"]:
        missing = [k for k in RESULT_KEYS if k not in row]
        if missing:
            raise ValueError(f"result {row.get('name')!r} missing {missing}")
        if not (row["seconds_per_pass"] > 0 and row["achieved_gbps"] > 0):
            raise ValueError(f"{row['name']}: non-positive measurement")
        if not (isinstance(row["n_shards"], int) and row["n_shards"] >= 1):
            raise ValueError(
                f"{row['name']}: n_shards must be an int >= 1, got "
                f"{row['n_shards']!r}"
            )
        pps = row["rows_per_s_per_shard"]
        if not (pps > 0 and abs(pps * row["n_shards"] - row["rows_per_s"])
                <= 1e-6 * row["rows_per_s"]):
            raise ValueError(
                f"{row['name']}: rows_per_s_per_shard ({pps!r}) must be "
                f"rows_per_s / n_shards"
            )
        if row["bank_resident"] not in ("vmem", "hbm"):
            raise ValueError(
                f"{row['name']}: unknown bank_resident "
                f"{row['bank_resident']!r}"
            )
        if row["kernel"] not in (None, "linear", "rbf"):
            raise ValueError(
                f"{row['name']}: unknown kernel {row['kernel']!r}"
            )
        if row["kernel"] is not None and not (
            isinstance(row["coreset_size"], int) and row["coreset_size"] >= 1
        ):
            raise ValueError(
                f"{row['name']}: kernelized rows need coreset_size >= 1, "
                f"got {row['coreset_size']!r}"
            )
        if row["kernel"] is None and row["coreset_size"] is not None:
            raise ValueError(
                f"{row['name']}: coreset_size={row['coreset_size']!r} "
                "without a kernel"
            )
        if row["kernel"] is not None:
            if row["eviction"] not in ("smallest-coef", "farthest-point"):
                raise ValueError(
                    f"{row['name']}: kernelized rows need eviction in "
                    "('smallest-coef', 'farthest-point'), got "
                    f"{row['eviction']!r}"
                )
        elif row["eviction"] is not None:
            raise ValueError(
                f"{row['name']}: eviction={row['eviction']!r} without a "
                "kernel"
            )
        if not (
            isinstance(row["vmem_working_set_bytes"], int)
            and row["vmem_working_set_bytes"] > 0
        ):
            raise ValueError(
                f"{row['name']}: vmem_working_set_bytes must be a positive "
                f"int, got {row['vmem_working_set_bytes']!r}"
            )
        if not row["hbm_peak_gbps"] > 0:
            raise ValueError(
                f"{row['name']}: hbm_peak_gbps must be positive, got "
                f"{row['hbm_peak_gbps']!r}"
            )
        eff = row["dma_overlap_efficiency"]
        if eff is not None and not eff > 0:
            raise ValueError(
                f"{row['name']}: dma_overlap_efficiency must be null or "
                f"positive, got {eff!r}"
            )
    return True


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny CI sweep")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument(
        "--out",
        default=str(Path(__file__).resolve().parent.parent / "BENCH_engine.json"),
    )
    ap.add_argument(
        "--interpret", default=None, choices=["true", "false"],
        help="force interpret mode (default: auto — interpret off-TPU)",
    )
    ap.add_argument(
        "--hbm-peak-gbps", type=float, default=None, metavar="GBPS",
        help="HBM roofline peak in GB/s (default: REPRO_HBM_PEAK_GBPS env "
        f"var, else {DEFAULT_HBM_PEAK_GBPS} — TPU v5e per chip)",
    )
    ap.add_argument(
        "--filter", default=None, metavar="SUBSTR",
        help="bench only configs whose name contains SUBSTR",
    )
    ap.add_argument(
        "--append", action="store_true",
        help="merge results into an existing --out report (rows with the "
        "same name are replaced). Lets sharded rows — which need forced "
        "host devices — be measured in a separate process from the "
        "single-device rows, which must see the real device count "
        "(conftest rule); CI's bench-smoke runs the harness twice this way",
    )
    args = ap.parse_args(argv)
    interpret = None if args.interpret is None else args.interpret == "true"

    report = run(args.smoke, args.reps, interpret, name_filter=args.filter,
                 peak_gbps=args.hbm_peak_gbps)
    out_path = Path(args.out)
    if args.append and out_path.exists():
        prev = json.loads(out_path.read_text())
        new_names = {r["name"] for r in report["results"]}
        report["results"] = [
            r for r in prev.get("results", []) if r["name"] not in new_names
        ] + report["results"]
    validate(report)
    out_path.write_text(json.dumps(report, indent=2) + "\n")

    hdr = ("name", "shards", "resident", "rows/s", "model-rows/s", "GB/s",
           "roofline%", "overlap-eff", "s/pass")
    print(",".join(hdr))
    for r in report["results"]:
        eff = r["dma_overlap_efficiency"]
        print(
            f'{r["name"]},{r["n_shards"]},{r["bank_resident"]},'
            f'{r["rows_per_s"]:.0f},'
            f'{r["model_rows_per_s"]:.0f},'
            f'{r["achieved_gbps"]:.3f},{100 * r["roofline_frac"]:.2f},'
            f'{"-" if eff is None else f"{eff:.3f}"},'
            f'{r["seconds_per_pass"]:.4f}'
        )
    print(f"BENCH written: {args.out}")


if __name__ == "__main__":
    main()
