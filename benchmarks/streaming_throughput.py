"""Throughput/claims benchmark: per-example cost and constant memory.

Validates the paper's complexity claims on this host:
  - per-example wall time is O(D) and independent of N (constant state);
  - state size is exactly D+3 floats regardless of N consumed;
  - the Pallas block-streaming kernel vs the lax.scan reference;
  - distributed scaling: shards process 1/P of the stream each.
Prints name,us_per_example,derived CSV rows.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fit, fit_ball, init_ball
from repro.kernels import streamsvm_fit


def _time(f, *args, reps=3):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def run():
    rows = []
    rng = np.random.default_rng(0)
    # per-example time vs N (expect ~flat us/example)
    for N in (10_000, 40_000, 160_000):
        D = 128
        X = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
        y = jnp.asarray(np.sign(rng.normal(size=N)).astype(np.float32))
        t = _time(lambda: jax.block_until_ready(fit(X, y, 10.0)))
        rows.append((f"scan_fit_N{N}_D{D}", 1e6 * t / N, "us/example"))
    # per-example time vs D (expect ~linear in D)
    for D in (128, 512, 2048):
        N = 40_000
        X = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
        y = jnp.asarray(np.sign(rng.normal(size=N)).astype(np.float32))
        t = _time(lambda: jax.block_until_ready(fit(X, y, 10.0)))
        rows.append((f"scan_fit_N{N}_D{D}", 1e6 * t / N, "us/example"))
    # pallas kernel vs scan at same size
    N, D = 40_000, 512
    X = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=N)).astype(np.float32))
    t_scan = _time(lambda: jax.block_until_ready(fit(X, y, 10.0)))
    t_pal = _time(lambda: jax.block_until_ready(streamsvm_fit(X, y, 10.0)))
    rows.append(("pallas_kernel_N40000_D512", 1e6 * t_pal / N, "us/example"))
    rows.append(("pallas_vs_scan_speedup", t_scan / t_pal, "x (interpret mode)"))
    # constant state: bytes of the ball
    ball = fit(X[:1000], y[:1000], 10.0)
    state_bytes = sum(np.asarray(v).nbytes for v in jax.tree.leaves(ball))
    rows.append(("state_bytes_D512", state_bytes, "bytes (= 4D+12)"))
    return rows


def main():
    for name, val, unit in run():
        print(f"{name},{val:.3f},{unit}")


if __name__ == "__main__":
    main()
