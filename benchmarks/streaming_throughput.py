"""Engine throughput harness: sweeps the tiled bank engine, emits BENCH JSON.

Sweeps (B, D, N, block_n, b_tile, stream_dtype, variant) over the tiled
multi-ball engine, measures seconds/pass, rows/s and model-rows/s, derives
achieved GB/s from the engine's modeled HBM byte traffic, and compares
against a bandwidth-roofline estimate (TPU v5e 819 GB/s per chip; on the CPU
interpret backend the roofline fraction is reported for trend only).

The modeled bytes encode the engine's central claim: the stream is read ONCE
per fit regardless of how many bank tiles revisit it (``stream_passes`` stays
1.0 while ``naive_stream_bytes`` shows what B/b_tile passes would cost), and
bf16 stream tiles halve the stream term. The bank round-trips HBM twice
(in + out), independent of N.

Writes ``BENCH_engine.json`` at the repo root (schema below) so the perf
trajectory is tracked from this PR onward, and prints one ``BENCH`` line per
config. ``--smoke`` runs a seconds-scale sweep in interpret mode for CI,
which validates the same schema.

    PYTHONPATH=src python benchmarks/streaming_throughput.py [--smoke]
        [--out BENCH_engine.json] [--reps 3]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import streamsvm_fit_many
from repro.kernels.ops import bank_tiling

SCHEMA = "streamsvm-bench-engine/v1"
HBM_PEAK_GBPS = 819.0  # TPU v5e, per chip
_DTYPE_BYTES = {"f32": 4, "bf16": 2}

# Keys every result row must carry — CI validates the emitted JSON against
# this (see .github/workflows/ci.yml bench-smoke).
RESULT_KEYS = (
    "name", "B", "D", "N", "block_n", "b_tile", "n_bank_tiles",
    "stream_dtype", "variant", "lookahead", "seconds_per_pass", "rows_per_s",
    "model_rows_per_s", "bytes", "stream_passes", "naive_stream_bytes",
    "achieved_gbps", "roofline_seconds", "roofline_frac",
)


def modeled_bytes(B, D, N, stream_dtype):
    """HBM bytes per pass under the tiled engine's movement model.

    stream: each (block_n, D) tile DMA'd once (data-major grid) — N*D at the
    stream dtype, NOT multiplied by the B/b_tile bank tiles that revisit it.
    signs:  each (b_tile, block_n) tile read once over the whole grid — B*N.
    bank:   (B, D) f32 in once + out once; scalar state is negligible.
    """
    sz = _DTYPE_BYTES[stream_dtype]
    return {
        "stream": N * D * sz,
        "signs": B * N * sz,
        "bank": 2 * B * D * 4,
    }


def bench_one(cfg, reps, interpret):
    B, D, N = cfg["B"], cfg["D"], cfg["N"]
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    Y = jnp.asarray(np.sign(rng.normal(size=(B, N))).astype(np.float32))
    cs = jnp.asarray(np.full(B, 10.0, np.float32))
    variant = cfg.get("variant", "exact")
    lookahead = cfg.get("lookahead")
    kw = dict(
        variant=variant,
        lookahead=lookahead,
        block_n=cfg["block_n"],
        b_tile=cfg["b_tile"],
        stream_dtype=cfg["stream_dtype"] if cfg["stream_dtype"] != "f32" else None,
        interpret=interpret,
    )
    run = lambda: jax.block_until_ready(streamsvm_fit_many(X, Y, cs, **kw))
    run()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        run()
    sec = (time.perf_counter() - t0) / reps

    b_tile_eff, n_btiles = bank_tiling(B, cfg["b_tile"])
    by = modeled_bytes(B, D, N, cfg["stream_dtype"])
    total = sum(by.values())
    roofline_sec = total / (HBM_PEAK_GBPS * 1e9)
    return {
        "name": cfg["name"],
        "B": B,
        "D": D,
        "N": N,
        "block_n": cfg["block_n"],
        "b_tile": b_tile_eff,
        "n_bank_tiles": n_btiles,
        "stream_dtype": cfg["stream_dtype"],
        "variant": variant,
        "lookahead": lookahead,
        "seconds_per_pass": sec,
        "rows_per_s": N / sec,
        "model_rows_per_s": B * N / sec,  # conditional updates applied / s
        "bytes": {**by, "total": total},
        "stream_passes": 1.0,  # data-major grid: NOT B/b_tile
        "naive_stream_bytes": n_btiles * by["stream"],  # bank-major would pay this
        "achieved_gbps": total / sec / 1e9,
        "roofline_seconds": roofline_sec,
        "roofline_frac": roofline_sec / sec,
    }


def sweep(smoke: bool):
    if smoke:
        base = dict(B=16, D=64, N=512, block_n=128)
        return [
            dict(name="smoke_single_tile", **base, b_tile=None, stream_dtype="f32"),
            dict(name="smoke_tiled", **base, b_tile=8, stream_dtype="f32"),
            dict(name="smoke_bf16", **base, b_tile=8, stream_dtype="bf16"),
            dict(name="smoke_lookahead", **base, b_tile=8, stream_dtype="f32",
                 variant="lookahead", lookahead=4),
        ]
    base = dict(D=128, N=4096, block_n=256)
    cfgs = [
        # bank scaling at fixed tile: one stream pass for 1x..8x the tile
        dict(name="bank_b64_single_tile", B=64, **base, b_tile=None,
             stream_dtype="f32"),
        dict(name="bank_b64_t8", B=64, **base, b_tile=8, stream_dtype="f32"),
        dict(name="bank_b128_t8", B=128, **base, b_tile=8, stream_dtype="f32"),
        dict(name="bank_b256_t32", B=256, **base, b_tile=32, stream_dtype="f32"),
        # dtype policy: same shape, half the stream bytes
        dict(name="bank_b64_t8_bf16", B=64, **base, b_tile=8,
             stream_dtype="bf16"),
        dict(name="bank_b256_t32_bf16", B=256, **base, b_tile=32,
             stream_dtype="bf16"),
        # fused Algorithm-2 lookahead in the same single pass
        dict(name="lookahead_b64_t8_L8", B=64, **base, b_tile=8,
             stream_dtype="f32", variant="lookahead", lookahead=8),
        # block_n sensitivity
        dict(name="bank_b64_t8_n512", B=64, D=128, N=4096, block_n=512,
             b_tile=8, stream_dtype="f32"),
    ]
    return cfgs


def run(smoke: bool, reps: int, interpret):
    results = [bench_one(cfg, reps, interpret) for cfg in sweep(smoke)]
    return {
        "schema": SCHEMA,
        "generated": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "backend": jax.default_backend(),
        "interpret": (
            jax.default_backend() != "tpu" if interpret is None else interpret
        ),
        "jax_version": jax.__version__,
        "hbm_peak_gbps": HBM_PEAK_GBPS,
        "smoke": smoke,
        "reps": reps,
        "results": results,
    }


def validate(report: dict):
    """Schema check (used by the CI bench-smoke job).

    This validates the report's SHAPE and that the measurements are sane
    numbers. The one-pass property itself (stream_passes == 1.0) is a design
    invariant of the data-major grid, enforced by the kernel parity suites
    (tests/test_tiled_engine.py bit-exactness across b_tile), not something
    this harness can measure from wall time in interpret mode — the field is
    reported so downstream readers model bytes correctly.
    """
    for key in ("schema", "generated", "backend", "hbm_peak_gbps", "results"):
        if key not in report:
            raise ValueError(f"BENCH report missing key {key!r}")
    if report["schema"] != SCHEMA:
        raise ValueError(f"unexpected schema {report['schema']!r}")
    if not report["results"]:
        raise ValueError("BENCH report has no results")
    for row in report["results"]:
        missing = [k for k in RESULT_KEYS if k not in row]
        if missing:
            raise ValueError(f"result {row.get('name')!r} missing {missing}")
        if not (row["seconds_per_pass"] > 0 and row["achieved_gbps"] > 0):
            raise ValueError(f"{row['name']}: non-positive measurement")
    return True


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny CI sweep")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument(
        "--out",
        default=str(Path(__file__).resolve().parent.parent / "BENCH_engine.json"),
    )
    ap.add_argument(
        "--interpret", default=None, choices=["true", "false"],
        help="force interpret mode (default: auto — interpret off-TPU)",
    )
    args = ap.parse_args(argv)
    interpret = None if args.interpret is None else args.interpret == "true"

    report = run(args.smoke, args.reps, interpret)
    validate(report)
    Path(args.out).write_text(json.dumps(report, indent=2) + "\n")

    hdr = ("name", "rows/s", "model-rows/s", "GB/s", "roofline%", "s/pass")
    print(",".join(hdr))
    for r in report["results"]:
        print(
            f'{r["name"]},{r["rows_per_s"]:.0f},{r["model_rows_per_s"]:.0f},'
            f'{r["achieved_gbps"]:.3f},{100 * r["roofline_frac"]:.2f},'
            f'{r["seconds_per_pass"]:.4f}'
        )
    print(f"BENCH written: {args.out}")


if __name__ == "__main__":
    main()
