"""Roofline analysis per (arch x shape x mesh) from the dry-run artifacts.

Three terms (seconds, per training/serve step, on the single-pod mesh):

  compute    = FLOPs_total / (chips * 197e12)         [bf16 MXU peak]
  memory     = HBM_bytes   / (chips * 819e9)
  collective = collective_bytes / (chips * 50e9)      [per-link ICI]

Methodology note (EXPERIMENTS.md §Roofline): XLA-CPU's cost_analysis counts
while-loop (lax.scan) bodies ONCE and legalizes bf16 temps to f32, so its
"flops"/"bytes" undercount scanned layers and overstate buffer sizes. The
roofline terms therefore come from the explicit analytic cost model below
(the same napkin math the perf loop optimizes); the HLO-parsed collective
bytes and memory_analysis numbers from the dry-run JSONs are reported
alongside as observed per-iteration lower bounds / f32-inflated peaks.

MODEL_FLOPS = 6 * N_active * tokens (the useful-compute yardstick);
ratio = MODEL_FLOPS / FLOPs_total exposes remat/attention/dispatch overhead.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

from repro.configs import SHAPES, applicable, get_config, list_archs
from repro.configs.base import ArchConfig

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e)
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link
BP = 2  # param bytes (bf16)
BA = 2  # activation bytes (bf16)


@dataclass
class Cost:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    model_flops: float


def _layer_dims(cfg: ArchConfig):
    d, hd = cfg.d_model, cfg.hd
    attn_proj = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    if cfg.moe is not None:
        ffn = cfg.moe.top_k * 3 * d * cfg.moe.d_ff + d * cfg.moe.n_experts
    elif cfg.mlp in ("swiglu", "geglu"):
        ffn = 3 * d * cfg.d_ff
    elif cfg.mlp == "none":
        ffn = 0
    else:
        ffn = 2 * d * cfg.d_ff
    return attn_proj, ffn


def _attn_layers(cfg: ArchConfig):
    """(n_full_attn_layers, n_local_attn_layers, n_ssm_layers, n_mlstm)."""
    L = cfg.n_layers
    if cfg.family == "hybrid":
        n_shared = len([i for i in range(L) if cfg.shared_attn_every and i % cfg.shared_attn_every == 0])
        return n_shared, 0, L, 0
    if cfg.family == "ssm":
        n_s = len([i for i in range(L) if cfg.slstm_every and i % cfg.slstm_every == cfg.slstm_every - 1])
        return 0, 0, n_s, L - n_s
    if cfg.global_every:
        n_glob = L // cfg.global_every
        return n_glob, L - n_glob, 0, 0
    return L, 0, 0, 0


def _score_flops(cfg, B, S, kind):
    """Attention score+PV flops (fwd)."""
    hd = cfg.hd
    n_full, n_local, n_ssm, n_mlstm = _attn_layers(cfg)
    win = cfg.window or S
    if kind == "decode":
        per_full = 4 * B * S * cfg.n_heads * hd
        per_local = 4 * B * min(win, S) * cfg.n_heads * hd
        per_mlstm = 4 * B * cfg.n_heads * hd * hd  # state matmul
        ssm = n_ssm * 2 * B * (2 * cfg.d_model) * (cfg.ssm.d_state if cfg.ssm else hd)
        return n_full * per_full + n_local * per_local + n_mlstm * per_mlstm + ssm
    # train/prefill (causal => half)
    per_full = 2 * B * S * S * cfg.n_heads * hd
    per_local = 2 * B * S * min(win, S) * cfg.n_heads * hd
    per_mlstm = 2 * B * S * S * cfg.n_heads * hd  # quadratic parallel form
    ssm_flops = 0
    if cfg.ssm is not None:
        h = (cfg.ssm.expand * cfg.d_model) // cfg.ssm.head_dim
        p, n, ch = cfg.ssm.head_dim, cfg.ssm.d_state, cfg.ssm.chunk
        ssm_flops = n_ssm * (2 * B * S * ch * h * p + 4 * B * S * h * p * n)
    if cfg.family == "encdec":
        enc = cfg.n_encoder_layers * 2 * B * cfg.encoder_seq**2 * cfg.n_heads * hd * 2
        cross = cfg.n_layers * 4 * B * S * cfg.encoder_seq * cfg.n_heads * hd
        return n_full * per_full + enc + cross + ssm_flops
    return n_full * per_full + n_local * per_local + n_mlstm * per_mlstm + ssm_flops


def cell_cost(cfg: ArchConfig, shape, mesh_devices: int, microbatches: int = 8) -> Cost:
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = cfg.n_layers
    attn_proj, ffn = _layer_dims(cfg)
    P_mm_layer = attn_proj + ffn
    unembed = d * cfg.vocab
    P_total = cfg.n_params()
    P_active = cfg.active_params()

    if shape.kind == "train":
        T = B * S
        fwd = 2 * T * (L * P_mm_layer + unembed) + _score_flops(cfg, B, S, "train")
        flops = 4.0 * fwd  # fwd + bwd(2x) + remat re-fwd(1x)
        A = microbatches
        param_traffic = 3 * A * P_total * BP  # read per microbatch (fwd/bwd/refwd)
        grad_traffic = 3 * P_total * 4
        opt_traffic = 5 * P_total * 4
        act_traffic = 20 * T * d * L * BA
        hbm = param_traffic + grad_traffic + opt_traffic + act_traffic
        # FSDP all-gather 3x per microbatch + grad reduce-scatter per mb + TP
        coll = 3 * A * P_total * BP + A * P_total * BP + 6 * L * T * d * BA
        model_flops = 6.0 * P_active * T
    elif shape.kind == "prefill":
        T = B * S
        fwd = 2 * T * (L * P_mm_layer + unembed) + _score_flops(cfg, B, S, "prefill")
        flops = fwd
        kv_write = 2 * L * T * cfg.n_kv_heads * cfg.hd * BP
        hbm = P_total * BP + 8 * T * d * L * BA + kv_write + _score_flops(cfg, B, S, "prefill") / (2 * cfg.hd) * BA
        coll = P_total * BP + 2 * L * T * d * BA
        model_flops = 2.0 * P_active * T  # forward only
    else:  # decode
        T = B
        fwd = 2 * T * (L * P_mm_layer + unembed) + _score_flops(cfg, B, S, "decode")
        flops = fwd
        n_full, n_local, n_ssm, n_mlstm = _attn_layers(cfg)
        win = cfg.window or S
        kv_read = 2 * (n_full * S + n_local * min(win, S)) * cfg.n_kv_heads * cfg.hd * B * BP
        state_read = 0
        if cfg.ssm is not None:
            h = (cfg.ssm.expand * d) // cfg.ssm.head_dim
            state_read = 2 * n_ssm * B * h * cfg.ssm.head_dim * cfg.ssm.d_state * 4
        if cfg.family == "ssm":
            state_read = 2 * L * B * cfg.n_heads * (2 * d // cfg.n_heads) ** 2 * 4
        hbm = P_active * BP + kv_read + state_read + 4 * T * d * L * BA
        # H3 (measured): GSPMD keeps FSDP-sharded weights stationary at
        # decode and reduces the (tiny) activations instead — collective
        # volume is O(L*B*d) activations + MoE dispatch, NOT O(P).
        coll = 2 * L * T * d * BA
        if cfg.moe is not None:
            coll += 4 * T * cfg.moe.top_k * d * BA  # a2a dispatch+combine
        model_flops = 2.0 * P_active * T  # forward only
    return Cost(flops=flops, hbm_bytes=hbm, coll_bytes=coll, model_flops=model_flops)


def analyze(results_dir: str = "results/dryrun", mesh: str = "single"):
    rows = []
    chips = 256 if mesh == "single" else 512
    for arch in list_archs():
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, why = applicable(cfg, shape)
            rec_path = Path(results_dir) / f"{arch}__{sname}__{mesh}.json"
            rec = json.loads(rec_path.read_text()) if rec_path.exists() else {}
            if not ok:
                rows.append({"arch": arch, "shape": sname, "status": "SKIP", "why": why})
                continue
            mb = rec.get("microbatches", 8)
            c = cell_cost(cfg, shape, chips, microbatches=mb)
            t_comp = c.flops / (chips * PEAK_FLOPS)
            t_mem = c.hbm_bytes / (chips * HBM_BW)
            t_coll = c.coll_bytes / (chips * ICI_BW)
            dom = max(("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
                      key=lambda kv: kv[1])
            bound = t_comp + t_mem + t_coll  # no-overlap step-time bound
            rows.append({
                "arch": arch, "shape": sname, "status": rec.get("status", "?"),
                "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
                "dominant": dom[0],
                "roofline_frac": t_comp / bound if bound > 0 else 0.0,
                "model_flops": c.model_flops,
                "flops_total": c.flops,
                "useful_ratio": c.model_flops / c.flops if c.flops else 0.0,
                "hlo_flops_per_dev_raw": rec.get("flops"),
                "hlo_collective_bytes": rec.get("collective_bytes"),
                "temp_bytes_f32_inflated": rec.get("temp_size_in_bytes"),
                "arg_bytes": rec.get("argument_size_in_bytes"),
                "compile_s": rec.get("compile_s"),
            })
    return rows


def main():
    rows = analyze()
    print("arch,shape,status,dominant,t_compute_s,t_memory_s,t_collective_s,"
          "roofline_frac,useful_ratio")
    for r in rows:
        if r["status"] == "SKIP":
            print(f'{r["arch"]},{r["shape"]},SKIP,,,,,,')
            continue
        print(
            f'{r["arch"]},{r["shape"]},{r["status"]},{r["dominant"]},'
            f'{r["t_compute_s"]:.4g},{r["t_memory_s"]:.4g},'
            f'{r["t_collective_s"]:.4g},{r["roofline_frac"]:.3f},'
            f'{r["useful_ratio"]:.3f}'
        )


if __name__ == "__main__":
    main()
