"""Beyond-paper benchmarks: multi-ball (paper Sec 4.3, sketched-not-built),
kernelized RBF StreamSVM (Sec 4.2), and distributed stream sharding.

    PYTHONPATH=src python -m benchmarks.beyond
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import fit, fit_kernelized, rbf_kernel
from repro.core.kernelized import decision_function as kdec
from repro.core.multiball import decision_function as mb_dec, fit_multiball
from repro.data import load_dataset, preprocess_for


def circles(n=4000, seed=0):
    rng = np.random.default_rng(seed)
    r_in = rng.uniform(0.0, 1.0, n // 2)
    r_out = rng.uniform(1.5, 2.5, n // 2)
    th = rng.uniform(0, 2 * np.pi, n)
    r = np.concatenate([r_in, r_out])
    X = np.stack([r * np.cos(th), r * np.sin(th)], 1).astype(np.float32)
    y = np.concatenate([np.ones(n // 2), -np.ones(n // 2)]).astype(np.float32)
    idx = rng.permutation(n)
    return X[idx][: 3 * n // 4], y[idx][: 3 * n // 4], X[idx][3 * n // 4 :], y[idx][3 * n // 4 :]


def run():
    rows = []
    # multi-ball vs Algorithm 1 (single pass each)
    Xtr, ytr, Xte, yte = load_dataset("mnist89")
    Xtr, Xte = preprocess_for("mnist89", Xtr, Xte)
    Xj, yj = jnp.asarray(Xtr), jnp.asarray(ytr)
    acc = lambda s: float(np.mean(np.sign(np.asarray(s)) == yte)) * 100
    b1 = fit(Xj, yj, 10.0)
    rows.append(("multiball_L1_algo1", acc(Xte @ np.asarray(b1.w)), "acc% mnist89"))
    for L in (2, 4, 8):
        mb = fit_multiball(Xj, yj, 10.0, n_balls=L)
        rows.append((f"multiball_L{L}", acc(mb_dec(mb, jnp.asarray(Xte))), "acc% mnist89"))

    # kernelized RBF one-pass on a nonlinearly separable stream
    Xtr, ytr, Xte2, yte2 = circles()
    acc2 = lambda s: float(np.mean(np.sign(np.asarray(s)) == yte2)) * 100
    b = fit(jnp.asarray(Xtr), jnp.asarray(ytr), 10.0)
    rows.append(("circles_linear_algo1", acc2(Xte2 @ np.asarray(b.w)), "acc%"))
    kb = fit_kernelized(jnp.asarray(Xtr), jnp.asarray(ytr), 10.0, kernel_fn=rbf_kernel(0.5))
    sc = kdec(kb, jnp.asarray(Xtr), jnp.asarray(Xte2), kernel_fn=rbf_kernel(0.5))
    rows.append(("circles_rbf_onepass", acc2(sc), f"acc% (m={int(kb.m)})"))
    return rows


def main():
    for name, val, unit in run():
        print(f"{name},{val:.2f},{unit}")


if __name__ == "__main__":
    main()
