"""Render EXPERIMENTS.md tables (dry-run matrix + roofline) from artifacts.

    PYTHONPATH=src python -m benchmarks.report [--dir results/dryrun]

Prints markdown; EXPERIMENTS.md embeds the output.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import SHAPES, get_config, list_archs
from benchmarks.roofline import analyze


def dryrun_table(results_dir: str, mesh: str):
    print(f"\n### Dry-run matrix — mesh `{mesh}` "
          f"({256 if mesh == 'single' else 512} chips)\n")
    print("| arch | shape | status | compile (s) | args GB/dev | temp GB/dev"
          " (f32-inflated) | HLO collective bytes (per-iter lower bound) |")
    print("|---|---|---|---|---|---|---|")
    for arch in list_archs():
        for sname in SHAPES:
            p = Path(results_dir) / f"{arch}__{sname}__{mesh}.json"
            if not p.exists():
                print(f"| {arch} | {sname} | MISSING | | | | |")
                continue
            r = json.loads(p.read_text())
            if r["status"] == "SKIP":
                print(f"| {arch} | {sname} | SKIP | | | | {r['reason'][:50]} |")
                continue
            cb = r.get("collective_bytes", {})
            cbs = " ".join(
                f"{k.split('-')[-1][:3].upper()}={v/1e6:.0f}M"
                for k, v in cb.items() if v
            ) or "–"
            print(
                f"| {arch} | {sname} | {r['status']} | {r.get('compile_s','')} "
                f"| {r.get('argument_size_in_bytes',0)/1e9:.2f} "
                f"| {r.get('temp_size_in_bytes',0)/1e9:.2f} | {cbs} |"
            )


def roofline_table(results_dir: str):
    print("\n### Roofline (single-pod, 256 chips; terms in seconds/step)\n")
    print("| arch | shape | dominant | t_compute | t_memory | t_collective |"
          " frac (comp/sum) | 6ND/total-FLOPs |")
    print("|---|---|---|---|---|---|---|---|")
    for r in analyze(results_dir):
        if r["status"] == "SKIP":
            print(f"| {r['arch']} | {r['shape']} | SKIP | | | | | |")
            continue
        print(
            f"| {r['arch']} | {r['shape']} | **{r['dominant']}** "
            f"| {r['t_compute_s']:.4g} | {r['t_memory_s']:.4g} "
            f"| {r['t_collective_s']:.4g} | {r['roofline_frac']:.3f} "
            f"| {r['useful_ratio']:.3f} |"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    dryrun_table(args.dir, "single")
    dryrun_table(args.dir, "multi")
    roofline_table(args.dir)


if __name__ == "__main__":
    main()
