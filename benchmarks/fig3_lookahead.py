"""Paper Fig 3: accuracy mean/std vs lookahead L over random stream orders.

Validates both of the paper's observations: accuracy rises with L, and the
std across stream orderings shrinks (robustness to bad orders). The paper
used 100 permutations of MNIST 8vs9; runs are configurable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fit, fit_lookahead
from repro.data import load_dataset, preprocess_for
from repro.data.stream import permuted


def run(dataset="mnist89", C=10.0, Ls=(1, 2, 5, 10, 20, 50), runs=20, seed=0):
    Xtr, ytr, Xte, yte = load_dataset(dataset, seed=seed)
    Xtr, Xte = preprocess_for(dataset, Xtr, Xte)
    rows = []
    for L in Ls:
        accs = []
        for r in range(runs):
            Xp, yp = permuted(Xtr, ytr, seed=seed * 7777 + r)
            Xpj, ypj = jnp.asarray(Xp), jnp.asarray(yp)
            if L <= 1:
                ball = fit(Xpj, ypj, C)
            else:
                ball = fit_lookahead(Xpj, ypj, C, int(L))
            accs.append(
                float(np.mean(np.sign(Xte @ np.asarray(ball.w)) == yte)) * 100
            )
        rows.append(
            {"L": L, "mean": float(np.mean(accs)), "std": float(np.std(accs)),
             "n_sv": int(ball.m)}
        )
    return rows


def main():
    print("L,acc_mean,acc_std,n_sv")
    for r in run():
        print(f'{r["L"]},{r["mean"]:.2f},{r["std"]:.3f},{r["n_sv"]}')


if __name__ == "__main__":
    main()
