"""Benchmark aggregator: one function per paper table/figure + system benches.

``PYTHONPATH=src python -m benchmarks.run [--fast]``
Prints ``name,value,derived`` CSV sections.
"""
from __future__ import annotations

import argparse
import time


def _section(title):
    print(f"\n# === {title} ===", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduced runs for CI")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()
    runs = 3 if args.fast else 5

    t0 = time.time()

    _section("Table 1: single-pass accuracies (ours vs paper)")
    from benchmarks import table1

    rows = table1.run(runs=runs, lasvm_cap=4000 if args.fast else 8000)
    print("dataset,C,batch,perceptron,pegasos_k1,pegasos_k20,lasvm,algo1,algo2,"
          "paper_batch,paper_algo1,paper_algo2")
    for r in rows:
        p = r["paper"]
        print(
            f'{r["dataset"]},{r["C"]},{r["batch"]:.2f},{r["perceptron"]:.2f},'
            f'{r["pegasos1"]:.2f},{r["pegasos20"]:.2f},{r["lasvm"]:.2f},'
            f'{r["algo1"]:.2f},{r["algo2"]:.2f},{p[0]},{p[5]},{p[6]}'
        )

    _section("Fig 2: CVM passes vs one StreamSVM pass")
    from benchmarks import fig2_cvm

    out = fig2_cvm.run(max_passes=16 if args.fast else 32)
    for i, a in enumerate(out["cvm_curve"]):
        print(f"cvm_pass_{i + 1},{a:.2f},acc%")
    print(f"streamsvm_algo2_single_pass,{out['streamsvm_algo2_1pass']:.2f},acc%")
    print(f"cvm_passes_to_match,{out['cvm_passes_to_match_algo2']},passes")

    _section("Fig 3: lookahead vs accuracy/std over stream orders")
    from benchmarks import fig3_lookahead

    for r in fig3_lookahead.run(runs=8 if args.fast else 20):
        print(f'lookahead_L{r["L"]},{r["mean"]:.2f},acc% (std {r["std"]:.3f})')

    _section("Streaming throughput / constant-memory claims")
    from benchmarks import streaming_throughput

    for name, val, unit in streaming_throughput.run():
        print(f"{name},{val:.3f},{unit}")

    _section("Beyond-paper: multi-ball (Sec 4.3) + RBF kernelized (Sec 4.2)")
    from benchmarks import beyond

    for name, val, unit in beyond.run():
        print(f"{name},{val:.2f},{unit}")

    if not args.skip_roofline:
        _section("Roofline (single-pod, from dry-run artifacts)")
        try:
            from benchmarks import roofline

            for r in roofline.analyze():
                if r["status"] == "SKIP":
                    print(f'{r["arch"]}__{r["shape"]},SKIP,{r["why"]}')
                else:
                    print(
                        f'{r["arch"]}__{r["shape"]},{r["dominant"]},'
                        f'comp={r["t_compute_s"]:.4g}s mem={r["t_memory_s"]:.4g}s '
                        f'coll={r["t_collective_s"]:.4g}s frac={r["roofline_frac"]:.3f}'
                    )
        except Exception as e:  # dry-run artifacts may not exist yet
            print(f"roofline_skipped,0,{type(e).__name__}: {e}")

    print(f"\n# total benchmark time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
