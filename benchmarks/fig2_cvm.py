"""Paper Fig 2: CVM accuracy vs number of data passes, against one
StreamSVM pass (MNIST 8vs9 in the paper; surrogate here).

CVM makes one full pass per core vector; the question is how many passes it
needs to match a single StreamSVM pass.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.baselines import fit_cvm
from repro.core import fit, fit_lookahead
from repro.data import load_dataset, preprocess_for


def run(dataset: str = "mnist89", C: float = 10.0, max_passes: int = 32, seed=0):
    Xtr, ytr, Xte, yte = load_dataset(dataset, seed=seed)
    Xtr, Xte = preprocess_for(dataset, Xtr, Xte)
    acc = lambda w: float(np.mean(np.sign(Xte @ np.asarray(w)) == yte)) * 100

    b1 = fit(jnp.asarray(Xtr), jnp.asarray(ytr), C)
    b2 = fit_lookahead(jnp.asarray(Xtr), jnp.asarray(ytr), C, 10)
    stream1, stream2 = acc(b1.w), acc(b2.w)

    res = fit_cvm(Xtr, ytr, C=C, eps=1e-4, max_passes=max_passes, solver_iters=1000)
    cvm_curve = [acc(w) for w in res["w_per_pass"]]
    passes_to_beat = next(
        (i + 1 for i, a in enumerate(cvm_curve) if a >= stream2), None
    )
    return {
        "dataset": dataset,
        "streamsvm_algo1_1pass": stream1,
        "streamsvm_algo2_1pass": stream2,
        "cvm_curve": cvm_curve,
        "cvm_passes_to_match_algo2": passes_to_beat,
    }


def main():
    out = run()
    print("pass,cvm_acc,streamsvm_algo2_single_pass")
    for i, a in enumerate(out["cvm_curve"]):
        print(f"{i + 1},{a:.2f},{out['streamsvm_algo2_1pass']:.2f}")
    print(f"# passes for CVM to match one StreamSVM pass: "
          f"{out['cvm_passes_to_match_algo2']}")


if __name__ == "__main__":
    main()
