"""Serving throughput harness: sweeps the predict engine, emits BENCH JSON.

Sweeps (Q, D, B, q_block, b_tile, stream_dtype, epilogue, bank_resident)
over the fused bank-inference kernel (kernels.ops.predict_bank) and over the
end-to-end BankServer microbatching path, measures seconds/batch, queries/s
and model-scores/s (Q * B margins evaluated per batch), derives achieved
GB/s from the engine's modeled HBM byte traffic, and compares against the
same bandwidth roofline as the training harness (default TPU v5e 819 GB/s
per chip — override with ``--hbm-peak-gbps`` or ``REPRO_HBM_PEAK_GBPS`` for
TPU-measured runs; on the CPU interpret backend the roofline fraction is a
trend number only). ``bank_resident="hbm"`` rows serve the bank out of
ANY/HBM space through the kernel's 2-slot async-copy ring instead of the
BlockSpec pipeline — same modeled bytes (the bank is re-read once per
resident query tile either way), so the wall-time ratio against the
equal-shape vmem baseline (``dma_overlap_efficiency`` =
seconds(vmem)/seconds(hbm), which at equal modeled bytes IS the
achieved-GB/s ratio) isolates how well the manual prefetch hides the bank
fetch — 1.0 means it matches the BlockSpec pipeline. Rows record the per-config VMEM
working-set estimate (``vmem_working_set_bytes``).

The modeled bytes encode the serving engine's movement claim, the mirror
image of training's: the QUERY stream is the big term and is read ONCE per
batch (data-major grid — ``query_passes`` stays 1.0 no matter how many bank
tiles revisit each resident tile, and bf16 query tiles halve the term),
while the tiny (B, D) bank is re-read once per resident query tile — the
cheap term, because one-pass training left the model constant-storage.

``path="live"`` rows benchmark the continuous train->serve loop
(repro.live.LiveBank) instead of a predict kernel: steady-state ingest rate
(rows/s through train+fold+swap+checkpoint), hot-swap latency (seconds for
``BankServer.swap_bank`` to publish an already-folded bank — the serving
blackout window), and ``recovery_seconds`` — wall time from relaunching a
killed trainer (crash injected mid-stream, after the last checkpoint) to
the first FRESH bank swapped into the surviving server. Each live row
records its ``bank_kind``: ``"linear"`` Ball loops and ``"kernel"``
core-set loops (train through fit_kernel_bank, Sec-4.3 kernel merges on
retire/fold, RBF serving) share the measurement surface, so their ingest /
blackout / recovery numbers are directly comparable.

Writes ``BENCH_serving.json`` at the repo root (validated by CI's
bench-smoke next to BENCH_engine.json) and prints one ``BENCH`` line per
config. ``--smoke`` runs a seconds-scale sweep in interpret mode for CI and
always includes an ``ovr``-epilogue row, a linear ``live`` row, and a
kernelized ``live`` row (CI asserts all three).

    PYTHONPATH=src python benchmarks/serving_throughput.py [--smoke]
        [--out BENCH_serving.json] [--reps 3]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import predict_bank, predict_kernel_bank
from repro.kernels.ops import (
    bank_tiling,
    gram_tiling,
    ovr_group_tiling,
    predict_vmem_bytes,
)
from repro.serve import BankServer

SCHEMA = "streamsvm-bench-serving/v5"
DEFAULT_HBM_PEAK_GBPS = 819.0  # TPU v5e, per chip — same as BENCH_engine
_DTYPE_BYTES = {"f32": 4, "bf16": 2}


def hbm_peak_gbps(override=None) -> float:
    """Roofline peak: --hbm-peak-gbps flag > REPRO_HBM_PEAK_GBPS env >
    the TPU v5e default — so TPU-measured runs never need a source edit."""
    if override is not None:
        return float(override)
    env = os.environ.get("REPRO_HBM_PEAK_GBPS")
    return float(env) if env else DEFAULT_HBM_PEAK_GBPS


# Keys every result row must carry — CI validates the emitted JSON against
# this (see .github/workflows/ci.yml bench-smoke).
RESULT_KEYS = (
    "name", "Q", "D", "B", "q_block", "b_tile", "n_bank_tiles", "epilogue",
    "n_classes", "k", "stream_dtype", "path", "bank_resident", "kernel",
    "coreset_size", "vmem_working_set_bytes", "seconds_per_batch",
    "queries_per_s", "model_scores_per_s", "bytes", "query_passes",
    "naive_query_bytes", "achieved_gbps", "hbm_peak_gbps",
    "roofline_seconds", "roofline_frac", "dma_overlap_efficiency",
)

# Keys for path="live" rows — the train->serve loop has its own surface
# (ingest rate + swap latency + crash-recovery time, not kernel bytes).
# bank_kind distinguishes linear Ball loops from kernelized core-set loops
# (schema v4). Schema v5 adds the ELASTIC fields: ``n_stream_shards`` (the
# logical shard count each chunk trains across), ``rows_per_s_per_shard``
# (per-shard ingest rate — the weak-scaling denominator), and
# ``remesh_recovery_seconds`` — wall time from relaunching a killed sharded
# trainer on a SMALLER mesh (devices lost for good) to the first fresh bank
# swap; null for unsharded rows. CI's chaos-smoke asserts a sharded live
# row carries all three.
LIVE_RESULT_KEYS = (
    "name", "path", "bank_kind", "B", "D", "chunk_rows", "n_chunks",
    "n_sub_banks", "rotate_every", "swap_every", "n_stream_shards",
    "seconds_per_chunk", "rows_per_s", "rows_per_s_per_shard", "swaps",
    "checkpoints", "swap_latency_s", "recovery_seconds",
    "remesh_recovery_seconds",
)


def out_bytes(Q, B, epilogue, n_classes, k):
    """HBM bytes of the epilogue output per batch (f32 + int32 pairs)."""
    if epilogue == "scores":
        return Q * B * 4
    if epilogue == "ovr":
        return Q * (B // n_classes) * 8  # class ids + margins
    return Q * k * 8  # topk values + ids


def modeled_bytes(Q, D, B, q_block, epilogue, n_classes, k, stream_dtype,
                  kernel=None, coreset_size=None):
    """HBM bytes per batch under the predict engine's movement model.

    queries: each (q_block, D) tile DMA'd once (data-major grid) — Q*D at
    the stream dtype, NOT multiplied by the B/b_tile bank tiles revisiting
    it. bank: (B, D) f32 re-read once per resident query tile — the paper's
    constant-storage model makes this the small term. out: the epilogue's
    emitted rows.
    """
    sz = _DTYPE_BYTES[stream_dtype]
    n_q_blocks = -(-Q // q_block)
    if kernel is not None:
        # Kernelized bank: the (B*S, D) core-set operand replaces the (B, D)
        # weight rows in the Gram launch (re-fetched once per resident query
        # tile, like the linear bank), the (Q, B*S) kernel block round-trips
        # once between the Gram launch and the coefficient contraction, and
        # the (B, S) coefficients are read once per query tile.
        return {
            "queries": Q * D * sz,
            "bank": n_q_blocks * B * coreset_size * D * 4,
            "kernel_block": 2 * Q * B * coreset_size * 4,
            "coef": n_q_blocks * B * coreset_size * 4,
            "out": out_bytes(Q, B, epilogue, n_classes, k),
        }
    return {
        "queries": Q * D * sz,
        "bank": n_q_blocks * B * D * 4,
        "out": out_bytes(Q, B, epilogue, n_classes, k),
    }


def bench_one(cfg, reps, interpret, peak_gbps):
    Q, D, B = cfg["Q"], cfg["D"], cfg["B"]
    epilogue = cfg.get("epilogue", "scores")
    n_classes = cfg.get("n_classes")
    k = cfg.get("k")
    path = cfg.get("path", "ops")
    bank_resident = cfg.get("bank_resident", "vmem")
    kernel = cfg.get("kernel")
    coreset_size = cfg.get("coreset_size")
    sdt = cfg["stream_dtype"] if cfg["stream_dtype"] != "f32" else None
    rng = np.random.default_rng(0)
    X = rng.normal(size=(Q, D)).astype(np.float32)
    W = rng.normal(size=(B, D)).astype(np.float32)
    if kernel is not None:
        # Kernelized bank: a synthetic core-set buffer of the benchmarked
        # shape (serving cost depends only on (B, S, D), not the fit).
        from repro.core import KernelBank

        S = coreset_size
        points = jnp.asarray(rng.normal(size=(B, S, D)).astype(np.float32))
        coef = jnp.asarray(
            rng.normal(size=(B, S)).astype(np.float32) / np.sqrt(S)
        )
        kkw = dict(
            kernel=kernel, gamma=0.5, epilogue=epilogue, n_classes=n_classes,
            k=k, q_block=cfg["q_block"], stream_dtype=sdt,
            interpret=interpret,
        )
        if path == "server":
            kb = KernelBank(
                idx=jnp.zeros((B, S), jnp.int32), coef=coef, points=points,
                q=jnp.ones((B,)), r=jnp.ones((B,)), xi2=jnp.ones((B,)),
                m=jnp.full((B,), S, jnp.int32),
            )
            sizes = _ragged_sizes(Q)
            skw = dict(kkw)
            skw.pop("kernel"), skw.pop("gamma")

            def run():
                server = BankServer(kb, kernel=kernel, gamma=0.5, **skw)
                reqs = [server.submit(X[lo:hi]) for lo, hi in sizes]
                server.run()
                return reqs[-1].result
        else:
            run = lambda: jax.block_until_ready(
                predict_kernel_bank(jnp.asarray(X), points, coef, **kkw)
            )
    else:
        kw = dict(
            epilogue=epilogue,
            n_classes=n_classes,
            k=k,
            q_block=cfg["q_block"],
            b_tile=cfg["b_tile"],
            stream_dtype=sdt,
            bank_resident=bank_resident,
            interpret=interpret,
        )
        if path == "server":
            # end-to-end: FIFO packing of ragged requests + the kernel — a
            # new server per rep so admission/packing overhead is inside the
            # clock
            sizes = _ragged_sizes(Q)

            def run():
                server = BankServer(W, **kw)
                reqs = [server.submit(X[lo:hi]) for lo, hi in sizes]
                server.run()
                return reqs[-1].result
        else:
            run = lambda: jax.block_until_ready(
                predict_bank(jnp.asarray(X), jnp.asarray(W), **kw)
            )
    run()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        run()
    sec = (time.perf_counter() - t0) / reps

    if kernel is not None:
        b_tile_eff, n_btiles = None, 1
        bank_resident = "vmem"
        # Working-set estimate: the Gram launch's operand tiles + f32
        # accumulator, plus the coefficient contraction's inputs.
        bm_, bn_ = gram_tiling(Q, B * coreset_size, cfg["q_block"], 256)
        bk = 512
        working_set = (
            (bm_ * bk + bn_ * bk + bm_ * bn_) * 4
            + B * coreset_size * 4
        )
    elif epilogue == "ovr":
        nc_pad, g_tile, gp = ovr_group_tiling(B, n_classes, cfg["b_tile"])
        b_tile_eff, n_btiles = g_tile * nc_pad, gp // g_tile
    else:
        b_tile_eff, n_btiles = bank_tiling(B, cfg["b_tile"])
    by = modeled_bytes(
        Q, D, B, cfg["q_block"], epilogue, n_classes, k, cfg["stream_dtype"],
        kernel=kernel, coreset_size=coreset_size,
    )
    total = sum(by.values())
    roofline_sec = total / (peak_gbps * 1e9)
    if kernel is None:
        working_set = sum(
            predict_vmem_bytes(
                B, D, q_block=cfg["q_block"], b_tile=cfg["b_tile"],
                stream_dtype=(
                    cfg["stream_dtype"] if cfg["stream_dtype"] != "f32"
                    else None
                ),
                epilogue=epilogue, n_classes=n_classes, k=k,
                bank_resident=bank_resident,
            ).values()
        )
    return {
        "name": cfg["name"],
        "Q": Q,
        "D": D,
        "B": B,
        "q_block": cfg["q_block"],
        "b_tile": b_tile_eff,
        "n_bank_tiles": n_btiles,
        "epilogue": epilogue,
        "n_classes": n_classes,
        "k": k,
        "stream_dtype": cfg["stream_dtype"],
        "path": path,
        "bank_resident": bank_resident,
        "kernel": kernel,
        "coreset_size": coreset_size,
        "vmem_working_set_bytes": working_set,
        "seconds_per_batch": sec,
        "queries_per_s": Q / sec,
        "model_scores_per_s": Q * B / sec,  # margins evaluated / s
        "bytes": {**by, "total": total},
        "query_passes": 1.0,  # data-major grid: NOT B/b_tile
        "naive_query_bytes": n_btiles * by["queries"],  # bank-major cost
        "achieved_gbps": total / sec / 1e9,
        "hbm_peak_gbps": peak_gbps,
        "roofline_seconds": roofline_sec,
        "roofline_frac": roofline_sec / sec,
        # filled in post-sweep for hbm rows with a named vmem baseline
        "dma_overlap_efficiency": None,
    }


class _TimingServer:
    """Hot-swap target that timestamps every published bank (both kinds)."""

    def __init__(self):
        self.times = []

    def swap_bank(self, bank):
        jax.block_until_ready(
            bank.points if hasattr(bank, "points") else bank.w
        )
        self.times.append(time.perf_counter())


def bench_live(cfg, reps, interpret):
    """The train->serve loop end to end: steady-state ingest, hot-swap
    latency, and recovery-to-fresh-bank after an injected mid-stream kill.
    ``bank_kind="kernel"`` runs the same loop through fit_kernel_bank +
    the Sec-4.3 kernel merge, so the linear/kernel rows are comparable."""
    import tempfile

    from repro.live import ArraySource, LiveBank
    from repro.runtime import InjectedFailure

    B, D = cfg["B"], cfg["D"]
    bank_kind = cfg.get("bank_kind", "linear")
    n_shards = int(cfg.get("n_stream_shards", 1))
    mesh = None
    if n_shards > 1:
        if len(jax.devices()) < n_shards:
            print(
                f'SKIP {cfg["name"]}: needs {n_shards} devices for the '
                f"sharded live row, have {len(jax.devices())} (run under "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{n_shards} with --filter sharded --append)"
            )
            return None
        mesh = jax.make_mesh((n_shards,), ("data",))
    chunk, n_chunks = cfg["chunk_rows"], cfg["n_chunks"]
    n_rows = chunk * n_chunks
    rng = np.random.default_rng(0)
    X = rng.normal(size=(n_rows, D)).astype(np.float32)
    X /= np.linalg.norm(X, axis=1, keepdims=True)
    y = np.sign(rng.normal(size=n_rows) + X[:, 0]).astype(np.float32)
    Y = np.tile(y, (B, 1))
    cs = jnp.asarray(np.linspace(1.0, 8.0, B, dtype=np.float32))
    kernel_kw = (
        dict(
            kernel=cfg.get("kernel", "rbf"), gamma=cfg.get("gamma", 0.5),
            coreset_size=cfg.get("coreset_size", 32),
        )
        if bank_kind == "kernel"
        else {}
    )

    def make(td, srv, failpoints=None, run_mesh=None):
        return LiveBank(
            ArraySource(X, Y, chunk), cs, ckpt_dir=os.path.join(td, "ck"),
            bank_kind=bank_kind, n_sub_banks=cfg["n_sub_banks"],
            rotate_every=cfg["rotate_every"], swap_every=cfg["swap_every"],
            mesh=run_mesh if run_mesh is not None else mesh,
            n_stream_shards=n_shards,
            server=srv, failpoints=failpoints, sleep=lambda s: None,
            interpret=interpret, **kernel_kw,
        )

    with tempfile.TemporaryDirectory() as td:
        make(td, _TimingServer()).run()  # compile warm-up
    with tempfile.TemporaryDirectory() as td:
        live = make(td, _TimingServer())
        t0 = time.perf_counter()
        stats = live.run()
        total = time.perf_counter() - t0
        bank = live.serving_bank()

    # Hot-swap latency: publishing an already-folded bank into a warm
    # server (same shape — never recompiles). This is the serving blackout.
    if bank_kind == "kernel":
        server = BankServer(
            bank, kernel=kernel_kw["kernel"], gamma=kernel_kw["gamma"],
            interpret=interpret,
        )
    else:
        server = BankServer(bank, interpret=interpret)
    server.swap_bank(bank)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        server.swap_bank(bank)
    swap_latency = (time.perf_counter() - t0) / reps

    # Recovery: kill the trainer after it trains a chunk PAST its last
    # checkpoint, relaunch, and clock the window until the surviving server
    # receives its first fresh bank (replay + fold + swap).
    crash_at = (n_chunks // 2) + 1
    with tempfile.TemporaryDirectory() as td:
        srv = _TimingServer()
        live = make(td, srv, failpoints=[("post_train", crash_at)])
        try:
            live.run()
        except InjectedFailure:
            pass
        swaps_before = len(srv.times)
        t0 = time.perf_counter()
        live.run()
        recovery = srv.times[swaps_before] - t0

    # Remesh recovery (sharded rows only): the kill takes its devices with
    # it — the relaunch restores the checkpoint onto a HALF-SIZE mesh
    # (same logical shards, re-placed slots, degraded per-range training)
    # and the clock runs until the surviving server gets a fresh bank.
    remesh_recovery = None
    if n_shards > 1:
        small = jax.make_mesh((max(1, n_shards // 2),), ("data",))
        fps = {("post_train", crash_at)}  # shared: the kill fires ONCE
        with tempfile.TemporaryDirectory() as td:
            srv = _TimingServer()
            live = make(td, srv, failpoints=fps)
            try:
                live.run()
            except InjectedFailure:
                pass
            swaps_before = len(srv.times)
            t0 = time.perf_counter()
            relaunched = make(td, srv, failpoints=fps, run_mesh=small)
            relaunched.run()
            remesh_recovery = srv.times[swaps_before] - t0
            assert relaunched.stats.remeshes >= 1

    return {
        "name": cfg["name"],
        "path": "live",
        "bank_kind": bank_kind,
        "B": B,
        "D": D,
        "chunk_rows": chunk,
        "n_chunks": n_chunks,
        "n_sub_banks": cfg["n_sub_banks"],
        "rotate_every": cfg["rotate_every"],
        "swap_every": cfg["swap_every"],
        "n_stream_shards": n_shards,
        "seconds_per_chunk": total / n_chunks,
        "rows_per_s": n_rows / total,
        "rows_per_s_per_shard": n_rows / total / n_shards,
        "swaps": stats.swaps,
        "checkpoints": stats.checkpoints,
        "swap_latency_s": swap_latency,
        "recovery_seconds": recovery,
        "remesh_recovery_seconds": remesh_recovery,
    }


def _ragged_sizes(Q):
    """Deterministic ragged request spans covering Q rows (server path)."""
    spans, lo, step = [], 0, 0
    while lo < Q:
        n = [7, 33, 128, 15, 64][step % 5]
        spans.append((lo, min(lo + n, Q)))
        lo += n
        step += 1
    return spans


def sweep(smoke: bool):
    if smoke:
        base = dict(Q=512, D=64, q_block=128)
        return [
            dict(name="smoke_scores_single_tile", **base, B=48, b_tile=None,
                 stream_dtype="f32"),
            dict(name="smoke_scores_tiled", **base, B=48, b_tile=8,
                 stream_dtype="f32"),
            dict(name="smoke_bf16", **base, B=48, b_tile=8,
                 stream_dtype="bf16"),
            # the acceptance row: fused per-C-grid-group argmax epilogue
            dict(name="smoke_ovr", **base, B=48, b_tile=16, stream_dtype="f32",
                 epilogue="ovr", n_classes=16),
            dict(name="smoke_topk", **base, B=48, b_tile=8, stream_dtype="f32",
                 epilogue="topk", k=4),
            # HBM-resident bank served through the async-copy ring (CI
            # asserts this row + its fields)
            dict(name="smoke_hbm", **base, B=48, b_tile=8,
                 stream_dtype="f32", bank_resident="hbm",
                 overlap_baseline="smoke_scores_tiled"),
            # end-to-end microbatching server (ragged FIFO packing included)
            dict(name="smoke_server_ovr", **base, B=48, b_tile=16,
                 stream_dtype="f32", epilogue="ovr", n_classes=16,
                 path="server"),
            # kernelized bank served through the fused Gram epilogue (CI
            # asserts this row + its fields)
            dict(name="smoke_kernel_rbf", **base, B=48, b_tile=None,
                 stream_dtype="f32", kernel="rbf", coreset_size=16),
            dict(name="smoke_server_kernel_rbf", **base, B=48, b_tile=None,
                 stream_dtype="f32", kernel="rbf", coreset_size=16,
                 path="server"),
            # continuous train->serve loop with an injected kill (CI asserts
            # this row + its swap-latency/recovery fields)
            dict(name="smoke_live", path="live", B=16, D=32, chunk_rows=128,
                 n_chunks=8, n_sub_banks=2, rotate_every=3, swap_every=2),
            # the kernelized live loop: same measurement surface, core-set
            # train/merge/fold + RBF serving (CI asserts this row too)
            dict(name="smoke_live_kernel", path="live", bank_kind="kernel",
                 B=8, D=16, chunk_rows=64, n_chunks=6, n_sub_banks=2,
                 rotate_every=3, swap_every=2, coreset_size=16),
            # the ELASTIC live loop: 8 logical shards on an 8-device mesh,
            # measured only in the forced-device second pass
            # (--filter sharded --append); CI's chaos-smoke asserts this
            # row's per-shard rate and remesh-recovery fields
            dict(name="smoke_live_sharded", path="live", B=16, D=32,
                 chunk_rows=128, n_chunks=8, n_sub_banks=2, rotate_every=3,
                 swap_every=2, n_stream_shards=8),
        ]
    base = dict(D=128, q_block=256)
    return [
        # query-stream scaling at the quickstart bank shape (600 models)
        dict(name="serve_q4096_b600", Q=4096, **base, B=600, b_tile=64,
             stream_dtype="f32"),
        dict(name="serve_q16384_b600", Q=16384, **base, B=600, b_tile=64,
             stream_dtype="f32"),
        # dtype policy: same shape, half the query bytes
        dict(name="serve_q16384_b600_bf16", Q=16384, **base, B=600, b_tile=64,
             stream_dtype="bf16"),
        # bank scaling: one query pass for 1x..8x the bank
        dict(name="serve_q4096_b64", Q=4096, **base, B=64, b_tile=64,
             stream_dtype="f32"),
        dict(name="serve_q4096_b512", Q=4096, **base, B=512, b_tile=64,
             stream_dtype="f32"),
        # fused epilogues at the quickstart layout (200 classes x 3 C points)
        dict(name="serve_ovr_200c_x3", Q=4096, **base, B=600, b_tile=200,
             stream_dtype="f32", epilogue="ovr", n_classes=200),
        dict(name="serve_topk8_b600", Q=4096, **base, B=600, b_tile=64,
             stream_dtype="f32", epilogue="topk", k=8),
        # HBM-resident bank: equal-shape pair isolates the manual ring's
        # prefetch overlap vs the BlockSpec pipeline
        dict(name="serve_q4096_b512_hbm", Q=4096, **base, B=512, b_tile=64,
             stream_dtype="f32", bank_resident="hbm",
             overlap_baseline="serve_q4096_b512"),
        # a bank beyond the default 16 MiB VMEM budget, served from HBM
        dict(name="serve_b1536_d4096_hbm_beyond_vmem", Q=512, D=4096,
             q_block=256, B=1536, b_tile=64, stream_dtype="f32",
             bank_resident="hbm"),
        # end-to-end server (packing overhead included)
        dict(name="serve_server_ovr_200c_x3", Q=4096, **base, B=600,
             b_tile=200, stream_dtype="f32", epilogue="ovr", n_classes=200,
             path="server"),
        # kernelized core-set bank through the fused Gram epilogues
        dict(name="serve_kernel_rbf_b64_s64", Q=4096, **base, B=64,
             b_tile=None, stream_dtype="f32", kernel="rbf", coreset_size=64),
        dict(name="serve_kernel_linear_b64_s64", Q=4096, **base, B=64,
             b_tile=None, stream_dtype="f32", kernel="linear",
             coreset_size=64),
        # coreset-size sweep: S is the serve-side state/latency knob the
        # training evictions trade accuracy against — (Q, B*S) kernel block
        # and (B, S, D) gather scale linearly in S
        dict(name="serve_kernel_rbf_b64_s16", Q=4096, **base, B=64,
             b_tile=None, stream_dtype="f32", kernel="rbf", coreset_size=16),
        dict(name="serve_kernel_rbf_b64_s128", Q=4096, **base, B=64,
             b_tile=None, stream_dtype="f32", kernel="rbf",
             coreset_size=128),
        dict(name="serve_server_kernel_rbf_b64_s64", Q=4096, **base, B=64,
             b_tile=None, stream_dtype="f32", kernel="rbf", coreset_size=64,
             path="server"),
        # the live loop at a production-ish shape: ingest rate, hot-swap
        # blackout, and recovery time after a mid-stream kill
        dict(name="live_b64_d128", path="live", B=64, D=128, chunk_rows=2048,
             n_chunks=16, n_sub_banks=4, rotate_every=4, swap_every=2),
        # its kernelized twin: core-set S=64 train/merge/fold + RBF serving,
        # same cadences — the rows pair up for linear-vs-kernel comparison
        dict(name="live_kernel_b16_d64_s64", path="live", bank_kind="kernel",
             B=16, D=64, chunk_rows=512, n_chunks=12, n_sub_banks=4,
             rotate_every=4, swap_every=2, coreset_size=64),
        # the elastic sharded live loop: 8 logical shards on an 8-device
        # mesh, plus the remesh-recovery clock (kill, relaunch on 4
        # devices) — skipped loudly without devices, measured in the
        # --filter sharded --append pass
        dict(name="live_sharded_b64_d128", path="live", B=64, D=128,
             chunk_rows=2048, n_chunks=16, n_sub_banks=4, rotate_every=4,
             swap_every=2, n_stream_shards=8),
    ]


def run(smoke: bool, reps: int, interpret, name_filter: str | None = None,
        peak_gbps: float | None = None):
    peak = hbm_peak_gbps(peak_gbps)
    results = []
    baselines = {}
    for cfg in sweep(smoke):
        if name_filter is not None and name_filter not in cfg["name"]:
            continue
        if cfg.get("path") == "live":
            row = bench_live(cfg, reps, interpret)
            if row is not None:  # sharded rows skip loudly sans devices
                results.append(row)
            continue
        row = bench_one(cfg, reps, interpret, peak)
        base = baselines.get(cfg.get("overlap_baseline"))
        if base is not None:
            # DMA-overlap efficiency: wall time vs the equal-shape vmem
            # baseline (equal modeled bytes, so this is also the
            # achieved-GB/s ratio); 1.0 = the ring matches the BlockSpec
            # pipeline
            row["dma_overlap_efficiency"] = (
                base["seconds_per_batch"] / row["seconds_per_batch"]
            )
        elif cfg.get("overlap_baseline") is not None:
            print(
                f'NOTE {cfg["name"]}: overlap baseline '
                f'{cfg["overlap_baseline"]!r} not measured in this run — '
                "dma_overlap_efficiency stays null"
            )
        baselines[cfg["name"]] = row
        results.append(row)
    return {
        "schema": SCHEMA,
        "generated": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "backend": jax.default_backend(),
        "interpret": (
            jax.default_backend() != "tpu" if interpret is None else interpret
        ),
        "jax_version": jax.__version__,
        "hbm_peak_gbps": peak,
        "smoke": smoke,
        "reps": reps,
        "results": results,
    }


def validate(report: dict):
    """Schema check (used by the CI bench-smoke job).

    Validates the report's SHAPE and that the measurements are sane numbers.
    The one-pass query-movement property (query_passes == 1.0) is a design
    invariant of the data-major grid, enforced by the kernel parity suite
    (tests/test_predict_engine.py bit-exactness across b_tile); the field is
    reported so downstream readers model bytes correctly.
    """
    for key in ("schema", "generated", "backend", "hbm_peak_gbps", "results"):
        if key not in report:
            raise ValueError(f"BENCH report missing key {key!r}")
    if report["schema"] != SCHEMA:
        raise ValueError(f"unexpected schema {report['schema']!r}")
    if not report["results"]:
        raise ValueError("BENCH report has no results")
    for row in report["results"]:
        if row.get("path") == "live":
            missing = [k for k in LIVE_RESULT_KEYS if k not in row]
            if missing:
                raise ValueError(
                    f"live result {row.get('name')!r} missing {missing}"
                )
            for key in ("seconds_per_chunk", "rows_per_s", "swap_latency_s",
                        "recovery_seconds"):
                if not row[key] > 0:
                    raise ValueError(
                        f"{row['name']}: non-positive {key} ({row[key]!r})"
                    )
            if not (row["swaps"] >= 1 and row["checkpoints"] >= 1):
                raise ValueError(
                    f"{row['name']}: a live run must swap and checkpoint at "
                    f"least once (swaps={row['swaps']}, "
                    f"checkpoints={row['checkpoints']})"
                )
            if row["bank_kind"] not in ("linear", "kernel"):
                raise ValueError(
                    f"{row['name']}: unknown bank_kind {row['bank_kind']!r}"
                )
            shards = row["n_stream_shards"]
            if not (isinstance(shards, int) and shards >= 1):
                raise ValueError(
                    f"{row['name']}: n_stream_shards must be an int >= 1, "
                    f"got {shards!r}"
                )
            pps = row["rows_per_s_per_shard"]
            if not (pps > 0 and abs(pps * shards - row["rows_per_s"])
                    <= 1e-6 * row["rows_per_s"]):
                raise ValueError(
                    f"{row['name']}: rows_per_s_per_shard ({pps!r}) must "
                    "be rows_per_s / n_stream_shards"
                )
            rr = row["remesh_recovery_seconds"]
            if shards > 1:
                if not (rr is not None and rr > 0):
                    raise ValueError(
                        f"{row['name']}: sharded live rows must clock a "
                        f"positive remesh_recovery_seconds, got {rr!r}"
                    )
            elif rr is not None:
                raise ValueError(
                    f"{row['name']}: remesh_recovery_seconds={rr!r} on an "
                    "unsharded row (must be null)"
                )
            continue
        missing = [k for k in RESULT_KEYS if k not in row]
        if missing:
            raise ValueError(f"result {row.get('name')!r} missing {missing}")
        if not (row["seconds_per_batch"] > 0 and row["achieved_gbps"] > 0):
            raise ValueError(f"{row['name']}: non-positive measurement")
        if row["epilogue"] not in ("scores", "ovr", "topk"):
            raise ValueError(
                f"{row['name']}: unknown epilogue {row['epilogue']!r}"
            )
        if row["path"] not in ("ops", "server"):
            raise ValueError(f"{row['name']}: unknown path {row['path']!r}")
        if row["bank_resident"] not in ("vmem", "hbm"):
            raise ValueError(
                f"{row['name']}: unknown bank_resident "
                f"{row['bank_resident']!r}"
            )
        if row["kernel"] not in (None, "linear", "rbf"):
            raise ValueError(
                f"{row['name']}: unknown kernel {row['kernel']!r}"
            )
        if row["kernel"] is not None and not (
            isinstance(row["coreset_size"], int) and row["coreset_size"] >= 1
        ):
            raise ValueError(
                f"{row['name']}: kernelized rows need coreset_size >= 1, "
                f"got {row['coreset_size']!r}"
            )
        if row["kernel"] is None and row["coreset_size"] is not None:
            raise ValueError(
                f"{row['name']}: coreset_size={row['coreset_size']!r} "
                "without a kernel"
            )
        if not (
            isinstance(row["vmem_working_set_bytes"], int)
            and row["vmem_working_set_bytes"] > 0
        ):
            raise ValueError(
                f"{row['name']}: vmem_working_set_bytes must be a positive "
                f"int, got {row['vmem_working_set_bytes']!r}"
            )
        if not row["hbm_peak_gbps"] > 0:
            raise ValueError(
                f"{row['name']}: hbm_peak_gbps must be positive, got "
                f"{row['hbm_peak_gbps']!r}"
            )
        eff = row["dma_overlap_efficiency"]
        if eff is not None and not eff > 0:
            raise ValueError(
                f"{row['name']}: dma_overlap_efficiency must be null or "
                f"positive, got {eff!r}"
            )
    return True


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny CI sweep")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument(
        "--out",
        default=str(Path(__file__).resolve().parent.parent / "BENCH_serving.json"),
    )
    ap.add_argument(
        "--interpret", default=None, choices=["true", "false"],
        help="force interpret mode (default: auto — interpret off-TPU)",
    )
    ap.add_argument(
        "--hbm-peak-gbps", type=float, default=None, metavar="GBPS",
        help="HBM roofline peak in GB/s (default: REPRO_HBM_PEAK_GBPS env "
        f"var, else {DEFAULT_HBM_PEAK_GBPS} — TPU v5e per chip)",
    )
    ap.add_argument(
        "--filter", default=None, metavar="SUBSTR",
        help="bench only configs whose name contains SUBSTR",
    )
    ap.add_argument(
        "--append", action="store_true",
        help="merge results into an existing --out report (rows with the "
        "same name are replaced)",
    )
    args = ap.parse_args(argv)
    interpret = None if args.interpret is None else args.interpret == "true"

    report = run(args.smoke, args.reps, interpret, name_filter=args.filter,
                 peak_gbps=args.hbm_peak_gbps)
    out_path = Path(args.out)
    if args.append and out_path.exists():
        prev = json.loads(out_path.read_text())
        new_names = {r["name"] for r in report["results"]}
        report["results"] = [
            r for r in prev.get("results", []) if r["name"] not in new_names
        ] + report["results"]
    validate(report)
    out_path.write_text(json.dumps(report, indent=2) + "\n")

    hdr = ("name", "epilogue", "path", "resident", "queries/s",
           "model-scores/s", "GB/s", "roofline%", "overlap-eff", "s/batch")
    print(",".join(hdr))
    for r in report["results"]:
        if r["path"] == "live":
            print(
                f'{r["name"]},{r["bank_kind"]},live,-,'
                f'{r["rows_per_s"]:.0f} rows/s,'
                f'swap={r["swap_latency_s"] * 1e3:.2f}ms,'
                f'recovery={r["recovery_seconds"]:.3f}s,-,-,'
                f'{r["seconds_per_chunk"]:.4f}/chunk'
            )
            continue
        eff = r["dma_overlap_efficiency"]
        print(
            f'{r["name"]},{r["epilogue"]},{r["path"]},{r["bank_resident"]},'
            f'{r["queries_per_s"]:.0f},{r["model_scores_per_s"]:.0f},'
            f'{r["achieved_gbps"]:.3f},{100 * r["roofline_frac"]:.2f},'
            f'{"-" if eff is None else f"{eff:.3f}"},'
            f'{r["seconds_per_batch"]:.4f}'
        )
    print(f"BENCH written: {args.out}")


if __name__ == "__main__":
    main()
