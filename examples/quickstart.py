"""Quickstart: one-pass StreamSVM vs single-pass baselines on Synthetic-A,
then a whole C-grid trained in ONE pass via the multi-ball engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import fit_pegasos, fit_perceptron
from repro.core import accuracy, fit, fit_c_grid, fit_lookahead
from repro.data import load_dataset, preprocess_for


def main():
    Xtr, ytr, Xte, yte = load_dataset("synthetic_a")
    Xtr, Xte = preprocess_for("synthetic_a", Xtr, Xte)
    Xj, yj = jnp.asarray(Xtr), jnp.asarray(ytr)
    Xt, yt = jnp.asarray(Xte), jnp.asarray(yte)

    C = 10.0
    ball = fit(Xj, yj, C)  # Algorithm 1: one pass, O(D) state
    ball2 = fit_lookahead(Xj, yj, C, 10)  # Algorithm 2: lookahead 10

    acc = lambda w: float(np.mean(np.sign(Xte @ np.asarray(w)) == yte)) * 100
    wp, _ = fit_perceptron(Xj, yj)
    wpeg = fit_pegasos(Xj, yj, lam=1.0 / (C * len(ytr)), k=20)

    print(f"StreamSVM Algo-1 : {acc(ball.w):5.1f}%  (core vectors: {int(ball.m)})")
    print(f"StreamSVM Algo-2 : {acc(ball2.w):5.1f}%  (core vectors: {int(ball2.m)})")
    print(f"Perceptron       : {acc(wp):5.1f}%")
    print(f"Pegasos k=20     : {acc(wpeg):5.1f}%")
    print(f"ball radius R={float(ball.r):.3f}  xi2={float(ball.xi2):.4f}  "
          f"state = {ball.w.nbytes + 12} bytes (constant in N)")

    # --- hyper-parameter grid in ONE pass (multi-ball Pallas engine) --------
    # Every C value is a model in the engine's bank: each (block_n, D) tile of
    # the stream is read from HBM once and updates all grid points, so model
    # selection costs one data pass instead of len(grid) passes.
    grid = jnp.asarray([0.1, 1.0, 10.0, 100.0, 1000.0], jnp.float32)
    bank = fit_c_grid(Xj, yj, grid)  # warmup/compile
    t0 = time.perf_counter()
    bank = jax.block_until_ready(fit_c_grid(Xj, yj, grid))
    dt = time.perf_counter() - t0
    accs = [acc(bank.w[i]) for i in range(len(grid))]
    print(f"\nC-grid in one pass ({len(grid)} models, {dt*1e3:.0f} ms):")
    for i, c in enumerate(np.asarray(grid)):
        print(f"  C={c:7.1f}  acc={accs[i]:5.1f}%  "
              f"core vectors={int(bank.m[i])}")
    best = int(np.argmax(accs))
    print(f"selected C* = {float(grid[best]):g} — one stream read for the "
          f"whole grid (state O(B*D) = {bank.w.nbytes} bytes)")


if __name__ == "__main__":
    main()
