"""Quickstart: one-pass StreamSVM vs single-pass baselines on Synthetic-A,
a whole C-grid trained in ONE pass via the multi-ball engine, then a
200-class OVR x 3-point C-grid (600 models) in one pass of the TILED engine
— re-trained HBM-resident (``bank_resident="hbm"`` — the double-buffered
ring that lifts the VMEM cap on B*D, bit-exact with VMEM scratch) — and the
trained bank SERVED back through the fused predict engine
(serve.BankServer), bit-exact with the direct readout.

    PYTHONPATH=src python examples/quickstart.py

SHARDED: every bank entry point also takes ``mesh=`` — the stream splits
into contiguous ranges over a device mesh axis, each shard runs the same
tiled engine over its range, and the per-shard banks are folded with the
paper's Sec-4.3 merge (one all_gather). N need not divide the shard count
(ragged remainders are padded with inert sign-0 rows):

    mesh = jax.make_mesh((8,), ("data",))
    bank = fit_bank(X, Y, cs, b_tile=64, stream_dtype="bf16", mesh=mesh)
    # equivalently: fit_ovr(..., mesh=mesh), fit_c_grid(..., mesh=mesh),
    # fit_chunked_many(..., mesh=mesh) — and core.fit_bank_sharded directly.

Run the 8-device version of this flow (simulated host devices):

    PYTHONPATH=src python examples/svm_distributed.py

Engine throughput numbers for these paths are tracked in BENCH_engine.json
(including the ``n_shards`` scaling rows) — regenerate with:

    PYTHONPATH=src python benchmarks/streaming_throughput.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import fit_pegasos, fit_perceptron
from repro.core import accuracy, fit, fit_bank, fit_c_grid, fit_lookahead, ovr_signs
from repro.data import load_dataset, preprocess_for


def main():
    Xtr, ytr, Xte, yte = load_dataset("synthetic_a")
    Xtr, Xte = preprocess_for("synthetic_a", Xtr, Xte)
    Xj, yj = jnp.asarray(Xtr), jnp.asarray(ytr)
    Xt, yt = jnp.asarray(Xte), jnp.asarray(yte)

    C = 10.0
    ball = fit(Xj, yj, C)  # Algorithm 1: one pass, O(D) state
    ball2 = fit_lookahead(Xj, yj, C, 10)  # Algorithm 2: lookahead 10

    acc = lambda w: float(np.mean(np.sign(Xte @ np.asarray(w)) == yte)) * 100
    wp, _ = fit_perceptron(Xj, yj)
    wpeg = fit_pegasos(Xj, yj, lam=1.0 / (C * len(ytr)), k=20)

    print(f"StreamSVM Algo-1 : {acc(ball.w):5.1f}%  (core vectors: {int(ball.m)})")
    print(f"StreamSVM Algo-2 : {acc(ball2.w):5.1f}%  (core vectors: {int(ball2.m)})")
    print(f"Perceptron       : {acc(wp):5.1f}%")
    print(f"Pegasos k=20     : {acc(wpeg):5.1f}%")
    print(f"ball radius R={float(ball.r):.3f}  xi2={float(ball.xi2):.4f}  "
          f"state = {ball.w.nbytes + 12} bytes (constant in N)")

    # --- hyper-parameter grid in ONE pass (multi-ball Pallas engine) --------
    # Every C value is a model in the engine's bank: each (block_n, D) tile of
    # the stream is read from HBM once and updates all grid points, so model
    # selection costs one data pass instead of len(grid) passes.
    grid = jnp.asarray([0.1, 1.0, 10.0, 100.0, 1000.0], jnp.float32)
    bank = fit_c_grid(Xj, yj, grid)  # warmup/compile
    t0 = time.perf_counter()
    bank = jax.block_until_ready(fit_c_grid(Xj, yj, grid))
    dt = time.perf_counter() - t0
    accs = [acc(bank.w[i]) for i in range(len(grid))]
    print(f"\nC-grid in one pass ({len(grid)} models, {dt*1e3:.0f} ms):")
    for i, c in enumerate(np.asarray(grid)):
        print(f"  C={c:7.1f}  acc={accs[i]:5.1f}%  "
              f"core vectors={int(bank.m[i])}")
    best = int(np.argmax(accs))
    print(f"selected C* = {float(grid[best]):g} — one stream read for the "
          f"whole grid (state O(B*D) = {bank.w.nbytes} bytes)")

    # --- 200-class OVR x 3-point C-grid: 600 models, ONE pass ---------------
    # Classes x C-grid flatten onto the bank axis of the TILED engine: the
    # 2-D (data-major) grid re-visits each resident stream tile with every
    # b_tile-model bank tile, so the stream is still read once, bf16 tiles
    # halve its HBM bytes, and B is no longer capped by the per-step VMEM
    # working set. Training 600 independent fits here would read the stream
    # 600 times; the bank reads it ONCE. (Scaled-down shapes so the CPU
    # interpret mode stays fast; on TPU crank N/D and watch BENCH_engine.json.
    # Note the per-model core-vector budget m stays O(log N) — the paper's
    # sparsity claim — so extreme-imbalance OVR argmax at 200 classes is a
    # stress test of Algorithm 1 itself, not of the engine; the engine is
    # bit-exact with 600 separate single-model fits.)
    n_classes, c_pts = 200, (1.0, 10.0, 100.0)
    rng = np.random.default_rng(0)
    proto = rng.normal(size=(n_classes, 64)).astype(np.float32) * 3
    labels = rng.integers(0, n_classes, size=2000)
    Xm = (rng.normal(size=(2000, 64)) + proto[labels]).astype(np.float32)
    Xm /= np.linalg.norm(Xm, axis=1, keepdims=True)
    signs = ovr_signs(jnp.asarray(labels), n_classes)  # (200, N)
    Y = jnp.tile(signs, (len(c_pts), 1))  # (600, N): class-major per C point
    cs = jnp.repeat(jnp.asarray(c_pts, jnp.float32), n_classes)  # (600,)
    ovr = fit_bank(jnp.asarray(Xm), Y, cs, b_tile=64, stream_dtype="bf16")
    t0 = time.perf_counter()
    ovr = jax.block_until_ready(
        fit_bank(jnp.asarray(Xm), Y, cs, b_tile=64, stream_dtype="bf16")
    )
    dt = time.perf_counter() - t0
    B, N = Y.shape
    print(f"\n200-class OVR x {len(c_pts)}-point C-grid: {B} models, "
          f"ONE {N}-row stream pass in {dt*1e3:.0f} ms "
          f"({B * N / dt / 1e6:.1f}M model-row updates/s, interpret mode)")
    m = np.asarray(ovr.m)
    for ci, cval in enumerate(c_pts):
        mc = m[ci * n_classes : (ci + 1) * n_classes]
        print(f"  C={cval:6.1f}  core vectors/model: "
              f"min={mc.min()} mean={mc.mean():.1f} max={mc.max()}")
    print(f"bank state O(B*D) = {ovr.w.nbytes} bytes vs one stream read "
          f"of {Xm.nbytes} bytes; throughput harness: "
          "PYTHONPATH=src python benchmarks/streaming_throughput.py")

    # --- the same bank, HBM-resident ----------------------------------------
    # bank_resident="hbm" lifts the VMEM cap on B*D: the bank stays in HBM
    # and (b_tile, D) slices double-buffer through a 2-slot VMEM ring (async
    # prefetch + write-back overlapped with compute) — bit-exact with the
    # VMEM-resident layout, so a 1000-class x C-grid bank at D=4096 (~49 MB,
    # far beyond VMEM scratch) trains with the exact same call. The default
    # "auto" switches over at the VMEM budget (REPRO_VMEM_BUDGET_BYTES).
    ovr_hbm = jax.block_until_ready(
        fit_bank(jnp.asarray(Xm), Y, cs, b_tile=64, stream_dtype="bf16",
                 bank_resident="hbm")
    )
    assert np.array_equal(np.asarray(ovr_hbm.w), np.asarray(ovr.w))
    print('bank_resident="hbm": HBM-resident ring-buffered bank is '
          "bit-exact with VMEM-resident (lifts the VMEM cap on B*D)")

    # --- serve it: the bank through the fused predict engine ----------------
    # The trained bank is tiny and constant-storage, which is exactly the
    # high-QPS deploy shape: serve.BankServer microbatches ragged query
    # batches into fixed (q_block,) row slots and scores each microbatch with
    # ONE fused Pallas kernel launch (per-C-grid-group argmax epilogue).
    # Served f32 results are bit-exact with the direct jnp readout. From a
    # fit_chunked_many checkpoint the same flow is
    # BankServer.from_checkpoint(path, epilogue="ovr").score(queries) — see
    # examples/serve_bank.py.
    from repro.core import predict_c_grid
    from repro.serve import BankServer

    server = BankServer(ovr, epilogue="ovr", n_classes=n_classes,
                        q_block=256, b_tile=200)
    server.score(Xm[:1])  # warmup/compile (the kernel shape is (q_block, D))
    steps0 = server.stats.steps
    t0 = time.perf_counter()
    cls, _ = server.score(Xm)
    dt = time.perf_counter() - t0
    direct_cls, _ = predict_c_grid(ovr, jnp.asarray(Xm), n_classes)
    served = np.mean(cls == np.asarray(labels)[:, None], axis=0)
    direct = np.mean(np.asarray(direct_cls) == np.asarray(labels)[:, None], axis=0)
    print(f"\nserved the bank back over the {len(Xm)} training rows in "
          f"{server.stats.steps - steps0} microbatches ({dt*1e3:.0f} ms, "
          f"{len(Xm)/dt:.0f} queries/s, interpret mode):")
    for ci, cval in enumerate(c_pts):
        print(f"  C={cval:6.1f}  served acc={100*served[ci]:5.1f}%  "
              f"direct acc={100*direct[ci]:5.1f}%")
    exact = np.array_equal(cls, np.asarray(direct_cls))
    print(f"served == direct predict_c_grid readout, bit for bit: {exact}")


if __name__ == "__main__":
    main()
