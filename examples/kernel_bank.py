"""Kernelized one-pass bank: nonlinear data -> RBF core-set bank -> serving.

    PYTHONPATH=src python examples/kernel_bank.py

Two concentric rings are not linearly separable, so the linear one-pass
engine tops out near chance. ``core.fit_kernel_bank`` runs the SAME
Algorithm 1 recursion in kernel space over the SAME single stream pass:
each of the B models keeps a bounded core-set buffer of at most S stream
rows (state O(B * S * D), independent of stream length N — the paper's
constant-storage claim carried to kernel space) and evicts a slot when
full — ``eviction="smallest-coef"`` drops the smallest-|coef| slot,
``eviction="farthest-point"`` drops the slot closest to the center and
keeps the extremes that carry the ball geometry. The C grid AND gamma are
traced, so a whole hyperparameter sweep is one compilation; ``s_tile=``
chunks the core-set Gram launch (bit-exact) when B * S outgrows the VMEM
budget; ``mesh=`` shards the stream over devices and folds the per-shard
banks with the kernelized Sec-4.3 merge (demonstrated below when more
than one device is visible).

The trained bank checkpoints through ``core.save_kernel_bank`` and serves
through the same ``BankServer`` as the linear bank —
``from_checkpoint`` restores the kernel/gamma config from the checkpoint
meta, and served scores are BIT-EXACT with the direct
``core.kernel_bank_decision`` readout (asserted below, not just printed).

Throughput rows for this path live in BENCH_engine.json (kernel_* rows)
and BENCH_serving.json (serve_kernel_* rows).
"""
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fit_kernel_bank, kernel_bank_decision, save_kernel_bank
from repro.serve import BankServer


def make_rings(n, d, seed):
    """Inner ring -> +1, outer ring -> -1; extra dims are noise."""
    rng = np.random.default_rng(seed)
    y = np.where(rng.uniform(size=n) < 0.5, 1.0, -1.0).astype(np.float32)
    radius = np.where(y > 0, 1.0, 2.5)
    theta = rng.uniform(0, 2 * np.pi, size=n)
    X = rng.normal(scale=0.1, size=(n, d)).astype(np.float32)
    X[:, 0] += (radius * np.cos(theta)).astype(np.float32)
    X[:, 1] += (radius * np.sin(theta)).astype(np.float32)
    return X, y


def bank_accuracy(bank, Xte, yte, *, kernel, gamma):
    scores = np.asarray(
        kernel_bank_decision(bank, jnp.asarray(Xte), kernel=kernel, gamma=gamma)
    )  # (Q, B)
    return [float(np.mean(np.sign(s) == yte)) for s in scores.T]


def main():
    c_pts, d, s_size, gamma = (0.5, 5.0, 50.0), 8, 64, 2.0
    Xtr, ytr = make_rings(1200, d, seed=0)
    Xte, yte = make_rings(400, d, seed=1)

    Y = jnp.tile(jnp.asarray(ytr)[None, :], (len(c_pts), 1))  # (B, N)
    cs = jnp.asarray(c_pts, jnp.float32)

    # --- one stream pass per kernel; identical API, only the epilogue flips
    banks = {}
    for kernel in ("linear", "rbf"):
        t0 = time.perf_counter()
        banks[kernel] = fit_kernel_bank(
            jnp.asarray(Xtr), Y, cs,
            kernel=kernel, gamma=gamma, coreset_size=s_size, block_n=128,
        )
        t_fit = time.perf_counter() - t0
        accs = bank_accuracy(banks[kernel], Xte, yte, kernel=kernel, gamma=gamma)
        kept = int(np.asarray(banks[kernel].m).max())
        print(
            f"{kernel:>6}: ONE {len(Xtr)}-row pass in {t_fit*1e3:5.0f} ms "
            f"(interpret mode), buffer S={s_size}, {kept} core-set updates; "
            "held-out acc "
            + ", ".join(
                f"C={c:4.1f}: {100*a:5.1f}%" for c, a in zip(c_pts, accs)
            )
        )
    # rings are radially separable only in kernel space: expect the RBF bank
    # far above the ~50% linear ceiling
    best_rbf = max(bank_accuracy(banks["rbf"], Xte, yte, kernel="rbf", gamma=gamma))
    assert best_rbf > 0.9, f"RBF bank should separate the rings, got {best_rbf}"

    # --- eviction + s_tile: same pass, different slot policy / tiling ------
    # farthest-point keeps the slots FARTHEST from the center (the extremes
    # that pin down the enclosing ball) instead of the largest coefficients.
    bank_fp = fit_kernel_bank(
        jnp.asarray(Xtr), Y, cs,
        kernel="rbf", gamma=gamma, coreset_size=s_size, block_n=128,
        eviction="farthest-point",
    )
    best_fp = max(bank_accuracy(bank_fp, Xte, yte, kernel="rbf", gamma=gamma))
    # s_tile chunks the (block_n, B*S) core-set Gram launch into s_tile-slot
    # column strips — smaller VMEM working set, bit-identical bank.
    bank_tiled = fit_kernel_bank(
        jnp.asarray(Xtr), Y, cs,
        kernel="rbf", gamma=gamma, coreset_size=s_size, block_n=128,
        s_tile=16,
    )
    assert all(
        np.array_equal(a, b) for a, b in zip(banks["rbf"], bank_tiled)
    ), "s_tile chunking must be bit-exact"
    print(
        f"eviction sweep: smallest-coef {100*best_rbf:5.1f}% vs "
        f"farthest-point {100*best_fp:5.1f}% held-out acc; s_tile=16 refit "
        "is BIT-EXACT with the unchunked bank (7/7 leaves)"
    )

    # --- mesh-sharded fit: split the stream, merge the banks (Sec 4.3) ----
    # Each device runs the one-pass recursion on its own shard; the
    # per-shard banks fold pairwise with the kernelized ball merge
    # (concatenated core-sets re-compressed to S slots). Run with
    #   XLA_FLAGS=--xla_force_host_platform_device_count=8
    # to see the multi-device path on CPU.
    n_dev = len(jax.devices())
    if n_dev > 1:
        mesh = jax.make_mesh((n_dev,), ("data",))
        t0 = time.perf_counter()
        bank_sh = fit_kernel_bank(
            jnp.asarray(Xtr), Y, cs,
            kernel="rbf", gamma=gamma, coreset_size=s_size, block_n=128,
            mesh=mesh, shard_axis="data",
        )
        t_sh = time.perf_counter() - t0
        best_sh = max(
            bank_accuracy(bank_sh, Xte, yte, kernel="rbf", gamma=gamma)
        )
        assert best_sh > 0.9, f"sharded RBF bank lost the rings: {best_sh}"
        print(
            f"mesh fit over {n_dev} stream shards in {t_sh*1e3:5.0f} ms: "
            f"held-out acc {100*best_sh:5.1f}% (single-pass "
            f"{100*best_rbf:5.1f}%) — merged bank still O(B*S*D)"
        )
    else:
        print(
            "mesh demo skipped (1 device); rerun with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8"
        )

    with tempfile.TemporaryDirectory() as td:
        # --- checkpoint -> serve: meta carries bank_kind/kernel/gamma ------
        save_kernel_bank(td, banks["rbf"], kernel="rbf", gamma=gamma)
        server = BankServer.from_checkpoint(td, q_block=128)
        print(
            f"serving core-set bank {server.bank_shape} from checkpoint "
            f"(kernel={server.kernel!r}, gamma={server.gamma} via meta)"
        )
        rng = np.random.default_rng(7)
        reqs, lo = [], 0
        while lo < len(Xte):  # ragged client batches, FIFO-packed into slots
            n = int(rng.integers(1, 100))
            reqs.append(server.submit(Xte[lo : lo + n]))
            lo += n
        t0 = time.perf_counter()
        stats = server.run()
        t_serve = time.perf_counter() - t0

    served = np.concatenate([r.result for r in reqs])  # (Q, B) margins

    # --- served == direct readout, bit for bit ----------------------------
    direct = np.asarray(
        kernel_bank_decision(
            banks["rbf"], jnp.asarray(Xte), kernel="rbf", gamma=gamma
        )
    )
    assert np.array_equal(served, direct), "served kernel scores diverged"
    print(
        f"served {len(Xte)} queries x {len(c_pts)} models in {stats.steps} "
        f"microbatches ({t_serve*1e3:.0f} ms, {len(Xte)/t_serve:.0f} "
        f"queries/s, slot utilization {stats.utilization:.1%}); served f32 "
        "scores BIT-EXACT with core.kernel_bank_decision"
    )

    # --- hot swap: continue the fit on fresh rows, serving keeps running --
    X2, y2 = make_rings(600, d, seed=2)
    X12 = np.concatenate([Xtr, X2])
    Y12 = jnp.tile(jnp.asarray(np.concatenate([ytr, y2]))[None, :],
                   (len(c_pts), 1))
    bank2 = fit_kernel_bank(
        jnp.asarray(X12), Y12, cs,
        kernel="rbf", gamma=gamma, coreset_size=s_size, block_n=128,
    )
    server.submit(Xte[:128])
    server.step()  # scores against the OLD bank
    server.swap_bank(bank2)  # queued requests survive the swap
    server.run()
    print(
        f"hot-swapped to the {len(X12)}-row bank mid-stream "
        f"({server.stats.bank_swaps} swap, {server.stats.finished} requests "
        "finished, none dropped)"
    )


if __name__ == "__main__":
    main()
