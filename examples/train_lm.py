"""End-to-end LM training driver with checkpoint/restart.

Default: a ~15M-param internlm2-family model, 60 steps (CPU-friendly).
--full: a ~100M-param model for 300 steps (the assignment's e2e driver).

    PYTHONPATH=src python examples/train_lm.py [--full] [--steps N]

Demonstrates: config system -> model build -> synthetic data pipeline ->
jit'd train step (microbatch accumulation + remat) -> checkpoint every 20
steps -> resume after a simulated preemption at step 30.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs.base import ArchConfig
from repro.data.tokens import token_batches
from repro.models import build_model
from repro.train import TrainCfg, init_state, make_train_step


def small_cfg(full: bool) -> ArchConfig:
    if full:  # ~100M params
        return ArchConfig(
            name="lm-100m", family="dense", n_layers=8, d_model=640,
            n_heads=10, n_kv_heads=5, d_ff=2560, vocab=50304, mlp="swiglu",
        )
    return ArchConfig(  # ~15M params
        name="lm-15m", family="dense", n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=2, d_ff=1024, vocab=8192, mlp="swiglu",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = small_cfg(args.full)
    steps = args.steps or (300 if args.full else 60)
    batch = args.batch or (4 if args.full else 8)
    seq = args.seq or (256 if args.full else 128)

    model = build_model(cfg, remat="none")
    tcfg = TrainCfg(
        peak_lr=1e-3 if args.full else 3e-3,
        warmup_steps=min(10, steps // 4),
        total_steps=steps,
        microbatches=1,
    )
    state = init_state(model, jax.random.PRNGKey(0), tcfg)
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params, {steps} steps, "
          f"batch {batch}x{seq}")

    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
    batches = list(token_batches(cfg.vocab, batch, seq, steps, seed=1))

    t0 = time.time()

    def run(state, start, stop):
        m = {}
        for i in range(start, stop):
            b = {k: jnp.asarray(v) for k, v in batches[i].items()}
            state, m = step_fn(state, b)
            if (i + 1) % 20 == 0 or i == 0:
                toks = batch * seq * (i + 1)
                print(f"step {i+1:4d} loss={float(m['loss']):.4f} "
                      f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.2f} "
                      f"tok/s={toks/(time.time()-t0):.0f}", flush=True)
                ckpt.save(args.ckpt_dir, state, meta={"step": i + 1})
        return state, m

    crash_at = min(30, steps)
    state, m = run(state, 0, crash_at)
    print("-- simulated preemption: restoring from last durable checkpoint --")
    meta = ckpt.load_meta(args.ckpt_dir)
    state = ckpt.restore(args.ckpt_dir, state)
    print(f"-- resumed at step {meta['step']} --")
    state, m = run(state, meta["step"], steps)

    print(f"done in {time.time()-t0:.1f}s; final loss {float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
