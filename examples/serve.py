"""Batched serving demo: prefill + decode loop with a KV cache.

    PYTHONPATH=src python examples/serve.py [--arch internlm2-1.8b]

Uses the reduced (smoke) config of the chosen architecture so it runs on CPU;
the identical code path is what the dry-run lowers at production shapes.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    B, P = args.batch, args.prompt_len
    max_len = P + args.gen
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, P)), jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)) * 0.1, jnp.float32)

    prefill = jax.jit(lambda p, b: model.prefill(p, {**b, "max_len": max_len}))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [toks]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, toks)
        toks = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    t_dec = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={cfg.name} batch={B} prompt={P} generated={gen.shape[1]}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: "
          f"{t_dec*1e3/ (args.gen-1):.1f} ms/token  ({(args.gen-1)*B/t_dec:.1f} tok/s)")
    print("sample tokens:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
