"""Distributed one-pass StreamSVM: sharded streams + ball merge + C-grid,
then the SHARDED BANK ENGINE — a 200-class OVR x 3-point C-grid (600 models)
trained across 8 devices in one pass of each shard's stream range.

Runs on 8 simulated devices (this example sets the XLA host-device flag
itself — run it as a script, not an import).

    PYTHONPATH=src python examples/svm_distributed.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    accuracy,
    fit,
    fit_bank_sharded,
    fit_c_grid,
    fit_sharded,
    ovr_signs,
    predict_ovr,
)
from repro.data import load_dataset, preprocess_for


def main():
    Xtr, ytr, Xte, yte = load_dataset("mnist89")
    Xtr, Xte = preprocess_for("mnist89", Xtr, Xte)
    n = (len(ytr) // 8) * 8
    Xj, yj = jnp.asarray(Xtr[:n]), jnp.asarray(ytr[:n])
    Xt, yt = jnp.asarray(Xte), jnp.asarray(yte)

    mesh = jax.make_mesh((8,), ("data",))
    print(f"devices: {len(jax.devices())}  mesh: {mesh.shape}")

    t0 = time.time()
    ball_seq = fit(Xj, yj, 10.0)
    t_seq = time.time() - t0

    t0 = time.time()
    ball_dist = fit_sharded(Xj, yj, 10.0, mesh, lookahead=10)
    t_dist = time.time() - t0

    print(f"sequential  : acc={float(accuracy(ball_seq, Xt, yt)) * 100:5.2f}%  "
          f"r={float(ball_seq.r):.3f}  ({t_seq:.2f}s)")
    print(f"8-shard+merge: acc={float(accuracy(ball_dist, Xt, yt)) * 100:5.2f}%  "
          f"r={float(ball_dist.r):.3f}  ({t_dist:.2f}s)")

    # hyper-parameter grid: the whole grid is a bank in the engine, and the
    # STREAM is sharded over the mesh — grid x shards in one pass per shard
    grid = jnp.asarray([0.1, 1.0, 10.0, 100.0], jnp.float32)
    balls = fit_c_grid(Xj, yj, grid, mesh=mesh)
    accs = [float(accuracy(jax.tree.map(lambda x: x[i], balls), Xt, yt)) * 100
            for i in range(len(grid))]
    for c, a in zip(np.asarray(grid), accs):
        print(f"C={c:7.1f}: acc={a:5.2f}%")

    # --- sharded bank engine: 200-class OVR x 3 C points on 8 devices -------
    # Classes x C-grid flatten onto the bank axis (fit_bank's B), the STREAM
    # splits into 8 contiguous shards (fit_bank_sharded pads the ragged
    # remainder with inert sign-0 rows), every shard runs the tiled Pallas
    # engine over its range, and one all_gather + bank-vectorized Sec-4.3
    # fold (meb.fold_merge over the (8, 600, D) stack) replicates the merged
    # bank everywhere. Each stream row is read from HBM exactly once, on
    # exactly one device.
    n_classes, c_pts = 200, (1.0, 10.0, 100.0)
    rng = np.random.default_rng(0)
    proto = rng.normal(size=(n_classes, 64)).astype(np.float32) * 3
    labels = rng.integers(0, n_classes, size=2003)  # ragged on purpose
    Xm = (rng.normal(size=(2003, 64)) + proto[labels]).astype(np.float32)
    Xm /= np.linalg.norm(Xm, axis=1, keepdims=True)
    signs = ovr_signs(jnp.asarray(labels), n_classes)      # (200, N)
    Y = jnp.tile(signs, (len(c_pts), 1))                   # (600, N)
    cs = jnp.repeat(jnp.asarray(c_pts, jnp.float32), n_classes)
    jax.block_until_ready(  # warm-up: compile once, so the timed call below
        fit_bank_sharded(   # measures the pass, not tracing + compilation
            jnp.asarray(Xm), Y, cs, mesh, b_tile=64, stream_dtype="bf16"
        )
    )
    t0 = time.perf_counter()
    ovr = jax.block_until_ready(
        fit_bank_sharded(
            jnp.asarray(Xm), Y, cs, mesh, b_tile=64, stream_dtype="bf16"
        )
    )
    dt = time.perf_counter() - t0
    B, N = Y.shape
    print(f"\nsharded bank: {B} models x 8 stream shards, N={N} "
          f"(ragged; padded with inert rows) in {dt*1e3:.0f} ms")
    m = np.asarray(ovr.m)
    for ci, cval in enumerate(c_pts):
        blk = jax.tree.map(lambda x: x[ci * n_classes:(ci + 1) * n_classes], ovr)
        pred = predict_ovr(blk, jnp.asarray(Xm))
        acc = float(jnp.mean(pred == jnp.asarray(labels))) * 100
        mc = m[ci * n_classes:(ci + 1) * n_classes]
        # NOTE (same caveat as quickstart): extreme-imbalance OVR argmax at
        # 200 classes stresses Algorithm 1 itself, not the engine — quote
        # accuracy against the 0.5% chance rate, not against a tuned SVM.
        print(f"  C={cval:6.1f}  OVR train acc {acc:5.1f}% (chance 0.5%)  "
              f"core vectors/model: min={mc.min()} mean={mc.mean():.1f} "
              f"max={mc.max()}")
    print(f"  merged bank state O(B*D) = {ovr.w.nbytes} bytes, replicated on "
          f"all {len(jax.devices())} devices; throughput rows: "
          "PYTHONPATH=src python benchmarks/streaming_throughput.py")


if __name__ == "__main__":
    main()
