"""Distributed one-pass StreamSVM: sharded streams + ball merge + C-grid.

Runs on 8 simulated devices (this example sets the XLA host-device flag
itself — run it as a script, not an import).

    PYTHONPATH=src python examples/svm_distributed.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accuracy, fit, fit_c_grid, fit_sharded
from repro.data import load_dataset, preprocess_for


def main():
    Xtr, ytr, Xte, yte = load_dataset("mnist89")
    Xtr, Xte = preprocess_for("mnist89", Xtr, Xte)
    n = (len(ytr) // 8) * 8
    Xj, yj = jnp.asarray(Xtr[:n]), jnp.asarray(ytr[:n])
    Xt, yt = jnp.asarray(Xte), jnp.asarray(yte)

    mesh = jax.make_mesh((8,), ("data",))
    print(f"devices: {len(jax.devices())}  mesh: {mesh.shape}")

    t0 = time.time()
    ball_seq = fit(Xj, yj, 10.0)
    t_seq = time.time() - t0

    t0 = time.time()
    ball_dist = fit_sharded(Xj, yj, 10.0, mesh, lookahead=10)
    t_dist = time.time() - t0

    print(f"sequential  : acc={float(accuracy(ball_seq, Xt, yt)) * 100:5.2f}%  "
          f"r={float(ball_seq.r):.3f}  ({t_seq:.2f}s)")
    print(f"8-shard+merge: acc={float(accuracy(ball_dist, Xt, yt)) * 100:5.2f}%  "
          f"r={float(ball_dist.r):.3f}  ({t_dist:.2f}s)")

    # hyper-parameter grid fitted in one vmapped pass
    grid = jnp.asarray([0.1, 1.0, 10.0, 100.0], jnp.float32)
    balls = fit_c_grid(Xj, yj, grid)
    accs = [float(accuracy(jax.tree.map(lambda x: x[i], balls), Xt, yt)) * 100
            for i in range(len(grid))]
    for c, a in zip(np.asarray(grid), accs):
        print(f"C={c:7.1f}: acc={a:5.2f}%")


if __name__ == "__main__":
    main()
