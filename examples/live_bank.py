"""Live bank: always-on ingest -> train -> fold -> hot-swap, crash included.

    PYTHONPATH=src python examples/live_bank.py

A drifting stream (class prototypes rotate a little every chunk) feeds a
``repro.live.LiveBank``: each chunk trains into the active sub-bank through
the tiled one-pass engine, the K rotating sub-banks fold with the Sec-4.3
merge into ONE serving bank (drift repair: fresh epochs get fresh balls,
the oldest re-merge away), and every fold hot-swaps a running
``BankServer`` — which answers queries the whole time.

Then the fault-tolerance claim, demonstrated rather than asserted on faith:
the same stream is re-run with crashes injected at four different phase
boundaries (mid-chunk, between fold and swap, mid-checkpoint-commit, after
a swap) plus transient fetch faults and one poison chunk. The recovery
driver restarts from the atomic StreamCheckpoint each time, the server
keeps serving the last good bank while the trainer is down (its staleness
visible as ``LiveStats.bank_age_chunks``), and the final bank + served
scores come out BIT-IDENTICAL (f32) to the uninterrupted run — asserted.

Next, elastic sharded training: the same drifting stream trains with FOUR
logical stream shards (``n_stream_shards=4``) — fanned out across a 4-device
mesh when the host exposes one, per-range on a single device otherwise.
The fold structure is fixed by the LOGICAL shard count (durable in every
checkpoint), not by the physical mesh, so a mid-run crash followed by a
relaunch on a SMALLER mesh (remesh-on-restart, the 8 -> 4 -> 1 elastic
story) resumes bit-exactly: the relaunch omits ``n_stream_shards`` and
adopts the checkpoint's, and the final bank + served scores equal the
crash-free single-device run — asserted.

The closing segment runs the KERNELIZED live loop (``bank_kind="kernel"``)
on drifting concentric rings — a stream no linear Ball bank can separate:
chunks train through the core-set engine, sub-banks retire through the
Sec-4.3 kernel merge (``LiveStats.merge_dropped_mass`` audits the |coef|
mass the S-slot re-compressions discarded), the server scores through the
fused RBF Gram path, and the same crash-recovery claim is asserted
bit-exactly on the core-set buffers and the served RBF scores.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ovr_signs
from repro.live import (
    ArraySource,
    FlakySource,
    LiveBank,
    run_live_with_restarts,
)
from repro.runtime.fault_tolerance import InjectedFailure
from repro.serve import BankServer


N_CHUNKS, CHUNK, D, N_CLASSES = 24, 200, 32, 8
C_PTS = (1.0, 10.0)
N_RING_CHUNKS, RING_CHUNK = 12, 128


def drifting_stream(seed=0):
    """(X, labels) whose class prototypes rotate slowly chunk over chunk."""
    rng = np.random.default_rng(seed)
    proto = rng.normal(size=(N_CLASSES, D)).astype(np.float32) * 3
    drift = rng.normal(size=(N_CLASSES, D)).astype(np.float32) * 0.15
    Xs, ys = [], []
    for t in range(N_CHUNKS):
        p = proto + t * drift  # the distribution the paper assumes away
        labels = rng.integers(0, N_CLASSES, size=CHUNK)
        X = rng.normal(size=(CHUNK, D)).astype(np.float32) + p[labels]
        X /= np.linalg.norm(X, axis=1, keepdims=True)
        Xs.append(X)
        ys.append(labels)
    return np.concatenate(Xs), np.concatenate(ys)


def drifting_rings(seed=1):
    """Binary concentric rings whose radii drift chunk over chunk — a
    stream only a nonlinear (RBF) bank can track."""
    rng = np.random.default_rng(seed)
    Xs, ys = [], []
    for t in range(N_RING_CHUNKS):
        y = np.where(rng.uniform(size=RING_CHUNK) < 0.5, 1.0, -1.0)
        rad = np.where(y > 0, 1.0, 2.5) + 0.05 * t  # the drift
        ang = rng.uniform(0, 2 * np.pi, size=RING_CHUNK)
        X = rng.normal(scale=0.1, size=(RING_CHUNK, 2)).astype(np.float32)
        X[:, 0] += (rad * np.cos(ang)).astype(np.float32)
        X[:, 1] += (rad * np.sin(ang)).astype(np.float32)
        Xs.append(X)
        ys.append(y.astype(np.float32))
    return np.concatenate(Xs), np.tile(np.concatenate(ys), (2, 1))


def make_kernel_live(source, ckpt_dir, **kw):
    cs = jnp.asarray([0.5, 5.0], jnp.float32)  # C sweep, 2 models
    return LiveBank(
        source, cs, ckpt_dir=ckpt_dir, bank_kind="kernel", kernel="rbf",
        gamma=2.0, coreset_size=32, n_sub_banks=2, rotate_every=4,
        swap_every=2,
        server_factory=lambda bank: BankServer(
            bank, kernel="rbf", gamma=2.0, q_block=64
        ),
        **kw,
    )


def make_live(source, ckpt_dir, **kw):
    cs = jnp.repeat(jnp.asarray(C_PTS, jnp.float32), N_CLASSES)  # (16,)
    return LiveBank(
        source, cs, ckpt_dir=ckpt_dir, n_sub_banks=3, rotate_every=4,
        swap_every=2, b_tile=8,
        server_factory=lambda bank: BankServer(
            bank, epilogue="ovr", n_classes=N_CLASSES, q_block=128
        ),
        **kw,
    )


def main():
    X, labels = drifting_stream()
    signs = ovr_signs(jnp.asarray(labels), N_CLASSES)  # (8, N)
    Y = jnp.tile(signs, (len(C_PTS), 1))  # (16, N)
    Yn = np.asarray(Y)
    queries = X[-256:]

    # --- uninterrupted run: the reference trajectory ----------------------
    with tempfile.TemporaryDirectory() as td:
        live = make_live(ArraySource(X, Yn, CHUNK), td + "/ckpt")
        stats = live.run()
        ref_bank = live.serving_bank()
        ref_cls, ref_margin = live.server.score(queries)
    print(
        f"clean run: {stats.chunks_ingested} chunks / {stats.rows_ingested} "
        f"rows -> {stats.folds} folds, {stats.swaps} hot-swaps, "
        f"{stats.rotations} rotations ({stats.retirements} retirements), "
        f"{stats.checkpoints} checkpoints; serving bank "
        f"{tuple(ref_bank.w.shape)}"
    )

    # --- same stream, hostile infrastructure ------------------------------
    flaky = FlakySource(
        ArraySource(X, Yn, CHUNK),
        {3: 2, 15: FlakySource.POISON},  # 2 transient faults + 1 poison chunk
    )
    failpoints = [
        ("post_train", 5),       # mid-chunk: trained, position not durable
        ("post_fold", 9),        # between fold and swap
        ("mid_checkpoint", 13),  # mid-commit: torn tmp debris left behind
        ("post_swap", 19),       # swapped, checkpoint not yet committed
    ]
    with tempfile.TemporaryDirectory() as td:
        live = make_live(flaky, td + "/ckpt", failpoints=failpoints,
                         sleep=lambda s: None)
        # no-op sleep: the example should not actually back off for seconds
        stats2 = run_live_with_restarts(live, sleep=lambda s: None)
        # the server survived every trainer crash and answers immediately
        cls, margin = live.server.score(queries)
        bank = live.serving_bank()

    print(
        f"crashy run: {stats2.restarts} restarts, {stats2.retries} fetch "
        f"retries, quarantined chunks {stats2.quarantined}, bank age at "
        f"exit {stats2.bank_age_chunks} chunks"
    )

    # The reference for the crash-equivalence claim: the SAME flaky source
    # (same transient faults, same poison chunk — a quarantined chunk keeps
    # its stream position, so epochs line up) with NO crashes injected.
    flaky_ref = FlakySource(
        ArraySource(X, Yn, CHUNK), {3: 2, 15: FlakySource.POISON}
    )
    with tempfile.TemporaryDirectory() as td:
        live_q = make_live(flaky_ref, td + "/c", sleep=lambda s: None)
        live_q.run()
        qbank = live_q.serving_bank()
        qcls, _ = live_q.server.score(queries)

    same = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(bank, qbank)
    )
    assert same, "recovered bank diverged from the crash-free run"
    assert np.array_equal(np.asarray(cls), np.asarray(qcls))
    print(
        "recovered bank + served scores BIT-IDENTICAL (f32) to the "
        "crash-free run — crashes at 4 phase boundaries changed nothing"
    )

    # Drift repair visible end to end: the served OVR accuracy on the LAST
    # (most drifted) chunks, old greedy single-ball vs the rotating cover.
    g = 0  # C = C_PTS[0] group
    acc = float(np.mean(np.asarray(cls)[:, g] == labels[-256:]))
    print(f"served held-out acc on the freshest chunk: {100 * acc:.1f}% "
          f"(K=3 rotating sub-banks, retire='merge')")

    # --- elastic sharded training: mesh fan-out + remesh-on-restart -------
    # Four LOGICAL stream shards fix the fold structure; the physical mesh
    # (when the host exposes >= 4 devices — e.g. under
    # XLA_FLAGS=--xla_force_host_platform_device_count=8) only decides
    # where the range fits execute, so every substrate below produces the
    # SAME bank bit-exactly.
    n_dev = len(jax.devices())
    mesh4 = jax.make_mesh((4,), ("data",)) if n_dev >= 4 else None
    mesh2 = jax.make_mesh((2,), ("data",)) if n_dev >= 2 else None

    # crash-free referent: 4 logical shards, NO mesh (pure per-range path)
    with tempfile.TemporaryDirectory() as td:
        live_s = make_live(ArraySource(X, Yn, CHUNK), td + "/cs",
                           n_stream_shards=4, sleep=lambda s: None)
        live_s.run()
        sbank = live_s.serving_bank()
        scls, _ = live_s.server.score(queries)

    # the elastic run: launch on the 4-device mesh, crash once mid-stream,
    # relaunch on a 2-device mesh. The relaunch OMITS n_stream_shards and
    # adopts the checkpoint's logical shard count — that is what keeps the
    # remesh invisible. The failpoint set is shared so the kill fires once.
    fps = {("post_train", 7)}
    with tempfile.TemporaryDirectory() as td:
        live_e = make_live(ArraySource(X, Yn, CHUNK), td + "/ce",
                           n_stream_shards=4, mesh=mesh4, failpoints=fps,
                           sleep=lambda s: None)
        try:
            live_e.run()
            raise AssertionError("the injected crash never fired")
        except InjectedFailure:
            pass
        restarts = live_e.stats.restarts + 1
        live_e = make_live(ArraySource(X, Yn, CHUNK), td + "/ce",
                           mesh=mesh2, failpoints=fps, sleep=lambda s: None)
        live_e.stats.restarts = restarts
        estats = live_e.run()
        ebank = live_e.serving_bank()
        ecls, _ = live_e.server.score(queries)

    assert live_e.n_stream_shards == 4, "checkpoint shard count not adopted"
    assert all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(sbank, ebank)
    ), "elastic remesh changed the bank"
    assert np.array_equal(np.asarray(scls), np.asarray(ecls))
    remeshed = mesh4 is not None or mesh2 is not None
    assert estats.remeshes >= (1 if remeshed else 0)
    print(
        f"elastic sharded run: 4 logical shards on "
        f"{'a 4-device mesh' if mesh4 is not None else 'one device'} -> "
        f"crash at chunk 7 -> resume on "
        f"{'a 2-device mesh' if mesh2 is not None else 'one device'} "
        f"({estats.remeshes} remesh(es), {estats.restarts} restart); bank + "
        "served scores BIT-IDENTICAL to the single-device referent"
    )

    # --- the kernelized live loop: drifting RINGS (nonlinear) -------------
    Xr, Yr = drifting_rings()
    rq = Xr[-RING_CHUNK:]
    with tempfile.TemporaryDirectory() as td:
        live_k = make_kernel_live(
            ArraySource(Xr, Yr, RING_CHUNK), td + "/ck", sleep=lambda s: None
        )
        kstats = live_k.run()
        kbank = live_k.serving_bank()
        kref = np.asarray(live_k.server.score(rq))
    print(
        f"kernel clean run: {kstats.chunks_ingested} ring chunks -> "
        f"{kstats.folds} folds, {kstats.swaps} hot-swaps, core-set bank "
        f"{tuple(kbank.points.shape)}; re-compression dropped |coef| mass "
        f"{kstats.merge_dropped_mass:.4f} (the S=32 buffers' audit)"
    )

    failpoints_k = [
        ("post_train", 3),       # trained, position not durable
        ("mid_checkpoint", 7),   # torn-commit debris left behind
        ("post_fold", 9),        # between fold and swap
    ]
    with tempfile.TemporaryDirectory() as td:
        live_k2 = make_kernel_live(
            ArraySource(Xr, Yr, RING_CHUNK), td + "/ck",
            failpoints=failpoints_k, sleep=lambda s: None,
        )
        kstats2 = run_live_with_restarts(live_k2, sleep=lambda s: None)
        kbank2 = live_k2.serving_bank()
        kscores2 = np.asarray(live_k2.server.score(rq))
    assert all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(kbank, kbank2)
    ), "recovered kernel bank diverged from the crash-free run"
    assert np.array_equal(kref, kscores2)
    assert kstats2.merge_dropped_mass == kstats.merge_dropped_mass
    acc_k = float(np.mean(np.sign(kref[:, 1]) == Yr[0, -RING_CHUNK:]))
    print(
        f"kernel crashy run: {kstats2.restarts} restarts — core-set bank, "
        "served RBF scores AND the dropped-mass audit BIT-IDENTICAL (f32) "
        f"to the crash-free run; acc on the freshest (most drifted) ring "
        f"chunk: {100 * acc_k:.1f}%"
    )


if __name__ == "__main__":
    main()
