"""The paper's technique as a first-class framework feature: a one-pass
StreamSVM head over LM backbone features.

A small LM backbone embeds documents (mean-pooled final hidden states); the
StreamSVM head learns a binary "style" classifier in a SINGLE PASS over the
streamed activations, with O(d_model) state — no stored activations, no
epochs. This is the deployment pattern for labeling/routing/filtering at
serving time (DESIGN.md §2).

    PYTHONPATH=src python examples/llm_feature_svm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import StreamCheckpoint, accuracy, fit_chunked
from repro.data.tokens import styled_corpus
from repro.models import build_model
from repro.train import TrainCfg, init_state, make_train_step


def main():
    cfg = ArchConfig(
        name="feat-lm", family="dense", n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=2, d_ff=1024, vocab=8192, mlp="swiglu",
    )
    model = build_model(cfg)

    # 1) briefly pretrain the backbone with the LM objective on the mixed
    #    corpus (a random-init backbone is a poor feature extractor; 60 steps
    #    of next-token prediction recovers the style structure).
    pre_toks, _ = styled_corpus(cfg.vocab, 256, 65, seed=42)
    tcfg = TrainCfg(peak_lr=1e-3, warmup_steps=10, total_steps=60)
    state = init_state(model, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
    t0 = time.time()
    for i in range(60):
        sl = pre_toks[(i * 8) % 248 : (i * 8) % 248 + 8]
        b = {"tokens": jnp.asarray(sl[:, :-1]), "targets": jnp.asarray(sl[:, 1:])}
        state, m = step(state, b)
    print(f"backbone pretrain: 60 steps, final LM loss "
          f"{float(m['loss']):.3f} ({time.time()-t0:.1f}s)")
    params = state["params"]

    @jax.jit
    def embed_docs(params, tokens, center):
        """Multi-level features (ELMo-style): mean-pooled token embeddings
        concatenated with mean-pooled final hidden states, centered +
        L2-normalized (K(x,x)=1, the reduction's kernel assumption)."""
        e = model._embed(params, {"tokens": tokens})
        h, _, _ = model._stack(params, e)

        def pool(x):
            f = jnp.mean(x.astype(jnp.float32), axis=1)
            return f / jnp.maximum(jnp.linalg.norm(f, axis=-1, keepdims=True), 1e-8)

        feats = jnp.concatenate([pool(e), pool(h)], axis=-1) - center
        return feats / jnp.maximum(
            jnp.linalg.norm(feats, axis=-1, keepdims=True), 1e-8
        )

    n_train, n_test, seq = 1024, 256, 64
    toks, labels = styled_corpus(cfg.vocab, n_train + n_test, seq, seed=0)
    toks_tr, y_tr = toks[:n_train], labels[:n_train]
    toks_te, y_te = toks[n_train:], labels[n_train:]

    # streaming-compatible centering: estimate the feature mean from the
    # FIRST chunk only (O(d) state, no second pass), freeze it thereafter
    zero = jnp.zeros((2 * cfg.d_model,), jnp.float32)
    first = embed_docs(params, jnp.asarray(toks_tr[:128]), zero)
    center = jnp.mean(first, axis=0)

    # stream: embed a chunk of docs -> feed the one-pass SVM -> discard
    def chunks():
        B = 128
        for lo in range(0, n_train, B):
            feats = embed_docs(params, jnp.asarray(toks_tr[lo : lo + B]), center)
            yield feats, jnp.asarray(y_tr[lo : lo + B])

    t0 = time.time()
    out: StreamCheckpoint = fit_chunked(chunks(), c=10.0, lookahead=10)
    t = time.time() - t0

    feats_te = embed_docs(params, jnp.asarray(toks_te), center)
    acc = float(accuracy(out.ball, feats_te, jnp.asarray(y_te))) * 100
    print(f"one-pass StreamSVM head on {n_train} streamed docs: "
          f"test acc {acc:.1f}%  ({t:.2f}s, state={out.ball.w.nbytes + 12} bytes, "
          f"core vectors {int(out.ball.m)})")


if __name__ == "__main__":
    main()
