"""Train -> checkpoint -> serve: the quickstart's 600-model bank end to end.

    PYTHONPATH=src python examples/serve_bank.py

One pass of the tiled engine fits a 200-class OVR x 3-point C-grid (600
models) through the chunked streaming driver, the checkpoint callback
persists the bank (state O(B * D) — the paper's constant-storage claim),
and ``BankServer.from_checkpoint`` serves it: ragged query batches are
microbatched into fixed (q_block,) row slots and scored by the fused Pallas
predict kernel (per-C-grid-group argmax epilogue). Served f32 results are
BIT-EXACT with the direct jnp readout (core.predict_c_grid) — asserted
below, not just printed.

Serving throughput numbers for this path are tracked in BENCH_serving.json:

    PYTHONPATH=src python benchmarks/serving_throughput.py
"""
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import fit_chunked_many, ovr_signs, predict_c_grid
from repro.serve import BankServer


def make_blobs(n, n_classes, d, seed, proto_seed=0):
    proto = (
        np.random.default_rng(proto_seed).normal(size=(n_classes, d)) * 3
    ).astype(np.float32)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n)
    X = (rng.normal(size=(n, d)) + proto[labels]).astype(np.float32)
    X /= np.linalg.norm(X, axis=1, keepdims=True)
    return X, labels


def main():
    n_classes, c_pts, d = 200, (1.0, 10.0, 100.0), 64
    Xtr, ytr = make_blobs(2000, n_classes, d, seed=0)
    Xte, yte = make_blobs(600, n_classes, d, seed=1)

    # --- train: one stream pass over chunks, bank checkpointed ------------
    signs = ovr_signs(jnp.asarray(ytr), n_classes)  # (200, N)
    Y = jnp.tile(signs, (len(c_pts), 1))  # (600, N): class-major per C point
    cs = jnp.repeat(jnp.asarray(c_pts, jnp.float32), n_classes)  # (600,)
    chunks = [
        (Xtr[lo : lo + 500], Y[:, lo : lo + 500])
        for lo in range(0, len(Xtr), 500)
    ]
    t0 = time.perf_counter()
    result = fit_chunked_many(chunks, cs, b_tile=64, stream_dtype="bf16")
    t_fit = time.perf_counter() - t0
    bank = result.ball
    print(
        f"fit: {bank.w.shape[0]} models, ONE {result.position}-row stream "
        f"pass in {t_fit*1e3:.0f} ms (interpret mode); bank state "
        f"O(B*D) = {bank.w.nbytes} bytes"
    )

    with tempfile.TemporaryDirectory() as td:
        ckpt.save(
            td, bank,
            meta={"position": result.position, "n_classes": n_classes},
        )

        # --- serve: checkpoint -> BankServer, ragged batches -> slots -----
        server = BankServer.from_checkpoint(
            td, epilogue="ovr", q_block=256, b_tile=200
        )
        print(
            f"serving bank {server.bank_shape} from checkpoint "
            f"(n_classes={server.n_classes} via checkpoint meta)"
        )
        rng = np.random.default_rng(7)
        reqs, lo = [], 0
        while lo < len(Xte):  # ragged client batches, FIFO-packed into slots
            n = int(rng.integers(1, 200))
            reqs.append(server.submit(Xte[lo : lo + n]))
            lo += n
        t0 = time.perf_counter()
        stats = server.run()
        t_serve = time.perf_counter() - t0

    cls = np.concatenate([r.result[0] for r in reqs])
    margin = np.concatenate([r.result[1] for r in reqs])

    # --- served == direct readout, bit for bit ----------------------------
    rcls, rmargin = predict_c_grid(bank, jnp.asarray(Xte), n_classes)
    assert np.array_equal(cls, np.asarray(rcls)), "served class ids diverged"
    assert np.array_equal(margin, np.asarray(rmargin)), "served margins diverged"
    print(
        f"served {len(Xte)} queries x {bank.w.shape[0]} models in "
        f"{stats.steps} microbatches ({t_serve*1e3:.0f} ms, "
        f"{len(Xte)/t_serve:.0f} queries/s, slot utilization "
        f"{stats.utilization:.1%}); served f32 scores BIT-EXACT with "
        "core.predict_c_grid"
    )
    for g, cval in enumerate(c_pts):
        acc = float(np.mean(cls[:, g] == yte))
        print(f"  C={cval:6.1f}  served held-out acc={100*acc:5.1f}%")
    # (absolute accuracy at 200-way extreme-imbalance OVR is Algorithm 1's
    # known stress case — see the quickstart note; chance is 0.5% — the
    # serving claim is the exact parity asserted above)

    # --- hot swap: re-fit continues, serving never drops a request --------
    more_chunks = [(Xte[:500], jnp.tile(ovr_signs(jnp.asarray(yte[:500]),
                                                  n_classes), (len(c_pts), 1)))]
    result2 = fit_chunked_many(more_chunks, cs, resume=result, b_tile=64,
                               stream_dtype="bf16")
    for lo in range(0, 256, 64):
        server.submit(Xte[lo : lo + 64])
    server.step()  # first 256 rows score against the OLD bank
    server.swap_bank(result2.ball)  # queued requests survive the swap
    server.run()
    print(
        f"hot-swapped to the {result2.position}-row bank mid-stream "
        f"({server.stats.bank_swaps} swap, {server.stats.finished} requests "
        "finished, none dropped)"
    )


if __name__ == "__main__":
    main()
