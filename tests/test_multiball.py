"""Multi-ball StreamSVM (paper Sec 4.3 general case) invariants."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import fit
from repro.core.multiball import decision_function, fit_multiball, to_single_ball


def _data(n=1500, d=8, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = np.sign(rng.normal(size=n) + 1.5 * X[:, 0]).astype(np.float32)
    y[y == 0] = 1
    X /= np.linalg.norm(X, axis=1, keepdims=True)
    return jnp.asarray(X), jnp.asarray(y)


def test_multiball_L1_equals_algo1():
    X, y = _data()
    mb = fit_multiball(X, y, 10.0, n_balls=1)
    b = fit(X, y, 10.0)
    np.testing.assert_allclose(np.asarray(mb.w[0]), np.asarray(b.w), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(mb.r[0]), float(b.r), rtol=1e-5)
    assert int(mb.m[0]) == int(b.m)


def test_multiball_counts_and_activity():
    X, y = _data(seed=1)
    for L in (2, 4):
        mb = fit_multiball(X, y, 10.0, n_balls=L)
        assert bool(mb.active[0])  # first ball always opened
        # every absorbed point is counted exactly once across balls
        assert int(jnp.sum(jnp.where(mb.active, mb.m, 0))) >= 1
        merged = to_single_ball(mb)
        assert np.isfinite(float(merged.r))
        # merged ball encloses each active component ball
        for i in range(L):
            if bool(mb.active[i]):
                assert float(mb.r[i]) <= float(merged.r) + 1e-4


def test_multiball_classifies():
    X, y = _data(seed=2)
    mb = fit_multiball(X, y, 10.0, n_balls=4)
    acc = float(jnp.mean(jnp.sign(decision_function(mb, X)) == y))
    assert acc > 0.6  # above chance; quality is benchmarked, not unit-tested
