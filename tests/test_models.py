"""Per-architecture smoke tests (reduced configs) + numerical equivalences."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model
from repro.models.layers import flash_attention
from repro.models.mamba2 import ssd_chunked
from repro.models.xlstm import mlstm_parallel, mlstm_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=64):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)) * 0.02, jnp.bfloat16
        )
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)) * 0.1, jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/backward step on CPU; shapes + no NaNs."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)

    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg, B=2, S=32)
    pf = dict(batch)
    pf.pop("targets")
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, {**b, "max_len": 40})
    )(params, pf)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    logits2, cache = jax.jit(model.decode_step)(
        params, cache, jnp.ones((2, 1), jnp.int32)
    )
    assert logits2.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma3-27b", "whisper-base"])
def test_prefill_decode_consistency(arch):
    """Teacher forcing: decode(t) after prefill(0..t-1) == full forward."""
    cfg = get_config(arch, smoke=True)
    # use f32 params for a tight comparison
    cfg = type(cfg)(**{**cfg.__dict__, "param_dtype": "float32", "act_dtype": "float32"})
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg, B=1, S=16)
    tokens = batch["tokens"]

    pf = dict(batch)
    pf.pop("targets")
    pf["max_len"] = 17
    pf["tokens"] = tokens[:, :15]
    logits_pf, cache = model.prefill(params, pf)
    logits_dec, _ = model.decode_step(params, cache, tokens[:, 15:16])

    pf2 = dict(pf)
    pf2["tokens"] = tokens
    pf2["max_len"] = 17
    logits_full, _ = model.prefill(params, pf2)  # last-position logits
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_flash_attention_vs_naive_gqa_window():
    B, S, H, KV, hd = 2, 96, 8, 4, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    for window in (None, 17):
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / np.sqrt(hd)
        rel = jnp.arange(S)[:, None] - jnp.arange(S)[None, :]
        mask = jnp.where(rel < 0, -1e30, 0.0)
        if window:
            mask = jnp.where(rel >= window, -1e30, mask)
        p = jax.nn.softmax(s + mask, -1)
        ref = jnp.moveaxis(
            jnp.einsum("bkgqs,bskd->bkgqd", p, v).reshape(B, KV, G, S, hd), 3, 1
        ).reshape(B, S, H, hd)
        out = flash_attention(q, k, v, causal=True, window=window, block_kv=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_ssd_chunked_vs_sequential():
    b, s, h, p, n = 2, 64, 4, 16, 8
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    Bm = jax.random.normal(ks[2], (b, s, n))
    Cm = jax.random.normal(ks[3], (b, s, n))
    A_log = jnp.zeros((h,))
    y1, st1 = ssd_chunked(x, dt, A_log, Bm, Cm, chunk=16)
    A = -jnp.exp(A_log)
    st = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        dA = jnp.exp(dt[:, t] * A)
        st = st * dA[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", x[:, t] * dt[:, t][..., None], Bm[:, t]
        )
        ys.append(jnp.einsum("bhpn,bn->bhp", st, Cm[:, t]))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(jnp.stack(ys, 1)), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st), rtol=1e-4, atol=1e-4)


def test_mlstm_parallel_vs_recurrent():
    B, S, H, hd = 2, 24, 4, 8
    ks = jax.random.split(KEY, 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, hd)) for i in range(3))
    i_pre = jax.random.normal(ks[3], (B, S, H))
    f_pre = jax.random.normal(ks[4], (B, S, H)) + 2.0
    yp = mlstm_parallel(q, k, v, i_pre, f_pre)
    st = (jnp.zeros((B, H, hd, hd)), jnp.zeros((B, H, hd)), jnp.zeros((B, H)))
    ys = []
    for t in range(S):
        yt, st = mlstm_step(st, q[:, t], k[:, t], v[:, t], i_pre[:, t], f_pre[:, t])
        ys.append(yt)
    np.testing.assert_allclose(
        np.asarray(yp), np.asarray(jnp.stack(ys, 1)), rtol=5e-4, atol=5e-4
    )


def test_moe_routes_and_balances():
    from repro.models.moe import moe_apply, moe_init
    from repro.configs.base import MoECfg

    mcfg = MoECfg(n_experts=8, top_k=2, d_ff=32)
    p = moe_init(KEY, 16, mcfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    out, aux = moe_apply(p, x, mcfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert 0.5 < float(aux) < 8.0  # ~1 at perfect balance
