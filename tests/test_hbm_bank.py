"""HBM-resident double-buffered bank: parity, policy, preflight, recompiles.

The HBM layout re-routes the bank (plus state and lookahead windows) through
ANY-space buffers and a 2-slot VMEM ring, but shares the per-(block x tile)
compute core with the VMEM layout — so it must be BIT-EXACT (f32) with it
across every ring regime (J = 1, 2 resident tiles; J odd/even cycling),
ragged banks, bf16 stream tiles and fused lookahead. The "auto" policy must
flip residency exactly at the VMEM-budget boundary, impossible configs must
die in the ops.py preflight with the byte breakdown (never inside Pallas
lowering — and never silently under ``python -O``), and a residency switch
must recompile while a C sweep must not.
"""
import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import fit_bank, fit_ovr, ovr_signs
from repro.kernels import streamsvm_fit_many
from repro.kernels.ops import (
    DEFAULT_VMEM_BUDGET_BYTES,
    engine_vmem_bytes,
    predict_vmem_bytes,
    resolve_bank_resident,
    vmem_budget_bytes,
)
from repro.kernels.ref import streamsvm_scan_many_ref


def _bank_data(b, n, d, seed):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    Y = jnp.asarray(np.sign(rng.normal(size=(b, n))).astype(np.float32))
    cs = jnp.asarray(np.exp(rng.uniform(-1, 4, size=b)).astype(np.float32))
    return X, Y, cs


def _assert_banks_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
    np.testing.assert_array_equal(np.asarray(a.r), np.asarray(b.r))
    np.testing.assert_array_equal(np.asarray(a.xi2), np.asarray(b.xi2))
    np.testing.assert_array_equal(np.asarray(a.m), np.asarray(b.m))


# ---------------------------------------------------------------------------
# Tentpole: hbm == vmem, bit for bit, across every ring regime
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,n,d,block_n,b_tile", [
    (8, 300, 20, 64, 8),       # J=1: nothing cycles (load once / store once)
    (16, 300, 20, 64, 8),      # J=2: slot-pinned tiles, still no cycling
    (24, 384, 24, 128, 8),     # J=3: odd tile count cycling through 2 slots
    (64, 300, 20, 64, 8),      # J=8: steady-state ring over 5 data blocks
    (11, 257, 33, 64, 8),      # ragged B % b_tile != 0 (padded inert lanes)
    (13, 300, 20, 64, 3),      # b_tile not a multiple of 8 (rounded up)
    (40, 128, 40, 256, 8),     # single data block: prefetch chain only
])
def test_hbm_bit_exact_with_vmem(b, n, d, block_n, b_tile):
    """The residency switch must not change a single bit of f32 output."""
    X, Y, cs = _bank_data(b, n, d, seed=b * n + d)
    kw = dict(block_n=block_n, b_tile=b_tile)
    vmem = streamsvm_fit_many(X, Y, cs, bank_resident="vmem", **kw)
    hbm = streamsvm_fit_many(X, Y, cs, bank_resident="hbm", **kw)
    _assert_banks_equal(hbm, vmem)
    assert np.isfinite(np.asarray(hbm.w)).all()


@pytest.mark.parametrize("lookahead", [2, 5, (3, 1, 7, 2) * 6])
def test_hbm_lookahead_bit_exact_with_vmem(lookahead):
    """Fused Algorithm 2: the (B*L, D) windows ride the same ring — per-model
    L, window state crossing block AND tile boundaries, boundary flush."""
    b, n, d = 24, 333, 20
    X, Y, cs = _bank_data(b, n, d, seed=7)
    kw = dict(variant="lookahead", lookahead=lookahead, block_n=64, b_tile=8)
    vmem = streamsvm_fit_many(X, Y, cs, bank_resident="vmem", **kw)
    hbm = streamsvm_fit_many(X, Y, cs, bank_resident="hbm", **kw)
    _assert_banks_equal(hbm, vmem)


def test_hbm_bf16_stream_tiles_bit_exact_with_vmem():
    """bf16 stream tiles: rounding must be identical in both residencies
    (the ring carries the f32 bank; only BlockSpec'd stream tiles are bf16)."""
    b, n, d = 24, 300, 24
    X, Y, cs = _bank_data(b, n, d, seed=11)
    kw = dict(block_n=64, b_tile=8, stream_dtype="bf16")
    vmem = streamsvm_fit_many(X, Y, cs, bank_resident="vmem", **kw)
    hbm = streamsvm_fit_many(X, Y, cs, bank_resident="hbm", **kw)
    _assert_banks_equal(hbm, vmem)


def test_hbm_matches_bank_oracle():
    """Not just self-consistency: the hbm path against the pure-jnp oracle."""
    b, n, d = 32, 400, 24
    X, Y, cs = _bank_data(b, n, d, seed=17)
    bank = streamsvm_fit_many(
        X, Y, cs, block_n=128, b_tile=8, bank_resident="hbm"
    )
    c_inv = 1.0 / cs
    W0 = Y[:, 0:1] * X[0][None, :]
    w, r, xi2, m = streamsvm_scan_many_ref(
        X[1:], Y[:, 1:], W0, 0.0, c_inv, c_inv, 1, gain=c_inv
    )
    np.testing.assert_allclose(
        np.asarray(bank.w), np.asarray(w), rtol=2e-4, atol=2e-5
    )
    np.testing.assert_array_equal(np.asarray(bank.m), np.asarray(m))


def test_hbm_continue_from_bank_and_wrappers():
    """fit_bank continue-from-bank and fit_ovr route residency through."""
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.normal(size=(220, 16)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 6, size=220))
    o_v = fit_ovr(X, labels, 6, 10.0, b_tile=8, bank_resident="vmem")
    o_h = fit_ovr(X, labels, 6, 10.0, b_tile=8, bank_resident="hbm")
    np.testing.assert_array_equal(np.asarray(o_h.w), np.asarray(o_v.w))
    ys = ovr_signs(labels, 6)
    half_h = fit_bank(X[:100], ys[:, :100], 10.0, b_tile=8,
                      bank_resident="hbm")
    cont_h = fit_bank(X[100:], ys[:, 100:], 10.0, half_h, b_tile=8,
                      bank_resident="hbm")
    half_v = fit_bank(X[:100], ys[:, :100], 10.0, b_tile=8,
                      bank_resident="vmem")
    cont_v = fit_bank(X[100:], ys[:, 100:], 10.0, half_v, b_tile=8,
                      bank_resident="vmem")
    _assert_banks_equal(cont_h, cont_v)


# ---------------------------------------------------------------------------
# The "auto" policy: routing at the budget boundary
# ---------------------------------------------------------------------------


def test_auto_routes_at_budget_boundary():
    """auto == vmem exactly AT the vmem working-set total, hbm one byte under.

    B = 8 * b_tile so the full-bank vmem scratch strictly exceeds the 2-slot
    ring and the boundary separates the two regimes."""
    model = lambda res: engine_vmem_bytes(
        64, 64, block_n=128, b_tile=8, bank_resident=res
    )
    total = sum(model("vmem").values())
    res, by = resolve_bank_resident(
        "auto", model, vmem_budget=total, what="t", shapes="s"
    )
    assert res == "vmem" and by == model("vmem")
    res, by = resolve_bank_resident(
        "auto", model, vmem_budget=total - 1, what="t", shapes="s"
    )
    assert res == "hbm" and by == model("hbm")


def test_auto_hbm_routing_is_bit_exact_end_to_end():
    """A budget too small for the vmem working set must silently route auto
    to hbm and produce the identical bank."""
    b, n, d = 24, 300, 20
    X, Y, cs = _bank_data(b, n, d, seed=23)
    vmem = streamsvm_fit_many(X, Y, cs, block_n=64, b_tile=8,
                              bank_resident="vmem")
    model = lambda res: engine_vmem_bytes(
        b, d, block_n=64, b_tile=8, bank_resident=res
    )
    squeeze = sum(model("vmem").values()) - 1
    assert sum(model("hbm").values()) <= squeeze  # hbm fits where vmem won't
    auto = streamsvm_fit_many(X, Y, cs, block_n=64, b_tile=8,
                              bank_resident="auto",
                              vmem_budget_bytes=squeeze)
    _assert_banks_equal(auto, vmem)


def test_auto_derives_ring_tile_when_none_given():
    """With the default b_tile=None, an over-budget bank must still train:
    auto/hbm derive a budget-fitting ring tile instead of trying to ring the
    whole bank (which would be twice the bank per step) — so the ROADMAP's
    "auto picks this for you" holds without hand-picking a tile."""
    b, n, d = 64, 256, 64
    X, Y, cs = _bank_data(b, n, d, seed=29)
    ref = streamsvm_fit_many(X, Y, cs, block_n=64, bank_resident="vmem")
    # budget fits the stream tiles + a small ring but NOT the whole bank:
    model = lambda res, bt: engine_vmem_bytes(
        b, d, block_n=64, b_tile=bt, bank_resident=res
    )
    squeeze = sum(model("hbm", 8).values()) + 1
    assert sum(model("vmem", None).values()) > squeeze
    assert sum(model("hbm", None).values()) > squeeze  # whole-bank ring: no
    for residency in ("auto", "hbm"):
        got = streamsvm_fit_many(X, Y, cs, block_n=64,
                                 bank_resident=residency,
                                 vmem_budget_bytes=squeeze)
        _assert_banks_equal(got, ref)
    # serving twin: same derivation on the predict side
    from repro.kernels import predict_bank

    Xq = X[:40]
    base = predict_bank(Xq, ref.w, q_block=64)
    got = predict_bank(Xq, ref.w, q_block=64, bank_resident="hbm",
                       vmem_budget_bytes=squeeze)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_vmem_budget_resolution_order():
    """Explicit override > REPRO_VMEM_BUDGET_BYTES env > default."""
    assert vmem_budget_bytes(123) == 123
    old = os.environ.get("REPRO_VMEM_BUDGET_BYTES")
    try:
        os.environ["REPRO_VMEM_BUDGET_BYTES"] = "456"
        assert vmem_budget_bytes() == 456
        assert vmem_budget_bytes(123) == 123
        del os.environ["REPRO_VMEM_BUDGET_BYTES"]
        assert vmem_budget_bytes() == DEFAULT_VMEM_BUDGET_BYTES
    finally:
        if old is not None:
            os.environ["REPRO_VMEM_BUDGET_BYTES"] = old
        else:
            os.environ.pop("REPRO_VMEM_BUDGET_BYTES", None)


def test_byte_model_scales_like_the_layouts():
    """vmem's working set grows with B; hbm's is B-independent (ring only)."""
    v64 = sum(engine_vmem_bytes(64, 128, b_tile=8,
                                bank_resident="vmem").values())
    v512 = sum(engine_vmem_bytes(512, 128, b_tile=8,
                                 bank_resident="vmem").values())
    h64 = sum(engine_vmem_bytes(64, 128, b_tile=8,
                                bank_resident="hbm").values())
    h512 = sum(engine_vmem_bytes(512, 128, b_tile=8,
                                 bank_resident="hbm").values())
    assert v512 > v64
    assert h512 == h64
    # lookahead windows dominate both models when L is large
    vl = engine_vmem_bytes(64, 128, b_tile=8, lookahead_max=16,
                           bank_resident="vmem")
    assert vl["lookahead"] > vl["bank"]
    # predict: the serving working set never contains the full bank
    p64 = sum(predict_vmem_bytes(64, 128, b_tile=8).values())
    p4096 = sum(predict_vmem_bytes(4096, 128, b_tile=8).values())
    assert p4096 == p64


# ---------------------------------------------------------------------------
# Preflight: impossible configs die in ops.py with the byte breakdown
# ---------------------------------------------------------------------------


def test_forced_vmem_beyond_budget_raises_with_breakdown():
    b, n, d = 16, 128, 64
    X, Y, cs = _bank_data(b, n, d, seed=1)
    with pytest.raises(ValueError) as ei:
        streamsvm_fit_many(X, Y, cs, block_n=128, b_tile=8,
                           bank_resident="vmem", vmem_budget_bytes=10_000)
    msg = str(ei.value)
    assert "breakdown" in msg and "bank_resident='vmem'" in msg
    assert f"B={b}" in msg and f"D={d}" in msg and "10000" in msg
    assert "hbm" in msg  # the error tells you the way out


def test_no_residency_fits_raises():
    b, n, d = 16, 128, 64
    X, Y, cs = _bank_data(b, n, d, seed=2)
    with pytest.raises(ValueError, match="shrink"):
        streamsvm_fit_many(X, Y, cs, block_n=128, b_tile=8,
                           bank_resident="hbm", vmem_budget_bytes=1_000)
    with pytest.raises(ValueError, match="shrink"):
        streamsvm_fit_many(X, Y, cs, block_n=128, b_tile=8,
                           bank_resident="auto", vmem_budget_bytes=1_000)


def test_unknown_residency_raises():
    X, Y, cs = _bank_data(8, 64, 16, seed=3)
    with pytest.raises(ValueError, match="bank_resident"):
        streamsvm_fit_many(X, Y, cs, bank_resident="sram")


@pytest.mark.slow
def test_vmem_preflight_error_survives_python_O():
    """The preflight must be a ValueError (not a bare assert) so `python -O`
    cannot strip it — a VMEM-overflowing bank must never reach Pallas
    lowering's opaque failure."""
    script = r"""
import numpy as np, jax.numpy as jnp
from repro.kernels import streamsvm_fit_many
X = jnp.zeros((128, 64), jnp.float32)
Y = jnp.ones((16, 128), jnp.float32)
cs = jnp.full((16,), 10.0, jnp.float32)
try:
    streamsvm_fit_many(X, Y, cs, block_n=128, b_tile=8,
                       bank_resident="vmem", vmem_budget_bytes=10_000)
except ValueError as e:
    msg = str(e)
    assert "breakdown" in msg and "B=16" in msg and "D=64" in msg, msg
    print("VALUE_ERROR_OK")
else:
    raise SystemExit("oversized vmem bank was accepted")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-O", "-c", script],
        env=env, capture_output=True, text=True, timeout=300,
    )
    assert out.returncode == 0, (
        f"stdout:{out.stdout[-2000:]}\nstderr:{out.stderr[-4000:]}"
    )
    assert "VALUE_ERROR_OK" in out.stdout


# ---------------------------------------------------------------------------
# Compile-cache regression: residency is static, C stays traced
# ---------------------------------------------------------------------------


def test_residency_switch_recompiles_c_sweep_does_not():
    b, n, d = 16, 128, 16
    X, Y, _ = _bank_data(b, n, d, seed=5)
    start = streamsvm_fit_many._cache_size()
    for c in (1.0, 10.0, 100.0):  # C sweep inside hbm: ONE entry
        streamsvm_fit_many(X, Y, jnp.full((b,), c), block_n=64, b_tile=8,
                           bank_resident="hbm")
    assert streamsvm_fit_many._cache_size() == start + 1
    streamsvm_fit_many(X, Y, jnp.full((b,), 1.0), block_n=64, b_tile=8,
                       bank_resident="vmem")  # residency switch: new entry
    assert streamsvm_fit_many._cache_size() == start + 2
