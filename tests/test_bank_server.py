"""BankServer: golden train->serve handoff + scheduler semantics.

The golden test pins the whole deploy path — fit_bank -> checkpoint ->
BankServer.from_checkpoint -> held-out accuracy — EXACTLY (f32) against the
direct core.predict_ovr / predict_c_grid readouts. The scheduler tests pin
microbatch packing, slot-utilization accounting, and mid-stream bank
hot-swap (queued requests survive, old rows keep old results, no recompile).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import ckpt
from repro.core import (
    fit_bank,
    fit_chunked_many,
    ovr_signs,
    predict_c_grid,
    predict_ovr,
)
from repro.kernels import predict_bank
from repro.serve import BankServer


def _blobs(n, n_classes, d, seed, proto_seed=0):
    """Class-blob samples; a fixed proto_seed shares prototypes across
    train/test splits (different ``seed`` -> held-out draw, same classes)."""
    proto = (
        np.random.default_rng(proto_seed).normal(size=(n_classes, d)) * 3
    ).astype(np.float32)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n)
    X = (rng.normal(size=(n, d)) + proto[labels]).astype(np.float32)
    X /= np.linalg.norm(X, axis=1, keepdims=True)
    return X, labels


def _jnp_scores(queries: np.ndarray, W) -> np.ndarray:
    """The direct jnp readout the served scores must match bitwise (numpy's
    own matmul may differ in the last ulp — the contract is vs jnp)."""
    return np.asarray(jnp.asarray(queries) @ jnp.asarray(W).T)


# ---------------------------------------------------------------------------
# Golden end-to-end: train -> checkpoint -> serve == direct readout, exactly
# ---------------------------------------------------------------------------


def test_served_ovr_matches_direct_readout_exactly(tmp_path):
    """fit_chunked_many -> ckpt.save -> from_checkpoint -> score: the served
    class ids and f32 margins must equal core.predict_c_grid bit for bit,
    and the single-group slice must equal core.predict_ovr."""
    n_classes, c_pts, d = 5, (1.0, 10.0, 100.0), 24
    Xtr, ytr = _blobs(600, n_classes, d, seed=10)
    Xte, yte = _blobs(200, n_classes, d, seed=11)
    signs = ovr_signs(jnp.asarray(ytr), n_classes)
    Y = jnp.tile(signs, (len(c_pts), 1))  # (30, N), class-major per C point
    cs = jnp.repeat(jnp.asarray(c_pts, jnp.float32), n_classes)

    # the train->serve handoff object: a fit_chunked_many checkpoint
    chunks = [
        (Xtr[lo : lo + 200], Y[:, lo : lo + 200]) for lo in range(0, 600, 200)
    ]
    result = fit_chunked_many(chunks, cs, b_tile=8)
    assert result.position == 600
    path = str(tmp_path / "bank")
    ckpt.save(
        path, result.ball,
        meta={"position": result.position, "n_classes": n_classes},
    )

    server = BankServer.from_checkpoint(
        path, epilogue="ovr", q_block=64, b_tile=32
    )
    assert server.n_classes == n_classes  # picked up from checkpoint meta
    cls, margin = server.score(Xte)

    bank = result.ball
    rcls, rmargin = predict_c_grid(bank, jnp.asarray(Xte), n_classes)
    np.testing.assert_array_equal(cls, np.asarray(rcls))
    np.testing.assert_array_equal(margin, np.asarray(rmargin))

    # per-C-point accuracy identical to the direct readout, and the grid's
    # best C point actually classifies (the reason the grid is served)
    accs = []
    for g in range(len(c_pts)):
        acc = float(np.mean(cls[:, g] == yte))
        assert acc == float(np.mean(np.asarray(rcls)[:, g] == yte))
        accs.append(acc)
    assert max(accs) > 0.9, accs

    # single-group slice == predict_ovr on the sliced bank
    one = jax.tree.map(lambda v: v[:n_classes], bank)
    np.testing.assert_array_equal(
        cls[:, 0], np.asarray(predict_ovr(one, jnp.asarray(Xte)))
    )


def test_served_scores_bit_exact_with_matmul():
    X, y = _blobs(150, 4, 16, seed=2)
    bank = fit_bank(jnp.asarray(X), ovr_signs(jnp.asarray(y), 4), 10.0)
    server = BankServer(bank, q_block=64)
    out = server.score(X)
    np.testing.assert_array_equal(
        out, np.asarray(jnp.asarray(X) @ bank.w.T)
    )


def test_topk_serving_matches_ref():
    rng = np.random.default_rng(3)
    W = rng.normal(size=(20, 12)).astype(np.float32)
    X = rng.normal(size=(90, 12)).astype(np.float32)
    server = BankServer(W, epilogue="topk", k=3, q_block=32)
    vals, ids = server.score(X)
    rv, ri = jax.lax.top_k(jnp.asarray(X) @ jnp.asarray(W).T, 3)
    np.testing.assert_array_equal(vals, np.asarray(rv))
    np.testing.assert_array_equal(ids, np.asarray(ri).astype(np.int32))


# ---------------------------------------------------------------------------
# Scheduler semantics: packing, admission, utilization
# ---------------------------------------------------------------------------


def test_step_packs_ragged_requests_into_slots():
    """Several small requests share one microbatch; a large one spans
    several. Steps = ceil(total_rows / q_block) regardless of the split."""
    rng = np.random.default_rng(4)
    W = rng.normal(size=(8, 8)).astype(np.float32)
    server = BankServer(W, q_block=16)
    sizes = [5, 3, 16, 9, 40, 1]  # 74 rows -> ceil(74/16) = 5 steps
    reqs = [server.submit(rng.normal(size=(n, 8)).astype(np.float32))
            for n in sizes]
    stats = server.run()
    assert stats.steps == 5
    assert stats.finished == len(sizes)
    assert stats.slot_busy_rows == sum(sizes)
    assert stats.slot_idle_rows == 5 * 16 - sum(sizes)
    assert stats.utilization == sum(sizes) / (5 * 16)
    for r in reqs:
        assert r.done
        np.testing.assert_array_equal(r.result, _jnp_scores(r.queries, W))


def test_admission_under_full_slots():
    """One step scores exactly q_block rows; the overflow stays queued (not
    dropped, not scored early)."""
    rng = np.random.default_rng(5)
    W = rng.normal(size=(8, 8)).astype(np.float32)
    server = BankServer(W, q_block=8)
    big = server.submit(rng.normal(size=(13, 8)).astype(np.float32))
    small = server.submit(rng.normal(size=(4, 8)).astype(np.float32))
    assert server.pending_rows() == 17
    assert server.step() == 8  # the slots fill from the FIFO head only
    assert big.rows_scored == 8 and not big.done
    assert small.rows_scored == 0 and not small.done
    assert server.pending_rows() == 9
    assert server.step() == 8  # big's tail (5) + small fully (4) wait... 5+4=9 -> 8
    assert big.done
    server.run()
    assert small.done
    np.testing.assert_array_equal(big.result, _jnp_scores(big.queries, W))
    np.testing.assert_array_equal(small.result, _jnp_scores(small.queries, W))


def test_run_raises_when_max_steps_cannot_drain():
    """Exhausting max_steps with rows pending must raise — returning would
    hand back requests whose result rows were never written."""
    rng = np.random.default_rng(9)
    W = rng.normal(size=(8, 8)).astype(np.float32)
    server = BankServer(W, q_block=4)
    req = server.submit(rng.normal(size=(12, 8)).astype(np.float32))
    with pytest.raises(RuntimeError, match="max_steps"):
        server.run(max_steps=2)
    assert not req.done
    server.run()  # plenty of steps: drains fine
    assert req.done
    np.testing.assert_array_equal(req.result, _jnp_scores(req.queries, W))


def test_empty_request_finishes_immediately():
    W = np.eye(4, dtype=np.float32)
    server = BankServer(W, q_block=8)
    req = server.submit(np.zeros((0, 4), np.float32))
    assert req.done and server.pending_rows() == 0
    assert req.result.shape == (0, 4)


# ---------------------------------------------------------------------------
# Hot swap: queued requests survive, row provenance is exact, no recompile
# ---------------------------------------------------------------------------


def test_hot_swap_mid_stream_correctness():
    """Rows scored before the swap carry bank A's scores, rows after carry
    bank B's — including the two halves of ONE request split by the swap —
    and nothing queued is dropped."""
    rng = np.random.default_rng(6)
    A = rng.normal(size=(6, 8)).astype(np.float32)
    B = rng.normal(size=(6, 8)).astype(np.float32)
    server = BankServer(A, q_block=8)
    r1 = server.submit(rng.normal(size=(8, 8)).astype(np.float32))
    r2 = server.submit(rng.normal(size=(12, 8)).astype(np.float32))
    server.step()  # r1 fully scored against A
    assert r1.done and not r2.done
    server.step()  # r2 rows [0, 8) against A
    assert r2.rows_scored == 8
    server.swap_bank(B)
    stats = server.run()  # r2 rows [8, 12) against B
    assert r2.done and stats.bank_swaps == 1
    np.testing.assert_array_equal(r1.result, _jnp_scores(r1.queries, A))
    np.testing.assert_array_equal(r2.result[:8], _jnp_scores(r2.queries[:8], A))
    np.testing.assert_array_equal(r2.result[8:], _jnp_scores(r2.queries[8:], B))


def test_hot_swap_same_shape_never_recompiles():
    rng = np.random.default_rng(7)
    server = BankServer(rng.normal(size=(8, 8)).astype(np.float32), q_block=8)
    server.score(rng.normal(size=(3, 8)).astype(np.float32))  # compile once
    start = predict_bank._cache_size()
    for seed in range(3):
        server.swap_bank(
            np.random.default_rng(seed).normal(size=(8, 8)).astype(np.float32)
        )
        server.score(rng.normal(size=(3, 8)).astype(np.float32))
    assert predict_bank._cache_size() == start  # swaps reused the jit entry


def test_swap_and_submit_validate_shapes():
    rng = np.random.default_rng(8)
    server = BankServer(rng.normal(size=(6, 8)).astype(np.float32), q_block=8)
    with pytest.raises(ValueError, match="hot-swap"):
        server.swap_bank(rng.normal(size=(6, 10)).astype(np.float32))
    with pytest.raises(ValueError, match=r"\(n, D=8\)"):
        server.submit(rng.normal(size=(4, 5)).astype(np.float32))
    with pytest.raises(ValueError, match="n_classes"):
        BankServer(rng.normal(size=(6, 8)).astype(np.float32), epilogue="ovr",
                   n_classes=4)
    with pytest.raises(ValueError, match="k="):
        BankServer(rng.normal(size=(6, 8)).astype(np.float32),
                   epilogue="topk", k=9)
    with pytest.raises(ValueError, match="epilogue"):
        BankServer(rng.normal(size=(6, 8)).astype(np.float32),
                   epilogue="softmax")


def test_from_checkpoint_rejects_non_bank_trees(tmp_path):
    path = str(tmp_path / "notabank")
    ckpt.save(path, {"a": jnp.zeros((3,)), "b": jnp.ones((2, 2))})
    with pytest.raises(ValueError, match="4-leaf"):
        BankServer.from_checkpoint(path)
