"""Core StreamSVM correctness: oracle equivalence, geometry invariants,
kernelized/linear agreement, lookahead behavior, streaming resume.

Deterministic throughout — randomized property versions of the oracle and QP
checks live in test_core_streamsvm_properties.py behind the OPTIONAL
`hypothesis` test dependency (pytest.importorskip), so this module collects
and runs everywhere.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    accuracy,
    fit,
    fit_ball,
    fit_chunked,
    fit_kernelized,
    fit_lookahead,
    fit_ovr,
    init_ball,
    linear_weights,
    merge_balls,
    fold_merge,
    point_distance,
    predict_ovr,
    solve_meb_ball_points,
)
from repro.core.meb import Ball, make_ball
from repro.core.oracle import fit_explicit
from repro.data.stream import chunk_stream


def _data(n, d, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(dtype)
    y = np.sign(rng.normal(size=n) + X[:, 0]).astype(dtype)
    y[y == 0] = 1
    return X, y


@pytest.mark.parametrize("n,d,c,seed", [
    (20, 1, 0.1, 11),
    (57, 3, 1.0, 202),
    (120, 8, 10.0, 3033),
    (200, 16, 100.0, 4044),
    (199, 5, 10.0, 5055),
])
def test_algo1_matches_explicit_oracle(n, d, c, seed):
    """O(D) recursion == explicit augmented-space simulation (paper Sec 4.1)."""
    X, y = _data(n, d, seed)
    ball = fit(jnp.asarray(X), jnp.asarray(y), c)
    ref = fit_explicit(X, y, c, variant="exact")
    np.testing.assert_allclose(np.asarray(ball.w), ref["w"], rtol=2e-4, atol=2e-5)
    assert abs(float(ball.r) - ref["r"]) < 1e-3 * max(1.0, ref["r"])
    assert abs(float(ball.xi2) - ref["xi2"]) < 1e-3 * max(1.0, ref["xi2"])
    assert int(ball.m) == ref["m"]


def test_paper_listing_variant_matches_at_c1():
    X, y = _data(300, 6, 0)
    b1 = fit(jnp.asarray(X), jnp.asarray(y), 1.0, variant="exact")
    b2 = fit(jnp.asarray(X), jnp.asarray(y), 1.0, variant="paper-listing")
    np.testing.assert_allclose(np.asarray(b1.w), np.asarray(b2.w), rtol=1e-6)
    assert float(abs(b1.r - b2.r)) < 1e-5


def test_kernelized_linear_equals_algo1():
    X, y = _data(400, 8, 1)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    kb = fit_kernelized(Xj, yj, 3.0)
    b = fit(Xj, yj, 3.0)
    np.testing.assert_allclose(
        np.asarray(linear_weights(kb, Xj)), np.asarray(b.w), rtol=1e-4, atol=1e-5
    )
    assert int(kb.m) == int(b.m)
    np.testing.assert_allclose(float(kb.r), float(b.r), rtol=1e-5)


@pytest.mark.parametrize("n,d", [(16, 3), (100, 8), (333, 20), (800, 5)])
@pytest.mark.parametrize("c", [0.1, 1.0, 50.0])
def test_kernelized_linear_identity_sweep(n, d, c):
    """The linear-kernel dual recursion IS Algorithm 1, across shapes and
    the C range (radius, count and primal weights all agree)."""
    X, y = _data(n, d, seed=n + d)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    kb = fit_kernelized(Xj, yj, c)
    b = fit(Xj, yj, c)
    np.testing.assert_allclose(
        np.asarray(linear_weights(kb, Xj)), np.asarray(b.w),
        rtol=2e-4, atol=2e-5,
    )
    assert int(kb.m) == int(b.m)
    np.testing.assert_allclose(float(kb.r), float(b.r), rtol=1e-4)
    np.testing.assert_allclose(
        float(kb.xi2), float(b.xi2), rtol=1e-3, atol=1e-6
    )


@pytest.mark.parametrize("kernel", ["rbf", "linear"])
def test_kernel_bank_small_n_equals_dense(kernel):
    """coreset_size >= N: the bounded-buffer bank engine never evicts, so
    each model's (index, coefficient) buffer must rebuild the dense
    fit_kernelized alpha vector exactly (up to f32 roundoff)."""
    from repro.core import fit_kernel_bank, linear_kernel, rbf_kernel

    n, d, b = 24, 6, 3
    X, y = _data(n, d, seed=4)
    Xj = jnp.asarray(X)
    Y = jnp.asarray(np.stack([y, -y, y]))
    cs = jnp.asarray([0.5, 2.0, 10.0], jnp.float32)
    gamma = 0.8
    kfn = rbf_kernel(gamma) if kernel == "rbf" else linear_kernel
    kb = fit_kernel_bank(
        Xj, Y, cs, kernel=kernel, gamma=gamma, coreset_size=n, block_n=8
    )
    for bi in range(3):
        dense = fit_kernelized(Xj, Y[bi], float(cs[bi]), kfn)
        alpha = np.zeros(n, np.float32)
        idx = np.asarray(kb.idx[bi])
        live = idx >= 0
        alpha[idx[live]] = np.asarray(kb.coef[bi])[live]
        np.testing.assert_allclose(
            alpha, np.asarray(dense.alpha), rtol=1e-4, atol=1e-5
        )
        assert int(kb.m[bi]) == int(dense.m)


def test_radius_monotone_nondecreasing():
    """R never shrinks during the stream (enclosure invariant)."""
    X, y = _data(500, 5, 2)
    c_inv = 1.0 / 10.0
    ball = init_ball(jnp.asarray(X[0]), jnp.asarray(y[0]), 10.0)
    r_prev = float(ball.r)
    for i in range(1, 120):
        ball = fit_ball(ball, jnp.asarray(X[i : i + 1]), jnp.asarray(y[i : i + 1]), 10.0)
        assert float(ball.r) >= r_prev - 1e-6
        r_prev = float(ball.r)


@pytest.mark.parametrize("L,d,seed", [
    (2, 2, 0),
    (5, 4, 123),
    (8, 7, 456),
    (12, 10, 789),
])
def test_qp_solver_enclosure_and_near_optimality(L, d, seed):
    """MEB(ball, points): encloses everything; radius near the brute optimum."""
    from repro.core.oracle import meb_brute

    rng = np.random.default_rng(seed)
    pts_np = rng.normal(size=(L, d)).astype(np.float32)
    pts = jnp.asarray(pts_np)
    w0_np = rng.normal(size=d).astype(np.float32)
    ball = make_ball(jnp.asarray(w0_np), r=1.0, xi2=0.2, m=1)
    c_inv = 0.5
    out, aux = solve_meb_ball_points(
        ball, pts, jnp.ones(L, bool), c_inv, iters=512, return_aux=True
    )
    # enclosure: by construction r_new = max distance; verify the plumbing
    assert float(jnp.max(aux["point_dists"])) <= float(out.r) + 1e-5
    assert float(aux["ball_dist"]) <= float(out.r) + 1e-5
    assert float(out.xi2) >= 0.0

    # near-optimality vs explicit-space brute MEB (ball sampled on surface)
    dim = d + 1 + L
    ex_pts = []
    for i in range(L):
        v = np.zeros(dim); v[:d] = pts_np[i]; v[d + 1 + i] = np.sqrt(c_inv)
        ex_pts.append(v)
    cb = np.zeros(dim); cb[:d] = w0_np; cb[d] = np.sqrt(0.2)
    rs = np.random.default_rng(1)
    for _ in range(600):
        u = rs.normal(size=dim); u /= np.linalg.norm(u)
        ex_pts.append(cb + 1.0 * u)
    _, r_ref = meb_brute(np.array(ex_pts), iters=4000)
    assert float(out.r) <= 1.25 * r_ref + 1e-3


def test_lookahead_accuracy_and_sv_count():
    """Fig-3 behavior: larger L -> at least comparable accuracy, more SVs."""
    X, y = _data(2000, 8, 3)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    a1 = float(accuracy(fit(Xj, yj, 10.0), Xj, yj))
    b10 = fit_lookahead(Xj, yj, 10.0, 10)
    a10 = float(accuracy(b10, Xj, yj))
    assert a10 >= a1 - 0.02
    assert int(b10.m) >= int(fit(Xj, yj, 10.0).m)


def test_merge_commutative_and_encloses():
    X, y = _data(600, 6, 4)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    b1 = fit(Xj[:300], yj[:300], 5.0)
    b2 = fit(Xj[300:], yj[300:], 5.0)
    m12 = merge_balls(b1, b2)
    m21 = merge_balls(b2, b1)
    np.testing.assert_allclose(np.asarray(m12.w), np.asarray(m21.w), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m12.r), float(m21.r), rtol=1e-5)
    from repro.core import center_distance

    # The merged center sits at fraction t along the segment c1 -> c2 (in the
    # joint space where b1/b2 slack blocks ARE disjoint); enclosure of both
    # input balls is: t*d12 + r1 <= r_m and (1-t)*d12 + r2 <= r_m.
    d12 = float(center_distance(b1, b2))
    t = (float(m12.r) - float(b1.r)) / d12
    assert 0.0 <= t <= 1.0
    assert t * d12 + float(b1.r) <= float(m12.r) + 1e-4
    assert (1.0 - t) * d12 + float(b2.r) <= float(m12.r) + 1e-4


def test_fold_merge_order_insensitive_accuracy():
    """Straggler re-assignment safety: shard order must not matter much."""
    X, y = _data(800, 6, 5)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    balls = [fit(Xj[i * 200 : (i + 1) * 200], yj[i * 200 : (i + 1) * 200], 5.0) for i in range(4)]

    def fold(order):
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *[balls[i] for i in order])
        return fold_merge(stacked)

    a = fold([0, 1, 2, 3])
    b = fold([3, 1, 0, 2])
    acc_a = float(accuracy(a, Xj, yj))
    acc_b = float(accuracy(b, Xj, yj))
    assert abs(acc_a - acc_b) < 0.05
    assert abs(float(a.r) - float(b.r)) / max(float(a.r), 1e-6) < 0.25


def test_chunked_fit_equals_full_fit_and_resume():
    X, y = _data(1000, 7, 6)
    full = fit(jnp.asarray(X), jnp.asarray(y), 10.0)
    ck = fit_chunked(chunk_stream(X, y, 128), 10.0)
    np.testing.assert_allclose(np.asarray(ck.ball.w), np.asarray(full.w), rtol=1e-4, atol=1e-5)
    assert ck.position == 1000

    # preemption at example 512: resume must give the identical model
    saved = {}
    fit_chunked(
        chunk_stream(X, y, 128), 10.0,
        checkpoint_every=512, checkpoint_cb=lambda s: saved.update(ck=s),
    )
    resume = saved["ck"]
    rest = fit_chunked(
        chunk_stream(X, y, 128, start=resume.position), 10.0, resume=resume
    )
    np.testing.assert_allclose(np.asarray(rest.ball.w), np.asarray(full.w), rtol=1e-4, atol=1e-5)
    assert int(rest.ball.m) == int(full.m)


def test_multiclass_ovr():
    rng = np.random.default_rng(7)
    proto = rng.normal(size=(4, 12)) * 4
    labels = rng.integers(0, 4, size=1500)
    X = (rng.normal(size=(1500, 12)) + proto[labels]).astype(np.float32)
    X /= np.linalg.norm(X, axis=1, keepdims=True)
    balls = fit_ovr(jnp.asarray(X), jnp.asarray(labels), 4, 10.0, lookahead=8)
    pred = predict_ovr(balls, jnp.asarray(X))
    assert float(jnp.mean(pred == jnp.asarray(labels))) > 0.9
