# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see the
# real single CPU device; multi-device tests spawn subprocesses, and the
# dry-run sets --xla_force_host_platform_device_count=512 itself.
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running (subprocess/multi-device)")
