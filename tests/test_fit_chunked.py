"""fit_chunked / fit_chunked_many streaming-driver semantics.

Chunked + resumed runs must equal a single fit over the concatenated stream
(lookahead=1 exactly; lookahead>1 up to the documented chunk-boundary flush),
and the bank driver must carry the whole bank through checkpoint/resume.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    fit,
    fit_c_grid,
    fit_chunked,
    fit_chunked_many,
    fit_lookahead_ball,
    init_ball,
    ovr_signs,
)
from repro.data.stream import chunk_stream


def _data(n, d, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = np.sign(rng.normal(size=n) + X[:, 0]).astype(np.float32)
    y[y == 0] = 1
    return X, y


@pytest.mark.parametrize("chunk", [64, 100, 256, 1000, 2048])
def test_chunked_equals_fit_any_chunking(chunk):
    """Chunk size (incl. ragged final chunks and chunk > N) must not matter."""
    X, y = _data(1000, 6, 0)
    full = fit(jnp.asarray(X), jnp.asarray(y), 10.0)
    ck = fit_chunked(chunk_stream(X, y, chunk), 10.0)
    np.testing.assert_allclose(
        np.asarray(ck.ball.w), np.asarray(full.w), rtol=1e-4, atol=1e-5
    )
    assert int(ck.ball.m) == int(full.m)
    assert ck.position == 1000


@pytest.mark.parametrize("ckpt_every", [100, 333, 512])
def test_chunked_resume_equals_fit(ckpt_every):
    """Preempt at any checkpoint: resumed run == single fit on the full stream."""
    X, y = _data(900, 5, 1)
    full = fit(jnp.asarray(X), jnp.asarray(y), 5.0)
    saved = []
    fit_chunked(
        chunk_stream(X, y, 100), 5.0,
        checkpoint_every=ckpt_every, checkpoint_cb=saved.append,
    )
    assert saved, "no checkpoint emitted"
    first = saved[0]
    assert first.position < 900
    rest = fit_chunked(
        chunk_stream(X, y, 100, start=first.position), 5.0, resume=first
    )
    np.testing.assert_allclose(
        np.asarray(rest.ball.w), np.asarray(full.w), rtol=1e-4, atol=1e-5
    )
    assert int(rest.ball.m) == int(full.m)
    assert rest.position == 900


def test_chunked_lookahead_boundary_flush_semantics():
    """lookahead>1 flushes its violator buffer at every chunk boundary; the
    driver must equal manually applying fit_lookahead_ball chunk by chunk."""
    X, y = _data(640, 7, 2)
    L, c, chunk = 4, 10.0, 160
    ck = fit_chunked(chunk_stream(X, y, chunk), c, lookahead=L)

    ball = init_ball(jnp.asarray(X[0]), jnp.asarray(y[0]), c)
    first = True
    for Xc, yc in chunk_stream(X, y, chunk):
        Xc, yc = jnp.asarray(Xc), jnp.asarray(yc)
        if first:
            Xc, yc = Xc[1:], yc[1:]
            first = False
        ball = fit_lookahead_ball(ball, Xc, yc, c, L)
    np.testing.assert_allclose(
        np.asarray(ck.ball.w), np.asarray(ball.w), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(float(ck.ball.r), float(ball.r), rtol=1e-4)
    assert int(ck.ball.m) == int(ball.m)


def test_chunked_lookahead_resume_equals_continuous_chunked():
    """With lookahead>1, resume from a checkpoint == the continuous chunked
    run over the same boundaries (flush state is part of the contract)."""
    X, y = _data(800, 6, 3)
    L, c, chunk = 5, 10.0, 200
    cont = fit_chunked(chunk_stream(X, y, chunk), c, lookahead=L)
    saved = []
    fit_chunked(
        chunk_stream(X, y, chunk), c, lookahead=L,
        checkpoint_every=400, checkpoint_cb=saved.append,
    )
    first = saved[0]
    rest = fit_chunked(
        chunk_stream(X, y, chunk, start=first.position), c,
        lookahead=L, resume=first,
    )
    np.testing.assert_allclose(
        np.asarray(rest.ball.w), np.asarray(cont.ball.w), rtol=1e-5, atol=1e-6
    )
    assert int(rest.ball.m) == int(cont.ball.m)


def test_chunked_many_grid_resume_equals_full_grid():
    """Bank driver: chunked + resumed C-grid == one-call grid fit; the
    checkpoint carries the whole bank (O(B*D) state)."""
    X, y = _data(700, 9, 4)
    cs = jnp.asarray([1.0, 10.0, 100.0])
    full = fit_c_grid(jnp.asarray(X), jnp.asarray(y), cs)
    saved = []
    fit_chunked_many(
        chunk_stream(X, y, 128), cs,
        checkpoint_every=256, checkpoint_cb=saved.append,
    )
    first = saved[0]
    assert first.ball.w.shape == (3, 9)
    rest = fit_chunked_many(
        chunk_stream(X, y, 128, start=first.position), cs, resume=first
    )
    np.testing.assert_allclose(
        np.asarray(rest.ball.w), np.asarray(full.w), rtol=2e-4, atol=2e-5
    )
    np.testing.assert_array_equal(np.asarray(rest.ball.m), np.asarray(full.m))
    assert rest.position == 700


def test_chunked_many_ovr_sign_rows():
    """(B, n) per-model sign chunks (one-vs-rest) stream correctly."""
    from repro.kernels import streamsvm_fit

    rng = np.random.default_rng(5)
    X = rng.normal(size=(500, 8)).astype(np.float32)
    labels = rng.integers(0, 4, size=500)
    Y = np.asarray(ovr_signs(jnp.asarray(labels), 4))
    cs = jnp.full((4,), 10.0)

    def chunks():
        for lo in range(0, 500, 125):
            yield X[lo : lo + 125], Y[:, lo : lo + 125]

    out = fit_chunked_many(chunks(), cs)
    for k in range(4):
        single = streamsvm_fit(jnp.asarray(X), jnp.asarray(Y[k]), 10.0)
        np.testing.assert_allclose(
            np.asarray(out.ball.w[k]), np.asarray(single.w), rtol=2e-4, atol=2e-5
        )
        assert int(out.ball.m[k]) == int(single.m)
