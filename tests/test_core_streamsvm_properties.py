"""Property-based StreamSVM tests (optional `hypothesis` dependency).

`hypothesis` is an OPTIONAL test dependency: these randomized-property
versions run wherever it is installed (see .github/workflows/ci.yml) and the
module skips cleanly everywhere else. Deterministic fixed-seed equivalents of
both properties live in test_core_streamsvm.py so coverage does not depend on
the extra package.
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import fit, solve_meb_ball_points
from repro.core.meb import make_ball
from repro.core.oracle import fit_explicit, meb_brute


def _data(n, d, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(dtype)
    y = np.sign(rng.normal(size=n) + X[:, 0]).astype(dtype)
    y[y == 0] = 1
    return X, y


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(20, 200),
    d=st.integers(1, 16),
    c=st.sampled_from([0.1, 1.0, 10.0, 100.0]),
    seed=st.integers(0, 10_000),
)
def test_algo1_matches_explicit_oracle(n, d, c, seed):
    """O(D) recursion == explicit augmented-space simulation (paper Sec 4.1)."""
    X, y = _data(n, d, seed)
    ball = fit(jnp.asarray(X), jnp.asarray(y), c)
    ref = fit_explicit(X, y, c, variant="exact")
    np.testing.assert_allclose(np.asarray(ball.w), ref["w"], rtol=2e-4, atol=2e-5)
    assert abs(float(ball.r) - ref["r"]) < 1e-3 * max(1.0, ref["r"])
    assert abs(float(ball.xi2) - ref["xi2"]) < 1e-3 * max(1.0, ref["xi2"])
    assert int(ball.m) == ref["m"]


@settings(max_examples=10, deadline=None)
@given(
    L=st.integers(2, 12),
    d=st.integers(2, 10),
    seed=st.integers(0, 1000),
)
def test_qp_solver_enclosure_and_near_optimality(L, d, seed):
    """MEB(ball, points): encloses everything; radius near the brute optimum."""
    rng = np.random.default_rng(seed)
    pts_np = rng.normal(size=(L, d)).astype(np.float32)
    pts = jnp.asarray(pts_np)
    w0_np = rng.normal(size=d).astype(np.float32)
    ball = make_ball(jnp.asarray(w0_np), r=1.0, xi2=0.2, m=1)
    c_inv = 0.5
    out, aux = solve_meb_ball_points(
        ball, pts, jnp.ones(L, bool), c_inv, iters=512, return_aux=True
    )
    # enclosure: by construction r_new = max distance; verify the plumbing
    assert float(jnp.max(aux["point_dists"])) <= float(out.r) + 1e-5
    assert float(aux["ball_dist"]) <= float(out.r) + 1e-5
    assert float(out.xi2) >= 0.0

    # near-optimality vs explicit-space brute MEB (ball sampled on surface)
    dim = d + 1 + L
    ex_pts = []
    for i in range(L):
        v = np.zeros(dim); v[:d] = pts_np[i]; v[d + 1 + i] = np.sqrt(c_inv)
        ex_pts.append(v)
    cb = np.zeros(dim); cb[:d] = w0_np; cb[d] = np.sqrt(0.2)
    rs = np.random.default_rng(1)
    for _ in range(600):
        u = rs.normal(size=dim); u /= np.linalg.norm(u)
        ex_pts.append(cb + 1.0 * u)
    _, r_ref = meb_brute(np.array(ex_pts), iters=4000)
    assert float(out.r) <= 1.25 * r_ref + 1e-3
