"""Live loop: crash equivalence at every phase boundary — for BOTH bank
kinds (linear Ball and kernelized core-set sub-banks) — retry/quarantine,
K-sub-bank drift repair, server survival, the fold helpers (linear + kernel
twins, property-tested), and the kernel-merge re-compression loss audit."""
import functools
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import (
    KernelBank,
    fit_bank,
    fit_kernel_bank,
    fold_banks,
    fold_kernel_banks,
    kernel_bank_decision,
    merge_banks,
    merge_kernel_banks,
    nonfinite_rows,
    shard_ranges,
    stack_banks,
    stack_kernel_banks,
)
from repro.core.meb import Ball, fold_merge
from repro.live import (
    PHASES,
    ArraySource,
    FlakySource,
    LiveBank,
    ShardFaults,
    TransientSourceError,
    chaos_reference,
    chaos_schedule,
    run_chaos,
    run_live_with_restarts,
)
from repro.runtime import InjectedFailure, RetryPolicy, StragglerPolicy
from repro.serve.bank_server import BankServer

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

D, B, CHUNK, N_CHUNKS = 8, 3, 32, 10
CS = jnp.asarray([0.5, 2.0, 8.0], jnp.float32)
_NOSLEEP = lambda s: None
BANK_KINDS = ("linear", "kernel")
# small-but-lossy kernel config for the live tests: S=6 < CHUNK forces
# eviction AND merge re-compression on every chunk continuation
KERNEL_KW = dict(kernel="rbf", gamma=0.7, coreset_size=6, block_n=32)


def _stream(n_chunks=N_CHUNKS, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n_chunks * CHUNK, D)).astype(np.float32)
    X /= np.linalg.norm(X, axis=1, keepdims=True)
    y = np.sign(rng.normal(size=X.shape[0]) + X[:, 0]).astype(np.float32)
    return X, np.tile(y, (B, 1))


def _make(source, ckpt_dir, bank_kind="linear", **kw):
    kw.setdefault("n_sub_banks", 2)
    kw.setdefault("rotate_every", 3)
    kw.setdefault("swap_every", 2)
    kw.setdefault("sleep", _NOSLEEP)
    if bank_kind == "kernel":
        for key, val in KERNEL_KW.items():
            kw.setdefault(key, val)
    return LiveBank(
        source, CS, ckpt_dir=str(ckpt_dir), bank_kind=bank_kind, **kw
    )


def _bank_eq(a, b) -> bool:
    return all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(a, b)
    )


_QUERIES = _stream(1, seed=99)[0][:16]


def _served_scores(bank) -> np.ndarray:
    """Decision scores on fixed queries, by bank kind (the served readout)."""
    if hasattr(bank, "coef"):
        return np.asarray(
            kernel_bank_decision(
                bank, jnp.asarray(_QUERIES),
                kernel=KERNEL_KW["kernel"], gamma=KERNEL_KW["gamma"],
            )
        )
    return _QUERIES @ np.asarray(bank.w).T


# ---------------------------------------------------------------------------
# training semantics
# ---------------------------------------------------------------------------


def test_single_slot_matches_sequential_fit_bank(tmp_path):
    """K=1 with no rotation is exactly the chunked one-pass bank fit."""
    X, Y = _stream()
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c",
        n_sub_banks=1, rotate_every=10**9, swap_every=1,
    )
    live.run()

    ref = None
    for i in range(N_CHUNKS):
        lo = i * CHUNK
        ref = fit_bank(
            jnp.asarray(X[lo:lo + CHUNK]),
            jnp.asarray(Y[:, lo:lo + CHUNK]), CS, ref,
        )
    assert _bank_eq(live.serving_bank(), ref)


def test_kernel_single_slot_matches_chunkwise_merge(tmp_path):
    """K=1 kernel loop == the documented referent: each chunk fits FRESH
    through fit_kernel_bank, its core-set ids lift to absolute stream
    coordinates, and Sec-4.3 merges into the prior state — bit-exactly."""
    X, Y = _stream()
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", bank_kind="kernel",
        n_sub_banks=1, rotate_every=10**9, swap_every=1,
    )
    live.run()

    fit_kw = dict(
        kernel=KERNEL_KW["kernel"], gamma=KERNEL_KW["gamma"],
        coreset_size=KERNEL_KW["coreset_size"], block_n=KERNEL_KW["block_n"],
    )
    merge_kw = dict(kernel=KERNEL_KW["kernel"], gamma=KERNEL_KW["gamma"])
    ref = None
    for i in range(N_CHUNKS):
        lo = i * CHUNK
        chunk = fit_kernel_bank(
            jnp.asarray(X[lo:lo + CHUNK]),
            jnp.asarray(Y[:, lo:lo + CHUNK]), CS, **fit_kw,
        )
        chunk = chunk._replace(
            idx=jnp.where(chunk.idx >= 0, chunk.idx + lo, chunk.idx)
        )
        ref = chunk if ref is None else merge_kernel_banks(
            ref, chunk, **merge_kw
        )
    assert _bank_eq(live.serving_bank(), ref)
    # the absolute-coordinate lift: live core-set ids address the stream
    idx = np.asarray(live.serving_bank().idx)
    assert idx.max() >= CHUNK  # ids from later chunks kept their offset
    assert idx[idx >= 0].max() < N_CHUNKS * CHUNK


def test_clean_run_stats_accounting(tmp_path):
    """Cadence arithmetic: rotations at 3/6/9, folds+swaps+ckpts at every
    even chunk, retirements once both K=2 slots are full."""
    X, Y = _stream()
    stats = _make(ArraySource(X, Y, CHUNK), tmp_path / "c").run()
    assert stats.chunks_ingested == N_CHUNKS
    assert stats.rows_ingested == N_CHUNKS * CHUNK
    assert stats.rotations == 3 and stats.retirements == 2
    assert stats.folds == stats.swaps == stats.checkpoints == 5
    assert stats.last_swap_chunk == N_CHUNKS
    assert stats.bank_age_chunks == 0 and stats.quarantined == []


def test_rotation_retirement_exact():
    """K=2, rotate_every=2 over 8 chunks pins the retirement semantics:
    retire='drop' serves ONLY the final epoch's bank (epochs e0..e2 were
    dropped), retire='merge' serves merge(merge(merge(e0,e1),e2),e3) —
    both bit-identical to the hand-built referents."""
    X, Y = _stream(8, seed=3)

    def fit_epoch(e, prior=None):
        ref = prior
        for c in (2 * e, 2 * e + 1):
            lo = c * CHUNK
            ref = fit_bank(
                jnp.asarray(X[lo:lo + CHUNK]),
                jnp.asarray(Y[:, lo:lo + CHUNK]), CS, ref,
            )
        return ref

    epochs = [fit_epoch(e) for e in range(4)]
    banks = {}
    for retire in ("drop", "merge"):
        import tempfile
        with tempfile.TemporaryDirectory() as td:
            live = _make(
                ArraySource(X, Y, CHUNK), td, n_sub_banks=2,
                rotate_every=2, swap_every=8, retire=retire,
            )
            stats = live.run()
            assert stats.rotations == 4 and stats.retirements == 3
            banks[retire] = live.serving_bank()

    assert _bank_eq(banks["drop"], epochs[3])
    assert _bank_eq(
        banks["merge"], functools.reduce(merge_banks, epochs)
    )
    assert not _bank_eq(banks["drop"], banks["merge"])


def test_fold_helpers():
    X, Y = _stream(3, seed=5)
    chunks = [
        fit_bank(
            jnp.asarray(X[i * CHUNK:(i + 1) * CHUNK]),
            jnp.asarray(Y[:, i * CHUNK:(i + 1) * CHUNK]), CS,
        )
        for i in range(3)
    ]
    stacked = stack_banks(chunks)
    assert stacked.w.shape == (3, B, D) and stacked.r.shape == (3, B)
    # deterministic: the same fold twice is bit-identical; numerically it is
    # the sequential left merge (last-ulp apart from the eager python
    # reduce — jit fuses the scan arithmetic differently)
    assert _bank_eq(fold_banks(chunks), fold_banks(list(chunks)))
    eager = functools.reduce(merge_banks, chunks)
    for a, b in zip(fold_banks(chunks), eager):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7
        )
    assert int(fold_banks(chunks).m.sum()) == int(eager.m.sum())
    assert fold_banks(chunks[:1]) is chunks[0]
    with pytest.raises(ValueError, match="empty"):
        fold_banks([])
    with pytest.raises(ValueError, match="empty"):
        stack_banks(())


def test_constructor_validation(tmp_path):
    X, Y = _stream(1)
    src = ArraySource(X, Y, CHUNK)
    with pytest.raises(ValueError, match="n_sub_banks"):
        _make(src, tmp_path, n_sub_banks=0)
    with pytest.raises(ValueError, match="rotate_every"):
        _make(src, tmp_path, rotate_every=0)
    with pytest.raises(ValueError, match="retire"):
        _make(src, tmp_path, retire="evict")
    with pytest.raises(ValueError, match="unknown failpoint phase"):
        _make(src, tmp_path, failpoints=[("pre_train", 3)])
    with pytest.raises(ValueError, match="chunk_size"):
        ArraySource(X, Y, 0)
    with pytest.raises(ValueError, match="bank_kind"):
        _make(src, tmp_path, bank_kind="quadratic")
    with pytest.raises(ValueError, match="unknown kernel"):
        _make(src, tmp_path, bank_kind="kernel", kernel="poly")
    with pytest.raises(ValueError, match="unknown eviction"):
        _make(src, tmp_path, bank_kind="kernel", eviction="lru")
    with pytest.raises(ValueError, match="coreset_size"):
        _make(src, tmp_path, bank_kind="kernel", coreset_size=0)


# ---------------------------------------------------------------------------
# crash equivalence — the acceptance criterion
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module", params=BANK_KINDS)
def clean_reference(request, tmp_path_factory):
    """Per bank kind: the uninterrupted run every crashy variant must
    reproduce bit-exactly — bank leaves, served scores, durable stats."""
    kind = request.param
    X, Y = _stream()
    live = _make(
        ArraySource(X, Y, CHUNK),
        tmp_path_factory.mktemp(f"clean_{kind}") / "c",
        bank_kind=kind,
    )
    stats = live.run()
    bank = live.serving_bank()
    return kind, bank, _served_scores(bank), stats.durable()


@pytest.mark.parametrize("phase", PHASES)
def test_crash_equivalence_at_every_phase(tmp_path, phase, clean_reference):
    """Inject a crash at each phase boundary of chunk 5 (where rotation,
    fold, swap and checkpoint ALL fire: chunk_idx 6 is divisible by both
    cadences) — one restart later the bank, the served scores and the
    durable accounting are bit-identical to the uninterrupted run.
    Parametrized over bank_kind: the kernelized loop must recover its
    (B, S) core-set state exactly like the linear loop recovers (B, D)."""
    kind, ref_bank, ref_scores, ref_stats = clean_reference
    X, Y = _stream()
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", bank_kind=kind,
        failpoints=[(phase, 5)],
    )
    stats = run_live_with_restarts(live, sleep=_NOSLEEP)
    assert stats.restarts == 1, f"failpoint {phase!r} never fired"
    assert _bank_eq(live.serving_bank(), ref_bank)
    assert np.array_equal(_served_scores(live.serving_bank()), ref_scores)
    assert stats.durable() == ref_stats
    # recovery swept up any mid-commit debris (mid_checkpoint drops a torn
    # .tmp in the directory first; the next commit's GC removes it)
    leftover = [f for f in os.listdir(tmp_path / "c") if f.endswith(".tmp")]
    assert leftover == []


def test_repeated_crashes_still_converge(tmp_path, clean_reference):
    """Five crashes at five different boundaries in one run."""
    kind, ref_bank, ref_scores, ref_stats = clean_reference
    X, Y = _stream()
    fps = [("fetch", 1), ("post_train", 3), ("post_fold", 5),
           ("mid_checkpoint", 7), ("post_swap", 9)]
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", bank_kind=kind,
        failpoints=fps,
    )
    stats = run_live_with_restarts(live, sleep=_NOSLEEP)
    assert stats.restarts == 5
    assert _bank_eq(live.serving_bank(), ref_bank)
    assert np.array_equal(_served_scores(live.serving_bank()), ref_scores)
    assert stats.durable() == ref_stats


def test_serve_from_live_checkpoint(tmp_path, clean_reference):
    """BankServer.from_checkpoint on a live StreamCheckpoint folds the live
    slots into exactly the bank the loop was serving at its last commit —
    kernel config restored from the meta (save_kernel_bank contract)."""
    kind, ref_bank, ref_scores, _ = clean_reference
    X, Y = _stream()
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", bank_kind=kind
    )
    live.run()
    srv = BankServer.from_checkpoint(str(tmp_path / "c"), q_block=16)
    if kind == "kernel":
        assert srv.kernel == KERNEL_KW["kernel"]
        assert srv.gamma == KERNEL_KW["gamma"]
    else:
        assert srv.kernel is None
    req = srv.submit(_QUERIES)
    while not req.done:
        srv.step()
    # the final checkpoint commits the final fold: served == loop's scores
    assert np.array_equal(req.result, ref_scores)


def test_run_live_nonretryable_propagates(tmp_path):
    """run_live_with_restarts must not eat programming errors."""
    def bad_source(i):
        raise TypeError("a bug, not infrastructure")

    live = _make(bad_source, tmp_path / "c")
    # TypeError is not in the fetch RetryPolicy either: straight through
    with pytest.raises(TypeError, match="a bug"):
        run_live_with_restarts(live, sleep=_NOSLEEP)
    assert live.stats.restarts == 0 and live.stats.retries == 0


def test_resume_rejects_mismatched_configuration(tmp_path):
    X, Y = _stream(4)
    _make(ArraySource(X, Y, CHUNK), tmp_path / "c").run()
    other = _make(ArraySource(X, Y, CHUNK), tmp_path / "c", n_sub_banks=3)
    with pytest.raises(ValueError, match="K=2"):
        other.run()


def test_resume_rejects_mismatched_bank_kind_and_kernel_config(tmp_path):
    """A linear checkpoint refuses a kernel loop (and vice versa), and a
    kernel checkpoint refuses a drifted kernel config — ValueErrors naming
    both sides, instead of restoring garbage into the wrong algebra."""
    X, Y = _stream(4)
    _make(ArraySource(X, Y, CHUNK), tmp_path / "lin").run()
    with pytest.raises(ValueError, match="bank_kind='linear'.*'kernel'"):
        _make(ArraySource(X, Y, CHUNK), tmp_path / "lin",
              bank_kind="kernel").run()

    _make(ArraySource(X, Y, CHUNK), tmp_path / "ker",
          bank_kind="kernel").run()
    with pytest.raises(ValueError, match="bank_kind='kernel'.*'linear'"):
        _make(ArraySource(X, Y, CHUNK), tmp_path / "ker").run()
    for drift in (
        {"gamma": 0.9}, {"kernel": "linear"},
        {"coreset_size": 7}, {"eviction": "farthest-point"},
    ):
        with pytest.raises(ValueError, match="kernel config"):
            _make(ArraySource(X, Y, CHUNK), tmp_path / "ker",
                  bank_kind="kernel", **drift).run()


def test_checkpointing_disabled(tmp_path):
    X, Y = _stream(4)
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", checkpoint_every_folds=0
    )
    stats = live.run()
    assert stats.checkpoints == 0
    assert not ckpt.exists(str(tmp_path / "c"))


# ---------------------------------------------------------------------------
# retry / quarantine
# ---------------------------------------------------------------------------


def test_fetch_retry_backoff_and_quarantine(tmp_path):
    """Transient chunk delivers after its faults; poison chunk exhausts the
    budget into quarantine; the recorded sleeps are the capped exponential."""
    X, Y = _stream()
    delays = []
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", sleep=delays.append,
        retry=RetryPolicy(
            retryable=(TransientSourceError,), max_retries=2,
            backoff_base=0.1, backoff_cap=0.15,
        ),
    )
    live.source = FlakySource(
        live.source, {1: 2, 4: FlakySource.POISON}
    )
    stats = live.run()
    # chunk 1: two faults then delivered; chunk 4: 2 retries then quarantined
    assert stats.retries == 4
    assert delays == [0.1, 0.15, 0.1, 0.15]
    assert stats.quarantined == [4]
    assert stats.chunks_ingested == N_CHUNKS - 1
    assert stats.rows_ingested == (N_CHUNKS - 1) * CHUNK
    # a quarantined chunk keeps its stream position
    assert live.chunk_idx == N_CHUNKS


def test_fetch_nonretryable_propagates(tmp_path):
    X, Y = _stream()
    live = _make(ArraySource(X, Y, CHUNK), tmp_path / "c")
    live.source = FlakySource(live.source, {2: 1}, exc=ZeroDivisionError)
    with pytest.raises(ZeroDivisionError):
        live.run()
    assert live.stats.retries == 0


# ---------------------------------------------------------------------------
# server decoupling
# ---------------------------------------------------------------------------


class _RecordingServer:
    """Stand-in hot-swap target: remembers every bank it was handed."""

    def __init__(self):
        self.banks = []

    def swap_bank(self, bank):
        self.banks.append(bank)


def test_server_survives_trainer_crash(tmp_path):
    """The server object outlives the trainer: it keeps the last good bank
    through the crash (staleness visible in bank_age_chunks), and the
    post-restart swap history is bit-identical to the crash-free run's."""
    X, Y = _stream()

    clean_srv = _RecordingServer()
    _make(ArraySource(X, Y, CHUNK), tmp_path / "a", server=clean_srv).run()

    srv = _RecordingServer()
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "b", server=srv,
        failpoints=[("post_train", 5)],
    )
    with pytest.raises(InjectedFailure):
        live.run()
    # trainer is down; the server still holds the chunk-4 bank and knows
    # how stale it is (chunk 5 ingested since the swap)
    assert len(srv.banks) == 2
    assert _bank_eq(srv.banks[-1], clean_srv.banks[1])
    assert live.stats.bank_age_chunks == 1

    live.run()  # recovery: resume from the durable checkpoint
    assert len(srv.banks) == len(clean_srv.banks) == 5
    assert all(_bank_eq(a, b) for a, b in zip(srv.banks, clean_srv.banks))


def test_attach_server_pushes_current_bank(tmp_path):
    X, Y = _stream(4)
    live = _make(ArraySource(X, Y, CHUNK), tmp_path / "c")
    live.run()
    srv = _RecordingServer()
    live.attach_server(srv)
    assert len(srv.banks) == 1 and _bank_eq(srv.banks[0], live.serving_bank())


def test_live_loop_rejects_mismatched_server_kernel_config(tmp_path):
    """Hot-swapping into a server whose kernel config differs from the
    loop's raises a ValueError naming both configs — at attach time and at
    the first factory-built push alike."""
    X, Y = _stream(4)

    # kernel loop -> linear server
    klive = _make(ArraySource(X, Y, CHUNK), tmp_path / "k", bank_kind="kernel")
    klive.run()
    linear_srv = BankServer(np.zeros((B, D), np.float32))
    with pytest.raises(ValueError, match="kernel='rbf'.*kernel=None"):
        klive.attach_server(linear_srv)

    # kernel loop -> kernel server with a drifted gamma
    bank = klive.serving_bank()
    bad_gamma_srv = BankServer(bank, kernel="rbf", gamma=9.9)
    with pytest.raises(ValueError, match="gamma=0.7.*gamma=9.9"):
        klive.attach_server(bad_gamma_srv)

    # linear loop -> kernel server
    llive = _make(ArraySource(X, Y, CHUNK), tmp_path / "l")
    llive.run()
    kernel_srv = BankServer(bank, kernel="rbf", gamma=0.7)
    with pytest.raises(ValueError, match="kernel=None.*kernel='rbf'"):
        llive.attach_server(kernel_srv)

    # the factory path validates the server it just built, mid-run
    mlive = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "m", bank_kind="kernel",
        server_factory=lambda b: BankServer(b, kernel="rbf", gamma=9.9),
    )
    with pytest.raises(ValueError, match="gamma=0.7.*gamma=9.9"):
        mlive.run()


def test_swap_bank_rejects_mismatched_kernel_config(tmp_path):
    """BankServer.swap_bank(kernel=, gamma=) validates the incoming bank's
    declared train-time config against the server's, naming both."""
    X, Y = _stream(4)
    live = _make(ArraySource(X, Y, CHUNK), tmp_path / "c", bank_kind="kernel")
    live.run()
    bank = live.serving_bank()
    srv = BankServer(bank, kernel="rbf", gamma=0.7)
    srv.swap_bank(bank, kernel="rbf", gamma=0.7)  # matching: fine
    with pytest.raises(ValueError, match="kernel='linear'.*kernel='rbf'"):
        srv.swap_bank(bank, kernel="linear")
    with pytest.raises(ValueError, match="gamma=0.9.*gamma=0.7"):
        srv.swap_bank(bank, kernel="rbf", gamma=0.9)
    assert srv.stats.bank_swaps == 1  # only the matching swap landed


# ---------------------------------------------------------------------------
# process-level crash: the trainer actually dies
# ---------------------------------------------------------------------------

_SUBPROC = r"""
import os, sys
import numpy as np, jax.numpy as jnp
from repro.checkpoint import ckpt
from repro.core import kernel_bank_decision
from repro.live import ArraySource, LiveBank
from repro.runtime import InjectedFailure

ckpt_dir, out_dir, mode, bank_kind = sys.argv[1:5]
rng = np.random.default_rng(7)
X = rng.normal(size=(8 * 16, 4)).astype(np.float32)
y = np.sign(rng.normal(size=X.shape[0]) + X[:, 0]).astype(np.float32)
y[y == 0] = 1.0
kw = {}
if bank_kind == "kernel":
    kw = dict(kernel="rbf", gamma=0.7, coreset_size=5, block_n=16)
live = LiveBank(
    ArraySource(X, y, 16), jnp.asarray([1.0, 4.0]), ckpt_dir=ckpt_dir,
    n_sub_banks=2, rotate_every=3, swap_every=2, sleep=lambda s: None,
    bank_kind=bank_kind,
    failpoints=[("post_fold", 3)] if mode == "crash" else None,
    **kw,
)
try:
    live.run()
except InjectedFailure:
    os._exit(7)  # hard exit: no unwinding, no cleanup — a real dead process
bank = live.serving_bank()
if bank_kind == "kernel":
    scores = kernel_bank_decision(
        bank, jnp.asarray(X[:16]), kernel="rbf", gamma=0.7
    )
else:
    scores = jnp.asarray(X[:16]) @ bank.w.T
ckpt.save(
    out_dir, {"bank": bank, "scores": scores},
    meta={"stats": live.stats.durable()},
)
print("DONE")
"""


@pytest.mark.slow
@pytest.mark.parametrize("bank_kind", BANK_KINDS)
def test_process_crash_and_relaunch_bit_exact(tmp_path, bank_kind):
    """The trainer PROCESS dies (os._exit mid-run, nothing flushed) and a
    fresh process resumes from the on-disk checkpoint: final bank, served
    scores and durable stats equal a process that never crashed — for the
    linear AND the kernelized loop."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )

    def launch(ckpt_dir, out_dir, mode):
        return subprocess.run(
            [sys.executable, "-c", _SUBPROC,
             str(ckpt_dir), str(out_dir), mode, bank_kind],
            env=env, capture_output=True, text=True, timeout=300,
        )

    def out_leaves(out_dir):
        manifest = ckpt.load_manifest(str(out_dir))
        target = ckpt.zeros_like_manifest(manifest)
        return [np.asarray(x) for x in ckpt.restore(str(out_dir), target)]

    crashed = launch(tmp_path / "ck", tmp_path / "out", "crash")
    assert crashed.returncode == 7, crashed.stderr[-4000:]
    relaunch = launch(tmp_path / "ck", tmp_path / "out", "resume")
    assert relaunch.returncode == 0, relaunch.stderr[-4000:]
    assert "DONE" in relaunch.stdout

    clean = launch(tmp_path / "ck_clean", tmp_path / "out_clean", "clean")
    assert clean.returncode == 0, clean.stderr[-4000:]

    recovered = out_leaves(tmp_path / "out")
    reference = out_leaves(tmp_path / "out_clean")
    # bank leaves (7 for KernelBank, 4 for Ball) + served scores, bit-equal
    assert len(recovered) == len(reference) == (
        8 if bank_kind == "kernel" else 5
    )
    for got, want in zip(recovered, reference):
        assert np.array_equal(got, want)
    assert (
        ckpt.load_meta(str(tmp_path / "out"))["stats"]
        == ckpt.load_meta(str(tmp_path / "out_clean"))["stats"]
    )


# ---------------------------------------------------------------------------
# the live fold helpers: property layer (linear + kernel twins)
# ---------------------------------------------------------------------------


def _rand_ball_banks(k, b, d, rng):
    return [
        Ball(
            w=jnp.asarray(rng.normal(size=(b, d)).astype(np.float32)),
            r=jnp.asarray(np.abs(rng.normal(size=b)).astype(np.float32)),
            xi2=jnp.asarray(
                (0.01 + np.abs(rng.normal(size=b))).astype(np.float32)
            ),
            m=jnp.ones((b,), jnp.int32),
        )
        for _ in range(k)
    ]


def _linear_kernel_banks(k, b, d, rng):
    """K linear-consistent KernelBanks (B models, 2 live slots each, q ==
    |sum_s coef[s] p[s]|^2) whose total live count fits one buffer — every
    fold order is drop-free, so merge_balls algebra is the exact oracle
    (the construction of tests/test_kernel_merge.py, bank-vectorized)."""
    live_per = 2
    s = live_per * k
    banks = []
    for i in range(k):
        idx = np.full((b, s), -1, np.int32)
        coef = np.zeros((b, s), np.float32)
        pts = np.zeros((b, s, d), np.float32)
        for bi in range(b):
            sl = rng.choice(s, size=live_per, replace=False)
            idx[bi, sl] = i * 1000 + rng.choice(
                999, size=live_per, replace=False
            )
            coef[bi, sl] = rng.normal(size=live_per).astype(np.float32)
            pts[bi, sl] = rng.normal(size=(live_per, d)).astype(np.float32)
        w = np.einsum("bs,bsd->bd", coef, pts)
        banks.append(KernelBank(
            idx=jnp.asarray(idx),
            coef=jnp.asarray(coef),
            points=jnp.asarray(pts),
            q=jnp.asarray(np.sum(w * w, axis=1).astype(np.float32)),
            r=jnp.asarray(np.abs(rng.normal(size=b)).astype(np.float32)),
            xi2=jnp.asarray(
                (0.01 + np.abs(rng.normal(size=b))).astype(np.float32)
            ),
            m=jnp.asarray(rng.integers(1, 9, size=b).astype(np.int32)),
        ))
    return banks


def _emerge(c1, r1, c2, r2):
    """merge_balls in explicit coordinates (the numpy oracle)."""
    dist = float(np.linalg.norm(c1 - c2))
    if dist + r1 <= r2:
        return c2.copy(), r2
    if dist + r2 <= r1:
        return c1.copy(), r1
    rj = 0.5 * (r1 + r2 + dist)
    t = np.clip((rj - r1) / max(dist, 1e-12), 0.0, 1.0)
    return c1 + t * (c2 - c1), rj


def _fold_props_case(kind, k, b, d, seed, atol=1e-4):
    """Every fold order of the live fold helper must (a) agree with the
    explicit orthogonal-slack embedding, (b) enclose every input ball,
    (c) land any two birth orders' centers within min(r) of each other,
    (d) keep radii within the provable 2x band — and be deterministic
    (the same order twice is bit-identical). Per model lane."""
    rng = np.random.default_rng(seed)
    orders = [list(range(k)), list(range(k))[::-1],
              [int(j) for j in np.roll(np.arange(k), 1)]]
    if kind == "linear":
        banks = _rand_ball_banks(k, b, d, rng)
        fold = lambda bs: fold_banks(list(bs))

        def lane(bank, bi):
            return (np.asarray(bank.w[bi], np.float64),
                    float(bank.r[bi]), float(bank.xi2[bi]))
    else:
        banks = _linear_kernel_banks(k, b, d, rng)
        fold = lambda bs: fold_kernel_banks(list(bs), kernel="linear")

        def lane(bank, bi):
            w = np.einsum(
                "s,sd->d", np.asarray(bank.coef[bi], np.float64),
                np.asarray(bank.points[bi], np.float64),
            )
            return w, float(bank.r[bi]), float(bank.xi2[bi])

    folds = {bi: [] for bi in range(b)}
    for order in orders:
        got = fold([banks[i] for i in order])
        assert _bank_eq(got, fold([banks[i] for i in order]))  # determinism
        for bi in range(b):
            cs = np.zeros((k, d + k))
            rs = np.zeros(k)
            for j in range(k):
                w, r, xi2 = lane(banks[j], bi)
                cs[j, :d] = w
                cs[j, d + j] = np.sqrt(xi2)
                rs[j] = r
            c_e, r_e = cs[order[0]].copy(), rs[order[0]]
            for j in order[1:]:
                c_e, r_e = _emerge(c_e, r_e, cs[j], rs[j])
            scale = max(1.0, float(np.max(np.abs(cs))), float(np.max(rs)))
            tol = atol * scale
            gw, gr, gxi2 = lane(got, bi)
            # (a) the implicit fold == the explicit embedding
            np.testing.assert_allclose(gw, c_e[:d], rtol=1e-4, atol=tol)
            np.testing.assert_allclose(gr, r_e, rtol=1e-4, atol=tol)
            np.testing.assert_allclose(
                gxi2, float(np.sum(c_e[d:] ** 2)), rtol=1e-3, atol=tol
            )
            # (b) enclosure of every input ball
            for j in range(k):
                gap = np.linalg.norm(c_e - cs[j]) + rs[j] - r_e
                assert gap <= tol, (kind, order, bi, j, gap)
            folds[bi].append((c_e, r_e))
    # (c) + (d): cross-birth-order bounds
    for bi in range(b):
        fs = folds[bi]
        for a in range(len(fs)):
            for z in range(a + 1, len(fs)):
                (ca, ra), (cz, rz) = fs[a], fs[z]
                tol = atol * max(1.0, ra, rz)
                assert np.linalg.norm(ca - cz) <= min(ra, rz) + tol
                assert max(ra, rz) <= 2.0 * min(ra, rz) + tol


def _dead_slot_case(kind, seed):
    """live-mask dead-slot exactness: folding with zeroed dead slots and a
    live mask is BIT-identical to folding only the live banks."""
    rng = np.random.default_rng(seed)
    k, b, d = 4, 2, 5
    make = _rand_ball_banks if kind == "linear" else _linear_kernel_banks
    banks = make(k, b, d, rng)
    zero = jax.tree.map(jnp.zeros_like, banks[0])
    padded = [banks[0], zero, banks[1], zero, banks[2], banks[3]]
    live = np.asarray([1, 0, 1, 0, 1, 1], bool)
    if kind == "linear":
        want = fold_banks(banks)
        assert _bank_eq(fold_banks(padded, live=live), want)
        # fold_merge twin on the stacked (checkpoint) layout
        got = fold_merge(stack_banks(padded), live=jnp.asarray(live))
        assert _bank_eq(got, want)
    else:
        want = fold_kernel_banks(banks, kernel="linear")
        got = fold_kernel_banks(padded, kernel="linear", live=live)
        assert _bank_eq(got, want)
        # the stacked-KernelBank input form (the checkpoint layout)
        stacked = stack_kernel_banks(padded)
        assert stacked.coef.shape == (6, b, 2 * k)
        got2 = fold_kernel_banks(stacked, kernel="linear", live=live)
        assert _bank_eq(got2, want)
        with pytest.raises(ValueError, match="LIVE"):
            fold_kernel_banks(
                padded, kernel="linear", live=np.zeros(6, bool)
            )


@pytest.mark.parametrize("kind", BANK_KINDS)
def test_live_fold_properties_deterministic(kind):
    """Fixed-seed twin of the hypothesis layer — coverage must not depend
    on the optional dependency (repo convention)."""
    _fold_props_case(kind, k=4, b=2, d=5, seed=11)
    _dead_slot_case(kind, seed=12)


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        kind=st.sampled_from(BANK_KINDS),
        k=st.integers(2, 5),
        b=st.integers(1, 3),
        d=st.integers(1, 6),
        seed=st.integers(0, 10_000),
    )
    def test_live_fold_properties_hypothesis(kind, k, b, d, seed):
        _fold_props_case(kind, k, b, d, seed)

    @settings(max_examples=15, deadline=None)
    @given(kind=st.sampled_from(BANK_KINDS), seed=st.integers(0, 10_000))
    def test_live_fold_dead_slot_exactness_hypothesis(kind, seed):
        _dead_slot_case(kind, seed)


# ---------------------------------------------------------------------------
# kernel-merge re-compression loss audit (live side)
# ---------------------------------------------------------------------------


def test_live_merge_dropped_mass_audit(tmp_path):
    """LiveStats.merge_dropped_mass — exactly 0.0 for linear loops and for
    kernel loops whose live slots always fit S; strictly positive once the
    S=6 buffer forces real drops. (Durability across crashes is covered by
    the crash matrix: merge_dropped_mass is part of durable().)"""
    X, Y = _stream()
    lin = _make(ArraySource(X, Y, CHUNK), tmp_path / "l").run()
    assert lin.merge_dropped_mass == 0.0
    lossy = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "k", bank_kind="kernel"
    ).run()
    assert lossy.merge_dropped_mass > 0.0
    roomy = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "e", bank_kind="kernel",
        coreset_size=N_CHUNKS * CHUNK + 8,
    ).run()
    assert roomy.merge_dropped_mass == 0.0


# ---------------------------------------------------------------------------
# the new kernel-config guards survive `python -O` (no bare asserts)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_kernel_config_guards_survive_python_O():
    """Mixing linear/kernel banks, folding with an all-dead mask, swapping
    a mismatched kernel config, and attaching a mismatched server must all
    be ValueErrors naming both sides — `python -O` cannot strip them."""
    script = r"""
import numpy as np, jax.numpy as jnp
from repro.core import (
    KernelBank, fold_kernel_banks, merge_banks, merge_kernel_banks,
    stack_banks, stack_kernel_banks,
)
from repro.core.meb import Ball
from repro.live import LiveBank
from repro.serve.bank_server import BankServer

ball = Ball(w=jnp.zeros((2, 3)), r=jnp.zeros(2), xi2=jnp.zeros(2),
            m=jnp.ones(2, jnp.int32))
kb = KernelBank(idx=jnp.zeros((2, 4), jnp.int32), coef=jnp.zeros((2, 4)),
                points=jnp.zeros((2, 4, 3)), q=jnp.zeros(2), r=jnp.zeros(2),
                xi2=jnp.zeros(2), m=jnp.ones(2, jnp.int32))

try:  # 1) linear ball into the kernel merge
    merge_kernel_banks(ball, kb, kernel="rbf")
except ValueError as e:
    assert "Ball" in str(e) and "KernelBank" in str(e), e
    print("MIX1_OK")
try:  # 2) kernel bank into the linear merge
    merge_banks(kb, kb)
except ValueError as e:
    assert "KernelBank" in str(e), e
    print("MIX2_OK")
try:  # 3) kernel bank into the linear stack
    stack_banks([kb])
except ValueError as e:
    assert "KernelBank" in str(e), e
    print("MIX3_OK")
try:  # 4) linear ball into the kernel stack
    stack_kernel_banks([ball])
except ValueError as e:
    assert "Ball" in str(e), e
    print("MIX4_OK")
try:  # 5) all-dead live mask has nothing to fold
    fold_kernel_banks([kb, kb], kernel="rbf", live=np.zeros(2, bool))
except ValueError as e:
    assert "LIVE" in str(e), e
    print("LIVE_OK")

srv = BankServer(kb, kernel="rbf", gamma=0.5)
try:  # 6) hot-swap declaring a drifted gamma
    srv.swap_bank(kb, kernel="rbf", gamma=0.9)
except ValueError as e:
    assert "gamma=0.9" in str(e) and "gamma=0.5" in str(e), e
    print("SWAP_OK")

live = LiveBank(lambda i: None, jnp.ones(2), ckpt_dir="unused",
                bank_kind="kernel", kernel="rbf", gamma=0.7,
                sleep=lambda s: None)
try:  # 7) attaching a server with a mismatched kernel config
    live.attach_server(srv)
except ValueError as e:
    assert "gamma=0.7" in str(e) and "gamma=0.5" in str(e), e
    print("ATTACH_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-O", "-c", script],
        env=env, capture_output=True, text=True, timeout=300,
    )
    assert out.returncode == 0, (
        f"stdout:{out.stdout[-2000:]}\nstderr:{out.stderr[-4000:]}"
    )
    for token in ("MIX1_OK", "MIX2_OK", "MIX3_OK", "MIX4_OK", "LIVE_OK",
                  "SWAP_OK", "ATTACH_OK"):
        assert token in out.stdout, out.stdout


# ---------------------------------------------------------------------------
# elastic sharded training (mesh= / n_stream_shards=): referents, faults,
# the publish guard, rotate_on, remesh resume, and the chaos harness
# ---------------------------------------------------------------------------


def _need_mesh(n):
    if len(jax.devices()) < n:
        pytest.skip(
            f"needs {n} devices (run with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n})"
        )
    return jax.make_mesh((n,), ("data",))


def _elastic_referent(X, Y, kind, n_shards, drop=None):
    """The documented K=1 elastic referent: every chunk splits into the
    LOGICAL ``shard_ranges``, each range fits FRESH, ranges fold ascending
    through the eager Sec-4.3 merges, and the prior merges in last. ``drop``
    maps chunk index -> shard ids whose assigned range is masked out (the
    poison / all-dead outcome); the stream offset still advances by the FULL
    chunk so kernel core-set ids stay replay-stable."""
    drop = drop or {}
    merge_kw = dict(kernel=KERNEL_KW["kernel"], gamma=KERNEL_KW["gamma"])
    n_chunks = -(-X.shape[0] // CHUNK)
    ref, rows = None, 0
    for i in range(n_chunks):
        Xc = jnp.asarray(X[i * CHUNK:(i + 1) * CHUNK])
        Yc = jnp.asarray(Y[:, i * CHUNK:(i + 1) * CHUNK])
        n = int(Xc.shape[0])
        banks = []
        for j, (lo, hi) in enumerate(shard_ranges(n, n_shards)):
            if lo >= hi or j in drop.get(i, ()):
                continue
            if kind == "kernel":
                b = fit_kernel_bank(
                    Xc[lo:hi], Yc[:, lo:hi], CS,
                    kernel=KERNEL_KW["kernel"], gamma=KERNEL_KW["gamma"],
                    coreset_size=KERNEL_KW["coreset_size"],
                    block_n=KERNEL_KW["block_n"],
                )
                b = b._replace(idx=jnp.where(b.idx >= 0, b.idx + lo, b.idx))
            else:
                b = fit_bank(Xc[lo:hi], Yc[:, lo:hi], CS, None)
            banks.append(b)
        if banks:
            if kind == "kernel":
                folded = fold_kernel_banks(banks, **merge_kw)
                folded = folded._replace(
                    idx=jnp.where(folded.idx >= 0, folded.idx + rows,
                                  folded.idx)
                )
                ref = folded if ref is None else merge_kernel_banks(
                    ref, folded, **merge_kw
                )
            else:
                folded = banks[0] if len(banks) == 1 else fold_merge(
                    stack_banks(banks)
                )
                ref = folded if ref is None else merge_banks(ref, folded)
        rows += n
    return ref


@pytest.mark.parametrize("kind", BANK_KINDS)
def test_elastic_matches_per_range_referent(tmp_path, kind):
    """n_stream_shards=4 without any mesh: each chunk is four fresh range
    fits folded ascending, prior merged last — bit-identical to the
    hand-built referent for BOTH bank kinds."""
    X, Y = _stream()
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", bank_kind=kind,
        n_sub_banks=1, rotate_every=10**9, swap_every=1, n_stream_shards=4,
    )
    stats = live.run()
    assert _bank_eq(live.serving_bank(), _elastic_referent(X, Y, kind, 4))
    assert stats.rows_ingested == N_CHUNKS * CHUNK
    assert stats.rows_lost == stats.ranges_reissued == 0
    if kind == "kernel":
        idx = np.asarray(live.serving_bank().idx)
        assert idx.max() >= CHUNK  # absolute stream coordinates survived
        assert idx[idx >= 0].max() < N_CHUNKS * CHUNK


@pytest.mark.parametrize("kind", BANK_KINDS)
def test_elastic_one_device_mesh_fast_path(tmp_path, kind):
    """A 1-device mesh takes the mesh FAST path (devices == logical shards)
    in the fast CI suite: the kernel loop is bit-identical to the legacy
    single path (fresh fit + Sec-4.3 merge either way), the linear loop to
    its fresh-fit + merge referent (elastic semantics: the engine
    continuation is the documented legacy-only difference)."""
    mesh1 = _need_mesh(1)
    X, Y = _stream()
    if kind == "kernel":
        fast = _make(
            ArraySource(X, Y, CHUNK), tmp_path / "m", bank_kind=kind,
            mesh=mesh1, n_stream_shards=1,
        )
        sf = fast.run()
        legacy = _make(
            ArraySource(X, Y, CHUNK), tmp_path / "l", bank_kind=kind,
        )
        sl = legacy.run()
        assert _bank_eq(fast.serving_bank(), legacy.serving_bank())
        assert np.array_equal(
            _served_scores(fast.serving_bank()),
            _served_scores(legacy.serving_bank()),
        )
        assert sf.durable() == sl.durable()
    else:
        fast = _make(
            ArraySource(X, Y, CHUNK), tmp_path / "m", bank_kind=kind,
            mesh=mesh1, n_stream_shards=1,
            n_sub_banks=1, rotate_every=10**9, swap_every=1,
        )
        fast.run()
        assert _bank_eq(
            fast.serving_bank(), _elastic_referent(X, Y, kind, 1)
        )


@pytest.mark.parametrize("kind", BANK_KINDS)
def test_elastic_ragged_chunks_and_empty_tails(tmp_path, kind):
    """Ragged everything: a 7-row final chunk under n_stream_shards=5 gives
    ceil ranges (2,2,2,1) plus an EMPTY tail shard — the loop and the
    referent agree bit-exactly and account every row."""
    X, Y = _stream()
    n = 3 * CHUNK + 7
    X, Y = X[:n], Y[:, :n]
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", bank_kind=kind,
        n_sub_banks=1, rotate_every=10**9, swap_every=1, n_stream_shards=5,
    )
    stats = live.run()
    assert stats.rows_ingested == n
    assert stats.rows_lost == 0
    assert _bank_eq(live.serving_bank(), _elastic_referent(X, Y, kind, 5))


@pytest.mark.parametrize("kind", BANK_KINDS)
def test_elastic_crash_equivalence(tmp_path, kind):
    """The crash matrix holds on the ELASTIC path too: crashes at four
    phase boundaries of a n_stream_shards=3 run recover bit-identically —
    bank, served scores, durable stats (now including the loss/reissue
    counters)."""
    X, Y = _stream()
    clean = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "a", bank_kind=kind,
        n_stream_shards=3,
    )
    ref_stats = clean.run()
    fps = [("fetch", 1), ("post_train", 3), ("mid_checkpoint", 5),
           ("post_swap", 7)]
    crashy = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "b", bank_kind=kind,
        n_stream_shards=3, failpoints=fps,
    )
    stats = run_live_with_restarts(crashy, sleep=_NOSLEEP)
    assert stats.restarts == 4
    assert _bank_eq(crashy.serving_bank(), clean.serving_bank())
    assert np.array_equal(
        _served_scores(crashy.serving_bank()),
        _served_scores(clean.serving_bank()),
    )
    assert stats.durable() == ref_stats.durable()


@pytest.mark.parametrize("kind", BANK_KINDS)
def test_flaky_shard_within_budget_invisible(tmp_path, kind):
    """A flaky shard that delivers within the per-shard retry budget changes
    NOTHING: same rows, same fold partition, so the bank and every durable
    stat are bit-identical to the fault-free run — only the volatile
    shard_retries counter moves."""
    X, Y = _stream()
    clean = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "a", bank_kind=kind,
        n_stream_shards=3,
    )
    ref_stats = clean.run()
    faulty = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "b", bank_kind=kind,
        n_stream_shards=3, shard_faults=ShardFaults(flaky={(1, 0): 2}),
    )
    stats = faulty.run()
    assert _bank_eq(faulty.serving_bank(), clean.serving_bank())
    assert np.array_equal(
        _served_scores(faulty.serving_bank()),
        _served_scores(clean.serving_bank()),
    )
    assert stats.shard_retries == 2  # the flaky shard's two burned retries
    assert stats.rows_lost == 0 and stats.ranges_reissued == 0
    assert stats.durable() == ref_stats.durable()


@pytest.mark.parametrize("kind", BANK_KINDS)
def test_lost_and_straggler_reissue_deterministic(tmp_path, kind):
    """A lost device's range and a declared straggler's range re-issue to
    the survivors — a DIFFERENT (but deterministic) fold partition, no rows
    lost. The structural contract: the same fault plan replays identically
    through crashes, so a run crashing right at the faulty chunks recovers
    bit-identical banks, scores and durable stats."""
    X, Y = _stream()

    def make(name, **kw):
        return _make(
            ArraySource(X, Y, CHUNK), tmp_path / name, bank_kind=kind,
            n_stream_shards=3,
            shard_faults=ShardFaults(
                lost={2: (1,)}, slow={5: (1.0, 1.0, 10.0)},
            ),
            straggler_policy=StragglerPolicy(), **kw,
        )

    smooth = make("a")
    ref_stats = smooth.run()
    assert ref_stats.ranges_reissued == 2  # one lost + one straggler range
    assert ref_stats.rows_lost == 0 and ref_stats.shard_ranges_lost == 0

    crashy = make("b", failpoints=[("post_train", 2), ("fetch", 5)])
    stats = run_live_with_restarts(crashy, sleep=_NOSLEEP)
    assert stats.restarts == 2
    assert _bank_eq(crashy.serving_bank(), smooth.serving_bank())
    assert np.array_equal(
        _served_scores(crashy.serving_bank()),
        _served_scores(smooth.serving_bank()),
    )
    assert stats.durable() == ref_stats.durable()


@pytest.mark.parametrize("kind", BANK_KINDS)
def test_poison_shard_masked_with_loss_recorded(tmp_path, kind):
    """A shard whose fetch faults outlive the retry budget is masked out:
    its range's rows are recorded in rows_lost / shard_ranges_lost, the
    fold simply skips it (bit-identical to the referent that never saw
    those rows), and the stream offset still advances by the FULL chunk so
    later kernel ids keep their absolute coordinates."""
    X, Y = _stream()
    faults = ShardFaults(flaky={(2, 1): ShardFaults.POISON})
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", bank_kind=kind,
        n_sub_banks=1, rotate_every=10**9, swap_every=1,
        n_stream_shards=4, shard_faults=faults,
    )
    stats = live.run()
    assert stats.rows_lost == CHUNK // 4
    assert stats.shard_ranges_lost == 1
    assert stats.shard_retries == 2  # the default per-shard budget, burned
    assert stats.rows_ingested == N_CHUNKS * CHUNK  # full-chunk advance
    assert _bank_eq(
        live.serving_bank(),
        _elastic_referent(X, Y, kind, 4, drop={2: {1}}),
    )


def test_all_shards_dead_chunk_masked(tmp_path):
    """Every shard of one chunk lost at once: no survivor to re-issue to,
    so the whole chunk degrades to recorded loss and the bank equals the
    referent that skipped it."""
    X, Y = _stream()
    faults = ShardFaults(lost={1: (0, 1, 2)})
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c",
        n_sub_banks=1, rotate_every=10**9, swap_every=1,
        n_stream_shards=3, shard_faults=faults,
    )
    stats = live.run()
    assert stats.rows_lost == CHUNK
    assert stats.shard_ranges_lost == 3
    assert stats.ranges_reissued == 0
    assert _bank_eq(
        live.serving_bank(),
        _elastic_referent(X, Y, "linear", 3, drop={1: {0, 1, 2}}),
    )


def test_resume_adopts_checkpoint_shards_rejects_explicit_mismatch(tmp_path):
    """n_stream_shards is durable: an explicit mismatch at resume is a
    ValueError naming both sides; an implicit (defaulted) loop ADOPTS the
    checkpoint's logical shard count and continues bit-identically."""
    X, Y = _stream()
    first = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", n_stream_shards=3,
    )
    first.run(max_chunks=4)

    with pytest.raises(ValueError, match="n_stream_shards=3"):
        _make(
            ArraySource(X, Y, CHUNK), tmp_path / "c", n_stream_shards=2,
        ).run()

    resumed = _make(ArraySource(X, Y, CHUNK), tmp_path / "c")
    stats = resumed.run()
    assert resumed.n_stream_shards == 3
    assert stats.remeshes == 0  # same (absent) mesh on both sides

    clean = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "ref", n_stream_shards=3,
    )
    ref_stats = clean.run()
    assert _bank_eq(resumed.serving_bank(), clean.serving_bank())
    assert stats.durable() == ref_stats.durable()


# ---------------------------------------------------------------------------
# the non-finite publish guard
# ---------------------------------------------------------------------------


def test_nonfinite_rows_unit():
    """nonfinite_rows flags exactly the poisoned model rows, over any float
    leaf, and ignores the integer leaves."""
    w = np.zeros((3, 4), np.float32)
    w[1, 2] = np.nan
    bank = Ball(
        w=jnp.asarray(w), r=jnp.zeros(3), xi2=jnp.ones(3),
        m=jnp.ones((3,), jnp.int32),
    )
    assert np.asarray(nonfinite_rows(bank)).tolist() == [False, True, False]
    r = np.zeros(3, np.float32)
    r[0] = np.inf
    bank2 = bank._replace(w=jnp.zeros((3, 4)), r=jnp.asarray(r))
    assert np.asarray(nonfinite_rows(bank2)).tolist() == [True, False, False]


def _poisoned_stream():
    """The clean stream with chunk 1's rows NaN-poisoned."""
    X, Y = _stream()
    X = X.copy()
    X[CHUNK:2 * CHUNK] = np.nan
    return X, Y


def test_nonfinite_fold_quarantined_by_default(tmp_path):
    """A NaN-poisoned chunk must never reach the server: the poisoned folds
    are quarantined (counted, not pushed), the server keeps the last good
    bank, and once the poisoned epoch retires the loop publishes again."""
    X, Y = _poisoned_stream()
    srv = _RecordingServer()
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", server=srv, retire="drop",
    )
    stats = live.run()
    # folds at chunks 2 and 4 hold the poisoned epoch; the chunk-6 rotation
    # drops it (retire="drop", K=2), so folds 6/8/10 publish again
    assert stats.folds_quarantined == 2
    assert stats.folds == 3
    assert len(srv.banks) == 3
    for bank in srv.banks + [live.serving_bank()]:
        assert not bool(np.any(np.asarray(nonfinite_rows(bank))))
    # durability: the counter survives a crash (it is part of durable())
    assert "folds_quarantined" in stats.durable()


def test_nonfinite_fold_strict_raises_naming_rows(tmp_path):
    """strict_finite=True turns the quarantine into a loud ValueError that
    names the poisoned model rows and the chunk."""
    X, Y = _poisoned_stream()
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", strict_finite=True,
    )
    with pytest.raises(
        ValueError,
        match=r"non-finite serving fold at chunk 2.*\[0, 1, 2\]",
    ):
        live.run()
    assert live.serving_bank() is None  # nothing poisoned was ever served


# ---------------------------------------------------------------------------
# pluggable rotation triggers (rotate_on=)
# ---------------------------------------------------------------------------


def test_rotate_on_matches_epoch_referent(tmp_path):
    """A rotate_on callable reproducing the cadence is bit-identical to the
    built-in rotate_every — same rotations, same bank, same stats."""
    X, Y = _stream()
    cadence = _make(ArraySource(X, Y, CHUNK), tmp_path / "a", rotate_every=3)
    ref_stats = cadence.run()
    custom = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "b", rotate_every=10**9,
        rotate_on=lambda s: s.chunks_ingested % 3 == 0,
    )
    stats = custom.run()
    assert stats.rotations == ref_stats.rotations == 3
    assert _bank_eq(custom.serving_bank(), cadence.serving_bank())
    assert stats.durable() == ref_stats.durable()


def test_rotate_on_composes_with_rotate_every(tmp_path):
    """rotate_on fires IN ADDITION to rotate_every (consulted only when the
    cadence did not already rotate): rotate_every=4 plus an every-3-chunks
    trigger rotates at 3,4,6,8,9 — five rotations over ten chunks."""
    X, Y = _stream()
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", rotate_every=4,
        rotate_on=lambda s: s.chunks_ingested % 3 == 0,
    )
    stats = live.run()
    assert stats.rotations == 5


def test_rotate_on_replay_stable_across_crash(tmp_path):
    """rotate_on sees only replay-stable durable stats, so a crash-recovered
    run re-fires the custom rotations identically — the bank and durable
    stats match the uninterrupted rotate_on run bit-exactly."""
    X, Y = _stream()
    trigger = lambda s: s.chunks_ingested % 3 == 0
    clean = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "a", rotate_every=10**9,
        rotate_on=trigger,
    )
    ref_stats = clean.run()
    crashy = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "b", rotate_every=10**9,
        rotate_on=trigger, failpoints=[("post_rotate", 5), ("post_fold", 7)],
    )
    stats = run_live_with_restarts(crashy, sleep=_NOSLEEP)
    assert stats.restarts == 2
    assert _bank_eq(crashy.serving_bank(), clean.serving_bank())
    assert stats.durable() == ref_stats.durable()


# ---------------------------------------------------------------------------
# chaos: kills + shard faults + remesh-on-restart, bit-identical recovery
# ---------------------------------------------------------------------------


def _chaos_make_live(X, Y, kind, base_dir, name, n_shards, **extra):
    def make_live(mesh, failpoints, faults):
        return _make(
            ArraySource(X, Y, CHUNK), base_dir / name, bank_kind=kind,
            mesh=mesh, n_stream_shards=n_shards, shard_faults=faults,
            failpoints=failpoints, straggler_policy=StragglerPolicy(),
            **extra,
        )
    return make_live


@pytest.mark.parametrize("kind", BANK_KINDS)
def test_chaos_without_mesh_bit_exact(tmp_path, kind):
    """The fast-suite chaos run: seeded kills + shard faults over a
    n_stream_shards=4 stream, no mesh — recovered bank, served scores and
    durable stats bit-identical to the crash-free reference."""
    X, Y = _stream()
    sched = chaos_schedule(
        11, n_chunks=N_CHUNKS, n_shards=4, kills=3,
        kill_phases=("fetch", "post_train"),
        lost_chunks=1, flaky_chunks=1, poison_chunks=1, slow_chunks=1,
    )
    chaos = run_chaos(
        _chaos_make_live(X, Y, kind, tmp_path, "chaos", 4), sched
    )
    ref = chaos_reference(
        _chaos_make_live(X, Y, kind, tmp_path, "ref", 4), sched
    )
    assert chaos.stats.restarts == 3
    assert _bank_eq(chaos.serving_bank(), ref.serving_bank())
    assert np.array_equal(
        _served_scores(chaos.serving_bank()),
        _served_scores(ref.serving_bank()),
    )
    assert chaos.stats.durable() == ref.stats.durable()
    assert chaos.stats.rows_lost > 0  # the poison chunk really masked rows


@pytest.mark.slow
@pytest.mark.parametrize("kind", BANK_KINDS)
def test_chaos_with_remesh_schedule_bit_exact(tmp_path, kind):
    """THE acceptance run: a 16-chunk drifting stream on an 8-device mesh,
    four seeded kills remeshing 8 -> 4 -> single-device, plus lost/flaky/
    poison/straggler shards — the final bank, served scores and durable
    stats are bit-identical (f32) to the crash-free no-mesh reference, for
    BOTH bank kinds."""
    mesh8 = _need_mesh(8)
    mesh4 = jax.make_mesh((4,), ("data",))
    X, Y = _stream(16)
    sched = chaos_schedule(
        7, n_chunks=16, n_shards=8, kills=4,
        kill_phases=("fetch", "post_train"),
    )
    chaos = run_chaos(
        _chaos_make_live(X, Y, kind, tmp_path, "chaos", 8), sched,
        meshes=(mesh8, mesh4, None),
    )
    ref = chaos_reference(
        _chaos_make_live(X, Y, kind, tmp_path, "ref", 8), sched
    )
    assert chaos.stats.restarts == 4
    assert chaos.stats.remeshes == 1  # the final relaunch adopted [4]->None
    assert _bank_eq(chaos.serving_bank(), ref.serving_bank())
    assert np.array_equal(
        _served_scores(chaos.serving_bank()),
        _served_scores(ref.serving_bank()),
    )
    assert chaos.stats.durable() == ref.stats.durable()
    assert chaos.stats.rows_lost > 0
    assert chaos.stats.ranges_reissued > 0


@pytest.mark.slow
@pytest.mark.parametrize("kind", BANK_KINDS)
def test_elastic_mesh_fast_path_matches_degraded(tmp_path, kind):
    """8 logical shards on an 8-device mesh (the single-dispatch fast path)
    == the same 8 logical shards with no mesh at all (per-range fits):
    bank, served scores, durable stats, bit for bit."""
    _need_mesh(8)
    mesh8 = jax.make_mesh((8,), ("data",))
    X, Y = _stream()
    fast = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "m", bank_kind=kind,
        mesh=mesh8, n_stream_shards=8,
    )
    sf = fast.run()
    slow = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "s", bank_kind=kind,
        n_stream_shards=8,
    )
    ss = slow.run()
    assert _bank_eq(fast.serving_bank(), slow.serving_bank())
    assert np.array_equal(
        _served_scores(fast.serving_bank()),
        _served_scores(slow.serving_bank()),
    )
    assert sf.durable() == ss.durable()


@pytest.mark.slow
@pytest.mark.parametrize(
    "kind,schedule",
    [(k, s) for k in BANK_KINDS for s in ("8-4-1", "4-8")],
)
def test_elastic_remesh_resume(tmp_path, kind, schedule):
    """Elastic resume across device counts: a run killed twice remeshes
    8 -> 4 -> single-device (or 4 -> 8), restoring slots onto the new mesh
    each time — including restores where some K slots are still dead — and
    finishes bit-identical to the uninterrupted no-mesh run with the same
    logical shard count."""
    _need_mesh(8)
    mesh8 = jax.make_mesh((8,), ("data",))
    mesh4 = jax.make_mesh((4,), ("data",))
    if schedule == "8-4-1":
        n_shards, meshes = 8, [mesh8, mesh4, None]
        # the first kill lands right after the chunk-2 commit — the only
        # one so far, holding a half-populated slot set (K=2, slot B is
        # first written at the chunk-3 rotation): the mesh4 restore must
        # re-place live AND dead slots
        fps = {("post_train", 2), ("post_fold", 5)}
    else:
        n_shards, meshes = 4, [mesh4, mesh8, None]
        fps = {("post_train", 3), ("post_swap", 7)}
    X, Y = _stream()
    clean = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "ref", bank_kind=kind,
        n_stream_shards=n_shards,
    )
    ref_stats = clean.run()

    failpoints = set(fps)  # shared across relaunches (kills fire once)
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", bank_kind=kind,
        mesh=meshes[0], n_stream_shards=n_shards, failpoints=failpoints,
    )
    crashes = 0
    for mesh in meshes[1:]:
        with pytest.raises(InjectedFailure):
            live.run()
        crashes += 1
        live = _make(
            ArraySource(X, Y, CHUNK), tmp_path / "c", bank_kind=kind,
            mesh=mesh, failpoints=failpoints,  # shards adopted from ckpt
        )
    stats = live.run()
    assert crashes == 2
    assert live.n_stream_shards == n_shards
    assert stats.remeshes == 1  # this relaunch's mesh differed from meta
    assert _bank_eq(live.serving_bank(), clean.serving_bank())
    assert np.array_equal(
        _served_scores(live.serving_bank()),
        _served_scores(clean.serving_bank()),
    )
    assert stats.durable() == ref_stats.durable()
