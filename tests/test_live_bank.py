"""Live loop: crash equivalence at every phase boundary, retry/quarantine,
K-sub-bank drift repair, server survival, and the fold helpers."""
import functools
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import fit_bank, fold_banks, merge_banks, stack_banks
from repro.core.meb import Ball
from repro.live import (
    PHASES,
    ArraySource,
    FlakySource,
    LiveBank,
    TransientSourceError,
    run_live_with_restarts,
)
from repro.runtime import InjectedFailure, RetryPolicy

D, B, CHUNK, N_CHUNKS = 8, 3, 32, 10
CS = jnp.asarray([0.5, 2.0, 8.0], jnp.float32)
_NOSLEEP = lambda s: None


def _stream(n_chunks=N_CHUNKS, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n_chunks * CHUNK, D)).astype(np.float32)
    X /= np.linalg.norm(X, axis=1, keepdims=True)
    y = np.sign(rng.normal(size=X.shape[0]) + X[:, 0]).astype(np.float32)
    return X, np.tile(y, (B, 1))


def _make(source, ckpt_dir, **kw):
    kw.setdefault("n_sub_banks", 2)
    kw.setdefault("rotate_every", 3)
    kw.setdefault("swap_every", 2)
    kw.setdefault("sleep", _NOSLEEP)
    return LiveBank(source, CS, ckpt_dir=str(ckpt_dir), **kw)


def _bank_eq(a: Ball, b: Ball) -> bool:
    return all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(a, b)
    )


# ---------------------------------------------------------------------------
# training semantics
# ---------------------------------------------------------------------------


def test_single_slot_matches_sequential_fit_bank(tmp_path):
    """K=1 with no rotation is exactly the chunked one-pass bank fit."""
    X, Y = _stream()
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c",
        n_sub_banks=1, rotate_every=10**9, swap_every=1,
    )
    live.run()

    ref = None
    for i in range(N_CHUNKS):
        lo = i * CHUNK
        ref = fit_bank(
            jnp.asarray(X[lo:lo + CHUNK]),
            jnp.asarray(Y[:, lo:lo + CHUNK]), CS, ref,
        )
    assert _bank_eq(live.serving_bank(), ref)


def test_clean_run_stats_accounting(tmp_path):
    """Cadence arithmetic: rotations at 3/6/9, folds+swaps+ckpts at every
    even chunk, retirements once both K=2 slots are full."""
    X, Y = _stream()
    stats = _make(ArraySource(X, Y, CHUNK), tmp_path / "c").run()
    assert stats.chunks_ingested == N_CHUNKS
    assert stats.rows_ingested == N_CHUNKS * CHUNK
    assert stats.rotations == 3 and stats.retirements == 2
    assert stats.folds == stats.swaps == stats.checkpoints == 5
    assert stats.last_swap_chunk == N_CHUNKS
    assert stats.bank_age_chunks == 0 and stats.quarantined == []


def test_rotation_retirement_exact():
    """K=2, rotate_every=2 over 8 chunks pins the retirement semantics:
    retire='drop' serves ONLY the final epoch's bank (epochs e0..e2 were
    dropped), retire='merge' serves merge(merge(merge(e0,e1),e2),e3) —
    both bit-identical to the hand-built referents."""
    X, Y = _stream(8, seed=3)

    def fit_epoch(e, prior=None):
        ref = prior
        for c in (2 * e, 2 * e + 1):
            lo = c * CHUNK
            ref = fit_bank(
                jnp.asarray(X[lo:lo + CHUNK]),
                jnp.asarray(Y[:, lo:lo + CHUNK]), CS, ref,
            )
        return ref

    epochs = [fit_epoch(e) for e in range(4)]
    banks = {}
    for retire in ("drop", "merge"):
        import tempfile
        with tempfile.TemporaryDirectory() as td:
            live = _make(
                ArraySource(X, Y, CHUNK), td, n_sub_banks=2,
                rotate_every=2, swap_every=8, retire=retire,
            )
            stats = live.run()
            assert stats.rotations == 4 and stats.retirements == 3
            banks[retire] = live.serving_bank()

    assert _bank_eq(banks["drop"], epochs[3])
    assert _bank_eq(
        banks["merge"], functools.reduce(merge_banks, epochs)
    )
    assert not _bank_eq(banks["drop"], banks["merge"])


def test_fold_helpers():
    X, Y = _stream(3, seed=5)
    chunks = [
        fit_bank(
            jnp.asarray(X[i * CHUNK:(i + 1) * CHUNK]),
            jnp.asarray(Y[:, i * CHUNK:(i + 1) * CHUNK]), CS,
        )
        for i in range(3)
    ]
    stacked = stack_banks(chunks)
    assert stacked.w.shape == (3, B, D) and stacked.r.shape == (3, B)
    # deterministic: the same fold twice is bit-identical; numerically it is
    # the sequential left merge (last-ulp apart from the eager python
    # reduce — jit fuses the scan arithmetic differently)
    assert _bank_eq(fold_banks(chunks), fold_banks(list(chunks)))
    eager = functools.reduce(merge_banks, chunks)
    for a, b in zip(fold_banks(chunks), eager):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7
        )
    assert int(fold_banks(chunks).m.sum()) == int(eager.m.sum())
    assert fold_banks(chunks[:1]) is chunks[0]
    with pytest.raises(ValueError, match="empty"):
        fold_banks([])
    with pytest.raises(ValueError, match="empty"):
        stack_banks(())


def test_constructor_validation(tmp_path):
    X, Y = _stream(1)
    src = ArraySource(X, Y, CHUNK)
    with pytest.raises(ValueError, match="n_sub_banks"):
        _make(src, tmp_path, n_sub_banks=0)
    with pytest.raises(ValueError, match="rotate_every"):
        _make(src, tmp_path, rotate_every=0)
    with pytest.raises(ValueError, match="retire"):
        _make(src, tmp_path, retire="evict")
    with pytest.raises(ValueError, match="unknown failpoint phase"):
        _make(src, tmp_path, failpoints=[("pre_train", 3)])
    with pytest.raises(ValueError, match="chunk_size"):
        ArraySource(X, Y, 0)


# ---------------------------------------------------------------------------
# crash equivalence — the acceptance criterion
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def clean_reference(tmp_path_factory):
    """The uninterrupted run every crashy variant must reproduce bit-exactly."""
    X, Y = _stream()
    live = _make(
        ArraySource(X, Y, CHUNK),
        tmp_path_factory.mktemp("clean") / "c",
    )
    stats = live.run()
    return live.serving_bank(), stats.durable()


@pytest.mark.parametrize("phase", PHASES)
def test_crash_equivalence_at_every_phase(tmp_path, phase, clean_reference):
    """Inject a crash at each phase boundary of chunk 5 (where rotation,
    fold, swap and checkpoint ALL fire: chunk_idx 6 is divisible by both
    cadences) — one restart later the bank and the durable accounting are
    bit-identical to the uninterrupted run."""
    ref_bank, ref_stats = clean_reference
    X, Y = _stream()
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", failpoints=[(phase, 5)]
    )
    stats = run_live_with_restarts(live, sleep=_NOSLEEP)
    assert stats.restarts == 1, f"failpoint {phase!r} never fired"
    assert _bank_eq(live.serving_bank(), ref_bank)
    assert stats.durable() == ref_stats
    # recovery swept up any mid-commit debris (mid_checkpoint drops a torn
    # .tmp in the directory first; the next commit's GC removes it)
    leftover = [f for f in os.listdir(tmp_path / "c") if f.endswith(".tmp")]
    assert leftover == []


def test_repeated_crashes_still_converge(tmp_path, clean_reference):
    """Five crashes at five different boundaries in one run."""
    ref_bank, ref_stats = clean_reference
    X, Y = _stream()
    fps = [("fetch", 1), ("post_train", 3), ("post_fold", 5),
           ("mid_checkpoint", 7), ("post_swap", 9)]
    live = _make(ArraySource(X, Y, CHUNK), tmp_path / "c", failpoints=fps)
    stats = run_live_with_restarts(live, sleep=_NOSLEEP)
    assert stats.restarts == 5
    assert _bank_eq(live.serving_bank(), ref_bank)
    assert stats.durable() == ref_stats


def test_run_live_nonretryable_propagates(tmp_path):
    """run_live_with_restarts must not eat programming errors."""
    def bad_source(i):
        raise TypeError("a bug, not infrastructure")

    live = _make(bad_source, tmp_path / "c")
    # TypeError is not in the fetch RetryPolicy either: straight through
    with pytest.raises(TypeError, match="a bug"):
        run_live_with_restarts(live, sleep=_NOSLEEP)
    assert live.stats.restarts == 0 and live.stats.retries == 0


def test_resume_rejects_mismatched_configuration(tmp_path):
    X, Y = _stream(4)
    _make(ArraySource(X, Y, CHUNK), tmp_path / "c").run()
    other = _make(ArraySource(X, Y, CHUNK), tmp_path / "c", n_sub_banks=3)
    with pytest.raises(ValueError, match="K=2"):
        other.run()


def test_checkpointing_disabled(tmp_path):
    X, Y = _stream(4)
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", checkpoint_every_folds=0
    )
    stats = live.run()
    assert stats.checkpoints == 0
    assert not ckpt.exists(str(tmp_path / "c"))


# ---------------------------------------------------------------------------
# retry / quarantine
# ---------------------------------------------------------------------------


def test_fetch_retry_backoff_and_quarantine(tmp_path):
    """Transient chunk delivers after its faults; poison chunk exhausts the
    budget into quarantine; the recorded sleeps are the capped exponential."""
    X, Y = _stream()
    delays = []
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "c", sleep=delays.append,
        retry=RetryPolicy(
            retryable=(TransientSourceError,), max_retries=2,
            backoff_base=0.1, backoff_cap=0.15,
        ),
    )
    live.source = FlakySource(
        live.source, {1: 2, 4: FlakySource.POISON}
    )
    stats = live.run()
    # chunk 1: two faults then delivered; chunk 4: 2 retries then quarantined
    assert stats.retries == 4
    assert delays == [0.1, 0.15, 0.1, 0.15]
    assert stats.quarantined == [4]
    assert stats.chunks_ingested == N_CHUNKS - 1
    assert stats.rows_ingested == (N_CHUNKS - 1) * CHUNK
    # a quarantined chunk keeps its stream position
    assert live.chunk_idx == N_CHUNKS


def test_fetch_nonretryable_propagates(tmp_path):
    X, Y = _stream()
    live = _make(ArraySource(X, Y, CHUNK), tmp_path / "c")
    live.source = FlakySource(live.source, {2: 1}, exc=ZeroDivisionError)
    with pytest.raises(ZeroDivisionError):
        live.run()
    assert live.stats.retries == 0


# ---------------------------------------------------------------------------
# server decoupling
# ---------------------------------------------------------------------------


class _RecordingServer:
    """Stand-in hot-swap target: remembers every bank it was handed."""

    def __init__(self):
        self.banks = []

    def swap_bank(self, bank):
        self.banks.append(bank)


def test_server_survives_trainer_crash(tmp_path):
    """The server object outlives the trainer: it keeps the last good bank
    through the crash (staleness visible in bank_age_chunks), and the
    post-restart swap history is bit-identical to the crash-free run's."""
    X, Y = _stream()

    clean_srv = _RecordingServer()
    _make(ArraySource(X, Y, CHUNK), tmp_path / "a", server=clean_srv).run()

    srv = _RecordingServer()
    live = _make(
        ArraySource(X, Y, CHUNK), tmp_path / "b", server=srv,
        failpoints=[("post_train", 5)],
    )
    with pytest.raises(InjectedFailure):
        live.run()
    # trainer is down; the server still holds the chunk-4 bank and knows
    # how stale it is (chunk 5 ingested since the swap)
    assert len(srv.banks) == 2
    assert _bank_eq(srv.banks[-1], clean_srv.banks[1])
    assert live.stats.bank_age_chunks == 1

    live.run()  # recovery: resume from the durable checkpoint
    assert len(srv.banks) == len(clean_srv.banks) == 5
    assert all(_bank_eq(a, b) for a, b in zip(srv.banks, clean_srv.banks))


def test_attach_server_pushes_current_bank(tmp_path):
    X, Y = _stream(4)
    live = _make(ArraySource(X, Y, CHUNK), tmp_path / "c")
    live.run()
    srv = _RecordingServer()
    live.attach_server(srv)
    assert len(srv.banks) == 1 and _bank_eq(srv.banks[0], live.serving_bank())


# ---------------------------------------------------------------------------
# process-level crash: the trainer actually dies
# ---------------------------------------------------------------------------

_SUBPROC = r"""
import os, sys
import numpy as np, jax.numpy as jnp
from repro.checkpoint import ckpt
from repro.live import ArraySource, LiveBank
from repro.runtime import InjectedFailure

ckpt_dir, out_dir, mode = sys.argv[1], sys.argv[2], sys.argv[3]
rng = np.random.default_rng(7)
X = rng.normal(size=(8 * 16, 4)).astype(np.float32)
y = np.sign(rng.normal(size=X.shape[0]) + X[:, 0]).astype(np.float32)
live = LiveBank(
    ArraySource(X, y, 16), jnp.asarray([1.0, 4.0]), ckpt_dir=ckpt_dir,
    n_sub_banks=2, rotate_every=3, swap_every=2, sleep=lambda s: None,
    failpoints=[("post_fold", 3)] if mode == "crash" else None,
)
try:
    live.run()
except InjectedFailure:
    os._exit(7)  # hard exit: no unwinding, no cleanup — a real dead process
ckpt.save(out_dir, live.serving_bank(), meta={"stats": live.stats.durable()})
print("DONE")
"""


@pytest.mark.slow
def test_process_crash_and_relaunch_bit_exact(tmp_path):
    """The trainer PROCESS dies (os._exit mid-run, nothing flushed) and a
    fresh process resumes from the on-disk checkpoint: final bank and
    durable stats equal a process that never crashed."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )

    def launch(ckpt_dir, out_dir, mode):
        return subprocess.run(
            [sys.executable, "-c", _SUBPROC, str(ckpt_dir), str(out_dir), mode],
            env=env, capture_output=True, text=True, timeout=300,
        )

    crashed = launch(tmp_path / "ck", tmp_path / "out", "crash")
    assert crashed.returncode == 7, crashed.stderr[-4000:]
    relaunch = launch(tmp_path / "ck", tmp_path / "out", "resume")
    assert relaunch.returncode == 0, relaunch.stderr[-4000:]
    assert "DONE" in relaunch.stdout

    clean = launch(tmp_path / "ck_clean", tmp_path / "out_clean", "clean")
    assert clean.returncode == 0, clean.stderr[-4000:]

    target = Ball(
        w=jnp.zeros((2, 4)), r=jnp.zeros((2,)), xi2=jnp.zeros((2,)),
        m=jnp.zeros((2,), jnp.int32),
    )
    recovered = ckpt.restore(str(tmp_path / "out"), target)
    reference = ckpt.restore(str(tmp_path / "out_clean"), target)
    assert _bank_eq(recovered, reference)
    assert (
        ckpt.load_meta(str(tmp_path / "out"))["stats"]
        == ckpt.load_meta(str(tmp_path / "out_clean"))["stats"]
    )
