"""Training loop: loss decreases; microbatch accumulation == full batch."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.optim import adamw
from repro.train import TrainCfg, init_state, make_train_step


def _toy():
    cfg = get_config("internlm2-1.8b", smoke=True)
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, 64, (8, 32)), jnp.int32),
    }
    return cfg, model, batch


def test_loss_decreases():
    cfg, model, batch = _toy()
    tcfg = TrainCfg(peak_lr=1e-3, warmup_steps=2, total_steps=40)
    state = init_state(model, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(make_train_step(model, tcfg))
    losses = []
    for _ in range(15):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatch_equals_fullbatch_grads():
    """A=4 accumulation must match A=1 (same data) up to fp tolerance."""
    cfg, model, batch = _toy()
    s1 = init_state(model, jax.random.PRNGKey(0), TrainCfg(microbatches=1))
    s4 = init_state(model, jax.random.PRNGKey(0), TrainCfg(microbatches=4))
    st1 = jax.jit(make_train_step(model, TrainCfg(microbatches=1)))
    st4 = jax.jit(make_train_step(model, TrainCfg(microbatches=4)))
    o1, m1 = st1(s1, batch)
    o4, m4 = st4(s4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=2e-2)
    for a, b in zip(jax.tree.leaves(o1["params"]), jax.tree.leaves(o4["params"])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=0.1, atol=2e-2
        )


def test_adamw_moments_dtype():
    params = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    st = adamw.init(params, jnp.bfloat16)
    assert st.m["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    newp, st2, metrics = adamw.update(grads, st, params, lr=1e-2)
    assert newp["w"].dtype == jnp.bfloat16
    assert float(metrics["grad_norm"]) > 0
    assert int(st2.step) == 1
