"""Recurrent-state serving consistency: prefill+decode == full forward for
the SSM/hybrid families (exercises the chunked-SSD state handoff, conv
caches, and mLSTM/sLSTM recurrent states)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(3)


def _f32(cfg):
    return type(cfg)(**{**cfg.__dict__, "param_dtype": "float32", "act_dtype": "float32"})


@pytest.mark.parametrize("arch,prefill_len", [
    ("zamba2-1.2b", 32),   # multiple of smoke ssm.chunk -> chunked SSD path
    ("zamba2-1.2b", 17),   # odd length -> sequential scan path
    ("xlstm-125m", 24),
])
def test_prefill_decode_equals_full_forward(arch, prefill_len):
    cfg = _f32(get_config(arch, smoke=True))
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 1, prefill_len + 1
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    logits_pf, cache = model.prefill(
        params, {"tokens": tokens[:, :prefill_len], "max_len": S}
    )
    logits_dec, _ = model.decode_step(params, cache, tokens[:, prefill_len:])

    logits_full, _ = model.prefill(params, {"tokens": tokens, "max_len": S})
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32),
        rtol=5e-3, atol=5e-3,
    )


def test_zamba_decode_chain_matches_prefill():
    """Decode 4 tokens one-by-one; logits at each step match prefills."""
    cfg = _f32(get_config("zamba2-1.2b", smoke=True))
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(1)
    S0, n_extra = 32, 3
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, S0 + n_extra)), jnp.int32)

    _, cache = model.prefill(params, {"tokens": tokens[:, :S0], "max_len": S0 + n_extra})
    for t in range(n_extra):
        logits, cache = model.decode_step(params, cache, tokens[:, S0 + t : S0 + t + 1])
        ref, _ = model.prefill(
            params, {"tokens": tokens[:, : S0 + t + 1], "max_len": S0 + n_extra}
        )
        np.testing.assert_allclose(
            np.asarray(logits, np.float32), np.asarray(ref, np.float32),
            rtol=5e-3, atol=5e-3,
        )


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.0 and balanced-ish routing, most tokens keep
    both experts; a tiny capacity drops most -> outputs shrink."""
    from repro.configs.base import MoECfg
    from repro.models.moe import moe_apply, moe_init

    mcfg_big = MoECfg(n_experts=4, top_k=2, d_ff=16, capacity_factor=2.0)
    mcfg_tiny = MoECfg(n_experts=4, top_k=2, d_ff=16, capacity_factor=0.05)
    p = moe_init(KEY, 8, mcfg_big, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 8))
    out_big, _ = moe_apply(p, x, mcfg_big)
    out_tiny, _ = moe_apply(p, x, mcfg_tiny)
    n_big = float(jnp.linalg.norm(out_big))
    n_tiny = float(jnp.linalg.norm(out_tiny))
    assert n_tiny < n_big  # dropped tokens contribute zero
    assert np.isfinite(n_tiny) and np.isfinite(n_big)
