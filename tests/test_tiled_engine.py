"""Tiled bank engine: b_tile sweeps, fused lookahead, bf16 tiles, recompiles.

The tiled 2-D grid path must be BIT-EXACT (f32) with the single-tile layout —
same per-lane arithmetic, only the grid decomposition changes — and the fused
in-kernel Algorithm 2 must match the plain-python oracle in ref.py across
(B, N, D, L, block_n), including L > block_n boundary flushes and per-model
L. bf16 stream tiles trade bounded precision for half the stream traffic.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import fit_bank, fit_lookahead, fit_ovr, predict_ovr
from repro.kernels import streamsvm_fit, streamsvm_fit_many
from repro.kernels.ref import (
    streamsvm_scan_lookahead_many_ref,
    streamsvm_scan_lookahead_ref,
    streamsvm_scan_many_ref,
)


def _bank_data(b, n, d, seed):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    Y = jnp.asarray(np.sign(rng.normal(size=(b, n))).astype(np.float32))
    cs = jnp.asarray(np.exp(rng.uniform(-1, 4, size=b)).astype(np.float32))
    return X, Y, cs


# ---------------------------------------------------------------------------
# Bank tiling (tentpole): 2-D grid == single-tile, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,n,d,block_n,b_tile", [
    (64, 300, 20, 64, 8),      # 8 tiles: B = 8x the single-tile layout
    (16, 512, 128, 128, 8),
    (11, 257, 33, 64, 8),      # B not a multiple of b_tile (padded lanes)
    (13, 300, 20, 64, 3),      # b_tile not a multiple of 8 (rounded up)
    (24, 200, 40, 256, 8),     # N < block_n, multiple tiles
])
def test_tiled_bit_exact_with_single_tile(b, n, d, block_n, b_tile):
    """The grid decomposition must not change a single bit of f32 output."""
    X, Y, cs = _bank_data(b, n, d, seed=b * n + d)
    one = streamsvm_fit_many(X, Y, cs, block_n=block_n)
    tiled = streamsvm_fit_many(X, Y, cs, block_n=block_n, b_tile=b_tile)
    np.testing.assert_array_equal(np.asarray(tiled.w), np.asarray(one.w))
    np.testing.assert_array_equal(np.asarray(tiled.r), np.asarray(one.r))
    np.testing.assert_array_equal(np.asarray(tiled.xi2), np.asarray(one.xi2))
    np.testing.assert_array_equal(np.asarray(tiled.m), np.asarray(one.m))


def test_tiled_matches_bank_ref_at_8x_tile():
    """B = 8 * b_tile against the pure-jnp oracle (not just self-consistency)."""
    b, n, d, b_tile = 64, 400, 24, 8
    X, Y, cs = _bank_data(b, n, d, seed=17)
    bank = streamsvm_fit_many(X, Y, cs, block_n=128, b_tile=b_tile)
    c_inv = 1.0 / cs
    W0 = Y[:, 0:1] * X[0][None, :]
    w, r, xi2, m = streamsvm_scan_many_ref(
        X[1:], Y[:, 1:], W0, 0.0, c_inv, c_inv, 1, gain=c_inv
    )
    np.testing.assert_allclose(np.asarray(bank.w), np.asarray(w), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(bank.r), np.asarray(r), rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(bank.m), np.asarray(m))


def test_padded_model_rows_stay_inert():
    """B % b_tile != 0 pads model lanes; results must equal the unpadded run
    and contain no NaN/inf leakage from the padded lanes."""
    b, n, d = 10, 333, 18
    X, Y, cs = _bank_data(b, n, d, seed=5)
    plain = streamsvm_fit_many(X, Y, cs, block_n=64)
    padded = streamsvm_fit_many(X, Y, cs, block_n=64, b_tile=8)  # pads to 16
    np.testing.assert_array_equal(np.asarray(padded.w), np.asarray(plain.w))
    np.testing.assert_array_equal(np.asarray(padded.m), np.asarray(plain.m))
    assert np.isfinite(np.asarray(padded.w)).all()
    assert np.isfinite(np.asarray(padded.r)).all()


def test_tiled_restart_equals_continuous_pass():
    """Bank checkpoint/resume with tiling == one continuous tiled pass.

    allclose, not bit-equal: the restart re-derives |w|^2 from the
    checkpointed center while the continuous pass maintains it by recursion
    (identical to the PR 1 restart semantics).
    """
    b, n, d = 20, 514, 41
    X, Y, cs = _bank_data(b, n, d, seed=99)
    full = streamsvm_fit_many(X, Y, cs, block_n=64, b_tile=8)
    head = streamsvm_fit_many(X[:200], Y[:, :200], cs, block_n=64, b_tile=8)
    rest = streamsvm_fit_many(X[200:], Y[:, 200:], cs, head, block_n=64, b_tile=8)
    np.testing.assert_allclose(
        np.asarray(rest.w), np.asarray(full.w), rtol=2e-4, atol=2e-5
    )
    np.testing.assert_array_equal(np.asarray(rest.m), np.asarray(full.m))


# ---------------------------------------------------------------------------
# Fused Algorithm-2 lookahead vs the ref.py oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,n,d,block_n,b_tile,ls", [
    (5, 257, 16, 64, 8, (1, 4, 7, 100, 3)),    # per-model L, L > block_n
    (8, 400, 24, 128, 8, 10),                  # shared L
    (3, 129, 7, 256, None, (2, 300, 5)),       # L >> N: single final flush
    (12, 300, 33, 64, 8, 6),                   # unaligned B/D
])
def test_lookahead_kernel_matches_oracle(b, n, d, block_n, b_tile, ls):
    X, Y, cs = _bank_data(b, n, d, seed=7 * b + n)
    bank = streamsvm_fit_many(
        X, Y, cs, variant="lookahead", lookahead=ls, block_n=block_n,
        b_tile=b_tile,
    )
    c_inv = 1.0 / np.asarray(cs)
    W0 = np.asarray(Y[:, 0:1] * X[0][None, :])
    w, r, xi2, m = streamsvm_scan_lookahead_many_ref(
        np.asarray(X[1:]), np.asarray(Y[:, 1:]), W0, 0.0, c_inv, c_inv, 1, ls,
        gain=c_inv,
    )
    np.testing.assert_allclose(np.asarray(bank.w), w, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(bank.r), r, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(bank.xi2), xi2, rtol=1e-3, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(bank.m), m)


def test_lookahead_paper_variant_honors_gain():
    """variant='lookahead-paper' must use the paper-listing slack gain (1.0),
    both through the kernel and through core.fit_lookahead's routing."""
    X, Y, cs = _bank_data(4, 200, 10, seed=37)
    exact = streamsvm_fit_many(X, Y, cs, variant="lookahead", lookahead=5, block_n=64)
    paper = streamsvm_fit_many(
        X, Y, cs, variant="lookahead-paper", lookahead=5, block_n=64
    )
    assert not np.allclose(np.asarray(paper.xi2), np.asarray(exact.xi2))
    c_inv = 1.0 / np.asarray(cs)
    W0 = np.asarray(Y[:, 0:1] * X[0][None, :])
    ones = np.ones_like(c_inv)
    w, r, xi2, m = streamsvm_scan_lookahead_many_ref(
        np.asarray(X[1:]), np.asarray(Y[:, 1:]), W0, 0.0, ones, c_inv, 1, 5,
        gain=ones,
    )
    np.testing.assert_allclose(np.asarray(paper.w), w, rtol=2e-4, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(paper.m), m)
    one = fit_lookahead(X, Y[0], float(cs[0]), 5, variant="paper-listing", block_n=64)
    np.testing.assert_allclose(np.asarray(one.w), w[0], rtol=2e-4, atol=2e-5)


def test_lookahead_one_equals_algorithm_1():
    """L=1 buffers each violator and immediately flushes it: Algorithm 1."""
    X, Y, cs = _bank_data(6, 300, 12, seed=2)
    la = streamsvm_fit_many(X, Y, cs, variant="lookahead", lookahead=1, block_n=64)
    a1 = streamsvm_fit_many(X, Y, cs, block_n=64)
    np.testing.assert_allclose(
        np.asarray(la.w), np.asarray(a1.w), rtol=2e-5, atol=2e-6
    )
    np.testing.assert_array_equal(np.asarray(la.m), np.asarray(a1.m))


def test_lookahead_chunk_boundary_flush_semantics():
    """A chained lookahead fit flushes its windows at the pass boundary; the
    oracle applied chunk by chunk (each with its trailing flush) must agree."""
    b, n, d, L, cut = 4, 360, 10, 6, 150
    X, Y, cs = _bank_data(b, n, d, seed=11)
    head = streamsvm_fit_many(
        X[:cut], Y[:, :cut], cs, variant="lookahead", lookahead=L, block_n=64
    )
    rest = streamsvm_fit_many(
        X[cut:], Y[:, cut:], cs, head, variant="lookahead", lookahead=L,
        block_n=64,
    )
    c_inv = 1.0 / np.asarray(cs)
    W0 = np.asarray(Y[:, 0:1] * X[0][None, :])
    w, r, xi2, m = streamsvm_scan_lookahead_many_ref(
        np.asarray(X[1:cut]), np.asarray(Y[:, 1:cut]), W0, 0.0, c_inv, c_inv,
        1, L, gain=c_inv,
    )
    w, r, xi2, m = streamsvm_scan_lookahead_many_ref(
        np.asarray(X[cut:]), np.asarray(Y[:, cut:]), w, r, xi2, c_inv, m, L,
        gain=c_inv,
    )
    np.testing.assert_allclose(np.asarray(rest.w), w, rtol=2e-4, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(rest.m), m)


def test_fit_lookahead_routes_to_engine():
    """core.fit_lookahead default engine is the fused kernel; single model
    must match the single-model oracle."""
    rng = np.random.default_rng(21)
    X = jnp.asarray(rng.normal(size=(400, 14)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=400)).astype(np.float32))
    ball = fit_lookahead(X, y, 10.0, 8)
    w, r, xi2, m = streamsvm_scan_lookahead_ref(
        np.asarray(X[1:]), np.asarray(y[1:]), np.asarray(y[0] * X[0]),
        0.0, 0.1, 0.1, 1, 8, gain=np.float32(0.1),
    )
    np.testing.assert_allclose(np.asarray(ball.w), w, rtol=2e-4, atol=2e-5)
    assert int(ball.m) == int(m)
    # the BC window-solve path stays available
    qp = fit_lookahead(X, y, 10.0, 8, engine="qp")
    assert qp.w.shape == ball.w.shape


def test_fit_ovr_lookahead_via_engine():
    """200-class-style OVR with in-kernel lookahead: correct and one-pass."""
    rng = np.random.default_rng(31)
    proto = rng.normal(size=(6, 16)) * 4
    labels = rng.integers(0, 6, size=900)
    X = (rng.normal(size=(900, 16)) + proto[labels]).astype(np.float32)
    X /= np.linalg.norm(X, axis=1, keepdims=True)
    balls = fit_ovr(
        jnp.asarray(X), jnp.asarray(labels), 6, 10.0, lookahead=8, b_tile=8
    )
    pred = predict_ovr(balls, jnp.asarray(X))
    assert float(jnp.mean(pred == jnp.asarray(labels))) > 0.9


# ---------------------------------------------------------------------------
# bf16 stream tiles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b_tile", [None, 8])
def test_bf16_stream_tolerance(b_tile):
    """bf16 tiles halve stream bytes; the result must stay within a few bf16
    eps of the f32 run (labels are exact in bf16, features round)."""
    X, Y, cs = _bank_data(8, 600, 32, seed=13)
    f32 = streamsvm_fit_many(X, Y, cs, block_n=128, b_tile=b_tile)
    bf16 = streamsvm_fit_many(
        X, Y, cs, block_n=128, b_tile=b_tile, stream_dtype="bf16"
    )
    scale = np.abs(np.asarray(f32.w)).max()
    rel = np.abs(np.asarray(bf16.w) - np.asarray(f32.w)).max() / scale
    assert rel < 0.05, rel  # a sequential process: allow a few accumulated ulp
    np.testing.assert_allclose(
        np.asarray(bf16.r), np.asarray(f32.r), rtol=2e-2
    )
    # the models must still be *useful*: sign agreement on the stream
    agree = np.mean(
        np.sign(np.asarray(X) @ np.asarray(f32.w).T)
        == np.sign(np.asarray(X) @ np.asarray(bf16.w).T)
    )
    assert agree > 0.97, agree


def test_bf16_lookahead_runs():
    X, Y, cs = _bank_data(4, 300, 16, seed=23)
    bank = streamsvm_fit_many(
        X, Y, cs, variant="lookahead", lookahead=4, stream_dtype="bf16",
        block_n=64, b_tile=8,
    )
    assert np.isfinite(np.asarray(bank.w)).all()


# ---------------------------------------------------------------------------
# Compile-cache regressions: C sweeps must not recompile
# ---------------------------------------------------------------------------


def test_no_recompile_across_c_values():
    X, Y, _ = _bank_data(4, 96, 9, seed=41)
    y = Y[0]
    start = streamsvm_fit._cache_size()
    for c in (0.5, 3.0, 77.0):
        streamsvm_fit(X, y, c, block_n=32)
    assert streamsvm_fit._cache_size() == start + 1  # one entry, three Cs

    start = streamsvm_fit_many._cache_size()
    for scale in (1.0, 2.0, 10.0):
        streamsvm_fit_many(X, Y, scale * jnp.ones((4,), jnp.float32), block_n=32)
    assert streamsvm_fit_many._cache_size() == start + 1


# ---------------------------------------------------------------------------
# Shape errors survive python -O and carry the offending shapes
# ---------------------------------------------------------------------------


def test_shape_errors_are_value_errors():
    X, Y, cs = _bank_data(4, 64, 8, seed=1)
    with pytest.raises(ValueError, match=r"\(4, 64\)"):
        streamsvm_fit_many(X[:32], Y, cs)  # Y rows don't match N
    with pytest.raises(ValueError, match="sign rows"):
        streamsvm_fit_many(X, Y.T, cs)
    with pytest.raises(ValueError, match=r"y must be \(N,\)"):
        streamsvm_fit(X, Y, 1.0)  # 2-D labels: classic fit_ovr misuse
    with pytest.raises(ValueError, match="variant"):
        streamsvm_fit_many(X, Y, cs, variant="bogus")
    with pytest.raises(ValueError, match="lookahead"):
        streamsvm_fit_many(X, Y, cs, variant="lookahead", lookahead=(2, 2))
    with pytest.raises(ValueError, match="stream_dtype"):
        streamsvm_fit_many(X, Y, cs, stream_dtype="int7")
    with pytest.raises(ValueError, match="variant"):
        fit_lookahead(X, Y[0], 1.0, 4, variant="lookahead")  # fit_bank-ism
    with pytest.raises(ValueError, match="variant"):
        fit_ovr(X, jnp.zeros(64, jnp.int32), 2, 1.0, lookahead=4, variant="exactt")


def test_scan_wrapper_validates_tiling():
    from repro.kernels.streamsvm_scan import streamsvm_scan_many_pallas

    X = jnp.zeros((128, 128), jnp.float32)
    Y = jnp.zeros((8, 128), jnp.float32)
    W0 = jnp.zeros((8, 128), jnp.float32)
    z = jnp.zeros((8,), jnp.float32)
    with pytest.raises(ValueError, match="b_tile"):
        streamsvm_scan_many_pallas(X, Y, W0, z, z, z, z, block_n=128, b_tile=3)
    with pytest.raises(ValueError, match="block_n"):
        streamsvm_scan_many_pallas(X[:100], Y[:, :100], W0, z, z, z, z, block_n=64)
    with pytest.raises(ValueError, match="lookahead_max"):
        streamsvm_scan_many_pallas(
            X, Y, W0, z, z, z, z, block_n=128,
            lookahead=jnp.ones((8,), jnp.int32),
        )
