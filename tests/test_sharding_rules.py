"""Sharding rules produce valid, divisible specs for every architecture.

Runs on the single real device but builds specs against abstract production
meshes (no device allocation — NamedSharding construction requires real
devices, so we validate PartitionSpecs directly against mesh axis sizes).
"""
import numpy as np
import jax
import pytest

from repro.configs import SHAPES, get_config, list_archs
from repro.launch import specs as S
from repro.models import build_model
from repro.sharding import rules as R


class _FakeMesh:
    """Duck-typed mesh: axis names/sizes only (spec validation needs no devices)."""

    def __init__(self, shape_map):
        self.shape = shape_map
        self.axis_names = tuple(shape_map)


MESHES = {
    "single": _FakeMesh({"data": 16, "model": 16}),
    "multi": _FakeMesh({"pod": 2, "data": 16, "model": 16}),
}


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _check_tree(tree, mesh, spec_fn):
    mapping = R.mesh_mapping(mesh)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    n_sharded = 0
    for path, leaf in flat:
        spec = spec_fn(path, leaf, mesh, mapping)
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, axes in zip(leaf.shape, tuple(spec) + (None,) * 8):
            size = _axis_size(mesh, axes)
            assert dim % size == 0, (path, spec, leaf.shape)
            n_sharded += size > 1
    return n_sharded


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mesh_name", ["single", "multi"])
def test_param_specs_valid_and_nontrivial(arch, mesh_name):
    cfg = get_config(arch)
    model = build_model(cfg)
    params = S.params_specs(model)
    mesh = MESHES[mesh_name]
    n_sharded = _check_tree(params, mesh, R.param_spec)
    assert n_sharded > 0, "no parameter got sharded at all"


@pytest.mark.parametrize("arch", ["nemotron-4-340b", "qwen3-moe-235b-a22b", "zamba2-1.2b"])
def test_cache_and_batch_specs_valid(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    mesh = MESHES["single"]
    batch = S.train_batch_specs(cfg, SHAPES["train_4k"])
    _check_tree(batch, mesh, R.batch_spec)
    cache, tokens = S.decode_specs(model, cfg, SHAPES["decode_32k"])
    _check_tree(cache, mesh, R.cache_spec)
    _check_tree(cache, mesh, R.serve_cache_spec)


def test_param_bytes_per_device_fit_hbm():
    """params+moments per device must fit 16 GB on the single-pod mesh for
    the largest configs (bf16 moments where configured)."""
    mesh = MESHES["single"]
    n_dev = 256
    for arch in ("nemotron-4-340b", "qwen3-moe-235b-a22b"):
        cfg = get_config(arch)
        model = build_model(cfg)
        params = S.params_specs(model)
        mapping = R.mesh_mapping(mesh)
        mdt_bytes = 2 if cfg.moment_dtype == "bfloat16" else 4
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
            spec = R.param_spec(path, leaf, mesh, mapping)
            shard = 1
            for axes in spec:
                shard *= _axis_size(mesh, axes)
            per_dev = leaf.size // shard
            total += per_dev * (2 + 2 * mdt_bytes)  # bf16 param + 2 moments
        assert total < 16e9, (arch, total / 1e9)
