"""Baselines sanity + dataset generators (shapes, balance, determinism)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.baselines import (
    fit_batch_l2svm,
    fit_cvm,
    fit_lasvm,
    fit_pegasos,
    fit_perceptron,
)
from repro.data import DATASETS, load_dataset, preprocess_for
from repro.data.preprocess import l2_normalize


def _sep_data(n=2000, d=10, margin=1.5, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=d)
    w /= np.linalg.norm(w)
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = np.sign(X @ w).astype(np.float32)
    X += margin * y[:, None] * w[None, :] * 0.5
    return l2_normalize(X), y


def test_perceptron_separable():
    X, y = _sep_data()
    w, m = fit_perceptron(jnp.asarray(X), jnp.asarray(y))
    assert float(np.mean(np.sign(X @ np.asarray(w)) == y)) > 0.97


def test_pegasos_reasonable():
    X, y = _sep_data()
    w = fit_pegasos(jnp.asarray(X), jnp.asarray(y), lam=1e-4, k=20)
    assert float(np.mean(np.sign(X @ np.asarray(w)) == y)) > 0.95


def test_batch_l2svm_is_strongest():
    X, y = _sep_data(margin=0.8, seed=1)
    wb, obj = fit_batch_l2svm(jnp.asarray(X), jnp.asarray(y), 10.0, iters=800)
    accb = float(np.mean(np.sign(X @ np.asarray(wb)) == y))
    wp, _ = fit_perceptron(jnp.asarray(X), jnp.asarray(y))
    accp = float(np.mean(np.sign(X @ np.asarray(wp)) == y))
    assert accb >= accp - 0.01
    assert np.isfinite(float(obj))


def test_cvm_multipass_converges():
    X, y = _sep_data(n=1500, seed=2)
    res = fit_cvm(X, y, C=10.0, eps=1e-3, max_passes=12, solver_iters=500)
    acc = float(np.mean(np.sign(X @ res["w"]) == y))
    assert acc > 0.95
    assert res["passes"] >= 2  # CVM cannot return in a single pass


def test_lasvm_small():
    X, y = _sep_data(n=800, seed=3)
    w, nsv = fit_lasvm(X, y, C=10.0)
    assert float(np.mean(np.sign(X @ w) == y)) > 0.95
    assert 0 < nsv < 800


def test_lasvm_bias_on_imbalanced():
    rng = np.random.default_rng(9)
    n, d = 2000, 20
    X = np.abs(rng.normal(size=(n, d))).astype(np.float32)  # all-positive
    wtrue = rng.normal(size=d)
    s = X @ wtrue
    y = np.where(s > np.quantile(s, 0.95), 1.0, -1.0).astype(np.float32)  # 5% pos
    X = l2_normalize(X)
    w, b, _ = fit_lasvm(X, y, C=1.0, return_bias=True)
    acc = float(np.mean(np.sign(X @ w + b) == y))
    assert acc > 0.9


@pytest.mark.parametrize("name", sorted(DATASETS))
def test_dataset_spec(name):
    Xtr, ytr, Xte, yte = load_dataset(name, seed=0)
    spec = {
        "synthetic_a": (20000, 200, 2), "synthetic_b": (20000, 200, 3),
        "synthetic_c": (20000, 200, 5), "waveform": (4000, 1000, 21),
        "mnist01": (12665, 2115, 784), "mnist89": (11800, 1983, 784),
        "ijcnn": (35000, 91701, 22), "w3a": (44837, 4912, 300),
    }[name]
    assert Xtr.shape == (spec[0], spec[2])
    assert Xte.shape == (spec[1], spec[2])
    assert set(np.unique(ytr)) <= {-1.0, 1.0}
    # determinism
    Xtr2, *_ = load_dataset(name, seed=0)
    np.testing.assert_array_equal(Xtr, Xtr2)


def test_preprocess_unit_norm():
    Xtr, ytr, Xte, yte = load_dataset("waveform")
    a, b = preprocess_for("waveform", Xtr, Xte)
    np.testing.assert_allclose(np.linalg.norm(a, axis=1), 1.0, rtol=1e-5)
    np.testing.assert_allclose(np.linalg.norm(b, axis=1), 1.0, rtol=1e-5)
