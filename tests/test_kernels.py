"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp ref."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import fit
from repro.kernels import gram, streamsvm_fit
from repro.kernels.ref import gram_ref, streamsvm_scan_ref


@pytest.mark.parametrize("n,d,block_n", [
    (64, 16, 32),
    (500, 100, 128),
    (1000, 300, 256),
    (257, 129, 64),     # deliberately unaligned
])
def test_streamsvm_kernel_vs_ref(n, d, block_n):
    rng = np.random.default_rng(n + d)
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=n)).astype(np.float32))
    ball = streamsvm_fit(X, y, 7.0, block_n=block_n)
    w, r, xi2, m = streamsvm_scan_ref(
        X[1:], y[1:], y[0] * X[0], 0.0, 1.0 / 7.0, 1.0 / 7.0, 1
    )
    np.testing.assert_allclose(np.asarray(ball.w), np.asarray(w), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(ball.r), float(r), rtol=1e-4)
    np.testing.assert_allclose(float(ball.xi2), float(xi2), rtol=1e-3, atol=1e-6)
    assert int(ball.m) == int(m)


def test_streamsvm_kernel_equals_core_fit():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(777, 90)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=777)).astype(np.float32))
    bk = streamsvm_fit(X, y, 3.0)
    bc = fit(X, y, 3.0)
    np.testing.assert_allclose(np.asarray(bk.w), np.asarray(bc.w), rtol=2e-4, atol=2e-5)
    assert int(bk.m) == int(bc.m)


@pytest.mark.parametrize("m,n,d", [(64, 64, 128), (100, 513, 300), (8, 1024, 512)])
@pytest.mark.parametrize("epilogue", ["linear", "rbf"])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_gram_kernel_vs_ref(m, n, d, epilogue, dtype):
    rng = np.random.default_rng(m * n)
    A = jnp.asarray(rng.normal(size=(m, d)).astype(dtype))
    B = jnp.asarray(rng.normal(size=(n, d)).astype(dtype))
    K1 = gram(A, B, epilogue=epilogue, gamma=0.05, bk=128)
    K2 = gram_ref(A, B, epilogue=epilogue, gamma=0.05)
    np.testing.assert_allclose(np.asarray(K1), np.asarray(K2), rtol=2e-3, atol=2e-3)


def test_streamsvm_kernel_continues_from_ball():
    """Kernel restart mid-stream == one continuous pass."""
    rng = np.random.default_rng(5)
    X = jnp.asarray(rng.normal(size=(512, 64)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=512)).astype(np.float32))
    b_half = streamsvm_fit(X[:256], y[:256], 5.0)
    b_rest = streamsvm_fit(X[256:], y[256:], 5.0, ball=b_half)
    b_full = streamsvm_fit(X, y, 5.0)
    np.testing.assert_allclose(np.asarray(b_rest.w), np.asarray(b_full.w), rtol=2e-4, atol=2e-5)
    assert int(b_rest.m) == int(b_full.m)
