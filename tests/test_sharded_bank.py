"""Sharded bank engine: M stream shards x B models in one pass + merge suite.

Three layers:

1. FAST, no devices needed — the engine's sign-0 inert-row contract (the
   padding primitive ``fit_bank_sharded`` is built on) and the bank-
   vectorized ``fold_merge`` (vmap dispatch, live-mask skipping, bank-axis
   equivariance, agreement with an EXPLICIT augmented-space embedding that
   tracks every slack coordinate — the oracle the implicit xi2 recursion is
   checked against).

2. Property tests (optional ``hypothesis`` dependency, like
   test_core_streamsvm_properties.py): permutation-invariance and
   associativity of the merge up to its PROVABLE geometric slack. The fold
   is not pointwise order-independent — but every fold order must (a) agree
   with the explicit embedding, (b) enclose every input ball, (c) land its
   center in the convex hull of the input centers (so any two orders are
   within min(r_a, r_b) of each other), and (d) have radius in
   [R*, 2 R*] for the same R*, so any two orders' radii are within 2x.
   (a)-(d) are theorems, not tuning, so the tests cannot flake under
   hypothesis shrinking.

3. SLOW, 8 host devices (the CI slow job exports
   XLA_FLAGS=--xla_force_host_platform_device_count=8; locally run
   ``XLA_FLAGS=... pytest -m slow tests/test_sharded_bank.py``):
   shard-count invariance of ``fit_bank_sharded`` against the manually
   folded ragged ranges (exact + lookahead, N % n_shards != 0,
   B % b_tile != 0, fully-dead shards), statistical parity with the
   single-device ``fit_bank``, mesh routing of fit_ovr / fit_c_grid /
   fit_chunked_many, checkpoint/resume under a mesh including an elastic
   reshard, and the python -O survival of the shape ValueError.
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import fit_bank, fold_merge, merge_balls, merge_banks
from repro.core.meb import Ball

try:
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def _bank_data(b, n, d, seed):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    Y = jnp.asarray(np.sign(rng.normal(size=(b, n))).astype(np.float32))
    cs = jnp.asarray(np.exp(rng.uniform(-1, 3, size=b)).astype(np.float32))
    return X, Y, cs


def _random_balls(s, b, d, seed):
    """(s,) stacked banks of b models in d dims with positive r / xi2."""
    rng = np.random.default_rng(seed)
    return Ball(
        w=jnp.asarray(rng.normal(size=(s, b, d)).astype(np.float32)),
        r=jnp.asarray(np.abs(rng.normal(size=(s, b))).astype(np.float32)),
        xi2=jnp.asarray(
            (0.01 + np.abs(rng.normal(size=(s, b)))).astype(np.float32)
        ),
        m=jnp.asarray(rng.integers(1, 50, size=(s, b)).astype(np.int32)),
    )


def _explicit_embed(ws, rs, xi2s):
    """Embed S balls with mutually-orthogonal slack blocks explicitly.

    Ball i's slack block is one coordinate (D + i) carrying norm sqrt(xi2_i)
    — a faithful model of disjoint per-shard slack (meb.py docstring).
    Returns (centers (S, D+S), radii (S,)).
    """
    s, d = len(ws), len(ws[0])
    cs = np.zeros((s, d + s), np.float64)
    for i in range(s):
        cs[i, :d] = ws[i]
        cs[i, d + i] = np.sqrt(xi2s[i])
    return cs, np.asarray(rs, np.float64)


def _emerge(c1, r1, c2, r2):
    """merge_balls in explicit coordinates (the numpy oracle)."""
    d = float(np.linalg.norm(c1 - c2))
    if d + r1 <= r2:
        return c2.copy(), r2
    if d + r2 <= r1:
        return c1.copy(), r1
    rj = 0.5 * (r1 + r2 + d)
    t = np.clip((rj - r1) / max(d, 1e-12), 0.0, 1.0)
    return c1 + t * (c2 - c1), rj


def _explicit_fold(centers, radii, order):
    c, r = centers[order[0]].copy(), radii[order[0]]
    for i in order[1:]:
        c, r = _emerge(c, r, centers[i], radii[i])
    return c, r


def _implicit_fold_single(ws, rs, xi2s, order):
    """fold_merge on stacked single balls in the given order."""
    stacked = Ball(
        w=jnp.asarray(np.stack([ws[i] for i in order]), jnp.float32),
        r=jnp.asarray([rs[i] for i in order], jnp.float32),
        xi2=jnp.asarray([xi2s[i] for i in order], jnp.float32),
        m=jnp.ones(len(order), jnp.int32),
    )
    return fold_merge(stacked)


def _check_fold_properties(ws, rs, xi2s, orders, atol=1e-4):
    """Assert the provable merge-fold properties for every order given."""
    centers, radii = _explicit_embed(ws, rs, xi2s)
    scale = max(1.0, float(np.max(np.abs(centers))), float(np.max(radii)))
    tol = atol * scale
    folds = []
    for order in orders:
        c_e, r_e = _explicit_fold(centers, radii, order)
        ball = _implicit_fold_single(ws, rs, xi2s, order)
        # (a) implicit xi2 recursion == explicit slack embedding
        np.testing.assert_allclose(
            np.asarray(ball.w), c_e[: len(ws[0])], rtol=1e-4, atol=tol
        )
        np.testing.assert_allclose(float(ball.r), r_e, rtol=1e-4, atol=tol)
        np.testing.assert_allclose(
            float(ball.xi2),
            float(np.sum(c_e[len(ws[0]):] ** 2)),
            rtol=1e-3,
            atol=tol,
        )
        # (b) enclosure: the fold contains every input ball
        for i in range(len(radii)):
            gap = np.linalg.norm(c_e - centers[i]) + radii[i] - r_e
            assert gap <= tol, (order, i, gap)
        folds.append((c_e, r_e))
    # (c) any two orders: centers within min radius of each other
    # (d) radii within the provable 2x band around R*
    for a in range(len(folds)):
        for b_ in range(a + 1, len(folds)):
            (ca, ra), (cb, rb) = folds[a], folds[b_]
            dist = np.linalg.norm(ca - cb)
            assert dist <= min(ra, rb) + tol, (dist, ra, rb)
            assert max(ra, rb) <= 2.0 * min(ra, rb) + tol, (ra, rb)


# ---------------------------------------------------------------------------
# FAST: engine padding contract (sign-0 rows are exact no-ops)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant,lookahead", [("exact", None), ("lookahead", 4)])
def test_sign0_rows_are_inert(variant, lookahead):
    """Appending (0-feature, 0-sign) rows — fit_bank_sharded's remainder
    padding — must not change a single bit of any model."""
    b, n, d, pad = 6, 257, 12, 31
    X, Y, cs = _bank_data(b, n, d, seed=3)
    plain = fit_bank(X, Y, cs, variant=variant, lookahead=lookahead, block_n=64)
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    Yp = jnp.pad(Y, ((0, 0), (0, pad)))
    padded = fit_bank(Xp, Yp, cs, variant=variant, lookahead=lookahead, block_n=64)
    np.testing.assert_array_equal(np.asarray(padded.w), np.asarray(plain.w))
    np.testing.assert_array_equal(np.asarray(padded.r), np.asarray(plain.r))
    np.testing.assert_array_equal(np.asarray(padded.xi2), np.asarray(plain.xi2))
    np.testing.assert_array_equal(np.asarray(padded.m), np.asarray(plain.m))


def test_sign0_rows_inert_in_ref_oracles():
    """The ref.py oracles honor the same contract (they anchor the kernel)."""
    from repro.kernels.ref import (
        streamsvm_scan_lookahead_ref,
        streamsvm_scan_ref,
    )

    rng = np.random.default_rng(9)
    X = rng.normal(size=(40, 5)).astype(np.float32)
    y = np.sign(rng.normal(size=40)).astype(np.float32)
    y[y == 0] = 1
    Xp = np.concatenate([X, rng.normal(size=(7, 5)).astype(np.float32)])
    yp = np.concatenate([y, np.zeros(7, np.float32)])
    for fn in (
        lambda X_, y_: streamsvm_scan_ref(X_, y_, y_[0] * X_[0], 0.0, 0.1, 0.1, 1),
        lambda X_, y_: streamsvm_scan_lookahead_ref(
            X_, y_, y_[0] * X_[0], 0.0, 0.1, 0.1, 1, 3
        ),
    ):
        w0, r0, xi0, m0 = fn(jnp.asarray(X), jnp.asarray(y))
        w1, r1, xi1, m1 = fn(jnp.asarray(Xp), jnp.asarray(yp))
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w0), rtol=1e-6)
        assert int(m1) == int(m0)


# ---------------------------------------------------------------------------
# FAST: bank-vectorized fold_merge
# ---------------------------------------------------------------------------


def test_fold_merge_bank_matches_per_model_fold():
    """Folding an (S, B, ...) stack == independently folding each model lane."""
    s, b, d = 5, 7, 9
    banks = _random_balls(s, b, d, seed=11)
    folded = fold_merge(banks)
    assert folded.w.shape == (b, d)
    for k in range(b):
        lane = jax.tree.map(lambda x: x[:, k], banks)
        one = fold_merge(lane)
        np.testing.assert_allclose(
            np.asarray(folded.w[k]), np.asarray(one.w), rtol=1e-6, atol=1e-7
        )
        np.testing.assert_allclose(float(folded.r[k]), float(one.r), rtol=1e-6)
        np.testing.assert_allclose(
            float(folded.xi2[k]), float(one.xi2), rtol=1e-5
        )
        assert int(folded.m[k]) == int(one.m)


def test_fold_merge_bank_axis_permutation_equivariance():
    """Model lanes never interact: permuting B commutes with the fold."""
    banks = _random_balls(4, 6, 5, seed=21)
    perm = np.asarray([3, 0, 5, 1, 4, 2])
    direct = fold_merge(banks)
    permuted = fold_merge(jax.tree.map(lambda x: x[:, perm], banks))
    np.testing.assert_allclose(
        np.asarray(permuted.w), np.asarray(direct.w)[perm], rtol=1e-6, atol=1e-7
    )
    np.testing.assert_allclose(
        np.asarray(permuted.r), np.asarray(direct.r)[perm], rtol=1e-6
    )


def test_fold_merge_live_mask_skips_dead_entries():
    """Masked-out shards must be skipped EXACTLY (bit-equal to slicing them
    out) — this is what makes remainder padding shard-count invariant."""
    banks = _random_balls(6, 3, 4, seed=31)
    live = jnp.asarray([True, True, False, True, False, True])
    masked = fold_merge(banks, live=live)
    sliced = fold_merge(jax.tree.map(lambda x: x[np.asarray(live)], banks))
    np.testing.assert_allclose(
        np.asarray(masked.w), np.asarray(sliced.w), rtol=1e-6, atol=1e-7
    )
    np.testing.assert_allclose(
        np.asarray(masked.r), np.asarray(sliced.r), rtol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(masked.xi2), np.asarray(sliced.xi2), rtol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(masked.m), np.asarray(sliced.m))


def test_fold_merge_dead_entry_zero():
    """A dead entry 0 must not contaminate the fold — the fold starts at the
    first LIVE entry (entry 0 could be a garbage placeholder ball)."""
    banks = _random_balls(5, 3, 4, seed=33)
    # poison entry 0 so any accidental inclusion is loud
    banks = Ball(
        w=banks.w.at[0].set(jnp.inf), r=banks.r, xi2=banks.xi2, m=banks.m
    )
    live = jnp.asarray([False, True, False, True, True])
    masked = fold_merge(banks, live=live)
    sliced = fold_merge(jax.tree.map(lambda x: x[np.asarray(live)], banks))
    assert np.isfinite(np.asarray(masked.w)).all()
    np.testing.assert_allclose(
        np.asarray(masked.w), np.asarray(sliced.w), rtol=1e-6, atol=1e-7
    )
    np.testing.assert_array_equal(np.asarray(masked.m), np.asarray(sliced.m))


def test_merge_banks_is_vmapped_merge_balls():
    b1 = jax.tree.map(lambda x: x[0], _random_balls(1, 5, 6, seed=41))
    b2 = jax.tree.map(lambda x: x[0], _random_balls(1, 5, 6, seed=42))
    out = merge_banks(b1, b2)
    for k in range(5):
        one = merge_balls(
            jax.tree.map(lambda x: x[k], b1), jax.tree.map(lambda x: x[k], b2)
        )
        np.testing.assert_allclose(
            np.asarray(out.w[k]), np.asarray(one.w), rtol=1e-6, atol=1e-7
        )
        np.testing.assert_allclose(float(out.r[k]), float(one.r), rtol=1e-6)


def test_merge_is_commutative():
    a = jax.tree.map(lambda x: x[0, 0], _random_balls(1, 1, 8, seed=51))
    b = jax.tree.map(lambda x: x[0, 0], _random_balls(1, 1, 8, seed=52))
    ab, ba = merge_balls(a, b), merge_balls(b, a)
    np.testing.assert_allclose(np.asarray(ab.w), np.asarray(ba.w), rtol=1e-6)
    np.testing.assert_allclose(float(ab.r), float(ba.r), rtol=1e-6)
    np.testing.assert_allclose(float(ab.xi2), float(ba.xi2), rtol=1e-5)


def test_fold_properties_deterministic():
    """Fixed-seed equivalent of the hypothesis properties (coverage must not
    depend on the optional dependency — repo convention)."""
    rng = np.random.default_rng(61)
    s, d = 5, 6
    ws = [rng.normal(size=d).astype(np.float32) for _ in range(s)]
    rs = [float(abs(rng.normal())) for _ in range(s)]
    xi2s = [float(0.01 + abs(rng.normal())) for _ in range(s)]
    orders = [list(range(s)), list(range(s))[::-1], [2, 0, 4, 1, 3]]
    _check_fold_properties(ws, rs, xi2s, orders)


# ---------------------------------------------------------------------------
# Property tests (optional hypothesis dependency)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        s=st.integers(2, 6),
        d=st.integers(1, 8),
        seed=st.integers(0, 10_000),
    )
    def test_fold_merge_permutation_invariant_up_to_tolerance(s, d, seed):
        """Any shard order: same explicit-embedding semantics, encloses all
        inputs, centers within min(r) of each other, radii within 2x."""
        rng = np.random.default_rng(seed)
        ws = [rng.normal(size=d).astype(np.float32) for _ in range(s)]
        rs = [float(abs(rng.normal())) for _ in range(s)]
        xi2s = [float(0.01 + abs(rng.normal())) for _ in range(s)]
        orders = [list(range(s))] + [
            list(rng.permutation(s)) for _ in range(3)
        ]
        _check_fold_properties(ws, rs, xi2s, orders)

    @settings(max_examples=25, deadline=None)
    @given(d=st.integers(1, 8), seed=st.integers(0, 10_000))
    def test_merge_associative_up_to_tolerance(d, seed):
        """merge(merge(a,b),c) vs merge(a,merge(b,c)): both enclose {a,b,c},
        centers within min radius, radii within the provable 2x band."""
        rng = np.random.default_rng(seed)
        ws = [rng.normal(size=d).astype(np.float32) for _ in range(3)]
        rs = [float(abs(rng.normal())) for _ in range(3)]
        xi2s = [float(0.01 + abs(rng.normal())) for _ in range(3)]
        centers, radii = _explicit_embed(ws, rs, xi2s)
        scale = max(1.0, float(np.max(np.abs(centers))), float(np.max(radii)))
        tol = 1e-4 * scale
        cl, rl = _explicit_fold(centers, radii, [0, 1, 2])  # (a+b)+c
        cbc, rbc = _emerge(centers[1], radii[1], centers[2], radii[2])
        cr, rr = _emerge(centers[0], radii[0], cbc, rbc)  # a+(b+c)
        for c_, r_ in ((cl, rl), (cr, rr)):
            for i in range(3):
                gap = np.linalg.norm(c_ - centers[i]) + radii[i] - r_
                assert gap <= tol, (i, gap)
        assert np.linalg.norm(cl - cr) <= min(rl, rr) + tol
        assert max(rl, rr) <= 2.0 * min(rl, rr) + tol

    @settings(max_examples=15, deadline=None)
    @given(
        s=st.integers(2, 5),
        b=st.integers(1, 4),
        d=st.integers(1, 6),
        seed=st.integers(0, 10_000),
    )
    def test_bank_fold_matches_scalar_folds(s, b, d, seed):
        """The bank-vectorized fold is exactly B independent scalar folds."""
        banks = _random_balls(s, b, d, seed=seed)
        folded = fold_merge(banks)
        for k in range(b):
            one = fold_merge(jax.tree.map(lambda x: x[:, k], banks))
            np.testing.assert_allclose(
                np.asarray(folded.w[k]), np.asarray(one.w), rtol=1e-6, atol=1e-7
            )
            np.testing.assert_allclose(float(folded.r[k]), float(one.r), rtol=1e-6)


# ---------------------------------------------------------------------------
# SLOW: 8-device shard-count invariance and mesh routing
# ---------------------------------------------------------------------------


def _need_devices(n):
    if len(jax.devices()) < n:
        pytest.skip(
            f"needs {n} devices (run with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n})"
        )
    return jax.make_mesh((n,), ("data",))


def _manual_ragged_fold(X, Y, cs, n_shards, **kw):
    """Oracle: fit each contiguous ragged range separately, fold the banks."""
    n = X.shape[0]
    shard_n = -(-n // n_shards)
    banks = []
    for k in range(n_shards):
        lo, hi = k * shard_n, min((k + 1) * shard_n, n)
        if lo >= n:
            break
        banks.append(fit_bank(X[lo:hi], Y[:, lo:hi], cs, **kw))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *banks)
    return fold_merge(stacked)


@pytest.mark.slow
@pytest.mark.parametrize(
    "b,n,d,b_tile,variant,lookahead",
    [
        (6, 640, 12, None, "exact", None),     # even split
        (6, 611, 12, None, "exact", None),     # N % n_shards != 0
        (11, 611, 12, 8, "exact", None),       # ... and B % b_tile != 0
        (6, 611, 12, None, "lookahead", 4),    # fused Algorithm 2
        (11, 613, 10, 8, "lookahead", (1, 3, 5, 2, 7, 4, 1, 6, 3, 2, 5)),
    ],
)
def test_fit_bank_sharded_matches_manual_ragged_fold(
    b, n, d, b_tile, variant, lookahead
):
    """The mesh path must equal per-range fits + bank fold — including inert
    remainder padding and padded bank lanes."""
    from repro.core import fit_bank_sharded

    mesh = _need_devices(8)
    X, Y, cs = _bank_data(b, n, d, seed=b + n)
    kw = dict(variant=variant, lookahead=lookahead, block_n=64, b_tile=b_tile)
    out = fit_bank_sharded(X, Y, cs, mesh, **kw)
    ref = _manual_ragged_fold(X, Y, cs, 8, **kw)
    np.testing.assert_allclose(
        np.asarray(out.w), np.asarray(ref.w), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(out.r), np.asarray(ref.r), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(out.xi2), np.asarray(ref.xi2), rtol=1e-4, atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(out.m), np.asarray(ref.m))


@pytest.mark.slow
def test_fit_bank_sharded_dead_shards_masked():
    """N < usable rows per shard count: fully-padded shards must be skipped
    exactly (N=9 on 8 shards -> 3 dead shards of pure padding)."""
    from repro.core import fit_bank_sharded

    mesh = _need_devices(8)
    X, Y, cs = _bank_data(4, 9, 6, seed=7)
    out = fit_bank_sharded(X, Y, cs, mesh, block_n=64)
    ref = _manual_ragged_fold(X, Y, cs, 8, block_n=64)
    np.testing.assert_allclose(
        np.asarray(out.w), np.asarray(ref.w), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(out.m), np.asarray(ref.m))
    assert np.isfinite(np.asarray(out.w)).all()


@pytest.mark.slow
@pytest.mark.parametrize("variant,lookahead", [("exact", None), ("lookahead", 6)])
def test_fit_bank_sharded_vs_single_device_statistical(variant, lookahead):
    """Sharding + merge is a different (lossier) estimator than one
    sequential pass, but must stay in the same model class: per-model sign
    agreement high, merged radius within the 2x enclosure band."""
    from repro.core import fit_bank_sharded

    mesh = _need_devices(8)
    rng = np.random.default_rng(17)
    n, d, b = 2048, 24, 5
    Xn = rng.normal(size=(n, d)).astype(np.float32)
    Xn /= np.linalg.norm(Xn, axis=1, keepdims=True)
    X = jnp.asarray(Xn)
    y = np.sign(rng.normal(size=n) + 2 * Xn[:, 0]).astype(np.float32)
    y[y == 0] = 1
    Y = jnp.asarray(np.tile(y, (b, 1)))
    cs = jnp.asarray([0.5, 1.0, 10.0, 50.0, 100.0], jnp.float32)
    kw = dict(variant=variant, lookahead=lookahead, block_n=128)
    sharded = fit_bank_sharded(X, Y, cs, mesh, **kw)
    single = fit_bank(X, Y, cs, **kw)
    acc_s = np.mean(np.sign(Xn @ np.asarray(sharded.w).T) == y[:, None], axis=0)
    acc_1 = np.mean(np.sign(Xn @ np.asarray(single.w).T) == y[:, None], axis=0)
    assert np.all(np.abs(acc_s - acc_1) < 0.08), (acc_s, acc_1)
    assert np.all(np.asarray(sharded.r) <= 2.0 * np.asarray(single.r) + 1e-5)
    # total core vectors: sum of per-shard counts, bounded by the stream
    assert np.all(np.asarray(sharded.m) <= n)


@pytest.mark.slow
def test_fit_ovr_and_c_grid_route_through_mesh():
    """mesh= on the jit'd wrappers == calling fit_bank_sharded directly."""
    from repro.core import fit_bank_sharded, fit_c_grid, fit_ovr, ovr_signs, predict_ovr

    mesh = _need_devices(8)
    rng = np.random.default_rng(23)
    n, d, k = 900, 16, 6
    proto = rng.normal(size=(k, d)) * 4
    labels = rng.integers(0, k, size=n)
    Xn = (rng.normal(size=(n, d)) + proto[labels]).astype(np.float32)
    Xn /= np.linalg.norm(Xn, axis=1, keepdims=True)
    X, lab = jnp.asarray(Xn), jnp.asarray(labels)

    balls = fit_ovr(X, lab, k, 10.0, mesh=mesh, b_tile=8)
    direct = fit_bank_sharded(
        X, ovr_signs(lab, k), jnp.full((k,), 10.0), mesh, b_tile=8
    )
    np.testing.assert_allclose(
        np.asarray(balls.w), np.asarray(direct.w), rtol=1e-5, atol=1e-6
    )
    # the sharded OVR bank must still classify the clustered stream
    pred = predict_ovr(balls, X)
    assert float(jnp.mean(pred == lab)) > 0.9

    y = jnp.asarray(np.where(labels == 0, 1.0, -1.0).astype(np.float32))
    grid = jnp.asarray([1.0, 10.0, 100.0], jnp.float32)
    gb = fit_c_grid(X, y, grid, mesh=mesh)
    gd = fit_bank_sharded(
        X, jnp.broadcast_to(y[None, :], (3, n)), grid, mesh
    )
    np.testing.assert_allclose(
        np.asarray(gb.w), np.asarray(gd.w), rtol=1e-5, atol=1e-6
    )
    with pytest.raises(ValueError, match="mesh"):
        fit_ovr(X, lab, k, 10.0, mesh=mesh, engine="scan")


@pytest.mark.slow
def test_chunked_many_mesh_resume_same_shard_count_exact():
    """Uninterrupted sharded chunk stream == checkpoint + resume (same mesh):
    the checkpoint carries ONE folded bank, so replay is deterministic."""
    from repro.core import fit_chunked_many
    from repro.data.stream import chunk_stream

    mesh = _need_devices(8)
    rng = np.random.default_rng(29)
    n, d = 803, 9
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = np.sign(rng.normal(size=n) + X[:, 0]).astype(np.float32)
    y[y == 0] = 1
    cs = jnp.asarray([1.0, 10.0, 100.0])
    cont = fit_chunked_many(chunk_stream(X, y, 128), cs, mesh=mesh, block_n=64)
    saved = []
    fit_chunked_many(
        chunk_stream(X, y, 128), cs, mesh=mesh, block_n=64,
        checkpoint_every=256, checkpoint_cb=saved.append,
    )
    first = saved[0]
    assert first.position < n
    assert first.ball.w.shape == (3, d)  # ONE folded bank, not per-shard
    rest = fit_chunked_many(
        chunk_stream(X, y, 128, start=first.position), cs,
        mesh=mesh, block_n=64, resume=first,
    )
    np.testing.assert_allclose(
        np.asarray(rest.ball.w), np.asarray(cont.ball.w), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_array_equal(
        np.asarray(rest.ball.m), np.asarray(cont.ball.m)
    )
    assert rest.position == n


@pytest.mark.slow
def test_chunked_many_mesh_resume_elastic_reshard():
    """Resume the SAME checkpoint on a different shard count: the post-resume
    merge partition differs, so the banks are not bit-equal — but the model
    class must be preserved (high sign agreement on the stream and radii in
    each other's 2x enclosure band)."""
    from repro.core import fit_chunked_many
    from repro.data.stream import chunk_stream

    mesh8 = _need_devices(8)
    mesh4 = jax.make_mesh((4,), ("data",))
    rng = np.random.default_rng(31)
    n, d = 900, 10
    X = rng.normal(size=(n, d)).astype(np.float32)
    X /= np.linalg.norm(X, axis=1, keepdims=True)
    y = np.sign(rng.normal(size=n) + 2 * X[:, 0]).astype(np.float32)
    y[y == 0] = 1
    cs = jnp.asarray([1.0, 10.0, 100.0])
    cont = fit_chunked_many(chunk_stream(X, y, 150), cs, mesh=mesh8, block_n=64)
    saved = []
    fit_chunked_many(
        chunk_stream(X, y, 150), cs, mesh=mesh8, block_n=64,
        checkpoint_every=300, checkpoint_cb=saved.append,
    )
    rest = fit_chunked_many(
        chunk_stream(X, y, 150, start=saved[0].position), cs,
        mesh=mesh4, block_n=64, resume=saved[0],  # ELASTIC: 8 -> 4 shards
    )
    w_c, w_r = np.asarray(cont.ball.w), np.asarray(rest.ball.w)
    cos = np.sum(w_c * w_r, axis=1) / (
        np.linalg.norm(w_c, axis=1) * np.linalg.norm(w_r, axis=1)
    )
    assert np.all(cos > 0.85), cos
    acc_c = np.mean(np.sign(X @ w_c.T) == y[:, None], axis=0)
    acc_r = np.mean(np.sign(X @ w_r.T) == y[:, None], axis=0)
    assert np.all(np.abs(acc_c - acc_r) < 0.06), (acc_c, acc_r)
    r_c, r_r = np.asarray(cont.ball.r), np.asarray(rest.ball.r)
    assert np.all(r_r <= 2.0 * r_c + 1e-5) and np.all(r_c <= 2.0 * r_r + 1e-5)
    assert rest.position == n


@pytest.mark.slow
def test_fit_sharded_shape_error_survives_python_O():
    """The divisibility check must be a ValueError (not a bare assert), so
    `python -O` cannot strip it."""
    script = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import fit_sharded
mesh = jax.make_mesh((8,), ("data",))
X = jnp.zeros((13, 4), jnp.float32)   # 13 % 8 != 0
y = jnp.ones((13,), jnp.float32)
try:
    fit_sharded(X, y, 10.0, mesh)
except ValueError as e:
    msg = str(e)
    assert "(13, 4)" in msg and "8" in msg, msg
    print("VALUE_ERROR_OK")
else:
    raise SystemExit("fit_sharded accepted an indivisible stream")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-O", "-c", script],
        env=env, capture_output=True, text=True, timeout=300,
    )
    assert out.returncode == 0, (
        f"stdout:{out.stdout[-2000:]}\nstderr:{out.stderr[-4000:]}"
    )
    assert "VALUE_ERROR_OK" in out.stdout
