"""Multi-ball engine (streamsvm_fit_many): one data pass, B models.

Parity sweeps against (a) a loop of single-ball Pallas fits and (b) the
pure-jnp bank reference, across (B, N, D, block_n) including unaligned
shapes; bank checkpoint/restart; engine-backed fit_ovr / fit_c_grid vs their
pre-engine scan paths.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import fit_bank, fit_c_grid, fit_ovr
from repro.kernels import streamsvm_fit, streamsvm_fit_many
from repro.kernels.ref import streamsvm_scan_many_ref


def _bank_data(b, n, d, seed):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    Y = jnp.asarray(np.sign(rng.normal(size=(b, n))).astype(np.float32))
    cs = jnp.asarray(np.exp(rng.uniform(-1, 4, size=b)).astype(np.float32))
    return X, Y, cs


@pytest.mark.parametrize("b,n,d,block_n", [
    (8, 300, 20, 64),
    (8, 512, 128, 128),
    (11, 257, 33, 64),    # everything unaligned: B, N, D
    (3, 129, 7, 256),     # N < block_n (single padded block)
    (16, 1000, 90, 256),
])
def test_fit_many_matches_per_ball_loop(b, n, d, block_n):
    X, Y, cs = _bank_data(b, n, d, seed=b * n)
    bank = streamsvm_fit_many(X, Y, cs, block_n=block_n)
    assert bank.w.shape == (b, d)
    for i in range(b):
        single = streamsvm_fit(X, Y[i], float(cs[i]), block_n=block_n)
        np.testing.assert_allclose(
            np.asarray(bank.w[i]), np.asarray(single.w), rtol=2e-4, atol=2e-5
        )
        np.testing.assert_allclose(float(bank.r[i]), float(single.r), rtol=1e-4)
        np.testing.assert_allclose(
            float(bank.xi2[i]), float(single.xi2), rtol=1e-3, atol=1e-6
        )
        assert int(bank.m[i]) == int(single.m)


@pytest.mark.parametrize("b,n,d,block_n", [
    (8, 400, 24, 128),
    (5, 333, 17, 64),
])
@pytest.mark.parametrize("variant", ["exact", "paper-listing"])
def test_fit_many_matches_bank_ref(b, n, d, block_n, variant):
    X, Y, cs = _bank_data(b, n, d, seed=7 * b + n)
    bank = streamsvm_fit_many(X, Y, cs, variant=variant, block_n=block_n)
    c_inv = 1.0 / cs
    gain = c_inv if variant == "exact" else jnp.ones_like(c_inv)
    W0 = Y[:, 0:1] * X[0][None, :]
    w, r, xi2, m = streamsvm_scan_many_ref(
        X[1:], Y[:, 1:], W0, 0.0, gain, c_inv, 1, gain=gain
    )
    np.testing.assert_allclose(np.asarray(bank.w), np.asarray(w), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(bank.r), np.asarray(r), rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(bank.xi2), np.asarray(xi2), rtol=1e-3, atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(bank.m), np.asarray(m))


def test_bank_restart_equals_continuous_pass():
    """Mid-stream bank checkpoint/resume == one continuous pass."""
    b, n, d = 9, 514, 41
    X, Y, cs = _bank_data(b, n, d, seed=99)
    full = streamsvm_fit_many(X, Y, cs, block_n=64)
    for cut in (1, 200, 257, 513):
        head = streamsvm_fit_many(X[:cut], Y[:, :cut], cs, block_n=64)
        rest = streamsvm_fit_many(X[cut:], Y[:, cut:], cs, head, block_n=64)
        np.testing.assert_allclose(
            np.asarray(rest.w), np.asarray(full.w), rtol=2e-4, atol=2e-5
        )
        np.testing.assert_array_equal(np.asarray(rest.m), np.asarray(full.m))


def test_block_size_invariance():
    """The engine result must not depend on the HBM tiling."""
    X, Y, cs = _bank_data(8, 500, 30, seed=5)
    ref = streamsvm_fit_many(X, Y, cs, block_n=32)
    for block_n in (64, 128, 256):
        bank = streamsvm_fit_many(X, Y, cs, block_n=block_n)
        np.testing.assert_allclose(
            np.asarray(bank.w), np.asarray(ref.w), rtol=2e-4, atol=2e-5
        )
        np.testing.assert_array_equal(np.asarray(bank.m), np.asarray(ref.m))


def test_fit_ovr_engine_matches_scan_path():
    rng = np.random.default_rng(17)
    X = jnp.asarray(rng.normal(size=(600, 12)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 8, size=600))
    be = fit_ovr(X, labels, 8, 10.0)
    bs = fit_ovr(X, labels, 8, 10.0, engine="scan")
    np.testing.assert_allclose(np.asarray(be.w), np.asarray(bs.w), rtol=2e-4, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(be.m), np.asarray(bs.m))


def test_fit_c_grid_engine_matches_scan_path():
    rng = np.random.default_rng(23)
    X = jnp.asarray(rng.normal(size=(700, 19)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=700) + X[:, 0]))
    grid = jnp.asarray([0.5, 1.0, 10.0, 100.0, 1000.0])
    ge = fit_c_grid(X, y, grid)
    gs = fit_c_grid(X, y, grid, engine="scan")
    np.testing.assert_allclose(np.asarray(ge.w), np.asarray(gs.w), rtol=2e-4, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(ge.m), np.asarray(gs.m))


def test_fit_bank_continues_from_single_model_states():
    """A bank assembled from heterogeneous per-model states keeps each lane
    independent (no cross-model leakage through the shared Gram tile)."""
    from repro.core import bank_stack

    rng = np.random.default_rng(31)
    X = jnp.asarray(rng.normal(size=(400, 16)).astype(np.float32))
    Y = jnp.asarray(np.sign(rng.normal(size=(8, 400))).astype(np.float32))
    cs = jnp.asarray([0.3, 1.0, 3.0, 10.0, 30.0, 100.0, 300.0, 1000.0])
    singles = [streamsvm_fit(X[:150], Y[i, :150], float(cs[i])) for i in range(8)]
    bank = fit_bank(X[150:], Y[:, 150:], cs, bank_stack(singles))
    for i in range(8):
        cont = streamsvm_fit(X[150:], Y[i, 150:], float(cs[i]), ball=singles[i])
        np.testing.assert_allclose(
            np.asarray(bank.w[i]), np.asarray(cont.w), rtol=2e-4, atol=2e-5
        )
        assert int(bank.m[i]) == int(cont.m)
