"""Fused predict kernel: tiling bit-exactness, epilogues, bf16, recompiles.

The serving grid decomposition must not change a single bit of f32 output —
tiled vs single-tile, ragged Q and B — and every fused epilogue must match
the predict_bank_ref jnp oracle and the core.predict_ovr / predict_c_grid
direct readouts exactly. bf16 query tiles trade bounded precision for half
the query HBM traffic. Serving a NEW bank of the same shape never
recompiles.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    fit_bank,
    ovr_signs,
    predict_c_grid,
    predict_ovr,
)
from repro.core.meb import Ball
from repro.kernels import predict_bank
from repro.kernels.ref import predict_bank_ref


def _qw(q, d, b, seed):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(q, d)).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    return X, W


# ---------------------------------------------------------------------------
# Tiling (tentpole): grid decomposition == single tile, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q,d,b,q_block,b_tile", [
    (512, 64, 64, 128, 8),     # aligned everything, 8 bank tiles
    (300, 33, 37, 128, 8),     # ragged Q and B, unaligned D
    (100, 16, 11, 256, 3),     # Q < q_block; b_tile rounded up to 8
    (257, 128, 48, 64, 16),
])
def test_tiled_scores_bit_exact_with_single_tile(q, d, b, q_block, b_tile):
    X, W = _qw(q, d, b, seed=q + d + b)
    one = predict_bank(X, W)
    tiled = predict_bank(X, W, q_block=q_block, b_tile=b_tile)
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(one))
    assert tiled.shape == (q, b)


def test_scores_bit_exact_with_direct_matmul():
    """The serving acceptance bar: f32 kernel scores == X @ W.T, bitwise —
    including on the quickstart bank shape (D=64, B=600)."""
    for q, d, b, qb, bt in [(300, 64, 600, 128, 64), (129, 40, 21, 64, 8)]:
        X, W = _qw(q, d, b, seed=d * b)
        s = predict_bank(X, W, q_block=qb, b_tile=bt)
        np.testing.assert_array_equal(np.asarray(s), np.asarray(X @ W.T))
        np.testing.assert_array_equal(
            np.asarray(s), np.asarray(predict_bank_ref(X, W))
        )


def test_padded_lanes_and_rows_do_not_leak():
    """Ragged Q % q_block and B % b_tile: outputs carry no padding values."""
    X, W = _qw(70, 10, 13, seed=3)
    s = predict_bank(X, W, q_block=64, b_tile=8)  # pads Q->128, B->16
    assert s.shape == (70, 13)
    assert np.isfinite(np.asarray(s)).all()
    v, i = predict_bank(X, W, epilogue="topk", k=13, q_block=64, b_tile=8)
    assert int(np.asarray(i).max()) <= 12  # padded model lanes never selected
    assert np.isfinite(np.asarray(v)).all()


# ---------------------------------------------------------------------------
# Epilogues vs the oracle and the core readouts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_classes,g,b_tile", [
    (5, 3, 8),       # nc_pad=8: one group per... b_tile//8=1 group per tile
    (10, 4, 40),     # two padded groups (16 lanes) per tile
    (3, 1, None),    # single group, single tile
    (12, 5, 8),      # b_tile < nc_pad: clamps to one group per tile
])
def test_ovr_epilogue_matches_oracle(n_classes, g, b_tile):
    X, W = _qw(150, 20, n_classes * g, seed=n_classes * g)
    cls, margin = predict_bank(
        X, W, epilogue="ovr", n_classes=n_classes, q_block=64, b_tile=b_tile
    )
    rcls, rmargin = predict_bank_ref(X, W, epilogue="ovr", n_classes=n_classes)
    np.testing.assert_array_equal(np.asarray(cls), np.asarray(rcls))
    np.testing.assert_array_equal(np.asarray(margin), np.asarray(rmargin))
    assert cls.dtype == jnp.int32 and cls.shape == (150, g)


def test_ovr_epilogue_parity_with_core_predict_ovr():
    """On a single-group bank the fused ovr argmax IS core.predict_ovr."""
    rng = np.random.default_rng(11)
    proto = rng.normal(size=(7, 18)).astype(np.float32) * 3
    labels = rng.integers(0, 7, size=500)
    X = (rng.normal(size=(500, 18)) + proto[labels]).astype(np.float32)
    X /= np.linalg.norm(X, axis=1, keepdims=True)
    bank = fit_bank(
        jnp.asarray(X), ovr_signs(jnp.asarray(labels), 7), 10.0, b_tile=8
    )
    cls, _ = predict_bank(
        jnp.asarray(X), bank.w, epilogue="ovr", n_classes=7, q_block=128
    )
    np.testing.assert_array_equal(
        np.asarray(cls[:, 0]), np.asarray(predict_ovr(bank, jnp.asarray(X)))
    )


def test_ovr_epilogue_parity_with_core_predict_c_grid():
    """Multi-group bank: fused per-group argmax == core.predict_c_grid."""
    X, W = _qw(200, 24, 30, seed=77)
    bank = Ball(
        w=W, r=jnp.zeros(30), xi2=jnp.zeros(30), m=jnp.ones(30, jnp.int32)
    )
    cls, margin = predict_bank(
        X, W, epilogue="ovr", n_classes=10, q_block=64, b_tile=16
    )
    rcls, rmargin = predict_c_grid(bank, X, 10)
    np.testing.assert_array_equal(np.asarray(cls), np.asarray(rcls))
    np.testing.assert_array_equal(np.asarray(margin), np.asarray(rmargin))


@pytest.mark.parametrize("k,b_tile", [(1, 8), (4, 8), (16, None), (37, 8)])
def test_topk_epilogue_matches_lax_top_k(k, b_tile):
    X, W = _qw(130, 12, 37, seed=k)
    vals, ids = predict_bank(
        X, W, epilogue="topk", k=k, q_block=64, b_tile=b_tile
    )
    rvals, rids = predict_bank_ref(X, W, epilogue="topk", k=k)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rvals))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(rids))


def test_topk_running_state_resets_between_query_tiles():
    """Multiple query tiles share the VMEM running-top-k scratch; tile i+1
    must not inherit tile i's ranking."""
    X, W = _qw(256, 16, 24, seed=5)
    vals, ids = predict_bank(X, W, epilogue="topk", k=3, q_block=64, b_tile=8)
    rvals, rids = predict_bank_ref(X, W, epilogue="topk", k=3)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rvals))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(rids))


# ---------------------------------------------------------------------------
# bf16 query tiles
# ---------------------------------------------------------------------------


def test_bf16_query_tolerance():
    """bf16 query tiles halve query bytes; scores must stay within a few
    bf16 eps of f32 (one rounding per feature, one matmul — no accumulation
    across steps like training)."""
    X, W = _qw(400, 48, 32, seed=9)
    f32 = predict_bank(X, W, q_block=128, b_tile=8)
    bf16 = predict_bank(X, W, q_block=128, b_tile=8, stream_dtype="bf16")
    scale = np.abs(np.asarray(f32)).max()
    rel = np.abs(np.asarray(bf16) - np.asarray(f32)).max() / scale
    assert rel < 0.02, rel
    # rankings must survive the rounding almost everywhere
    agree = np.mean(
        np.argmax(np.asarray(bf16), 1) == np.argmax(np.asarray(f32), 1)
    )
    assert agree > 0.95, agree


def test_bf16_ovr_and_topk_run():
    X, W = _qw(100, 16, 20, seed=4)
    cls, margin = predict_bank(
        X, W, epilogue="ovr", n_classes=5, stream_dtype="bf16", q_block=64
    )
    assert np.isfinite(np.asarray(margin)).all()
    vals, _ = predict_bank(
        X, W, epilogue="topk", k=4, stream_dtype="bf16", q_block=64
    )
    assert np.isfinite(np.asarray(vals)).all()


# ---------------------------------------------------------------------------
# HBM-resident bank: the serving twin of the training engine's ring layout
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q,d,b,q_block,b_tile", [
    (256, 64, 8, 128, 8),      # J=1: tile loads once, stays resident
    (256, 64, 16, 128, 8),     # J=2: slot-pinned
    (384, 33, 24, 128, 8),     # J=3: odd tile count cycling through 2 slots
    (128, 64, 40, 128, 8),     # J=5, single query tile (prefetch chain only)
    (300, 20, 37, 64, 8),      # ragged Q and B
])
def test_hbm_scores_bit_exact_with_vmem(q, d, b, q_block, b_tile):
    """Serving the bank out of ANY/HBM space through the async-copy ring
    must not change a single bit of f32 output."""
    X, W = _qw(q, d, b, seed=q + d + b)
    kw = dict(q_block=q_block, b_tile=b_tile)
    vmem = predict_bank(X, W, bank_resident="vmem", **kw)
    hbm = predict_bank(X, W, bank_resident="hbm", **kw)
    np.testing.assert_array_equal(np.asarray(hbm), np.asarray(vmem))
    np.testing.assert_array_equal(np.asarray(hbm), np.asarray(X @ W.T))


def test_hbm_ovr_and_topk_bit_exact_with_vmem():
    X, W = _qw(200, 24, 30, seed=77)
    for kw in (
        dict(epilogue="ovr", n_classes=10, q_block=64, b_tile=16),
        dict(epilogue="topk", k=7, q_block=64, b_tile=8),
    ):
        v = predict_bank(X, W, bank_resident="vmem", **kw)
        h = predict_bank(X, W, bank_resident="hbm", **kw)
        for a, c in zip(v, h):
            np.testing.assert_array_equal(np.asarray(c), np.asarray(a))


def test_hbm_bf16_query_tiles_bit_exact_with_vmem():
    """bf16 rounds the queries identically in both residencies (the ring
    carries the f32 bank)."""
    X, W = _qw(256, 48, 24, seed=9)
    v = predict_bank(X, W, q_block=128, b_tile=8, stream_dtype="bf16",
                     bank_resident="vmem")
    h = predict_bank(X, W, q_block=128, b_tile=8, stream_dtype="bf16",
                     bank_resident="hbm")
    np.testing.assert_array_equal(np.asarray(h), np.asarray(v))


def test_predict_auto_residency_follows_bank_footprint():
    """auto serves hbm exactly when the full (B, D) f32 bank footprint
    exceeds the budget — the dominant term of the training policy's
    boundary — and the routing never changes the scores."""
    X, W = _qw(100, 64, 512, seed=4)
    base = predict_bank(X, W, q_block=64, b_tile=8)
    dp = 128  # D=64 pads to the 128-lane multiple
    footprint = W.shape[0] * dp * 4  # 256 KiB — dwarfs the per-step set
    from repro.kernels.ops import predict_vmem_bytes

    working = sum(
        predict_vmem_bytes(512, 64, q_block=64, b_tile=8).values()
    )
    assert working < footprint  # the budget window below exists
    over = predict_bank(X, W, q_block=64, b_tile=8,
                        vmem_budget_bytes=footprint - 1)  # -> hbm
    at = predict_bank(X, W, q_block=64, b_tile=8,
                      vmem_budget_bytes=footprint)  # -> vmem
    np.testing.assert_array_equal(np.asarray(over), np.asarray(base))
    np.testing.assert_array_equal(np.asarray(at), np.asarray(base))


def test_predict_preflight_and_residency_errors():
    X, W = _qw(32, 8, 6, seed=0)
    with pytest.raises(ValueError, match="bank_resident"):
        predict_bank(X, W, bank_resident="sram")
    with pytest.raises(ValueError, match="breakdown"):
        predict_bank(X, W, q_block=256, vmem_budget_bytes=1_000)


def test_bank_server_hbm_serves_bit_exact():
    """End-to-end serving twin: an HBM-resident BankServer microbatches to
    the same bits as the vmem one (and as the direct readout)."""
    from repro.serve import BankServer

    X, W = _qw(150, 20, 30, seed=15)
    kw = dict(epilogue="ovr", n_classes=10, q_block=64, b_tile=16)
    h = BankServer(W, bank_resident="hbm", **kw).score(np.asarray(X))
    v = BankServer(W, bank_resident="vmem", **kw).score(np.asarray(X))
    for a, c in zip(v, h):
        np.testing.assert_array_equal(c, a)


# ---------------------------------------------------------------------------
# Compile-cache regression: new bank, same shape -> no recompile
# ---------------------------------------------------------------------------


def test_no_recompile_across_banks_of_same_shape():
    X, W = _qw(64, 16, 8, seed=1)
    start = predict_bank._cache_size()
    for seed in (2, 3, 4):
        _, W2 = _qw(64, 16, 8, seed=seed)
        predict_bank(X, W2, q_block=64, b_tile=8)
    assert predict_bank._cache_size() == start + 1  # one entry, three banks
    # a different epilogue is a new (static) entry, but again only ONE
    for seed in (2, 3):
        _, W2 = _qw(64, 16, 8, seed=seed)
        predict_bank(X, W2, epilogue="topk", k=2, q_block=64, b_tile=8)
    assert predict_bank._cache_size() == start + 2
    # a residency switch is a new (static) entry; swapping banks within the
    # hbm residency is not — hot-swap never stalls on a recompile there either
    for seed in (2, 3):
        _, W2 = _qw(64, 16, 8, seed=seed)
        predict_bank(X, W2, q_block=64, b_tile=8, bank_resident="hbm")
    assert predict_bank._cache_size() == start + 3


# ---------------------------------------------------------------------------
# Shape/argument errors are ValueErrors carrying the shapes
# ---------------------------------------------------------------------------


def test_predict_errors_are_value_errors():
    X, W = _qw(32, 8, 6, seed=0)
    with pytest.raises(ValueError, match="feature axis"):
        predict_bank(X, W[:, :4])
    with pytest.raises(ValueError, match="epilogue"):
        predict_bank(X, W, epilogue="softmax")
    with pytest.raises(ValueError, match="n_classes"):
        predict_bank(X, W, epilogue="ovr")  # missing n_classes
    with pytest.raises(ValueError, match="n_classes"):
        predict_bank(X, W, epilogue="ovr", n_classes=4)  # 6 % 4 != 0
    with pytest.raises(ValueError, match="n_classes"):
        predict_bank(X, W, n_classes=3)  # n_classes without ovr
    with pytest.raises(ValueError, match="k="):
        predict_bank(X, W, epilogue="topk", k=7)  # k > B
    with pytest.raises(ValueError, match="k="):
        predict_bank(X, W, k=2)  # k without topk
    with pytest.raises(ValueError, match="stream_dtype"):
        predict_bank(X, W, stream_dtype="int7")


def test_predict_pallas_wrapper_validates_tiling():
    from repro.kernels.predict import predict_bank_pallas

    Q = jnp.zeros((128, 128), jnp.float32)
    W = jnp.zeros((8, 128), jnp.float32)
    bias = jnp.zeros((8, 1), jnp.float32)
    with pytest.raises(ValueError, match="b_tile"):
        predict_bank_pallas(Q, W, bias, q_block=128, b_tile=3)
    with pytest.raises(ValueError, match="q_block"):
        predict_bank_pallas(Q[:100], W, bias, q_block=64)
    with pytest.raises(ValueError, match="bias"):
        predict_bank_pallas(Q, W, bias[:4], q_block=128)
    with pytest.raises(ValueError, match="nc_pad"):
        predict_bank_pallas(Q, W, bias, epilogue="ovr", q_block=128)
