"""Kernelized one-pass bank: core-set engine, serving twin, parity bugfixes.

The load-bearing contracts of this suite:

  - with ``coreset_size >= N`` the bounded-buffer engine NEVER evicts, so it
    must reproduce the dense O(N)-state ``fit_kernelized`` per model (f32
    roundoff only — the engine evaluates kernels through the tiled Pallas
    Gram kernel, the dense fit through one jnp expansion);
  - with a small buffer it must match the plain-numpy row-at-a-time oracle
    ``fit_kernel_bank_ref`` (identical slot indices — the eviction POLICY is
    part of the contract, not just the scores);
  - ``predict_kernel_bank`` / the kernel ``BankServer`` score bit-exact with
    the jnp oracle against the stored core sets (the train->serve parity
    contract of the linear bank, carried to kernel space);
  - ``kernelized.rbf_kernel`` clamps d^2 at 0, matching the Gram epilogue
    exactly on streams with duplicate rows (this PR's numerical-parity fix);
  - ``ops.gram`` keeps its derived tiles sublane/lane aligned for odd M/N
    (this PR's tiling fix — m=100 used to produce a 100-row block that only
    survived in interpret mode).
"""
import os
import subprocess
import sys
import tempfile

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    KernelBank,
    fit_kernel_bank,
    fit_kernelized,
    kernel_bank_decision,
    linear_kernel,
    linear_weights,
    rbf_kernel,
    save_kernel_bank,
)
from repro.core.kernelized import decision_function
from repro.kernels import gram, predict_kernel_bank
from repro.kernels.ops import gram_tiling
from repro.kernels.ref import (
    fit_kernel_bank_ref,
    gram_ref,
    predict_kernel_bank_ref,
)
from repro.serve.bank_server import BankServer


def _bank_data(b, n, d, seed=0, zeros=False):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    Y = np.sign(rng.normal(size=(b, n))).astype(np.float32)
    Y[Y == 0] = 1.0
    if zeros:  # sprinkle inert rows, but keep row 0 live (it seeds the fit)
        mask = rng.random(size=(b, n)) < 0.2
        mask[:, 0] = False
        Y[mask] = 0.0
    cs = np.linspace(0.5, 8.0, b).astype(np.float32)
    return jnp.asarray(X), jnp.asarray(Y), jnp.asarray(cs)


def _kernel_fn(kernel, gamma):
    return rbf_kernel(gamma) if kernel == "rbf" else linear_kernel


# ---------------------------------------------------------------------------
# Tentpole: S >= N reproduces the dense kernelized fit per model
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kernel", ["rbf", "linear"])
@pytest.mark.parametrize("block_n", [8, 32])
def test_full_buffer_matches_dense_fit(kernel, block_n):
    b, n, d = 3, 41, 12
    X, Y, cs = _bank_data(b, n, d, seed=7)
    gamma = 0.7
    kb = fit_kernel_bank(
        X, Y, cs, kernel=kernel, gamma=gamma, coreset_size=n + 5,
        block_n=block_n,
    )
    for bi in range(b):
        dense = fit_kernelized(
            X, Y[bi], float(cs[bi]), _kernel_fn(kernel, gamma)
        )
        alpha = np.zeros(n, np.float32)
        idx = np.asarray(kb.idx[bi])
        coef = np.asarray(kb.coef[bi])
        live = idx >= 0
        alpha[idx[live]] = coef[live]
        np.testing.assert_allclose(
            alpha, np.asarray(dense.alpha), rtol=1e-4, atol=1e-5
        )
        np.testing.assert_allclose(
            float(kb.q[bi]), float(dense.q), rtol=1e-4, atol=1e-6
        )
        np.testing.assert_allclose(
            float(kb.r[bi]), float(dense.r), rtol=1e-4, atol=1e-6
        )
        np.testing.assert_allclose(
            float(kb.xi2[bi]), float(dense.xi2), rtol=1e-3, atol=1e-6
        )
        assert int(kb.m[bi]) == int(dense.m)


def test_full_buffer_decision_matches_dense(seed=11):
    """End to end: served margins == dense decision_function, per model."""
    b, n, d, q = 3, 30, 10, 17
    X, Y, cs = _bank_data(b, n, d, seed=seed)
    rng = np.random.default_rng(seed + 1)
    Q = jnp.asarray(rng.normal(size=(q, d)).astype(np.float32))
    gamma = 0.4
    kb = fit_kernel_bank(
        X, Y, cs, kernel="rbf", gamma=gamma, coreset_size=n, block_n=8
    )
    scores = kernel_bank_decision(kb, Q, kernel="rbf", gamma=gamma)
    for bi in range(b):
        dense = fit_kernelized(X, Y[bi], float(cs[bi]), rbf_kernel(gamma))
        want = decision_function(dense, X, Q, rbf_kernel(gamma))
        np.testing.assert_allclose(
            np.asarray(scores[:, bi]), np.asarray(want), rtol=1e-4, atol=1e-5
        )


def test_full_buffer_linear_weights_match(seed=3):
    """Linear kernel, S >= N: sum_s coef * points is the primal w of the
    dense kernelized fit (linear_weights) — kernel space collapses back to
    the (D,) weight the linear engine would serve."""
    b, n, d = 2, 25, 9
    X, Y, cs = _bank_data(b, n, d, seed=seed)
    kb = fit_kernel_bank(X, Y, cs, kernel="linear", coreset_size=n, block_n=8)
    w_bank = jnp.einsum("bs,bsd->bd", kb.coef, kb.points)
    for bi in range(b):
        dense = fit_kernelized(X, Y[bi], float(cs[bi]), linear_kernel)
        np.testing.assert_allclose(
            np.asarray(w_bank[bi]), np.asarray(linear_weights(dense, X)),
            rtol=1e-4, atol=1e-5,
        )


# ---------------------------------------------------------------------------
# Bounded buffer: engine vs the plain-numpy eviction oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kernel", ["rbf", "linear"])
@pytest.mark.parametrize("coreset_size,block_n", [(4, 8), (8, 16), (16, 8)])
def test_bounded_buffer_matches_ref(kernel, coreset_size, block_n):
    b, n, d = 3, 57, 11
    X, Y, cs = _bank_data(b, n, d, seed=coreset_size, zeros=True)
    gamma = 0.6
    kb = fit_kernel_bank(
        X, Y, cs, kernel=kernel, gamma=gamma, coreset_size=coreset_size,
        block_n=block_n,
    )
    idx, coef, points, q, r, xi2, m = fit_kernel_bank_ref(
        np.asarray(X), np.asarray(Y), np.asarray(cs), kernel=kernel,
        gamma=gamma, coreset_size=coreset_size,
    )
    # The slot trajectory is part of the contract: identical buffers, not
    # just close scores.
    np.testing.assert_array_equal(np.asarray(kb.idx), idx)
    np.testing.assert_allclose(np.asarray(kb.coef), coef, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kb.points), points, rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(kb.q), q, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kb.r), r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kb.xi2), xi2, rtol=1e-3, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(kb.m), m)


def test_inert_rows_do_not_move_state():
    """Sign-0 rows are inert per model (the stream-padding contract)."""
    b, n, d = 2, 33, 7
    X, Y, cs = _bank_data(b, n, d, seed=9)
    Y0 = np.asarray(Y).copy()
    keep = np.ones(n, bool)
    keep[1::3] = False
    keep[0] = True
    Yz = Y0.copy()
    Yz[:, ~keep] = 0.0
    kb_dense = fit_kernel_bank(
        jnp.asarray(np.asarray(X)[keep]), jnp.asarray(Y0[:, keep]), cs,
        kernel="rbf", gamma=0.5, coreset_size=8, block_n=8,
    )
    kb_inert = fit_kernel_bank(
        X, jnp.asarray(Yz), cs, kernel="rbf", gamma=0.5, coreset_size=8,
        block_n=8,
    )
    # Indices differ (they index different streams) but everything the
    # decision function sees must agree.
    np.testing.assert_allclose(
        np.asarray(kb_inert.points), np.asarray(kb_dense.points),
        rtol=1e-6, atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(kb_inert.coef), np.asarray(kb_dense.coef),
        rtol=1e-5, atol=1e-6,
    )
    np.testing.assert_array_equal(
        np.asarray(kb_inert.m), np.asarray(kb_dense.m)
    )


def test_single_row_stream():
    X = jnp.asarray(np.eye(1, 5, dtype=np.float32))
    Y = jnp.asarray(np.ones((2, 1), np.float32))
    kb = fit_kernel_bank(X, Y, 1.0, kernel="rbf", coreset_size=4)
    assert isinstance(kb, KernelBank)
    np.testing.assert_array_equal(np.asarray(kb.m), [1, 1])
    np.testing.assert_array_equal(np.asarray(kb.idx[:, 0]), [0, 0])


def test_c_sweep_does_not_recompile():
    b, n, d = 2, 20, 6
    X, Y, _ = _bank_data(b, n, d, seed=13)
    start = fit_kernel_bank._cache_size()
    for c in (0.5, 2.0, 8.0):
        fit_kernel_bank(
            X, Y, jnp.full((b,), c), kernel="rbf", coreset_size=8, block_n=8
        )
    assert fit_kernel_bank._cache_size() == start + 1


def test_stream_dtype_bf16_close():
    b, n, d = 2, 40, 16
    X, Y, cs = _bank_data(b, n, d, seed=21)
    kb32 = fit_kernel_bank(
        X, Y, cs, kernel="rbf", gamma=0.3, coreset_size=16, block_n=16
    )
    kb16 = fit_kernel_bank(
        X, Y, cs, kernel="rbf", gamma=0.3, coreset_size=16, block_n=16,
        stream_dtype="bf16",
    )
    np.testing.assert_allclose(
        np.asarray(kb16.q), np.asarray(kb32.q), rtol=5e-2, atol=5e-2
    )
    np.testing.assert_allclose(
        np.asarray(kb16.r), np.asarray(kb32.r), rtol=5e-2, atol=5e-2
    )


def test_fit_kernel_bank_validation():
    X, Y, cs = _bank_data(2, 10, 4, seed=1)
    with pytest.raises(ValueError, match="kernel"):
        fit_kernel_bank(X, Y, cs, kernel="poly", coreset_size=4)
    with pytest.raises(ValueError, match="coreset_size"):
        fit_kernel_bank(X, Y, cs, kernel="rbf", coreset_size=0)
    with pytest.raises(ValueError, match="variant"):
        fit_kernel_bank(X, Y, cs, kernel="rbf", coreset_size=4, variant="x")
    with pytest.raises(ValueError, match=r"\(B, N\)"):
        fit_kernel_bank(X, Y[:, :-1], cs, kernel="rbf", coreset_size=4)


# ---------------------------------------------------------------------------
# Satellite: rbf_kernel clamp parity with the Gram epilogue (duplicates)
# ---------------------------------------------------------------------------


def test_rbf_kernel_clamp_matches_gram_on_duplicates():
    """Exact duplicate rows make the d^2 expansion go (slightly) negative in
    f32; both the jnp helper and the Pallas epilogue must clamp at 0 so
    K <= 1 with K(x, x) == 1 — the constant-diagonal assumption the MEB
    update relies on."""
    rng = np.random.default_rng(2)
    A = rng.normal(size=(12, 40)).astype(np.float32)
    A[3] = A[0]  # exact duplicates, plus self-pairs on the diagonal
    A[9] = A[4]
    B = A.copy()
    gamma = 2.5
    # The data must actually trigger the bug: the unclamped expansion goes
    # negative somewhere (duplicate or self pair) in f32.
    a2 = np.sum(A * A, 1)
    d2_raw = a2[:, None] + a2[None, :] - 2.0 * (A @ B.T)
    assert d2_raw.min() < 0.0
    K_jnp = rbf_kernel(gamma)(jnp.asarray(A), jnp.asarray(B))
    K_gram = gram(jnp.asarray(A), jnp.asarray(B), epilogue="rbf", gamma=gamma)
    # Post-clamp: K can never exceed kappa = 1 (pre-fix it did, breaking the
    # constant-diagonal assumption); duplicate/self pairs sit at 1 up to the
    # f32 residue of the expansion (the clamp removes only the negative
    # side).
    assert float(jnp.max(K_jnp)) <= 1.0
    assert float(jnp.max(K_gram)) <= 1.0
    np.testing.assert_allclose(
        np.asarray(jnp.diagonal(K_jnp)), 1.0, rtol=0, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(K_jnp), np.asarray(K_gram), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# Satellite: gram derived-tile alignment for odd M/N
# ---------------------------------------------------------------------------


def test_gram_tiling_alignment():
    for m, n in [(1, 1), (7, 100), (100, 200), (257, 513), (8, 128)]:
        bm_, bn_ = gram_tiling(m, n, 256, 256)
        assert bm_ % 8 == 0 and bn_ % 128 == 0, (m, n, bm_, bn_)
        assert bm_ >= min(256, m) and bn_ >= min(256, n)
    assert gram_tiling(1000, 1000, 256, 256) == (256, 256)
    assert gram_tiling(100, 200, 256, 256) == (104, 256)


@pytest.mark.parametrize("m,n,d", [(100, 200, 48), (37, 130, 513), (9, 1, 7)])
@pytest.mark.parametrize("epilogue", ["linear", "rbf"])
def test_gram_odd_shapes_vs_ref(m, n, d, epilogue):
    """Regression: odd M/N used to derive misaligned (non-8/128-multiple)
    block shapes that only interpret mode accepted."""
    rng = np.random.default_rng(m + n)
    A = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    B = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    K1 = gram(A, B, epilogue=epilogue, gamma=0.1)
    K2 = gram_ref(A, B, epilogue=epilogue, gamma=0.1)
    np.testing.assert_allclose(
        np.asarray(K1), np.asarray(K2), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# Serving twin: predict_kernel_bank vs oracle, all epilogues
# ---------------------------------------------------------------------------


def _served_bank(seed=5, b=4, n=48, d=10, s=12, gamma=0.5):
    X, Y, cs = _bank_data(b, n, d, seed=seed)
    kb = fit_kernel_bank(
        X, Y, cs, kernel="rbf", gamma=gamma, coreset_size=s, block_n=16
    )
    rng = np.random.default_rng(seed + 100)
    Q = jnp.asarray(rng.normal(size=(23, d)).astype(np.float32))
    return kb, Q, gamma


def test_predict_kernel_bank_scores_bit_exact():
    kb, Q, gamma = _served_bank()
    got = predict_kernel_bank(Q, kb.points, kb.coef, kernel="rbf", gamma=gamma)
    want = predict_kernel_bank_ref(
        Q, kb.points, kb.coef, kernel="rbf", gamma=gamma
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_predict_kernel_bank_ovr_topk():
    kb, Q, gamma = _served_bank()
    cls, margin = predict_kernel_bank(
        Q, kb.points, kb.coef, kernel="rbf", gamma=gamma, epilogue="ovr",
        n_classes=2,
    )
    cls_r, margin_r = predict_kernel_bank_ref(
        Q, kb.points, kb.coef, kernel="rbf", gamma=gamma, epilogue="ovr",
        n_classes=2,
    )
    np.testing.assert_array_equal(np.asarray(cls), np.asarray(cls_r))
    np.testing.assert_array_equal(np.asarray(margin), np.asarray(margin_r))
    vals, ids = predict_kernel_bank(
        Q, kb.points, kb.coef, kernel="rbf", gamma=gamma, epilogue="topk", k=3
    )
    vals_r, ids_r = predict_kernel_bank_ref(
        Q, kb.points, kb.coef, kernel="rbf", gamma=gamma, epilogue="topk", k=3
    )
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(vals_r))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids_r))


def test_predict_kernel_bank_validation():
    kb, Q, gamma = _served_bank()
    with pytest.raises(ValueError, match="feature axis"):
        predict_kernel_bank(Q[:, :-1], kb.points, kb.coef, kernel="rbf")
    with pytest.raises(ValueError, match=r"\(B, S\)"):
        predict_kernel_bank(Q, kb.points, kb.coef[:-1], kernel="rbf")
    with pytest.raises(ValueError, match="kernel"):
        predict_kernel_bank(Q, kb.points, kb.coef, kernel="poly")
    with pytest.raises(ValueError, match="n_classes"):
        predict_kernel_bank(
            Q, kb.points, kb.coef, kernel="rbf", epilogue="ovr", n_classes=3
        )
    with pytest.raises(ValueError, match="topk"):
        predict_kernel_bank(
            Q, kb.points, kb.coef, kernel="rbf", epilogue="topk", k=99
        )


# ---------------------------------------------------------------------------
# BankServer: kernelized serving, checkpoint round-trip, hot swap
# ---------------------------------------------------------------------------


def test_bank_server_kernel_end_to_end(tmp_path):
    kb, Q, gamma = _served_bank(seed=17)
    path = str(tmp_path / "kb")
    save_kernel_bank(path, kb, kernel="rbf", gamma=gamma, meta={"n_classes": 2})
    srv = BankServer.from_checkpoint(path, q_block=16)
    assert srv.kernel == "rbf" and srv.gamma == gamma
    assert srv.bank_shape == tuple(kb.points.shape)
    got = srv.score(np.asarray(Q))
    want = kernel_bank_decision(kb, Q, kernel="rbf", gamma=gamma)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert srv.stats.finished == 1 and srv.stats.steps == 2  # 23 rows / 16

    # ovr server picks n_classes up from the meta
    srv_ovr = BankServer.from_checkpoint(path, epilogue="ovr", q_block=16)
    assert srv_ovr.n_classes == 2
    cls, margin = srv_ovr.score(np.asarray(Q))
    cls_r, margin_r = predict_kernel_bank_ref(
        Q, kb.points, kb.coef, kernel="rbf", gamma=gamma, epilogue="ovr",
        n_classes=2,
    )
    np.testing.assert_array_equal(cls, np.asarray(cls_r))
    np.testing.assert_array_equal(margin, np.asarray(margin_r))


def test_bank_server_kernel_hot_swap():
    kb, Q, gamma = _served_bank(seed=19)
    srv = BankServer(kb, kernel="rbf", gamma=gamma, q_block=16)
    first = np.asarray(srv.score(np.asarray(Q)))
    kb2 = KernelBank(
        idx=kb.idx, coef=-kb.coef, points=kb.points, q=kb.q, r=kb.r,
        xi2=kb.xi2, m=kb.m,
    )
    srv.swap_bank(kb2)
    second = np.asarray(srv.score(np.asarray(Q)))
    np.testing.assert_array_equal(second, -first)
    assert srv.stats.bank_swaps == 1


def test_bank_server_kernel_validation():
    kb, Q, gamma = _served_bank(seed=23)
    with pytest.raises(ValueError, match="kernel="):
        BankServer(kb)  # KernelBank without kernel=
    with pytest.raises(ValueError, match="KernelBank"):
        BankServer(np.zeros((3, 4), np.float32), kernel="rbf")
    srv = BankServer(kb, kernel="rbf", gamma=gamma)
    with pytest.raises(ValueError, match="KernelBank"):
        srv.swap_bank(np.zeros((3, 4), np.float32))
    small = KernelBank(
        idx=kb.idx[:, :4], coef=kb.coef[:, :4], points=kb.points[:, :4],
        q=kb.q, r=kb.r, xi2=kb.xi2, m=kb.m,
    )
    with pytest.raises(ValueError, match="hot-swap"):
        srv.swap_bank(small)
    lin_srv = BankServer(np.zeros((3, 10), np.float32))
    with pytest.raises(ValueError, match="KernelBank"):
        lin_srv.swap_bank(kb)


# ---------------------------------------------------------------------------
# Satellite: the new ValueErrors survive `python -O` (no bare asserts)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_new_value_errors_survive_python_O():
    """The four guards this PR converted from bare asserts must be
    ValueErrors with shape context, so `python -O` cannot strip them."""
    script = r"""
import numpy as np, jax.numpy as jnp
from repro.kernels.gram import gram_pallas
from repro.core import fit_chunked, fit_chunked_many
from repro.runtime.fault_tolerance import rebalance_ranges

try:  # 1) gram_pallas misaligned operands
    gram_pallas(jnp.zeros((100, 512)), jnp.zeros((256, 512)), interpret=True)
except ValueError as e:
    assert "pre-padded" in str(e) and "A.shape=(100, 512)" in str(e), e
    print("GRAM_OK")

try:  # 2) fit_chunked empty stream
    fit_chunked(iter(()), 1.0)
except ValueError as e:
    assert "empty stream" in str(e), e
    print("CHUNKED_OK")

try:  # 3) fit_chunked_many empty stream
    fit_chunked_many(iter(()), jnp.ones((4,)))
except ValueError as e:
    assert "empty stream" in str(e) and "4-model" in str(e), e
    print("MANY_OK")

try:  # 4) rebalance_ranges with no survivors
    rebalance_ranges([(0, 10), (10, 20)], dead=[0, 1])
except ValueError as e:
    assert "no survivors" in str(e) and "2 shard(s)" in str(e), e
    print("REBALANCE_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-O", "-c", script],
        env=env, capture_output=True, text=True, timeout=300,
    )
    assert out.returncode == 0, (
        f"stdout:{out.stdout[-2000:]}\nstderr:{out.stderr[-4000:]}"
    )
    for token in ("GRAM_OK", "CHUNKED_OK", "MANY_OK", "REBALANCE_OK"):
        assert token in out.stdout, out.stdout


# ---------------------------------------------------------------------------
# This PR: seed-sign validation, deferred seeding, traced gamma, _kdiag
# parity, eviction policies, s_tile chunking + the VMEM byte-model preflight
# ---------------------------------------------------------------------------


def test_seed_sign_zero_raises_naming_rows():
    """Y[b, 0] == 0 used to silently seed model b on a zero center (w=0,
    q=0) and poison every later step. It must now refuse, naming the rows."""
    X, Y, cs = _bank_data(4, 30, 5, seed=70)
    Ybad = np.asarray(Y).copy()
    Ybad[1, 0] = 0.0
    Ybad[3, 0] = 0.0
    with pytest.raises(ValueError) as err:
        fit_kernel_bank(X, jnp.asarray(Ybad), cs, coreset_size=8, block_n=32)
    msg = str(err.value)
    assert "Y[:, 0]" in msg and "[1, 3]" in msg, msg


def test_deferred_seeding_skips_inert_prefix():
    """The engine core (what each mesh shard runs) seeds each model on its first
    LIVE row, so shard-local streams that START with sign-0 padding or inert
    rows stay correct. A fully-inert model must come back as the exact merge
    identity (m=0, r=q=0, idx all -1)."""
    from repro.core.kernel_bank import _fit_kernel_bank

    rng = np.random.default_rng(71)
    b, n, d, S = 3, 60, 5, 8
    X = rng.normal(size=(n, d)).astype(np.float32)
    Y = np.sign(rng.normal(size=(b, n))).astype(np.float32)
    Y[Y == 0] = 1.0
    Y[1, :7] = 0.0   # model 1 seeds on row 7
    Y[2, :] = 0.0    # model 2 never seeds
    cs = np.linspace(0.5, 4.0, b).astype(np.float32)
    kb = _fit_kernel_bank(
        jnp.asarray(X), jnp.asarray(Y), jnp.asarray(cs), 0.6,
        kernel="rbf", coreset_size=S, eviction="smallest-coef",
        variant="exact", block_n=32, s_tile=None, stream_dtype=None,
        interpret=None,
    )
    idx, coef, points, q, r, xi2, m = fit_kernel_bank_ref(
        X, Y, cs, kernel="rbf", gamma=0.6, coreset_size=S
    )
    np.testing.assert_array_equal(np.asarray(kb.idx), idx)
    np.testing.assert_allclose(np.asarray(kb.coef), coef, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kb.q), q, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kb.r), r, rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(kb.m), m)
    assert int(kb.m[1]) >= 1  # seeded despite the sign-0 prefix
    # merge identity for the dead model
    assert int(kb.m[2]) == 0 and float(kb.r[2]) == 0.0 and float(kb.q[2]) == 0.0
    assert np.all(np.asarray(kb.idx)[2] == -1)


def test_gamma_sweep_does_not_recompile():
    """gamma is a TRACED operand of the Gram epilogue now — a bandwidth sweep
    must reuse one executable (it used to recompile per value), and the value
    must still reach the kernel (different gammas -> different banks)."""
    X, Y, cs = _bank_data(2, 50, 4, seed=72)
    fit_kernel_bank(X, Y, cs, coreset_size=8, gamma=0.5, block_n=32)
    start = fit_kernel_bank._cache_size()
    banks = [
        fit_kernel_bank(X, Y, cs, coreset_size=8, gamma=g, block_n=32)
        for g in (0.1, 0.7, 2.0)
    ]
    assert fit_kernel_bank._cache_size() == start
    assert not np.allclose(np.asarray(banks[0].q), np.asarray(banks[2].q))

    kb = banks[0]
    Q = X[:16]
    predict_kernel_bank(Q, kb.points, kb.coef, kernel="rbf", gamma=0.1)
    start_p = predict_kernel_bank._cache_size()
    s_lo = predict_kernel_bank(Q, kb.points, kb.coef, kernel="rbf", gamma=0.1)
    s_hi = predict_kernel_bank(Q, kb.points, kb.coef, kernel="rbf", gamma=5.0)
    assert predict_kernel_bank._cache_size() == start_p
    assert not np.allclose(np.asarray(s_lo), np.asarray(s_hi))


def test_kdiag_matches_gram_diagonal():
    """The K(x, x) diagonal the fit feeds its q-recursion must equal the Gram
    epilogue's own diagonal. The old rbf branch computed exp(-g*max(x2+x2-
    2*x2, 0)) — identically exp(0) — which HAPPENED to be right only because
    K(x, x) = 1 for rbf; it is now the explicit ones vector."""
    from repro.core.kernel_bank import _kdiag

    rng = np.random.default_rng(73)
    X = rng.normal(size=(37, 6)).astype(np.float32)
    X[5] = X[19]  # duplicate rows: the d^2 >= 0 clamp territory
    Xj = jnp.asarray(X)

    kd_rbf = np.asarray(_kdiag(Xj, "rbf"))
    np.testing.assert_array_equal(kd_rbf, np.ones(37, np.float32))
    K = np.asarray(gram(Xj, Xj, epilogue="rbf", gamma=0.7))
    np.testing.assert_allclose(np.diagonal(K), kd_rbf, rtol=1e-6, atol=1e-6)

    kd_lin = np.asarray(_kdiag(Xj, "linear"))
    Kl = np.asarray(gram(Xj, Xj, epilogue="linear"))
    np.testing.assert_allclose(np.diagonal(Kl), kd_lin, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kernel", ["rbf", "linear"])
def test_farthest_point_eviction_matches_ref(kernel):
    """farthest-point keeps the extreme points that carry the ball geometry;
    the slot trajectory must equal the numpy oracle's exactly."""
    b, n, d, S = 3, 120, 6, 8
    X, Y, cs = _bank_data(b, n, d, seed=74, zeros=True)
    kb = fit_kernel_bank(
        X, Y, cs, kernel=kernel, gamma=0.6, coreset_size=S,
        eviction="farthest-point", block_n=32,
    )
    idx, coef, points, q, r, xi2, m = fit_kernel_bank_ref(
        np.asarray(X), np.asarray(Y), np.asarray(cs), kernel=kernel,
        gamma=0.6, coreset_size=S, eviction="farthest-point",
    )
    np.testing.assert_array_equal(np.asarray(kb.idx), idx)
    np.testing.assert_allclose(np.asarray(kb.coef), coef, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kb.q), q, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kb.r), r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kb.xi2), xi2, rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(kb.m), m)


def test_eviction_validation():
    X, Y, cs = _bank_data(2, 10, 4, seed=75)
    with pytest.raises(ValueError, match="eviction"):
        fit_kernel_bank(X, Y, cs, eviction="lru")


@pytest.mark.parametrize("s_tile", [1, 3, 8])
def test_s_tile_is_bit_exact(s_tile):
    """Chunking the K_cs launch over the S axis is pure launch partitioning:
    every state leaf must be BIT-equal to the unchunked fit."""
    X, Y, cs = _bank_data(3, 90, 6, seed=76, zeros=True)
    base = fit_kernel_bank(X, Y, cs, coreset_size=8, gamma=0.8, block_n=32)
    tiled = fit_kernel_bank(
        X, Y, cs, coreset_size=8, gamma=0.8, block_n=32, s_tile=s_tile
    )
    for name, a, b_ in zip(base._fields, base, tiled):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b_), err_msg=name
        )


def test_vmem_preflight_names_s_tile():
    """An over-budget (B * S) core-set operand must fail fast with the knob
    that fixes it, and the byte model must agree that s_tile shrinks it."""
    from repro.kernels.ops import kernel_engine_vmem_bytes

    X, Y, cs = _bank_data(2, 20, 4, seed=77)
    with pytest.raises(ValueError, match="s_tile"):
        fit_kernel_bank(
            X, Y, cs, coreset_size=8, block_n=64, vmem_budget_bytes=100_000
        )
    full = sum(kernel_engine_vmem_bytes(64, 128, coreset_size=64).values())
    tiled = sum(
        kernel_engine_vmem_bytes(64, 128, coreset_size=64, s_tile=8).values()
    )
    assert tiled < full
