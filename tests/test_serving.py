"""Continuous-batching scheduler: exactness + slot utilization."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ContinuousBatcher, Request

KEY = jax.random.PRNGKey(0)


def _reference_generate(model, params, prompt, n_new, max_len=128):
    """Single-request greedy decode — the ground truth per request."""
    logits, state = jax.jit(
        lambda p, b: model.prefill(p, {**b, "max_len": max_len})
    )(params, {"tokens": jnp.asarray(prompt[None, :], jnp.int32)})
    toks = [int(jnp.argmax(logits[0]))]
    dec = jax.jit(model.decode_step)
    for _ in range(n_new - 1):
        logits, state = dec(params, state, jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
    return toks


@pytest.fixture(scope="module")
def xlstm_model():
    cfg = get_config("xlstm-125m", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def test_scheduler_exactness(xlstm_model):
    """Tokens from slot-batched continuous decoding == single-request decode."""
    cfg, model, params = xlstm_model
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8 + 4 * i).astype(np.int32),
                max_new=6)
        for i in range(3)
    ]
    refs = [
        _reference_generate(model, params, r.prompt, r.max_new) for r in reqs
    ]
    batcher = ContinuousBatcher(model, params, n_slots=2)
    stats = batcher.run(reqs)
    assert stats.finished == 3
    for r, ref in zip(reqs, refs):
        assert r.done
        assert r.generated == ref, (r.rid, r.generated, ref)


def test_scheduler_utilization_beats_static(xlstm_model):
    """Mixed-length workload: continuous batching wastes fewer slot-tokens
    than static batching (which holds every slot until the longest
    request finishes)."""
    cfg, model, params = xlstm_model
    rng = np.random.default_rng(1)
    lengths = [2, 4, 16, 16, 4, 2]
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                max_new=n)
        for i, n in enumerate(lengths)
    ]
    batcher = ContinuousBatcher(model, params, n_slots=2)
    stats = batcher.run(reqs)
    assert stats.finished == len(reqs)
    # static batching of (2,4) (16,16) (4,2) pairs: busy = sum(lengths),
    # held = sum(max of each pair * 2)
    static_util = sum(lengths) / (2 * (4 + 16 + 4))
    assert stats.utilization > static_util - 0.05
    assert stats.utilization > 0.7
