"""Kernelized Sec-4.3 merge + mesh-sharded kernel-bank fit (this PR).

Three layers, mirroring test_sharded_bank.py:

1. FAST, no devices — ``merge_kernel_banks`` against the plain-numpy oracle
   ``merge_kernel_banks_ref`` (identical kept-slot indices — the compression
   POLICY is part of the contract), the empty-bank merge identity the
   dead-shard fold relies on, the left-fold equivalence, and the LINEAR-
   kernel cross-check: on banks whose live slots fit the compressed buffer
   (no core-set drop), the kernelized merge must reproduce ``merge_balls``
   on the explicit centers w = sum_s coef[s] p[s] — same r / xi2 / m, q equal
   to |w_join|^2, and the kept (coef, point) pairs reconstructing w_join.

2. Property tests (optional ``hypothesis``, with fixed-seed deterministic
   equivalents — coverage must not depend on the optional dependency): in
   the no-drop linear regime every fold order agrees with the explicit
   slack-block embedding of test_sharded_bank.py, so the provable geometric
   bounds carry over verbatim: every order encloses every input ball, any
   two orders' centers are within min(r) of each other, radii within the 2x
   band. Commutativity holds for the scalars plus decision parity (the
   compressed buffers may keep the same slots in different order).

3. SLOW, 8 host devices (CI exports
   XLA_FLAGS=--xla_force_host_platform_device_count=8):
   ``fit_kernel_bank(..., mesh=)`` against the numpy fold of per-range
   engine fits (ragged N, both evictions, dead shards — GLOBAL idx exact),
   statistical parity with the single-device fit on concentric rings, and
   ``BankServer.from_checkpoint`` serving a sharded-trained bank bit-exact
   (f32) with ``kernel_bank_decision``.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    KernelBank,
    fit_kernel_bank,
    fold_kernel_banks,
    kernel_bank_decision,
    merge_banks,
    merge_kernel_banks,
    save_kernel_bank,
    stack_banks,
    stack_kernel_banks,
)
from repro.core.kernel_bank import _fit_kernel_bank
from repro.core.meb import Ball
from repro.kernels.ref import _kernel_ref, merge_kernel_banks_ref
from repro.serve.bank_server import BankServer

try:
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def _fit_two_banks(kernel, seed, b=3, n=80, d=6, s=8, gamma=0.7):
    """Two realistic banks from disjoint halves of one stream (idx disjoint
    by construction: the second fit's indices are offset by n)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(2 * n, d)).astype(np.float32)
    Y = np.sign(rng.normal(size=(b, 2 * n))).astype(np.float32)
    Y[Y == 0] = 1.0
    cs = np.linspace(0.5, 4.0, b).astype(np.float32)
    kw = dict(kernel=kernel, gamma=gamma, coreset_size=s, block_n=32)
    b1 = fit_kernel_bank(jnp.asarray(X[:n]), jnp.asarray(Y[:, :n]), cs, **kw)
    b2 = fit_kernel_bank(jnp.asarray(X[n:]), jnp.asarray(Y[:, n:]), cs, **kw)
    b2 = b2._replace(idx=jnp.where(b2.idx >= 0, b2.idx + n, b2.idx))
    return b1, b2, gamma


def _empty_bank(b, s, d):
    return KernelBank(
        idx=jnp.full((b, s), -1, jnp.int32),
        coef=jnp.zeros((b, s), jnp.float32),
        points=jnp.zeros((b, s, d), jnp.float32),
        q=jnp.zeros((b,), jnp.float32),
        r=jnp.zeros((b,), jnp.float32),
        xi2=jnp.zeros((b,), jnp.float32),
        m=jnp.zeros((b,), jnp.int32),
    )


def _linear_bank(b, s, d, k_live, seed, idx_base=0):
    """A synthetic LINEAR-consistent bank: q == |sum_s coef[s] p[s]|^2, so the
    implicit RKHS center is the explicit euclidean one and merge_balls is an
    exact oracle. k_live <= s // 2 keeps merges in the no-drop regime."""
    rng = np.random.default_rng(seed)
    idx = np.full((b, s), -1, np.int32)
    coef = np.zeros((b, s), np.float32)
    pts = np.zeros((b, s, d), np.float32)
    for bi in range(b):
        sl = rng.choice(s, size=k_live, replace=False)
        idx[bi, sl] = idx_base + rng.choice(10_000, size=k_live, replace=False)
        coef[bi, sl] = rng.normal(size=k_live).astype(np.float32)
        pts[bi, sl] = rng.normal(size=(k_live, d)).astype(np.float32)
    w = np.einsum("bs,bsd->bd", coef, pts).astype(np.float32)
    return KernelBank(
        idx=jnp.asarray(idx),
        coef=jnp.asarray(coef),
        points=jnp.asarray(pts),
        q=jnp.asarray(np.sum(w * w, axis=1).astype(np.float32)),
        r=jnp.asarray(np.abs(rng.normal(size=b)).astype(np.float32)),
        xi2=jnp.asarray((0.01 + np.abs(rng.normal(size=b))).astype(np.float32)),
        m=jnp.asarray(rng.integers(1, 50, size=b).astype(np.int32)),
    )


def _w_of(bank):
    """Explicit euclidean center of a linear-kernel bank."""
    return np.einsum(
        "bs,bsd->bd", np.asarray(bank.coef), np.asarray(bank.points)
    )


def _as_ball(bank):
    return Ball(
        w=jnp.asarray(_w_of(bank).astype(np.float32)),
        r=bank.r, xi2=bank.xi2, m=bank.m,
    )


def _decision_np(bank, Q, kernel, gamma):
    """sum_s coef[s] k(x, p[s]) per model — free slots carry coef == 0."""
    coef, pts = np.asarray(bank.coef), np.asarray(bank.points)
    return np.stack(
        [
            _kernel_ref(Q, pts[bi], kernel=kernel, gamma=gamma) @ coef[bi]
            for bi in range(coef.shape[0])
        ],
        axis=1,
    )


def _assert_banks_close(got, want7, rtol=1e-4, atol=1e-5):
    idx, coef, points, q, r, xi2, m = want7
    np.testing.assert_array_equal(np.asarray(got.idx), idx)
    np.testing.assert_allclose(np.asarray(got.coef), coef, rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(got.points), points, rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(got.q), q, rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(got.r), r, rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(got.xi2), xi2, rtol=rtol, atol=atol)
    np.testing.assert_array_equal(np.asarray(got.m), m)


# ---------------------------------------------------------------------------
# FAST: merge vs numpy oracle, identity, fold
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kernel", ["rbf", "linear"])
@pytest.mark.parametrize("eviction", ["smallest-coef", "farthest-point"])
def test_merge_matches_ref_oracle(kernel, eviction):
    """Kept-slot indices EXACT, algebra allclose — the compression policy
    (top-S by score, ties to the lower slot) is part of the contract."""
    b1, b2, gamma = _fit_two_banks(kernel, seed=5)
    got = merge_kernel_banks(b1, b2, kernel=kernel, gamma=gamma, eviction=eviction)
    want = merge_kernel_banks_ref(
        b1, b2, kernel=kernel, gamma=gamma, eviction=eviction
    )
    _assert_banks_close(got, want)


def test_merge_empty_bank_is_identity():
    """An m == 0 bank (a fully-padded shard) must merge away exactly: scalars
    bit-equal, the (idx -> coef) slot map preserved, decisions unchanged."""
    b1, _, gamma = _fit_two_banks("rbf", seed=7)
    empty = _empty_bank(*b1.coef.shape, b1.points.shape[-1])
    for got in (
        merge_kernel_banks(b1, empty, kernel="rbf", gamma=gamma),
        merge_kernel_banks(empty, b1, kernel="rbf", gamma=gamma),
    ):
        np.testing.assert_array_equal(np.asarray(got.q), np.asarray(b1.q))
        np.testing.assert_array_equal(np.asarray(got.r), np.asarray(b1.r))
        np.testing.assert_array_equal(np.asarray(got.xi2), np.asarray(b1.xi2))
        np.testing.assert_array_equal(np.asarray(got.m), np.asarray(b1.m))
        # compression may reorder slots (top-S by score): compare the map
        for bi in range(b1.coef.shape[0]):
            want_map = {
                int(i): float(c)
                for i, c in zip(np.asarray(b1.idx[bi]), np.asarray(b1.coef[bi]))
                if i >= 0
            }
            got_map = {
                int(i): float(c)
                for i, c in zip(np.asarray(got.idx[bi]), np.asarray(got.coef[bi]))
                if i >= 0
            }
            assert got_map == want_map, bi
    # and merging two empties stays the identity (dead-shard folds)
    both = merge_kernel_banks(empty, empty, kernel="rbf", gamma=gamma)
    assert int(jnp.sum(both.m)) == 0 and float(jnp.sum(both.q)) == 0.0


def test_fold_kernel_banks_is_left_fold():
    b1, b2, gamma = _fit_two_banks("rbf", seed=9)
    b3 = jax.tree.map(lambda x: x, b1)._replace(
        idx=jnp.where(b1.idx >= 0, b1.idx + 1000, b1.idx), coef=-b1.coef
    )
    folded = fold_kernel_banks([b1, b2, b3], kernel="rbf", gamma=gamma)
    manual = merge_kernel_banks(
        merge_kernel_banks(b1, b2, kernel="rbf", gamma=gamma),
        b3, kernel="rbf", gamma=gamma,
    )
    for name, a, b_ in zip(folded._fields, folded, manual):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_), err_msg=name)
    with pytest.raises(ValueError, match="empty"):
        fold_kernel_banks([], kernel="rbf")
    one = fold_kernel_banks([b1], kernel="rbf", gamma=gamma)
    np.testing.assert_array_equal(np.asarray(one.coef), np.asarray(b1.coef))


def test_merge_linear_no_drop_matches_merge_balls():
    """In the no-drop linear regime the kernelized merge IS merge_balls on
    the explicit centers: same r / xi2 / m, q = |w_join|^2, and the kept
    coefficients reconstruct w_join."""
    b, s, d = 4, 12, 5
    b1 = _linear_bank(b, s, d, k_live=5, seed=11, idx_base=0)
    b2 = _linear_bank(b, s, d, k_live=5, seed=12, idx_base=20_000)
    got = merge_kernel_banks(b1, b2, kernel="linear")
    want = merge_banks(_as_ball(b1), _as_ball(b2))
    np.testing.assert_allclose(
        np.asarray(got.r), np.asarray(want.r), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(got.xi2), np.asarray(want.xi2), rtol=1e-4, atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(got.m), np.asarray(want.m))
    w_join = np.asarray(want.w)
    np.testing.assert_allclose(
        np.asarray(got.q), np.sum(w_join * w_join, axis=1), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(_w_of(got), w_join, rtol=1e-4, atol=1e-5)


def test_merge_commutative_semantics():
    """Swapping the arguments: identical algebra, identical decisions (the
    kept slots may land in a different order)."""
    b1, b2, gamma = _fit_two_banks("rbf", seed=13)
    ab = merge_kernel_banks(b1, b2, kernel="rbf", gamma=gamma)
    ba = merge_kernel_banks(b2, b1, kernel="rbf", gamma=gamma)
    np.testing.assert_allclose(
        np.asarray(ab.q), np.asarray(ba.q), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(ab.r), np.asarray(ba.r), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(ab.xi2), np.asarray(ba.xi2), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(ab.m), np.asarray(ba.m))
    rng = np.random.default_rng(14)
    Q = rng.normal(size=(17, b1.points.shape[-1])).astype(np.float32)
    np.testing.assert_allclose(
        _decision_np(ab, Q, "rbf", gamma),
        _decision_np(ba, Q, "rbf", gamma),
        rtol=1e-4, atol=1e-5,
    )


def test_merge_shape_and_eviction_validation():
    b1, b2, gamma = _fit_two_banks("rbf", seed=15, s=8)
    with pytest.raises(ValueError, match="eviction"):
        merge_kernel_banks(b1, b2, kernel="rbf", eviction="lru")
    small = jax.tree.map(lambda x: x[:, :4] if x.ndim > 1 else x, b2)
    with pytest.raises(ValueError, match="shape"):
        merge_kernel_banks(b1, small, kernel="rbf")


def test_mixing_linear_and_kernel_banks_raises():
    """Every fold/merge entry point refuses Ball/KernelBank mixing with a
    ValueError naming both types — their merge algebras are not
    interchangeable, and silent coercion would serve garbage scores."""
    b1, b2, gamma = _fit_two_banks("rbf", seed=43)
    ball = _as_ball(b1)
    with pytest.raises(ValueError, match=r"Ball.*KernelBank|KernelBank.*Ball"):
        merge_kernel_banks(ball, b2, kernel="rbf", gamma=gamma)
    with pytest.raises(ValueError, match="KernelBank"):
        merge_banks(b1, b2)
    with pytest.raises(ValueError, match="KernelBank"):
        stack_banks([b1, b2])
    with pytest.raises(ValueError, match="Ball"):
        stack_kernel_banks([ball, ball])
    with pytest.raises(ValueError, match=r"Ball.*KernelBank|KernelBank.*Ball"):
        fold_kernel_banks([b1, ball], kernel="rbf", gamma=gamma)


# ---------------------------------------------------------------------------
# Re-compression loss audit: merge_kernel_banks(..., return_dropped=True)
# ---------------------------------------------------------------------------


def test_merge_dropped_mass_exact_zero_when_no_drop():
    """When every live candidate fits the compressed buffer the dropped
    slots are all FREE (coef == 0), so the audit is EXACTLY 0.0 — not
    merely small — and requesting it must not perturb the merge."""
    b1, b2 = _no_drop_banks(2, d=5, seed=23)
    plain = merge_kernel_banks(b1, b2, kernel="linear")
    merged, dropped = merge_kernel_banks(
        b1, b2, kernel="linear", return_dropped=True
    )
    assert dropped.shape == (1,)
    assert float(jnp.sum(dropped)) == 0.0
    for name, a, b_ in zip(plain._fields, plain, merged):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_), err_msg=name)
    # realistic lossy fits: per-model, finite, non-negative
    f1, f2, gamma = _fit_two_banks("rbf", seed=31)
    _, dropped2 = merge_kernel_banks(
        f1, f2, kernel="rbf", gamma=gamma, return_dropped=True
    )
    assert dropped2.shape == (3,)
    d2 = np.asarray(dropped2)
    assert np.isfinite(d2).all() and (d2 >= 0.0).all()


def _pair_at_s(s, d=5, k_live=12, seed=29):
    """The SAME two banks (identical live entries, scalars) embedded into
    buffers of size ``s`` — only the merge's keep budget varies with s, so
    dropped mass is comparable across buffer sizes."""
    rng = np.random.default_rng(seed)
    banks = []
    for t in range(2):
        coef_v = rng.normal(size=k_live).astype(np.float32)
        pts_v = rng.normal(size=(k_live, d)).astype(np.float32)
        r = np.float32(abs(rng.normal()) + 0.5)
        xi2 = np.float32(abs(rng.normal()) + 0.01)
        m = np.int32(rng.integers(1, 50))
        w = coef_v @ pts_v
        idx = np.full((1, s), -1, np.int32)
        coef = np.zeros((1, s), np.float32)
        pts = np.zeros((1, s, d), np.float32)
        idx[0, :k_live] = t * 1000 + np.arange(k_live)
        coef[0, :k_live] = coef_v
        pts[0, :k_live] = pts_v
        banks.append(KernelBank(
            idx=jnp.asarray(idx),
            coef=jnp.asarray(coef),
            points=jnp.asarray(pts),
            q=jnp.asarray([np.float32(w @ w)]),
            r=jnp.asarray([r]),
            xi2=jnp.asarray([xi2]),
            m=jnp.asarray([m]),
        ))
    return banks


def test_merge_dropped_mass_monotone_in_buffer_size():
    """On a fixed pair of banks the dropped |coef| mass is non-increasing
    in the buffer size S (top-S keep sets are nested in S), strictly
    positive while 2*k_live > S, and exactly 0.0 once everything fits."""
    masses = []
    for s in (12, 16, 20, 24, 32):
        b1, b2 = _pair_at_s(s)
        _, dropped = merge_kernel_banks(
            b1, b2, kernel="rbf", gamma=0.7, return_dropped=True
        )
        masses.append(float(jnp.sum(dropped)))
    assert masses[0] > 0.0
    for hi, lo in zip(masses, masses[1:]):
        assert lo <= hi + 1e-5, masses
    assert masses[-2] == 0.0 and masses[-1] == 0.0  # S >= 24 keeps all


def test_fold_dropped_mass_accumulates():
    """fold_kernel_banks sums per-merge losses: zero in the no-drop regime,
    and at least the first pairwise loss on a lossy chain."""
    banks = _no_drop_banks(3, d=5, seed=37)
    _, dropped = fold_kernel_banks(
        banks, kernel="linear", return_dropped=True
    )
    assert dropped.shape == (1,) and float(dropped[0]) == 0.0
    f1, f2, gamma = _fit_two_banks("rbf", seed=41, s=4)
    _, d12 = merge_kernel_banks(
        f1, f2, kernel="rbf", gamma=gamma, return_dropped=True
    )
    assert float(jnp.sum(d12)) > 0.0  # S=4 forces real drops
    _, chain = fold_kernel_banks(
        [f1, f2, f1], kernel="rbf", gamma=gamma, return_dropped=True
    )
    assert np.all(np.asarray(chain) >= np.asarray(d12) - 1e-6)


# ---------------------------------------------------------------------------
# Merge-fold geometric properties (fixed-seed; hypothesis variants below)
# ---------------------------------------------------------------------------


def _explicit_embed_1d(ws, rs, xi2s):
    """test_sharded_bank.py's explicit slack-block embedding (B == 1)."""
    s, d = len(ws), len(ws[0])
    cs = np.zeros((s, d + s), np.float64)
    for i in range(s):
        cs[i, :d] = ws[i]
        cs[i, d + i] = np.sqrt(xi2s[i])
    return cs, np.asarray(rs, np.float64)


def _emerge(c1, r1, c2, r2):
    d = float(np.linalg.norm(c1 - c2))
    if d + r1 <= r2:
        return c2.copy(), r2
    if d + r2 <= r1:
        return c1.copy(), r1
    rj = 0.5 * (r1 + r2 + d)
    t = np.clip((rj - r1) / max(d, 1e-12), 0.0, 1.0)
    return c1 + t * (c2 - c1), rj


def _check_kernel_fold_properties(banks, orders, atol=1e-4):
    """No-drop linear regime: every fold order of ``fold_kernel_banks`` must
    (a) agree with the explicit slack-block embedding, (b) enclose every
    input ball, (c) land any two orders' centers within min(r) of each
    other, (d) keep radii within the provable 2x band."""
    ws = [_w_of(b)[0] for b in banks]
    rs = [float(b.r[0]) for b in banks]
    xi2s = [float(b.xi2[0]) for b in banks]
    centers, radii = _explicit_embed_1d(ws, rs, xi2s)
    d = len(ws[0])
    scale = max(1.0, float(np.max(np.abs(centers))), float(np.max(radii)))
    tol = atol * scale
    folds = []
    for order in orders:
        c_e, r_e = centers[order[0]].copy(), radii[order[0]]
        for i in order[1:]:
            c_e, r_e = _emerge(c_e, r_e, centers[i], radii[i])
        kb = fold_kernel_banks([banks[i] for i in order], kernel="linear")
        # (a) the kernelized fold == the explicit embedding
        np.testing.assert_allclose(
            _w_of(kb)[0], c_e[:d], rtol=1e-4, atol=tol
        )
        np.testing.assert_allclose(float(kb.r[0]), r_e, rtol=1e-4, atol=tol)
        np.testing.assert_allclose(
            float(kb.xi2[0]), float(np.sum(c_e[d:] ** 2)), rtol=1e-3, atol=tol
        )
        np.testing.assert_allclose(
            float(kb.q[0]), float(np.sum(c_e[:d] ** 2)), rtol=1e-3, atol=tol
        )
        # (b) enclosure of every input
        for i in range(len(radii)):
            gap = np.linalg.norm(c_e - centers[i]) + radii[i] - r_e
            assert gap <= tol, (order, i, gap)
        folds.append((c_e, r_e))
    # (c) + (d): cross-order bounds
    for a in range(len(folds)):
        for b_ in range(a + 1, len(folds)):
            (ca, ra), (cb, rb) = folds[a], folds[b_]
            assert np.linalg.norm(ca - cb) <= min(ra, rb) + tol
            assert max(ra, rb) <= 2.0 * min(ra, rb) + tol


def _no_drop_banks(s_banks, d, seed):
    """s_banks single-model linear-consistent banks whose TOTAL live count
    fits one buffer — every fold order is drop-free."""
    s_slots = 4 * s_banks  # 4 live each, buffer holds all of them
    return [
        _linear_bank(1, s_slots, d, k_live=4, seed=seed + i, idx_base=i * 100)
        for i in range(s_banks)
    ]


def test_kernel_fold_properties_deterministic():
    banks = _no_drop_banks(4, d=6, seed=17)
    orders = [[0, 1, 2, 3], [3, 2, 1, 0], [1, 3, 0, 2]]
    _check_kernel_fold_properties(banks, orders)


if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(
        s=st.integers(2, 5),
        d=st.integers(1, 7),
        seed=st.integers(0, 10_000),
    )
    def test_kernel_fold_permutation_invariant_up_to_tolerance(s, d, seed):
        """Any shard order: explicit-embedding semantics, enclosure, centers
        within min(r), radii within 2x — the merge-fold theorems, in RKHS."""
        rng = np.random.default_rng(seed)
        banks = _no_drop_banks(s, d=d, seed=seed)
        orders = [list(range(s))] + [list(rng.permutation(s)) for _ in range(2)]
        _check_kernel_fold_properties(banks, orders)

    @settings(max_examples=15, deadline=None)
    @given(d=st.integers(1, 7), seed=st.integers(0, 10_000))
    def test_kernel_merge_associative_up_to_tolerance(d, seed):
        """(a+b)+c vs a+(b+c): both enclose {a, b, c}; centers within min
        radius; radii within the 2x band."""
        banks = _no_drop_banks(3, d=d, seed=seed)
        left = merge_kernel_banks(
            merge_kernel_banks(banks[0], banks[1], kernel="linear"),
            banks[2], kernel="linear",
        )
        right = merge_kernel_banks(
            banks[0],
            merge_kernel_banks(banks[1], banks[2], kernel="linear"),
            kernel="linear",
        )
        ws = [_w_of(b)[0] for b in banks]
        rs = [float(b.r[0]) for b in banks]
        xi2s = [float(b.xi2[0]) for b in banks]
        centers, radii = _explicit_embed_1d(ws, rs, xi2s)
        scale = max(1.0, float(np.max(np.abs(centers))), float(np.max(radii)))
        tol = 1e-4 * scale
        for kb in (left, right):
            c = np.zeros(centers.shape[1])
            c[: len(ws[0])] = _w_of(kb)[0]
            # slack block norm is tracked only as a scalar: bound with it
            r_ = float(kb.r[0])
            for i in range(3):
                w_gap = np.linalg.norm(c[: len(ws[0])] - centers[i][: len(ws[0])])
                slack = np.sqrt(float(kb.xi2[0]) + xi2s[i])  # orthogonal worst case
                assert np.sqrt(w_gap**2) <= r_ + slack + radii[i] + tol
        rl, rr = float(left.r[0]), float(right.r[0])
        assert max(rl, rr) <= 2.0 * min(rl, rr) + tol
        np.testing.assert_allclose(
            float(left.m[0]), float(right.m[0]), rtol=0, atol=0
        )

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_kernel_merge_commutative_property(seed):
        banks = _no_drop_banks(2, d=5, seed=seed)
        ab = merge_kernel_banks(banks[0], banks[1], kernel="linear")
        ba = merge_kernel_banks(banks[1], banks[0], kernel="linear")
        np.testing.assert_allclose(
            np.asarray(ab.q), np.asarray(ba.q), rtol=1e-5, atol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(ab.r), np.asarray(ba.r), rtol=1e-5, atol=1e-6
        )
        np.testing.assert_allclose(
            _w_of(ab), _w_of(ba), rtol=1e-4, atol=1e-5
        )


# ---------------------------------------------------------------------------
# SLOW: 8-device mesh fit vs numpy fold oracle, rings parity, serving
# ---------------------------------------------------------------------------


def _need_devices(n):
    if len(jax.devices()) < n:
        pytest.skip(
            f"needs {n} devices (run with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n})"
        )
    return jax.make_mesh((n,), ("data",))


def _per_shard_banks(X, Y, cs, n_shards, *, kernel, gamma, coreset_size,
                     eviction, block_n):
    """Per-range ENGINE fits (deferred seeding handles ranges whose first
    rows are inert), slot indices globalized — the fold's inputs."""
    n = X.shape[0]
    shard_n = -(-n // n_shards)
    banks = []
    for k in range(n_shards):
        lo, hi = k * shard_n, min((k + 1) * shard_n, n)
        if lo >= n:
            break
        kb = _fit_kernel_bank(
            jnp.asarray(X[lo:hi]), jnp.asarray(Y[:, lo:hi]), jnp.asarray(cs),
            gamma, kernel=kernel, coreset_size=coreset_size,
            eviction=eviction, variant="exact", block_n=block_n,
            s_tile=None, stream_dtype=None, interpret=None,
        )
        banks.append(kb._replace(idx=jnp.where(kb.idx >= 0, kb.idx + lo, kb.idx)))
    return banks


def _ref_fold(banks, *, kernel, gamma, eviction):
    folded = tuple(banks[0])
    for kb in banks[1:]:
        folded = merge_kernel_banks_ref(
            folded, tuple(kb), kernel=kernel, gamma=gamma, eviction=eviction
        )
    return folded


@pytest.mark.slow
@pytest.mark.parametrize(
    "b,n,d,s,eviction",
    [
        (3, 203, 6, 8, "smallest-coef"),   # ragged N (203 = 8*26 - 5)
        (3, 203, 6, 8, "farthest-point"),
        (2, 9, 5, 4, "smallest-coef"),     # 3 fully-dead shards of padding
    ],
)
def test_fit_kernel_bank_mesh_matches_numpy_fold(b, n, d, s, eviction):
    """Two layers of oracle: (1) the mesh path must be BIT-equal to the
    explicit fold of per-range engine fits (shard_map + all_gather + fold is
    pure plumbing), and (2) the fold must match the numpy Sec-4.3 merge —
    GLOBAL slot indices exact for smallest-coef; farthest-point scores are
    kernel dot products, so ulp-level f32 ties may legitimately keep a
    different near-equidistant slot (the algebra and decisions must still
    agree)."""
    mesh = _need_devices(8)
    rng = np.random.default_rng(n)
    X = rng.normal(size=(n, d)).astype(np.float32)
    Y = np.sign(rng.normal(size=(b, n))).astype(np.float32)
    Y[Y == 0] = 1.0
    cs = np.linspace(0.5, 4.0, b).astype(np.float32)
    kw = dict(kernel="rbf", gamma=0.7, coreset_size=s, eviction=eviction,
              block_n=64)
    out = fit_kernel_bank(
        jnp.asarray(X), jnp.asarray(Y), jnp.asarray(cs), mesh=mesh, **kw
    )
    banks = _per_shard_banks(X, Y, cs, 8, **kw)
    explicit = fold_kernel_banks(
        banks, kernel="rbf", gamma=0.7, eviction=eviction
    )
    # same slot trajectory; floats only ulp-off (the mesh fold runs fused
    # inside shard_map, the explicit one eagerly)
    for name, a, b_ in zip(out._fields, out, explicit):
        a, b_ = np.asarray(a), np.asarray(b_)
        if name in ("idx", "m", "points"):
            np.testing.assert_array_equal(a, b_, err_msg=name)
        else:
            np.testing.assert_allclose(
                a, b_, rtol=1e-6, atol=1e-8, err_msg=name
            )
    want = _ref_fold(banks, kernel="rbf", gamma=0.7, eviction=eviction)
    if eviction == "smallest-coef":
        _assert_banks_close(out, want, rtol=3e-5, atol=1e-5)
    else:
        idx, coef, points, q, r, xi2, m = want
        np.testing.assert_allclose(np.asarray(out.q), q, rtol=3e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(out.r), r, rtol=3e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(out.xi2), xi2, rtol=3e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(out.m), m)
        ref_bank = KernelBank(*map(jnp.asarray, want))
        Q = rng.normal(size=(19, d)).astype(np.float32)
        np.testing.assert_allclose(
            _decision_np(out, Q, "rbf", 0.7),
            _decision_np(ref_bank, Q, "rbf", 0.7),
            rtol=1e-3, atol=1e-4,
        )
    assert np.isfinite(np.asarray(out.q)).all()


@pytest.mark.slow
def test_mesh_statistical_parity_on_rings():
    """Shard + merge is a lossier estimator than one sequential pass, but on
    rbf-separable concentric rings it must stay in the same model class."""
    mesh = _need_devices(8)
    rng = np.random.default_rng(19)
    n, d = 2048, 6
    y = np.where(rng.uniform(size=n) < 0.5, 1.0, -1.0).astype(np.float32)
    rad = np.where(y > 0, 1.0, 2.5)
    ang = rng.uniform(0, 2 * np.pi, size=n)
    X = rng.normal(scale=0.1, size=(n, d)).astype(np.float32)
    X[:, 0] += (rad * np.cos(ang)).astype(np.float32)
    X[:, 1] += (rad * np.sin(ang)).astype(np.float32)
    Y = np.tile(y, (3, 1))
    cs = np.asarray([0.5, 5.0, 50.0], np.float32)  # C sweep; compare the best
    kw = dict(kernel="rbf", gamma=2.0, coreset_size=64, block_n=128)
    single = fit_kernel_bank(jnp.asarray(X), jnp.asarray(Y), jnp.asarray(cs), **kw)
    sharded = fit_kernel_bank(
        jnp.asarray(X), jnp.asarray(Y), jnp.asarray(cs), mesh=mesh, **kw
    )
    acc = []
    for kb in (single, sharded):
        scores = np.asarray(
            kernel_bank_decision(kb, jnp.asarray(X), kernel="rbf", gamma=2.0)
        )
        acc.append(np.mean(np.sign(scores) == y[:, None], axis=0))
    acc_1, acc_s = acc
    assert np.max(acc_1) > 0.9, acc_1  # rings are rbf-separable
    assert abs(np.max(acc_s) - np.max(acc_1)) < 0.08, (acc_s, acc_1)
    # merged radius stays within the 2x enclosure band of the sequential fit
    assert np.all(
        np.asarray(sharded.r) <= 2.0 * np.asarray(single.r) + 1e-5
    )


@pytest.mark.slow
def test_bank_server_serves_sharded_kernel_bank(tmp_path):
    """Sharded-trained kernel banks checkpoint and serve EXACTLY like
    single-device ones: from_checkpoint scores bit-equal (f32) to
    kernel_bank_decision on the same bank."""
    mesh = _need_devices(8)
    rng = np.random.default_rng(21)
    n, d, b, gamma = 300, 6, 3, 0.9
    X = rng.normal(size=(n, d)).astype(np.float32)
    Y = np.sign(rng.normal(size=(b, n))).astype(np.float32)
    Y[Y == 0] = 1.0
    cs = np.linspace(1.0, 8.0, b).astype(np.float32)
    kb = fit_kernel_bank(
        jnp.asarray(X), jnp.asarray(Y), jnp.asarray(cs), mesh=mesh,
        kernel="rbf", gamma=gamma, coreset_size=16,
        eviction="farthest-point", block_n=64,
    )
    path = str(tmp_path / "sharded_kb")
    save_kernel_bank(path, kb, kernel="rbf", gamma=gamma)
    srv = BankServer.from_checkpoint(path, q_block=32)
    assert srv.kernel == "rbf" and srv.gamma == gamma
    Q = rng.normal(size=(64, d)).astype(np.float32)  # 2 full serve steps
    got = np.asarray(srv.score(Q))
    want = np.asarray(
        kernel_bank_decision(kb, jnp.asarray(Q), kernel="rbf", gamma=gamma)
    )
    np.testing.assert_array_equal(got, want)
